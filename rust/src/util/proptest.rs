//! Miniature property-testing harness (offline replacement for `proptest`).
//!
//! A property is a closure from a seeded [`Gen`] to `Result<(), String>`;
//! the runner executes it for many random seeds and, on failure, reports
//! the failing seed so the case can be replayed deterministically:
//!
//! ```
//! use datadiffusion::util::proptest::{property, Gen};
//!
//! property("reverse twice is identity", 200, |g: &mut Gen| {
//!     let xs = g.vec_u64(0..50, 1000);
//!     let mut ys = xs.clone();
//!     ys.reverse();
//!     ys.reverse();
//!     if ys == xs { Ok(()) } else { Err(format!("mismatch for {xs:?}")) }
//! });
//! ```
//!
//! The harness intentionally favours *replayability* over shrinking: every
//! failure message carries the case seed, and `DATADIFF_PROP_SEED` replays
//! a single case under a debugger.

use super::prng::Pcg64;
use std::ops::Range;

/// Random input source handed to properties.
pub struct Gen {
    rng: Pcg64,
    /// Seed of this particular case (for the failure report).
    pub case_seed: u64,
}

impl Gen {
    /// Underlying generator for ad-hoc draws.
    pub fn rng(&mut self) -> &mut Pcg64 {
        &mut self.rng
    }

    /// u64 in [range.start, range.end).
    pub fn u64_in(&mut self, range: Range<u64>) -> u64 {
        assert!(range.start < range.end);
        range.start + self.rng.below(range.end - range.start)
    }

    /// usize in [range.start, range.end).
    pub fn usize_in(&mut self, range: Range<usize>) -> usize {
        self.u64_in(range.start as u64..range.end as u64) as usize
    }

    /// f64 in [lo, hi).
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    /// Bernoulli draw.
    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.chance(p)
    }

    /// Vector of u64 draws with random length in [0, max_len] and values
    /// from `vals`.
    pub fn vec_u64(&mut self, vals: Range<u64>, max_len: usize) -> Vec<u64> {
        let len = self.usize_in(0..max_len + 1);
        (0..len).map(|_| self.u64_in(vals.clone())).collect()
    }

    /// Pick one element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        self.rng.choose(xs)
    }
}

/// Run `cases` random cases of `prop`; panic with the failing seed on the
/// first failure. Set `DATADIFF_PROP_SEED=<seed>` to replay one case.
pub fn property<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    if let Ok(seed_str) = std::env::var("DATADIFF_PROP_SEED") {
        let seed: u64 = seed_str.parse().expect("DATADIFF_PROP_SEED must be u64");
        let mut g = Gen {
            rng: Pcg64::new(seed, 0x9e37),
            case_seed: seed,
        };
        if let Err(msg) = prop(&mut g) {
            panic!("property '{name}' failed on replayed seed {seed}: {msg}");
        }
        return;
    }
    // Derive per-case seeds from the property name so adding properties
    // elsewhere does not perturb this one's cases.
    let mut name_hash = 0xcbf2_9ce4_8422_2325u64;
    for b in name.bytes() {
        name_hash ^= b as u64;
        name_hash = name_hash.wrapping_mul(0x1000_0000_01b3);
    }
    for case in 0..cases {
        let seed = name_hash.wrapping_add(case.wrapping_mul(0x9e37_79b9_7f4a_7c15));
        let mut g = Gen {
            rng: Pcg64::new(seed, 0x9e37),
            case_seed: seed,
        };
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property '{name}' failed (case {case}/{cases}, seed {seed}): {msg}\n\
                 replay with: DATADIFF_PROP_SEED={seed}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        property("tautology", 50, |_g| {
            n += 1;
            Ok(())
        });
        assert_eq!(n, 50);
    }

    #[test]
    #[should_panic(expected = "property 'falsum' failed")]
    fn failing_property_panics_with_seed() {
        property("falsum", 10, |g| {
            let x = g.u64_in(0..100);
            if x < 1000 {
                Err(format!("found {x}"))
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn generators_are_in_range() {
        property("gen ranges", 100, |g| {
            let a = g.u64_in(5..10);
            if !(5..10).contains(&a) {
                return Err(format!("u64_in out of range: {a}"));
            }
            let f = g.f64_in(-1.0, 1.0);
            if !(-1.0..1.0).contains(&f) {
                return Err(format!("f64_in out of range: {f}"));
            }
            let v = g.vec_u64(0..3, 8);
            if v.len() > 8 || v.iter().any(|&x| x >= 3) {
                return Err(format!("vec_u64 out of spec: {v:?}"));
            }
            Ok(())
        });
    }
}
