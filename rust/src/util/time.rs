//! Microsecond-resolution simulation time.
//!
//! The simulator and the coordinator share one clock type, [`Micros`], a
//! monotone `u64` count of microseconds since experiment start. Integer
//! time keeps event ordering exact and runs bit-reproducible (no FP drift
//! in the event queue).

use std::fmt;
use std::ops::{Add, AddAssign, Sub};

/// A point in (or duration of) simulated time, in microseconds.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Default)]
pub struct Micros(pub u64);

impl Micros {
    /// Time zero.
    pub const ZERO: Micros = Micros(0);
    /// The far future; used as a sentinel for "no deadline".
    pub const MAX: Micros = Micros(u64::MAX);

    /// From whole seconds.
    pub fn from_secs(s: u64) -> Micros {
        Micros(s * 1_000_000)
    }

    /// From fractional seconds (rounds to the nearest microsecond).
    pub fn from_secs_f64(s: f64) -> Micros {
        debug_assert!(s >= 0.0, "negative duration: {s}");
        Micros((s * 1e6).round() as u64)
    }

    /// From whole milliseconds.
    pub fn from_millis(ms: u64) -> Micros {
        Micros(ms * 1000)
    }

    /// As fractional seconds.
    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 / 1e6
    }

    /// As whole seconds, truncated (the metrics bucket index).
    pub fn as_secs(self) -> u64 {
        self.0 / 1_000_000
    }

    /// Saturating subtraction.
    pub fn saturating_sub(self, rhs: Micros) -> Micros {
        Micros(self.0.saturating_sub(rhs.0))
    }

    /// Checked addition (None on overflow, e.g. `MAX + x`).
    pub fn checked_add(self, rhs: Micros) -> Option<Micros> {
        self.0.checked_add(rhs.0).map(Micros)
    }
}

impl Add for Micros {
    type Output = Micros;
    fn add(self, rhs: Micros) -> Micros {
        Micros(self.0 + rhs.0)
    }
}

impl AddAssign for Micros {
    fn add_assign(&mut self, rhs: Micros) {
        self.0 += rhs.0;
    }
}

impl Sub for Micros {
    type Output = Micros;
    fn sub(self, rhs: Micros) -> Micros {
        debug_assert!(self.0 >= rhs.0, "time went backwards: {self} - {rhs}");
        Micros(self.0 - rhs.0)
    }
}

impl fmt::Display for Micros {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = self.as_secs_f64();
        if s >= 1.0 {
            write!(f, "{s:.3}s")
        } else {
            write!(f, "{:.3}ms", s * 1e3)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conversions_round_trip() {
        assert_eq!(Micros::from_secs(3).0, 3_000_000);
        assert_eq!(Micros::from_millis(10).0, 10_000);
        assert_eq!(Micros::from_secs_f64(1.5).as_secs_f64(), 1.5);
        assert_eq!(Micros::from_secs(7).as_secs(), 7);
        assert_eq!(Micros(1_999_999).as_secs(), 1);
    }

    #[test]
    fn arithmetic() {
        let a = Micros::from_secs(2);
        let b = Micros::from_millis(500);
        assert_eq!((a + b).as_secs_f64(), 2.5);
        assert_eq!((a - b).as_secs_f64(), 1.5);
        assert_eq!(b.saturating_sub(a), Micros::ZERO);
        assert_eq!(Micros::MAX.checked_add(Micros(1)), None);
    }

    #[test]
    fn ordering_is_numeric() {
        assert!(Micros(5) < Micros(6));
        assert!(Micros::MAX > Micros::from_secs(1_000_000));
    }

    #[test]
    fn display_picks_unit() {
        assert_eq!(format!("{}", Micros::from_secs(2)), "2.000s");
        assert_eq!(format!("{}", Micros::from_millis(2)), "2.000ms");
    }
}
