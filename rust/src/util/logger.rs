//! Minimal `log` backend (offline replacement for `env_logger`).
//!
//! Level is controlled by `DATADIFF_LOG` (`error|warn|info|debug|trace`,
//! default `info`). Output goes to stderr so report tables on stdout stay
//! machine-parseable.

use log::{Level, LevelFilter, Metadata, Record};
use std::io::Write;

struct StderrLogger {
    level: LevelFilter,
}

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= self.level
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let tag = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        let mut err = std::io::stderr().lock();
        let _ = writeln!(err, "[{tag}] {}: {}", record.target(), record.args());
    }

    fn flush(&self) {
        let _ = std::io::stderr().flush();
    }
}

/// Install the logger (idempotent; later calls are no-ops).
pub fn init() {
    let level = match std::env::var("DATADIFF_LOG").as_deref() {
        Ok("error") => LevelFilter::Error,
        Ok("warn") => LevelFilter::Warn,
        Ok("debug") => LevelFilter::Debug,
        Ok("trace") => LevelFilter::Trace,
        _ => LevelFilter::Info,
    };
    let logger = Box::new(StderrLogger { level });
    if log::set_boxed_logger(logger).is_ok() {
        log::set_max_level(level);
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logger smoke test");
    }
}
