//! Minimal leveled logging facade — the offline replacement for the
//! `log` + `env_logger` crates, keeping the crate dependency-free.
//!
//! Level is controlled by `DATADIFF_LOG` (`error|warn|info|debug|trace`,
//! default `info`). Output goes to stderr so report tables on stdout stay
//! machine-parseable. Call sites use the crate-root macros:
//! `crate::info!(...)`, `crate::warn!(...)`, `crate::error!(...)`.

use std::fmt;
use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};

/// Log severity, most severe first.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    /// Unrecoverable or data-losing conditions.
    Error = 1,
    /// Suspicious but recovered conditions (task replays, skipped work).
    Warn = 2,
    /// High-level progress (experiment start/finish).
    Info = 3,
    /// Per-decision detail.
    Debug = 4,
    /// Firehose.
    Trace = 5,
}

impl Level {
    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

/// Current maximum emitted level (atomic: worker threads log lock-free).
static MAX_LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Install the logger: reads `DATADIFF_LOG` and sets the level.
/// Idempotent; later calls simply re-read the environment.
pub fn init() {
    let level = match std::env::var("DATADIFF_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Info,
    };
    set_max_level(level);
}

/// Override the level programmatically (tests, examples).
pub fn set_max_level(level: Level) {
    MAX_LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Would a record at `level` be emitted?
pub fn enabled(level: Level) -> bool {
    (level as u8) <= MAX_LEVEL.load(Ordering::Relaxed)
}

/// Emit one record. Prefer the crate-root macros at call sites; they
/// capture `module_path!()` as the target and defer formatting until the
/// level check passes.
pub fn log(level: Level, target: &str, args: fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let mut err = std::io::stderr().lock();
    let _ = writeln!(err, "[{}] {}: {}", level.tag(), target, args);
}

/// Log at [`Level::Error`].
#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => {
        $crate::util::logger::log(
            $crate::util::logger::Level::Error,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

/// Log at [`Level::Warn`].
#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => {
        $crate::util::logger::log(
            $crate::util::logger::Level::Warn,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

/// Log at [`Level::Info`].
#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::util::logger::log(
            $crate::util::logger::Level::Info,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

/// Log at [`Level::Debug`].
#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        $crate::util::logger::log(
            $crate::util::logger::Level::Debug,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

/// Log at [`Level::Trace`].
#[macro_export]
macro_rules! trace {
    ($($arg:tt)*) => {
        $crate::util::logger::log(
            $crate::util::logger::Level::Trace,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    // One test only: the level is process-global, and parallel test
    // threads mutating it would race.
    #[test]
    fn init_and_level_gating() {
        init();
        init();
        crate::info!("logger smoke test");
        set_max_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_max_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }
}
