//! Byte and bandwidth unit helpers.
//!
//! The paper reports storage in GB (decimal) and bandwidth in Gb/s
//! (decimal bits). We follow the paper's conventions: `1 GB = 1e9 bytes`,
//! `1 Gb/s = 1e9 bits/s = 125e6 bytes/s`.

/// Bytes per decimal kilobyte/megabyte/gigabyte.
pub const KB: u64 = 1_000;
/// Bytes per decimal megabyte.
pub const MB: u64 = 1_000_000;
/// Bytes per decimal gigabyte.
pub const GB: u64 = 1_000_000_000;

/// Convert a byte count to decimal gigabytes.
pub fn bytes_to_gb(bytes: u64) -> f64 {
    bytes as f64 / GB as f64
}

/// Convert bytes/second to Gb/s (gigabits per second).
pub fn bps_to_gbps(bytes_per_sec: f64) -> f64 {
    bytes_per_sec * 8.0 / 1e9
}

/// Convert Gb/s (gigabits per second) to bytes/second.
pub fn gbps_to_bps(gbps: f64) -> f64 {
    gbps * 1e9 / 8.0
}

/// Human-readable byte count (decimal units, two significant decimals).
pub fn fmt_bytes(bytes: u64) -> String {
    if bytes >= GB {
        format!("{:.2}GB", bytes as f64 / GB as f64)
    } else if bytes >= MB {
        format!("{:.2}MB", bytes as f64 / MB as f64)
    } else if bytes >= KB {
        format!("{:.2}KB", bytes as f64 / KB as f64)
    } else {
        format!("{bytes}B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gbps_round_trip() {
        let bw = gbps_to_bps(4.0);
        assert_eq!(bw, 0.5e9); // 4 Gb/s = 500 MB/s
        assert!((bps_to_gbps(bw) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn byte_formatting() {
        assert_eq!(fmt_bytes(10 * MB), "10.00MB");
        assert_eq!(fmt_bytes(GB + GB / 2), "1.50GB");
        assert_eq!(fmt_bytes(999), "999B");
        assert_eq!(fmt_bytes(1_500), "1.50KB");
    }

    #[test]
    fn paper_units_sanity() {
        // 10 MB file at GPFS's 4 Gb/s = 0.02 s transfer.
        let secs = (10 * MB) as f64 / gbps_to_bps(4.0);
        assert!((secs - 0.02).abs() < 1e-9);
    }
}
