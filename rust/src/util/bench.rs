//! Micro-benchmark harness — the offline replacement for `criterion`.
//!
//! Each `rust/benches/*.rs` binary is declared with `harness = false` and
//! drives this module directly. Two kinds of benchmarks are supported:
//!
//! * [`Bench::iter`] — classic timed closures with warm-up, multiple
//!   samples, and mean/stddev/throughput reporting (used by the scheduler
//!   micro-benchmarks of Figure 3 and the §Perf hot-path benches);
//! * whole-experiment runs, where the "benchmark" regenerates a paper
//!   figure and the harness just frames and times it.
//!
//! Results are printed as ASCII tables and optionally appended as CSV under
//! `target/bench-results/` so EXPERIMENTS.md numbers are traceable.

use std::time::{Duration, Instant};

/// One benchmark group; prints a header on creation.
pub struct Bench {
    name: String,
    samples: usize,
    warmup: usize,
    min_duration: Duration,
    results: Vec<Measurement>,
}

/// Result of one timed benchmark case.
#[derive(Debug, Clone)]
pub struct Measurement {
    /// Case label.
    pub label: String,
    /// Mean wall time per iteration, seconds.
    pub mean_s: f64,
    /// Standard deviation across samples, seconds.
    pub stddev_s: f64,
    /// Iterations per second (1/mean).
    pub per_sec: f64,
    /// Optional user-supplied item count per iteration → items/sec.
    pub items_per_sec: Option<f64>,
}

impl Bench {
    /// New group with default settings (3 warm-up, 10 samples, each sample
    /// runs the closure enough times to take ≥20 ms).
    pub fn new(name: &str) -> Self {
        println!("\n== bench: {name} ==");
        Bench {
            name: name.to_string(),
            samples: 10,
            warmup: 3,
            min_duration: Duration::from_millis(20),
            results: Vec::new(),
        }
    }

    /// Override the number of timed samples.
    pub fn samples(mut self, n: usize) -> Self {
        self.samples = n.max(1);
        self
    }

    /// Override the per-sample minimum duration.
    pub fn min_sample_duration(mut self, d: Duration) -> Self {
        self.min_duration = d;
        self
    }

    /// Time `f`, which processes `items` logical items per call (pass 1 for
    /// plain latency benchmarks). Reports mean/stddev and items/sec.
    pub fn iter<F: FnMut()>(&mut self, label: &str, items: u64, mut f: F) -> &Measurement {
        // Warm-up.
        for _ in 0..self.warmup {
            f();
        }
        // Determine batch size so one sample takes at least min_duration.
        let t0 = Instant::now();
        f();
        let one = t0.elapsed().max(Duration::from_nanos(50));
        let batch = (self.min_duration.as_secs_f64() / one.as_secs_f64()).ceil() as usize;
        let batch = batch.clamp(1, 1_000_000);

        let mut times = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..batch {
                f();
            }
            times.push(t.elapsed().as_secs_f64() / batch as f64);
        }
        let mean = times.iter().sum::<f64>() / times.len() as f64;
        let var = times.iter().map(|t| (t - mean).powi(2)).sum::<f64>()
            / (times.len().max(2) - 1) as f64;
        let m = Measurement {
            label: label.to_string(),
            mean_s: mean,
            stddev_s: var.sqrt(),
            per_sec: 1.0 / mean,
            items_per_sec: if items > 1 {
                Some(items as f64 / mean)
            } else {
                None
            },
        };
        self.print_row(&m);
        self.results.push(m);
        self.results.last().unwrap()
    }

    fn print_row(&self, m: &Measurement) {
        let rate = match m.items_per_sec {
            Some(ips) => format!("{:>12.0} items/s", ips),
            None => format!("{:>12.1} iters/s", m.per_sec),
        };
        println!(
            "  {:<42} {:>12} ± {:<10} {rate}",
            m.label,
            fmt_duration(m.mean_s),
            fmt_duration(m.stddev_s),
        );
    }

    /// Group name.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// All measurements taken so far.
    pub fn results(&self) -> &[Measurement] {
        &self.results
    }

    /// Write results as CSV under `target/bench-results/<group>.csv`.
    pub fn write_csv(&self) -> std::io::Result<std::path::PathBuf> {
        let dir = std::path::Path::new("target/bench-results");
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{}.csv", self.name.replace([' ', '/'], "_")));
        let mut out = String::from("label,mean_s,stddev_s,per_sec,items_per_sec\n");
        for m in &self.results {
            out.push_str(&format!(
                "{},{},{},{},{}\n",
                m.label,
                m.mean_s,
                m.stddev_s,
                m.per_sec,
                m.items_per_sec.unwrap_or(f64::NAN)
            ));
        }
        std::fs::write(&path, out)?;
        Ok(path)
    }
}

/// Serialize bench groups as a JSON snapshot (the `BENCH_baseline.json`
/// schema, version 2): future PRs regenerate the file with the same
/// bench binary and diff the numbers to track the perf trajectory.
///
/// `counters` carries deterministic work metrics (tasks inspected per
/// pickup, boundary-cursor steps, flow rerate counts) — unlike wall
/// times these are machine-independent, so the CI gate
/// (`tools/bench_gate.py`) can compare them against the committed
/// baseline with tight-ish tolerances while treating timings as
/// within-run ratios only. `"measured": true` marks a snapshot produced
/// by an actual bench run (the seed baseline was authored without a
/// toolchain and carries `false`).
///
/// The crate is dependency-free, so the writer is hand-rolled; labels are
/// plain ASCII and escaped minimally.
pub fn baseline_json(bench_name: &str, groups: &[&Bench], counters: &[(String, f64)]) -> String {
    fn esc(s: &str) -> String {
        s.replace('\\', "\\\\").replace('"', "\\\"")
    }
    fn num(x: f64) -> String {
        if x.is_finite() {
            format!("{x:e}")
        } else {
            "null".to_string()
        }
    }
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"schema\": 2,\n");
    out.push_str(&format!("  \"bench\": \"{}\",\n", esc(bench_name)));
    out.push_str("  \"unit\": \"seconds_per_iteration\",\n");
    out.push_str("  \"measured\": true,\n");
    out.push_str("  \"counters\": {\n");
    for (i, (name, value)) in counters.iter().enumerate() {
        out.push_str(&format!(
            "    \"{}\": {}{}\n",
            esc(name),
            num(*value),
            if i + 1 < counters.len() { "," } else { "" }
        ));
    }
    out.push_str("  },\n");
    out.push_str("  \"groups\": [\n");
    for (gi, g) in groups.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"name\": \"{}\", \"cases\": [\n",
            esc(g.name())
        ));
        for (ci, m) in g.results().iter().enumerate() {
            out.push_str(&format!(
                "      {{\"label\": \"{}\", \"mean_s\": {}, \"stddev_s\": {}, \
                 \"per_sec\": {}, \"items_per_sec\": {}}}{}\n",
                esc(&m.label),
                num(m.mean_s),
                num(m.stddev_s),
                num(m.per_sec),
                m.items_per_sec.map_or("null".to_string(), num),
                if ci + 1 < g.results().len() { "," } else { "" },
            ));
        }
        out.push_str(&format!(
            "    ]}}{}\n",
            if gi + 1 < groups.len() { "," } else { "" }
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

/// Format a duration in engineering units.
pub fn fmt_duration(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3}µs", s * 1e6)
    } else {
        format!("{:.1}ns", s * 1e9)
    }
}

/// Prevent the optimizer from eliding a computed value
/// (`std::hint::black_box` wrapper kept local so benches read uniformly).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut b = Bench::new("selftest").samples(3).min_sample_duration(Duration::from_millis(1));
        let mut acc = 0u64;
        let m = b.iter("count", 100, || {
            for i in 0..100u64 {
                acc = black_box(acc.wrapping_add(i));
            }
        });
        assert!(m.mean_s > 0.0);
        assert!(m.items_per_sec.unwrap() > 0.0);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn baseline_json_is_well_formed() {
        let mut b = Bench::new("json selftest")
            .samples(2)
            .min_sample_duration(Duration::from_millis(1));
        let mut acc = 0u64;
        b.iter("case \"quoted\"", 10, || {
            acc = black_box(acc.wrapping_add(1));
        });
        let counters = vec![
            ("inspected/per_pickup".to_string(), 3.5),
            ("bad".to_string(), f64::NAN),
        ];
        let j = baseline_json("selftest", &[&b], &counters);
        assert!(j.contains("\"schema\": 2"));
        assert!(j.contains("\"bench\": \"selftest\""));
        assert!(j.contains("\"measured\": true"));
        assert!(j.contains("\"inspected/per_pickup\": 3.5e0"));
        assert!(j.contains("\"bad\": null"));
        assert!(j.contains("\\\"quoted\\\""));
        assert!(j.contains("\"mean_s\": "));
        assert!(j.trim_end().ends_with('}'));
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration(2.5), "2.500s");
        assert_eq!(fmt_duration(2.5e-3), "2.500ms");
        assert_eq!(fmt_duration(2.5e-6), "2.500µs");
        assert_eq!(fmt_duration(25e-9), "25.0ns");
    }
}
