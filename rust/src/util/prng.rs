//! Deterministic pseudo-random number generation.
//!
//! All stochastic behaviour in the simulator, the workload generators and
//! the property-test harness flows through [`Pcg64`], a permuted
//! congruential generator (PCG-XSL-RR 128/64, O'Neill 2014). Seeding is
//! explicit everywhere so every experiment is bit-reproducible; the
//! integration suite asserts run-to-run determinism.

/// PCG-XSL-RR 128/64: 128-bit LCG state, 64-bit xorshift-rotate output.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed and a stream id. Different streams
    /// with the same seed are statistically independent.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg64 {
            state: 0,
            inc: ((stream as u128) << 1) | 1,
        };
        rng.next_u64();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.next_u64();
        rng
    }

    /// Create a generator from a bare seed (stream 0).
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda7a_d1ff)
    }

    /// Derive an independent child generator; used to give each component
    /// (provisioner, workload, network jitter, …) its own stream.
    pub fn fork(&mut self, stream: u64) -> Pcg64 {
        Pcg64::new(self.next_u64(), stream)
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, bound) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "below(0) is meaningless");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.next_f64() * (hi - lo)
    }

    /// Bernoulli draw.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Exponentially distributed draw with the given rate (λ).
    #[inline]
    pub fn exponential(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0);
        // Avoid ln(0).
        let u = 1.0 - self.next_f64();
        -u.ln() / rate
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty());
        &xs[self.below(xs.len() as u64) as usize]
    }
}

/// Zipf-distributed sampler over {0, 1, …, n-1} with exponent `s`.
///
/// Used for skewed file-popularity workloads (the paper's astronomy traces
/// have strong locality of reference; zipf is the standard synthetic
/// stand-in). Sampling is by inverse CDF over a precomputed table — O(log n)
/// per draw, exact.
#[derive(Debug, Clone)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    /// Build a sampler for `n` items with exponent `s` (s = 0 is uniform).
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for v in cdf.iter_mut() {
            *v /= total;
        }
        // Guard against FP round-off in the final bucket.
        *cdf.last_mut().unwrap() = 1.0;
        Zipf { cdf }
    }

    /// Draw an item index in [0, n).
    pub fn sample(&self, rng: &mut Pcg64) -> usize {
        let u = rng.next_f64();
        match self
            .cdf
            .binary_search_by(|p| p.partial_cmp(&u).unwrap())
        {
            Ok(i) => i,
            Err(i) => i,
        }
    }

    /// Number of items.
    pub fn len(&self) -> usize {
        self.cdf.len()
    }

    /// True when the table is empty (never, by construction).
    pub fn is_empty(&self) -> bool {
        self.cdf.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Pcg64::seeded(42);
        let mut b = Pcg64::seeded(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Pcg64::seeded(1);
        let mut b = Pcg64::seeded(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn forked_streams_are_independent() {
        let mut root = Pcg64::seeded(7);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Pcg64::seeded(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = rng.below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_in_unit_interval_with_plausible_mean() {
        let mut rng = Pcg64::seeded(11);
        let mut sum = 0.0;
        const N: usize = 20_000;
        for _ in 0..N {
            let v = rng.next_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / N as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn exponential_mean_matches_rate() {
        let mut rng = Pcg64::seeded(5);
        let rate = 4.0;
        let n = 50_000;
        let sum: f64 = (0..n).map(|_| rng.exponential(rate)).sum();
        let mean = sum / n as f64;
        assert!((mean - 1.0 / rate).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn zipf_uniform_when_s_zero() {
        let z = Zipf::new(10, 0.0);
        let mut rng = Pcg64::seeded(9);
        let mut counts = [0usize; 10];
        for _ in 0..20_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 2000.0).abs() < 300.0, "counts={counts:?}");
        }
    }

    #[test]
    fn zipf_skewed_prefers_head() {
        let z = Zipf::new(100, 1.2);
        let mut rng = Pcg64::seeded(13);
        let mut head = 0usize;
        const N: usize = 10_000;
        for _ in 0..N {
            if z.sample(&mut rng) < 10 {
                head += 1;
            }
        }
        // With s=1.2 the top-10 of 100 hold well over half the mass.
        assert!(head > N / 2, "head={head}");
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut rng = Pcg64::seeded(17);
        let mut xs: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>(), "astronomically unlikely");
    }
}
