//! Zero-dependency scoped-thread fan-out for independent work items.
//!
//! The figure suite and the policy sweeps run many deterministic,
//! independent experiments; [`map`] spreads them over `std::thread::scope`
//! workers pulling from a shared queue and returns the results **in input
//! order**, so merged tables are byte-identical regardless of the job
//! count (the `--jobs 1` vs `--jobs N` parity the CI figure gate relies
//! on). Each item carries its own seed inside its config, so per-run
//! determinism is untouched by scheduling.
//!
//! `jobs <= 1` (or a single item) runs inline on the caller's thread —
//! no threads are spawned, preserving exact sequential behaviour.

use std::collections::VecDeque;
use std::sync::Mutex;

/// Number of worker threads to use when the caller does not specify one.
pub fn default_jobs() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Apply `f` to every item on up to `jobs` worker threads; results come
/// back in input order. A panic in `f` propagates to the caller after
/// the remaining workers finish their current items.
pub fn map<T, R, F>(items: Vec<T>, jobs: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, T) -> R + Sync,
{
    let n = items.len();
    let jobs = jobs.clamp(1, n.max(1));
    if jobs <= 1 || n <= 1 {
        return items.into_iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let queue: Mutex<VecDeque<(usize, T)>> = Mutex::new(items.into_iter().enumerate().collect());
    let done: Mutex<Vec<(usize, R)>> = Mutex::new(Vec::with_capacity(n));
    std::thread::scope(|s| {
        for _ in 0..jobs {
            s.spawn(|| loop {
                let item = queue.lock().expect("worker panicked holding queue").pop_front();
                let Some((i, t)) = item else { break };
                let r = f(i, t);
                done.lock().expect("worker panicked holding results").push((i, r));
            });
        }
    });
    let mut out = done.into_inner().expect("worker panicked holding results");
    out.sort_unstable_by_key(|&(i, _)| i);
    debug_assert_eq!(out.len(), n);
    out.into_iter().map(|(_, r)| r).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn preserves_input_order() {
        let items: Vec<u64> = (0..100).collect();
        let out = map(items.clone(), 8, |i, x| {
            assert_eq!(i as u64, x);
            x * 2
        });
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn inline_path_matches_threaded() {
        let items: Vec<u64> = (0..37).collect();
        let seq = map(items.clone(), 1, |_, x| x * x);
        let par = map(items, 4, |_, x| x * x);
        assert_eq!(seq, par);
    }

    #[test]
    fn uses_multiple_workers() {
        // With more items than workers and a tiny sleep, at least two
        // distinct threads must participate.
        let seen = Mutex::new(std::collections::HashSet::new());
        let busy = AtomicUsize::new(0);
        map((0..64).collect::<Vec<u64>>(), 4, |_, _| {
            busy.fetch_add(1, Ordering::SeqCst);
            std::thread::sleep(std::time::Duration::from_millis(1));
            seen.lock().unwrap().insert(std::thread::current().id());
        });
        assert!(seen.lock().unwrap().len() >= 2);
    }

    #[test]
    fn handles_empty_and_oversized_jobs() {
        let out: Vec<u64> = map(Vec::<u64>::new(), 8, |_, x| x);
        assert!(out.is_empty());
        let out = map(vec![7u64], 100, |_, x| x + 1);
        assert_eq!(out, vec![8]);
    }

    #[test]
    fn default_jobs_is_positive() {
        assert!(default_jobs() >= 1);
    }
}
