//! Streaming and exact statistics used by the metrics layer and the
//! model-validation experiments.

/// Streaming mean/variance (Welford) plus min/max.
#[derive(Debug, Clone, Default)]
pub struct Running {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Running {
    /// Fresh accumulator.
    pub fn new() -> Self {
        Running {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Add one observation.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Number of observations.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Sample mean (0 if empty).
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    /// Sample standard deviation (0 for n < 2).
    pub fn stddev(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }

    /// Minimum observation (0 if empty).
    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    /// Maximum observation (0 if empty).
    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }
}

/// Exact percentile over a sample (nearest-rank on a sorted copy).
///
/// `q` in [0, 1]; `q = 0.99` gives the paper's "peak (99 percentile)"
/// throughput statistic of Figure 12.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    assert!((0.0..=1.0).contains(&q), "q out of range: {q}");
    if xs.is_empty() {
        return 0.0;
    }
    let mut sorted: Vec<f64> = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
    sorted[rank - 1]
}

/// Arithmetic mean of a slice (0 if empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Coefficient of determination R² of predictions vs observations.
///
/// Used by the model-validation harness (the paper proposes R² and
/// residual analysis for the simulation-based validation, §4.4).
pub fn r_squared(observed: &[f64], predicted: &[f64]) -> f64 {
    assert_eq!(observed.len(), predicted.len());
    if observed.is_empty() {
        return 0.0;
    }
    let mean_obs = mean(observed);
    let ss_tot: f64 = observed.iter().map(|o| (o - mean_obs).powi(2)).sum();
    let ss_res: f64 = observed
        .iter()
        .zip(predicted)
        .map(|(o, p)| (o - p).powi(2))
        .sum();
    if ss_tot == 0.0 {
        if ss_res == 0.0 {
            1.0
        } else {
            0.0
        }
    } else {
        1.0 - ss_res / ss_tot
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn running_matches_closed_form() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut r = Running::new();
        for &x in &xs {
            r.push(x);
        }
        assert_eq!(r.count(), 8);
        assert!((r.mean() - 5.0).abs() < 1e-12);
        // Sample stddev of that classic dataset is ~2.138.
        assert!((r.stddev() - 2.13809).abs() < 1e-4);
        assert_eq!(r.min(), 2.0);
        assert_eq!(r.max(), 9.0);
    }

    #[test]
    fn empty_running_is_zeroes() {
        let r = Running::new();
        assert_eq!(r.mean(), 0.0);
        assert_eq!(r.stddev(), 0.0);
        assert_eq!(r.min(), 0.0);
        assert_eq!(r.max(), 0.0);
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.99), 99.0);
        assert_eq!(percentile(&xs, 0.5), 50.0);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 100.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
    }

    #[test]
    fn r_squared_perfect_and_flat() {
        let o = [1.0, 2.0, 3.0];
        assert_eq!(r_squared(&o, &o), 1.0);
        let bad = [3.0, 1.0, 2.0];
        assert!(r_squared(&o, &bad) < 1.0);
        assert_eq!(r_squared(&[], &[]), 0.0);
    }
}
