//! Small self-contained utilities used across the crate.
//!
//! The build environment is fully offline, so crates that would normally be
//! pulled in (`rand`, `criterion`, `proptest`) are replaced by small,
//! well-tested local implementations:
//!
//! * [`prng`] — a deterministic PCG64 generator plus the distributions the
//!   workloads need (uniform, zipf, exponential).
//! * [`stats`] — streaming mean/variance and exact percentiles.
//! * [`time`] — the microsecond-resolution simulation clock.
//! * [`units`] — byte / bandwidth unit helpers and formatting.
//! * [`bench`] — a micro-benchmark harness (criterion replacement) used by
//!   the `rust/benches/*` binaries.
//! * [`par`] — a zero-dependency scoped-thread fan-out (the figure suite
//!   and policy sweeps run independent experiments across cores).
//! * [`proptest`] — a miniature property-testing harness with input
//!   shrinking, used by the test suites.
//! * [`logger`] — a tiny leveled logging facade writing to stderr (the
//!   `log` crate replacement; see the crate-root `info!`/`warn!`/`error!`
//!   macros).

pub mod bench;
pub mod logger;
pub mod par;
pub mod prng;
pub mod proptest;
pub mod stats;
pub mod time;
pub mod units;
