//! Live execution engine: the sharded coordinator driving *real* work.
//!
//! Where [`crate::sim`] substitutes the testbed, this engine drives the
//! **same** [`ShardedCoordinator`] — K coordinator cores behind the
//! hash router, each with its wait queue, data-aware scheduler,
//! location index, per-executor caches and demand-driven provisioner —
//! over real worker threads that move real files and run real compute.
//! The module is a *driver*: it enacts the router's [`Effect`]s on the
//! wall clock and the filesystem and feeds worker outcomes back into
//! the router's event API; it contains no dispatch logic of its own
//! (`rust/tests/live_parity.rs` proves the K=1 live driver replays the
//! bare core's decision sequence bit-for-bit on a shared deterministic
//! workload, and that K=4 runs conserve every tally):
//!
//! * [`Effect::Notify`] → an immediate pickup round-trip (no dispatcher
//!   service model on a local testbed), delivered through a **per-shard
//!   FIFO queue** so each shard's notification order is deterministic;
//! * [`Effect::Fetch`] → an assignment to the executor's worker thread:
//!   fetch from its own cache directory (local hit), a peer worker's
//!   cache directory (global hit — the GridFTP path; under the router a
//!   peer may live in a *different shard*, making the copy a real
//!   cross-shard transfer accounted as `cross_in`/`cross_out`), or the
//!   **persistent store** directory (miss) — exactly the three-way
//!   split of §5.2.1 — then run the compute;
//! * [`Effect::Compute`] → already performed by the worker alongside the
//!   fetch, so the driver feeds it straight back as `on_compute_done`;
//! * [`Effect::Allocate`] → spawn worker threads on demand (live DRP —
//!   no GRAM latency on a local testbed); the router grants allocations
//!   to the shard that requested them, so every shard can regrow its
//!   own pool;
//! * [`Effect::Release`] → retire an idle worker: scrub it from the
//!   router, shut its thread down and delete its cache directory (the
//!   transient resource and the replicas it held are gone, as on a
//!   deallocated node). Enabled by `LiveConfig::idle_release_s > 0`;
//!   the router withholds executors still serving **cross-shard** peer
//!   transfers (counted as `cross_release_deferrals`), and a racing
//!   peer *copy* from a vanished directory falls back to the
//!   persistent store.
//!
//! [`LiveFaults`] injects the chaos harness's fault menu into a live
//! run — a worker thread killed mid-run (the router requeues its tasks
//! via `on_executor_failed`; late messages from the dead thread are
//! dropped) and a shard partition (cross-shard copies refused, workers
//! fall back to the persistent store and report the miss they really
//! experienced). Every live run ends with the router's
//! [`ShardedCoordinator::check_integrity`] oracle.
//!
//! Per-task compute is either a calibrated sleep or the AOT-compiled
//! **PJRT stacking pipeline** (`examples/astronomy_stacking.rs`), so the
//! full three-layer stack (Rust → HLO → Pallas kernel) is on the hot
//! path with Python nowhere in sight. Hit/miss tallies come from the
//! per-shard [`Recorder`]s merged losslessly at the end of the run
//! (workers report the access kind they actually experienced — a peer
//! copy can race the peer's eviction and fall back to persistent
//! storage, which the recorder then counts as the miss it really was).

use crate::cache::CacheConfig;
use crate::coordinator::core::{CoreConfig, Effect, FetchPlan, FileSizes};
use crate::coordinator::provisioner::{AllocationPolicy, ProvisionerConfig};
use crate::coordinator::queue::Task;
use crate::coordinator::scheduler::{DispatchPolicy, SchedulerConfig};
use crate::coordinator::shard::ShardedCoordinator;
use crate::coordinator::AccessKind;
use crate::ids::{ExecutorId, FileId, TaskId};
use crate::metrics::{Recorder, ShardCounters};
use crate::util::prng::Pcg64;
use crate::util::time::Micros;
use crate::{Error, Result};
use std::collections::{HashMap, HashSet, VecDeque};
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::thread;
use std::time::{Duration, Instant};

/// What a worker does after staging its input file.
#[derive(Debug, Clone)]
pub enum ComputeKind {
    /// Sleep for the given duration (micro-benchmark workloads).
    Sleep(Duration),
    /// Run the AOT stacking pipeline on the file's contents (the file
    /// must hold STACK-shaped f32 cutouts + weights; see
    /// [`crate::runtime::StackingExecutable`]). Each worker compiles its
    /// own executable (PJRT handles are not Sync).
    Stacking,
}

/// Seeded fault plan for a live run — the chaos harness's live
/// counterpart. Triggers are **completion counts**, not wall-clock
/// times, so a plan reproduces across machines regardless of timing.
#[derive(Debug, Clone, Default)]
pub struct LiveFaults {
    /// After this many task completions, kill one worker thread as if
    /// its node died. Coordinator-side this is a kill-mid-fetch: the
    /// router requeues the victim's in-flight work and any message the
    /// dead thread already sent is dropped. The victim is chosen from
    /// shards with ≥ 2 workers (no shard is emptied); if none is
    /// eligible yet, the kill retries on later completions.
    pub kill_worker_after: Option<u64>,
    /// After this many task completions, partition the shards:
    /// cross-shard peer copies are refused at assignment time and fall
    /// back to the persistent store (counted in
    /// [`LiveReport::partition_fallbacks`]; the worker reports the miss
    /// it really experienced).
    pub partition_after: Option<u64>,
}

/// Live-engine configuration.
#[derive(Debug, Clone)]
pub struct LiveConfig {
    /// Workers to start with.
    pub initial_workers: usize,
    /// Maximum workers the provisioner may spawn.
    pub max_workers: usize,
    /// Queue length per worker that triggers growth (the provisioner's
    /// `queue_tasks_per_node`).
    pub queue_tasks_per_worker: usize,
    /// How aggressively the provisioner requests new workers — the same
    /// allocation policies as the simulated DRP, shared through the
    /// coordinator core (`one`/`add:N`/`mult:F`/`all`/`model`; under
    /// `model` the core runs the §3 performance model online and the
    /// provisioner tracks its solved worker target).
    pub allocation: AllocationPolicy,
    /// Dispatch policy.
    pub policy: DispatchPolicy,
    /// Per-worker cache configuration.
    pub cache: CacheConfig,
    /// Directory holding the dataset (the persistent store).
    pub persistent_dir: PathBuf,
    /// Root under which per-worker cache directories are created.
    pub cache_root: PathBuf,
    /// Per-task compute.
    pub compute: ComputeKind,
    /// PRNG seed (peer selection, eviction randomness).
    pub seed: u64,
    /// Seconds of idleness before the provisioner retires a worker
    /// mid-run ([`Effect::Release`] → thread shutdown + cache-dir
    /// removal). `0.0` disables mid-run retirement — the right choice
    /// for short benchmark runs, where the fleet should stay warm.
    pub idle_release_s: f64,
    /// Coordinator shards (K cores behind the hash router). `0` and `1`
    /// both mean the unsharded single-core layout.
    pub shards: usize,
    /// Fault-injection plan (default: no faults).
    pub faults: LiveFaults,
}

/// One task for the live engine: stage its input files, compute.
#[derive(Debug, Clone)]
pub struct LiveTask {
    /// Primary input's file name inside `persistent_dir`. The primary
    /// input determines the task's home shard under the router.
    pub file_name: String,
    /// Primary input's logical file id (for the scheduler/index).
    pub file: FileId,
    /// Additional inputs `(id, name)`. The coordinator fetches inputs
    /// in declaration order — primary first — chaining one fetch per
    /// file before the compute; an extra homed on a *different* shard
    /// is what makes a live cross-shard transfer happen.
    pub extra: Vec<(FileId, String)>,
}

impl LiveTask {
    /// A single-input task (the common case).
    pub fn single(file_name: impl Into<String>, file: FileId) -> Self {
        LiveTask {
            file_name: file_name.into(),
            file,
            extra: Vec::new(),
        }
    }

    /// All inputs, primary first.
    fn inputs(&self) -> impl Iterator<Item = (FileId, &str)> {
        std::iter::once((self.file, self.file_name.as_str()))
            .chain(self.extra.iter().map(|(f, n)| (*f, n.as_str())))
    }

    fn file_ids(&self) -> Vec<FileId> {
        self.inputs().map(|(f, _)| f).collect()
    }
}

/// Where the worker should fetch its input from.
#[derive(Debug, Clone)]
enum FetchSource {
    /// Already in the worker's own cache directory.
    Local,
    /// Copy from this peer cache directory.
    Peer(PathBuf),
    /// Copy from the persistent store.
    Persistent,
}

#[derive(Debug)]
struct Assignment {
    task_id: TaskId,
    file_name: String,
    source: FetchSource,
    /// Files the worker should delete from its cache dir (evictions
    /// decided by the coordinator-side cache model).
    evict: Vec<String>,
}

#[derive(Debug)]
enum WorkerMsg {
    Done {
        worker: usize,
        task_id: TaskId,
        kind: AccessKind,
        bytes: u64,
        fetch: Duration,
        compute: Duration,
    },
    Failed {
        worker: usize,
        task_id: TaskId,
        error: String,
    },
}

enum ToWorker {
    Run(Assignment),
    Shutdown,
}

struct WorkerHandle {
    tx: mpsc::Sender<ToWorker>,
    join: thread::JoinHandle<()>,
    cache_dir: PathBuf,
    /// Thread index (names the cache dir and tags worker messages).
    idx: usize,
    /// Assignments sent and not yet answered by this worker.
    inflight: u32,
}

/// End-of-run report from the live engine.
#[derive(Debug)]
pub struct LiveReport {
    /// Tasks completed successfully.
    pub completed: u64,
    /// Tasks failed (worker errors; the replay policy retries once).
    pub failed: u64,
    /// Wall-clock makespan.
    pub makespan: Duration,
    /// Local cache hits (from the merged per-shard recorders).
    pub hits_local: u64,
    /// Peer-cache hits.
    pub hits_global: u64,
    /// Persistent-store misses.
    pub misses: u64,
    /// Total bytes fetched (all sources).
    pub bytes_moved: u64,
    /// Mean per-task fetch time.
    pub avg_fetch: Duration,
    /// Mean per-task compute time.
    pub avg_compute: Duration,
    /// Peak worker count (provisioning).
    pub peak_workers: usize,
    /// Workers retired mid-run by [`Effect::Release`] enactment.
    pub workers_released: u64,
    /// Tasks in dispatch order — the coordinator decision trace
    /// `live_parity` compares against the bare core.
    pub dispatch_order: Vec<TaskId>,
    /// Per-second recorder (the per-shard recorders merged losslessly —
    /// identical shape to the simulator's).
    pub recorder: Recorder,
    /// Router counters: per-shard routing/dispatch tallies plus
    /// cross-shard fetches, bytes, deferrals and executor failures.
    pub shard: ShardCounters,
    /// Peak live workers per shard.
    pub workers_per_shard: Vec<usize>,
    /// Cross-shard copies refused by an injected partition (each fell
    /// back to the persistent store).
    pub partition_fallbacks: u64,
}

/// The live driver: the sharded coordinator plus the worker fleet and
/// the per-shard FIFO notification queues the `Notify` effects drain
/// through.
struct Driver<'a> {
    config: &'a LiveConfig,
    router: ShardedCoordinator,
    workers: HashMap<ExecutorId, WorkerHandle>,
    /// Thread index → executor, for workers still alive (reverse of
    /// [`WorkerHandle::idx`]; worker messages carry the thread index).
    exec_of_idx: HashMap<usize, ExecutorId>,
    /// Thread indices killed by fault injection. Late messages from
    /// these workers are dropped by the main loop — the router already
    /// requeued their tasks via `on_executor_failed`.
    dead_workers: HashSet<usize>,
    /// Reserved-but-undelivered dispatch notifications, one FIFO per
    /// shard — the live stand-in for the sim's dispatcher service queue.
    notify_q: Vec<VecDeque<ExecutorId>>,
    /// Assignments sent to workers and not yet answered.
    outstanding: usize,
    /// Tasks whose compute has closed (`Effect::Compute` enacted). With
    /// multi-input tasks a task spans several fetch round-trips, so
    /// completion is counted here, not per worker message.
    tasks_finished: u64,
    next_worker_idx: usize,
    peak_workers: usize,
    workers_released: u64,
    /// Live workers per shard, and the per-shard peaks.
    shard_counts: Vec<usize>,
    shard_peaks: Vec<usize>,
    /// Injected partition active? (Cross-shard copies refused.)
    partitioned: bool,
    partition_fallbacks: u64,
    file_names: HashMap<FileId, String>,
    done_tx: mpsc::Sender<WorkerMsg>,
}

impl<'a> Driver<'a> {
    fn new(
        config: &'a LiveConfig,
        router: ShardedCoordinator,
        done_tx: mpsc::Sender<WorkerMsg>,
    ) -> Self {
        let k = router.shards();
        Driver {
            config,
            router,
            workers: HashMap::new(),
            exec_of_idx: HashMap::new(),
            dead_workers: HashSet::new(),
            notify_q: vec![VecDeque::new(); k],
            outstanding: 0,
            tasks_finished: 0,
            next_worker_idx: 0,
            peak_workers: 0,
            workers_released: 0,
            shard_counts: vec![0; k],
            shard_peaks: vec![0; k],
            partitioned: false,
            partition_fallbacks: 0,
            file_names: HashMap::new(),
            done_tx,
        }
    }

    fn shard_of(&self, exec: ExecutorId) -> usize {
        self.router.shard_of_exec(exec).unwrap_or(0)
    }

    /// Spawn one worker thread and register it with the router (round-
    /// robin shard placement); returns the registration effects (the
    /// fresh executor's `Notify`).
    fn spawn_worker(&mut self, now: Micros) -> Result<Vec<Effect>> {
        let (exec, effects) = self.router.register_node(now);
        self.attach_worker(exec)?;
        Ok(effects)
    }

    /// Create the cache directory and worker thread backing `exec`.
    fn attach_worker(&mut self, exec: ExecutorId) -> Result<()> {
        let idx = self.next_worker_idx;
        self.next_worker_idx += 1;
        let cache_dir = self.config.cache_root.join(format!("worker-{idx}"));
        std::fs::create_dir_all(&cache_dir)?;
        let (tx, rx) = mpsc::channel::<ToWorker>();
        let done = self.done_tx.clone();
        let persistent = self.config.persistent_dir.clone();
        let cdir = cache_dir.clone();
        let compute = self.config.compute.clone();
        let join = thread::Builder::new()
            .name(format!("dd-worker-{idx}"))
            .spawn(move || worker_main(idx, rx, done, persistent, cdir, compute))
            .map_err(Error::Io)?;
        self.workers.insert(
            exec,
            WorkerHandle {
                tx,
                join,
                cache_dir,
                idx,
                inflight: 0,
            },
        );
        self.exec_of_idx.insert(idx, exec);
        let s = self.shard_of(exec);
        self.shard_counts[s] += 1;
        self.shard_peaks[s] = self.shard_peaks[s].max(self.shard_counts[s]);
        self.peak_workers = self.peak_workers.max(self.workers.len());
        Ok(())
    }

    /// Enact a batch of router effects on the worker fleet. FIFO so
    /// notification delivery order stays deterministic. Effects carry
    /// *global* executor ids — the router translates shard-local ids at
    /// the boundary.
    fn apply(&mut self, effects: Vec<Effect>, now: Micros) -> Result<()> {
        let mut queue: VecDeque<Effect> = effects.into();
        while let Some(effect) = queue.pop_front() {
            match effect {
                Effect::Notify(e) => {
                    let s = self.shard_of(e);
                    self.notify_q[s].push_back(e);
                }
                Effect::Fetch(plan) => self.send_assignment(plan)?,
                Effect::Compute { task_id, .. } => {
                    // The worker already ran the compute alongside the
                    // fetch: close the loop immediately.
                    self.tasks_finished += 1;
                    let mut effs = self.router.on_compute_done(task_id, now, now);
                    queue.extend(effs.drain(..));
                    self.router.recycle_effects(effs);
                }
                Effect::Allocate(n) => {
                    for _ in 0..n {
                        let mut effs = self.spawn_worker_registered(now)?;
                        queue.extend(effs.drain(..));
                        self.router.recycle_effects(effs);
                    }
                }
                Effect::Release(execs) => {
                    for e in execs {
                        self.release_worker(e);
                    }
                }
            }
        }
        Ok(())
    }

    /// An [`Effect::Allocate`] node comes up instantly on a local
    /// testbed: drain the requesting shard's pending count and spawn.
    fn spawn_worker_registered(&mut self, now: Micros) -> Result<Vec<Effect>> {
        let (exec, effects) = self.router.on_node_registered(now);
        self.attach_worker(exec)?;
        Ok(effects)
    }

    /// Enact one [`Effect::Release`]: scrub the executor from the
    /// router, shut its worker thread down and delete its cache
    /// directory — the transient resource, and every replica it held,
    /// are gone, exactly like a deallocated node in the sim. The router
    /// only names idle executors with no pending reservation and no
    /// in-flight cross-shard transfer (those are deferred and counted),
    /// so no undelivered work targets this worker; a racing peer *copy*
    /// from the vanished directory falls back to the persistent store
    /// in `run_one` and is recorded as the miss it was.
    fn release_worker(&mut self, exec: ExecutorId) {
        // Capture the shard before the router drops the binding.
        let s = self.shard_of(exec);
        self.router.release_node(exec);
        if let Some(h) = self.workers.remove(&exec) {
            self.exec_of_idx.remove(&h.idx);
            let _ = h.tx.send(ToWorker::Shutdown);
            let _ = h.join.join();
            let _ = std::fs::remove_dir_all(&h.cache_dir);
            self.shard_counts[s] = self.shard_counts[s].saturating_sub(1);
            self.workers_released += 1;
            crate::debug!("released idle worker {exec} (shard {s})");
        }
        // Belt and braces: reserved executors are never named in a
        // release, so this should find nothing.
        for q in &mut self.notify_q {
            q.retain(|&e| e != exec);
        }
    }

    /// Fault injection: kill one worker as if its node died.
    ///
    /// Rust threads cannot be destroyed preemptively, so the kill is
    /// cooperative on the *thread* (shutdown + join) but abrupt on the
    /// *coordinator*: `on_executor_failed` is fed before any in-flight
    /// result from the victim reaches the event API, so router-side
    /// this is a kill-mid-fetch — the victim's tasks requeue and any
    /// message its thread already sent is dropped via `dead_workers`.
    /// Prefers a victim with work in flight (lowest executor id breaks
    /// ties) and only considers shards with ≥ 2 workers so no shard is
    /// emptied; returns `Ok(false)` when no worker is eligible yet.
    fn kill_one_worker(&mut self, now: Micros) -> Result<bool> {
        let mut candidates: Vec<(bool, u32, ExecutorId)> = self
            .workers
            .iter()
            .filter(|(e, _)| self.shard_counts[self.shard_of(**e)] >= 2)
            .map(|(e, h)| (h.inflight == 0, e.0, *e))
            .collect();
        candidates.sort_unstable();
        let Some(&(_, _, exec)) = candidates.first() else {
            return Ok(false);
        };
        let s = self.shard_of(exec);
        let h = self.workers.remove(&exec).expect("candidate was just listed");
        self.exec_of_idx.remove(&h.idx);
        self.dead_workers.insert(h.idx);
        let _ = h.tx.send(ToWorker::Shutdown);
        let _ = h.join.join();
        let _ = std::fs::remove_dir_all(&h.cache_dir);
        self.shard_counts[s] = self.shard_counts[s].saturating_sub(1);
        for q in &mut self.notify_q {
            q.retain(|&e| e != exec);
        }
        crate::warn!("fault injection: killed worker {exec} (shard {s})");
        let effects = self.router.on_executor_failed(exec, now);
        self.apply(effects, now)?;
        Ok(true)
    }

    /// Map a resolved fetch plan onto a worker assignment.
    fn send_assignment(&mut self, plan: FetchPlan) -> Result<()> {
        let file_name = self
            .file_names
            .get(&plan.file)
            .cloned()
            .ok_or_else(|| Error::Runtime(format!("no file name for {}", plan.file)))?;
        let source = match (plan.kind, plan.peer) {
            (AccessKind::HitLocal, _) => FetchSource::Local,
            (AccessKind::HitGlobal, Some(p)) => {
                if self.partitioned && self.shard_of(p) != self.shard_of(plan.exec) {
                    // Injected partition: the cross-shard copy path is
                    // cut; fall back to the persistent store and let the
                    // worker report the miss it really experienced.
                    self.partition_fallbacks += 1;
                    FetchSource::Persistent
                } else {
                    match self.workers.get(&p) {
                        Some(h) => FetchSource::Peer(h.cache_dir.clone()),
                        // Peer retired or killed between planning and
                        // enactment: persistent-store fallback.
                        None => FetchSource::Persistent,
                    }
                }
            }
            _ => FetchSource::Persistent,
        };
        let evict: Vec<String> = plan
            .evicted
            .iter()
            .filter_map(|f| self.file_names.get(f).cloned())
            .collect();
        let h = self
            .workers
            .get_mut(&plan.exec)
            .ok_or_else(|| Error::Runtime(format!("fetch for unknown worker {}", plan.exec)))?;
        h.inflight += 1;
        h.tx.send(ToWorker::Run(Assignment {
            task_id: plan.task_id,
            file_name,
            source,
            evict,
        }))
        .expect("worker channel closed");
        self.outstanding += 1;
        Ok(())
    }

    /// Deliver queued notifications, draining shard queues round-robin
    /// until a full pass over all shards makes no progress.
    fn drain_notifications(&mut self, now: Micros) -> Result<()> {
        let k = self.notify_q.len();
        loop {
            let mut progressed = false;
            for s in 0..k {
                while let Some(e) = self.notify_q[s].pop_front() {
                    progressed = true;
                    let effects = self.router.on_pickup(e, now);
                    self.apply(effects, now)?;
                }
            }
            if !progressed {
                return Ok(());
            }
        }
    }

    /// Deliver queued notifications and keep the cluster busy: the live
    /// analogue of the sim's dispatcher drain plus tick safety net.
    fn pump(&mut self, now: Micros) -> Result<()> {
        loop {
            self.drain_notifications(now)?;
            // Safety net: tasks wait, workers are free, nothing is in
            // flight — force progress (max-cache-hit can decline).
            if self.outstanding > 0
                || self.router.queue_is_empty()
                || self.router.free_count() == 0
            {
                break;
            }
            let queue_before = self.router.queue_len();
            let effects = self.router.kick();
            if effects.is_empty() {
                break;
            }
            self.apply(effects, now)?;
            self.drain_notifications(now)?;
            if self.outstanding == 0 && self.router.queue_len() == queue_before {
                break; // the forced pickup declined too; wait for events
            }
        }
        Ok(())
    }

    /// Account a worker's answer: one fewer assignment in flight there.
    fn note_answer(&mut self, idx: usize) {
        if let Some(exec) = self.exec_of_idx.get(&idx) {
            if let Some(h) = self.workers.get_mut(exec) {
                h.inflight = h.inflight.saturating_sub(1);
            }
        }
    }

    fn shutdown_workers(&mut self) {
        for (_, h) in self.workers.drain() {
            let _ = h.tx.send(ToWorker::Shutdown);
            let _ = h.join.join();
        }
        self.exec_of_idx.clear();
    }
}

/// Run `tasks` through the live engine.
pub fn run(config: &LiveConfig, tasks: &[LiveTask]) -> Result<LiveReport> {
    if tasks.is_empty() {
        return Err(Error::config("live run needs at least one task"));
    }
    std::fs::create_dir_all(&config.cache_root)?;
    let t0 = Instant::now();
    let (done_tx, done_rx) = mpsc::channel::<WorkerMsg>();
    let shards = config.shards.max(1);

    // File sizes from the persistent store (needed for cache accounting).
    let mut file_sizes: HashMap<FileId, u64> = HashMap::new();
    let mut file_names: HashMap<FileId, String> = HashMap::new();
    for t in tasks {
        for (file, name) in t.inputs() {
            if let std::collections::hash_map::Entry::Vacant(e) = file_sizes.entry(file) {
                let meta = std::fs::metadata(config.persistent_dir.join(name))?;
                e.insert(meta.len());
                file_names.insert(file, name.to_string());
            }
        }
    }

    // The router needs at least one executor slot per shard.
    let max_workers = config
        .max_workers
        .max(config.initial_workers)
        .max(1)
        .max(shards);
    let router = ShardedCoordinator::new(
        CoreConfig {
            scheduler: SchedulerConfig {
                policy: config.policy,
                ..SchedulerConfig::default()
            },
            provisioner: ProvisionerConfig {
                allocation: config.allocation,
                idle_release_s: config.idle_release_s,
                static_provisioning: false,
                initial_nodes: config.initial_workers.max(1),
                queue_tasks_per_node: config.queue_tasks_per_worker.max(1) as u64,
            },
            cache: config.cache,
            max_nodes: max_workers,
            slots_per_node: 1,
            file_sizes: FileSizes::per_file(file_sizes),
        },
        shards,
        Pcg64::seeded(config.seed),
    );
    let mut drv = Driver::new(config, router, done_tx);
    drv.file_names = file_names;

    // Initial fleet, then batch submission (like the §5.1 microbench):
    // the fresh workers' notifications queue up and deliver after the
    // whole queue is populated — matching the sim driver, where arrivals
    // outrun the dispatcher's service latency. Round-robin registration
    // seeds every shard's pool.
    for _ in 0..config.initial_workers.max(1) {
        let now = now_micros(t0);
        let effects = drv.spawn_worker(now)?;
        drv.apply(effects, now)?;
    }
    for (i, t) in tasks.iter().enumerate() {
        let now = now_micros(t0);
        let task = Task {
            id: TaskId(i as u64),
            files: t.file_ids(),
            compute: Micros::ZERO,
            arrival: Micros::ZERO,
        };
        let effects = drv.router.on_arrival(task, 0, 0.0, now);
        drv.apply(effects, now)?;
    }
    drv.pump(now_micros(t0))?;

    let mut retried: HashMap<u64, bool> = HashMap::new();
    let mut failed = 0u64;
    let mut bytes_moved = 0u64;
    let mut fetch_total = Duration::ZERO;
    let mut compute_total = Duration::ZERO;
    let mut kill_pending = config.faults.kill_worker_after;

    // Main loop: completions drive re-dispatch through the router; the
    // per-shard provisioners grow their pools while queues stay long.
    while drv.tasks_finished + failed < tasks.len() as u64 {
        let now = now_micros(t0);
        // Sample + provisioning decision (the sim's 1 Hz tick, run per
        // completion here). Also how a shard whose pool was emptied by
        // releases regrows: its provisioner allocates on the next tick.
        let effects = drv.router.on_tick(now);
        drv.apply(effects, now)?;
        drv.pump(now)?;

        let msg = done_rx
            .recv_timeout(Duration::from_secs(60))
            .map_err(|_| Error::Runtime("live engine stalled for 60s".into()))?;
        let now = now_micros(t0);
        match msg {
            WorkerMsg::Done { worker, .. } | WorkerMsg::Failed { worker, .. }
                if drv.dead_workers.contains(&worker) =>
            {
                // A message the victim thread sent before the kill
                // landed: the router already requeued its task, so the
                // stale answer must not reach the event API or tallies.
                drv.outstanding -= 1;
                crate::debug!("dropped stale message from killed worker {worker}");
            }
            WorkerMsg::Done {
                worker,
                task_id,
                kind,
                bytes,
                fetch,
                compute,
            } => {
                crate::debug!("worker {worker}: task {task_id} fetch done ({kind:?}, {bytes} B)");
                drv.outstanding -= 1;
                drv.note_answer(worker);
                bytes_moved += bytes;
                fetch_total += fetch;
                compute_total += compute;
                // Report what the worker actually experienced (a peer
                // copy may have fallen back to the persistent store).
                // Multi-input tasks chain here: the router answers with
                // the next file's fetch, then the closing compute.
                let effects = drv.router.on_fetch_done(task_id, now, Some((kind, bytes)));
                drv.apply(effects, now)?;
            }
            WorkerMsg::Failed {
                worker,
                task_id,
                error,
            } => {
                drv.outstanding -= 1;
                drv.note_answer(worker);
                // Frees the slot and — when a backlog remains — re-notifies
                // the freed worker, so a permanently-failed task cannot
                // idle its executor for the rest of the run.
                let effects = drv.router.on_task_failed(task_id, now);
                drv.apply(effects, now)?;
                // Replay policy (§4.2): re-dispatch once, then count as
                // failed.
                if !retried.get(&task_id.0).copied().unwrap_or(false) {
                    retried.insert(task_id.0, true);
                    let t = &tasks[task_id.0 as usize];
                    let task = Task {
                        id: task_id,
                        files: t.file_ids(),
                        compute: Micros::ZERO,
                        arrival: now,
                    };
                    let effects = drv.router.on_arrival(task, 0, 0.0, now);
                    drv.apply(effects, now)?;
                    crate::warn!("task {task_id} failed on worker {worker} ({error}); replaying");
                } else {
                    failed += 1;
                    crate::error!("task {task_id} failed twice (worker {worker}): {error}");
                }
            }
        }
        // Completion-count fault triggers.
        if let Some(n) = kill_pending {
            if drv.tasks_finished >= n && drv.kill_one_worker(now)? {
                kill_pending = None;
            }
        }
        if let Some(n) = config.faults.partition_after {
            if !drv.partitioned && drv.tasks_finished >= n {
                drv.partitioned = true;
                crate::warn!("fault injection: shards partitioned");
            }
        }
        drv.pump(now)?;
    }

    // Shut down workers, then hold the run to the chaos oracle: every
    // live run ends state-consistent or errors out.
    drv.shutdown_workers();
    drv.router
        .check_integrity()
        .map_err(Error::SimInvariant)?;

    let completed = drv.tasks_finished;
    let dispatch_order = drv.router.take_dispatch_log();
    let shard = drv.router.take_counters();
    let recorder = drv.router.take_merged_recorder();
    let (hits_local, hits_global, misses) = recorder.access_counts();
    let done_tasks = completed.max(1);
    Ok(LiveReport {
        completed,
        failed,
        makespan: t0.elapsed(),
        hits_local,
        hits_global,
        misses,
        bytes_moved,
        avg_fetch: fetch_total / done_tasks as u32,
        avg_compute: compute_total / done_tasks as u32,
        peak_workers: drv.peak_workers,
        workers_released: drv.workers_released,
        dispatch_order,
        recorder,
        shard,
        workers_per_shard: drv.shard_peaks.clone(),
        partition_fallbacks: drv.partition_fallbacks,
    })
}

/// Scripted two-shard release-deferral probe, exercised by the chaos
/// suite (`rust/tests/chaos.rs`). Drives a real two-worker fleet with
/// *manual* coordinator timestamps so the idle-release decision and the
/// cross-shard serving deferral are deterministic: worker 1 (shard 1)
/// caches its shard's file, then serves it cross-shard to worker 0
/// (shard 0) while a tick falls mid-transfer — the router must defer
/// worker 1's release until the copy is fed back, then retire both.
/// Returns `(workers_released, cross_release_deferrals)`.
#[doc(hidden)]
pub fn scripted_cross_release_probe(root: &Path) -> Result<(u64, u64)> {
    fn t(s: u64) -> Micros {
        Micros::from_secs(s)
    }
    fn feed_done(
        drv: &mut Driver<'_>,
        rx: &mpsc::Receiver<WorkerMsg>,
        now: Micros,
    ) -> Result<()> {
        match rx.recv_timeout(Duration::from_secs(30)) {
            Ok(WorkerMsg::Done {
                worker,
                task_id,
                kind,
                bytes,
                ..
            }) => {
                drv.outstanding -= 1;
                drv.note_answer(worker);
                let effects = drv.router.on_fetch_done(task_id, now, Some((kind, bytes)));
                drv.apply(effects, now)?;
                drv.pump(now)
            }
            Ok(WorkerMsg::Failed { task_id, error, .. }) => Err(Error::Runtime(format!(
                "probe task {task_id} failed: {error}"
            ))),
            Err(_) => Err(Error::Runtime("probe worker stalled".into())),
        }
    }

    let store = root.join("store");
    let cache_root = root.join("caches");
    std::fs::create_dir_all(&store)?;
    std::fs::create_dir_all(&cache_root)?;
    let config = LiveConfig {
        initial_workers: 2,
        max_workers: 2,
        queue_tasks_per_worker: usize::MAX >> 8,
        allocation: AllocationPolicy::OneAtATime,
        policy: DispatchPolicy::GoodCacheCompute,
        cache: CacheConfig::lru(1 << 20),
        persistent_dir: store.clone(),
        cache_root,
        compute: ComputeKind::Sleep(Duration::from_millis(1)),
        seed: 11,
        idle_release_s: 0.5,
        shards: 2,
        faults: LiveFaults::default(),
    };
    let (done_tx, done_rx) = mpsc::channel::<WorkerMsg>();
    let router = ShardedCoordinator::new(
        CoreConfig {
            scheduler: SchedulerConfig {
                policy: config.policy,
                ..SchedulerConfig::default()
            },
            provisioner: ProvisionerConfig {
                allocation: config.allocation,
                idle_release_s: config.idle_release_s,
                static_provisioning: false,
                initial_nodes: 2,
                queue_tasks_per_node: u64::MAX >> 8,
            },
            cache: config.cache,
            max_nodes: 2,
            slots_per_node: 1,
            file_sizes: FileSizes::Uniform(2048),
        },
        2,
        Pcg64::seeded(config.seed),
    );
    let mut drv = Driver::new(&config, router, done_tx);

    // One file homed on each shard (the hash router decides homes).
    let file_a = (0u32..1024)
        .map(FileId)
        .find(|&f| drv.router.shard_of_file(f) == 0)
        .ok_or_else(|| Error::Runtime("no shard-0 file id in probe range".into()))?;
    let file_b = (0u32..1024)
        .map(FileId)
        .find(|&f| drv.router.shard_of_file(f) == 1)
        .ok_or_else(|| Error::Runtime("no shard-1 file id in probe range".into()))?;
    std::fs::write(store.join("fa.bin"), vec![0xAAu8; 2048])?;
    std::fs::write(store.join("fb.bin"), vec![0xBBu8; 2048])?;
    drv.file_names.insert(file_a, "fa.bin".into());
    drv.file_names.insert(file_b, "fb.bin".into());

    // Round-robin registration: worker 0 → shard 0, worker 1 → shard 1.
    for _ in 0..2 {
        let effects = drv.spawn_worker(t(0))?;
        drv.apply(effects, t(0))?;
    }

    // Task 0 seeds worker 1's cache with shard 1's file.
    let effects = drv.router.on_arrival(
        Task {
            id: TaskId(0),
            files: vec![file_b],
            compute: Micros::ZERO,
            arrival: t(0),
        },
        0,
        0.0,
        t(0),
    );
    drv.apply(effects, t(0))?;
    drv.pump(t(0))?;
    feed_done(&mut drv, &done_rx, t(1))?;

    // Task 1 on shard 0 needs [file_a, file_b]: the chained second
    // fetch is the cross-shard copy served by worker 1.
    let effects = drv.router.on_arrival(
        Task {
            id: TaskId(1),
            files: vec![file_a, file_b],
            compute: Micros::ZERO,
            arrival: t(2),
        },
        0,
        0.0,
        t(2),
    );
    drv.apply(effects, t(2))?;
    drv.pump(t(2))?;
    // fa.bin staged (persistent miss); the router answers with the
    // cross-shard fetch of fb.bin and marks worker 1 as serving.
    feed_done(&mut drv, &done_rx, t(3))?;

    // Mid-transfer tick: worker 1 has been idle since t=1 — far past
    // the 0.5 s release threshold — but it is serving a cross-shard
    // copy, so the router must defer its release.
    let effects = drv.router.on_tick(t(10));
    drv.apply(effects, t(10))?;
    let deferrals = drv.router.counters().cross_release_deferrals;

    // The copy lands; task 1 completes.
    feed_done(&mut drv, &done_rx, t(11))?;

    // Post-transfer tick: both workers idle well past the threshold
    // and no transfer in flight — now they retire.
    let effects = drv.router.on_tick(t(20));
    drv.apply(effects, t(20))?;

    drv.router.check_integrity().map_err(Error::SimInvariant)?;
    drv.shutdown_workers();
    Ok((drv.workers_released, deferrals))
}

fn now_micros(t0: Instant) -> Micros {
    Micros(t0.elapsed().as_micros() as u64)
}

/// Worker thread: fetch the file per the coordinator's instruction, run
/// the compute, report back.
fn worker_main(
    idx: usize,
    rx: mpsc::Receiver<ToWorker>,
    done: mpsc::Sender<WorkerMsg>,
    persistent: PathBuf,
    cache_dir: PathBuf,
    compute: ComputeKind,
) {
    // PJRT handles are not Sync: each worker compiles its own pipeline.
    let stacker = match &compute {
        ComputeKind::Stacking => match crate::runtime::Artifacts::open_default()
            .and_then(|a| a.stacking())
        {
            Ok(s) => Some(s),
            Err(e) => {
                crate::error!("worker {idx}: cannot load stacking artifact: {e}");
                None
            }
        },
        ComputeKind::Sleep(_) => None,
    };
    while let Ok(ToWorker::Run(a)) = rx.recv() {
        let result = run_one(&a, &persistent, &cache_dir, &compute, stacker.as_ref());
        let msg = match result {
            Ok((kind, bytes, fetch, comp)) => WorkerMsg::Done {
                worker: idx,
                task_id: a.task_id,
                kind,
                bytes,
                fetch,
                compute: comp,
            },
            Err(e) => WorkerMsg::Failed {
                worker: idx,
                task_id: a.task_id,
                error: e.to_string(),
            },
        };
        if done.send(msg).is_err() {
            return; // coordinator gone
        }
    }
}

fn run_one(
    a: &Assignment,
    persistent: &Path,
    cache_dir: &Path,
    compute: &ComputeKind,
    stacker: Option<&crate::runtime::StackingExecutable>,
) -> Result<(AccessKind, u64, Duration, Duration)> {
    for name in &a.evict {
        let _ = std::fs::remove_file(cache_dir.join(name));
    }
    let local_path = cache_dir.join(&a.file_name);
    let tf = Instant::now();
    let (kind, bytes) = match &a.source {
        FetchSource::Local => {
            let meta = std::fs::metadata(&local_path)?;
            (AccessKind::HitLocal, meta.len())
        }
        FetchSource::Peer(peer_dir) => {
            // The peer may not have finished writing the object yet (the
            // coordinator's index is updated at dispatch time); fall back
            // to persistent storage like a real executor would (§3.1:
            // "only if no cached copy is available does the executor
            // request a copy from persistent storage").
            match std::fs::copy(peer_dir.join(&a.file_name), &local_path) {
                Ok(n) => (AccessKind::HitGlobal, n),
                Err(_) => {
                    let n = std::fs::copy(persistent.join(&a.file_name), &local_path)?;
                    (AccessKind::Miss, n)
                }
            }
        }
        FetchSource::Persistent => {
            let n = std::fs::copy(persistent.join(&a.file_name), &local_path)?;
            (AccessKind::Miss, n)
        }
    };
    let fetch = tf.elapsed();

    let tc = Instant::now();
    match compute {
        ComputeKind::Sleep(d) => thread::sleep(*d),
        ComputeKind::Stacking => {
            let stacker = stacker
                .ok_or_else(|| Error::Runtime("stacking executable unavailable".into()))?;
            let data = std::fs::read(&local_path)?;
            let floats: Vec<f32> = data
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect();
            use crate::runtime::shapes::{STACK_H, STACK_W};
            let frame = STACK_H * STACK_W;
            if floats.len() < frame + 1 {
                return Err(Error::Runtime(format!(
                    "file {} too small for stacking ({} floats)",
                    a.file_name,
                    floats.len()
                )));
            }
            // Layout: n full frames followed by n weights.
            let n = floats.len() / (frame + 1);
            let (cutouts, weights) = floats.split_at(n * frame);
            let res = stacker.stack(cutouts, &weights[..n])?;
            // Consume the result so the work cannot be elided.
            if !res.mean.is_finite() {
                return Err(Error::Runtime("non-finite stacking output".into()));
            }
        }
    }
    let comp = tc.elapsed();
    Ok((kind, bytes, fetch, comp))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::EvictionPolicy;

    fn setup_dataset(dir: &Path, files: usize, bytes: usize) -> Vec<LiveTask> {
        std::fs::create_dir_all(dir).unwrap();
        let mut tasks = Vec::new();
        for i in 0..files {
            let name = format!("f{i}.bin");
            std::fs::write(dir.join(&name), vec![i as u8; bytes]).unwrap();
            // 3 accesses per file.
            for _ in 0..3 {
                tasks.push(LiveTask::single(name.clone(), FileId(i as u32)));
            }
        }
        tasks
    }

    fn tmp(name: &str) -> PathBuf {
        let p = std::env::temp_dir().join(format!("dd-live-{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&p);
        p
    }

    fn base_config(data: PathBuf, cache_root: PathBuf) -> LiveConfig {
        LiveConfig {
            initial_workers: 3,
            max_workers: 3,
            queue_tasks_per_worker: 10,
            allocation: AllocationPolicy::OneAtATime,
            policy: DispatchPolicy::GoodCacheCompute,
            cache: CacheConfig {
                capacity_bytes: 1 << 20,
                policy: EvictionPolicy::Lru,
            },
            persistent_dir: data,
            cache_root,
            compute: ComputeKind::Sleep(Duration::from_millis(1)),
            seed: 7,
            idle_release_s: 0.0,
            shards: 1,
            faults: LiveFaults::default(),
        }
    }

    #[test]
    fn live_run_completes_and_hits_cache() {
        let root = tmp("basic");
        let data = root.join("store");
        let tasks = setup_dataset(&data, 10, 4096);
        let cfg = base_config(data, root.join("caches"));
        let report = run(&cfg, &tasks).expect("live run");
        assert_eq!(report.completed, 30);
        assert_eq!(report.failed, 0);
        // 10 cold misses; the 20 re-accesses must hit some cache.
        assert!(report.misses >= 10, "misses {}", report.misses);
        assert!(
            report.hits_local + report.hits_global >= 15,
            "hits {} + {}",
            report.hits_local,
            report.hits_global
        );
        // The report's tallies are the merged recorder's tallies.
        assert_eq!(
            report.recorder.access_counts(),
            (report.hits_local, report.hits_global, report.misses)
        );
        assert_eq!(report.dispatch_order.len(), 30);
        // K=1: one shard carrying the whole run, no cross traffic.
        assert_eq!(report.shard.shards, 1);
        assert_eq!(report.shard.cross_fetches, 0);
        assert_eq!(report.workers_per_shard, vec![3]);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn model_allocation_runs_live() {
        let root = tmp("model");
        let data = root.join("store");
        let tasks = setup_dataset(&data, 10, 4096);
        let mut cfg = base_config(data, root.join("caches"));
        cfg.initial_workers = 1;
        cfg.allocation = AllocationPolicy::Model;
        let report = run(&cfg, &tasks).expect("live run under --allocation model");
        assert_eq!(report.completed, 30);
        assert_eq!(report.failed, 0);
        assert_eq!(report.dispatch_order.len(), 30);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn first_available_never_caches() {
        let root = tmp("fa");
        let data = root.join("store");
        let tasks = setup_dataset(&data, 5, 1024);
        let mut cfg = base_config(data, root.join("caches"));
        cfg.initial_workers = 2;
        cfg.max_workers = 2;
        cfg.policy = DispatchPolicy::FirstAvailable;
        let report = run(&cfg, &tasks).expect("live run");
        assert_eq!(report.completed, 15);
        assert_eq!(report.misses, 15);
        assert_eq!(report.hits_local + report.hits_global, 0);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn sharded_live_run_completes_on_every_shard() {
        let root = tmp("sharded");
        let data = root.join("store");
        let tasks = setup_dataset(&data, 12, 2048);
        let mut cfg = base_config(data, root.join("caches"));
        cfg.initial_workers = 2;
        cfg.max_workers = 2;
        cfg.shards = 2;
        let report = run(&cfg, &tasks).expect("sharded live run");
        assert_eq!(report.completed, 36);
        assert_eq!(report.failed, 0);
        assert_eq!(report.shard.shards, 2);
        assert_eq!(report.workers_per_shard.len(), 2);
        // Round-robin registration puts one worker on each shard, and
        // 12 distinct files hash onto both shards.
        assert!(
            report.workers_per_shard.iter().all(|&w| w > 0),
            "some shard never had a worker: {:?}",
            report.workers_per_shard
        );
        let routed: Vec<u64> = report.shard.per_shard.iter().map(|s| s.tasks_routed).collect();
        assert_eq!(routed.iter().sum::<u64>(), 36);
        assert!(routed.iter().all(|&r| r > 0), "unbalanced routing {routed:?}");
        let dispatched: u64 = report.shard.per_shard.iter().map(|s| s.dispatches).sum();
        assert_eq!(dispatched, 36);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn release_effect_retires_worker_and_scrubs_cache_dir() {
        // Drive the Driver directly with router-time stamps so the test
        // is deterministic: two idle workers, a tick far in the future,
        // and the resulting Release must shut threads down, delete
        // cache directories and scrub the router.
        let root = tmp("release");
        let data = root.join("store");
        let _tasks = setup_dataset(&data, 2, 512);
        let mut cfg = base_config(data, root.join("caches"));
        cfg.initial_workers = 2;
        cfg.max_workers = 2;
        cfg.idle_release_s = 0.5;
        std::fs::create_dir_all(&cfg.cache_root).unwrap();
        let (done_tx, _done_rx) = mpsc::channel::<WorkerMsg>();
        let router = ShardedCoordinator::new(
            CoreConfig {
                scheduler: SchedulerConfig {
                    policy: cfg.policy,
                    ..SchedulerConfig::default()
                },
                provisioner: ProvisionerConfig {
                    allocation: cfg.allocation,
                    idle_release_s: cfg.idle_release_s,
                    static_provisioning: false,
                    initial_nodes: 2,
                    queue_tasks_per_node: 10,
                },
                cache: cfg.cache,
                max_nodes: 2,
                slots_per_node: 1,
                file_sizes: FileSizes::Uniform(512),
            },
            1,
            Pcg64::seeded(cfg.seed),
        );
        let mut drv = Driver::new(&cfg, router, done_tx);
        drv.spawn_worker(Micros::ZERO).unwrap();
        drv.spawn_worker(Micros::ZERO).unwrap();
        assert_eq!(drv.workers.len(), 2);
        let dirs: Vec<PathBuf> = drv.workers.values().map(|h| h.cache_dir.clone()).collect();
        assert!(dirs.iter().all(|d| d.exists()));

        // Ten idle seconds later the provisioner must want them gone.
        let now = Micros::from_secs(10);
        let effects = drv.router.on_tick(now);
        assert!(
            effects
                .iter()
                .any(|e| matches!(e, Effect::Release(v) if !v.is_empty())),
            "expected a release of idle workers, got {effects:?}"
        );
        drv.apply(effects, now).unwrap();
        assert!(drv.workers_released >= 1, "no worker was retired");
        assert_eq!(drv.workers.len(), 2 - drv.workers_released as usize);
        // Retired workers' cache directories are gone; survivors' remain.
        let gone = dirs.iter().filter(|d| !d.exists()).count();
        assert_eq!(gone as u64, drv.workers_released);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn provisioner_spawns_extra_workers() {
        let root = tmp("prov");
        let data = root.join("store");
        let tasks = setup_dataset(&data, 20, 512);
        let mut cfg = base_config(data, root.join("caches"));
        cfg.initial_workers = 1;
        cfg.max_workers = 4;
        cfg.queue_tasks_per_worker = 5;
        cfg.allocation = AllocationPolicy::Multiplicative(2.0);
        cfg.compute = ComputeKind::Sleep(Duration::from_millis(2));
        let report = run(&cfg, &tasks).expect("live run");
        assert_eq!(report.completed, 60);
        assert!(report.peak_workers > 1, "never grew: {}", report.peak_workers);
        let _ = std::fs::remove_dir_all(&root);
    }
}
