//! Live execution engine: the coordinator driving *real* work.
//!
//! Where [`crate::sim`] substitutes the testbed, this engine runs the
//! identical coordinator logic (wait queue, data-aware scheduler,
//! location index, per-executor caches, demand-driven provisioning) over
//! real worker threads that move real files and run real compute:
//!
//! * the **persistent store** is a directory (the GPFS stand-in);
//! * each worker owns a **local cache directory**; a dispatch tells it
//!   where to fetch from — its own cache (local hit), a peer worker's
//!   cache directory (global hit, the GridFTP path), or the persistent
//!   store (miss) — exactly the three-way split of §5.2.1;
//! * per-task compute is either a calibrated sleep or the AOT-compiled
//!   **PJRT stacking pipeline** (`examples/astronomy_stacking.rs`), so
//!   the full three-layer stack (Rust → HLO → Pallas kernel) is on the
//!   hot path with Python nowhere in sight;
//! * **dynamic provisioning**: workers are spawned on demand from the
//!   wait-queue length and retired when idle, mirroring the DRP.

use crate::cache::{CacheConfig, ObjectCache};
use crate::coordinator::pending::PendingIndex;
use crate::coordinator::queue::{Task, WaitQueue};
use crate::coordinator::scheduler::{DispatchPolicy, Scheduler, SchedulerConfig};
use crate::coordinator::executor::ExecutorRegistry;
use crate::coordinator::{resolve_access, AccessKind};
use crate::ids::{ExecutorId, FileId, TaskId};
use crate::index::LocationIndex;
use crate::metrics::Recorder;
use crate::util::prng::Pcg64;
use crate::util::time::Micros;
use crate::{Error, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::thread;
use std::time::{Duration, Instant};

/// What a worker does after staging its input file.
#[derive(Debug, Clone)]
pub enum ComputeKind {
    /// Sleep for the given duration (micro-benchmark workloads).
    Sleep(Duration),
    /// Run the AOT stacking pipeline on the file's contents (the file
    /// must hold STACK-shaped f32 cutouts + weights; see
    /// [`crate::runtime::StackingExecutable`]). Each worker compiles its
    /// own executable (PJRT handles are not Sync).
    Stacking,
}

/// Live-engine configuration.
#[derive(Debug, Clone)]
pub struct LiveConfig {
    /// Workers to start with.
    pub initial_workers: usize,
    /// Maximum workers the provisioner may spawn.
    pub max_workers: usize,
    /// Queue length per worker that triggers growth.
    pub queue_tasks_per_worker: usize,
    /// Dispatch policy.
    pub policy: DispatchPolicy,
    /// Per-worker cache configuration.
    pub cache: CacheConfig,
    /// Directory holding the dataset (the persistent store).
    pub persistent_dir: PathBuf,
    /// Root under which per-worker cache directories are created.
    pub cache_root: PathBuf,
    /// Per-task compute.
    pub compute: ComputeKind,
    /// PRNG seed (peer selection, eviction randomness).
    pub seed: u64,
}

/// One task for the live engine: read `file`, compute.
#[derive(Debug, Clone)]
pub struct LiveTask {
    /// File name inside `persistent_dir`.
    pub file_name: String,
    /// Logical file id (for the scheduler/index).
    pub file: FileId,
}

/// Where the worker should fetch its input from.
#[derive(Debug, Clone)]
enum FetchSource {
    /// Already in the worker's own cache directory.
    Local,
    /// Copy from this peer cache directory.
    Peer(PathBuf),
    /// Copy from the persistent store.
    Persistent,
}

#[derive(Debug)]
struct Assignment {
    task_id: TaskId,
    file_name: String,
    source: FetchSource,
    /// Files the worker should delete from its cache dir (evictions
    /// decided by the coordinator-side cache model).
    evict: Vec<String>,
}

#[derive(Debug)]
enum WorkerMsg {
    Done {
        worker: usize,
        task_id: TaskId,
        kind: AccessKind,
        bytes: u64,
        fetch: Duration,
        compute: Duration,
    },
    Failed {
        worker: usize,
        task_id: TaskId,
        error: String,
    },
}

enum ToWorker {
    Run(Assignment),
    Shutdown,
}

struct WorkerHandle {
    tx: mpsc::Sender<ToWorker>,
    join: thread::JoinHandle<()>,
    cache_dir: PathBuf,
}

/// End-of-run report from the live engine.
#[derive(Debug)]
pub struct LiveReport {
    /// Tasks completed successfully.
    pub completed: u64,
    /// Tasks failed (worker errors; the replay policy retries once).
    pub failed: u64,
    /// Wall-clock makespan.
    pub makespan: Duration,
    /// Local/global/miss access counts.
    pub hits_local: u64,
    /// Peer-cache hits.
    pub hits_global: u64,
    /// Persistent-store misses.
    pub misses: u64,
    /// Total bytes fetched (all sources).
    pub bytes_moved: u64,
    /// Mean per-task fetch time.
    pub avg_fetch: Duration,
    /// Mean per-task compute time.
    pub avg_compute: Duration,
    /// Peak worker count (provisioning).
    pub peak_workers: usize,
    /// Per-second recorder (same shape as the simulator's).
    pub recorder: Recorder,
}

/// Run `tasks` through the live engine.
pub fn run(config: &LiveConfig, tasks: &[LiveTask]) -> Result<LiveReport> {
    if tasks.is_empty() {
        return Err(Error::Config("live run needs at least one task".into()));
    }
    std::fs::create_dir_all(&config.cache_root)?;
    let t0 = Instant::now();
    let (done_tx, done_rx) = mpsc::channel::<WorkerMsg>();

    let mut rng = Pcg64::seeded(config.seed);
    let mut sched = Scheduler::new(SchedulerConfig {
        policy: config.policy,
        ..SchedulerConfig::default()
    });
    let mut reg = ExecutorRegistry::new();
    let mut index = LocationIndex::new();
    let mut queue = WaitQueue::new();
    let mut pending = PendingIndex::new();
    let mut caches: HashMap<ExecutorId, ObjectCache> = HashMap::new();
    let mut workers: HashMap<ExecutorId, WorkerHandle> = HashMap::new();
    let mut rec = Recorder::new();

    // File sizes from the persistent store (needed for cache accounting).
    let mut file_sizes: HashMap<FileId, u64> = HashMap::new();
    let mut file_names: HashMap<FileId, String> = HashMap::new();
    for t in tasks {
        if let std::collections::hash_map::Entry::Vacant(e) = file_sizes.entry(t.file) {
            let meta = std::fs::metadata(config.persistent_dir.join(&t.file_name))?;
            e.insert(meta.len());
            file_names.insert(t.file, t.file_name.clone());
        }
    }

    let spawn_worker = |idx: usize,
                        reg: &mut ExecutorRegistry,
                        index: &mut LocationIndex,
                        caches: &mut HashMap<ExecutorId, ObjectCache>,
                        workers: &mut HashMap<ExecutorId, WorkerHandle>|
     -> Result<ExecutorId> {
        let exec = reg.register(1, Micros::ZERO);
        let cache_dir = config.cache_root.join(format!("worker-{idx}"));
        std::fs::create_dir_all(&cache_dir)?;
        if config.policy.uses_caching() {
            index.register_executor(exec);
            caches.insert(exec, ObjectCache::new(config.cache));
        }
        let (tx, rx) = mpsc::channel::<ToWorker>();
        let done = done_tx.clone();
        let persistent = config.persistent_dir.clone();
        let cdir = cache_dir.clone();
        let compute = config.compute.clone();
        let join = thread::Builder::new()
            .name(format!("dd-worker-{idx}"))
            .spawn(move || worker_main(idx, rx, done, persistent, cdir, compute))
            .map_err(Error::Io)?;
        workers.insert(
            exec,
            WorkerHandle {
                tx,
                join,
                cache_dir,
            },
        );
        Ok(exec)
    };

    let mut next_worker_idx = 0usize;
    let mut exec_by_idx: Vec<ExecutorId> = Vec::new();
    for _ in 0..config.initial_workers.max(1) {
        let e = spawn_worker(next_worker_idx, &mut reg, &mut index, &mut caches, &mut workers)?;
        exec_by_idx.push(e);
        next_worker_idx += 1;
    }
    let mut peak_workers = workers.len();

    // Submit everything (batch submission, like the §5.1 microbench).
    for (i, t) in tasks.iter().enumerate() {
        let qref = queue.push_back(Task {
            id: TaskId(i as u64),
            files: vec![t.file],
            compute: Micros::ZERO,
            arrival: Micros::ZERO,
        });
        if config.policy.uses_caching() {
            pending.on_push(&queue, qref, &index);
        }
        rec.record_arrival(Micros::ZERO, 0, 0.0);
    }

    // Dispatch helper: assign work to one free worker; returns true if a
    // task was dispatched.
    let mut retried: HashMap<u64, bool> = HashMap::new();
    let mut completed = 0u64;
    let mut failed = 0u64;
    let (mut hits_local, mut hits_global, mut misses) = (0u64, 0u64, 0u64);
    let mut bytes_moved = 0u64;
    let mut fetch_total = Duration::ZERO;
    let mut compute_total = Duration::ZERO;

    macro_rules! pump {
        () => {{
            loop {
                let free: Vec<ExecutorId> = reg.free_iter().collect();
                let mut dispatched_any = false;
                for exec in free {
                    if queue.is_empty() {
                        break;
                    }
                    let picked =
                        sched.pick_tasks(exec, 1, &mut queue, &mut pending, &reg, &index);
                    for task in picked {
                        reg.start_task(exec, now_micros(t0));
                        let file = task.files[0];
                        let size = file_sizes[&file];
                        let file_name = file_names[&file].clone();
                        let (source, evict) = if config.policy.uses_caching() {
                            let cache = caches.get_mut(&exec).expect("cache");
                            let res =
                                resolve_access(exec, file, size, cache, &mut index, &mut rng);
                            // Keep the inverted pending index coherent
                            // with the index changes just made.
                            for &old in &res.evicted {
                                pending.on_index_remove(old, exec, &queue, &index);
                            }
                            if res.inserted {
                                pending.on_index_add(file, exec);
                            }
                            let evicted_names: Vec<String> = res
                                .evicted
                                .iter()
                                .filter_map(|f| file_names.get(f).cloned())
                                .collect();
                            let source = match (res.kind, res.peer) {
                                (AccessKind::HitLocal, _) => FetchSource::Local,
                                (AccessKind::HitGlobal, Some(p)) => {
                                    FetchSource::Peer(workers[&p].cache_dir.clone())
                                }
                                _ => FetchSource::Persistent,
                            };
                            (source, evicted_names)
                        } else {
                            (FetchSource::Persistent, Vec::new())
                        };
                        workers[&exec]
                            .tx
                            .send(ToWorker::Run(Assignment {
                                task_id: task.id,
                                file_name,
                                source,
                                evict,
                            }))
                            .expect("worker channel closed");
                        dispatched_any = true;
                    }
                }
                if !dispatched_any {
                    break;
                }
            }
        }};
    }

    pump!();

    // Main loop: completions drive re-dispatch; the provisioner grows
    // the fleet while the queue stays long.
    while completed + failed < tasks.len() as u64 {
        // Provision: spawn a worker if the queue is long and we have
        // headroom (live DRP — no GRAM latency on a local testbed).
        if queue.len() > config.queue_tasks_per_worker * workers.len()
            && workers.len() < config.max_workers
        {
            let e =
                spawn_worker(next_worker_idx, &mut reg, &mut index, &mut caches, &mut workers)?;
            exec_by_idx.push(e);
            next_worker_idx += 1;
            peak_workers = peak_workers.max(workers.len());
            pump!();
        }
        let msg = done_rx
            .recv_timeout(Duration::from_secs(60))
            .map_err(|_| Error::Runtime("live engine stalled for 60s".into()))?;
        let widx_of = |m: &WorkerMsg| match m {
            WorkerMsg::Done { worker, .. } | WorkerMsg::Failed { worker, .. } => *worker,
        };
        let sender_idx = widx_of(&msg);
        match msg {
            WorkerMsg::Done {
                worker: _,
                task_id,
                kind,
                bytes,
                fetch,
                compute,
            } => {
                completed += 1;
                match kind {
                    AccessKind::HitLocal => hits_local += 1,
                    AccessKind::HitGlobal => hits_global += 1,
                    AccessKind::Miss => misses += 1,
                }
                bytes_moved += bytes;
                fetch_total += fetch;
                compute_total += compute;
                let now = now_micros(t0);
                rec.record_access(now, kind, bytes);
                rec.record_completion(now, Micros::ZERO, 0);
                let _ = task_id;
            }
            WorkerMsg::Failed {
                worker: _,
                task_id,
                error,
            } => {
                // Replay policy (§4.2): re-dispatch once, then count as
                // failed.
                if !retried.get(&task_id.0).copied().unwrap_or(false) {
                    retried.insert(task_id.0, true);
                    let t = &tasks[task_id.0 as usize];
                    let qref = queue.push_back(Task {
                        id: task_id,
                        files: vec![t.file],
                        compute: Micros::ZERO,
                        arrival: now_micros(t0),
                    });
                    if config.policy.uses_caching() {
                        pending.on_push(&queue, qref, &index);
                    }
                    crate::warn!("task {task_id} failed ({error}); replaying");
                } else {
                    failed += 1;
                    crate::error!("task {task_id} failed twice: {error}");
                }
            }
        }
        // The sender's slot frees regardless of outcome (worker idx ==
        // spawn order == exec_by_idx position).
        reg.finish_task(exec_by_idx[sender_idx], now_micros(t0));
        rec.sample(
            now_micros(t0),
            queue.len(),
            workers.len(),
            reg.busy_slots(),
            reg.total_slots(),
        );
        pump!();
    }

    // Shut down workers.
    for (_, h) in workers.drain() {
        let _ = h.tx.send(ToWorker::Shutdown);
        let _ = h.join.join();
    }

    let done_tasks = completed.max(1);
    Ok(LiveReport {
        completed,
        failed,
        makespan: t0.elapsed(),
        hits_local,
        hits_global,
        misses,
        bytes_moved,
        avg_fetch: fetch_total / done_tasks as u32,
        avg_compute: compute_total / done_tasks as u32,
        peak_workers,
        recorder: rec,
    })
}

fn now_micros(t0: Instant) -> Micros {
    Micros(t0.elapsed().as_micros() as u64)
}

/// Worker thread: fetch the file per the coordinator's instruction, run
/// the compute, report back.
fn worker_main(
    idx: usize,
    rx: mpsc::Receiver<ToWorker>,
    done: mpsc::Sender<WorkerMsg>,
    persistent: PathBuf,
    cache_dir: PathBuf,
    compute: ComputeKind,
) {
    // PJRT handles are not Sync: each worker compiles its own pipeline.
    let stacker = match &compute {
        ComputeKind::Stacking => match crate::runtime::Artifacts::open_default()
            .and_then(|a| a.stacking())
        {
            Ok(s) => Some(s),
            Err(e) => {
                crate::error!("worker {idx}: cannot load stacking artifact: {e}");
                None
            }
        },
        ComputeKind::Sleep(_) => None,
    };
    while let Ok(ToWorker::Run(a)) = rx.recv() {
        let result = run_one(&a, &persistent, &cache_dir, &compute, stacker.as_ref());
        let msg = match result {
            Ok((kind, bytes, fetch, comp)) => WorkerMsg::Done {
                worker: idx,
                task_id: a.task_id,
                kind,
                bytes,
                fetch,
                compute: comp,
            },
            Err(e) => WorkerMsg::Failed {
                worker: idx,
                task_id: a.task_id,
                error: e.to_string(),
            },
        };
        if done.send(msg).is_err() {
            return; // coordinator gone
        }
    }
}

fn run_one(
    a: &Assignment,
    persistent: &Path,
    cache_dir: &Path,
    compute: &ComputeKind,
    stacker: Option<&crate::runtime::StackingExecutable>,
) -> Result<(AccessKind, u64, Duration, Duration)> {
    for name in &a.evict {
        let _ = std::fs::remove_file(cache_dir.join(name));
    }
    let local_path = cache_dir.join(&a.file_name);
    let tf = Instant::now();
    let (kind, bytes) = match &a.source {
        FetchSource::Local => {
            let meta = std::fs::metadata(&local_path)?;
            (AccessKind::HitLocal, meta.len())
        }
        FetchSource::Peer(peer_dir) => {
            // The peer may not have finished writing the object yet (the
            // coordinator's index is updated at dispatch time); fall back
            // to persistent storage like a real executor would (§3.1:
            // "only if no cached copy is available does the executor
            // request a copy from persistent storage").
            match std::fs::copy(peer_dir.join(&a.file_name), &local_path) {
                Ok(n) => (AccessKind::HitGlobal, n),
                Err(_) => {
                    let n = std::fs::copy(persistent.join(&a.file_name), &local_path)?;
                    (AccessKind::Miss, n)
                }
            }
        }
        FetchSource::Persistent => {
            let n = std::fs::copy(persistent.join(&a.file_name), &local_path)?;
            (AccessKind::Miss, n)
        }
    };
    let fetch = tf.elapsed();

    let tc = Instant::now();
    match compute {
        ComputeKind::Sleep(d) => thread::sleep(*d),
        ComputeKind::Stacking => {
            let stacker = stacker
                .ok_or_else(|| Error::Runtime("stacking executable unavailable".into()))?;
            let data = std::fs::read(&local_path)?;
            let floats: Vec<f32> = data
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect();
            use crate::runtime::shapes::{STACK_H, STACK_W};
            let frame = STACK_H * STACK_W;
            if floats.len() < frame + 1 {
                return Err(Error::Runtime(format!(
                    "file {} too small for stacking ({} floats)",
                    a.file_name,
                    floats.len()
                )));
            }
            // Layout: n full frames followed by n weights.
            let n = floats.len() / (frame + 1);
            let (cutouts, weights) = floats.split_at(n * frame);
            let res = stacker.stack(cutouts, &weights[..n])?;
            // Consume the result so the work cannot be elided.
            if !res.mean.is_finite() {
                return Err(Error::Runtime("non-finite stacking output".into()));
            }
        }
    }
    let comp = tc.elapsed();
    Ok((kind, bytes, fetch, comp))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::EvictionPolicy;

    fn setup_dataset(dir: &Path, files: usize, bytes: usize) -> Vec<LiveTask> {
        std::fs::create_dir_all(dir).unwrap();
        let mut tasks = Vec::new();
        for i in 0..files {
            let name = format!("f{i}.bin");
            std::fs::write(dir.join(&name), vec![i as u8; bytes]).unwrap();
            // 3 accesses per file.
            for _ in 0..3 {
                tasks.push(LiveTask {
                    file_name: name.clone(),
                    file: FileId(i as u32),
                });
            }
        }
        tasks
    }

    fn tmp(name: &str) -> PathBuf {
        let p = std::env::temp_dir().join(format!("dd-live-{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&p);
        p
    }

    #[test]
    fn live_run_completes_and_hits_cache() {
        let root = tmp("basic");
        let data = root.join("store");
        let tasks = setup_dataset(&data, 10, 4096);
        let cfg = LiveConfig {
            initial_workers: 3,
            max_workers: 3,
            queue_tasks_per_worker: 10,
            policy: DispatchPolicy::GoodCacheCompute,
            cache: CacheConfig {
                capacity_bytes: 1 << 20,
                policy: EvictionPolicy::Lru,
            },
            persistent_dir: data,
            cache_root: root.join("caches"),
            compute: ComputeKind::Sleep(Duration::from_millis(1)),
            seed: 7,
        };
        let report = run(&cfg, &tasks).expect("live run");
        assert_eq!(report.completed, 30);
        assert_eq!(report.failed, 0);
        // 10 cold misses; the 20 re-accesses must hit some cache.
        assert!(report.misses >= 10, "misses {}", report.misses);
        assert!(
            report.hits_local + report.hits_global >= 15,
            "hits {} + {}",
            report.hits_local,
            report.hits_global
        );
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn first_available_never_caches() {
        let root = tmp("fa");
        let data = root.join("store");
        let tasks = setup_dataset(&data, 5, 1024);
        let cfg = LiveConfig {
            initial_workers: 2,
            max_workers: 2,
            queue_tasks_per_worker: 10,
            policy: DispatchPolicy::FirstAvailable,
            cache: CacheConfig {
                capacity_bytes: 1 << 20,
                policy: EvictionPolicy::Lru,
            },
            persistent_dir: data,
            cache_root: root.join("caches"),
            compute: ComputeKind::Sleep(Duration::from_millis(1)),
            seed: 7,
        };
        let report = run(&cfg, &tasks).expect("live run");
        assert_eq!(report.completed, 15);
        assert_eq!(report.misses, 15);
        assert_eq!(report.hits_local + report.hits_global, 0);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn provisioner_spawns_extra_workers() {
        let root = tmp("prov");
        let data = root.join("store");
        let tasks = setup_dataset(&data, 20, 512);
        let cfg = LiveConfig {
            initial_workers: 1,
            max_workers: 4,
            queue_tasks_per_worker: 5,
            policy: DispatchPolicy::GoodCacheCompute,
            cache: CacheConfig {
                capacity_bytes: 1 << 20,
                policy: EvictionPolicy::Lru,
            },
            persistent_dir: data,
            cache_root: root.join("caches"),
            compute: ComputeKind::Sleep(Duration::from_millis(2)),
            seed: 7,
        };
        let report = run(&cfg, &tasks).expect("live run");
        assert_eq!(report.completed, 60);
        assert!(report.peak_workers > 1, "never grew: {}", report.peak_workers);
        let _ = std::fs::remove_dir_all(&root);
    }
}
