//! Live execution engine: the coordinator driving *real* work.
//!
//! Where [`crate::sim`] substitutes the testbed, this engine drives the
//! **same** [`CoordinatorCore`] — wait queue, data-aware scheduler,
//! location index, per-executor caches, demand-driven provisioner — over
//! real worker threads that move real files and run real compute. The
//! module is a *driver*: it enacts the core's [`Effect`]s on the wall
//! clock and the filesystem and feeds worker outcomes back into the
//! core's event API; it contains no dispatch logic of its own
//! (`rust/tests/core_parity.rs` proves both drivers replay identical
//! decision sequences on a shared deterministic workload):
//!
//! * [`Effect::Notify`] → an immediate pickup round-trip (no dispatcher
//!   service model on a local testbed), delivered in FIFO order;
//! * [`Effect::Fetch`] → an assignment to the executor's worker thread:
//!   fetch from its own cache directory (local hit), a peer worker's
//!   cache directory (global hit, the GridFTP path), or the
//!   **persistent store** directory (miss) — exactly the three-way
//!   split of §5.2.1 — then run the compute;
//! * [`Effect::Compute`] → already performed by the worker alongside the
//!   fetch, so the driver feeds it straight back as `on_compute_done`;
//! * [`Effect::Allocate`] → spawn worker threads on demand (live DRP —
//!   no GRAM latency on a local testbed);
//! * [`Effect::Release`] → retire an idle worker: scrub it from the
//!   core, shut its thread down and delete its cache directory (the
//!   transient resource and the replicas it held are gone, as on a
//!   deallocated node). Enabled by `LiveConfig::idle_release_s > 0`;
//!   the core withholds executors still serving peer transfers, and a
//!   racing peer *copy* from a vanished directory falls back to the
//!   persistent store.
//!
//! Per-task compute is either a calibrated sleep or the AOT-compiled
//! **PJRT stacking pipeline** (`examples/astronomy_stacking.rs`), so the
//! full three-layer stack (Rust → HLO → Pallas kernel) is on the hot
//! path with Python nowhere in sight. Hit/miss tallies come from the
//! core's shared [`Recorder`] (workers report the access kind they
//! actually experienced — a peer copy can race the peer's eviction and
//! fall back to persistent storage, which the recorder then counts as
//! the miss it really was).

use crate::cache::CacheConfig;
use crate::coordinator::core::{CoordinatorCore, CoreConfig, Effect, FetchPlan, FileSizes};
use crate::coordinator::provisioner::{AllocationPolicy, ProvisionerConfig};
use crate::coordinator::queue::Task;
use crate::coordinator::scheduler::{DispatchPolicy, SchedulerConfig};
use crate::coordinator::AccessKind;
use crate::ids::{ExecutorId, FileId, TaskId};
use crate::metrics::Recorder;
use crate::util::prng::Pcg64;
use crate::util::time::Micros;
use crate::{Error, Result};
use std::collections::{HashMap, VecDeque};
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::thread;
use std::time::{Duration, Instant};

/// What a worker does after staging its input file.
#[derive(Debug, Clone)]
pub enum ComputeKind {
    /// Sleep for the given duration (micro-benchmark workloads).
    Sleep(Duration),
    /// Run the AOT stacking pipeline on the file's contents (the file
    /// must hold STACK-shaped f32 cutouts + weights; see
    /// [`crate::runtime::StackingExecutable`]). Each worker compiles its
    /// own executable (PJRT handles are not Sync).
    Stacking,
}

/// Live-engine configuration.
#[derive(Debug, Clone)]
pub struct LiveConfig {
    /// Workers to start with.
    pub initial_workers: usize,
    /// Maximum workers the provisioner may spawn.
    pub max_workers: usize,
    /// Queue length per worker that triggers growth (the provisioner's
    /// `queue_tasks_per_node`).
    pub queue_tasks_per_worker: usize,
    /// How aggressively the provisioner requests new workers — the same
    /// allocation policies as the simulated DRP, shared through the
    /// coordinator core (`one`/`add:N`/`mult:F`/`all`/`model`; under
    /// `model` the core runs the §3 performance model online and the
    /// provisioner tracks its solved worker target).
    pub allocation: AllocationPolicy,
    /// Dispatch policy.
    pub policy: DispatchPolicy,
    /// Per-worker cache configuration.
    pub cache: CacheConfig,
    /// Directory holding the dataset (the persistent store).
    pub persistent_dir: PathBuf,
    /// Root under which per-worker cache directories are created.
    pub cache_root: PathBuf,
    /// Per-task compute.
    pub compute: ComputeKind,
    /// PRNG seed (peer selection, eviction randomness).
    pub seed: u64,
    /// Seconds of idleness before the provisioner retires a worker
    /// mid-run ([`Effect::Release`] → thread shutdown + cache-dir
    /// removal). `0.0` disables mid-run retirement — the right choice
    /// for short benchmark runs, where the fleet should stay warm.
    pub idle_release_s: f64,
}

/// One task for the live engine: read `file`, compute.
#[derive(Debug, Clone)]
pub struct LiveTask {
    /// File name inside `persistent_dir`.
    pub file_name: String,
    /// Logical file id (for the scheduler/index).
    pub file: FileId,
}

/// Where the worker should fetch its input from.
#[derive(Debug, Clone)]
enum FetchSource {
    /// Already in the worker's own cache directory.
    Local,
    /// Copy from this peer cache directory.
    Peer(PathBuf),
    /// Copy from the persistent store.
    Persistent,
}

#[derive(Debug)]
struct Assignment {
    task_id: TaskId,
    file_name: String,
    source: FetchSource,
    /// Files the worker should delete from its cache dir (evictions
    /// decided by the coordinator-side cache model).
    evict: Vec<String>,
}

#[derive(Debug)]
enum WorkerMsg {
    Done {
        worker: usize,
        task_id: TaskId,
        kind: AccessKind,
        bytes: u64,
        fetch: Duration,
        compute: Duration,
    },
    Failed {
        worker: usize,
        task_id: TaskId,
        error: String,
    },
}

enum ToWorker {
    Run(Assignment),
    Shutdown,
}

struct WorkerHandle {
    tx: mpsc::Sender<ToWorker>,
    join: thread::JoinHandle<()>,
    cache_dir: PathBuf,
}

/// End-of-run report from the live engine.
#[derive(Debug)]
pub struct LiveReport {
    /// Tasks completed successfully.
    pub completed: u64,
    /// Tasks failed (worker errors; the replay policy retries once).
    pub failed: u64,
    /// Wall-clock makespan.
    pub makespan: Duration,
    /// Local cache hits (from the shared recorder).
    pub hits_local: u64,
    /// Peer-cache hits.
    pub hits_global: u64,
    /// Persistent-store misses.
    pub misses: u64,
    /// Total bytes fetched (all sources).
    pub bytes_moved: u64,
    /// Mean per-task fetch time.
    pub avg_fetch: Duration,
    /// Mean per-task compute time.
    pub avg_compute: Duration,
    /// Peak worker count (provisioning).
    pub peak_workers: usize,
    /// Workers retired mid-run by [`Effect::Release`] enactment.
    pub workers_released: u64,
    /// Tasks in dispatch order — the coordinator-core decision trace
    /// `core_parity` compares against the sim driver.
    pub dispatch_order: Vec<TaskId>,
    /// Per-second recorder (same instance the coordinator core filled —
    /// identical shape to the simulator's).
    pub recorder: Recorder,
}

/// The live driver: the coordinator core plus the worker fleet and the
/// FIFO notification queue the `Notify` effects drain through.
struct Driver<'a> {
    config: &'a LiveConfig,
    core: CoordinatorCore,
    workers: HashMap<ExecutorId, WorkerHandle>,
    /// Reserved-but-undelivered dispatch notifications, FIFO — the live
    /// stand-in for the sim's dispatcher service queue.
    notify_q: VecDeque<ExecutorId>,
    /// Assignments sent to workers and not yet answered.
    outstanding: usize,
    next_worker_idx: usize,
    peak_workers: usize,
    workers_released: u64,
    file_names: HashMap<FileId, String>,
    done_tx: mpsc::Sender<WorkerMsg>,
}

impl Driver<'_> {
    /// Spawn one worker thread and register it with the core; returns the
    /// registration effects (the fresh executor's `Notify`).
    fn spawn_worker(&mut self, now: Micros) -> Result<Vec<Effect>> {
        let (exec, effects) = self.core.register_node(now);
        self.attach_worker(exec)?;
        Ok(effects)
    }

    /// Create the cache directory and worker thread backing `exec`.
    fn attach_worker(&mut self, exec: ExecutorId) -> Result<()> {
        let idx = self.next_worker_idx;
        self.next_worker_idx += 1;
        let cache_dir = self.config.cache_root.join(format!("worker-{idx}"));
        std::fs::create_dir_all(&cache_dir)?;
        let (tx, rx) = mpsc::channel::<ToWorker>();
        let done = self.done_tx.clone();
        let persistent = self.config.persistent_dir.clone();
        let cdir = cache_dir.clone();
        let compute = self.config.compute.clone();
        let join = thread::Builder::new()
            .name(format!("dd-worker-{idx}"))
            .spawn(move || worker_main(idx, rx, done, persistent, cdir, compute))
            .map_err(Error::Io)?;
        self.workers.insert(
            exec,
            WorkerHandle {
                tx,
                join,
                cache_dir,
            },
        );
        self.peak_workers = self.peak_workers.max(self.workers.len());
        Ok(())
    }

    /// Enact a batch of coordinator effects on the worker fleet. FIFO so
    /// notification delivery order stays deterministic.
    fn apply(&mut self, effects: Vec<Effect>, now: Micros) -> Result<()> {
        let mut queue: VecDeque<Effect> = effects.into();
        while let Some(effect) = queue.pop_front() {
            match effect {
                Effect::Notify(e) => self.notify_q.push_back(e),
                Effect::Fetch(plan) => self.send_assignment(plan)?,
                Effect::Compute { task_id, .. } => {
                    // The worker already ran the compute alongside the
                    // fetch: close the loop immediately.
                    let mut effs = self.core.on_compute_done(task_id, now, now);
                    queue.extend(effs.drain(..));
                    self.core.recycle_effects(effs);
                }
                Effect::Allocate(n) => {
                    for _ in 0..n {
                        let mut effs = self.spawn_worker_registered(now)?;
                        queue.extend(effs.drain(..));
                        self.core.recycle_effects(effs);
                    }
                }
                Effect::Release(execs) => {
                    for e in execs {
                        self.release_worker(e);
                    }
                }
            }
        }
        Ok(())
    }

    /// An [`Effect::Allocate`] node comes up instantly on a local
    /// testbed: drain the provisioner's pending count and spawn.
    fn spawn_worker_registered(&mut self, now: Micros) -> Result<Vec<Effect>> {
        let (exec, effects) = self.core.on_node_registered(now);
        self.attach_worker(exec)?;
        Ok(effects)
    }

    /// Enact one [`Effect::Release`]: scrub the executor from the core,
    /// shut its worker thread down and delete its cache directory — the
    /// transient resource, and every replica it held, are gone, exactly
    /// like a deallocated node in the sim. The core only names idle
    /// executors with no pending reservation and no in-flight peer
    /// transfer, so no undelivered work targets this worker; a racing
    /// peer *copy* from the vanished directory falls back to the
    /// persistent store in `run_one` and is recorded as the miss it was.
    fn release_worker(&mut self, exec: ExecutorId) {
        self.core.release_node(exec);
        if let Some(h) = self.workers.remove(&exec) {
            let _ = h.tx.send(ToWorker::Shutdown);
            let _ = h.join.join();
            let _ = std::fs::remove_dir_all(&h.cache_dir);
            self.workers_released += 1;
            crate::debug!("released idle worker {exec}");
        }
        // Belt and braces: reserved executors are never named in a
        // release, so this should find nothing.
        self.notify_q.retain(|&e| e != exec);
    }

    /// Map a resolved fetch plan onto a worker assignment.
    fn send_assignment(&mut self, plan: FetchPlan) -> Result<()> {
        let file_name = self
            .file_names
            .get(&plan.file)
            .cloned()
            .ok_or_else(|| Error::Runtime(format!("no file name for {}", plan.file)))?;
        let source = match (plan.kind, plan.peer) {
            (AccessKind::HitLocal, _) => FetchSource::Local,
            (AccessKind::HitGlobal, Some(p)) => {
                FetchSource::Peer(self.workers[&p].cache_dir.clone())
            }
            _ => FetchSource::Persistent,
        };
        let evict: Vec<String> = plan
            .evicted
            .iter()
            .filter_map(|f| self.file_names.get(f).cloned())
            .collect();
        self.workers[&plan.exec]
            .tx
            .send(ToWorker::Run(Assignment {
                task_id: plan.task_id,
                file_name,
                source,
                evict,
            }))
            .expect("worker channel closed");
        self.outstanding += 1;
        Ok(())
    }

    /// Deliver queued notifications and keep the cluster busy: the live
    /// analogue of the sim's dispatcher drain plus tick safety net.
    fn pump(&mut self, now: Micros) -> Result<()> {
        loop {
            while let Some(e) = self.notify_q.pop_front() {
                let effects = self.core.on_pickup(e, now);
                self.apply(effects, now)?;
            }
            // Safety net: tasks wait, workers are free, nothing is in
            // flight — force progress (max-cache-hit can decline).
            if self.outstanding > 0 || self.core.queue_is_empty() || self.core.free_count() == 0 {
                break;
            }
            let queue_before = self.core.queue_len();
            let effects = self.core.kick();
            if effects.is_empty() {
                break;
            }
            self.apply(effects, now)?;
            while let Some(e) = self.notify_q.pop_front() {
                let effects = self.core.on_pickup(e, now);
                self.apply(effects, now)?;
            }
            if self.outstanding == 0 && self.core.queue_len() == queue_before {
                break; // the forced pickup declined too; wait for events
            }
        }
        Ok(())
    }
}

/// Run `tasks` through the live engine.
pub fn run(config: &LiveConfig, tasks: &[LiveTask]) -> Result<LiveReport> {
    if tasks.is_empty() {
        return Err(Error::config("live run needs at least one task"));
    }
    std::fs::create_dir_all(&config.cache_root)?;
    let t0 = Instant::now();
    let (done_tx, done_rx) = mpsc::channel::<WorkerMsg>();

    // File sizes from the persistent store (needed for cache accounting).
    let mut file_sizes: HashMap<FileId, u64> = HashMap::new();
    let mut file_names: HashMap<FileId, String> = HashMap::new();
    for t in tasks {
        if let std::collections::hash_map::Entry::Vacant(e) = file_sizes.entry(t.file) {
            let meta = std::fs::metadata(config.persistent_dir.join(&t.file_name))?;
            e.insert(meta.len());
            file_names.insert(t.file, t.file_name.clone());
        }
    }

    let max_workers = config.max_workers.max(config.initial_workers).max(1);
    let core = CoordinatorCore::new(
        CoreConfig {
            scheduler: SchedulerConfig {
                policy: config.policy,
                ..SchedulerConfig::default()
            },
            provisioner: ProvisionerConfig {
                allocation: config.allocation,
                idle_release_s: config.idle_release_s,
                static_provisioning: false,
                initial_nodes: config.initial_workers.max(1),
                queue_tasks_per_node: config.queue_tasks_per_worker.max(1) as u64,
            },
            cache: config.cache,
            max_nodes: max_workers,
            slots_per_node: 1,
            file_sizes: FileSizes::per_file(file_sizes),
        },
        Pcg64::seeded(config.seed),
    );
    let mut drv = Driver {
        config,
        core,
        workers: HashMap::new(),
        notify_q: VecDeque::new(),
        outstanding: 0,
        next_worker_idx: 0,
        peak_workers: 0,
        workers_released: 0,
        file_names,
        done_tx,
    };

    // Initial fleet, then batch submission (like the §5.1 microbench):
    // the fresh workers' notifications queue up and deliver after the
    // whole queue is populated — matching the sim driver, where arrivals
    // outrun the dispatcher's service latency.
    for _ in 0..config.initial_workers.max(1) {
        let now = now_micros(t0);
        let effects = drv.spawn_worker(now)?;
        drv.apply(effects, now)?;
    }
    for (i, t) in tasks.iter().enumerate() {
        let now = now_micros(t0);
        let task = Task {
            id: TaskId(i as u64),
            files: vec![t.file],
            compute: Micros::ZERO,
            arrival: Micros::ZERO,
        };
        let effects = drv.core.on_arrival(task, 0, 0.0, now);
        drv.apply(effects, now)?;
    }
    drv.pump(now_micros(t0))?;

    let mut retried: HashMap<u64, bool> = HashMap::new();
    let mut completed = 0u64;
    let mut failed = 0u64;
    let mut bytes_moved = 0u64;
    let mut fetch_total = Duration::ZERO;
    let mut compute_total = Duration::ZERO;

    // Main loop: completions drive re-dispatch through the core; the
    // shared provisioner grows the fleet while the queue stays long.
    while completed + failed < tasks.len() as u64 {
        let now = now_micros(t0);
        // Sample + provisioning decision (the sim's 1 Hz tick, run per
        // completion here).
        let effects = drv.core.on_tick(now);
        drv.apply(effects, now)?;
        drv.pump(now)?;

        let msg = done_rx
            .recv_timeout(Duration::from_secs(60))
            .map_err(|_| Error::Runtime("live engine stalled for 60s".into()))?;
        let now = now_micros(t0);
        match msg {
            WorkerMsg::Done {
                worker,
                task_id,
                kind,
                bytes,
                fetch,
                compute,
            } => {
                crate::debug!("worker {worker}: task {task_id} done ({kind:?}, {bytes} B)");
                drv.outstanding -= 1;
                bytes_moved += bytes;
                fetch_total += fetch;
                compute_total += compute;
                // Report what the worker actually experienced (a peer
                // copy may have fallen back to the persistent store).
                let effects = drv.core.on_fetch_done(task_id, now, Some((kind, bytes)));
                drv.apply(effects, now)?;
                completed += 1;
            }
            WorkerMsg::Failed {
                worker,
                task_id,
                error,
            } => {
                drv.outstanding -= 1;
                // Frees the slot and — when a backlog remains — re-notifies
                // the freed worker, so a permanently-failed task cannot
                // idle its executor for the rest of the run.
                let effects = drv.core.on_task_failed(task_id, now);
                drv.apply(effects, now)?;
                // Replay policy (§4.2): re-dispatch once, then count as
                // failed.
                if !retried.get(&task_id.0).copied().unwrap_or(false) {
                    retried.insert(task_id.0, true);
                    let t = &tasks[task_id.0 as usize];
                    let task = Task {
                        id: task_id,
                        files: vec![t.file],
                        compute: Micros::ZERO,
                        arrival: now,
                    };
                    let effects = drv.core.on_arrival(task, 0, 0.0, now);
                    drv.apply(effects, now)?;
                    crate::warn!("task {task_id} failed on worker {worker} ({error}); replaying");
                } else {
                    failed += 1;
                    crate::error!("task {task_id} failed twice (worker {worker}): {error}");
                }
            }
        }
        drv.pump(now)?;
    }

    // Shut down workers.
    for (_, h) in drv.workers.drain() {
        let _ = h.tx.send(ToWorker::Shutdown);
        let _ = h.join.join();
    }

    let (hits_local, hits_global, misses) = drv.core.rec.access_counts();
    let recorder = std::mem::take(&mut drv.core.rec);
    let done_tasks = completed.max(1);
    Ok(LiveReport {
        completed,
        failed,
        makespan: t0.elapsed(),
        hits_local,
        hits_global,
        misses,
        bytes_moved,
        avg_fetch: fetch_total / done_tasks as u32,
        avg_compute: compute_total / done_tasks as u32,
        peak_workers: drv.peak_workers,
        workers_released: drv.workers_released,
        dispatch_order: drv.core.take_dispatch_log(),
        recorder,
    })
}

fn now_micros(t0: Instant) -> Micros {
    Micros(t0.elapsed().as_micros() as u64)
}

/// Worker thread: fetch the file per the coordinator's instruction, run
/// the compute, report back.
fn worker_main(
    idx: usize,
    rx: mpsc::Receiver<ToWorker>,
    done: mpsc::Sender<WorkerMsg>,
    persistent: PathBuf,
    cache_dir: PathBuf,
    compute: ComputeKind,
) {
    // PJRT handles are not Sync: each worker compiles its own pipeline.
    let stacker = match &compute {
        ComputeKind::Stacking => match crate::runtime::Artifacts::open_default()
            .and_then(|a| a.stacking())
        {
            Ok(s) => Some(s),
            Err(e) => {
                crate::error!("worker {idx}: cannot load stacking artifact: {e}");
                None
            }
        },
        ComputeKind::Sleep(_) => None,
    };
    while let Ok(ToWorker::Run(a)) = rx.recv() {
        let result = run_one(&a, &persistent, &cache_dir, &compute, stacker.as_ref());
        let msg = match result {
            Ok((kind, bytes, fetch, comp)) => WorkerMsg::Done {
                worker: idx,
                task_id: a.task_id,
                kind,
                bytes,
                fetch,
                compute: comp,
            },
            Err(e) => WorkerMsg::Failed {
                worker: idx,
                task_id: a.task_id,
                error: e.to_string(),
            },
        };
        if done.send(msg).is_err() {
            return; // coordinator gone
        }
    }
}

fn run_one(
    a: &Assignment,
    persistent: &Path,
    cache_dir: &Path,
    compute: &ComputeKind,
    stacker: Option<&crate::runtime::StackingExecutable>,
) -> Result<(AccessKind, u64, Duration, Duration)> {
    for name in &a.evict {
        let _ = std::fs::remove_file(cache_dir.join(name));
    }
    let local_path = cache_dir.join(&a.file_name);
    let tf = Instant::now();
    let (kind, bytes) = match &a.source {
        FetchSource::Local => {
            let meta = std::fs::metadata(&local_path)?;
            (AccessKind::HitLocal, meta.len())
        }
        FetchSource::Peer(peer_dir) => {
            // The peer may not have finished writing the object yet (the
            // coordinator's index is updated at dispatch time); fall back
            // to persistent storage like a real executor would (§3.1:
            // "only if no cached copy is available does the executor
            // request a copy from persistent storage").
            match std::fs::copy(peer_dir.join(&a.file_name), &local_path) {
                Ok(n) => (AccessKind::HitGlobal, n),
                Err(_) => {
                    let n = std::fs::copy(persistent.join(&a.file_name), &local_path)?;
                    (AccessKind::Miss, n)
                }
            }
        }
        FetchSource::Persistent => {
            let n = std::fs::copy(persistent.join(&a.file_name), &local_path)?;
            (AccessKind::Miss, n)
        }
    };
    let fetch = tf.elapsed();

    let tc = Instant::now();
    match compute {
        ComputeKind::Sleep(d) => thread::sleep(*d),
        ComputeKind::Stacking => {
            let stacker = stacker
                .ok_or_else(|| Error::Runtime("stacking executable unavailable".into()))?;
            let data = std::fs::read(&local_path)?;
            let floats: Vec<f32> = data
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect();
            use crate::runtime::shapes::{STACK_H, STACK_W};
            let frame = STACK_H * STACK_W;
            if floats.len() < frame + 1 {
                return Err(Error::Runtime(format!(
                    "file {} too small for stacking ({} floats)",
                    a.file_name,
                    floats.len()
                )));
            }
            // Layout: n full frames followed by n weights.
            let n = floats.len() / (frame + 1);
            let (cutouts, weights) = floats.split_at(n * frame);
            let res = stacker.stack(cutouts, &weights[..n])?;
            // Consume the result so the work cannot be elided.
            if !res.mean.is_finite() {
                return Err(Error::Runtime("non-finite stacking output".into()));
            }
        }
    }
    let comp = tc.elapsed();
    Ok((kind, bytes, fetch, comp))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::EvictionPolicy;

    fn setup_dataset(dir: &Path, files: usize, bytes: usize) -> Vec<LiveTask> {
        std::fs::create_dir_all(dir).unwrap();
        let mut tasks = Vec::new();
        for i in 0..files {
            let name = format!("f{i}.bin");
            std::fs::write(dir.join(&name), vec![i as u8; bytes]).unwrap();
            // 3 accesses per file.
            for _ in 0..3 {
                tasks.push(LiveTask {
                    file_name: name.clone(),
                    file: FileId(i as u32),
                });
            }
        }
        tasks
    }

    fn tmp(name: &str) -> PathBuf {
        let p = std::env::temp_dir().join(format!("dd-live-{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&p);
        p
    }

    #[test]
    fn live_run_completes_and_hits_cache() {
        let root = tmp("basic");
        let data = root.join("store");
        let tasks = setup_dataset(&data, 10, 4096);
        let cfg = LiveConfig {
            initial_workers: 3,
            max_workers: 3,
            queue_tasks_per_worker: 10,
            allocation: AllocationPolicy::OneAtATime,
            policy: DispatchPolicy::GoodCacheCompute,
            cache: CacheConfig {
                capacity_bytes: 1 << 20,
                policy: EvictionPolicy::Lru,
            },
            persistent_dir: data,
            cache_root: root.join("caches"),
            compute: ComputeKind::Sleep(Duration::from_millis(1)),
            seed: 7,
            idle_release_s: 0.0,
        };
        let report = run(&cfg, &tasks).expect("live run");
        assert_eq!(report.completed, 30);
        assert_eq!(report.failed, 0);
        // 10 cold misses; the 20 re-accesses must hit some cache.
        assert!(report.misses >= 10, "misses {}", report.misses);
        assert!(
            report.hits_local + report.hits_global >= 15,
            "hits {} + {}",
            report.hits_local,
            report.hits_global
        );
        // The report's tallies are the shared recorder's tallies.
        assert_eq!(
            report.recorder.access_counts(),
            (report.hits_local, report.hits_global, report.misses)
        );
        assert_eq!(report.dispatch_order.len(), 30);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn model_allocation_runs_live() {
        let root = tmp("model");
        let data = root.join("store");
        let tasks = setup_dataset(&data, 10, 4096);
        let cfg = LiveConfig {
            initial_workers: 1,
            max_workers: 3,
            queue_tasks_per_worker: 10,
            allocation: AllocationPolicy::Model,
            policy: DispatchPolicy::GoodCacheCompute,
            cache: CacheConfig {
                capacity_bytes: 1 << 20,
                policy: EvictionPolicy::Lru,
            },
            persistent_dir: data,
            cache_root: root.join("caches"),
            compute: ComputeKind::Sleep(Duration::from_millis(1)),
            seed: 7,
            idle_release_s: 0.0,
        };
        let report = run(&cfg, &tasks).expect("live run under --allocation model");
        assert_eq!(report.completed, 30);
        assert_eq!(report.failed, 0);
        assert_eq!(report.dispatch_order.len(), 30);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn first_available_never_caches() {
        let root = tmp("fa");
        let data = root.join("store");
        let tasks = setup_dataset(&data, 5, 1024);
        let cfg = LiveConfig {
            initial_workers: 2,
            max_workers: 2,
            queue_tasks_per_worker: 10,
            allocation: AllocationPolicy::OneAtATime,
            policy: DispatchPolicy::FirstAvailable,
            cache: CacheConfig {
                capacity_bytes: 1 << 20,
                policy: EvictionPolicy::Lru,
            },
            persistent_dir: data,
            cache_root: root.join("caches"),
            compute: ComputeKind::Sleep(Duration::from_millis(1)),
            seed: 7,
            idle_release_s: 0.0,
        };
        let report = run(&cfg, &tasks).expect("live run");
        assert_eq!(report.completed, 15);
        assert_eq!(report.misses, 15);
        assert_eq!(report.hits_local + report.hits_global, 0);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn release_effect_retires_worker_and_scrubs_cache_dir() {
        // Drive the Driver directly with core-time stamps so the test
        // is deterministic: two idle workers, a tick far in the future,
        // and the resulting Release must shut threads down, delete
        // cache directories and scrub the core.
        let root = tmp("release");
        let data = root.join("store");
        let _tasks = setup_dataset(&data, 2, 512);
        let cfg = LiveConfig {
            initial_workers: 2,
            max_workers: 2,
            queue_tasks_per_worker: 10,
            allocation: AllocationPolicy::OneAtATime,
            policy: DispatchPolicy::GoodCacheCompute,
            cache: CacheConfig {
                capacity_bytes: 1 << 20,
                policy: EvictionPolicy::Lru,
            },
            persistent_dir: data,
            cache_root: root.join("caches"),
            compute: ComputeKind::Sleep(Duration::from_millis(1)),
            seed: 7,
            idle_release_s: 0.5,
        };
        std::fs::create_dir_all(&cfg.cache_root).unwrap();
        let (done_tx, _done_rx) = mpsc::channel::<WorkerMsg>();
        let core = CoordinatorCore::new(
            CoreConfig {
                scheduler: SchedulerConfig {
                    policy: cfg.policy,
                    ..SchedulerConfig::default()
                },
                provisioner: ProvisionerConfig {
                    allocation: cfg.allocation,
                    idle_release_s: cfg.idle_release_s,
                    static_provisioning: false,
                    initial_nodes: 2,
                    queue_tasks_per_node: 10,
                },
                cache: cfg.cache,
                max_nodes: 2,
                slots_per_node: 1,
                file_sizes: FileSizes::Uniform(512),
            },
            Pcg64::seeded(cfg.seed),
        );
        let mut drv = Driver {
            config: &cfg,
            core,
            workers: HashMap::new(),
            notify_q: VecDeque::new(),
            outstanding: 0,
            next_worker_idx: 0,
            peak_workers: 0,
            workers_released: 0,
            file_names: HashMap::new(),
            done_tx,
        };
        drv.spawn_worker(Micros::ZERO).unwrap();
        drv.spawn_worker(Micros::ZERO).unwrap();
        assert_eq!(drv.workers.len(), 2);
        let dirs: Vec<PathBuf> = drv.workers.values().map(|h| h.cache_dir.clone()).collect();
        assert!(dirs.iter().all(|d| d.exists()));

        // Ten idle seconds later the provisioner must want them gone.
        let now = Micros::from_secs(10);
        let effects = drv.core.on_tick(now);
        assert!(
            effects
                .iter()
                .any(|e| matches!(e, Effect::Release(v) if !v.is_empty())),
            "expected a release of idle workers, got {effects:?}"
        );
        drv.apply(effects, now).unwrap();
        assert!(drv.workers_released >= 1, "no worker was retired");
        assert_eq!(drv.workers.len(), 2 - drv.workers_released as usize);
        // Retired workers' cache directories are gone; survivors' remain.
        let gone = dirs.iter().filter(|d| !d.exists()).count();
        assert_eq!(gone as u64, drv.workers_released);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn provisioner_spawns_extra_workers() {
        let root = tmp("prov");
        let data = root.join("store");
        let tasks = setup_dataset(&data, 20, 512);
        let cfg = LiveConfig {
            initial_workers: 1,
            max_workers: 4,
            queue_tasks_per_worker: 5,
            allocation: AllocationPolicy::Multiplicative(2.0),
            policy: DispatchPolicy::GoodCacheCompute,
            cache: CacheConfig {
                capacity_bytes: 1 << 20,
                policy: EvictionPolicy::Lru,
            },
            persistent_dir: data,
            cache_root: root.join("caches"),
            compute: ComputeKind::Sleep(Duration::from_millis(2)),
            seed: 7,
            idle_release_s: 0.0,
        };
        let report = run(&cfg, &tasks).expect("live run");
        assert_eq!(report.completed, 60);
        assert!(report.peak_workers > 1, "never grew: {}", report.peak_workers);
        let _ = std::fs::remove_dir_all(&root);
    }
}
