//! The abstract model of data-centric task farms (§4).
//!
//! Implements the paper's definitions verbatim:
//!
//! * average task execution time `B = (1/|K|) Σ μ(κ)`;
//! * computational intensity `I = B · A`;
//! * workload execution time `V = max(B/|T|, 1/A) · |K|`;
//! * overhead-inclusive average `Y = avg(μ + o [+ ζ(δ,τ)])`;
//! * overhead-inclusive execution time `W = max(Y/|T|, 1/A) · |K|`;
//! * efficiency `E = V/W`, speedup `S = E · |T|`;
//! * copy time `ζ(δ,τ) = β(δ) / min(η(ν_src,ω_src), η(ν_dst,ω_dst))` with
//!   available bandwidth `η(ν,ω) = ν/ω` for load ω ≥ 1.
//!
//! The store load ω is not observable before a run, so the evaluator
//! closes the loop with a small fixed-point iteration: the expected
//! number of concurrent readers of a store follows from the fraction of
//! task time spent copying, which depends on ζ, which depends on ω. The
//! paper notes its model captures contention "only simplistically" and
//! attributes its 5–8 % error to exactly this — our validation harness
//! (Figure 2 bench) measures the same gap against the simulator.
//!
//! The same arithmetic is exported two ways: pure Rust ([`predict`])
//! for fast sweeps, and — to exercise the AOT path end to end — a
//! batched evaluator compiled from JAX/Pallas and executed via PJRT
//! (see `crate::runtime`); a test asserts both agree.

use crate::config::{AccessSpec, ArrivalSpec, ExperimentConfig};

/// Inputs to the abstract model, extracted from an [`ExperimentConfig`].
#[derive(Debug, Clone, Copy)]
pub struct ModelInputs {
    /// Tasks |K|.
    pub num_tasks: f64,
    /// Transient compute resources |T| (CPU slots).
    pub cpus: f64,
    /// Mean task compute time μ (s).
    pub mu_s: f64,
    /// Dispatch + result-delivery overhead o (s).
    pub overhead_s: f64,
    /// Data object size β (bytes).
    pub object_bytes: f64,
    /// Mean task arrival rate A (tasks/s); `f64::INFINITY` for batch.
    pub arrival_rate: f64,
    /// Persistent-store ideal bandwidth ν(π) (bytes/s).
    pub persistent_bps: f64,
    /// Transient-store (local disk) ideal bandwidth ν(τ) (bytes/s).
    pub transient_bps: f64,
    /// Probability a task's object misses every cache (→ copy from π).
    pub p_miss: f64,
    /// Probability a task's object is cached locally (no copy at all).
    pub p_local: f64,
}

/// Model outputs (§4.3's quantities).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ModelPrediction {
    /// Average task execution time B (s).
    pub b: f64,
    /// Computational intensity I = B·A.
    pub intensity: f64,
    /// Ideal workload execution time V (s).
    pub v: f64,
    /// Overhead-inclusive average task time Y (s).
    pub y: f64,
    /// Overhead-inclusive workload execution time W (s).
    pub w: f64,
    /// Efficiency E = V/W ∈ (0, 1].
    pub efficiency: f64,
    /// Speedup S = E·|T|.
    pub speedup: f64,
    /// Converged persistent-store load ω(π) (concurrent readers).
    pub omega_pi: f64,
    /// Copy time from the persistent store ζ (s) at that load.
    pub zeta_s: f64,
}

impl ModelInputs {
    /// Derive model inputs from an experiment configuration.
    ///
    /// The miss/local-hit split is the model user's estimate; the default
    /// derivation assumes steady-state diffusion with caches large enough
    /// for the working set: every distinct file misses once, all repeat
    /// accesses hit locally (the paper's locality workloads). If the
    /// aggregate cache cannot hold the working set, the resident fraction
    /// scales the hit probability (LRU under uniform access).
    pub fn from_config(cfg: &ExperimentConfig) -> ModelInputs {
        let w = &cfg.workload;
        let accesses_per_file = match w.access {
            AccessSpec::Locality(l) => l.max(1.0),
            // Uniform: expected accesses per distinct file.
            AccessSpec::Uniform | AccessSpec::Zipf(_) => {
                w.num_tasks as f64 / w.num_files as f64
            }
        };
        let working_set = match w.access {
            AccessSpec::Locality(l) => {
                (w.num_tasks as f64 / l.max(1.0)).ceil() * w.file_size_bytes as f64
            }
            _ => w.num_files as f64 * w.file_size_bytes as f64,
        };
        let nodes = cfg.cluster.max_nodes as f64;
        let aggregate_cache = if cfg.scheduler.policy.uses_caching() {
            nodes * cfg.cache.capacity_bytes as f64
        } else {
            0.0
        };
        let resident = if working_set > 0.0 {
            (aggregate_cache / working_set).min(1.0)
        } else {
            0.0
        };
        // Cold miss once per file, then hits at the resident fraction.
        let p_first = 1.0 / accesses_per_file.max(1.0);
        let p_miss = (p_first + (1.0 - p_first) * (1.0 - resident)).clamp(0.0, 1.0);
        let arrival_rate = match w.arrival {
            ArrivalSpec::Batch => f64::INFINITY,
            ArrivalSpec::Constant(r) => r,
            ArrivalSpec::IncreasingRate { .. } => {
                // Mean rate over the run = |K| / span.
                let span = crate::workload::ideal_execution_time_s(w);
                if span > 0.0 {
                    w.num_tasks as f64 / span
                } else {
                    f64::INFINITY
                }
            }
        };
        ModelInputs {
            num_tasks: w.num_tasks as f64,
            cpus: nodes * cfg.cluster.cpus_per_node as f64,
            mu_s: w.compute_ms / 1e3,
            overhead_s: cfg.cluster.dispatch_service_us / 1e6
                + 2.0 * cfg.cluster.net_latency_ms / 1e3,
            object_bytes: w.file_size_bytes as f64,
            arrival_rate,
            persistent_bps: crate::util::units::gbps_to_bps(cfg.cluster.gpfs_gbps),
            transient_bps: crate::util::units::gbps_to_bps(cfg.cluster.local_disk_gbps),
            p_miss,
            p_local: 1.0 - p_miss,
        }
    }
}

/// Evaluate the model (fixed-point on store load, ≤32 iterations).
pub fn predict(inp: &ModelInputs) -> ModelPrediction {
    assert!(inp.cpus >= 1.0, "need at least one CPU");
    let b = inp.mu_s;
    let intensity = if inp.arrival_rate.is_finite() {
        b * inp.arrival_rate
    } else {
        f64::INFINITY
    };
    let inv_a = if inp.arrival_rate.is_finite() && inp.arrival_rate > 0.0 {
        1.0 / inp.arrival_rate
    } else {
        0.0
    };
    let v = (b / inp.cpus).max(inv_a) * inp.num_tasks;

    // Local reads: the object streams from the local disk (the paper
    // folds local-read I/O into the task's effective service time).
    let local_read_s = inp.object_bytes / inp.transient_bps;

    // Fixed point: ω(π) → ζ → time share copying → ω(π).
    let mut omega: f64 = 1.0;
    let mut zeta = inp.object_bytes / inp.persistent_bps;
    for _ in 0..32 {
        let eta = inp.persistent_bps / omega.max(1.0);
        zeta = inp.object_bytes / eta;
        let y = inp.mu_s + inp.overhead_s + inp.p_local * local_read_s + inp.p_miss * zeta;
        // Expected concurrent persistent-store readers: each CPU spends
        // p_miss·ζ/Y of its busy time copying from π; the number of busy
        // CPUs is capped by the arrival rate.
        let busy_cpus = if inp.arrival_rate.is_finite() {
            (inp.arrival_rate * y).min(inp.cpus)
        } else {
            inp.cpus
        };
        let new_omega = (busy_cpus * inp.p_miss * zeta / y).max(1.0);
        if (new_omega - omega).abs() < 1e-9 {
            omega = new_omega;
            break;
        }
        omega = new_omega;
    }
    let y = inp.mu_s + inp.overhead_s + inp.p_local * local_read_s + inp.p_miss * zeta;
    let w = (y / inp.cpus).max(inv_a) * inp.num_tasks;
    let efficiency = if w > 0.0 { (v / w).min(1.0) } else { 1.0 };
    ModelPrediction {
        b,
        intensity,
        v,
        y,
        w,
        efficiency,
        speedup: efficiency * inp.cpus,
        omega_pi: omega,
        zeta_s: zeta,
    }
}

/// Relative model error vs a measured workload execution time
/// (|W_model − WET_measured| / WET_measured) — the Figure 2 statistic.
pub fn relative_error(prediction: &ModelPrediction, measured_wet_s: f64) -> f64 {
    if measured_wet_s <= 0.0 {
        return f64::NAN;
    }
    (prediction.w - measured_wet_s).abs() / measured_wet_s
}

/// The E > 0.5 sufficient condition of §4.3:
/// μ(κ) > o(κ) + ζ(δ,τ) ⇒ efficiency above one half.
pub fn efficiency_condition_holds(inp: &ModelInputs) -> bool {
    let p = predict(inp);
    inp.mu_s > inp.overhead_s + p.zeta_s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::{gbps_to_bps, MB};

    fn base_inputs() -> ModelInputs {
        ModelInputs {
            num_tasks: 10_000.0,
            cpus: 128.0,
            mu_s: 0.01,
            overhead_s: 0.005,
            object_bytes: (10 * MB) as f64,
            arrival_rate: f64::INFINITY,
            persistent_bps: gbps_to_bps(4.0),
            transient_bps: gbps_to_bps(1.6),
            p_miss: 0.04,
            p_local: 0.96,
        }
    }

    #[test]
    fn v_is_ideal_time() {
        let inp = base_inputs();
        let p = predict(&inp);
        // Batch arrival: V = B/|T| · |K|.
        assert!((p.v - 0.01 / 128.0 * 10_000.0).abs() < 1e-12);
        assert!(p.w >= p.v, "overheads cannot make it faster");
        assert!(p.efficiency <= 1.0 && p.efficiency > 0.0);
        assert!((p.speedup - p.efficiency * 128.0).abs() < 1e-9);
    }

    #[test]
    fn arrival_rate_bounds_v() {
        let mut inp = base_inputs();
        inp.arrival_rate = 10.0; // slow arrivals dominate: V = |K|/A
        let p = predict(&inp);
        assert!((p.v - 10_000.0 / 10.0).abs() < 1e-9);
        assert!((p.intensity - 0.1).abs() < 1e-12);
    }

    #[test]
    fn misses_hurt_efficiency_monotonically() {
        let mut last = f64::INFINITY;
        for p_miss in [0.0, 0.1, 0.3, 0.7, 1.0] {
            let mut inp = base_inputs();
            inp.p_miss = p_miss;
            inp.p_local = 1.0 - p_miss;
            let e = predict(&inp).efficiency;
            assert!(e <= last + 1e-12, "p_miss={p_miss}: {e} > {last}");
            last = e;
        }
    }

    #[test]
    fn contention_fixed_point_converges_and_loads_store() {
        let mut inp = base_inputs();
        inp.p_miss = 1.0;
        inp.p_local = 0.0;
        let p = predict(&inp);
        // All 128 CPUs copying 10 MB objects from a 4 Gb/s store: load
        // must be far above 1 and ζ far above the unloaded 20 ms.
        assert!(p.omega_pi > 10.0, "ω={}", p.omega_pi);
        assert!(p.zeta_s > 0.1, "ζ={}", p.zeta_s);
        // Efficiency collapses — data-intensive without caching.
        assert!(p.efficiency < 0.2, "E={}", p.efficiency);
    }

    #[test]
    fn efficiency_condition_matches_definition() {
        let mut inp = base_inputs();
        inp.mu_s = 10.0; // compute-heavy: condition holds
        assert!(efficiency_condition_holds(&inp));
        let p = predict(&inp);
        assert!(p.efficiency > 0.5);

        inp.mu_s = 0.001; // data-heavy with misses: condition fails
        inp.p_miss = 1.0;
        inp.p_local = 0.0;
        assert!(!efficiency_condition_holds(&inp));
    }

    #[test]
    fn from_config_derives_miss_rates() {
        // first-available: no caching → p_miss = 1.
        let cfg = ExperimentConfig::paper_fig(4).unwrap();
        let inp = ModelInputs::from_config(&cfg);
        assert!((inp.p_miss - 1.0).abs() < 1e-9);

        // fig 8 (4 GB caches, 100 GB working set over 64 nodes): caches
        // hold everything → only cold misses remain (1/25 accesses).
        let cfg = ExperimentConfig::paper_fig(8).unwrap();
        let inp = ModelInputs::from_config(&cfg);
        assert!((inp.p_miss - 0.04).abs() < 0.001, "p_miss={}", inp.p_miss);

        // fig 5 (1 GB caches): 64 GB of 100 GB resident.
        let cfg = ExperimentConfig::paper_fig(5).unwrap();
        let inp = ModelInputs::from_config(&cfg);
        assert!(inp.p_miss > 0.3 && inp.p_miss < 0.5, "p_miss={}", inp.p_miss);
    }

    #[test]
    fn relative_error_math() {
        let p = predict(&base_inputs());
        assert!((relative_error(&p, p.w) - 0.0).abs() < 1e-12);
        assert!((relative_error(&p, p.w * 2.0) - 0.5).abs() < 1e-12);
    }
}
