//! PJRT runtime bridge — loads the AOT artifacts built by
//! `make artifacts` and executes them from the Rust request path.
//!
//! Pipeline (see /opt/xla-example/load_hlo and DESIGN.md §2):
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `client.compile` → `execute`.
//! Python never runs at request time; if `artifacts/` is missing the
//! loaders return [`crate::Error::Runtime`] telling the user to run
//! `make artifacts`.

use crate::model::{ModelInputs, ModelPrediction};
use crate::{Error, Result};
use std::path::{Path, PathBuf};

/// Fixed shapes baked into the artifacts (must match python/compile/aot.py).
pub mod shapes {
    /// Cutouts per stacking request.
    pub const STACK_N: usize = 128;
    /// Cutout height.
    pub const STACK_H: usize = 64;
    /// Cutout width.
    pub const STACK_W: usize = 64;
    /// Model-evaluator batch size.
    pub const MODEL_BATCH: usize = 64;
}

/// A directory of AOT artifacts plus a shared PJRT CPU client.
pub struct Artifacts {
    client: xla::PjRtClient,
    dir: PathBuf,
}

impl Artifacts {
    /// Open the artifacts directory (default `artifacts/`); creates the
    /// PJRT CPU client eagerly so failures surface early.
    pub fn open(dir: impl AsRef<Path>) -> Result<Artifacts> {
        let dir = dir.as_ref().to_path_buf();
        if !dir.join("manifest.txt").exists() {
            return Err(Error::Runtime(format!(
                "no artifact manifest under {} — run `make artifacts` first",
                dir.display()
            )));
        }
        Ok(Artifacts {
            client: xla::PjRtClient::cpu()?,
            dir,
        })
    }

    /// Open `artifacts/` relative to the workspace root, walking up from
    /// the current directory (so examples/tests work from any cwd).
    pub fn open_default() -> Result<Artifacts> {
        let mut dir = std::env::current_dir()?;
        loop {
            let candidate = dir.join("artifacts");
            if candidate.join("manifest.txt").exists() {
                return Self::open(candidate);
            }
            if !dir.pop() {
                return Err(Error::Runtime(
                    "artifacts/manifest.txt not found in any ancestor — run `make artifacts`"
                        .into(),
                ));
            }
        }
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load and compile one artifact by manifest name.
    pub fn load(&self, name: &str) -> Result<xla::PjRtLoadedExecutable> {
        let path = self.dir.join(format!("{name}.hlo.txt"));
        if !path.exists() {
            return Err(Error::Runtime(format!(
                "artifact `{name}` missing at {} — run `make artifacts`",
                path.display()
            )));
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| Error::Runtime("non-utf8 artifact path".into()))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        Ok(self.client.compile(&comp)?)
    }

    /// Load the astronomy stacking pipeline.
    pub fn stacking(&self) -> Result<StackingExecutable> {
        Ok(StackingExecutable {
            exe: self.load("stacking")?,
        })
    }

    /// Load the batched abstract-model evaluator.
    pub fn model_eval(&self) -> Result<ModelEvalExecutable> {
        Ok(ModelEvalExecutable {
            exe: self.load("model_eval")?,
        })
    }
}

/// Result of one stacking request.
#[derive(Debug, Clone)]
pub struct StackResult {
    /// Normalized stacked image, row-major (STACK_H × STACK_W).
    pub image: Vec<f32>,
    /// Mean pixel value.
    pub mean: f32,
    /// Peak pixel value.
    pub peak: f32,
}

/// The compiled astronomy stacking pipeline (L2+L1 in one HLO module).
pub struct StackingExecutable {
    exe: xla::PjRtLoadedExecutable,
}

impl StackingExecutable {
    /// Stack `cutouts` (STACK_N·STACK_H·STACK_W row-major) with
    /// `weights` (STACK_N). Shorter batches are zero-padded (zero weight
    /// ⇒ no contribution), so any `n ≤ STACK_N` works.
    pub fn stack(&self, cutouts: &[f32], weights: &[f32]) -> Result<StackResult> {
        use shapes::{STACK_H, STACK_N, STACK_W};
        let frame = STACK_H * STACK_W;
        let n = weights.len();
        if n > STACK_N || cutouts.len() != n * frame {
            return Err(Error::Runtime(format!(
                "stacking input mismatch: {} cutout floats / {} weights (max N={})",
                cutouts.len(),
                n,
                STACK_N
            )));
        }
        let mut cut = vec![0.0f32; STACK_N * frame];
        cut[..cutouts.len()].copy_from_slice(cutouts);
        let mut w = vec![0.0f32; STACK_N];
        w[..n].copy_from_slice(weights);

        let x = xla::Literal::vec1(&cut).reshape(&[
            STACK_N as i64,
            STACK_H as i64,
            STACK_W as i64,
        ])?;
        let wl = xla::Literal::vec1(&w);
        let result = self.exe.execute::<xla::Literal>(&[x, wl])?[0][0].to_literal_sync()?;
        let (img, mean, peak) = result.to_tuple3()?;
        Ok(StackResult {
            image: img.to_vec::<f32>()?,
            mean: mean.get_first_element::<f32>()?,
            peak: peak.get_first_element::<f32>()?,
        })
    }
}

/// The compiled batched model evaluator.
pub struct ModelEvalExecutable {
    exe: xla::PjRtLoadedExecutable,
}

impl ModelEvalExecutable {
    /// Evaluate model points via the AOT'd JAX/Pallas kernel; slices
    /// longer than [`shapes::MODEL_BATCH`] are processed in chunks.
    pub fn eval(&self, inputs: &[ModelInputs]) -> Result<Vec<ModelPrediction>> {
        let mut out = Vec::with_capacity(inputs.len());
        for chunk in inputs.chunks(shapes::MODEL_BATCH) {
            out.extend(self.eval_chunk(chunk)?);
        }
        Ok(out)
    }

    fn eval_chunk(&self, inputs: &[ModelInputs]) -> Result<Vec<ModelPrediction>> {
        use shapes::MODEL_BATCH;
        let n = inputs.len();
        debug_assert!(n <= MODEL_BATCH);
        // Pad with a benign point (all ones) to the fixed batch size.
        let mut cols = vec![vec![1.0f32; MODEL_BATCH]; 9];
        for (i, inp) in inputs.iter().enumerate() {
            let inv_a = if inp.arrival_rate.is_finite() && inp.arrival_rate > 0.0 {
                1.0 / inp.arrival_rate
            } else {
                0.0
            };
            let vals = [
                inp.num_tasks,
                inp.cpus,
                inp.mu_s,
                inp.overhead_s,
                inp.object_bytes,
                inv_a,
                inp.persistent_bps,
                inp.transient_bps,
                inp.p_miss,
            ];
            for (c, v) in vals.iter().enumerate() {
                cols[c][i] = *v as f32;
            }
        }
        let literals: Vec<xla::Literal> = cols.iter().map(|c| xla::Literal::vec1(c)).collect();
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        let outs = result.to_tuple()?;
        if outs.len() != 7 {
            return Err(Error::Runtime(format!(
                "model_eval returned {} outputs, expected 7",
                outs.len()
            )));
        }
        let get = |lit: &xla::Literal| -> Result<Vec<f32>> { Ok(lit.to_vec::<f32>()?) };
        let v = get(&outs[0])?;
        let y = get(&outs[1])?;
        let w = get(&outs[2])?;
        let e = get(&outs[3])?;
        let s = get(&outs[4])?;
        let omega = get(&outs[5])?;
        let zeta = get(&outs[6])?;
        Ok((0..n)
            .map(|i| ModelPrediction {
                b: inputs[i].mu_s,
                intensity: if inputs[i].arrival_rate.is_finite() {
                    inputs[i].mu_s * inputs[i].arrival_rate
                } else {
                    f64::INFINITY
                },
                v: v[i] as f64,
                y: y[i] as f64,
                w: w[i] as f64,
                efficiency: e[i] as f64,
                speedup: s[i] as f64,
                omega_pi: omega[i] as f64,
                zeta_s: zeta[i] as f64,
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    //! These tests require `make artifacts` to have run; they are part
    //! of `make test` (artifacts are a build prerequisite). If artifacts
    //! are absent the tests are skipped with a notice rather than
    //! failing, so `cargo test` alone stays green in a fresh checkout.
    use super::*;

    fn artifacts() -> Option<Artifacts> {
        match Artifacts::open_default() {
            Ok(a) => Some(a),
            Err(e) => {
                eprintln!("skipping runtime test: {e}");
                None
            }
        }
    }

    #[test]
    fn loads_and_reports_platform() {
        let Some(a) = artifacts() else { return };
        assert!(!a.platform().is_empty());
    }

    #[test]
    fn missing_artifact_is_a_clear_error() {
        let Some(a) = artifacts() else { return };
        let err = match a.load("no-such-artifact") {
            Ok(_) => panic!("loading a missing artifact must fail"),
            Err(e) => e,
        };
        assert!(err.to_string().contains("make artifacts"), "{err}");
    }

    #[test]
    fn stacking_matches_cpu_reference() {
        let Some(a) = artifacts() else { return };
        let exe = a.stacking().expect("compile stacking");
        use shapes::{STACK_H, STACK_N, STACK_W};
        let frame = STACK_H * STACK_W;
        let mut rng = crate::util::prng::Pcg64::seeded(99);
        let cutouts: Vec<f32> = (0..STACK_N * frame)
            .map(|_| (rng.next_f64() as f32) - 0.5)
            .collect();
        let weights: Vec<f32> = (0..STACK_N).map(|_| rng.next_f64() as f32).collect();
        let got = exe.stack(&cutouts, &weights).expect("execute");

        // CPU reference: normalized weighted sum.
        let total: f32 = weights.iter().sum();
        let mut want = vec![0.0f32; frame];
        for (i, w) in weights.iter().enumerate() {
            for p in 0..frame {
                want[p] += w * cutouts[i * frame + p];
            }
        }
        for p in want.iter_mut() {
            *p /= total;
        }
        assert_eq!(got.image.len(), frame);
        for (g, w) in got.image.iter().zip(&want) {
            assert!((g - w).abs() < 1e-3, "{g} vs {w}");
        }
        let mean: f32 = want.iter().sum::<f32>() / frame as f32;
        assert!((got.mean - mean).abs() < 1e-3);
    }

    #[test]
    fn stacking_pads_short_batches() {
        let Some(a) = artifacts() else { return };
        let exe = a.stacking().expect("compile stacking");
        use shapes::{STACK_H, STACK_W};
        let frame = STACK_H * STACK_W;
        let cutouts = vec![2.0f32; 3 * frame];
        let weights = vec![1.0f32; 3];
        let got = exe.stack(&cutouts, &weights).expect("execute");
        // Mean of three identical weight-1 cutouts of 2.0 = 2.0.
        assert!((got.mean - 2.0).abs() < 1e-4, "mean {}", got.mean);
        assert!((got.peak - 2.0).abs() < 1e-4);
    }

    #[test]
    fn stacking_rejects_mismatched_inputs() {
        let Some(a) = artifacts() else { return };
        let exe = a.stacking().expect("compile stacking");
        assert!(exe.stack(&[0.0; 10], &[1.0; 3]).is_err());
    }

    #[test]
    fn model_eval_agrees_with_rust_model() {
        let Some(a) = artifacts() else { return };
        let exe = a.model_eval().expect("compile model_eval");
        // A spread of model points, including batch (inv_a = 0) and
        // rate-limited cases — f32 kernel vs f64 Rust: 2% tolerance.
        let mut points = Vec::new();
        for &cpus in &[2.0, 16.0, 128.0] {
            for &p_miss in &[0.0, 0.04, 0.5, 1.0] {
                for &rate in &[f64::INFINITY, 50.0] {
                    points.push(ModelInputs {
                        num_tasks: 10_000.0,
                        cpus,
                        mu_s: 0.1,
                        overhead_s: 0.005,
                        object_bytes: 5e6,
                        arrival_rate: rate,
                        persistent_bps: 5.5e8,
                        transient_bps: 2e8,
                        p_miss,
                        p_local: 1.0 - p_miss,
                    });
                }
            }
        }
        let got = exe.eval(&points).expect("execute");
        assert_eq!(got.len(), points.len());
        for (inp, g) in points.iter().zip(&got) {
            let want = crate::model::predict(inp);
            let close = |a: f64, b: f64, what: &str| {
                let denom = b.abs().max(1e-9);
                assert!(
                    (a - b).abs() / denom < 0.02,
                    "{what}: pjrt {a} vs rust {b} (cpus={}, p_miss={})",
                    inp.cpus,
                    inp.p_miss
                );
            };
            close(g.w, want.w, "W");
            close(g.v, want.v, "V");
            close(g.efficiency, want.efficiency, "E");
            close(g.speedup, want.speedup, "S");
        }
    }
}
