//! Runtime bridge for the AOT-compiled JAX/Pallas artifacts.
//!
//! The original bridge executed the artifacts through PJRT
//! (`PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `compile` → `execute`; see
//! python/compile/aot.py for the producing side). This build environment
//! is offline and the crate carries **zero external dependencies**, so
//! the `xla` crate is unavailable; the same public API is provided by a
//! **pure-Rust reference backend** implementing exactly the math the
//! kernels were AOT'd from (`python/compile/kernels/ref.py` is the
//! executable spec both sides mirror). Callers are agnostic: the CLI,
//! the live engine, and `examples/astronomy_stacking.rs` compile and run
//! unchanged, and the artifact-presence checks keep their semantics so a
//! future PJRT backend can slot back in behind the same types.
//!
//! Artifacts are still located the same way: `Artifacts::open*` requires
//! the `artifacts/manifest.txt` produced by `make artifacts`, and
//! missing entries yield [`crate::Error::Runtime`] with guidance.

use crate::model::{ModelInputs, ModelPrediction};
use crate::{Error, Result};
use std::path::{Path, PathBuf};

/// Fixed shapes baked into the artifacts (must match python/compile/aot.py).
pub mod shapes {
    /// Cutouts per stacking request.
    pub const STACK_N: usize = 128;
    /// Cutout height.
    pub const STACK_H: usize = 64;
    /// Cutout width.
    pub const STACK_W: usize = 64;
    /// Model-evaluator batch size.
    pub const MODEL_BATCH: usize = 64;
}

/// A directory of AOT artifacts plus the executing backend.
pub struct Artifacts {
    dir: PathBuf,
}

impl Artifacts {
    /// Open the artifacts directory (default `artifacts/`); the manifest
    /// check surfaces a missing `make artifacts` run early.
    pub fn open(dir: impl AsRef<Path>) -> Result<Artifacts> {
        let dir = dir.as_ref().to_path_buf();
        if !dir.join("manifest.txt").exists() {
            return Err(Error::Runtime(format!(
                "no artifact manifest under {} — run `make artifacts` first",
                dir.display()
            )));
        }
        Ok(Artifacts { dir })
    }

    /// Open `artifacts/` relative to the workspace root, walking up from
    /// the current directory (so examples/tests work from any cwd).
    pub fn open_default() -> Result<Artifacts> {
        let mut dir = std::env::current_dir()?;
        loop {
            let candidate = dir.join("artifacts");
            if candidate.join("manifest.txt").exists() {
                return Self::open(candidate);
            }
            if !dir.pop() {
                return Err(Error::Runtime(
                    "artifacts/manifest.txt not found in any ancestor — run `make artifacts`"
                        .into(),
                ));
            }
        }
    }

    /// Executing platform name (diagnostics).
    pub fn platform(&self) -> String {
        "cpu-reference".to_string()
    }

    /// Check one artifact by manifest name (the PJRT backend compiled it
    /// here; the reference backend validates presence so missing-artifact
    /// errors keep their shape).
    pub fn load(&self, name: &str) -> Result<()> {
        let path = self.dir.join(format!("{name}.hlo.txt"));
        if !path.exists() {
            return Err(Error::Runtime(format!(
                "artifact `{name}` missing at {} — run `make artifacts`",
                path.display()
            )));
        }
        Ok(())
    }

    /// Load the astronomy stacking pipeline.
    pub fn stacking(&self) -> Result<StackingExecutable> {
        self.load("stacking")?;
        Ok(StackingExecutable { _priv: () })
    }

    /// Load the batched abstract-model evaluator.
    pub fn model_eval(&self) -> Result<ModelEvalExecutable> {
        self.load("model_eval")?;
        Ok(ModelEvalExecutable { _priv: () })
    }
}

/// Result of one stacking request.
#[derive(Debug, Clone)]
pub struct StackResult {
    /// Normalized stacked image, row-major (STACK_H × STACK_W).
    pub image: Vec<f32>,
    /// Mean pixel value.
    pub mean: f32,
    /// Peak pixel value.
    pub peak: f32,
}

/// The astronomy stacking pipeline (L2+L1 fused in the AOT module; the
/// reference backend computes the identical normalized weighted sum).
pub struct StackingExecutable {
    _priv: (),
}

impl StackingExecutable {
    /// Stack `cutouts` (STACK_N·STACK_H·STACK_W row-major) with
    /// `weights` (STACK_N). Shorter batches are zero-padded (zero weight
    /// ⇒ no contribution), so any `n ≤ STACK_N` works.
    pub fn stack(&self, cutouts: &[f32], weights: &[f32]) -> Result<StackResult> {
        use shapes::{STACK_H, STACK_N, STACK_W};
        let frame = STACK_H * STACK_W;
        let n = weights.len();
        if n > STACK_N || cutouts.len() != n * frame {
            return Err(Error::Runtime(format!(
                "stacking input mismatch: {} cutout floats / {} weights (max N={})",
                cutouts.len(),
                n,
                STACK_N
            )));
        }
        // Normalized weighted sum, accumulated cutout-major like the
        // kernel (f32 throughout, so results track the AOT path bit-close).
        let total: f32 = weights.iter().sum();
        let mut image = vec![0.0f32; frame];
        for (i, w) in weights.iter().enumerate() {
            for p in 0..frame {
                image[p] += w * cutouts[i * frame + p];
            }
        }
        if total != 0.0 {
            for p in image.iter_mut() {
                *p /= total;
            }
        }
        let mean = image.iter().sum::<f32>() / frame as f32;
        let peak = image.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        Ok(StackResult { image, mean, peak })
    }
}

/// The batched abstract-model evaluator.
pub struct ModelEvalExecutable {
    _priv: (),
}

impl ModelEvalExecutable {
    /// Evaluate model points. The reference backend applies the Rust
    /// model directly (the AOT kernel implements the same closed-form
    /// equations in f32; see `python/compile/kernels/model_eval.py`).
    pub fn eval(&self, inputs: &[ModelInputs]) -> Result<Vec<ModelPrediction>> {
        Ok(inputs.iter().map(crate::model::predict).collect())
    }
}

#[cfg(test)]
mod tests {
    //! These tests require `make artifacts` to have run (the manifest
    //! gates the loaders even under the reference backend, keeping the
    //! missing-artifact UX honest). If artifacts are absent the tests
    //! are skipped with a notice rather than failing, so `cargo test`
    //! alone stays green in a fresh checkout.
    use super::*;

    fn artifacts() -> Option<Artifacts> {
        match Artifacts::open_default() {
            Ok(a) => Some(a),
            Err(e) => {
                eprintln!("skipping runtime test: {e}");
                None
            }
        }
    }

    #[test]
    fn loads_and_reports_platform() {
        let Some(a) = artifacts() else { return };
        assert!(!a.platform().is_empty());
    }

    #[test]
    fn missing_artifact_is_a_clear_error() {
        let Some(a) = artifacts() else { return };
        let err = match a.load("no-such-artifact") {
            Ok(_) => panic!("loading a missing artifact must fail"),
            Err(e) => e,
        };
        assert!(err.to_string().contains("make artifacts"), "{err}");
    }

    #[test]
    fn stacking_matches_cpu_reference() {
        let Some(a) = artifacts() else { return };
        let exe = a.stacking().expect("load stacking");
        use shapes::{STACK_H, STACK_N, STACK_W};
        let frame = STACK_H * STACK_W;
        let mut rng = crate::util::prng::Pcg64::seeded(99);
        let cutouts: Vec<f32> = (0..STACK_N * frame)
            .map(|_| (rng.next_f64() as f32) - 0.5)
            .collect();
        let weights: Vec<f32> = (0..STACK_N).map(|_| rng.next_f64() as f32).collect();
        let got = exe.stack(&cutouts, &weights).expect("execute");

        // CPU reference: normalized weighted sum.
        let total: f32 = weights.iter().sum();
        let mut want = vec![0.0f32; frame];
        for (i, w) in weights.iter().enumerate() {
            for p in 0..frame {
                want[p] += w * cutouts[i * frame + p];
            }
        }
        for p in want.iter_mut() {
            *p /= total;
        }
        assert_eq!(got.image.len(), frame);
        for (g, w) in got.image.iter().zip(&want) {
            assert!((g - w).abs() < 1e-3, "{g} vs {w}");
        }
        let mean: f32 = want.iter().sum::<f32>() / frame as f32;
        assert!((got.mean - mean).abs() < 1e-3);
    }

    #[test]
    fn stacking_pads_short_batches() {
        let Some(a) = artifacts() else { return };
        let exe = a.stacking().expect("load stacking");
        use shapes::{STACK_H, STACK_W};
        let frame = STACK_H * STACK_W;
        let cutouts = vec![2.0f32; 3 * frame];
        let weights = vec![1.0f32; 3];
        let got = exe.stack(&cutouts, &weights).expect("execute");
        // Mean of three identical weight-1 cutouts of 2.0 = 2.0.
        assert!((got.mean - 2.0).abs() < 1e-4, "mean {}", got.mean);
        assert!((got.peak - 2.0).abs() < 1e-4);
    }

    #[test]
    fn stacking_rejects_mismatched_inputs() {
        let Some(a) = artifacts() else { return };
        let exe = a.stacking().expect("load stacking");
        assert!(exe.stack(&[0.0; 10], &[1.0; 3]).is_err());
    }

    #[test]
    fn model_eval_preserves_order_and_shape() {
        // NOTE: the reference backend routes through
        // `crate::model::predict`, so a value-level comparison against
        // `predict` would be circular (the pre-change test cross-checked
        // the independent f32 AOT kernel; that check must return with a
        // real PJRT backend). What is meaningful here: batching/order
        // preservation across the MODEL_BATCH chunk boundary, and sane
        // monotone structure of the outputs.
        let Some(a) = artifacts() else { return };
        let exe = a.model_eval().expect("load model_eval");
        // MODEL_BATCH + 7 points forces a second chunk in a PJRT-style
        // batched backend; outputs must stay aligned with inputs.
        let n = shapes::MODEL_BATCH + 7;
        let points: Vec<ModelInputs> = (0..n)
            .map(|i| ModelInputs {
                num_tasks: 10_000.0,
                cpus: (1 + i) as f64,
                mu_s: 0.1,
                overhead_s: 0.005,
                object_bytes: 5e6,
                arrival_rate: f64::INFINITY,
                persistent_bps: 5.5e8,
                transient_bps: 2e8,
                p_miss: 0.04,
                p_local: 0.96,
            })
            .collect();
        let got = exe.eval(&points).expect("execute");
        assert_eq!(got.len(), points.len());
        for w in got.windows(2) {
            assert!(
                w[1].speedup >= w[0].speedup - 1e-9,
                "speedup must not decrease with cpus: {} then {}",
                w[0].speedup,
                w[1].speedup
            );
        }
        for (i, g) in got.iter().enumerate() {
            assert!(
                g.efficiency > 0.0 && g.efficiency <= 1.0 + 1e-9,
                "point {i}: efficiency {} out of range",
                g.efficiency
            );
            assert!(g.w.is_finite() && g.w > 0.0, "point {i}: W {}", g.w);
        }
    }
}
