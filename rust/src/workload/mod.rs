//! Workload generation — arrival processes, file-access patterns, and the
//! scenario library.
//!
//! The paper's provisioning workload (§5.2): 250K tasks, each reading one
//! of 10K × 10 MB files chosen uniformly at random and computing for
//! 10 ms; arrival rate follows `A_i = min(ceil(A_{i-1}·1.3), 1000)` with
//! 60 s intervals — 24 intervals, ≈1415 s span. The scheduler
//! micro-benchmark (§5.1) uses the same shape with 1-byte files submitted
//! in batch. The astronomy model-validation workloads (§4.4) sweep a
//! *data locality* parameter from 1 to 30 (mean accesses per file).
//!
//! Beyond the paper's uniform-random stream, the [`scenarios`] module
//! generates heavy-tailed, bursty, batched, and dependency-structured
//! workloads (see `docs/WORKLOADS.md`). Every generator funnels through
//! the single [`generate`] entry point: a [`WorkloadConfig`] without a
//! scenario takes the legacy path — bit-identical to the pre-scenario
//! generator, which the four parity suites assert — while a configured
//! [`ScenarioSpec`](crate::config::ScenarioSpec) dispatches into the
//! library.
//!
//! The task shape is a file *set*: [`TaskSpec::inputs`] holds every file
//! the task reads, [`TaskSpec::outputs`] the files it produces (visible
//! in persistent storage once the task completes), and [`TaskSpec::deps`]
//! the predecessor tasks whose completion gates its submission.

pub mod scenarios;

use crate::config::{AccessSpec, ArrivalSpec, WorkloadConfig};
use crate::ids::{FileId, TaskId};
use crate::util::prng::{Pcg64, Zipf};
use crate::util::time::Micros;

/// One generated task.
#[derive(Debug, Clone)]
pub struct TaskSpec {
    /// Task id (stream position; equals the task's index in
    /// [`Workload::tasks`]).
    pub id: TaskId,
    /// Nominal submission time. Tasks with unmet [`deps`](Self::deps) are
    /// held past this instant until every predecessor completes.
    pub arrival: Micros,
    /// Files the task reads (θ(κ)); the paper's workloads read exactly
    /// one, pipeline stages read several.
    pub inputs: Vec<FileId>,
    /// Files the task produces. Outputs land in persistent storage when
    /// the task completes and may appear as later tasks' inputs.
    pub outputs: Vec<FileId>,
    /// Predecessor tasks (by id) whose completion gates submission.
    /// Generators only emit edges pointing at earlier stream positions.
    pub deps: Vec<TaskId>,
    /// Index of the arrival-rate interval this task belongs to (indexes
    /// [`Workload::stages`]; slowdown accounting, Fig 14).
    pub interval: u32,
}

impl TaskSpec {
    /// The task's dominant file — first input; shard routing key.
    pub fn dominant(&self) -> Option<FileId> {
        self.inputs.first().copied()
    }
}

/// A fully materialized workload.
#[derive(Debug, Clone)]
pub struct Workload {
    /// Tasks ordered by arrival time.
    pub tasks: Vec<TaskSpec>,
    /// Bytes per file.
    pub file_size_bytes: u64,
    /// Per-task compute time.
    pub compute: Micros,
    /// Arrival-rate stages: `(start, rate_tasks_per_s)` per interval
    /// (one entry for non-staged arrivals). [`TaskSpec::interval`] indexes
    /// this table.
    pub stages: Vec<(Micros, f64)>,
    /// Number of distinct input files actually referenced.
    pub distinct_files: u32,
    /// Total dependency edges across all tasks (0 for flat workloads).
    pub dep_edges: u64,
}

impl Workload {
    /// Total input bytes if every access read from scratch.
    pub fn total_bytes(&self) -> u64 {
        let accesses: u64 = self.tasks.iter().map(|t| t.inputs.len() as u64).sum();
        accesses * self.file_size_bytes
    }

    /// Working-set size in bytes (distinct input files × file size) — the
    /// |Ω| the caches must exceed for diffusion to reach steady state.
    pub fn working_set_bytes(&self) -> u64 {
        self.distinct_files as u64 * self.file_size_bytes
    }

    /// Arrival time of the last task.
    pub fn span(&self) -> Micros {
        self.tasks.last().map_or(Micros::ZERO, |t| t.arrival)
    }

    /// Arrival rate (tasks/s) in effect at time `t`.
    pub fn rate_at(&self, t: Micros) -> f64 {
        let mut rate = 0.0;
        for &(start, r) in &self.stages {
            if start <= t {
                rate = r;
            } else {
                break;
            }
        }
        rate
    }

    /// Ideal execution time (s) with infinite resources and free data:
    /// each task starts at `max(arrival, latest dep completion)` and runs
    /// for the compute time. Reduces to `span + compute` for flat
    /// workloads; for pipelines it is the critical path.
    pub fn ideal_execution_time_s(&self) -> f64 {
        let mut done: Vec<Micros> = Vec::with_capacity(self.tasks.len());
        let mut latest = Micros::ZERO;
        for (i, t) in self.tasks.iter().enumerate() {
            let mut start = t.arrival;
            for d in &t.deps {
                debug_assert!((d.0 as usize) < i, "dep edge must point backwards");
                if let Some(&fin) = done.get(d.0 as usize) {
                    start = start.max(fin);
                }
            }
            let fin = start + self.compute;
            latest = latest.max(fin);
            done.push(fin);
        }
        latest.as_secs_f64()
    }

    /// FNV-1a fingerprint of the full task stream (ids, arrivals,
    /// intervals, input/output sets, dependency edges). Golden
    /// determinism tests assert same-seed generations collide and
    /// different seeds diverge.
    pub fn fingerprint(&self) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut put = |h: &mut u64, v: u64| {
            for b in v.to_le_bytes() {
                *h ^= b as u64;
                *h = h.wrapping_mul(0x0000_0100_0000_01b3);
            }
        };
        put(&mut h, self.tasks.len() as u64);
        for t in &self.tasks {
            put(&mut h, t.id.0);
            put(&mut h, t.arrival.0);
            put(&mut h, t.interval as u64);
            put(&mut h, t.inputs.len() as u64);
            for f in &t.inputs {
                put(&mut h, f.0 as u64);
            }
            put(&mut h, t.outputs.len() as u64);
            for f in &t.outputs {
                put(&mut h, f.0 as u64);
            }
            put(&mut h, t.deps.len() as u64);
            for d in &t.deps {
                put(&mut h, d.0);
            }
        }
        h
    }
}

/// The ideal workload execution time (s) for the *legacy* arrival
/// processes: infinite resources, zero-cost communication — tasks finish
/// as they arrive (§5.2.5's 1415 s). Scenario workloads derive the same
/// quantity from the generated stream via
/// [`Workload::ideal_execution_time_s`].
pub fn ideal_execution_time_s(cfg: &WorkloadConfig) -> f64 {
    let arrivals = arrival_times(cfg);
    match arrivals.last() {
        Some(&(t, _)) => t.as_secs_f64() + cfg.compute_ms / 1e3,
        None => 0.0,
    }
}

/// Generate the full workload deterministically from `seed` — the single
/// entry point for every workload shape. Without a configured scenario
/// this is the paper's generator, bit-identical to its pre-scenario
/// form; with one it dispatches into [`scenarios`].
pub fn generate(cfg: &WorkloadConfig, seed: u64) -> Workload {
    match &cfg.scenario {
        None => generate_legacy(cfg, seed),
        Some(spec) => scenarios::generate(cfg, spec, seed),
    }
}

/// The paper's generator (uniform/zipf/locality access over the
/// configured arrival process). Draw order is frozen: one PRNG stream,
/// arrivals first, then the access sequence.
fn generate_legacy(cfg: &WorkloadConfig, seed: u64) -> Workload {
    let mut rng = Pcg64::new(seed, 0x6f72_6b6c); // "workl" stream
    let arrivals = arrival_times(cfg);
    let files = access_sequence(cfg, arrivals.len(), &mut rng);
    debug_assert_eq!(arrivals.len(), files.len());

    let mut distinct = std::collections::HashSet::new();
    let tasks: Vec<TaskSpec> = arrivals
        .iter()
        .zip(&files)
        .enumerate()
        .map(|(i, (&(arrival, interval), &file))| {
            distinct.insert(file);
            TaskSpec {
                id: TaskId(i as u64),
                arrival,
                inputs: vec![file],
                outputs: Vec::new(),
                deps: Vec::new(),
                interval,
            }
        })
        .collect();

    Workload {
        stages: stages(cfg, &tasks),
        tasks,
        file_size_bytes: cfg.file_size_bytes,
        compute: Micros::from_secs_f64(cfg.compute_ms / 1e3),
        distinct_files: distinct.len() as u32,
        dep_edges: 0,
    }
}

/// Arrival times plus interval index, per the configured process.
fn arrival_times(cfg: &WorkloadConfig) -> Vec<(Micros, u32)> {
    let n = cfg.num_tasks;
    match cfg.arrival {
        ArrivalSpec::Batch => (0..n).map(|_| (Micros::ZERO, 0)).collect(),
        ArrivalSpec::Constant(rate) => {
            let gap = 1e6 / rate;
            (0..n)
                .map(|i| (Micros((i as f64 * gap).round() as u64), 0))
                .collect()
        }
        ArrivalSpec::IncreasingRate {
            initial,
            factor,
            interval_s,
            max_rate,
        } => {
            // A_i = min(ceil(A_{i-1}·factor), max). Tasks are evenly
            // spaced within each interval; the last interval extends
            // until the task budget is exhausted (the paper's 24th
            // interval at 1000/s runs ~35 s).
            let mut out = Vec::with_capacity(n as usize);
            let mut rate = initial;
            let mut interval: u32 = 0;
            let mut t0 = 0.0f64;
            'outer: loop {
                let gap = 1.0 / rate;
                let capped = rate >= max_rate;
                let in_interval = if capped {
                    u64::MAX // run out the task budget at the cap
                } else {
                    (rate * interval_s).round() as u64
                };
                for j in 0..in_interval {
                    if out.len() as u64 >= n {
                        break 'outer;
                    }
                    let t = t0 + j as f64 * gap;
                    out.push((Micros::from_secs_f64(t), interval));
                }
                t0 += interval_s;
                rate = (rate * factor).ceil().min(max_rate);
                interval += 1;
            }
            out
        }
    }
}

/// Stage table `(start, rate)` for ideal-throughput plotting.
fn stages(cfg: &WorkloadConfig, tasks: &[TaskSpec]) -> Vec<(Micros, f64)> {
    match cfg.arrival {
        ArrivalSpec::Batch => vec![(Micros::ZERO, f64::INFINITY)],
        ArrivalSpec::Constant(rate) => vec![(Micros::ZERO, rate)],
        ArrivalSpec::IncreasingRate {
            initial,
            factor,
            interval_s,
            max_rate,
        } => {
            let last_interval = tasks.last().map_or(0, |t| t.interval);
            let mut out = Vec::new();
            let mut rate = initial;
            for i in 0..=last_interval {
                out.push((Micros::from_secs_f64(i as f64 * interval_s), rate));
                rate = (rate * factor).ceil().min(max_rate);
            }
            out
        }
    }
}

/// File-per-task sequence, per the configured access pattern.
fn access_sequence(cfg: &WorkloadConfig, n: usize, rng: &mut Pcg64) -> Vec<FileId> {
    match cfg.access {
        AccessSpec::Uniform => (0..n)
            .map(|_| FileId(rng.below(cfg.num_files as u64) as u32))
            .collect(),
        AccessSpec::Zipf(s) => {
            let z = Zipf::new(cfg.num_files as usize, s);
            (0..n).map(|_| FileId(z.sample(rng) as u32)).collect()
        }
        AccessSpec::Locality(l) => {
            // Each distinct file is accessed ⌈l⌉ or ⌊l⌋ times so the mean
            // is l; repeats are clustered in time (shuffled within a
            // bounded window) — the astronomy workloads' "locality"
            // (§4.4: 1 = one access per file … 30 = thirty).
            let distinct = ((n as f64 / l).ceil() as usize).clamp(1, cfg.num_files as usize);
            let mut seq = Vec::with_capacity(n);
            let mut remaining = n;
            for i in 0..distinct {
                // Distribute n accesses over `distinct` files as evenly
                // as integer arithmetic allows.
                let share = remaining / (distinct - i);
                for _ in 0..share {
                    seq.push(FileId((i % cfg.num_files as usize) as u32));
                }
                remaining -= share;
            }
            debug_assert_eq!(seq.len(), n);
            // Window shuffle: preserves coarse temporal locality while
            // breaking the degenerate exact-repeat pattern.
            let window = (l.ceil() as usize * 64).clamp(64, 8192).min(seq.len());
            let mut i = 0;
            while i < seq.len() {
                let end = (i + window).min(seq.len());
                rng.shuffle(&mut seq[i..end]);
                i = end;
            }
            seq
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::MB;

    fn paper_cfg() -> WorkloadConfig {
        WorkloadConfig::default()
    }

    #[test]
    fn paper_workload_span_matches_1415s() {
        let cfg = paper_cfg();
        let ideal = ideal_execution_time_s(&cfg);
        assert!(
            (ideal - 1415.0).abs() < 25.0,
            "ideal WET {ideal} ≉ paper's 1415 s"
        );
        let w = generate(&cfg, 1);
        assert_eq!(w.tasks.len(), 250_000);
        assert_eq!(w.file_size_bytes, 10 * MB);
        // 24 arrival intervals (§5.2).
        assert_eq!(w.stages.len(), 24, "stages: {}", w.stages.len());
        // Flat workload: the stream-derived ideal matches the config one.
        assert!((w.ideal_execution_time_s() - ideal).abs() < 1e-6);
    }

    #[test]
    fn arrivals_are_sorted_and_rates_increase() {
        let w = generate(&paper_cfg(), 7);
        for pair in w.tasks.windows(2) {
            assert!(pair[0].arrival <= pair[1].arrival);
            assert!(pair[0].interval <= pair[1].interval);
        }
        assert_eq!(w.rate_at(Micros::ZERO), 1.0);
        assert_eq!(w.rate_at(Micros::from_secs(61)), 2.0);
        assert_eq!(w.rate_at(Micros::from_secs(100_000)), 1000.0);
    }

    #[test]
    fn uniform_access_covers_files() {
        let mut cfg = paper_cfg();
        cfg.num_tasks = 50_000;
        cfg.num_files = 100;
        let w = generate(&cfg, 3);
        assert_eq!(w.distinct_files, 100);
        assert!(w.tasks.iter().all(|t| t.inputs.len() == 1));
        assert!(w.tasks.iter().all(|t| t.inputs[0].0 < 100));
        assert!(w.tasks.iter().all(|t| t.outputs.is_empty() && t.deps.is_empty()));
        assert_eq!(w.dep_edges, 0);
    }

    #[test]
    fn determinism_same_seed() {
        let a = generate(&paper_cfg(), 5);
        let b = generate(&paper_cfg(), 5);
        assert_eq!(a.tasks.len(), b.tasks.len());
        for (x, y) in a.tasks.iter().zip(&b.tasks) {
            assert_eq!(x.inputs, y.inputs);
            assert_eq!(x.arrival, y.arrival);
        }
        assert_eq!(a.fingerprint(), b.fingerprint());
        assert_ne!(a.fingerprint(), generate(&paper_cfg(), 6).fingerprint());
    }

    #[test]
    fn locality_controls_distinct_files() {
        let mut cfg = paper_cfg();
        cfg.num_tasks = 30_000;
        cfg.num_files = 1_000_000; // no cap
        cfg.access = AccessSpec::Locality(30.0);
        let w = generate(&cfg, 11);
        assert_eq!(w.distinct_files, 1000);
        // Mean accesses per file = 30.
        let mean = w.tasks.len() as f64 / w.distinct_files as f64;
        assert!((mean - 30.0).abs() < 0.5, "mean={mean}");

        cfg.access = AccessSpec::Locality(1.0);
        let w = generate(&cfg, 11);
        assert_eq!(w.distinct_files, 30_000);
    }

    #[test]
    fn zipf_access_is_skewed() {
        let mut cfg = paper_cfg();
        cfg.num_tasks = 20_000;
        cfg.num_files = 1000;
        cfg.access = AccessSpec::Zipf(1.2);
        let w = generate(&cfg, 13);
        let head = w.tasks.iter().filter(|t| t.inputs[0].0 < 100).count();
        assert!(head > w.tasks.len() / 2);
    }

    #[test]
    fn batch_and_constant_arrivals() {
        let mut cfg = paper_cfg();
        cfg.num_tasks = 100;
        cfg.arrival = ArrivalSpec::Batch;
        let w = generate(&cfg, 1);
        assert!(w.tasks.iter().all(|t| t.arrival == Micros::ZERO));

        cfg.arrival = ArrivalSpec::Constant(10.0);
        let w = generate(&cfg, 1);
        assert_eq!(w.span(), Micros::from_secs_f64(9.9));
    }

    #[test]
    fn working_set_math() {
        let mut cfg = paper_cfg();
        cfg.num_tasks = 1000;
        let w = generate(&cfg, 1);
        assert_eq!(
            w.working_set_bytes(),
            w.distinct_files as u64 * cfg.file_size_bytes
        );
        assert_eq!(w.total_bytes(), 1000 * cfg.file_size_bytes);
    }
}
