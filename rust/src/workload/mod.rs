//! Workload generation — arrival processes and file-access patterns.
//!
//! The paper's provisioning workload (§5.2): 250K tasks, each reading one
//! of 10K × 10 MB files chosen uniformly at random and computing for
//! 10 ms; arrival rate follows `A_i = min(ceil(A_{i-1}·1.3), 1000)` with
//! 60 s intervals — 24 intervals, ≈1415 s span. The scheduler
//! micro-benchmark (§5.1) uses the same shape with 1-byte files submitted
//! in batch. The astronomy model-validation workloads (§4.4) sweep a
//! *data locality* parameter from 1 to 30 (mean accesses per file).

use crate::config::{AccessSpec, ArrivalSpec, WorkloadConfig};
use crate::ids::{FileId, TaskId};
use crate::util::prng::{Pcg64, Zipf};
use crate::util::time::Micros;

/// One generated task.
#[derive(Debug, Clone)]
pub struct TaskSpec {
    /// Task id (stream position).
    pub id: TaskId,
    /// Submission time.
    pub arrival: Micros,
    /// File the task reads (θ(κ); the paper's workloads read one file).
    pub file: FileId,
    /// Index of the arrival-rate interval this task belongs to (slowdown
    /// accounting, Fig 14); 0 for non-staged arrivals.
    pub interval: u32,
}

/// A fully materialized workload.
#[derive(Debug, Clone)]
pub struct Workload {
    /// Tasks ordered by arrival time.
    pub tasks: Vec<TaskSpec>,
    /// Bytes per file.
    pub file_size_bytes: u64,
    /// Per-task compute time.
    pub compute: Micros,
    /// Arrival-rate stages: `(start, rate_tasks_per_s)` per interval
    /// (one entry for non-staged arrivals).
    pub stages: Vec<(Micros, f64)>,
    /// Number of distinct files actually referenced.
    pub distinct_files: u32,
}

impl Workload {
    /// Total workload bytes if every task read from scratch.
    pub fn total_bytes(&self) -> u64 {
        self.tasks.len() as u64 * self.file_size_bytes
    }

    /// Working-set size in bytes (distinct files × file size) — the |Ω|
    /// the caches must exceed for diffusion to reach steady state.
    pub fn working_set_bytes(&self) -> u64 {
        self.distinct_files as u64 * self.file_size_bytes
    }

    /// Arrival time of the last task.
    pub fn span(&self) -> Micros {
        self.tasks.last().map_or(Micros::ZERO, |t| t.arrival)
    }

    /// Arrival rate (tasks/s) in effect at time `t`.
    pub fn rate_at(&self, t: Micros) -> f64 {
        let mut rate = 0.0;
        for &(start, r) in &self.stages {
            if start <= t {
                rate = r;
            } else {
                break;
            }
        }
        rate
    }
}

/// The ideal workload execution time (s): infinite resources, zero-cost
/// communication — tasks finish as they arrive (§5.2.5's 1415 s).
pub fn ideal_execution_time_s(cfg: &WorkloadConfig) -> f64 {
    let arrivals = arrival_times(cfg);
    match arrivals.last() {
        Some(&(t, _)) => t.as_secs_f64() + cfg.compute_ms / 1e3,
        None => 0.0,
    }
}

/// Generate the full workload deterministically from `seed`.
pub fn generate(cfg: &WorkloadConfig, seed: u64) -> Workload {
    let mut rng = Pcg64::new(seed, 0x6f72_6b6c); // "workl" stream
    let arrivals = arrival_times(cfg);
    let files = access_sequence(cfg, arrivals.len(), &mut rng);
    debug_assert_eq!(arrivals.len(), files.len());

    let mut distinct = std::collections::HashSet::new();
    let tasks: Vec<TaskSpec> = arrivals
        .iter()
        .zip(&files)
        .enumerate()
        .map(|(i, (&(arrival, interval), &file))| {
            distinct.insert(file);
            TaskSpec {
                id: TaskId(i as u64),
                arrival,
                file,
                interval,
            }
        })
        .collect();

    Workload {
        stages: stages(cfg, &tasks),
        tasks,
        file_size_bytes: cfg.file_size_bytes,
        compute: Micros::from_secs_f64(cfg.compute_ms / 1e3),
        distinct_files: distinct.len() as u32,
    }
}

/// Arrival times plus interval index, per the configured process.
fn arrival_times(cfg: &WorkloadConfig) -> Vec<(Micros, u32)> {
    let n = cfg.num_tasks;
    match cfg.arrival {
        ArrivalSpec::Batch => (0..n).map(|_| (Micros::ZERO, 0)).collect(),
        ArrivalSpec::Constant(rate) => {
            let gap = 1e6 / rate;
            (0..n)
                .map(|i| (Micros((i as f64 * gap).round() as u64), 0))
                .collect()
        }
        ArrivalSpec::IncreasingRate {
            initial,
            factor,
            interval_s,
            max_rate,
        } => {
            // A_i = min(ceil(A_{i-1}·factor), max). Tasks are evenly
            // spaced within each interval; the last interval extends
            // until the task budget is exhausted (the paper's 24th
            // interval at 1000/s runs ~35 s).
            let mut out = Vec::with_capacity(n as usize);
            let mut rate = initial;
            let mut interval: u32 = 0;
            let mut t0 = 0.0f64;
            'outer: loop {
                let gap = 1.0 / rate;
                let capped = rate >= max_rate;
                let in_interval = if capped {
                    u64::MAX // run out the task budget at the cap
                } else {
                    (rate * interval_s).round() as u64
                };
                for j in 0..in_interval {
                    if out.len() as u64 >= n {
                        break 'outer;
                    }
                    let t = t0 + j as f64 * gap;
                    out.push((Micros::from_secs_f64(t), interval));
                }
                t0 += interval_s;
                rate = (rate * factor).ceil().min(max_rate);
                interval += 1;
            }
            out
        }
    }
}

/// Stage table `(start, rate)` for ideal-throughput plotting.
fn stages(cfg: &WorkloadConfig, tasks: &[TaskSpec]) -> Vec<(Micros, f64)> {
    match cfg.arrival {
        ArrivalSpec::Batch => vec![(Micros::ZERO, f64::INFINITY)],
        ArrivalSpec::Constant(rate) => vec![(Micros::ZERO, rate)],
        ArrivalSpec::IncreasingRate {
            initial,
            factor,
            interval_s,
            max_rate,
        } => {
            let last_interval = tasks.last().map_or(0, |t| t.interval);
            let mut out = Vec::new();
            let mut rate = initial;
            for i in 0..=last_interval {
                out.push((Micros::from_secs_f64(i as f64 * interval_s), rate));
                rate = (rate * factor).ceil().min(max_rate);
            }
            out
        }
    }
}

/// File-per-task sequence, per the configured access pattern.
fn access_sequence(cfg: &WorkloadConfig, n: usize, rng: &mut Pcg64) -> Vec<FileId> {
    match cfg.access {
        AccessSpec::Uniform => (0..n)
            .map(|_| FileId(rng.below(cfg.num_files as u64) as u32))
            .collect(),
        AccessSpec::Zipf(s) => {
            let z = Zipf::new(cfg.num_files as usize, s);
            (0..n).map(|_| FileId(z.sample(rng) as u32)).collect()
        }
        AccessSpec::Locality(l) => {
            // Each distinct file is accessed ⌈l⌉ or ⌊l⌋ times so the mean
            // is l; repeats are clustered in time (shuffled within a
            // bounded window) — the astronomy workloads' "locality"
            // (§4.4: 1 = one access per file … 30 = thirty).
            let distinct = ((n as f64 / l).ceil() as usize).clamp(1, cfg.num_files as usize);
            let mut seq = Vec::with_capacity(n);
            let mut remaining = n;
            for i in 0..distinct {
                // Distribute n accesses over `distinct` files as evenly
                // as integer arithmetic allows.
                let share = remaining / (distinct - i);
                for _ in 0..share {
                    seq.push(FileId((i % cfg.num_files as usize) as u32));
                }
                remaining -= share;
            }
            debug_assert_eq!(seq.len(), n);
            // Window shuffle: preserves coarse temporal locality while
            // breaking the degenerate exact-repeat pattern.
            let window = (l.ceil() as usize * 64).clamp(64, 8192).min(seq.len());
            let mut i = 0;
            while i < seq.len() {
                let end = (i + window).min(seq.len());
                rng.shuffle(&mut seq[i..end]);
                i = end;
            }
            seq
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::MB;

    fn paper_cfg() -> WorkloadConfig {
        WorkloadConfig::default()
    }

    #[test]
    fn paper_workload_span_matches_1415s() {
        let cfg = paper_cfg();
        let ideal = ideal_execution_time_s(&cfg);
        assert!(
            (ideal - 1415.0).abs() < 25.0,
            "ideal WET {ideal} ≉ paper's 1415 s"
        );
        let w = generate(&cfg, 1);
        assert_eq!(w.tasks.len(), 250_000);
        assert_eq!(w.file_size_bytes, 10 * MB);
        // 24 arrival intervals (§5.2).
        assert_eq!(w.stages.len(), 24, "stages: {}", w.stages.len());
    }

    #[test]
    fn arrivals_are_sorted_and_rates_increase() {
        let w = generate(&paper_cfg(), 7);
        for pair in w.tasks.windows(2) {
            assert!(pair[0].arrival <= pair[1].arrival);
            assert!(pair[0].interval <= pair[1].interval);
        }
        assert_eq!(w.rate_at(Micros::ZERO), 1.0);
        assert_eq!(w.rate_at(Micros::from_secs(61)), 2.0);
        assert_eq!(w.rate_at(Micros::from_secs(100_000)), 1000.0);
    }

    #[test]
    fn uniform_access_covers_files() {
        let mut cfg = paper_cfg();
        cfg.num_tasks = 50_000;
        cfg.num_files = 100;
        let w = generate(&cfg, 3);
        assert_eq!(w.distinct_files, 100);
        assert!(w.tasks.iter().all(|t| t.file.0 < 100));
    }

    #[test]
    fn determinism_same_seed() {
        let a = generate(&paper_cfg(), 5);
        let b = generate(&paper_cfg(), 5);
        assert_eq!(a.tasks.len(), b.tasks.len());
        for (x, y) in a.tasks.iter().zip(&b.tasks) {
            assert_eq!(x.file, y.file);
            assert_eq!(x.arrival, y.arrival);
        }
    }

    #[test]
    fn locality_controls_distinct_files() {
        let mut cfg = paper_cfg();
        cfg.num_tasks = 30_000;
        cfg.num_files = 1_000_000; // no cap
        cfg.access = AccessSpec::Locality(30.0);
        let w = generate(&cfg, 11);
        assert_eq!(w.distinct_files, 1000);
        // Mean accesses per file = 30.
        let mean = w.tasks.len() as f64 / w.distinct_files as f64;
        assert!((mean - 30.0).abs() < 0.5, "mean={mean}");

        cfg.access = AccessSpec::Locality(1.0);
        let w = generate(&cfg, 11);
        assert_eq!(w.distinct_files, 30_000);
    }

    #[test]
    fn zipf_access_is_skewed() {
        let mut cfg = paper_cfg();
        cfg.num_tasks = 20_000;
        cfg.num_files = 1000;
        cfg.access = AccessSpec::Zipf(1.2);
        let w = generate(&cfg, 13);
        let head = w.tasks.iter().filter(|t| t.file.0 < 100).count();
        assert!(head > w.tasks.len() / 2);
    }

    #[test]
    fn batch_and_constant_arrivals() {
        let mut cfg = paper_cfg();
        cfg.num_tasks = 100;
        cfg.arrival = ArrivalSpec::Batch;
        let w = generate(&cfg, 1);
        assert!(w.tasks.iter().all(|t| t.arrival == Micros::ZERO));

        cfg.arrival = ArrivalSpec::Constant(10.0);
        let w = generate(&cfg, 1);
        assert_eq!(w.span(), Micros::from_secs_f64(9.9));
    }

    #[test]
    fn working_set_math() {
        let mut cfg = paper_cfg();
        cfg.num_tasks = 1000;
        let w = generate(&cfg, 1);
        assert_eq!(
            w.working_set_bytes(),
            w.distinct_files as u64 * cfg.file_size_bytes
        );
        assert_eq!(w.total_bytes(), 1000 * cfg.file_size_bytes);
    }
}
