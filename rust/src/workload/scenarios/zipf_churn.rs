//! Zipf/heavy-tail popularity with hot-set churn.
//!
//! File popularity follows a Zipf law over *ranks*; a seeded permutation
//! maps ranks to concrete files. Every `churn_interval_s` the head of
//! the permutation (a `churn_fraction` of the catalog) is rewired to
//! random files, so the hot set rotates while the popularity *shape*
//! stays fixed — the pattern that defeats pure-LFU caching and skews
//! dominant-file shard routing over time.

use crate::config::WorkloadConfig;
use crate::ids::{FileId, TaskId};
use crate::util::prng::{Pcg64, Zipf};
use crate::util::time::Micros;
use crate::workload::{scenarios::finish, TaskSpec, Workload};

/// Generate the churned-Zipf stream: constant-rate arrivals, one input
/// per task drawn Zipf-by-rank through the churned permutation.
pub fn generate(
    cfg: &WorkloadConfig,
    s: f64,
    churn_interval_s: f64,
    churn_fraction: f64,
    rate: f64,
    seed: u64,
) -> Workload {
    let mut rng = Pcg64::new(seed, 0x7a69_7063); // "zipc" stream
    let n = cfg.num_tasks;
    let nf = cfg.num_files as usize;
    let z = Zipf::new(nf, s);
    let mut perm: Vec<u32> = (0..nf as u32).collect();
    rng.shuffle(&mut perm);

    let gap = 1e6 / rate;
    let epoch_us = (churn_interval_s * 1e6).round().max(1.0) as u64;
    let churn = ((churn_fraction * nf as f64).ceil() as usize).min(nf);

    let mut tasks = Vec::with_capacity(n as usize);
    let mut stages = vec![(Micros::ZERO, rate)];
    let mut epoch: u32 = 0;
    for i in 0..n {
        let arrival = Micros((i as f64 * gap).round() as u64);
        while arrival.0 >= (epoch as u64 + 1) * epoch_us {
            epoch += 1;
            stages.push((Micros(epoch as u64 * epoch_us), rate));
            // Rewire the hot head: each of the top `churn` ranks swaps
            // with a uniformly random catalog slot.
            for r in 0..churn {
                let j = rng.below(nf as u64) as usize;
                perm.swap(r, j);
            }
        }
        let rank = z.sample(&mut rng);
        tasks.push(TaskSpec {
            id: TaskId(i),
            arrival,
            inputs: vec![FileId(perm[rank])],
            outputs: Vec::new(),
            deps: Vec::new(),
            interval: epoch,
        });
    }
    finish(cfg, tasks, stages)
}
