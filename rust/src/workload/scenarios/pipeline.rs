//! Pilot-Data-style multi-stage pipelines.
//!
//! Each pipeline is a fan-in DAG (1301.6228's compute/data affinity
//! chains): stage 0 runs `fanin` tasks reading raw catalog files, each
//! producing one intermediate file; stage *k* halves the width and each
//! task consumes a partition of the previous stage's outputs (dependency
//! edges gate its submission on their completion) plus one shared
//! reference file. Locality decisions compound across stages: an
//! intermediate produced on one node is cheapest to consume there.
//!
//! The pipeline count is derived from `WorkloadConfig::num_tasks`, so
//! `--quick` scaling shrinks the stream without changing its shape.

use crate::config::WorkloadConfig;
use crate::ids::{FileId, TaskId};
use crate::util::prng::Pcg64;
use crate::util::time::Micros;
use crate::workload::{scenarios::finish, TaskSpec, Workload};

/// Generate the pipeline stream.
pub fn generate(
    cfg: &WorkloadConfig,
    stages_n: u32,
    fanin: u32,
    submit_gap_s: f64,
    seed: u64,
) -> Workload {
    let mut rng = Pcg64::new(seed, 0x7069_7065); // "pipe" stream
    let fanin = fanin.max(1);
    let widths: Vec<u32> = (0..stages_n.max(1)).map(|k| (fanin >> k).max(1)).collect();
    let per: u64 = widths.iter().map(|&w| w as u64).sum();
    let npipes = (cfg.num_tasks / per).max(1);
    let nf = cfg.num_files as u64;

    let mut tasks: Vec<TaskSpec> = Vec::with_capacity((npipes * per) as usize);
    let mut next_out = cfg.num_files; // intermediates live past the raw catalog
    for p in 0..npipes {
        let t0 = Micros::from_secs_f64(p as f64 * submit_gap_s);
        let mut prev: Vec<(TaskId, FileId)> = Vec::new();
        for (k, &w) in widths.iter().enumerate() {
            let mut cur = Vec::with_capacity(w as usize);
            for j in 0..w {
                let id = TaskId(tasks.len() as u64);
                let mut inputs = Vec::new();
                let mut deps = Vec::new();
                if k == 0 {
                    inputs.push(FileId(rng.below(nf) as u32));
                    if rng.chance(0.5) {
                        inputs.push(FileId(rng.below(nf) as u32));
                    }
                } else {
                    // Consume a partition of the previous stage's
                    // outputs; the producing tasks gate this one.
                    for (i, &(dep, out)) in prev.iter().enumerate() {
                        if i as u32 % w == j {
                            inputs.push(out);
                            deps.push(dep);
                        }
                    }
                    // Plus one shared reference file from the catalog.
                    inputs.push(FileId(rng.below(nf) as u32));
                }
                let out = FileId(next_out);
                next_out += 1;
                tasks.push(TaskSpec {
                    id,
                    arrival: t0,
                    inputs,
                    outputs: vec![out],
                    deps,
                    interval: 0,
                });
                cur.push((id, out));
            }
            prev = cur;
        }
    }
    // One stage entry: the long-run submission rate.
    let stage_tbl = vec![(Micros::ZERO, per as f64 / submit_gap_s.max(1e-9))];
    finish(cfg, tasks, stage_tbl)
}
