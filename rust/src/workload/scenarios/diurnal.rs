//! Diurnal multi-user traffic with flash crowds.
//!
//! A simulated population of users submits tasks at a rate that follows
//! a day/night sinusoid between `trough_rate` and `peak_rate` with
//! period `period_s`. Seeded flash-crowd windows multiply the
//! instantaneous rate by `flash_factor`. Each user owns a small
//! favourite file set; 30% of accesses instead hit a shared Zipf head,
//! so caches see both per-user locality and global skew. The rate
//! schedule stresses the provisioner's allocate/release hysteresis the
//! way the paper's monotone §5.2 ramp cannot.

use crate::config::WorkloadConfig;
use crate::ids::{FileId, TaskId};
use crate::util::prng::{Pcg64, Zipf};
use crate::util::time::Micros;
use crate::workload::{scenarios::finish, TaskSpec, Workload};

/// Files per simulated user's favourite set.
const FAVES_PER_USER: usize = 16;
/// Fraction of accesses that hit the shared Zipf head instead of the
/// submitting user's favourites.
const SHARED_HEAD_P: f64 = 0.3;

/// Generate the diurnal stream: 1 s rate slots with fractional carry,
/// arrivals spread evenly within each slot.
#[allow(clippy::too_many_arguments)]
pub fn generate(
    cfg: &WorkloadConfig,
    users: u32,
    period_s: f64,
    peak_rate: f64,
    trough_rate: f64,
    flash_crowds: u32,
    flash_factor: f64,
    flash_duration_s: f64,
    seed: u64,
) -> Workload {
    let mut rng = Pcg64::new(seed, 0x6469_7572); // "diur" stream
    let n = cfg.num_tasks as usize;
    let nf = cfg.num_files as u64;
    let users = users.max(1) as usize;

    let faves: Vec<Vec<FileId>> = (0..users)
        .map(|_| {
            (0..FAVES_PER_USER.min(nf as usize))
                .map(|_| FileId(rng.below(nf) as u32))
                .collect()
        })
        .collect();
    let head = Zipf::new(nf as usize, 1.1);

    // Flash-crowd windows land inside the stream's expected duration so
    // small (--quick) streams still see them.
    let mean_rate = 0.5 * (peak_rate + trough_rate);
    let est_duration_s = n as f64 / mean_rate.max(1e-9);
    let mut flashes: Vec<(f64, f64)> = (0..flash_crowds)
        .map(|_| {
            let t0 = rng.range_f64(0.0, (0.6 * est_duration_s).max(1.0));
            (t0, t0 + flash_duration_s)
        })
        .collect();
    flashes.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());

    let mut tasks = Vec::with_capacity(n);
    let mut stages = Vec::new();
    let mut acc = 0.0f64;
    let mut slot: u64 = 0;
    while tasks.len() < n {
        let t = slot as f64;
        let phase = (t % period_s) / period_s;
        let mut r = trough_rate
            + (peak_rate - trough_rate) * 0.5 * (1.0 - (phase * std::f64::consts::TAU).cos());
        if flashes.iter().any(|&(a, b)| t >= a && t < b) {
            r *= flash_factor;
        }
        stages.push((Micros::from_secs(slot), r));
        acc += r;
        let emit = (acc.floor() as usize).min(n - tasks.len());
        acc -= acc.floor();
        for j in 0..emit {
            let arrival = Micros::from_secs_f64(t + (j as f64 + 0.5) / emit as f64);
            let user = rng.below(users as u64) as usize;
            let file = if rng.chance(SHARED_HEAD_P) {
                FileId(head.sample(&mut rng) as u32)
            } else {
                *rng.choose(&faves[user])
            };
            tasks.push(TaskSpec {
                id: TaskId(tasks.len() as u64),
                arrival,
                inputs: vec![file],
                outputs: Vec::new(),
                deps: Vec::new(),
                interval: slot as u32,
            });
        }
        slot += 1;
    }
    finish(cfg, tasks, stages)
}
