//! DIANA-style bulk batch submission.
//!
//! Bulk scheduling (cs/0602026) submits whole job collections at once,
//! each collection sharing a dataset. Here `batches` batches arrive
//! `batch_gap_s` apart; every task of a batch lands at the same instant
//! (the legacy `ArrivalSpec::Batch` shape, repeated), and each batch
//! reads uniformly from its own contiguous window of the file catalog —
//! the at-once queue pressure and dataset reuse that stress the
//! wait-queue, notify, and pickup paths.

use crate::config::WorkloadConfig;
use crate::ids::{FileId, TaskId};
use crate::util::prng::Pcg64;
use crate::util::time::Micros;
use crate::workload::{scenarios::finish, TaskSpec, Workload};

/// Generate the bulk-batch stream.
pub fn generate(cfg: &WorkloadConfig, batches: u32, batch_gap_s: f64, seed: u64) -> Workload {
    let mut rng = Pcg64::new(seed, 0x62_756c_6b); // "bulk" stream
    let b = batches.max(1) as u64;
    let n = cfg.num_tasks;
    let nf = cfg.num_files as u64;
    let window = (nf / b).max(1);

    let mut tasks = Vec::with_capacity(n as usize);
    let mut stages = Vec::with_capacity(b as usize);
    let mut remaining = n;
    for bi in 0..b {
        let share = remaining / (b - bi);
        let start = Micros::from_secs_f64(bi as f64 * batch_gap_s);
        // At-once submission: within the batch the instantaneous rate is
        // unbounded, matching the legacy batch stage convention.
        stages.push((start, f64::INFINITY));
        let w0 = rng.below(nf - window + 1);
        for _ in 0..share {
            let file = FileId((w0 + rng.below(window)) as u32);
            tasks.push(TaskSpec {
                id: TaskId(tasks.len() as u64),
                arrival: start,
                inputs: vec![file],
                outputs: Vec::new(),
                deps: Vec::new(),
                interval: bi as u32,
            });
        }
        remaining -= share;
    }
    finish(cfg, tasks, stages)
}
