//! The workload scenario library — seeded generators beyond the paper's
//! uniform-random stream (catalog and TOML schema in `docs/WORKLOADS.md`).
//!
//! Four families, each a [`ScenarioSpec`] variant with its own PRNG
//! stream constant so families never share draws:
//!
//! | family | models | stresses |
//! |---|---|---|
//! | [`zipf_churn`] | heavy-tailed popularity whose hot set rotates | cache eviction + shard skew |
//! | [`diurnal`] | a user population with day/night cycles and flash crowds | provisioning hysteresis |
//! | [`bulk`] | DIANA-style at-once batch submission over shared datasets | queue + notify paths |
//! | [`pipeline`] | Pilot-Data-style multi-stage pipelines (outputs feed inputs) | dependency gating + locality compounding |
//!
//! Determinism contract: a scenario workload is a pure function of
//! `(WorkloadConfig, ScenarioSpec, seed)`. Same seed → bit-identical
//! stream (asserted via [`Workload::fingerprint`] in the golden tests
//! below); different seeds diverge. Generators draw from
//! [`Pcg64`](crate::util::prng::Pcg64) streams distinct from the legacy
//! generator's, so adding a scenario can never perturb the paper
//! workloads.

pub mod bulk;
pub mod diurnal;
pub mod pipeline;
pub mod zipf_churn;

use super::{TaskSpec, Workload};
use crate::config::{ScenarioSpec, WorkloadConfig};
use crate::util::time::Micros;

/// Generate a scenario workload — the dispatch behind
/// [`workload::generate`](super::generate).
pub fn generate(cfg: &WorkloadConfig, spec: &ScenarioSpec, seed: u64) -> Workload {
    match *spec {
        ScenarioSpec::ZipfChurn {
            s,
            churn_interval_s,
            churn_fraction,
            rate,
        } => zipf_churn::generate(cfg, s, churn_interval_s, churn_fraction, rate, seed),
        ScenarioSpec::Diurnal {
            users,
            period_s,
            peak_rate,
            trough_rate,
            flash_crowds,
            flash_factor,
            flash_duration_s,
        } => diurnal::generate(
            cfg,
            users,
            period_s,
            peak_rate,
            trough_rate,
            flash_crowds,
            flash_factor,
            flash_duration_s,
            seed,
        ),
        ScenarioSpec::BulkBatch {
            batches,
            batch_gap_s,
        } => bulk::generate(cfg, batches, batch_gap_s, seed),
        ScenarioSpec::Pipeline {
            stages,
            fanin,
            submit_gap_s,
        } => pipeline::generate(cfg, stages, fanin, submit_gap_s, seed),
    }
}

/// Assemble a [`Workload`] from generated tasks + stage table, deriving
/// the distinct-input count and dependency-edge total.
pub(crate) fn finish(
    cfg: &WorkloadConfig,
    tasks: Vec<TaskSpec>,
    stages: Vec<(Micros, f64)>,
) -> Workload {
    let mut distinct = std::collections::HashSet::new();
    let mut dep_edges = 0u64;
    for t in &tasks {
        for f in &t.inputs {
            distinct.insert(*f);
        }
        dep_edges += t.deps.len() as u64;
    }
    Workload {
        stages,
        tasks,
        file_size_bytes: cfg.file_size_bytes,
        compute: Micros::from_secs_f64(cfg.compute_ms / 1e3),
        distinct_files: distinct.len() as u32,
        dep_edges,
    }
}

#[cfg(test)]
mod tests {
    use crate::config::{ScenarioSpec, WorkloadConfig};
    use crate::util::units::MB;
    use crate::workload::generate;

    fn cfg_for(spec: ScenarioSpec) -> WorkloadConfig {
        let mut cfg = WorkloadConfig::default();
        cfg.num_tasks = 4_000;
        cfg.num_files = 400;
        cfg.file_size_bytes = MB;
        cfg.compute_ms = 10.0;
        cfg.scenario = Some(spec);
        cfg
    }

    /// Golden determinism: same seed → identical stream fingerprint,
    /// different seed → different fingerprint — for every family.
    #[test]
    fn golden_determinism_per_scenario() {
        for name in ScenarioSpec::CATALOG {
            let spec = ScenarioSpec::preset(name).expect("catalog name");
            let cfg = cfg_for(spec);
            let a = generate(&cfg, 42);
            let b = generate(&cfg, 42);
            assert_eq!(
                a.fingerprint(),
                b.fingerprint(),
                "{name}: same seed must reproduce the stream"
            );
            let c = generate(&cfg, 43);
            assert_ne!(
                a.fingerprint(),
                c.fingerprint(),
                "{name}: different seeds must diverge"
            );
            assert_eq!(a.tasks.len() as u64, a.tasks.last().unwrap().id.0 + 1);
        }
    }

    #[test]
    fn all_scenarios_emit_sorted_well_formed_streams() {
        for name in ScenarioSpec::CATALOG {
            let spec = ScenarioSpec::preset(name).expect("catalog name");
            let cfg = cfg_for(spec);
            let w = generate(&cfg, 7);
            assert!(!w.tasks.is_empty(), "{name}: empty stream");
            for (i, t) in w.tasks.iter().enumerate() {
                assert_eq!(t.id.0, i as u64, "{name}: id must equal index");
                assert!(!t.inputs.is_empty(), "{name}: task without inputs");
                assert!(
                    (t.interval as usize) < w.stages.len(),
                    "{name}: interval must index stages"
                );
                for d in &t.deps {
                    assert!(d.0 < t.id.0, "{name}: dep edge must point backwards");
                }
                if i > 0 {
                    assert!(
                        w.tasks[i - 1].arrival <= t.arrival,
                        "{name}: arrivals must be sorted"
                    );
                }
            }
            assert!(w.distinct_files > 0);
        }
    }

    #[test]
    fn zipf_churn_concentrates_and_rotates_the_hot_set() {
        let spec = ScenarioSpec::preset("zipf-churn").unwrap();
        let cfg = cfg_for(spec);
        let w = generate(&cfg, 11);
        assert_eq!(w.dep_edges, 0);
        // Heavy tail *within an epoch*: the top-10% of files carry well
        // over half of the epoch's accesses (churn rotates the hot set
        // between epochs, so the global histogram is flatter).
        let epoch0: Vec<u32> = w
            .tasks
            .iter()
            .filter(|t| t.interval == 0)
            .map(|t| t.inputs[0].0)
            .collect();
        let mut counts = vec![0u32; cfg.num_files as usize];
        for f in &epoch0 {
            counts[*f as usize] += 1;
        }
        counts.sort_unstable_by(|a, b| b.cmp(a));
        let head: u32 = counts.iter().take(cfg.num_files as usize / 10).sum();
        assert!(
            head as usize > epoch0.len() / 2,
            "head carries {head} of {}",
            epoch0.len()
        );
        // Churn: the most popular file differs across epochs for at
        // least one epoch boundary.
        let last_epoch = w.tasks.last().unwrap().interval;
        assert!(last_epoch >= 1, "stream must span multiple churn epochs");
        let top_of = |epoch: u32| {
            let mut c = vec![0u32; cfg.num_files as usize];
            for t in w.tasks.iter().filter(|t| t.interval == epoch) {
                c[t.inputs[0].0 as usize] += 1;
            }
            c.iter().enumerate().max_by_key(|&(_, n)| n).unwrap().0
        };
        let tops: Vec<usize> = (0..=last_epoch).map(top_of).collect();
        assert!(
            tops.windows(2).any(|p| p[0] != p[1]),
            "hot set never churned: {tops:?}"
        );
    }

    #[test]
    fn diurnal_rates_cycle_and_flash_crowds_spike() {
        let spec = ScenarioSpec::preset("diurnal").unwrap();
        let cfg = cfg_for(spec);
        let w = generate(&cfg, 5);
        let rates: Vec<f64> = w.stages.iter().map(|&(_, r)| r).collect();
        let lo = rates.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = rates.iter().cloned().fold(0.0, f64::max);
        assert!(hi > 2.0 * lo, "no diurnal swing: lo={lo} hi={hi}");
        if let Some(ScenarioSpec::Diurnal {
            peak_rate,
            flash_factor,
            ..
        }) = cfg.scenario
        {
            // A flash crowd pushes past the plain diurnal peak.
            assert!(
                hi > peak_rate,
                "no flash crowd spike: hi={hi} peak={peak_rate} factor={flash_factor}"
            );
        }
    }

    #[test]
    fn bulk_batches_arrive_at_once() {
        let spec = ScenarioSpec::preset("bulk-batch").unwrap();
        let cfg = cfg_for(spec);
        let w = generate(&cfg, 3);
        let mut arrivals: Vec<u64> = w.tasks.iter().map(|t| t.arrival.0).collect();
        arrivals.dedup();
        if let Some(ScenarioSpec::BulkBatch { batches, .. }) = cfg.scenario {
            assert_eq!(arrivals.len(), batches as usize, "one arrival instant per batch");
        }
        // Each batch reads from a narrow dataset window.
        for interval in 0..arrivals.len() as u32 {
            let files: std::collections::HashSet<u32> = w
                .tasks
                .iter()
                .filter(|t| t.interval == interval)
                .map(|t| t.inputs[0].0)
                .collect();
            assert!(
                files.len() <= (cfg.num_files as usize) / 4,
                "batch {interval} touches {} files",
                files.len()
            );
        }
    }

    #[test]
    fn pipeline_outputs_feed_downstream_inputs() {
        let spec = ScenarioSpec::preset("pipeline").unwrap();
        let cfg = cfg_for(spec);
        let w = generate(&cfg, 9);
        assert!(w.dep_edges > 0, "pipelines must carry dependency edges");
        let mut produced = std::collections::HashMap::new();
        for t in &w.tasks {
            for o in &t.outputs {
                assert!(
                    o.0 >= cfg.num_files,
                    "outputs live past the raw catalog: {o:?}"
                );
                assert!(
                    produced.insert(*o, t.id).is_none(),
                    "output {o:?} produced twice"
                );
            }
        }
        // Every dep edge is mirrored by an input that the dep produced.
        let mut gated = 0u64;
        for t in &w.tasks {
            for d in &t.deps {
                assert!(
                    t.inputs.iter().any(|f| produced.get(f) == Some(d)),
                    "dep {d:?} of {:?} has no matching produced input",
                    t.id
                );
                gated += 1;
            }
        }
        assert_eq!(gated, w.dep_edges);
        // Dependencies stretch the ideal WET past the bare span.
        assert!(w.ideal_execution_time_s() > w.span().as_secs_f64() + 0.0105);
    }
}
