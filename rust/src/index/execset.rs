//! Fixed-width executor bitsets.
//!
//! Executor ids are small dense integers (the registry hands them out
//! sequentially; clusters are at most a few hundred nodes), so a holder
//! set is a handful of `u64` words: membership is a mask test, replica
//! counting is a popcount, and set iteration walks trailing-zero bits.
//! This replaces the `BTreeSet<ExecutorId>` holder sets the scheduler
//! §Perf profile showed as pointer-chasing hot (one probe per window
//! entry before the inverted pending index, one per candidate after).
//!
//! Iteration order is ascending executor id — the same order the old
//! sorted sets produced — so every tie-break downstream (notify scoring,
//! peer selection) is bit-identical to the pre-bitset implementation.

use crate::ids::ExecutorId;

const WORD_BITS: usize = 64;

/// A set of executors as a growable bitmask with a cached population
/// count (`len` is O(1)).
#[derive(Debug, Clone, Default)]
pub struct ExecSet {
    words: Vec<u64>,
    count: u32,
}

/// Equality is by membership, not representation: `words` never shrinks,
/// so a set that once held a high id keeps trailing zero words a fresh
/// structurally-equal set lacks.
impl PartialEq for ExecSet {
    fn eq(&self, other: &ExecSet) -> bool {
        if self.count != other.count {
            return false;
        }
        let (short, long) = if self.words.len() <= other.words.len() {
            (&self.words, &other.words)
        } else {
            (&other.words, &self.words)
        };
        short.iter().zip(long.iter()).all(|(a, b)| a == b)
            && long[short.len()..].iter().all(|&w| w == 0)
    }
}

impl Eq for ExecSet {}

impl ExecSet {
    /// Empty set.
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    fn split(e: ExecutorId) -> (usize, u64) {
        let idx = e.0 as usize;
        (idx / WORD_BITS, 1u64 << (idx % WORD_BITS))
    }

    /// Insert `e`; returns true if it was not already present.
    pub fn insert(&mut self, e: ExecutorId) -> bool {
        let (w, mask) = Self::split(e);
        if w >= self.words.len() {
            self.words.resize(w + 1, 0);
        }
        let word = &mut self.words[w];
        if *word & mask != 0 {
            return false;
        }
        *word |= mask;
        self.count += 1;
        true
    }

    /// Remove `e`; returns true if it was present. The word array never
    /// shrinks (sets churn around a stable cluster width).
    pub fn remove(&mut self, e: ExecutorId) -> bool {
        let (w, mask) = Self::split(e);
        match self.words.get_mut(w) {
            Some(word) if *word & mask != 0 => {
                *word &= !mask;
                self.count -= 1;
                true
            }
            _ => false,
        }
    }

    /// Membership test — O(1).
    #[inline]
    pub fn contains(&self, e: ExecutorId) -> bool {
        let (w, mask) = Self::split(e);
        self.words.get(w).is_some_and(|word| word & mask != 0)
    }

    /// Number of members — O(1) (cached popcount).
    #[inline]
    pub fn len(&self) -> usize {
        self.count as usize
    }

    /// True when no executor is in the set.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Smallest member, if any.
    pub fn first(&self) -> Option<ExecutorId> {
        self.iter().next()
    }

    /// Remove every member, keeping the word allocation (scratch reuse).
    pub fn clear(&mut self) {
        self.words.fill(0);
        self.count = 0;
    }

    /// Add every member of `other` — a word-wise OR, so the cost is
    /// O(words), independent of how many members either set has. This is
    /// the notify-memo union primitive: the candidate executors of a
    /// multi-file head task are the union of its files' holder sets, and
    /// building that union must not walk holders one by one (see
    /// [`crate::coordinator::pending::PendingIndex::head_ranked`]).
    pub fn union_with(&mut self, other: &ExecSet) {
        if other.words.len() > self.words.len() {
            self.words.resize(other.words.len(), 0);
        }
        let mut count = 0u32;
        for (i, w) in self.words.iter_mut().enumerate() {
            *w |= other.words.get(i).copied().unwrap_or(0);
            count += w.count_ones();
        }
        self.count = count;
    }

    /// Members shared with `other` — a word-wise AND + popcount.
    pub fn intersection_count(&self, other: &ExecSet) -> usize {
        self.words
            .iter()
            .zip(&other.words)
            .map(|(a, b)| (a & b).count_ones() as usize)
            .sum()
    }

    /// Bytes of heap behind the word array (capacity-based; feeds the
    /// `scale/peak_table_bytes` table estimate).
    pub fn heap_bytes(&self) -> usize {
        self.words.capacity() * std::mem::size_of::<u64>()
    }

    /// Iterate members in ascending id order.
    pub fn iter(&self) -> ExecSetIter<'_> {
        ExecSetIter {
            words: &self.words,
            word_idx: 0,
            current: self.words.first().copied().unwrap_or(0),
        }
    }
}

impl<'a> IntoIterator for &'a ExecSet {
    type Item = ExecutorId;
    type IntoIter = ExecSetIter<'a>;

    fn into_iter(self) -> ExecSetIter<'a> {
        self.iter()
    }
}

impl FromIterator<ExecutorId> for ExecSet {
    fn from_iter<T: IntoIterator<Item = ExecutorId>>(iter: T) -> Self {
        let mut s = ExecSet::new();
        for e in iter {
            s.insert(e);
        }
        s
    }
}

/// Ascending-order iterator over an [`ExecSet`].
pub struct ExecSetIter<'a> {
    words: &'a [u64],
    word_idx: usize,
    current: u64,
}

impl Iterator for ExecSetIter<'_> {
    type Item = ExecutorId;

    fn next(&mut self) -> Option<ExecutorId> {
        while self.current == 0 {
            self.word_idx += 1;
            if self.word_idx >= self.words.len() {
                return None;
            }
            self.current = self.words[self.word_idx];
        }
        let bit = self.current.trailing_zeros();
        self.current &= self.current - 1; // clear lowest set bit
        Some(ExecutorId((self.word_idx * WORD_BITS) as u32 + bit))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_remove_contains_len() {
        let mut s = ExecSet::new();
        assert!(s.is_empty());
        assert!(s.insert(ExecutorId(3)));
        assert!(!s.insert(ExecutorId(3)));
        assert!(s.insert(ExecutorId(200)));
        assert_eq!(s.len(), 2);
        assert!(s.contains(ExecutorId(3)));
        assert!(s.contains(ExecutorId(200)));
        assert!(!s.contains(ExecutorId(4)));
        assert!(s.remove(ExecutorId(3)));
        assert!(!s.remove(ExecutorId(3)));
        assert!(!s.remove(ExecutorId(9999)));
        assert_eq!(s.len(), 1);
        assert_eq!(s.first(), Some(ExecutorId(200)));
    }

    #[test]
    fn equality_ignores_trailing_zero_words() {
        let mut a = ExecSet::new();
        a.insert(ExecutorId(64));
        a.remove(ExecutorId(64));
        assert_eq!(a, ExecSet::new(), "empty sets must compare equal");
        let mut b = ExecSet::new();
        b.insert(ExecutorId(3));
        b.insert(ExecutorId(200));
        b.remove(ExecutorId(200));
        let c: ExecSet = [ExecutorId(3)].into_iter().collect();
        assert_eq!(b, c);
        assert_ne!(c, ExecSet::new());
    }

    #[test]
    fn iterates_in_ascending_order() {
        let ids = [130u32, 0, 63, 64, 5, 129];
        let s: ExecSet = ids.iter().map(|&i| ExecutorId(i)).collect();
        let got: Vec<u32> = s.iter().map(|e| e.0).collect();
        assert_eq!(got, vec![0, 5, 63, 64, 129, 130]);
    }

    #[test]
    fn union_with_ors_words_and_recounts() {
        let mut a: ExecSet = [0u32, 5, 64].iter().map(|&i| ExecutorId(i)).collect();
        let b: ExecSet = [5u32, 6, 200].iter().map(|&i| ExecutorId(i)).collect();
        a.union_with(&b);
        let got: Vec<u32> = a.iter().map(|e| e.0).collect();
        assert_eq!(got, vec![0, 5, 6, 64, 200]);
        assert_eq!(a.len(), 5);
        // Union with a shorter set must keep the long tail intact.
        let c: ExecSet = [1u32].iter().map(|&i| ExecutorId(i)).collect();
        a.union_with(&c);
        assert_eq!(a.len(), 6);
        assert!(a.contains(ExecutorId(200)));
    }

    #[test]
    fn clear_empties_but_keeps_capacity() {
        let mut s: ExecSet = [3u32, 190].iter().map(|&i| ExecutorId(i)).collect();
        s.clear();
        assert!(s.is_empty());
        assert_eq!(s.iter().count(), 0);
        assert_eq!(s, ExecSet::new(), "cleared set equals a fresh one");
        assert!(s.insert(ExecutorId(7)));
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn intersection_count_is_popcount_and() {
        let a: ExecSet = [0u32, 1, 64, 65].iter().map(|&i| ExecutorId(i)).collect();
        let b: ExecSet = [1u32, 64, 200].iter().map(|&i| ExecutorId(i)).collect();
        assert_eq!(a.intersection_count(&b), 2);
        assert_eq!(b.intersection_count(&a), 2);
        assert_eq!(a.intersection_count(&ExecSet::new()), 0);
    }

    #[test]
    fn matches_btreeset_under_random_ops() {
        use crate::util::proptest::{property, Gen};
        use std::collections::BTreeSet;
        property("execset vs btreeset", 100, |g: &mut Gen| {
            let mut fast = ExecSet::new();
            let mut slow: BTreeSet<ExecutorId> = BTreeSet::new();
            for _ in 0..g.usize_in(1..200) {
                let e = ExecutorId(g.u64_in(0..300) as u32);
                if g.bool(0.6) {
                    if fast.insert(e) != slow.insert(e) {
                        return Err(format!("insert({e}) disagreed"));
                    }
                } else if fast.remove(e) != slow.remove(&e) {
                    return Err(format!("remove({e}) disagreed"));
                }
                if fast.len() != slow.len() {
                    return Err(format!("len {} != {}", fast.len(), slow.len()));
                }
                let a: Vec<ExecutorId> = fast.iter().collect();
                let b: Vec<ExecutorId> = slow.iter().copied().collect();
                if a != b {
                    return Err(format!("order {a:?} != {b:?}"));
                }
            }
            Ok(())
        });
    }
}
