//! Centralized data-location index (§3.1.1, §3.2).
//!
//! The dispatcher keeps a *centralized index* recording where every cached
//! data object lives, maintained loosely coherent with executor caches via
//! update messages. The scheduler's two lookups are exactly the paper's
//! two maps:
//!
//! * `I_map` — file logical name → set of executors caching it, stored as
//!   an [`ExecSet`] **bitset** ([`LocationIndex::holders`]): membership is
//!   a mask test, the replication factor is a cached popcount, and holder
//!   iteration (notify scoring, peer selection) walks set bits in
//!   ascending id order — the same deterministic order the pre-bitset
//!   `BTreeSet` produced;
//! * `E_map` — executor name → hash set of file names it caches
//!   ([`LocationIndex::cached_at`]): O(1) hit-probes for the scheduler's
//!   cache-hit scoring (§Perf iteration 3 replaced the per-probe
//!   `BTreeSet` descent with a single hash lookup).
//!
//! Both directions are kept mutually consistent by construction (asserted
//! by a property test). Per-file holder probes ([`LocationIndex::holds`])
//! and replica counts ([`LocationIndex::replication`]) are O(1), matching
//! the paper's O(|θ(κ)| + replication + min(|Q|, W)) scheduling-cost
//! argument.
//!
//! The bitset representation is also what makes the §Perf iteration 4
//! notify memo cheap: the candidate executors of a multi-file head task
//! are the word-wise **union** of its files' holder sets
//! ([`ExecSet::union_with`]), built without iterating holders one by
//! one. Every mutation here must be mirrored into
//! [`crate::coordinator::pending::PendingIndex`] by the caller (the
//! engines' single mutation site is `coordinator::resolve_access` plus
//! executor deregistration) — the pending index's validity epochs hang
//! off that discipline.

pub mod execset;

pub use execset::ExecSet;

use crate::ids::{ExecutorId, FileId};
use std::collections::{HashMap, HashSet};

/// The dispatcher's central file-location index (`I_map` + `E_map`).
#[derive(Debug, Default)]
pub struct LocationIndex {
    /// I_map: file → executors holding it (bitset).
    holders: HashMap<FileId, ExecSet>,
    /// E_map: executor → files it holds.
    cached: HashMap<ExecutorId, HashSet<FileId>>,
    /// Total (file, executor) replica pairs — cheap global replication stat.
    replicas: u64,
}

impl LocationIndex {
    /// Empty index.
    pub fn new() -> Self {
        Self::default()
    }

    /// Register an executor with an empty cache (no-op if present).
    pub fn register_executor(&mut self, executor: ExecutorId) {
        self.cached.entry(executor).or_default();
    }

    /// Remove an executor and all its entries (deregistration / release by
    /// the provisioner). Returns the files it held, for accounting.
    pub fn deregister_executor(&mut self, executor: ExecutorId) -> Vec<FileId> {
        let files: Vec<FileId> = self
            .cached
            .remove(&executor)
            .map(|set| set.into_iter().collect())
            .unwrap_or_default();
        for &f in &files {
            if let Some(set) = self.holders.get_mut(&f) {
                if set.remove(executor) {
                    self.replicas -= 1;
                }
                if set.is_empty() {
                    self.holders.remove(&f);
                }
            }
        }
        files
    }

    /// Record that `executor` now caches `file` (an executor cache-content
    /// update message). One probe per map: both sides use the entry API.
    pub fn add(&mut self, file: FileId, executor: ExecutorId) {
        let inserted = self.holders.entry(file).or_default().insert(executor);
        self.cached.entry(executor).or_default().insert(file);
        if inserted {
            self.replicas += 1;
        }
    }

    /// Record that `executor` evicted `file`.
    pub fn remove(&mut self, file: FileId, executor: ExecutorId) {
        if let Some(set) = self.holders.get_mut(&file) {
            if set.remove(executor) {
                self.replicas -= 1;
            }
            if set.is_empty() {
                self.holders.remove(&file);
            }
        }
        if let Some(set) = self.cached.get_mut(&executor) {
            set.remove(&file);
        }
    }

    /// I_map lookup: executors currently caching `file`.
    pub fn holders(&self, file: FileId) -> Option<&ExecSet> {
        self.holders.get(&file)
    }

    /// Does `executor` cache `file`? One hash probe + one mask test —
    /// the scheduler's per-candidate hit-scoring primitive.
    #[inline]
    pub fn holds(&self, file: FileId, executor: ExecutorId) -> bool {
        self.holders
            .get(&file)
            .is_some_and(|set| set.contains(executor))
    }

    /// Number of replicas of `file` (the scheduler's replication-factor
    /// input for good-cache-compute). O(1): cached popcount.
    pub fn replication(&self, file: FileId) -> usize {
        self.holders.get(&file).map_or(0, |s| s.len())
    }

    /// E_map lookup: files cached at `executor`.
    pub fn cached_at(&self, executor: ExecutorId) -> Option<&HashSet<FileId>> {
        self.cached.get(&executor)
    }

    /// How many of `files` are cached at `executor` — the scheduling-window
    /// cache-hit score of §3.2 (|fileSet ∩ E_map(executor)|).
    pub fn hit_count(&self, executor: ExecutorId, files: &[FileId]) -> usize {
        match self.cached.get(&executor) {
            Some(set) => files.iter().filter(|f| set.contains(f)).count(),
            None => 0,
        }
    }

    /// Registered executors count.
    pub fn executors(&self) -> usize {
        self.cached.len()
    }

    /// Distinct files with at least one replica.
    pub fn distinct_files(&self) -> usize {
        self.holders.len()
    }

    /// Total replica pairs across the cluster.
    pub fn total_replicas(&self) -> u64 {
        self.replicas
    }

    /// Debug-check the two maps agree; used by tests.
    #[doc(hidden)]
    pub fn check_consistent(&self) -> Result<(), String> {
        let mut pairs = 0u64;
        for (&f, execs) in &self.holders {
            if execs.is_empty() {
                return Err(format!("empty holder set for {f}"));
            }
            for e in execs {
                pairs += 1;
                if !self.cached.get(&e).is_some_and(|s| s.contains(&f)) {
                    return Err(format!("I_map has ({f},{e}) but E_map does not"));
                }
            }
        }
        for (&e, files) in &self.cached {
            for &f in files {
                if !self.holders.get(&f).is_some_and(|s| s.contains(e)) {
                    return Err(format!("E_map has ({e},{f}) but I_map does not"));
                }
            }
        }
        if pairs != self.replicas {
            return Err(format!("replica count {} != actual {}", self.replicas, pairs));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{property, Gen};

    #[test]
    fn add_remove_round_trip() {
        let mut ix = LocationIndex::new();
        ix.register_executor(ExecutorId(1));
        ix.add(FileId(10), ExecutorId(1));
        ix.add(FileId(10), ExecutorId(2));
        assert_eq!(ix.replication(FileId(10)), 2);
        assert_eq!(ix.total_replicas(), 2);
        assert!(ix.holds(FileId(10), ExecutorId(1)));
        assert!(!ix.holds(FileId(11), ExecutorId(1)));
        ix.remove(FileId(10), ExecutorId(1));
        assert_eq!(ix.replication(FileId(10)), 1);
        assert!(!ix.holds(FileId(10), ExecutorId(1)));
        ix.remove(FileId(10), ExecutorId(2));
        assert_eq!(ix.replication(FileId(10)), 0);
        assert_eq!(ix.holders(FileId(10)), None);
        assert_eq!(ix.distinct_files(), 0);
    }

    #[test]
    fn double_add_is_idempotent() {
        let mut ix = LocationIndex::new();
        ix.add(FileId(1), ExecutorId(1));
        ix.add(FileId(1), ExecutorId(1));
        assert_eq!(ix.total_replicas(), 1);
        ix.check_consistent().unwrap();
    }

    #[test]
    fn hit_count_counts_intersection() {
        let mut ix = LocationIndex::new();
        for f in [1, 2, 3] {
            ix.add(FileId(f), ExecutorId(9));
        }
        let want = [FileId(2), FileId(3), FileId(4)];
        assert_eq!(ix.hit_count(ExecutorId(9), &want), 2);
        assert_eq!(ix.hit_count(ExecutorId(8), &want), 0);
    }

    #[test]
    fn holders_iterate_in_id_order() {
        let mut ix = LocationIndex::new();
        for e in [5u32, 1, 3, 200] {
            ix.add(FileId(7), ExecutorId(e));
        }
        let got: Vec<u32> = ix.holders(FileId(7)).unwrap().iter().map(|e| e.0).collect();
        assert_eq!(got, vec![1, 3, 5, 200]);
    }

    #[test]
    fn deregister_cleans_both_maps() {
        let mut ix = LocationIndex::new();
        ix.add(FileId(1), ExecutorId(1));
        ix.add(FileId(2), ExecutorId(1));
        ix.add(FileId(1), ExecutorId(2));
        let mut files = ix.deregister_executor(ExecutorId(1));
        files.sort();
        assert_eq!(files, vec![FileId(1), FileId(2)]);
        assert_eq!(ix.replication(FileId(1)), 1);
        assert_eq!(ix.replication(FileId(2)), 0);
        ix.check_consistent().unwrap();
    }

    #[test]
    fn maps_stay_mutually_consistent_under_random_ops() {
        property("index consistency", 100, |g: &mut Gen| {
            let mut ix = LocationIndex::new();
            let ops = g.usize_in(1..300);
            for _ in 0..ops {
                let f = FileId(g.u64_in(0..20) as u32);
                let e = ExecutorId(g.u64_in(0..8) as u32);
                match g.usize_in(0..4) {
                    0 | 1 => ix.add(f, e),
                    2 => ix.remove(f, e),
                    _ => {
                        ix.deregister_executor(e);
                    }
                }
                ix.check_consistent()?;
            }
            Ok(())
        });
    }
}
