//! Centralized data-location index (§3.1.1, §3.2).
//!
//! The dispatcher keeps a *centralized index* recording where every cached
//! data object lives, maintained loosely coherent with executor caches via
//! update messages. The scheduler's two lookups are exactly the paper's
//! two maps:
//!
//! * `I_map` — file logical name → set of executors caching it, stored as
//!   an [`ExecSet`] **bitset** ([`LocationIndex::holders`]): membership is
//!   a mask test, the replication factor is a cached popcount, and holder
//!   iteration (notify scoring, peer selection) walks set bits in
//!   ascending id order — the same deterministic order the pre-bitset
//!   `BTreeSet` produced;
//! * `E_map` — executor name → hash set of file names it caches
//!   ([`LocationIndex::cached_at`]): O(1) hit-probes for the scheduler's
//!   cache-hit scoring (§Perf iteration 3 replaced the per-probe
//!   `BTreeSet` descent with a single hash lookup).
//!
//! ## Arena layout (§Perf arena/SoA iteration)
//!
//! `FileId`/`ExecutorId` are dense `u32`s assigned from 0 by the
//! coordinator, so both maps are direct-indexed `Vec`s rather than hash
//! maps: `I_map` is `Vec<ExecSet>` indexed by `FileId.0` (an empty bitset
//! means "no replicas"; [`LocationIndex::holders`] still reports `None`
//! then, preserving the pre-arena `Option` contract), and `E_map` is
//! `Vec<Option<HashSet<FileId>>>` indexed by `ExecutorId.0`. The hot
//! probes ([`LocationIndex::holds`], [`LocationIndex::replication`],
//! [`LocationIndex::hit_count`]'s outer lookup) drop their hash of the key
//! entirely — one bounds check + one mask test.
//!
//! Both directions are kept mutually consistent by construction (asserted
//! by a property test). Per-file holder probes and replica counts are
//! O(1), matching the paper's O(|θ(κ)| + replication + min(|Q|, W))
//! scheduling-cost argument.
//!
//! The bitset representation is also what makes the §Perf iteration 4
//! notify memo cheap: the candidate executors of a multi-file head task
//! are the word-wise **union** of its files' holder sets
//! ([`ExecSet::union_with`]), built without iterating holders one by
//! one. Every mutation here must be mirrored into
//! [`crate::coordinator::pending::PendingIndex`] by the caller (the
//! engines' single mutation site is `coordinator::resolve_access` plus
//! executor deregistration) — the pending index's validity epochs hang
//! off that discipline.

pub mod execset;

pub use execset::ExecSet;

use crate::ids::{ExecutorId, FileId};
use std::collections::HashSet;

/// The dispatcher's central file-location index (`I_map` + `E_map`).
#[derive(Debug, Default)]
pub struct LocationIndex {
    /// I_map: `FileId.0` → executors holding it (bitset; empty = none).
    holders: Vec<ExecSet>,
    /// Files with at least one replica (live entries in `holders`).
    nonempty_files: usize,
    /// E_map: `ExecutorId.0` → files it holds (`None` = not registered).
    cached: Vec<Option<HashSet<FileId>>>,
    /// Registered executors (`Some` entries in `cached`).
    registered: usize,
    /// Total (file, executor) replica pairs — cheap global replication stat.
    replicas: u64,
}

impl LocationIndex {
    /// Empty index.
    pub fn new() -> Self {
        Self::default()
    }

    fn holder_slot(&mut self, file: FileId) -> &mut ExecSet {
        let i = file.0 as usize;
        if self.holders.len() <= i {
            self.holders.resize_with(i + 1, ExecSet::default);
        }
        &mut self.holders[i]
    }

    fn cached_slot(&mut self, executor: ExecutorId) -> &mut HashSet<FileId> {
        let i = executor.0 as usize;
        if self.cached.len() <= i {
            self.cached.resize_with(i + 1, || None);
        }
        let slot = &mut self.cached[i];
        if slot.is_none() {
            *slot = Some(HashSet::new());
            self.registered += 1;
        }
        slot.as_mut().expect("just registered")
    }

    /// Register an executor with an empty cache (no-op if present).
    pub fn register_executor(&mut self, executor: ExecutorId) {
        let _ = self.cached_slot(executor);
    }

    /// Remove an executor and all its entries (deregistration / release by
    /// the provisioner). Returns the files it held, for accounting.
    pub fn deregister_executor(&mut self, executor: ExecutorId) -> Vec<FileId> {
        let i = executor.0 as usize;
        let Some(set) = self.cached.get_mut(i).and_then(Option::take) else {
            return Vec::new();
        };
        self.registered -= 1;
        let files: Vec<FileId> = set.into_iter().collect();
        for &f in &files {
            if let Some(set) = self.holders.get_mut(f.0 as usize) {
                if set.remove(executor) {
                    self.replicas -= 1;
                    if set.is_empty() {
                        self.nonempty_files -= 1;
                    }
                }
            }
        }
        files
    }

    /// Record that `executor` now caches `file` (an executor cache-content
    /// update message).
    pub fn add(&mut self, file: FileId, executor: ExecutorId) {
        let set = self.holder_slot(file);
        let was_empty = set.is_empty();
        let inserted = set.insert(executor);
        self.cached_slot(executor).insert(file);
        if inserted {
            self.replicas += 1;
            if was_empty {
                self.nonempty_files += 1;
            }
        }
    }

    /// Record that `executor` evicted `file`.
    pub fn remove(&mut self, file: FileId, executor: ExecutorId) {
        if let Some(set) = self.holders.get_mut(file.0 as usize) {
            if set.remove(executor) {
                self.replicas -= 1;
                if set.is_empty() {
                    self.nonempty_files -= 1;
                }
            }
        }
        if let Some(Some(set)) = self.cached.get_mut(executor.0 as usize) {
            set.remove(&file);
        }
    }

    /// I_map lookup: executors currently caching `file`. `None` when no
    /// executor holds it (the dense slot may exist but be empty).
    pub fn holders(&self, file: FileId) -> Option<&ExecSet> {
        self.holders
            .get(file.0 as usize)
            .filter(|s| !s.is_empty())
    }

    /// Does `executor` cache `file`? One bounds check + one mask test —
    /// the scheduler's per-candidate hit-scoring primitive.
    #[inline]
    pub fn holds(&self, file: FileId, executor: ExecutorId) -> bool {
        self.holders
            .get(file.0 as usize)
            .is_some_and(|set| set.contains(executor))
    }

    /// Number of replicas of `file` (the scheduler's replication-factor
    /// input for good-cache-compute). O(1): cached popcount.
    pub fn replication(&self, file: FileId) -> usize {
        self.holders.get(file.0 as usize).map_or(0, |s| s.len())
    }

    /// E_map lookup: files cached at `executor`.
    pub fn cached_at(&self, executor: ExecutorId) -> Option<&HashSet<FileId>> {
        self.cached
            .get(executor.0 as usize)
            .and_then(|o| o.as_ref())
    }

    /// How many of `files` are cached at `executor` — the scheduling-window
    /// cache-hit score of §3.2 (|fileSet ∩ E_map(executor)|).
    pub fn hit_count(&self, executor: ExecutorId, files: &[FileId]) -> usize {
        match self.cached_at(executor) {
            Some(set) => files.iter().filter(|f| set.contains(f)).count(),
            None => 0,
        }
    }

    /// Registered executors count.
    pub fn executors(&self) -> usize {
        self.registered
    }

    /// Distinct files with at least one replica.
    pub fn distinct_files(&self) -> usize {
        self.nonempty_files
    }

    /// Total replica pairs across the cluster.
    pub fn total_replicas(&self) -> u64 {
        self.replicas
    }

    /// Approximate bytes held by the dense tables (capacity-based; the
    /// `scale/peak_table_bytes` bench counter sums this).
    pub fn table_bytes(&self) -> u64 {
        let holder_heap: usize = self.holders.iter().map(ExecSet::heap_bytes).sum();
        (self.holders.capacity() * std::mem::size_of::<ExecSet>()
            + holder_heap
            + self.cached.capacity() * std::mem::size_of::<Option<HashSet<FileId>>>()) as u64
            + self.replicas * std::mem::size_of::<FileId>() as u64
    }

    /// Debug-check the two maps agree; used by tests.
    #[doc(hidden)]
    pub fn check_consistent(&self) -> Result<(), String> {
        let mut pairs = 0u64;
        let mut nonempty = 0usize;
        for (i, execs) in self.holders.iter().enumerate() {
            let f = FileId(i as u32);
            if !execs.is_empty() {
                nonempty += 1;
            }
            for e in execs {
                pairs += 1;
                if !self.cached_at(e).is_some_and(|s| s.contains(&f)) {
                    return Err(format!("I_map has ({f},{e}) but E_map does not"));
                }
            }
        }
        let mut registered = 0usize;
        for (i, slot) in self.cached.iter().enumerate() {
            let Some(files) = slot else { continue };
            registered += 1;
            let e = ExecutorId(i as u32);
            for &f in files {
                if !self.holds(f, e) {
                    return Err(format!("E_map has ({e},{f}) but I_map does not"));
                }
            }
        }
        if pairs != self.replicas {
            return Err(format!("replica count {} != actual {}", self.replicas, pairs));
        }
        if nonempty != self.nonempty_files {
            return Err(format!(
                "nonempty_files {} != actual {}",
                self.nonempty_files, nonempty
            ));
        }
        if registered != self.registered {
            return Err(format!(
                "registered {} != actual {}",
                self.registered, registered
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{property, Gen};

    #[test]
    fn add_remove_round_trip() {
        let mut ix = LocationIndex::new();
        ix.register_executor(ExecutorId(1));
        ix.add(FileId(10), ExecutorId(1));
        ix.add(FileId(10), ExecutorId(2));
        assert_eq!(ix.replication(FileId(10)), 2);
        assert_eq!(ix.total_replicas(), 2);
        assert!(ix.holds(FileId(10), ExecutorId(1)));
        assert!(!ix.holds(FileId(11), ExecutorId(1)));
        ix.remove(FileId(10), ExecutorId(1));
        assert_eq!(ix.replication(FileId(10)), 1);
        assert!(!ix.holds(FileId(10), ExecutorId(1)));
        ix.remove(FileId(10), ExecutorId(2));
        assert_eq!(ix.replication(FileId(10)), 0);
        assert_eq!(ix.holders(FileId(10)), None);
        assert_eq!(ix.distinct_files(), 0);
    }

    #[test]
    fn double_add_is_idempotent() {
        let mut ix = LocationIndex::new();
        ix.add(FileId(1), ExecutorId(1));
        ix.add(FileId(1), ExecutorId(1));
        assert_eq!(ix.total_replicas(), 1);
        ix.check_consistent().unwrap();
    }

    #[test]
    fn hit_count_counts_intersection() {
        let mut ix = LocationIndex::new();
        for f in [1, 2, 3] {
            ix.add(FileId(f), ExecutorId(9));
        }
        let want = [FileId(2), FileId(3), FileId(4)];
        assert_eq!(ix.hit_count(ExecutorId(9), &want), 2);
        assert_eq!(ix.hit_count(ExecutorId(8), &want), 0);
    }

    #[test]
    fn holders_iterate_in_id_order() {
        let mut ix = LocationIndex::new();
        for e in [5u32, 1, 3, 200] {
            ix.add(FileId(7), ExecutorId(e));
        }
        let got: Vec<u32> = ix.holders(FileId(7)).unwrap().iter().map(|e| e.0).collect();
        assert_eq!(got, vec![1, 3, 5, 200]);
    }

    #[test]
    fn deregister_cleans_both_maps() {
        let mut ix = LocationIndex::new();
        ix.add(FileId(1), ExecutorId(1));
        ix.add(FileId(2), ExecutorId(1));
        ix.add(FileId(1), ExecutorId(2));
        let mut files = ix.deregister_executor(ExecutorId(1));
        files.sort();
        assert_eq!(files, vec![FileId(1), FileId(2)]);
        assert_eq!(ix.replication(FileId(1)), 1);
        assert_eq!(ix.replication(FileId(2)), 0);
        ix.check_consistent().unwrap();
    }

    #[test]
    fn emptied_slots_report_like_missing_files() {
        // Arena slots outlive their last replica; the read API must not
        // tell the difference from a never-seen file.
        let mut ix = LocationIndex::new();
        ix.add(FileId(3), ExecutorId(0));
        ix.remove(FileId(3), ExecutorId(0));
        assert_eq!(ix.holders(FileId(3)), None);
        assert_eq!(ix.replication(FileId(3)), 0);
        assert!(!ix.holds(FileId(3), ExecutorId(0)));
        assert_eq!(ix.distinct_files(), 0);
        // Re-adding revives the same slot.
        ix.add(FileId(3), ExecutorId(1));
        assert_eq!(ix.distinct_files(), 1);
        assert_eq!(ix.replication(FileId(3)), 1);
        ix.check_consistent().unwrap();
    }

    #[test]
    fn maps_stay_mutually_consistent_under_random_ops() {
        property("index consistency", 100, |g: &mut Gen| {
            let mut ix = LocationIndex::new();
            let ops = g.usize_in(1..300);
            for _ in 0..ops {
                let f = FileId(g.u64_in(0..20) as u32);
                let e = ExecutorId(g.u64_in(0..8) as u32);
                match g.usize_in(0..4) {
                    0 | 1 => ix.add(f, e),
                    2 => ix.remove(f, e),
                    _ => {
                        ix.deregister_executor(e);
                    }
                }
                ix.check_consistent()?;
            }
            Ok(())
        });
    }
}
