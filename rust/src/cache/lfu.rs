//! Least-Frequently-Used eviction, ties broken by least recency.
//!
//! Ordered set keyed on `(access_count, last_access_seq)` so the victim is
//! always the coldest object; all operations O(log n). The per-slot key
//! lives in a dense `Vec` indexed by the owning cache's slot id
//! (`(0, 0)` = untracked; real keys have count ≥ 1), replacing the old
//! `HashMap<FileId, (u64, u64)>` probe.

use super::EvictionState;
use crate::util::prng::Pcg64;
use std::collections::BTreeMap;

/// LFU book-keeping.
#[derive(Debug, Default)]
pub struct LfuState {
    clock: u64,
    /// (count, last-seq) → slot; BTreeMap iteration order = eviction order.
    by_key: BTreeMap<(u64, u64), u32>,
    /// slot → (count, last-seq) ((0, 0) = untracked).
    key_of: Vec<(u64, u64)>,
}

impl LfuState {
    /// Empty state.
    pub fn new() -> Self {
        Self::default()
    }

    fn bump(&mut self, slot: u32, start_count: u64) {
        if self.key_of.len() <= slot as usize {
            self.key_of.resize(slot as usize + 1, (0, 0));
        }
        self.clock += 1;
        let old = self.key_of[slot as usize];
        let new_key = if old != (0, 0) {
            self.by_key.remove(&old);
            (old.0 + 1, self.clock)
        } else {
            (start_count, self.clock)
        };
        self.key_of[slot as usize] = new_key;
        self.by_key.insert(new_key, slot);
    }
}

impl EvictionState for LfuState {
    fn on_insert(&mut self, slot: u32) {
        self.bump(slot, 1);
    }

    fn on_access(&mut self, slot: u32) {
        self.bump(slot, 1);
    }

    fn pick_victim(&mut self, _rng: &mut Pcg64) -> Option<u32> {
        self.by_key.first_key_value().map(|(_, &s)| s)
    }

    fn on_remove(&mut self, slot: u32) {
        let old = std::mem::replace(&mut self.key_of[slot as usize], (0, 0));
        if old != (0, 0) {
            self.by_key.remove(&old);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coldest_object_is_victim() {
        let mut rng = Pcg64::seeded(0);
        let mut s = LfuState::new();
        s.on_insert(1);
        s.on_insert(2);
        s.on_access(1); // slot 1 count=2, slot 2 count=1
        assert_eq!(s.pick_victim(&mut rng), Some(2));
    }

    #[test]
    fn frequency_ties_break_by_recency() {
        let mut rng = Pcg64::seeded(0);
        let mut s = LfuState::new();
        s.on_insert(1);
        s.on_insert(2);
        // Both count=1; slot 1 was inserted earlier → evict slot 1.
        assert_eq!(s.pick_victim(&mut rng), Some(1));
    }

    #[test]
    fn reused_slot_forgets_old_frequency() {
        let mut rng = Pcg64::seeded(0);
        let mut s = LfuState::new();
        s.on_insert(0);
        s.on_access(0);
        s.on_access(0); // hot occupant: count=3
        s.on_insert(1);
        s.on_remove(0);
        s.on_insert(0); // new occupant must restart at count=1
        s.on_access(1); // slot 1: count=2
        assert_eq!(s.pick_victim(&mut rng), Some(0));
    }
}
