//! Least-Frequently-Used eviction, ties broken by least recency.
//!
//! Ordered set keyed on `(access_count, last_access_seq)` so the victim is
//! always the coldest object; all operations O(log n).

use super::EvictionState;
use crate::ids::FileId;
use crate::util::prng::Pcg64;
use std::collections::{BTreeMap, HashMap};

/// LFU book-keeping.
#[derive(Debug, Default)]
pub struct LfuState {
    clock: u64,
    /// (count, last-seq) → file; BTreeMap iteration order = eviction order.
    by_key: BTreeMap<(u64, u64), FileId>,
    key_of: HashMap<FileId, (u64, u64)>,
}

impl LfuState {
    /// Empty state.
    pub fn new() -> Self {
        Self::default()
    }

    fn bump(&mut self, file: FileId, start_count: u64) {
        self.clock += 1;
        let new_key = match self.key_of.get(&file) {
            Some(&old) => {
                self.by_key.remove(&old);
                (old.0 + 1, self.clock)
            }
            None => (start_count, self.clock),
        };
        self.key_of.insert(file, new_key);
        self.by_key.insert(new_key, file);
    }
}

impl EvictionState for LfuState {
    fn on_insert(&mut self, file: FileId) {
        self.bump(file, 1);
    }

    fn on_access(&mut self, file: FileId) {
        self.bump(file, 1);
    }

    fn pick_victim(&mut self, _rng: &mut Pcg64) -> Option<FileId> {
        self.by_key.first_key_value().map(|(_, &f)| f)
    }

    fn on_remove(&mut self, file: FileId) {
        if let Some(key) = self.key_of.remove(&file) {
            self.by_key.remove(&key);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coldest_object_is_victim() {
        let mut rng = Pcg64::seeded(0);
        let mut s = LfuState::new();
        s.on_insert(FileId(1));
        s.on_insert(FileId(2));
        s.on_access(FileId(1)); // f1 count=2, f2 count=1
        assert_eq!(s.pick_victim(&mut rng), Some(FileId(2)));
    }

    #[test]
    fn frequency_ties_break_by_recency() {
        let mut rng = Pcg64::seeded(0);
        let mut s = LfuState::new();
        s.on_insert(FileId(1));
        s.on_insert(FileId(2));
        // Both count=1; f1 was inserted earlier → evict f1.
        assert_eq!(s.pick_victim(&mut rng), Some(FileId(1)));
    }
}
