//! Least-Recently-Used eviction (the paper's default policy).
//!
//! Implemented as a monotone "clock" per file: each access stamps the file
//! with a fresh sequence number kept in a `BTreeMap<seq, file>` ordered
//! set, so victim selection is O(log n) (`first_key_value`) and accesses
//! are O(log n) re-stampings — the same hash-map + sorted-set shape the
//! paper's §3.2 complexity argument relies on.

use super::EvictionState;
use crate::ids::FileId;
use crate::util::prng::Pcg64;
use std::collections::{BTreeMap, HashMap};

/// LRU book-keeping.
#[derive(Debug, Default)]
pub struct LruState {
    clock: u64,
    by_seq: BTreeMap<u64, FileId>,
    seq_of: HashMap<FileId, u64>,
}

impl LruState {
    /// Empty state.
    pub fn new() -> Self {
        Self::default()
    }

    fn stamp(&mut self, file: FileId) {
        self.clock += 1;
        if let Some(old) = self.seq_of.insert(file, self.clock) {
            self.by_seq.remove(&old);
        }
        self.by_seq.insert(self.clock, file);
    }
}

impl EvictionState for LruState {
    fn on_insert(&mut self, file: FileId) {
        self.stamp(file);
    }

    fn on_access(&mut self, file: FileId) {
        self.stamp(file);
    }

    fn pick_victim(&mut self, _rng: &mut Pcg64) -> Option<FileId> {
        self.by_seq.first_key_value().map(|(_, &f)| f)
    }

    fn on_remove(&mut self, file: FileId) {
        if let Some(seq) = self.seq_of.remove(&file) {
            self.by_seq.remove(&seq);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn victim_is_least_recent() {
        let mut rng = Pcg64::seeded(0);
        let mut s = LruState::new();
        s.on_insert(FileId(1));
        s.on_insert(FileId(2));
        s.on_insert(FileId(3));
        s.on_access(FileId(1));
        assert_eq!(s.pick_victim(&mut rng), Some(FileId(2)));
        s.on_remove(FileId(2));
        assert_eq!(s.pick_victim(&mut rng), Some(FileId(3)));
    }

    #[test]
    fn empty_has_no_victim() {
        let mut rng = Pcg64::seeded(0);
        let mut s = LruState::new();
        assert_eq!(s.pick_victim(&mut rng), None);
        s.on_insert(FileId(7));
        s.on_remove(FileId(7));
        assert_eq!(s.pick_victim(&mut rng), None);
    }
}
