//! Least-Recently-Used eviction (the paper's default policy).
//!
//! Implemented as a monotone "clock" per slot: each access stamps the slot
//! with a fresh sequence number kept in a `BTreeMap<seq, slot>` ordered
//! set, so victim selection is O(log n) (`first_key_value`) and accesses
//! are O(log n) re-stampings. The per-slot stamp lives in a dense `Vec`
//! indexed by the owning cache's slot id (0 = untracked; real stamps start
//! at 1), replacing the old `HashMap<FileId, u64>` probe.

use super::EvictionState;
use crate::util::prng::Pcg64;
use std::collections::BTreeMap;

/// LRU book-keeping.
#[derive(Debug, Default)]
pub struct LruState {
    clock: u64,
    by_seq: BTreeMap<u64, u32>,
    /// slot → stamp (0 = untracked).
    seq_of: Vec<u64>,
}

impl LruState {
    /// Empty state.
    pub fn new() -> Self {
        Self::default()
    }

    fn stamp(&mut self, slot: u32) {
        if self.seq_of.len() <= slot as usize {
            self.seq_of.resize(slot as usize + 1, 0);
        }
        self.clock += 1;
        let old = std::mem::replace(&mut self.seq_of[slot as usize], self.clock);
        if old != 0 {
            self.by_seq.remove(&old);
        }
        self.by_seq.insert(self.clock, slot);
    }
}

impl EvictionState for LruState {
    fn on_insert(&mut self, slot: u32) {
        self.stamp(slot);
    }

    fn on_access(&mut self, slot: u32) {
        self.stamp(slot);
    }

    fn pick_victim(&mut self, _rng: &mut Pcg64) -> Option<u32> {
        self.by_seq.first_key_value().map(|(_, &s)| s)
    }

    fn on_remove(&mut self, slot: u32) {
        let old = std::mem::replace(&mut self.seq_of[slot as usize], 0);
        if old != 0 {
            self.by_seq.remove(&old);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn victim_is_least_recent() {
        let mut rng = Pcg64::seeded(0);
        let mut s = LruState::new();
        s.on_insert(1);
        s.on_insert(2);
        s.on_insert(3);
        s.on_access(1);
        assert_eq!(s.pick_victim(&mut rng), Some(2));
        s.on_remove(2);
        assert_eq!(s.pick_victim(&mut rng), Some(3));
    }

    #[test]
    fn empty_has_no_victim() {
        let mut rng = Pcg64::seeded(0);
        let mut s = LruState::new();
        assert_eq!(s.pick_victim(&mut rng), None);
        s.on_insert(7);
        s.on_remove(7);
        assert_eq!(s.pick_victim(&mut rng), None);
    }

    #[test]
    fn reused_slot_starts_fresh() {
        let mut rng = Pcg64::seeded(0);
        let mut s = LruState::new();
        s.on_insert(0);
        s.on_insert(1);
        s.on_remove(0);
        s.on_insert(0); // slot reused by a new occupant → most recent now
        assert_eq!(s.pick_victim(&mut rng), Some(1));
    }
}
