//! Per-executor data-object caches (§3.1.1).
//!
//! Each executor manages its own byte-capacity cache of immutable data
//! objects and reports content changes to the dispatcher's central
//! [`crate::index::LocationIndex`]. The paper implements four eviction
//! policies — **Random, FIFO, LRU, LFU** — and runs all its experiments
//! with LRU; all four are provided here (the eviction-policy ablation the
//! paper defers to future work is exercised by `examples/policy_sweep.rs`
//! and the `fig04_10` bench's `--evict` flag).
//!
//! Because the paper assumes data is *never modified after creation*
//! (§3.1.1), there is no coherence protocol: a cache entry is just
//! `(FileId, size)` plus policy book-keeping.
//!
//! ## Slot-slab layout (§Perf arena/SoA iteration)
//!
//! Entries live in a dense slab: each resident object occupies a **slot**
//! (`u32` index into [`ObjectCache::entries`]); freed slots go on a free
//! list and are reused. Policy state ([`EvictionState`]) is keyed by slot,
//! so the per-policy recency/frequency maps become `Vec`s indexed by slot
//! (bounded by peak residency) instead of `HashMap<FileId, _>` probes.
//! Every slot carries a **generation** counter (odd = live, even = free,
//! bumped on every transition) so a stale handle from a previous occupant
//! can never alias the current one — [`ObjectCache::handle_live`] is the
//! check, and the byzantine chaos faults lean on it (docs/PERFORMANCE.md).

mod fifo;
mod lfu;
mod lru;
mod random;

pub use fifo::FifoState;
pub use lfu::LfuState;
pub use lru::LruState;
pub use random::RandomState;

use crate::ids::FileId;
use crate::util::prng::Pcg64;
use std::collections::HashMap;

/// Which eviction policy a cache uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EvictionPolicy {
    /// Evict a uniformly random resident object.
    Random,
    /// Evict the object resident the longest.
    Fifo,
    /// Evict the least-recently-used object (the paper's default).
    Lru,
    /// Evict the least-frequently-used object (ties broken by recency).
    Lfu,
}

impl EvictionPolicy {
    /// Parse from config text.
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "random" => Some(EvictionPolicy::Random),
            "fifo" => Some(EvictionPolicy::Fifo),
            "lru" => Some(EvictionPolicy::Lru),
            "lfu" => Some(EvictionPolicy::Lfu),
            _ => None,
        }
    }

    /// Canonical name.
    pub fn name(&self) -> &'static str {
        match self {
            EvictionPolicy::Random => "random",
            EvictionPolicy::Fifo => "fifo",
            EvictionPolicy::Lru => "lru",
            EvictionPolicy::Lfu => "lfu",
        }
    }
}

impl std::fmt::Display for EvictionPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for EvictionPolicy {
    type Err = String;

    /// The `FromStr` face of [`EvictionPolicy::parse`]; the `run`,
    /// `chaos`, and `scenarios` subcommands all parse `--cache` through
    /// this. Round-trips with `Display`.
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        EvictionPolicy::parse(s)
            .ok_or_else(|| format!("unknown eviction policy `{s}` (expected random|fifo|lru|lfu)"))
    }
}

/// Cache sizing + policy configuration.
#[derive(Debug, Clone, Copy)]
pub struct CacheConfig {
    /// Capacity in bytes (the paper varies 1 GB / 1.5 GB / 2 GB / 4 GB per node).
    pub capacity_bytes: u64,
    /// Eviction policy (paper experiments: LRU).
    pub policy: EvictionPolicy,
}

impl CacheConfig {
    /// LRU cache of the given capacity — the paper's configuration.
    pub fn lru(capacity_bytes: u64) -> Self {
        CacheConfig {
            capacity_bytes,
            policy: EvictionPolicy::Lru,
        }
    }
}

/// Policy-specific state: the ordering/recency structure that picks a
/// victim. Implementations must be O(log n) or better per operation — the
/// scheduler touches caches on every dispatch decision.
///
/// Operations are keyed by the owning [`ObjectCache`]'s dense **slot id**
/// (not `FileId`): slots are allocated contiguously and reused via a free
/// list, so implementations store per-slot state in plain `Vec`s whose
/// length is bounded by peak residency.
pub trait EvictionState: std::fmt::Debug {
    /// Record that the object in `slot` was inserted.
    fn on_insert(&mut self, slot: u32);
    /// Record an access (hit) on the object in `slot`.
    fn on_access(&mut self, slot: u32);
    /// Pick the victim slot to evict; `rng` is supplied for Random.
    /// Must only return currently-occupied slots.
    fn pick_victim(&mut self, rng: &mut Pcg64) -> Option<u32>;
    /// Record that the object in `slot` was removed (evicted or
    /// invalidated). Always called before the slot is freed for reuse.
    fn on_remove(&mut self, slot: u32);
}

fn new_state(policy: EvictionPolicy) -> Box<dyn EvictionState + Send> {
    match policy {
        EvictionPolicy::Random => Box::new(RandomState::new()),
        EvictionPolicy::Fifo => Box::new(FifoState::new()),
        EvictionPolicy::Lru => Box::new(LruState::new()),
        EvictionPolicy::Lfu => Box::new(LfuState::new()),
    }
}

/// One slab slot. `gen` is odd while the slot is live and even while it is
/// free; it bumps on every transition, so a `(slot, gen)` handle taken
/// while live can be validated after arbitrary churn.
#[derive(Debug, Clone, Copy)]
struct Entry {
    file: FileId,
    size: u64,
    gen: u32,
}

/// A generation-checked handle to a cache slot (see
/// [`ObjectCache::slot_handle`] / [`ObjectCache::handle_live`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct CacheSlot {
    /// Dense slot index.
    pub slot: u32,
    /// Generation observed when the handle was taken.
    pub gen: u32,
}

/// A byte-capacity object cache with pluggable eviction.
///
/// `insert` returns the list of evicted objects so the owner can propagate
/// index updates (the executor's periodic cache-content messages in the
/// paper's loosely-coherent design).
#[derive(Debug)]
pub struct ObjectCache {
    capacity: u64,
    used: u64,
    /// Dense slot slab; `free` holds reusable indices.
    entries: Vec<Entry>,
    free: Vec<u32>,
    /// Resident file → slot.
    slot_of: HashMap<FileId, u32>,
    state: Box<dyn EvictionState + Send>,
    policy: EvictionPolicy,
    /// Cumulative eviction count (for ablation reporting).
    pub evictions: u64,
}

impl ObjectCache {
    /// Create an empty cache.
    pub fn new(config: CacheConfig) -> Self {
        ObjectCache {
            capacity: config.capacity_bytes,
            used: 0,
            entries: Vec::new(),
            free: Vec::new(),
            slot_of: HashMap::new(),
            state: new_state(config.policy),
            policy: config.policy,
            evictions: 0,
        }
    }

    /// The configured policy.
    pub fn policy(&self) -> EvictionPolicy {
        self.policy
    }

    /// Capacity in bytes.
    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    /// Bytes currently resident.
    pub fn used(&self) -> u64 {
        self.used
    }

    /// Number of resident objects.
    pub fn len(&self) -> usize {
        self.slot_of.len()
    }

    /// True when nothing is resident.
    pub fn is_empty(&self) -> bool {
        self.slot_of.is_empty()
    }

    /// Is `file` resident? (Does *not* count as an access.)
    pub fn contains(&self, file: FileId) -> bool {
        self.slot_of.contains_key(&file)
    }

    /// Generation-checked handle to `file`'s current slot, if resident.
    pub fn slot_handle(&self, file: FileId) -> Option<CacheSlot> {
        self.slot_of.get(&file).map(|&s| CacheSlot {
            slot: s,
            gen: self.entries[s as usize].gen,
        })
    }

    /// Does `handle` still refer to the occupant it was taken for? False
    /// once the slot was freed — even if it has since been reused for
    /// another file (the generation moved on in both transitions).
    pub fn handle_live(&self, handle: CacheSlot) -> bool {
        self.entries
            .get(handle.slot as usize)
            .is_some_and(|e| e.gen == handle.gen && handle.gen % 2 == 1)
    }

    /// Record a read of a resident object (updates recency/frequency).
    /// Returns false if the object was not resident.
    pub fn touch(&mut self, file: FileId) -> bool {
        if let Some(&slot) = self.slot_of.get(&file) {
            self.state.on_access(slot);
            true
        } else {
            false
        }
    }

    /// Free `slot` (policy already notified), bumping its generation.
    fn release_slot(&mut self, slot: u32) {
        let e = &mut self.entries[slot as usize];
        debug_assert!(e.gen % 2 == 1, "releasing a free slot");
        e.gen += 1;
        self.used -= e.size;
        self.free.push(slot);
    }

    /// Insert `file` of `size` bytes, evicting as needed.
    ///
    /// Returns the evicted objects. Objects larger than the whole cache are
    /// rejected (`None`), mirroring Falkon executors refusing to cache
    /// objects beyond local disk capacity.
    pub fn insert(&mut self, file: FileId, size: u64, rng: &mut Pcg64) -> Option<Vec<FileId>> {
        if size > self.capacity {
            return None;
        }
        if let Some(&slot) = self.slot_of.get(&file) {
            // Re-insert of a resident object is just an access.
            self.state.on_access(slot);
            return Some(Vec::new());
        }
        let mut evicted = Vec::new();
        while self.used + size > self.capacity {
            let victim = self
                .state
                .pick_victim(rng)
                .expect("cache accounting: used > 0 implies a victim exists");
            let vfile = self.entries[victim as usize].file;
            self.slot_of
                .remove(&vfile)
                .expect("victim must be resident");
            self.state.on_remove(victim);
            self.release_slot(victim);
            self.evictions += 1;
            evicted.push(vfile);
        }
        let slot = match self.free.pop() {
            Some(s) => {
                let e = &mut self.entries[s as usize];
                debug_assert!(e.gen % 2 == 0, "free-list slot must be free");
                *e = Entry {
                    file,
                    size,
                    gen: e.gen + 1,
                };
                s
            }
            None => {
                self.entries.push(Entry { file, size, gen: 1 });
                (self.entries.len() - 1) as u32
            }
        };
        self.slot_of.insert(file, slot);
        self.state.on_insert(slot);
        self.used += size;
        Some(evicted)
    }

    /// Remove a specific object (e.g. on executor deregistration cleanup).
    pub fn remove(&mut self, file: FileId) -> bool {
        if let Some(slot) = self.slot_of.remove(&file) {
            self.state.on_remove(slot);
            self.release_slot(slot);
            true
        } else {
            false
        }
    }

    /// Iterate over resident objects (ascending slot order).
    pub fn files(&self) -> impl Iterator<Item = FileId> + '_ {
        self.entries
            .iter()
            .filter(|e| e.gen % 2 == 1)
            .map(|e| e.file)
    }

    /// Approximate bytes held by the slab tables (capacity, not length —
    /// the `scale/peak_table_bytes` bench counter sums this across
    /// executors). Deterministic for a deterministic drive.
    pub fn table_bytes(&self) -> u64 {
        (self.entries.capacity() * std::mem::size_of::<Entry>()
            + self.free.capacity() * std::mem::size_of::<u32>()
            + self.slot_of.capacity() * std::mem::size_of::<(FileId, u32)>()) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cache(policy: EvictionPolicy, cap: u64) -> ObjectCache {
        ObjectCache::new(CacheConfig {
            capacity_bytes: cap,
            policy,
        })
    }

    #[test]
    fn eviction_policy_round_trips_from_str_and_display() {
        for p in [
            EvictionPolicy::Random,
            EvictionPolicy::Fifo,
            EvictionPolicy::Lru,
            EvictionPolicy::Lfu,
        ] {
            assert_eq!(p.to_string().parse::<EvictionPolicy>(), Ok(p));
            assert_eq!(p.to_string(), p.name());
        }
        assert!("arc".parse::<EvictionPolicy>().is_err());
    }

    #[test]
    fn insert_and_contains() {
        let mut rng = Pcg64::seeded(1);
        let mut c = cache(EvictionPolicy::Lru, 100);
        assert_eq!(c.insert(FileId(1), 40, &mut rng), Some(vec![]));
        assert_eq!(c.insert(FileId(2), 40, &mut rng), Some(vec![]));
        assert!(c.contains(FileId(1)));
        assert!(c.contains(FileId(2)));
        assert_eq!(c.used(), 80);
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn rejects_oversized_object() {
        let mut rng = Pcg64::seeded(1);
        let mut c = cache(EvictionPolicy::Lru, 100);
        assert_eq!(c.insert(FileId(1), 101, &mut rng), None);
        assert!(c.is_empty());
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let mut rng = Pcg64::seeded(1);
        let mut c = cache(EvictionPolicy::Lru, 100);
        c.insert(FileId(1), 50, &mut rng).unwrap();
        c.insert(FileId(2), 50, &mut rng).unwrap();
        assert!(c.touch(FileId(1))); // 2 is now LRU
        let evicted = c.insert(FileId(3), 50, &mut rng).unwrap();
        assert_eq!(evicted, vec![FileId(2)]);
        assert!(c.contains(FileId(1)));
        assert!(c.contains(FileId(3)));
    }

    #[test]
    fn fifo_ignores_recency() {
        let mut rng = Pcg64::seeded(1);
        let mut c = cache(EvictionPolicy::Fifo, 100);
        c.insert(FileId(1), 50, &mut rng).unwrap();
        c.insert(FileId(2), 50, &mut rng).unwrap();
        c.touch(FileId(1)); // FIFO must not care
        let evicted = c.insert(FileId(3), 50, &mut rng).unwrap();
        assert_eq!(evicted, vec![FileId(1)]);
    }

    #[test]
    fn lfu_evicts_least_frequent() {
        let mut rng = Pcg64::seeded(1);
        let mut c = cache(EvictionPolicy::Lfu, 100);
        c.insert(FileId(1), 50, &mut rng).unwrap();
        c.insert(FileId(2), 50, &mut rng).unwrap();
        c.touch(FileId(1));
        c.touch(FileId(1));
        c.touch(FileId(2));
        let evicted = c.insert(FileId(3), 50, &mut rng).unwrap();
        assert_eq!(evicted, vec![FileId(2)]);
    }

    #[test]
    fn random_evicts_some_resident_object() {
        let mut rng = Pcg64::seeded(1);
        let mut c = cache(EvictionPolicy::Random, 100);
        c.insert(FileId(1), 50, &mut rng).unwrap();
        c.insert(FileId(2), 50, &mut rng).unwrap();
        let evicted = c.insert(FileId(3), 60, &mut rng).unwrap();
        // 60 bytes needs both 50-byte victims out.
        assert_eq!(evicted.len(), 2);
        assert!(c.contains(FileId(3)));
        assert_eq!(c.used(), 60);
    }

    #[test]
    fn reinsert_is_access_not_duplicate() {
        let mut rng = Pcg64::seeded(1);
        let mut c = cache(EvictionPolicy::Lru, 100);
        c.insert(FileId(1), 60, &mut rng).unwrap();
        assert_eq!(c.insert(FileId(1), 60, &mut rng), Some(vec![]));
        assert_eq!(c.used(), 60);
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn remove_frees_space() {
        let mut rng = Pcg64::seeded(1);
        let mut c = cache(EvictionPolicy::Lru, 100);
        c.insert(FileId(1), 60, &mut rng).unwrap();
        assert!(c.remove(FileId(1)));
        assert!(!c.remove(FileId(1)));
        assert_eq!(c.used(), 0);
        assert_eq!(c.insert(FileId(2), 100, &mut rng), Some(vec![]));
    }

    #[test]
    fn multi_eviction_until_fit() {
        let mut rng = Pcg64::seeded(1);
        let mut c = cache(EvictionPolicy::Lru, 100);
        for i in 0..10 {
            c.insert(FileId(i), 10, &mut rng).unwrap();
        }
        let evicted = c.insert(FileId(99), 95, &mut rng).unwrap();
        assert_eq!(evicted.len(), 10);
        assert_eq!(c.len(), 1);
        assert_eq!(c.evictions, 10);
    }

    #[test]
    fn slots_are_reused_not_grown() {
        let mut rng = Pcg64::seeded(1);
        let mut c = cache(EvictionPolicy::Lru, 100);
        // Steady-state churn: capacity holds 2 objects, insert 50.
        for i in 0..50 {
            c.insert(FileId(i), 50, &mut rng).unwrap();
        }
        assert_eq!(c.len(), 2);
        assert!(
            c.entries.len() <= 3,
            "slab grew to {} slots under steady churn",
            c.entries.len()
        );
    }

    #[test]
    fn generation_check_rejects_stale_handles() {
        let mut rng = Pcg64::seeded(1);
        let mut c = cache(EvictionPolicy::Lru, 100);
        c.insert(FileId(1), 100, &mut rng).unwrap();
        let h = c.slot_handle(FileId(1)).unwrap();
        assert!(c.handle_live(h));
        // Evict 1 by inserting 2; the slot is freed...
        c.insert(FileId(2), 100, &mut rng).unwrap();
        assert!(!c.handle_live(h), "freed slot must invalidate the handle");
        // ...and reused for file 2 — the old handle must still be stale.
        let h2 = c.slot_handle(FileId(2)).unwrap();
        assert_eq!(h2.slot, h.slot, "slot must be recycled for this test");
        assert_ne!(h2.gen, h.gen);
        assert!(!c.handle_live(h));
        assert!(c.handle_live(h2));
        // An out-of-range slot is never live.
        assert!(!c.handle_live(CacheSlot { slot: 999, gen: 1 }));
    }

    #[test]
    fn accounting_invariant_under_all_policies() {
        use crate::util::proptest::{property, Gen};
        for policy in [
            EvictionPolicy::Random,
            EvictionPolicy::Fifo,
            EvictionPolicy::Lru,
            EvictionPolicy::Lfu,
        ] {
            property(&format!("cache accounting {policy:?}"), 50, |g: &mut Gen| {
                let cap = g.u64_in(50..200);
                let mut rng = Pcg64::seeded(g.case_seed);
                let mut c = cache(policy, cap);
                let ops = g.usize_in(1..200);
                for _ in 0..ops {
                    let file = FileId(g.u64_in(0..30) as u32);
                    match g.usize_in(0..3) {
                        0 => {
                            let size = g.u64_in(1..60);
                            let _ = c.insert(file, size, &mut rng);
                        }
                        1 => {
                            let _ = c.touch(file);
                        }
                        _ => {
                            let _ = c.remove(file);
                        }
                    }
                    if c.used() > c.capacity() {
                        return Err(format!("used {} > cap {}", c.used(), c.capacity()));
                    }
                    let live: Vec<_> =
                        c.entries.iter().filter(|e| e.gen % 2 == 1).collect();
                    let sum: u64 = live.iter().map(|e| e.size).sum();
                    if sum != c.used() {
                        return Err(format!("sum {} != used {}", sum, c.used()));
                    }
                    if live.len() != c.slot_of.len() {
                        return Err(format!(
                            "live slots {} != map {}",
                            live.len(),
                            c.slot_of.len()
                        ));
                    }
                    if live.len() + c.free.len() != c.entries.len() {
                        return Err("free list disagrees with slab".into());
                    }
                }
                Ok(())
            });
        }
    }
}
