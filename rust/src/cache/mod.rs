//! Per-executor data-object caches (§3.1.1).
//!
//! Each executor manages its own byte-capacity cache of immutable data
//! objects and reports content changes to the dispatcher's central
//! [`crate::index::LocationIndex`]. The paper implements four eviction
//! policies — **Random, FIFO, LRU, LFU** — and runs all its experiments
//! with LRU; all four are provided here (the eviction-policy ablation the
//! paper defers to future work is exercised by `examples/policy_sweep.rs`
//! and the `fig04_10` bench's `--evict` flag).
//!
//! Because the paper assumes data is *never modified after creation*
//! (§3.1.1), there is no coherence protocol: a cache entry is just
//! `(FileId, size)` plus policy book-keeping.

mod fifo;
mod lfu;
mod lru;
mod random;

pub use fifo::FifoState;
pub use lfu::LfuState;
pub use lru::LruState;
pub use random::RandomState;

use crate::ids::FileId;
use crate::util::prng::Pcg64;
use std::collections::HashMap;

/// Which eviction policy a cache uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EvictionPolicy {
    /// Evict a uniformly random resident object.
    Random,
    /// Evict the object resident the longest.
    Fifo,
    /// Evict the least-recently-used object (the paper's default).
    Lru,
    /// Evict the least-frequently-used object (ties broken by recency).
    Lfu,
}

impl EvictionPolicy {
    /// Parse from config text.
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "random" => Some(EvictionPolicy::Random),
            "fifo" => Some(EvictionPolicy::Fifo),
            "lru" => Some(EvictionPolicy::Lru),
            "lfu" => Some(EvictionPolicy::Lfu),
            _ => None,
        }
    }

    /// Canonical name.
    pub fn name(&self) -> &'static str {
        match self {
            EvictionPolicy::Random => "random",
            EvictionPolicy::Fifo => "fifo",
            EvictionPolicy::Lru => "lru",
            EvictionPolicy::Lfu => "lfu",
        }
    }
}

impl std::fmt::Display for EvictionPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for EvictionPolicy {
    type Err = String;

    /// The `FromStr` face of [`EvictionPolicy::parse`]; the `run`,
    /// `chaos`, and `scenarios` subcommands all parse `--cache` through
    /// this. Round-trips with `Display`.
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        EvictionPolicy::parse(s)
            .ok_or_else(|| format!("unknown eviction policy `{s}` (expected random|fifo|lru|lfu)"))
    }
}

/// Cache sizing + policy configuration.
#[derive(Debug, Clone, Copy)]
pub struct CacheConfig {
    /// Capacity in bytes (the paper varies 1 GB / 1.5 GB / 2 GB / 4 GB per node).
    pub capacity_bytes: u64,
    /// Eviction policy (paper experiments: LRU).
    pub policy: EvictionPolicy,
}

impl CacheConfig {
    /// LRU cache of the given capacity — the paper's configuration.
    pub fn lru(capacity_bytes: u64) -> Self {
        CacheConfig {
            capacity_bytes,
            policy: EvictionPolicy::Lru,
        }
    }
}

/// Policy-specific state: the ordering/recency structure that picks a
/// victim. Implementations must be O(log n) or better per operation — the
/// scheduler touches caches on every dispatch decision.
pub trait EvictionState: std::fmt::Debug {
    /// Record that `file` was inserted.
    fn on_insert(&mut self, file: FileId);
    /// Record an access (hit) on `file`.
    fn on_access(&mut self, file: FileId);
    /// Pick the victim to evict; `rng` is supplied for Random.
    /// Must only return currently-resident files.
    fn pick_victim(&mut self, rng: &mut Pcg64) -> Option<FileId>;
    /// Record that `file` was removed (evicted or invalidated).
    fn on_remove(&mut self, file: FileId);
}

fn new_state(policy: EvictionPolicy) -> Box<dyn EvictionState + Send> {
    match policy {
        EvictionPolicy::Random => Box::new(RandomState::new()),
        EvictionPolicy::Fifo => Box::new(FifoState::new()),
        EvictionPolicy::Lru => Box::new(LruState::new()),
        EvictionPolicy::Lfu => Box::new(LfuState::new()),
    }
}

/// A byte-capacity object cache with pluggable eviction.
///
/// `insert` returns the list of evicted objects so the owner can propagate
/// index updates (the executor's periodic cache-content messages in the
/// paper's loosely-coherent design).
#[derive(Debug)]
pub struct ObjectCache {
    capacity: u64,
    used: u64,
    sizes: HashMap<FileId, u64>,
    state: Box<dyn EvictionState + Send>,
    policy: EvictionPolicy,
    /// Cumulative eviction count (for ablation reporting).
    pub evictions: u64,
}

impl ObjectCache {
    /// Create an empty cache.
    pub fn new(config: CacheConfig) -> Self {
        ObjectCache {
            capacity: config.capacity_bytes,
            used: 0,
            sizes: HashMap::new(),
            state: new_state(config.policy),
            policy: config.policy,
            evictions: 0,
        }
    }

    /// The configured policy.
    pub fn policy(&self) -> EvictionPolicy {
        self.policy
    }

    /// Capacity in bytes.
    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    /// Bytes currently resident.
    pub fn used(&self) -> u64 {
        self.used
    }

    /// Number of resident objects.
    pub fn len(&self) -> usize {
        self.sizes.len()
    }

    /// True when nothing is resident.
    pub fn is_empty(&self) -> bool {
        self.sizes.is_empty()
    }

    /// Is `file` resident? (Does *not* count as an access.)
    pub fn contains(&self, file: FileId) -> bool {
        self.sizes.contains_key(&file)
    }

    /// Record a read of a resident object (updates recency/frequency).
    /// Returns false if the object was not resident.
    pub fn touch(&mut self, file: FileId) -> bool {
        if self.sizes.contains_key(&file) {
            self.state.on_access(file);
            true
        } else {
            false
        }
    }

    /// Insert `file` of `size` bytes, evicting as needed.
    ///
    /// Returns the evicted objects. Objects larger than the whole cache are
    /// rejected (`None`), mirroring Falkon executors refusing to cache
    /// objects beyond local disk capacity.
    pub fn insert(&mut self, file: FileId, size: u64, rng: &mut Pcg64) -> Option<Vec<FileId>> {
        if size > self.capacity {
            return None;
        }
        if self.sizes.contains_key(&file) {
            // Re-insert of a resident object is just an access.
            self.state.on_access(file);
            return Some(Vec::new());
        }
        let mut evicted = Vec::new();
        while self.used + size > self.capacity {
            let victim = self
                .state
                .pick_victim(rng)
                .expect("cache accounting: used > 0 implies a victim exists");
            let vsize = self
                .sizes
                .remove(&victim)
                .expect("victim must be resident");
            self.state.on_remove(victim);
            self.used -= vsize;
            self.evictions += 1;
            evicted.push(victim);
        }
        self.sizes.insert(file, size);
        self.state.on_insert(file);
        self.used += size;
        Some(evicted)
    }

    /// Remove a specific object (e.g. on executor deregistration cleanup).
    pub fn remove(&mut self, file: FileId) -> bool {
        if let Some(size) = self.sizes.remove(&file) {
            self.state.on_remove(file);
            self.used -= size;
            true
        } else {
            false
        }
    }

    /// Iterate over resident objects.
    pub fn files(&self) -> impl Iterator<Item = FileId> + '_ {
        self.sizes.keys().copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cache(policy: EvictionPolicy, cap: u64) -> ObjectCache {
        ObjectCache::new(CacheConfig {
            capacity_bytes: cap,
            policy,
        })
    }

    #[test]
    fn eviction_policy_round_trips_from_str_and_display() {
        for p in [
            EvictionPolicy::Random,
            EvictionPolicy::Fifo,
            EvictionPolicy::Lru,
            EvictionPolicy::Lfu,
        ] {
            assert_eq!(p.to_string().parse::<EvictionPolicy>(), Ok(p));
            assert_eq!(p.to_string(), p.name());
        }
        assert!("arc".parse::<EvictionPolicy>().is_err());
    }

    #[test]
    fn insert_and_contains() {
        let mut rng = Pcg64::seeded(1);
        let mut c = cache(EvictionPolicy::Lru, 100);
        assert_eq!(c.insert(FileId(1), 40, &mut rng), Some(vec![]));
        assert_eq!(c.insert(FileId(2), 40, &mut rng), Some(vec![]));
        assert!(c.contains(FileId(1)));
        assert!(c.contains(FileId(2)));
        assert_eq!(c.used(), 80);
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn rejects_oversized_object() {
        let mut rng = Pcg64::seeded(1);
        let mut c = cache(EvictionPolicy::Lru, 100);
        assert_eq!(c.insert(FileId(1), 101, &mut rng), None);
        assert!(c.is_empty());
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let mut rng = Pcg64::seeded(1);
        let mut c = cache(EvictionPolicy::Lru, 100);
        c.insert(FileId(1), 50, &mut rng).unwrap();
        c.insert(FileId(2), 50, &mut rng).unwrap();
        assert!(c.touch(FileId(1))); // 2 is now LRU
        let evicted = c.insert(FileId(3), 50, &mut rng).unwrap();
        assert_eq!(evicted, vec![FileId(2)]);
        assert!(c.contains(FileId(1)));
        assert!(c.contains(FileId(3)));
    }

    #[test]
    fn fifo_ignores_recency() {
        let mut rng = Pcg64::seeded(1);
        let mut c = cache(EvictionPolicy::Fifo, 100);
        c.insert(FileId(1), 50, &mut rng).unwrap();
        c.insert(FileId(2), 50, &mut rng).unwrap();
        c.touch(FileId(1)); // FIFO must not care
        let evicted = c.insert(FileId(3), 50, &mut rng).unwrap();
        assert_eq!(evicted, vec![FileId(1)]);
    }

    #[test]
    fn lfu_evicts_least_frequent() {
        let mut rng = Pcg64::seeded(1);
        let mut c = cache(EvictionPolicy::Lfu, 100);
        c.insert(FileId(1), 50, &mut rng).unwrap();
        c.insert(FileId(2), 50, &mut rng).unwrap();
        c.touch(FileId(1));
        c.touch(FileId(1));
        c.touch(FileId(2));
        let evicted = c.insert(FileId(3), 50, &mut rng).unwrap();
        assert_eq!(evicted, vec![FileId(2)]);
    }

    #[test]
    fn random_evicts_some_resident_object() {
        let mut rng = Pcg64::seeded(1);
        let mut c = cache(EvictionPolicy::Random, 100);
        c.insert(FileId(1), 50, &mut rng).unwrap();
        c.insert(FileId(2), 50, &mut rng).unwrap();
        let evicted = c.insert(FileId(3), 60, &mut rng).unwrap();
        // 60 bytes needs both 50-byte victims out.
        assert_eq!(evicted.len(), 2);
        assert!(c.contains(FileId(3)));
        assert_eq!(c.used(), 60);
    }

    #[test]
    fn reinsert_is_access_not_duplicate() {
        let mut rng = Pcg64::seeded(1);
        let mut c = cache(EvictionPolicy::Lru, 100);
        c.insert(FileId(1), 60, &mut rng).unwrap();
        assert_eq!(c.insert(FileId(1), 60, &mut rng), Some(vec![]));
        assert_eq!(c.used(), 60);
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn remove_frees_space() {
        let mut rng = Pcg64::seeded(1);
        let mut c = cache(EvictionPolicy::Lru, 100);
        c.insert(FileId(1), 60, &mut rng).unwrap();
        assert!(c.remove(FileId(1)));
        assert!(!c.remove(FileId(1)));
        assert_eq!(c.used(), 0);
        assert_eq!(c.insert(FileId(2), 100, &mut rng), Some(vec![]));
    }

    #[test]
    fn multi_eviction_until_fit() {
        let mut rng = Pcg64::seeded(1);
        let mut c = cache(EvictionPolicy::Lru, 100);
        for i in 0..10 {
            c.insert(FileId(i), 10, &mut rng).unwrap();
        }
        let evicted = c.insert(FileId(99), 95, &mut rng).unwrap();
        assert_eq!(evicted.len(), 10);
        assert_eq!(c.len(), 1);
        assert_eq!(c.evictions, 10);
    }

    #[test]
    fn accounting_invariant_under_all_policies() {
        use crate::util::proptest::{property, Gen};
        for policy in [
            EvictionPolicy::Random,
            EvictionPolicy::Fifo,
            EvictionPolicy::Lru,
            EvictionPolicy::Lfu,
        ] {
            property(&format!("cache accounting {policy:?}"), 50, |g: &mut Gen| {
                let cap = g.u64_in(50..200);
                let mut rng = Pcg64::seeded(g.case_seed);
                let mut c = cache(policy, cap);
                let ops = g.usize_in(1..200);
                for _ in 0..ops {
                    let file = FileId(g.u64_in(0..30) as u32);
                    match g.usize_in(0..3) {
                        0 => {
                            let size = g.u64_in(1..60);
                            let _ = c.insert(file, size, &mut rng);
                        }
                        1 => {
                            let _ = c.touch(file);
                        }
                        _ => {
                            let _ = c.remove(file);
                        }
                    }
                    if c.used() > c.capacity() {
                        return Err(format!("used {} > cap {}", c.used(), c.capacity()));
                    }
                    let sum: u64 = c.sizes.values().sum();
                    if sum != c.used() {
                        return Err(format!("sum {} != used {}", sum, c.used()));
                    }
                }
                Ok(())
            });
        }
    }
}
