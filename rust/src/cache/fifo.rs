//! First-In-First-Out eviction: victims in insertion order, accesses
//! ignored.

use super::EvictionState;
use crate::util::prng::Pcg64;
use std::collections::BTreeMap;

/// FIFO book-keeping (insertion-ordered set). The per-slot stamp lives in
/// a dense `Vec` indexed by the owning cache's slot id (0 = untracked).
#[derive(Debug, Default)]
pub struct FifoState {
    clock: u64,
    by_seq: BTreeMap<u64, u32>,
    /// slot → insertion stamp (0 = untracked).
    seq_of: Vec<u64>,
}

impl FifoState {
    /// Empty state.
    pub fn new() -> Self {
        Self::default()
    }
}

impl EvictionState for FifoState {
    fn on_insert(&mut self, slot: u32) {
        // A freed-then-reused slot gets a fresh stamp for its new
        // occupant; on_insert of a live slot never happens (ObjectCache
        // treats a resident re-insert as an access).
        if self.seq_of.len() <= slot as usize {
            self.seq_of.resize(slot as usize + 1, 0);
        }
        self.clock += 1;
        let old = std::mem::replace(&mut self.seq_of[slot as usize], self.clock);
        if old != 0 {
            self.by_seq.remove(&old);
        }
        self.by_seq.insert(self.clock, slot);
    }

    fn on_access(&mut self, _slot: u32) {
        // FIFO ignores recency.
    }

    fn pick_victim(&mut self, _rng: &mut Pcg64) -> Option<u32> {
        self.by_seq.first_key_value().map(|(_, &s)| s)
    }

    fn on_remove(&mut self, slot: u32) {
        let old = std::mem::replace(&mut self.seq_of[slot as usize], 0);
        if old != 0 {
            self.by_seq.remove(&old);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insertion_order_victims() {
        let mut rng = Pcg64::seeded(0);
        let mut s = FifoState::new();
        s.on_insert(1);
        s.on_insert(2);
        s.on_access(1); // ignored
        assert_eq!(s.pick_victim(&mut rng), Some(1));
    }
}
