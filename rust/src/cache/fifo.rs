//! First-In-First-Out eviction: victims in insertion order, accesses
//! ignored.

use super::EvictionState;
use crate::ids::FileId;
use crate::util::prng::Pcg64;
use std::collections::{BTreeMap, HashMap};

/// FIFO book-keeping (insertion-ordered set).
#[derive(Debug, Default)]
pub struct FifoState {
    clock: u64,
    by_seq: BTreeMap<u64, FileId>,
    seq_of: HashMap<FileId, u64>,
}

impl FifoState {
    /// Empty state.
    pub fn new() -> Self {
        Self::default()
    }
}

impl EvictionState for FifoState {
    fn on_insert(&mut self, file: FileId) {
        // Re-insert of an evicted-then-refetched file gets a new slot;
        // on_insert of a resident file never happens (ObjectCache treats
        // that as an access).
        self.clock += 1;
        if let Some(old) = self.seq_of.insert(file, self.clock) {
            self.by_seq.remove(&old);
        }
        self.by_seq.insert(self.clock, file);
    }

    fn on_access(&mut self, _file: FileId) {
        // FIFO ignores recency.
    }

    fn pick_victim(&mut self, _rng: &mut Pcg64) -> Option<FileId> {
        self.by_seq.first_key_value().map(|(_, &f)| f)
    }

    fn on_remove(&mut self, file: FileId) {
        if let Some(seq) = self.seq_of.remove(&file) {
            self.by_seq.remove(&seq);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insertion_order_victims() {
        let mut rng = Pcg64::seeded(0);
        let mut s = FifoState::new();
        s.on_insert(FileId(1));
        s.on_insert(FileId(2));
        s.on_access(FileId(1)); // ignored
        assert_eq!(s.pick_victim(&mut rng), Some(FileId(1)));
    }
}
