//! Random eviction: victim chosen uniformly among resident objects.
//!
//! Swap-remove vector + position map gives O(1) insert/remove/pick.

use super::EvictionState;
use crate::ids::FileId;
use crate::util::prng::Pcg64;
use std::collections::HashMap;

/// Random-eviction book-keeping.
#[derive(Debug, Default)]
pub struct RandomState {
    items: Vec<FileId>,
    pos: HashMap<FileId, usize>,
}

impl RandomState {
    /// Empty state.
    pub fn new() -> Self {
        Self::default()
    }
}

impl EvictionState for RandomState {
    fn on_insert(&mut self, file: FileId) {
        if !self.pos.contains_key(&file) {
            self.pos.insert(file, self.items.len());
            self.items.push(file);
        }
    }

    fn on_access(&mut self, _file: FileId) {
        // Random eviction ignores access patterns.
    }

    fn pick_victim(&mut self, rng: &mut Pcg64) -> Option<FileId> {
        if self.items.is_empty() {
            None
        } else {
            let i = rng.below(self.items.len() as u64) as usize;
            Some(self.items[i])
        }
    }

    fn on_remove(&mut self, file: FileId) {
        if let Some(i) = self.pos.remove(&file) {
            let last = self.items.pop().expect("pos implies non-empty");
            if i < self.items.len() {
                self.items[i] = last;
                self.pos.insert(last, i);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn victims_are_resident_and_removal_is_consistent() {
        let mut rng = Pcg64::seeded(0);
        let mut s = RandomState::new();
        for i in 0..10 {
            s.on_insert(FileId(i));
        }
        for _ in 0..10 {
            let v = s.pick_victim(&mut rng).unwrap();
            assert!(v.0 < 10);
            s.on_remove(v);
        }
        assert_eq!(s.pick_victim(&mut rng), None);
    }

    #[test]
    fn all_objects_eventually_chosen() {
        let mut rng = Pcg64::seeded(1);
        let mut s = RandomState::new();
        for i in 0..4 {
            s.on_insert(FileId(i));
        }
        let mut seen = [false; 4];
        for _ in 0..200 {
            seen[s.pick_victim(&mut rng).unwrap().0 as usize] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }
}
