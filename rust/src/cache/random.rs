//! Random eviction: victim chosen uniformly among resident objects.
//!
//! Swap-remove vector + dense position table gives O(1)
//! insert/remove/pick. `items` preserves the insertion/swap-remove order
//! the pre-slab `Vec<FileId>` implementation had — one slot per resident
//! file, same positions — so the single `rng.below(len)` draw per victim
//! lands on the same object (the sched/core parity contract).

use super::EvictionState;
use crate::util::prng::Pcg64;

const ABSENT: u32 = u32::MAX;

/// Random-eviction book-keeping.
#[derive(Debug, Default)]
pub struct RandomState {
    items: Vec<u32>,
    /// slot → position in `items` (`ABSENT` = untracked).
    pos: Vec<u32>,
}

impl RandomState {
    /// Empty state.
    pub fn new() -> Self {
        Self::default()
    }
}

impl EvictionState for RandomState {
    fn on_insert(&mut self, slot: u32) {
        if self.pos.len() <= slot as usize {
            self.pos.resize(slot as usize + 1, ABSENT);
        }
        if self.pos[slot as usize] == ABSENT {
            self.pos[slot as usize] = self.items.len() as u32;
            self.items.push(slot);
        }
    }

    fn on_access(&mut self, _slot: u32) {
        // Random eviction ignores access patterns.
    }

    fn pick_victim(&mut self, rng: &mut Pcg64) -> Option<u32> {
        if self.items.is_empty() {
            None
        } else {
            let i = rng.below(self.items.len() as u64) as usize;
            Some(self.items[i])
        }
    }

    fn on_remove(&mut self, slot: u32) {
        let i = std::mem::replace(&mut self.pos[slot as usize], ABSENT);
        if i != ABSENT {
            let last = self.items.pop().expect("pos implies non-empty");
            if (i as usize) < self.items.len() {
                self.items[i as usize] = last;
                self.pos[last as usize] = i;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn victims_are_resident_and_removal_is_consistent() {
        let mut rng = Pcg64::seeded(0);
        let mut s = RandomState::new();
        for i in 0..10 {
            s.on_insert(i);
        }
        for _ in 0..10 {
            let v = s.pick_victim(&mut rng).unwrap();
            assert!(v < 10);
            s.on_remove(v);
        }
        assert_eq!(s.pick_victim(&mut rng), None);
    }

    #[test]
    fn all_objects_eventually_chosen() {
        let mut rng = Pcg64::seeded(1);
        let mut s = RandomState::new();
        for i in 0..4 {
            s.on_insert(i);
        }
        let mut seen = [false; 4];
        for _ in 0..200 {
            seen[s.pick_victim(&mut rng).unwrap() as usize] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }
}
