//! Command-line interface for the `datadiff` binary.
//!
//! Hand-rolled argv parsing (the build environment is offline; no clap).
//! Subcommands:
//!
//! * `run (--fig N | --config FILE) [--view SECS] [--csv]` — run one
//!   experiment and print its summary view;
//! * `figures [--scale X] [--quick] [--jobs N] [--check]` — regenerate
//!   every paper figure (2–15) plus the §6 sweeps through the figure
//!   registry, fanning independent runs out across `N` workers; with
//!   `--emit-shards DIR [--shards K]` it instead writes one recorder
//!   snapshot per coordinator shard, and `--merge DIR` recombines the
//!   envelopes losslessly (docs/LIVE.md);
//! * `fig2|fig3|fig4-10|fig11|fig12|fig13|fig14|fig15|sweeps` —
//!   regenerate a single figure (same flags);
//! * `validate-model [--pjrt]` — model-vs-simulator validation, with
//!   `--pjrt` evaluating the model through the AOT JAX/Pallas artifact;
//! * `artifacts-check` — verify the AOT artifacts load and execute;
//! * `chaos [--seed N] [--events M] [--shards K] [--policy P] [--sweep N]
//!   [--quick] [--self-test]` — seeded fault injection against the
//!   shadow-state oracle (docs/CHAOS.md); non-zero exit on any oracle
//!   violation, stall, or fault-free run;
//! * `scenarios [--name N] [--quick] [--scale X] [--jobs N] [--check]` —
//!   run the workload scenario library (docs/WORKLOADS.md) acceptance
//!   tables, all four families or one by name;
//! * `help` — usage.
//!
//! Flag values parse through the typed `FromStr` impls
//! ([`DispatchPolicy`](crate::coordinator::scheduler::DispatchPolicy),
//! [`AllocationPolicy`](crate::coordinator::provisioner::AllocationPolicy),
//! [`EvictionPolicy`](crate::cache::EvictionPolicy)) — the same parsing
//! path the `run`, `chaos`, and `scenarios` commands and the examples
//! share — and every CLI error renders uniformly through
//! [`ConfigError`](crate::config::ConfigError).

use crate::config::ExperimentConfig;
use crate::experiments::{self, fig02, registry};
use crate::{Error, Result};

/// Usage text.
pub const USAGE: &str = "\
datadiff — data diffusion (Raicu et al. 2008) reproduction

USAGE:
  datadiff run (--fig N | --config FILE) [--view SECS] [--csv]
               [--allocation one|add:N|mult:F|all|model] [--shards K]
               [--cache random|fifo|lru|lfu]
  datadiff figures [--scale X] [--quick] [--jobs N] [--check]
                                       regenerate Figures 2-15 + sweeps
  datadiff figures --emit-shards DIR [--shards K] [--scale X] [--quick]
                                       run Figures 4-10 and write one
                                       recorder snapshot per coordinator
                                       shard (JSON-lines envelopes)
  datadiff figures --merge DIR         recombine emitted snapshots and
                                       print the merged summary table
  datadiff fig2|fig3|fig4-10|fig11|fig12|fig13|fig14|fig15|sweeps
                                       one figure (same flags as figures)
  datadiff scenarios [--name N] [--quick] [--scale X] [--jobs N] [--check]
                                       workload scenario library acceptance
                                       (zipf-churn, diurnal, bulk-batch,
                                       pipeline — docs/WORKLOADS.md)
  datadiff validate-model [--pjrt]     model vs simulator (Figure 2 core)
  datadiff artifacts-check             verify AOT artifacts (PJRT)
  datadiff chaos [--seed N] [--events M] [--shards K] [--policy P]
                 [--sweep N] [--scenario F] [--quick] [--self-test]
                                       seeded fault injection vs the oracle
  datadiff help

Figures 4-10 presets: 4=first-available/GPFS, 5-8=good-cache-compute with
1/1.5/2/4GB caches, 9=max-cache-hit, 10=max-compute-util. --scale shrinks
workloads for quick runs (default 1.0 = paper scale); --quick is shorthand
for --scale 0.02 (the CI smoke scale). --jobs N fans independent runs out
across N threads (default: all cores; merged tables are byte-identical for
any N). --check fails with a non-zero exit on NaN cells or empty tables —
the CI figures-smoke gate. --allocation overrides the dynamic resource
provisioner's allocation policy (one node, fixed batch of N, growth
factor F, everything at once — §5.2.5 — or `model`, which runs the §3
performance model online as a closed-loop controller and tracks its
solved node target each tick, docs/PROVISIONING.md); the same policies
drive the live engine through the shared coordinator core. --shards K replicates
the coordinator K ways behind a router (task stream partitioned by
dominant-file hash, executors assigned per shard, GPFS misses rewritten
into cross-shard peer fetches — docs/SHARDING.md); K=1 (default) is
bit-identical to the single coordinator, and sharded runs print the
shard/* counter block after the summary. figures --emit-shards DIR runs
the Figure 4-10 set and writes each coordinator shard's recorder as a
JSON-lines snapshot envelope (one file per shard); figures --merge DIR
reads the envelopes back and recombines them losslessly, so the merged
summary is bit-identical to the in-process run — the file transport a
multi-process coordinator deployment rides on (docs/LIVE.md).

chaos runs a seeded fault-injection schedule (dropped/delayed/reordered
notifications, executors killed mid-fetch/mid-compute, stalled and partial
transfers, shard partitions) through the coordinator while a shadow-state
oracle checks exactly-once terminals, replica accounting, and that no
dispatch or fetch touches a dead executor. --sweep N runs N consecutive
seeds cycling through all 5 policies x shards 1 and 4 x allocation
mult:2 and model; --quick shrinks
each run to the CI smoke size; --self-test breaks an invariant on purpose
and prints the seed + fault plan + trailing trace dump. --scenario F
draws the task stream from a scenario-library family instead of the
built-in uniform stream (dependency-gated for pipelines). Exit is
non-zero if any run violates the oracle, stalls, or injects zero
faults — reproduce any failure with `datadiff chaos --seed N
[--scenario F]` (docs/CHAOS.md).

scenarios runs each workload family (heavy-tailed popularity with hot-set
churn, diurnal multi-user traffic with flash crowds, bulk batch
submission, multi-stage pipelines with dependency edges) end-to-end at
shards 1 and 4 and prints an acceptance table per family: task/edge
counts, the workload fingerprint, and the run's efficiency and hit-rate
split. --name picks one family; --quick/--scale/--jobs/--check behave as
for `figures` (docs/WORKLOADS.md).";

/// Parsed command line.
#[derive(Debug)]
pub enum Command {
    /// Run one experiment.
    Run {
        /// Experiment config.
        config: Box<ExperimentConfig>,
        /// Print the time-series view sampled every N seconds.
        view_every_s: usize,
        /// Also write CSVs.
        csv: bool,
    },
    /// Regenerate a set of figures.
    Figures {
        /// Which figures ("all", "2", "3", "4-10", "11"…"15", "sweeps").
        which: String,
        /// Workload scale factor.
        scale: f64,
        /// Fan-out width (None = all cores).
        jobs: Option<usize>,
        /// Fail on NaN cells / empty tables (the CI smoke gate).
        check: bool,
        /// Coordinator shards for `--emit-shards` runs (None = preset).
        shards: Option<usize>,
        /// Run Figures 4-10 and write one recorder snapshot per
        /// coordinator shard into this directory (JSON-lines envelopes,
        /// docs/LIVE.md) instead of printing tables.
        emit_shards: Option<std::path::PathBuf>,
        /// Recombine previously emitted snapshots from this directory
        /// and print the merged summary table.
        merge: Option<std::path::PathBuf>,
    },
    /// Model validation.
    ValidateModel {
        /// Evaluate through the PJRT artifact as well.
        pjrt: bool,
    },
    /// Artifact smoke test.
    ArtifactsCheck,
    /// Seeded chaos run(s) against the shadow-state oracle.
    Chaos {
        /// Base seed (`--sweep` runs seed, seed+1, …).
        seed: u64,
        /// Events per run (None = the chaos config's default).
        events: Option<usize>,
        /// Shard count (None = default; ignored under --sweep, which
        /// pins its own K ∈ {1, 4} cycle).
        shards: Option<usize>,
        /// Dispatch policy (None = default; ignored under --sweep).
        policy: Option<crate::coordinator::scheduler::DispatchPolicy>,
        /// Sweep width: N consecutive seeds cycling through all five
        /// policies × shards {1, 4}.
        sweep: Option<usize>,
        /// Scenario-library task stream (None = the built-in stream).
        scenario: Option<crate::config::ScenarioSpec>,
        /// CI smoke size (fewer events, smaller fleet).
        quick: bool,
        /// Deliberately break an invariant and print the oracle dump.
        self_test: bool,
    },
    /// Run the workload scenario library acceptance tables.
    Scenarios {
        /// One family by name (None = all four).
        name: Option<String>,
        /// Workload scale factor (as for `figures`).
        scale: f64,
        /// Fan-out width (None = all cores).
        jobs: Option<usize>,
        /// Fail on NaN cells / empty tables (the CI smoke gate).
        check: bool,
    },
    /// Print usage.
    Help,
}

/// Parse argv (without the program name).
pub fn parse(args: &[String]) -> Result<Command> {
    let mut it = args.iter().peekable();
    let cmd = match it.next().map(String::as_str) {
        None | Some("help") | Some("--help") | Some("-h") => return Ok(Command::Help),
        Some(c) => c,
    };
    let mut flags: Vec<(&str, Option<&str>)> = Vec::new();
    while let Some(a) = it.next() {
        if let Some(name) = a.strip_prefix("--") {
            let takes_value = matches!(
                name,
                "fig" | "config" | "view" | "scale" | "jobs" | "allocation" | "shards"
                    | "seed" | "events" | "policy" | "sweep" | "name" | "cache" | "scenario"
                    | "emit-shards" | "merge"
            );
            let value = if takes_value {
                Some(
                    it.next()
                        .ok_or_else(|| Error::config(format!("--{name} needs a value")))?
                        .as_str(),
                )
            } else {
                None
            };
            flags.push((name, value));
        } else {
            return Err(Error::config(format!("unexpected argument `{a}`")));
        }
    }
    let get = |name: &str| flags.iter().find(|(n, _)| *n == name).map(|(_, v)| *v);

    match cmd {
        "run" => {
            let mut config = if let Some(Some(fig)) = get("fig") {
                let n: u32 = fig
                    .parse()
                    .map_err(|_| Error::config(format!("bad figure `{fig}`")))?;
                ExperimentConfig::paper_fig(n)
                    .ok_or_else(|| Error::config(format!("no preset for figure {n}")))?
            } else if let Some(Some(path)) = get("config") {
                ExperimentConfig::from_file(std::path::Path::new(path))?
            } else {
                return Err(Error::config("run needs --fig N or --config FILE"));
            };
            if let Some(Some(alloc)) = get("allocation") {
                config.provisioner.allocation = alloc
                    .parse::<crate::coordinator::provisioner::AllocationPolicy>()
                    .map_err(Error::config)?;
            }
            if let Some(Some(cache)) = get("cache") {
                config.cache.policy = cache
                    .parse::<crate::cache::EvictionPolicy>()
                    .map_err(Error::config)?;
            }
            if let Some(Some(k)) = get("shards") {
                let n: usize = k
                    .parse()
                    .map_err(|_| Error::config(format!("bad --shards `{k}`")))?;
                if n == 0 {
                    return Err(Error::config("--shards must be >= 1"));
                }
                config.cluster.shards = n;
                // Full cross-field validation (quota per shard, static
                // fleets) happens in ExperimentConfig::validate at run.
            }
            let view_every_s = match get("view") {
                Some(Some(v)) => v
                    .parse()
                    .map_err(|_| Error::config(format!("bad --view `{v}`")))?,
                _ => 120,
            };
            Ok(Command::Run {
                config: Box::new(config),
                view_every_s,
                csv: get("csv").is_some(),
            })
        }
        "figures" => {
            let emit_shards = get("emit-shards").flatten().map(std::path::PathBuf::from);
            let merge = get("merge").flatten().map(std::path::PathBuf::from);
            if emit_shards.is_some() && merge.is_some() {
                return Err(Error::config(
                    "--emit-shards and --merge are mutually exclusive",
                ));
            }
            let shards = match get("shards") {
                Some(Some(s)) => Some(parse_positive(s, "shards")?),
                _ => None,
            };
            // `--shards` is meaningful here only as the fan-out width of
            // an `--emit-shards` run; otherwise keep the loud rejection.
            if shards.is_some() && emit_shards.is_none() {
                reject_shards_flag(&get)?;
            }
            Ok(Command::Figures {
                which: "all".into(),
                scale: parse_figures_scale(&get)?,
                jobs: parse_jobs(get("jobs"))?,
                check: get("check").is_some(),
                shards,
                emit_shards,
                merge,
            })
        }
        "fig2" | "fig3" | "fig4-10" | "fig11" | "fig12" | "fig13" | "fig14" | "fig15"
        | "sweeps" => {
            reject_shards_flag(&get)?;
            if get("emit-shards").is_some() || get("merge").is_some() {
                return Err(Error::config(
                    "--emit-shards/--merge apply to `figures` only",
                ));
            }
            Ok(Command::Figures {
                which: cmd.trim_start_matches("fig").into(),
                scale: parse_figures_scale(&get)?,
                jobs: parse_jobs(get("jobs"))?,
                check: get("check").is_some(),
                shards: None,
                emit_shards: None,
                merge: None,
            })
        }
        "validate-model" => Ok(Command::ValidateModel {
            pjrt: get("pjrt").is_some(),
        }),
        "artifacts-check" => Ok(Command::ArtifactsCheck),
        "scenarios" => Ok(Command::Scenarios {
            name: get("name").flatten().map(String::from),
            scale: parse_figures_scale(&get)?,
            jobs: parse_jobs(get("jobs"))?,
            check: get("check").is_some(),
        }),
        "chaos" => {
            let seed = match get("seed") {
                Some(Some(s)) => s
                    .parse()
                    .map_err(|_| Error::config(format!("bad --seed `{s}`")))?,
                _ => 1,
            };
            let events = match get("events") {
                Some(Some(s)) => Some(parse_positive(s, "events")?),
                _ => None,
            };
            let shards = match get("shards") {
                Some(Some(s)) => Some(parse_positive(s, "shards")?),
                _ => None,
            };
            let policy = match get("policy") {
                Some(Some(s)) => Some(
                    s.parse::<crate::coordinator::scheduler::DispatchPolicy>()
                        .map_err(Error::config)?,
                ),
                _ => None,
            };
            let sweep = match get("sweep") {
                Some(Some(s)) => Some(parse_positive(s, "sweep")?),
                _ => None,
            };
            let scenario = match get("scenario") {
                Some(Some(s)) => Some(crate::config::ScenarioSpec::preset(s).ok_or_else(|| {
                    Error::config(format!(
                        "unknown scenario `{s}` (expected one of: {})",
                        crate::config::ScenarioSpec::CATALOG.join(", ")
                    ))
                })?),
                _ => None,
            };
            Ok(Command::Chaos {
                seed,
                events,
                shards,
                policy,
                sweep,
                scenario,
                quick: get("quick").is_some(),
                self_test: get("self-test").is_some(),
            })
        }
        other => Err(Error::config(format!("unknown command `{other}`"))),
    }
}

/// The `--quick` workload scale: small enough for a CI smoke run, large
/// enough that every experiment clears its minimum-task floor.
pub const QUICK_SCALE: f64 = 0.02;

fn parse_figures_scale<'a>(get: &impl Fn(&str) -> Option<Option<&'a str>>) -> Result<f64> {
    if let Some(Some(s)) = get("scale") {
        return s
            .parse()
            .map_err(|_| Error::config(format!("bad --scale `{s}`")));
    }
    Ok(if get("quick").is_some() { QUICK_SCALE } else { 1.0 })
}

/// `--shards` only applies to `run` (figure presets pin their cluster
/// shape); silently ignoring it would let a user believe they
/// benchmarked the sharded router. Reject it loudly instead.
fn reject_shards_flag<'a>(get: &impl Fn(&str) -> Option<Option<&'a str>>) -> Result<()> {
    if get("shards").is_some() {
        return Err(Error::config(
            "--shards applies to `run` only; use `run --fig N --shards K` \
             (figure-suite workloads pin their cluster shape)",
        ));
    }
    Ok(())
}

fn parse_positive(s: &str, flag: &str) -> Result<usize> {
    let n: usize = s
        .parse()
        .map_err(|_| Error::config(format!("bad --{flag} `{s}`")))?;
    if n == 0 {
        return Err(Error::config(format!("--{flag} must be >= 1")));
    }
    Ok(n)
}

fn parse_jobs(v: Option<Option<&str>>) -> Result<Option<usize>> {
    match v {
        Some(Some(s)) => {
            let n: usize = s
                .parse()
                .map_err(|_| Error::config(format!("bad --jobs `{s}`")))?;
            if n == 0 {
                return Err(Error::config("--jobs must be >= 1"));
            }
            Ok(Some(n))
        }
        _ => Ok(None),
    }
}

/// Execute a parsed command; returns the process exit code.
pub fn execute(cmd: Command) -> Result<i32> {
    match cmd {
        Command::Help => {
            println!("{USAGE}");
            Ok(0)
        }
        Command::Run {
            config,
            view_every_s,
            csv,
        } => {
            // Validate up front so a bad --shards/--config combination is
            // a clean CLI error, not a panic inside the engine.
            config.validate()?;
            let r = experiments::run_summary_experiment(&config);
            let view = experiments::summary_view_table(&r, view_every_s);
            view.print();
            let t = experiments::summary_table(std::slice::from_ref(&r));
            t.print();
            print_shard_counters(&r.shard);
            if csv {
                let p1 = view.write_csv(&format!("{}_view", r.name))?;
                let p2 = t.write_csv(&format!("{}_summary", r.name))?;
                println!("wrote {} and {}", p1.display(), p2.display());
            }
            Ok(0)
        }
        Command::Figures {
            which,
            scale,
            jobs,
            check,
            shards,
            emit_shards,
            merge,
        } => {
            if let Some(dir) = merge {
                run_merge(&dir)?;
            } else if let Some(dir) = emit_shards {
                run_emit_shards(scale, shards, &dir)?;
            } else {
                run_figures(&which, scale, jobs, check)?;
            }
            Ok(0)
        }
        Command::ValidateModel { pjrt } => {
            let out = fig02::run(0.1);
            for t in fig02::tables(&out) {
                t.print();
            }
            if pjrt {
                validate_via_pjrt(&out)?;
            }
            Ok(0)
        }
        Command::ArtifactsCheck => {
            let a = crate::runtime::Artifacts::open_default()?;
            println!("PJRT platform: {}", a.platform());
            let s = a.stacking()?;
            let frame =
                crate::runtime::shapes::STACK_H * crate::runtime::shapes::STACK_W;
            let res = s.stack(&vec![1.0; frame], &[2.0])?;
            assert!((res.mean - 1.0).abs() < 1e-5);
            println!("stacking artifact: OK (mean {:.3})", res.mean);
            let m = a.model_eval()?;
            let p = m.eval(&[crate::model::ModelInputs {
                num_tasks: 1000.0,
                cpus: 64.0,
                mu_s: 0.01,
                overhead_s: 0.001,
                object_bytes: 1e7,
                arrival_rate: f64::INFINITY,
                persistent_bps: 5.5e8,
                transient_bps: 2e8,
                p_miss: 0.1,
                p_local: 0.9,
            }])?;
            println!(
                "model_eval artifact: OK (E {:.3}, S {:.1})",
                p[0].efficiency, p[0].speedup
            );
            Ok(0)
        }
        Command::Chaos {
            seed,
            events,
            shards,
            policy,
            sweep,
            scenario,
            quick,
            self_test,
        } => run_chaos_command(seed, events, shards, policy, sweep, scenario, quick, self_test),
        Command::Scenarios {
            name,
            scale,
            jobs,
            check,
        } => {
            run_scenarios_command(name.as_deref(), scale, jobs, check)?;
            Ok(0)
        }
    }
}

/// `datadiff scenarios`: run the workload scenario library's acceptance
/// figures (all four families, or one via `--name`), printing one table
/// per family. `--check` applies the same output gate as `figures
/// --check` — the CI `scenarios-smoke` command.
fn run_scenarios_command(
    name: Option<&str>,
    scale: f64,
    jobs: Option<usize>,
    check: bool,
) -> Result<()> {
    use crate::config::ScenarioSpec;
    let ids: Vec<String> = match name {
        Some(n) => {
            let spec = ScenarioSpec::preset(n).ok_or_else(|| {
                Error::config(format!(
                    "unknown scenario `{n}` (expected one of: {})",
                    ScenarioSpec::CATALOG.join(", ")
                ))
            })?;
            vec![experiments::scenarios::figure_id(&spec)]
        }
        None => ScenarioSpec::CATALOG
            .iter()
            .map(|n| format!("scenario-{n}"))
            .collect(),
    };
    let ids: Vec<&str> = ids.iter().map(String::as_str).collect();
    let jobs = jobs.unwrap_or_else(crate::util::par::default_jobs);
    crate::info!(
        "scenario suite: {} famil(ies) at scale {scale} with {jobs} job(s)",
        ids.len()
    );
    let outputs = registry::run_selected(&ids, scale, jobs);
    for o in &outputs {
        for t in &o.tables {
            t.print();
        }
    }
    if check {
        registry::check_outputs(&outputs).map_err(Error::SimInvariant)?;
        println!(
            "scenario check OK: {} famil(ies), {} tables, no NaN/empty output",
            outputs.len(),
            outputs.iter().map(|o| o.tables.len()).sum::<usize>()
        );
    }
    Ok(())
}

/// `datadiff chaos`: seeded fault schedules against the shadow-state
/// oracle, one summary line per run. Exit 1 on any non-clean run (oracle
/// violation, stall, or a schedule that injected zero faults).
#[allow(clippy::too_many_arguments)]
fn run_chaos_command(
    seed: u64,
    events: Option<usize>,
    shards: Option<usize>,
    policy: Option<crate::coordinator::scheduler::DispatchPolicy>,
    sweep: Option<usize>,
    scenario: Option<crate::config::ScenarioSpec>,
    quick: bool,
    self_test: bool,
) -> Result<i32> {
    use crate::chaos::{self, ChaosConfig};
    use crate::coordinator::scheduler::DispatchPolicy;
    if self_test {
        println!("{}", chaos::oracle_self_test());
        println!("\noracle self-test OK: the broken invariant was caught and dumped");
        return Ok(0);
    }
    let base = |s: u64| {
        let mut c = if quick {
            ChaosConfig::quick(s)
        } else {
            ChaosConfig::new(s)
        };
        if let Some(m) = events {
            c.events = m;
        }
        c.scenario = scenario.clone();
        c
    };
    let mut reports = Vec::new();
    if let Some(n) = sweep {
        // N consecutive seeds cycling through all 5 policies × K ∈ {1, 4}
        // × allocation ∈ {mult:2, model}, so any sweep of >= 20 seeds
        // covers every combination.
        use crate::coordinator::provisioner::AllocationPolicy;
        let combos: Vec<(DispatchPolicy, usize, AllocationPolicy)> = DispatchPolicy::ALL
            .iter()
            .flat_map(|&p| {
                [
                    (p, 1usize, AllocationPolicy::Multiplicative(2.0)),
                    (p, 4, AllocationPolicy::Multiplicative(2.0)),
                    (p, 1, AllocationPolicy::Model),
                    (p, 4, AllocationPolicy::Model),
                ]
            })
            .collect();
        for i in 0..n as u64 {
            let (p, k, a) = combos[i as usize % combos.len()];
            let mut c = base(seed + i);
            c.policy = p;
            c.shards = k;
            c.allocation = a;
            reports.push(chaos::run_chaos(&c));
        }
    } else {
        let mut c = base(seed);
        if let Some(k) = shards {
            c.shards = k;
        }
        if let Some(p) = policy {
            c.policy = p;
        }
        reports.push(chaos::run_chaos(&c));
    }
    let mut bad = 0usize;
    for r in &reports {
        println!("{}", r.summary_line());
        if !r.clean() {
            bad += 1;
            if let Some(d) = &r.dump {
                eprintln!("{d}");
            } else if r.stalled {
                eprintln!(
                    "chaos: seed {} stalled before every event reached a terminal state",
                    r.seed
                );
            } else {
                eprintln!("chaos: seed {} injected zero faults (schedule bug)", r.seed);
            }
        }
    }
    if bad > 0 {
        eprintln!("chaos: {bad}/{} run(s) NOT clean", reports.len());
        return Ok(1);
    }
    println!(
        "chaos: {} run(s) clean — reproduce any schedule with --seed N",
        reports.len()
    );
    Ok(0)
}

/// Print the router's cross-shard accounting after a sharded run (the
/// counter glossary lives in README "Running sharded"). Quiet for plain
/// single-coordinator runs.
fn print_shard_counters(shard: &crate::metrics::ShardCounters) {
    if shard.shards <= 1 {
        return;
    }
    println!("\nshard counters ({} shards):", shard.shards);
    println!("  shard/router_events          {:>12}", shard.router_events);
    println!("  shard/cross_fetches          {:>12}", shard.cross_fetches);
    println!("  shard/cross_bytes            {:>12}", shard.cross_bytes);
    println!(
        "  shard/cross_fetches_per_task {:>12.4}",
        shard.cross_fetches_per_task()
    );
    println!(
        "  shard/cross_release_deferrals {:>11}",
        shard.cross_release_deferrals
    );
    println!("  shard/exec_failures          {:>12}", shard.exec_failures);
    for (i, t) in shard.per_shard.iter().enumerate() {
        println!(
            "  shard {i}: routed {:>8}  dispatched {:>8}  cross in/out {:>6}/{:<6}",
            t.tasks_routed, t.dispatches, t.cross_in, t.cross_out
        );
    }
}

/// `datadiff figures --emit-shards DIR`: run the Figure 4-10 experiment
/// set (at `--scale`, optionally re-sharded to `--shards K`) and write
/// one recorder snapshot envelope per coordinator shard — the file leg
/// of the shard fan-out/merge transport (docs/LIVE.md).
fn run_emit_shards(scale: f64, shards: Option<usize>, dir: &std::path::Path) -> Result<()> {
    let mut cfgs = experiments::fig04_10::configs(scale);
    if let Some(k) = shards {
        for c in &mut cfgs {
            c.cluster.shards = k;
        }
    }
    // Validate up front so a bad --shards value is a clean CLI error.
    for c in &cfgs {
        c.validate()?;
    }
    let paths = experiments::shardio::emit_shards(&cfgs, dir)?;
    println!("wrote {} shard snapshot(s) under {}", paths.len(), dir.display());
    Ok(())
}

/// `datadiff figures --merge DIR`: recombine emitted shard snapshots
/// (lossless `Recorder::absorb`) and print one merged summary row per
/// run — bit-identical to the same run merged in-process.
fn run_merge(dir: &std::path::Path) -> Result<()> {
    use crate::report::{f, pct, Table};
    let merged = experiments::shardio::merge_dir(dir)?;
    let mut t = Table::new(
        "merged shard snapshots",
        &[
            "run",
            "shards",
            "WET(s)",
            "eff",
            "hit-local",
            "hit-global",
            "miss",
            "tasks",
        ],
    );
    for m in &merged {
        let s = m.recorder.summarize(m.ideal_wet_s);
        t.row(vec![
            m.name.clone(),
            m.shards.to_string(),
            f(s.workload_execution_time_s, 0),
            pct(s.efficiency),
            pct(s.hit_local_rate),
            pct(s.hit_global_rate),
            pct(s.miss_rate),
            s.tasks_completed.to_string(),
        ]);
    }
    t.print();
    println!("merged {} run(s) from {}", merged.len(), dir.display());
    Ok(())
}

fn run_figures(which: &str, scale: f64, jobs: Option<usize>, check: bool) -> Result<()> {
    let ids: Vec<&str> = match which {
        // `figures` keeps its paper-reproduction contract: the workload
        // scenario acceptance figures run via `datadiff scenarios`.
        "all" => registry::all_ids()
            .into_iter()
            .filter(|id| !id.starts_with("scenario-"))
            .collect(),
        "2" => vec!["fig02"],
        "3" => vec!["fig03"],
        "4-10" => vec!["fig04-10"],
        "11" => vec!["fig11"],
        "12" => vec!["fig12"],
        "13" => vec!["fig13"],
        "14" => vec!["fig14"],
        "15" => vec!["fig15"],
        "sweeps" => vec!["sweep-eviction", "sweep-dispatch", "sweep-allocation"],
        other => return Err(Error::config(format!("unknown figure set `{other}`"))),
    };
    let jobs = jobs.unwrap_or_else(crate::util::par::default_jobs);
    crate::info!(
        "figure suite: {} figure(s) at scale {scale} with {jobs} job(s)",
        ids.len()
    );
    let outputs = registry::run_selected(&ids, scale, jobs);
    let mut csvs: Vec<std::path::PathBuf> = Vec::new();
    for o in &outputs {
        for (i, t) in o.tables.iter().enumerate() {
            t.print();
            let base = o.id.replace('-', "_");
            let name = if o.tables.len() == 1 {
                base
            } else {
                format!("{base}_{i}")
            };
            if let Ok(p) = t.write_csv(&name) {
                csvs.push(p);
            }
        }
    }
    if !csvs.is_empty() {
        println!("\nCSV outputs under target/figures/:");
        for p in csvs {
            println!("  {}", p.display());
        }
    }
    if check {
        registry::check_outputs(&outputs).map_err(Error::SimInvariant)?;
        println!(
            "figure check OK: {} figures, {} tables, no NaN/empty output",
            outputs.len(),
            outputs.iter().map(|o| o.tables.len()).sum::<usize>()
        );
    }
    Ok(())
}

/// Re-predict the Figure 2 points through the AOT PJRT artifact and
/// report the Rust-vs-PJRT agreement (they implement the same model).
fn validate_via_pjrt(out: &fig02::Fig02Output) -> Result<()> {
    let a = crate::runtime::Artifacts::open_default()?;
    let exe = a.model_eval()?;
    let points: Vec<crate::model::ModelInputs> = out
        .cpu_sweep
        .iter()
        .map(|p| {
            let cfg = fig02::validation_config(p.cpus, p.locality, 2_000);
            crate::model::ModelInputs::from_config(&cfg)
        })
        .collect();
    let preds = exe.eval(&points)?;
    let mut worst: f64 = 0.0;
    for (inp, pjrt) in points.iter().zip(&preds) {
        let rust = crate::model::predict(inp);
        let err = (pjrt.w - rust.w).abs() / rust.w.max(1e-9);
        worst = worst.max(err);
    }
    println!(
        "\nPJRT model artifact vs Rust model: worst relative ΔW = {:.4}% over {} points",
        worst * 100.0,
        preds.len()
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_run_with_fig() {
        match parse(&args("run --fig 7 --view 60 --csv")).unwrap() {
            Command::Run {
                config,
                view_every_s,
                csv,
            } => {
                assert_eq!(config.name, "fig07-gcc-2gb");
                assert_eq!(view_every_s, 60);
                assert!(csv);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn parses_run_allocation_override() {
        use crate::coordinator::provisioner::AllocationPolicy;
        match parse(&args("run --fig 7 --allocation all")).unwrap() {
            Command::Run { config, .. } => {
                assert_eq!(config.provisioner.allocation, AllocationPolicy::AllAtOnce);
            }
            other => panic!("{other:?}"),
        }
        match parse(&args("run --fig 7 --allocation mult:1.5")).unwrap() {
            Command::Run { config, .. } => {
                assert_eq!(
                    config.provisioner.allocation,
                    AllocationPolicy::Multiplicative(1.5)
                );
            }
            other => panic!("{other:?}"),
        }
        match parse(&args("run --fig 7 --allocation model")).unwrap() {
            Command::Run { config, .. } => {
                assert_eq!(config.provisioner.allocation, AllocationPolicy::Model);
            }
            other => panic!("{other:?}"),
        }
        assert!(parse(&args("run --fig 7 --allocation banana")).is_err());
        assert!(parse(&args("run --fig 7 --allocation")).is_err());
    }

    #[test]
    fn parses_run_shards_override() {
        match parse(&args("run --fig 7 --shards 4")).unwrap() {
            Command::Run { config, .. } => {
                assert_eq!(config.cluster.shards, 4);
                config.validate().unwrap();
            }
            other => panic!("{other:?}"),
        }
        // Default stays the single coordinator.
        match parse(&args("run --fig 7")).unwrap() {
            Command::Run { config, .. } => assert_eq!(config.cluster.shards, 1),
            other => panic!("{other:?}"),
        }
        assert!(parse(&args("run --fig 7 --shards 0")).is_err());
        assert!(parse(&args("run --fig 7 --shards many")).is_err());
        assert!(parse(&args("run --fig 7 --shards")).is_err());
        // Loud rejection instead of silent ignore on figure commands.
        assert!(parse(&args("figures --quick --shards 4")).is_err());
        assert!(parse(&args("fig4-10 --shards 4")).is_err());
    }

    #[test]
    fn parses_figures_and_single_fig() {
        assert!(matches!(
            parse(&args("figures --scale 0.1")).unwrap(),
            Command::Figures { scale, .. } if (scale - 0.1).abs() < 1e-12
        ));
        assert!(matches!(
            parse(&args("fig14")).unwrap(),
            Command::Figures { which, .. } if which == "14"
        ));
        assert!(matches!(
            parse(&args("sweeps")).unwrap(),
            Command::Figures { which, .. } if which == "sweeps"
        ));
    }

    #[test]
    fn parses_quick_jobs_and_check() {
        match parse(&args("figures --quick --jobs 4 --check")).unwrap() {
            Command::Figures {
                which,
                scale,
                jobs,
                check,
                shards,
                emit_shards,
                merge,
            } => {
                assert_eq!(which, "all");
                assert!((scale - QUICK_SCALE).abs() < 1e-12);
                assert_eq!(jobs, Some(4));
                assert!(check);
                assert!(shards.is_none() && emit_shards.is_none() && merge.is_none());
            }
            other => panic!("{other:?}"),
        }
        // Explicit --scale wins over --quick; defaults are None/false.
        assert!(matches!(
            parse(&args("figures --quick --scale 0.5")).unwrap(),
            Command::Figures { scale, .. } if (scale - 0.5).abs() < 1e-12
        ));
        assert!(matches!(
            parse(&args("figures")).unwrap(),
            Command::Figures { jobs: None, check: false, .. }
        ));
        assert!(parse(&args("figures --jobs 0")).is_err());
        assert!(parse(&args("figures --jobs many")).is_err());
    }

    #[test]
    fn parses_figures_emit_and_merge() {
        use std::path::Path;
        // --shards is allowed alongside --emit-shards (it is the
        // fan-out width of the emitted runs)…
        match parse(&args("figures --quick --emit-shards out --shards 4")).unwrap() {
            Command::Figures {
                shards,
                emit_shards,
                merge,
                ..
            } => {
                assert_eq!(shards, Some(4));
                assert_eq!(emit_shards.as_deref(), Some(Path::new("out")));
                assert!(merge.is_none());
            }
            other => panic!("{other:?}"),
        }
        match parse(&args("figures --merge out")).unwrap() {
            Command::Figures {
                emit_shards, merge, ..
            } => {
                assert!(emit_shards.is_none());
                assert_eq!(merge.as_deref(), Some(Path::new("out")));
            }
            other => panic!("{other:?}"),
        }
        // …but stays rejected without it (see parses_run_shards_override),
        // and the modes are mutually exclusive.
        assert!(parse(&args("figures --emit-shards out --merge out")).is_err());
        assert!(parse(&args("figures --emit-shards out --shards 0")).is_err());
        assert!(parse(&args("figures --emit-shards")).is_err());
        assert!(parse(&args("figures --merge")).is_err());
        // Single-figure commands reject the transport flags loudly.
        assert!(parse(&args("fig4-10 --emit-shards out")).is_err());
        assert!(parse(&args("fig14 --merge out")).is_err());
    }

    #[test]
    fn parses_chaos() {
        use crate::coordinator::scheduler::DispatchPolicy;
        match parse(&args("chaos --seed 9 --events 100 --shards 4 --policy mch --quick")).unwrap()
        {
            Command::Chaos {
                seed,
                events,
                shards,
                policy,
                sweep,
                scenario,
                quick,
                self_test,
            } => {
                assert_eq!(seed, 9);
                assert_eq!(events, Some(100));
                assert_eq!(shards, Some(4));
                assert_eq!(policy, Some(DispatchPolicy::MaxCacheHit));
                assert_eq!(sweep, None);
                assert_eq!(scenario, None);
                assert!(quick);
                assert!(!self_test);
            }
            other => panic!("{other:?}"),
        }
        // Defaults: seed 1, everything else inherited from ChaosConfig.
        match parse(&args("chaos")).unwrap() {
            Command::Chaos {
                seed,
                events,
                shards,
                policy,
                sweep,
                scenario,
                quick,
                self_test,
            } => {
                assert_eq!(seed, 1);
                assert!(events.is_none() && shards.is_none() && policy.is_none());
                assert!(sweep.is_none() && scenario.is_none() && !quick && !self_test);
            }
            other => panic!("{other:?}"),
        }
        assert!(matches!(
            parse(&args("chaos --sweep 32 --self-test")).unwrap(),
            Command::Chaos { sweep: Some(32), self_test: true, .. }
        ));
        // Scenario streams parse through the catalog presets.
        match parse(&args("chaos --scenario zipf_churn")).unwrap() {
            Command::Chaos { scenario, .. } => {
                assert_eq!(scenario.map(|s| s.name()), Some("zipf-churn"));
            }
            other => panic!("{other:?}"),
        }
        assert!(parse(&args("chaos --seed banana")).is_err());
        assert!(parse(&args("chaos --events 0")).is_err());
        assert!(parse(&args("chaos --sweep 0")).is_err());
        assert!(parse(&args("chaos --policy banana")).is_err());
        assert!(parse(&args("chaos --scenario banana")).is_err());
    }

    #[test]
    fn parses_run_cache_override() {
        use crate::cache::EvictionPolicy;
        match parse(&args("run --fig 7 --cache lfu")).unwrap() {
            Command::Run { config, .. } => {
                assert_eq!(config.cache.policy, EvictionPolicy::Lfu);
            }
            other => panic!("{other:?}"),
        }
        assert!(parse(&args("run --fig 7 --cache banana")).is_err());
    }

    #[test]
    fn parses_scenarios() {
        match parse(&args("scenarios --name zipf-churn --quick --jobs 2 --check")).unwrap() {
            Command::Scenarios {
                name,
                scale,
                jobs,
                check,
            } => {
                assert_eq!(name.as_deref(), Some("zipf-churn"));
                assert!((scale - QUICK_SCALE).abs() < 1e-12);
                assert_eq!(jobs, Some(2));
                assert!(check);
            }
            other => panic!("{other:?}"),
        }
        assert!(matches!(
            parse(&args("scenarios")).unwrap(),
            Command::Scenarios { name: None, jobs: None, check: false, .. }
        ));
        // Family names resolve lazily at execute time; a bogus one is a
        // uniform typed config error there.
        assert!(run_scenarios_command(Some("banana"), 0.02, Some(1), false).is_err());
        assert!(parse(&args("scenarios --name")).is_err());
    }

    #[test]
    fn rejects_bad_input() {
        assert!(parse(&args("run")).is_err());
        assert!(parse(&args("run --fig banana")).is_err());
        assert!(parse(&args("bogus")).is_err());
        assert!(parse(&args("run stray")).is_err());
        assert!(parse(&args("run --fig")).is_err());
    }

    #[test]
    fn help_paths() {
        assert!(matches!(parse(&[]).unwrap(), Command::Help));
        assert!(matches!(parse(&args("help")).unwrap(), Command::Help));
        assert!(matches!(parse(&args("--help")).unwrap(), Command::Help));
    }
}
