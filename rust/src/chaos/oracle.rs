//! Shadow-state oracle for the chaos harness.
//!
//! The oracle mirrors the coordinator's externally visible promises in a
//! deliberately *independent* model — plain maps, no scheduler logic —
//! and checks them after every event the [`driver`](super) processes:
//!
//! 1. **Exactly-once terminal states** — every submitted task reaches
//!    `completed` or `failed permanently` exactly once, across any
//!    number of re-queues and retries (§4.2 replay policy).
//! 2. **Replica accounting** — the location index, the per-executor
//!    cache models and the peer-serving refcounts agree, checked via
//!    [`ShardedCoordinator::check_integrity`] (which in turn runs every
//!    shard's [`CoordinatorCore::check_integrity`]).
//! 3. **No dispatch to the dead** — no `Notify`/`Fetch`/`Compute`
//!    effect may name an executor the driver has killed or released.
//! 4. **No effect references a scrubbed cache slot** — a fetch may only
//!    name a live peer as its source, and no executor is released while
//!    it is still the in-flight source of somebody's transfer (the
//!    `Effect::Release` deferral contract).
//!
//! On violation the oracle records the failure and keeps going (one bad
//! run should surface *all* its symptoms); [`Oracle::dump`] renders the
//! seed, the injected fault plan and a minimal trailing event trace so
//! any failure reproduces from its seed alone.
//!
//! [`ShardedCoordinator::check_integrity`]:
//!     crate::coordinator::shard::ShardedCoordinator::check_integrity
//! [`CoordinatorCore::check_integrity`]:
//!     crate::coordinator::core::CoordinatorCore::check_integrity

use crate::coordinator::core::Effect;
use crate::coordinator::shard::ShardedCoordinator;
use crate::ids::ExecutorId;
use crate::util::time::Micros;
use std::collections::{HashMap, HashSet, VecDeque};

/// Ring-buffer capacity of the trailing event trace.
const TRACE_CAP: usize = 64;

/// Shadow lifecycle of one task, tracked independently of the
/// coordinator's own queue/in-flight state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Shadow {
    /// Submitted or re-queued; not on any executor.
    Queued,
    /// Dispatched: fetching or computing on this (global) executor id.
    Running(u32),
    /// Reached a terminal state (completed or permanently failed).
    Terminal,
}

/// The shadow model. Construct once per chaos run, feed every event and
/// effect, and read [`Oracle::violations`] at the end.
#[derive(Debug)]
pub struct Oracle {
    seed: u64,
    tasks: HashMap<u64, Shadow>,
    live: HashSet<u32>,
    /// In-flight transfer sources: task id → the peer executor serving
    /// its current fetch. An executor appearing as a value here must
    /// not be released.
    serving: HashMap<u64, u32>,
    trace: VecDeque<String>,
    violations: Vec<String>,
}

impl Oracle {
    /// Fresh oracle for a run seeded with `seed` (recorded for dumps).
    pub fn new(seed: u64) -> Self {
        Oracle {
            seed,
            tasks: HashMap::new(),
            live: HashSet::new(),
            serving: HashMap::new(),
            trace: VecDeque::with_capacity(TRACE_CAP),
            violations: Vec::new(),
        }
    }

    fn note(&mut self, line: String) {
        if self.trace.len() == TRACE_CAP {
            self.trace.pop_front();
        }
        self.trace.push_back(line);
    }

    fn violate(&mut self, now: Micros, msg: String) {
        self.note(format!("{now} VIOLATION {msg}"));
        self.violations.push(msg);
    }

    /// A task entered the system for the first time.
    pub fn on_submit(&mut self, task: u64, now: Micros) {
        self.note(format!("{now} submit t{task}"));
        if self.tasks.insert(task, Shadow::Queued).is_some() {
            self.violate(now, format!("task t{task} submitted twice"));
        }
    }

    /// An executor registered (initial fleet or `Effect::Allocate`).
    pub fn on_register(&mut self, exec: ExecutorId, now: Micros) {
        self.note(format!("{now} register {exec}"));
        self.live.insert(exec.0);
    }

    /// The driver is about to enact a release named in
    /// [`Effect::Release`]. The executor must be idle in the shadow
    /// model *and* must not be serving anybody's in-flight transfer.
    pub fn on_release(&mut self, exec: ExecutorId, now: Micros) {
        self.note(format!("{now} release {exec}"));
        let running: Vec<u64> = self
            .tasks
            .iter()
            .filter(|&(_, s)| *s == Shadow::Running(exec.0))
            .map(|(&t, _)| t)
            .collect();
        if !running.is_empty() {
            self.violate(now, format!("released busy executor {exec} (running {running:?})"));
        }
        if self.serving.values().any(|&p| p == exec.0) {
            self.violate(
                now,
                format!("released {exec} while it is serving an in-flight peer transfer"),
            );
        }
        self.live.remove(&exec.0);
    }

    /// An executor was killed by a fault. Its running tasks (per the
    /// shadow model) fall back to queued — mirroring the coordinator's
    /// §4.2 requeue — and any transfer it was sourcing loses its peer
    /// (drivers fall back to persistent storage).
    pub fn on_kill(&mut self, exec: ExecutorId, victims: &[u64], now: Micros) {
        self.note(format!("{now} kill {exec} (victims {victims:?})"));
        self.live.remove(&exec.0);
        for s in self.tasks.values_mut() {
            if *s == Shadow::Running(exec.0) {
                *s = Shadow::Queued;
            }
        }
        // A dead executor stops serving (value side) and its victims'
        // in-flight transfers abort, freeing *their* sources (key side).
        self.serving.retain(|_, &mut p| p != exec.0);
        for t in victims {
            self.serving.remove(t);
        }
    }

    /// A failed (partial-transfer) task is re-queued for another attempt.
    pub fn on_requeue(&mut self, task: u64, now: Micros) {
        self.note(format!("{now} requeue t{task}"));
        match self.tasks.get_mut(&task) {
            Some(s @ (Shadow::Queued | Shadow::Running(_))) => *s = Shadow::Queued,
            Some(Shadow::Terminal) => {
                self.violate(now, format!("re-queued terminal task t{task}"))
            }
            None => self.violate(now, format!("re-queued unknown task t{task}")),
        }
    }

    /// A task's current transfer drained (done or failed): its source,
    /// if any, stops serving.
    pub fn on_fetch_complete(&mut self, task: u64, now: Micros) {
        if let Some(p) = self.serving.remove(&task) {
            self.note(format!("{now} fetch-complete t{task} (source e{p})"));
        }
    }

    /// A task reached a terminal state (`"completed"` / `"failed"`).
    /// Exactly-once is the headline invariant.
    pub fn on_terminal(&mut self, task: u64, outcome: &str, now: Micros) {
        self.note(format!("{now} terminal t{task} {outcome}"));
        match self.tasks.get_mut(&task) {
            Some(s @ (Shadow::Queued | Shadow::Running(_))) => *s = Shadow::Terminal,
            Some(Shadow::Terminal) => self.violate(
                now,
                format!("task t{task} reached a terminal state twice ({outcome})"),
            ),
            None => self.violate(now, format!("terminal state for unknown task t{task}")),
        }
    }

    /// Inspect one coordinator effect before the driver enacts it:
    /// invariants 3 (no dispatch to the dead) and 4 (no scrubbed
    /// source) live here.
    pub fn observe_effect(&mut self, eff: &Effect, now: Micros) {
        match eff {
            Effect::Notify(e) => {
                self.note(format!("{now} effect notify {e}"));
                if !self.live.contains(&e.0) {
                    self.violate(now, format!("notify targets dead executor {e}"));
                }
            }
            Effect::Fetch(plan) => {
                let t = plan.task_id.0;
                self.note(format!(
                    "{now} effect fetch t{t} {} on {} ({:?} peer {:?})",
                    plan.file, plan.exec, plan.kind, plan.peer
                ));
                if !self.live.contains(&plan.exec.0) {
                    self.violate(now, format!("fetch dispatched to dead executor {}", plan.exec));
                }
                match self.tasks.get_mut(&t) {
                    Some(Shadow::Terminal) => {
                        self.violate(now, format!("fetch for terminal task t{t}"))
                    }
                    Some(s) => *s = Shadow::Running(plan.exec.0),
                    None => self.violate(now, format!("fetch for unknown task t{t}")),
                }
                if let Some(p) = plan.peer {
                    if self.live.contains(&p.0) {
                        self.serving.insert(t, p.0);
                    } else {
                        self.violate(
                            now,
                            format!("fetch for t{t} sources scrubbed cache slot on dead {p}"),
                        );
                    }
                }
            }
            Effect::Compute { task_id, exec, .. } => {
                let t = task_id.0;
                self.note(format!("{now} effect compute t{t} on {exec}"));
                if !self.live.contains(&exec.0) {
                    self.violate(now, format!("compute dispatched to dead executor {exec}"));
                }
                match self.tasks.get_mut(&t) {
                    Some(Shadow::Terminal) => {
                        self.violate(now, format!("compute for terminal task t{t}"))
                    }
                    Some(s) => *s = Shadow::Running(exec.0),
                    None => self.violate(now, format!("compute for unknown task t{t}")),
                }
            }
            Effect::Allocate(n) => self.note(format!("{now} effect allocate {n}")),
            Effect::Release(list) => self.note(format!("{now} effect release {list:?}")),
        }
    }

    /// Invariant 2: cross-check the coordinator's own books — index vs
    /// cache contents vs serving refcounts — via its integrity seam.
    pub fn check_router(&mut self, router: &ShardedCoordinator, now: Micros) {
        if let Err(msg) = router.check_integrity() {
            self.violate(now, format!("replica accounting diverged: {msg}"));
        }
    }

    /// Every task submitted so far that has not reached a terminal
    /// state (end-of-run liveness reporting).
    pub fn non_terminal(&self) -> Vec<u64> {
        let mut open: Vec<u64> = self
            .tasks
            .iter()
            .filter(|&(_, s)| *s != Shadow::Terminal)
            .map(|(&t, _)| t)
            .collect();
        open.sort_unstable();
        open
    }

    /// All recorded violations, in detection order.
    pub fn violations(&self) -> &[String] {
        &self.violations
    }

    /// Render the reproduce-by-seed failure report: seed, the injected
    /// fault plan and the minimal trailing event trace (last
    /// `TRACE_CAP` events before the violation).
    pub fn dump(&self, plan: &[String]) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "chaos oracle: {} violation(s), seed={}\n",
            self.violations.len(),
            self.seed
        ));
        out.push_str(&format!("fault plan ({} injected):\n", plan.len()));
        for line in plan {
            out.push_str(&format!("  {line}\n"));
        }
        out.push_str(&format!("trailing event trace (last {}):\n", self.trace.len()));
        for line in &self.trace {
            out.push_str(&format!("  {line}\n"));
        }
        out.push_str("violations:\n");
        for v in &self.violations {
            out.push_str(&format!("  - {v}\n"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::core::FetchPlan;
    use crate::coordinator::AccessKind;
    use crate::ids::{FileId, TaskId};

    #[test]
    fn double_terminal_is_a_violation() {
        let mut o = Oracle::new(7);
        o.on_submit(1, Micros::ZERO);
        o.on_terminal(1, "completed", Micros(10));
        assert!(o.violations().is_empty());
        o.on_terminal(1, "completed", Micros(20));
        assert_eq!(o.violations().len(), 1);
        assert!(o.violations()[0].contains("terminal state twice"));
    }

    #[test]
    fn dispatch_to_dead_executor_is_a_violation() {
        let mut o = Oracle::new(7);
        o.on_submit(1, Micros::ZERO);
        o.on_register(ExecutorId(0), Micros::ZERO);
        o.on_kill(ExecutorId(0), &[], Micros(5));
        o.observe_effect(
            &Effect::Compute {
                task_id: TaskId(1),
                exec: ExecutorId(0),
                compute: Micros(1),
            },
            Micros(6),
        );
        assert_eq!(o.violations().len(), 1);
        assert!(o.violations()[0].contains("dead executor"));
    }

    #[test]
    fn releasing_a_serving_source_is_a_violation() {
        let mut o = Oracle::new(7);
        o.on_submit(1, Micros::ZERO);
        o.on_register(ExecutorId(0), Micros::ZERO);
        o.on_register(ExecutorId(1), Micros::ZERO);
        o.observe_effect(
            &Effect::Fetch(FetchPlan {
                task_id: TaskId(1),
                exec: ExecutorId(1),
                file: FileId(3),
                bytes: 10,
                kind: AccessKind::HitGlobal,
                peer: Some(ExecutorId(0)),
                evicted: Vec::new(),
            }),
            Micros(5),
        );
        o.on_release(ExecutorId(0), Micros(6));
        assert_eq!(o.violations().len(), 1);
        assert!(o.violations()[0].contains("serving"));
        // After the fetch drains the release would have been fine.
        let mut o2 = Oracle::new(7);
        o2.on_submit(1, Micros::ZERO);
        o2.on_register(ExecutorId(0), Micros::ZERO);
        o2.on_fetch_complete(1, Micros(7));
        o2.on_release(ExecutorId(0), Micros(8));
        assert!(o2.violations().is_empty());
    }

    #[test]
    fn kill_requeues_shadow_tasks_and_scrubs_serving() {
        let mut o = Oracle::new(7);
        o.on_submit(1, Micros::ZERO);
        o.on_submit(2, Micros::ZERO);
        o.on_register(ExecutorId(0), Micros::ZERO);
        o.on_register(ExecutorId(1), Micros::ZERO);
        o.observe_effect(
            &Effect::Fetch(FetchPlan {
                task_id: TaskId(2),
                exec: ExecutorId(1),
                file: FileId(3),
                bytes: 10,
                kind: AccessKind::HitGlobal,
                peer: Some(ExecutorId(0)),
                evicted: Vec::new(),
            }),
            Micros(5),
        );
        o.on_kill(ExecutorId(0), &[], Micros(6));
        // The dead source no longer blocks anything; t2 still runs.
        o.on_terminal(2, "completed", Micros(9));
        o.on_terminal(1, "completed", Micros(10));
        assert!(o.violations().is_empty(), "{:?}", o.violations());
        assert!(o.non_terminal().is_empty());
    }

    #[test]
    fn dump_names_seed_plan_and_trace() {
        let mut o = Oracle::new(42);
        o.on_submit(1, Micros::ZERO);
        o.on_terminal(1, "completed", Micros(10));
        o.on_terminal(1, "completed", Micros(20));
        let dump = o.dump(&["#001 0.000ms delay-notify e0".to_string()]);
        assert!(dump.contains("seed=42"));
        assert!(dump.contains("delay-notify e0"));
        assert!(dump.contains("submit t1"), "trace present: {dump}");
        assert!(dump.contains("terminal state twice"));
    }

    #[test]
    fn trace_is_a_bounded_ring() {
        let mut o = Oracle::new(1);
        for i in 0..(TRACE_CAP as u64 + 10) {
            o.on_submit(i, Micros(i));
        }
        assert_eq!(o.trace.len(), TRACE_CAP);
        assert!(o.trace.front().unwrap().contains("submit t10"));
    }
}
