//! Seeded chaos harness for the coordinator core and the shard router.
//!
//! The coordinator is *pure decision logic*: events in, [`Effect`]s out
//! (see [`crate::coordinator::core`]). That boundary is exactly where
//! faults happen in a real deployment — notifications are lost on the
//! wire, executors die mid-fetch, GridFTP transfers stall — so the
//! chaos driver lives there too: it wraps a [`ShardedCoordinator`]
//! (K = 1 is the plain core) and perturbs the *enactment* of its effect
//! stream without touching a line of production code.
//!
//! ## Fault taxonomy
//!
//! Every fault is drawn from one splitmix64 stream seeded by
//! [`ChaosConfig::seed`], so a seed fully determines the fault
//! schedule, the dispatch trace and the final tallies — re-running a
//! seed reproduces a failure bit-for-bit. The kinds:
//!
//! | fault | enactment perturbation |
//! |---|---|
//! | [`FaultKind::DelayNotify`] | notification delivered 1–5 ms late |
//! | [`FaultKind::ReorderNotify`] | delivered 5–15 ms late, so later notifies overtake it |
//! | [`FaultKind::DropNotify`] | lost on the wire; the executor re-polls 50 ms later |
//! | [`FaultKind::KillMidFetch`] | the destination executor dies 0.2 ms into the transfer |
//! | [`FaultKind::KillMidCompute`] | the executor dies 0.2 ms into the task's compute |
//! | [`FaultKind::StallTransfer`] | transfer takes 20–80 ms instead of ~1 ms |
//! | [`FaultKind::PartialTransfer`] | transfer truncates: the task fails and is re-queued (≤ [`MAX_RETRIES`] times) |
//! | [`FaultKind::PartitionShard`] | one shard unreachable for 30 ms; its messages deliver after heal |
//! | [`FaultKind::DuplicateNotify`] | the same notification delivered twice; the second pickup is a plain poll |
//! | [`FaultKind::CorruptCompletion`] | a completion report forged with a task id the coordinator never issued |
//!
//! The last two are *byzantine*: they exercise the coordinator's input
//! validation rather than its recovery machinery. A duplicated
//! notification must behave like any redundant poll (dispatch stays
//! exactly-once because the queue hand-off is atomic), and a forged
//! completion must be rejected at the id tables — the router bounces
//! ids absent from its task→shard map, and each core bounces ids
//! absent from its in-flight slab — producing *zero* effects. The
//! driver enacts whatever the rejection returns, so if a forged id
//! ever leaked through, the oracle's unknown-task checks would trip;
//! [`ChaosReport::stale_rejected`] additionally pins the rejection
//! count to the injection count exactly.
//!
//! A dropped notification is modeled as a *very late* pickup rather
//! than no pickup at all: the core's notify reserves a pending slot,
//! and a real Falkon executor whose notification is lost re-polls the
//! dispatcher — the late poll resolves the reservation exactly like the
//! recovery path would.
//!
//! Executor kills route into
//! [`CoordinatorCore::on_executor_failed`](crate::coordinator::core::CoordinatorCore::on_executor_failed)
//! (scrub + §4.2 requeue); partial transfers route into
//! [`on_task_failed`](crate::coordinator::core::CoordinatorCore::on_task_failed)
//! with driver-side resubmission, and a retry budget turns repeat
//! offenders into permanent failures — both terminal paths must be
//! reached exactly once per task, which the [`oracle`] checks after
//! every step along with replica accounting and dead-executor hygiene.
//!
//! ## Task streams
//!
//! The built-in stream draws uniform 1–2-file tasks from a dedicated
//! splitmix64 workload stream (byte-identical to the pre-scenario
//! harness). Setting [`ChaosConfig::scenario`] instead pre-generates a
//! [`Workload`] from the scenario library (`docs/WORKLOADS.md`) at the
//! chaos seed and feeds its task stream — inputs, and for pipelines
//! dependency edges — through the same fault schedule. A dependency-
//! gated task is held until every predecessor reaches a terminal
//! state; a *failed* predecessor still satisfies the edge (the chaos
//! harness is probing coordinator invariants, not DAG semantics, and
//! cascading the failure would stall the run by design).
//!
//! Run it via `datadiff chaos --seed N --events M --shards K
//! [--scenario F]` or the `rust/tests/chaos.rs` sweep; `docs/CHAOS.md`
//! documents the fault plan format and the reproduce-by-seed workflow.

pub mod oracle;

use crate::cache::CacheConfig;
use crate::config::ScenarioSpec;
use crate::coordinator::core::{CoreConfig, Effect, FetchPlan, FileSizes};
use crate::coordinator::provisioner::{AllocationPolicy, ProvisionerConfig};
use crate::coordinator::queue::Task;
use crate::coordinator::scheduler::{DispatchPolicy, SchedulerConfig};
use crate::coordinator::shard::ShardedCoordinator;
use crate::coordinator::AccessKind;
use crate::ids::{ExecutorId, FileId, TaskId};
use crate::util::prng::Pcg64;
use crate::util::time::Micros;
use oracle::Oracle;
use std::collections::{BinaryHeap, HashMap, HashSet};

/// Uniform data-object size (bytes) in the chaos workload.
const FILE_BYTES: u64 = 10;
/// Task submission gap (µs): one arrival every 2 ms.
const SUBMIT_GAP_US: u64 = 2_000;
/// Provisioner tick period (ms); each tick also runs the kick safety net.
const TICK_MS: u64 = 10;
/// Modeled GRAM/LRM allocation latency (ms) for `Effect::Allocate`.
const GRAM_MS: u64 = 5;
/// Length of a shard partition window (ms).
const PARTITION_MS: u64 = 30;
/// Resubmissions allowed per task before it fails permanently.
pub const MAX_RETRIES: u32 = 2;

/// Bit OR-ed into a real task id to forge a [`FaultKind::CorruptCompletion`]
/// report. Real ids are dense from zero, so a forged id can never
/// collide with a task the coordinator knows about.
const FORGED_TASK_BIT: u64 = 1 << 40;

/// The ten fault kinds the harness injects. See the module docs for
/// what each does to the effect stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Notification delivered late (1–5 ms).
    DelayNotify,
    /// Notification delivered so late (5–15 ms) that later ones overtake.
    ReorderNotify,
    /// Notification lost; the executor re-polls 50 ms later.
    DropNotify,
    /// Destination executor killed 0.2 ms into a transfer.
    KillMidFetch,
    /// Executor killed 0.2 ms into a task's compute.
    KillMidCompute,
    /// Transfer stalls for 20–80 ms.
    StallTransfer,
    /// Transfer truncates; the task fails and re-queues.
    PartialTransfer,
    /// One shard unreachable for a window; messages deliver after heal.
    PartitionShard,
    /// The same notification delivered twice (byzantine duplicate).
    DuplicateNotify,
    /// A completion report forged with a never-issued task id
    /// (byzantine corruption); must be rejected with zero effects.
    CorruptCompletion,
}

impl FaultKind {
    /// All kinds, in tally order.
    pub const ALL: [FaultKind; 10] = [
        FaultKind::DelayNotify,
        FaultKind::ReorderNotify,
        FaultKind::DropNotify,
        FaultKind::KillMidFetch,
        FaultKind::KillMidCompute,
        FaultKind::StallTransfer,
        FaultKind::PartialTransfer,
        FaultKind::PartitionShard,
        FaultKind::DuplicateNotify,
        FaultKind::CorruptCompletion,
    ];

    /// Hyphenated name used in fault plans and tally rendering.
    pub fn name(self) -> &'static str {
        match self {
            FaultKind::DelayNotify => "delay-notify",
            FaultKind::ReorderNotify => "reorder-notify",
            FaultKind::DropNotify => "drop-notify",
            FaultKind::KillMidFetch => "kill-mid-fetch",
            FaultKind::KillMidCompute => "kill-mid-compute",
            FaultKind::StallTransfer => "stall-transfer",
            FaultKind::PartialTransfer => "partial-transfer",
            FaultKind::PartitionShard => "partition-shard",
            FaultKind::DuplicateNotify => "duplicate-notify",
            FaultKind::CorruptCompletion => "corrupt-completion",
        }
    }
}

/// Per-kind injection counters for one run.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultTally {
    counts: [u64; 10],
}

impl FaultTally {
    fn bump(&mut self, kind: FaultKind) {
        self.counts[kind as usize] += 1;
    }

    /// Injections of one kind.
    pub fn count(&self, kind: FaultKind) -> u64 {
        self.counts[kind as usize]
    }

    /// Total injections across all kinds.
    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }
}

impl std::fmt::Display for FaultTally {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.total() == 0 {
            return f.write_str("none");
        }
        let mut first = true;
        for kind in FaultKind::ALL {
            let n = self.count(kind);
            if n > 0 {
                if !first {
                    f.write_str(" ")?;
                }
                write!(f, "{}={n}", kind.name())?;
                first = false;
            }
        }
        Ok(())
    }
}

/// The splitmix64 generator driving the fault schedule (and, on a
/// separate stream, the workload shape). Chosen over the crate's
/// [`Pcg64`] deliberately: the ISSUE's plan format is defined in terms
/// of splitmix64 so plans are portable across reimplementations, and
/// keeping the fault stream out of [`Pcg64`] means chaos draws can
/// never perturb the coordinator's own peer/eviction randomness.
#[derive(Debug, Clone)]
struct SplitMix64(u64);

impl SplitMix64 {
    fn new(seed: u64) -> Self {
        SplitMix64(seed)
    }

    fn next(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, bound)`. `bound` must be non-zero.
    fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0, "below(0)");
        self.next() % bound
    }

    /// True with probability `p`.
    fn chance(&mut self, p: f64) -> bool {
        ((self.next() >> 11) as f64) / ((1u64 << 53) as f64) < p
    }
}

/// Parameters of one chaos run.
#[derive(Debug, Clone)]
pub struct ChaosConfig {
    /// Seed of the fault plan and the coordinator's own PRNG.
    pub seed: u64,
    /// Tasks submitted (one every 2 ms).
    pub events: usize,
    /// Coordinator shards (K = 1 is the plain core).
    pub shards: usize,
    /// Dispatch policy under test.
    pub policy: DispatchPolicy,
    /// Initial fleet size (`max_nodes` is twice this, leaving the
    /// provisioner room to replace kills).
    pub nodes: usize,
    /// Distinct data objects in the workload.
    pub files: u32,
    /// Per-decision fault probability.
    pub fault_rate: f64,
    /// Provisioner allocation policy under test. The default matches
    /// the pre-model harness (`mult:2`), so existing seed fingerprints
    /// are unchanged; sweeps also cycle `model` through here to pin
    /// the closed-loop controller against the oracle.
    pub allocation: AllocationPolicy,
    /// Draw the task stream from a scenario-library workload instead of
    /// the built-in uniform stream (None = built-in, byte-identical to
    /// the pre-scenario harness). `events` is clamped to the generated
    /// stream length (pipelines emit whole pipelines).
    pub scenario: Option<ScenarioSpec>,
}

impl ChaosConfig {
    /// Standard-size run: 200 tasks on 8 nodes.
    pub fn new(seed: u64) -> Self {
        ChaosConfig {
            seed,
            events: 200,
            shards: 1,
            policy: DispatchPolicy::GoodCacheCompute,
            nodes: 8,
            files: 24,
            fault_rate: 0.18,
            allocation: AllocationPolicy::Multiplicative(2.0),
            scenario: None,
        }
    }

    /// Small run for sweeps and CI smoke (`datadiff chaos --quick`).
    pub fn quick(seed: u64) -> Self {
        ChaosConfig {
            events: 60,
            nodes: 6,
            files: 16,
            ..ChaosConfig::new(seed)
        }
    }
}

/// Outcome of one chaos run. `plan` and `fingerprint` are pure
/// functions of the config, which is what the reproduce-by-seed tests
/// assert.
#[derive(Debug, Clone)]
pub struct ChaosReport {
    /// Seed the run was driven by.
    pub seed: u64,
    /// Policy under test.
    pub policy: DispatchPolicy,
    /// Shard count.
    pub shards: usize,
    /// Tasks submitted.
    pub events: usize,
    /// Tasks that completed.
    pub completed: u64,
    /// Tasks that failed permanently (retry budget exhausted).
    pub failed: u64,
    /// Total faults injected (`chaos/faults_injected`).
    pub faults_injected: u64,
    /// Per-kind injection counts.
    pub tally: FaultTally,
    /// The injected fault plan, one formatted line per fault.
    pub plan: Vec<String>,
    /// Oracle violations detected (`chaos/oracle_violations`).
    pub oracle_violations: usize,
    /// Forged (byzantine) reports the router/core rejected at the id
    /// tables. Equals the [`FaultKind::CorruptCompletion`] tally when
    /// rejection is airtight.
    pub stale_rejected: u64,
    /// The run hit its step budget with tasks still open.
    pub stalled: bool,
    /// FNV-1a digest of the dispatch trace, access tallies and fault
    /// tallies — equal across reruns of the same seed.
    pub fingerprint: u64,
    /// Oracle failure report (seed + plan + trailing trace), present
    /// only when violations were detected.
    pub dump: Option<String>,
}

impl ChaosReport {
    /// Did the run satisfy the robustness gate? Oracle-clean, no
    /// stall, and at least one fault actually injected (a faultless
    /// "chaos" run proves nothing).
    pub fn clean(&self) -> bool {
        self.oracle_violations == 0 && !self.stalled && self.faults_injected > 0
    }

    /// One-line summary for sweep output.
    pub fn summary_line(&self) -> String {
        format!(
            "seed={:<5} policy={:<20} shards={} tasks={:<4} completed={:<4} failed={} \
             faults={:<3} violations={} fingerprint={:016x}{}",
            self.seed,
            self.policy.name(),
            self.shards,
            self.events,
            self.completed,
            self.failed,
            self.faults_injected,
            self.oracle_violations,
            self.fingerprint,
            if self.stalled { " STALLED" } else { "" },
        )
    }
}

/// Run one seeded chaos schedule to completion.
pub fn run_chaos(cfg: &ChaosConfig) -> ChaosReport {
    Driver::new(cfg.clone()).run()
}

/// Deliberately trip the oracle (a double terminal state) and return
/// its failure dump — proves the watchdog bites and shows the
/// reproduce-by-seed report format. Wired to `datadiff chaos
/// --self-test` and asserted by the integration suite.
pub fn oracle_self_test() -> String {
    let mut o = Oracle::new(0xC0FFEE);
    o.on_submit(1, Micros::ZERO);
    o.on_register(ExecutorId(0), Micros::ZERO);
    o.on_terminal(1, "completed", Micros(1_000));
    o.on_terminal(1, "completed", Micros(2_000));
    assert!(
        !o.violations().is_empty(),
        "oracle self-test failed to trip the oracle"
    );
    o.dump(&["#001 0.000ms delay-notify e0 (self-test)".to_string()])
}

/// One queued driver action. Completion steps carry the task's attempt
/// number at scheduling time: any re-queue (kill, partial transfer)
/// bumps the attempt, so completions of a superseded attempt are
/// recognized as stale and skipped instead of reaching the coordinator.
#[derive(Debug, Clone)]
enum Step {
    /// Submit task `i` from the workload stream.
    Submit(u64),
    /// Deliver a (possibly delayed) notification round-trip.
    Pickup(ExecutorId),
    /// A transfer finished.
    FetchDone { task: u64, attempt: u32 },
    /// A compute finished.
    ComputeDone { task: u64, attempt: u32 },
    /// A partial transfer surfaced as a task failure.
    TaskFailed { task: u64, attempt: u32 },
    /// An executor dies.
    ExecFail(ExecutorId),
    /// An `Effect::Allocate` node finished its LRM bootstrap.
    NodeUp,
    /// A forged completion report (byzantine): `task` carries
    /// [`FORGED_TASK_BIT`], so the coordinator must reject it.
    Byzantine { task: u64, compute: bool },
    /// A shard partition heals.
    Heal(usize),
    /// Provisioner tick + kick safety net.
    Tick,
}

/// Heap entry ordered by `(at, seq)` — reversed so `BinaryHeap` (a
/// max-heap) pops the earliest step first. `seq` makes the order total
/// and deterministic.
#[derive(Debug)]
struct Scheduled {
    at: Micros,
    seq: u64,
    step: Step,
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl Eq for Scheduled {}
impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Scheduled {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (other.at, other.seq).cmp(&(self.at, self.seq))
    }
}

struct Driver {
    cfg: ChaosConfig,
    router: ShardedCoordinator,
    heap: BinaryHeap<Scheduled>,
    seq: u64,
    faults: SplitMix64,
    workload: SplitMix64,
    oracle: Oracle,
    /// Current attempt per task; bumped on every re-queue so stale
    /// completion steps are skipped.
    attempt: HashMap<u64, u32>,
    /// Partial-transfer resubmissions per task.
    retries: HashMap<u64, u32>,
    /// The in-flight fetch per task (attempt-tagged), for dead-source
    /// fallback at completion time.
    fetches: HashMap<u64, (u32, FetchPlan)>,
    /// Executor each dispatched task currently occupies.
    task_exec: HashMap<u64, ExecutorId>,
    /// Shard of each executor at registration (partition targeting).
    exec_shard: HashMap<u32, usize>,
    /// Executors the driver believes alive.
    live: HashSet<u32>,
    /// Open partition window: (shard, heal time).
    partition: Option<(usize, Micros)>,
    /// Kill budget; never kills the last node.
    kills_left: u32,
    /// Every run injects ≥ 1 fault: the first notification is always
    /// delayed, so `faults_injected > 0` holds for any seed.
    forced_first_fault: bool,
    tally: FaultTally,
    plan: Vec<String>,
    /// Original task specs, for resubmission after partial transfers.
    tasks: HashMap<u64, Task>,
    /// Pre-generated scenario workload (None = built-in stream).
    scenario_wl: Option<crate::workload::Workload>,
    /// Dependency gating over the scenario stream (empty otherwise):
    /// unmet-predecessor counts, reverse edges, and the held set.
    dep_remaining: Vec<u32>,
    dep_children: Vec<Vec<u64>>,
    held: HashSet<u64>,
    completed: u64,
    failed: u64,
    terminal: u64,
}

fn fnv_mix(fp: &mut u64, v: u64) {
    for b in v.to_le_bytes() {
        *fp ^= b as u64;
        *fp = fp.wrapping_mul(0x0000_0100_0000_01b3);
    }
}

impl Driver {
    fn new(mut cfg: ChaosConfig) -> Self {
        // Scenario streams are pre-generated from the chaos seed; the
        // event count follows the stream (pipelines emit whole
        // pipelines, so the generator may round `events` down).
        let mut scenario_wl = None;
        let (mut dep_remaining, mut dep_children) = (Vec::new(), Vec::new());
        if let Some(spec) = &cfg.scenario {
            let mut wcfg = crate::config::WorkloadConfig::default();
            wcfg.num_tasks = cfg.events as u64;
            wcfg.num_files = cfg.files;
            wcfg.file_size_bytes = FILE_BYTES;
            wcfg.scenario = Some(spec.clone());
            let wl = crate::workload::generate(&wcfg, cfg.seed);
            cfg.events = wl.tasks.len();
            if wl.dep_edges > 0 {
                dep_remaining = vec![0u32; wl.tasks.len()];
                dep_children = vec![Vec::new(); wl.tasks.len()];
                for (i, t) in wl.tasks.iter().enumerate() {
                    dep_remaining[i] = t.deps.len() as u32;
                    for d in &t.deps {
                        dep_children[d.0 as usize].push(i as u64);
                    }
                }
            }
            scenario_wl = Some(wl);
        }
        let core_cfg = CoreConfig {
            scheduler: SchedulerConfig {
                policy: cfg.policy,
                ..SchedulerConfig::default()
            },
            provisioner: ProvisionerConfig {
                allocation: cfg.allocation,
                // Short idle-release so the Release/deferral machinery
                // is exercised while transfers are still in flight.
                idle_release_s: 0.5,
                ..ProvisionerConfig::default()
            },
            cache: CacheConfig::lru(cfg.files as u64 * FILE_BYTES / 3),
            max_nodes: cfg.nodes * 2,
            slots_per_node: 1,
            file_sizes: FileSizes::Uniform(FILE_BYTES),
        };
        let router = ShardedCoordinator::new(core_cfg, cfg.shards, Pcg64::seeded(cfg.seed));
        Driver {
            faults: SplitMix64::new(cfg.seed),
            workload: SplitMix64::new(cfg.seed ^ 0x5eed_0f_da7a),
            oracle: Oracle::new(cfg.seed),
            kills_left: cfg.nodes as u32,
            router,
            heap: BinaryHeap::new(),
            seq: 0,
            attempt: HashMap::new(),
            retries: HashMap::new(),
            fetches: HashMap::new(),
            task_exec: HashMap::new(),
            exec_shard: HashMap::new(),
            live: HashSet::new(),
            partition: None,
            forced_first_fault: false,
            tally: FaultTally::default(),
            plan: Vec::new(),
            tasks: HashMap::new(),
            scenario_wl,
            dep_remaining,
            dep_children,
            held: HashSet::new(),
            completed: 0,
            failed: 0,
            terminal: 0,
            cfg,
        }
    }

    fn schedule(&mut self, at: Micros, step: Step) {
        self.seq += 1;
        self.heap.push(Scheduled {
            at,
            seq: self.seq,
            step,
        });
    }

    fn inject(&mut self, kind: FaultKind, now: Micros, detail: String) {
        self.tally.bump(kind);
        self.plan.push(format!(
            "#{:03} {now} {} {detail}",
            self.plan.len() + 1,
            kind.name()
        ));
    }

    fn make_task(&mut self, i: u64, now: Micros) -> Task {
        // Scenario stream: the pre-generated input set (the chaos tempo
        // and compute time stay the harness's own).
        if let Some(wl) = &self.scenario_wl {
            return Task {
                id: TaskId(i),
                files: wl.tasks[i as usize].inputs.clone(),
                compute: Micros::from_millis(5),
                arrival: now,
            };
        }
        let dominant = FileId(self.workload.below(self.cfg.files as u64) as u32);
        let mut files = vec![dominant];
        if self.workload.chance(0.35) {
            let second = FileId(self.workload.below(self.cfg.files as u64) as u32);
            if second != dominant {
                files.push(second);
            }
        }
        Task {
            id: TaskId(i),
            files,
            compute: Micros::from_millis(5),
            arrival: now,
        }
    }

    /// Submit task `i` to the router — at its Submit step, or when the
    /// last gating predecessor reaches a terminal state.
    fn submit_task(&mut self, i: u64, now: Micros) {
        let task = self.make_task(i, now);
        self.tasks.insert(i, task.clone());
        self.attempt.insert(i, 0);
        self.oracle.on_submit(i, now);
        let effs = self.router.on_arrival(task, 0, 0.0, now);
        self.enact(effs, now);
    }

    /// A task reached a terminal state (completed *or* permanently
    /// failed — see the module docs): decrement each dependent's
    /// unmet-predecessor count and submit any dependent whose Submit
    /// step already passed while it was held.
    fn release_children(&mut self, task: u64, now: Micros) {
        if self.dep_children.is_empty() {
            return;
        }
        let children = self.dep_children[task as usize].clone();
        for c in children {
            self.dep_remaining[c as usize] -= 1;
            if self.dep_remaining[c as usize] == 0 && self.held.remove(&c) {
                self.submit_task(c, now);
            }
        }
    }

    /// The shard a step's messages traverse, for partition targeting.
    fn step_shard(&self, step: &Step) -> Option<usize> {
        match step {
            Step::Pickup(e) | Step::ExecFail(e) => self.exec_shard.get(&e.0).copied(),
            Step::FetchDone { task, .. }
            | Step::ComputeDone { task, .. }
            | Step::TaskFailed { task, .. } => self
                .task_exec
                .get(task)
                .and_then(|e| self.exec_shard.get(&e.0))
                .copied(),
            Step::Submit(_) | Step::NodeUp | Step::Heal(_) | Step::Tick => None,
            // Forged ids resolve to no shard; delivery is unaffected by
            // partitions (an attacker is not bound by our cut).
            Step::Byzantine { .. } => None,
        }
    }

    fn run(mut self) -> ChaosReport {
        for _ in 0..self.cfg.nodes {
            let (exec, effs) = self.router.register_node(Micros::ZERO);
            self.live.insert(exec.0);
            self.exec_shard
                .insert(exec.0, self.router.shard_of_exec(exec).expect("registered"));
            self.oracle.on_register(exec, Micros::ZERO);
            self.enact(effs, Micros::ZERO);
        }
        for i in 0..self.cfg.events as u64 {
            self.schedule(Micros(i * SUBMIT_GAP_US), Step::Submit(i));
        }
        self.schedule(Micros::ZERO, Step::Tick);

        let max_steps = 1_000 + self.cfg.events * 120;
        let mut steps = 0usize;
        let mut stalled = false;
        while let Some(s) = self.heap.pop() {
            if self.terminal as usize >= self.cfg.events {
                break;
            }
            steps += 1;
            if steps > max_steps {
                stalled = true;
                break;
            }
            // Open partition window: messages to/from the cut shard are
            // held back and delivered after heal.
            if let Some((shard, heal)) = self.partition {
                if s.at < heal && self.step_shard(&s.step) == Some(shard) {
                    self.schedule(heal, s.step);
                    continue;
                }
            }
            self.process(s.at, s.step);
        }
        stalled |= (self.terminal as usize) < self.cfg.events;

        let mut fp = 0xcbf2_9ce4_8422_2325u64;
        for t in self.router.take_dispatch_log() {
            fnv_mix(&mut fp, t.0);
        }
        let (hl, hg, miss) = self.router.take_merged_recorder().access_counts();
        for v in [self.completed, self.failed, hl, hg, miss] {
            fnv_mix(&mut fp, v);
        }
        for kind in FaultKind::ALL {
            fnv_mix(&mut fp, self.tally.count(kind));
        }
        let stale_rejected = self.router.stale_events();
        fnv_mix(&mut fp, stale_rejected);
        if stalled {
            let open = self.oracle.non_terminal();
            crate::warn!(
                "chaos seed {} stalled with {} open task(s): {open:?}",
                self.cfg.seed,
                open.len()
            );
        }
        let violations = self.oracle.violations().len();
        let dump = if violations > 0 {
            Some(self.oracle.dump(&self.plan))
        } else {
            None
        };
        ChaosReport {
            seed: self.cfg.seed,
            policy: self.cfg.policy,
            shards: self.cfg.shards,
            events: self.cfg.events,
            completed: self.completed,
            failed: self.failed,
            faults_injected: self.tally.total(),
            tally: self.tally,
            plan: self.plan,
            oracle_violations: violations,
            stale_rejected,
            stalled,
            fingerprint: fp,
            dump,
        }
    }

    fn process(&mut self, now: Micros, step: Step) {
        match step {
            Step::Submit(i) => {
                if self
                    .dep_remaining
                    .get(i as usize)
                    .is_some_and(|&r| r > 0)
                {
                    self.held.insert(i);
                    return;
                }
                self.submit_task(i, now);
            }
            Step::Pickup(e) => {
                if !self.live.contains(&e.0) {
                    return; // died while the notification was in flight
                }
                let effs = self.router.on_pickup(e, now);
                self.enact(effs, now);
            }
            Step::FetchDone { task, attempt } => {
                if self.attempt.get(&task) != Some(&attempt) {
                    return; // superseded by a re-queue
                }
                // Dead-source fallback: if the serving peer died while
                // the transfer was in flight, the driver re-reads from
                // persistent storage and reports the observed miss.
                let observed = match self.fetches.remove(&task) {
                    Some((a, plan)) if a == attempt => match plan.peer {
                        Some(p) if !self.live.contains(&p.0) => {
                            Some((AccessKind::Miss, plan.bytes))
                        }
                        _ => None,
                    },
                    _ => None,
                };
                self.oracle.on_fetch_complete(task, now);
                let effs = self.router.on_fetch_done(TaskId(task), now, observed);
                self.enact(effs, now);
            }
            Step::ComputeDone { task, attempt } => {
                if self.attempt.get(&task) != Some(&attempt) {
                    return;
                }
                self.oracle.on_terminal(task, "completed", now);
                self.terminal += 1;
                self.completed += 1;
                self.task_exec.remove(&task);
                let effs = self.router.on_compute_done(TaskId(task), now, now);
                self.enact(effs, now);
                self.release_children(task, now);
            }
            Step::TaskFailed { task, attempt } => {
                if self.attempt.get(&task) != Some(&attempt) {
                    return;
                }
                *self.attempt.get_mut(&task).expect("guard above") += 1;
                self.fetches.remove(&task);
                self.task_exec.remove(&task);
                self.oracle.on_fetch_complete(task, now);
                let effs = self.router.on_task_failed(TaskId(task), now);
                self.enact(effs, now);
                let tries = self.retries.entry(task).or_insert(0);
                *tries += 1;
                if *tries <= MAX_RETRIES {
                    // §4.2 replay: resubmit through the normal arrival
                    // path so the task re-routes and re-diffuses.
                    self.oracle.on_requeue(task, now);
                    let mut t = self.tasks[&task].clone();
                    t.arrival = now;
                    let effs = self.router.on_arrival(t, 0, 0.0, now);
                    self.enact(effs, now);
                } else {
                    self.oracle.on_terminal(task, "failed", now);
                    self.terminal += 1;
                    self.failed += 1;
                    // A dead predecessor still unblocks its dependents.
                    self.release_children(task, now);
                }
            }
            Step::ExecFail(e) => {
                if !self.live.remove(&e.0) {
                    return; // already dead or released
                }
                self.exec_shard.remove(&e.0);
                // Bump every victim's attempt so completions scheduled
                // for the dead node are recognized as stale.
                let mut victims: Vec<u64> = self
                    .task_exec
                    .iter()
                    .filter(|&(_, &x)| x == e)
                    .map(|(&t, _)| t)
                    .collect();
                victims.sort_unstable();
                for t in &victims {
                    *self.attempt.get_mut(t).expect("dispatched task has an attempt") += 1;
                    self.fetches.remove(t);
                    self.task_exec.remove(t);
                }
                self.oracle.on_kill(e, &victims, now);
                let effs = self.router.on_executor_failed(e, now);
                self.enact(effs, now);
            }
            Step::NodeUp => {
                let (exec, effs) = self.router.on_node_registered(now);
                self.live.insert(exec.0);
                self.exec_shard
                    .insert(exec.0, self.router.shard_of_exec(exec).expect("registered"));
                self.oracle.on_register(exec, now);
                self.enact(effs, now);
            }
            Step::Byzantine { task, compute } => {
                // The forged id names a task the coordinator never
                // issued. Rejection must produce zero effects; we enact
                // the result anyway so that if a forged id ever leaked
                // through, the oracle's unknown-task checks would trip.
                let effs = if compute {
                    self.router.on_compute_done(TaskId(task), now, now)
                } else {
                    self.router.on_fetch_done(TaskId(task), now, None)
                };
                self.enact(effs, now);
            }
            Step::Heal(shard) => {
                if matches!(self.partition, Some((s, _)) if s == shard) {
                    self.partition = None;
                }
            }
            Step::Tick => {
                if self.cfg.shards > 1
                    && self.partition.is_none()
                    && self.faults.chance(self.cfg.fault_rate * 0.25)
                {
                    let shard = self.faults.below(self.cfg.shards as u64) as usize;
                    let heal = now + Micros::from_millis(PARTITION_MS);
                    self.partition = Some((shard, heal));
                    self.inject(
                        FaultKind::PartitionShard,
                        now,
                        format!("shard {shard} until {heal}"),
                    );
                    self.schedule(heal, Step::Heal(shard));
                }
                let effs = self.router.on_tick(now);
                self.enact(effs, now);
                let effs = self.router.kick();
                self.enact(effs, now);
                if (self.terminal as usize) < self.cfg.events {
                    self.schedule(now + Micros::from_millis(TICK_MS), Step::Tick);
                }
            }
        }
        self.oracle.check_router(&self.router, now);
    }

    /// Enact one effect batch, rolling the fault stream at every
    /// perturbable point.
    fn enact(&mut self, effects: Vec<Effect>, now: Micros) {
        for eff in effects {
            self.oracle.observe_effect(&eff, now);
            match eff {
                Effect::Notify(e) => {
                    let delay_us = if !self.forced_first_fault {
                        self.forced_first_fault = true;
                        self.inject(FaultKind::DelayNotify, now, format!("{e} (forced)"));
                        1_000 + self.faults.below(4_000)
                    } else if self.faults.chance(self.cfg.fault_rate) {
                        match self.faults.below(3) {
                            0 => {
                                self.inject(FaultKind::DelayNotify, now, format!("{e}"));
                                1_000 + self.faults.below(4_000)
                            }
                            1 => {
                                self.inject(FaultKind::ReorderNotify, now, format!("{e}"));
                                5_000 + self.faults.below(10_000)
                            }
                            _ => {
                                self.inject(FaultKind::DropNotify, now, format!("{e}"));
                                50_000
                            }
                        }
                    } else {
                        100
                    };
                    self.schedule(now + Micros(delay_us), Step::Pickup(e));
                    if self.faults.chance(self.cfg.fault_rate * 0.5) {
                        // Byzantine duplicate: the same notification
                        // arrives twice. The second pickup must behave
                        // like a redundant poll, never a double grant.
                        self.inject(FaultKind::DuplicateNotify, now, format!("{e}"));
                        let echo = delay_us + 300 + self.faults.below(700);
                        self.schedule(now + Micros(echo), Step::Pickup(e));
                    }
                }
                Effect::Fetch(plan) => {
                    let task = plan.task_id.0;
                    let attempt = *self.attempt.get(&task).unwrap_or(&0);
                    self.task_exec.insert(task, plan.exec);
                    let roll = self.faults.chance(self.cfg.fault_rate);
                    let kill = roll
                        && self.kills_left > 0
                        && self.router.node_count() > 1
                        && self.faults.chance(0.35);
                    if kill {
                        self.kills_left -= 1;
                        self.inject(
                            FaultKind::KillMidFetch,
                            now,
                            format!("{} fetching {} for t{task}", plan.exec, plan.file),
                        );
                        // The transfer dies with the executor: no
                        // FetchDone; on_executor_failed re-queues.
                        self.schedule(now + Micros(200), Step::ExecFail(plan.exec));
                        continue;
                    }
                    let partial = roll && self.faults.chance(0.4);
                    self.fetches.insert(task, (attempt, plan.clone()));
                    if partial {
                        self.inject(
                            FaultKind::PartialTransfer,
                            now,
                            format!("t{task} reading {}", plan.file),
                        );
                        self.schedule(now + Micros(1_000), Step::TaskFailed { task, attempt });
                    } else if roll {
                        self.inject(
                            FaultKind::StallTransfer,
                            now,
                            format!("t{task} reading {}", plan.file),
                        );
                        let stall = 20_000 + self.faults.below(60_000);
                        self.schedule(now + Micros(stall), Step::FetchDone { task, attempt });
                    } else {
                        let xfer = 500 + self.faults.below(1_500);
                        self.schedule(now + Micros(xfer), Step::FetchDone { task, attempt });
                    }
                    if self.faults.chance(self.cfg.fault_rate * 0.25) {
                        let forged = task | FORGED_TASK_BIT;
                        self.inject(
                            FaultKind::CorruptCompletion,
                            now,
                            format!("fetch-done t{task} forged as t{forged}"),
                        );
                        self.schedule(
                            now + Micros(300),
                            Step::Byzantine {
                                task: forged,
                                compute: false,
                            },
                        );
                    }
                }
                Effect::Compute {
                    task_id,
                    exec,
                    compute,
                } => {
                    let task = task_id.0;
                    let attempt = *self.attempt.get(&task).unwrap_or(&0);
                    self.task_exec.insert(task, exec);
                    if self.kills_left > 0
                        && self.router.node_count() > 1
                        && self.faults.chance(self.cfg.fault_rate * 0.5)
                    {
                        self.kills_left -= 1;
                        self.inject(
                            FaultKind::KillMidCompute,
                            now,
                            format!("{exec} running t{task}"),
                        );
                        self.schedule(now + Micros(200), Step::ExecFail(exec));
                    } else {
                        self.schedule(now + compute, Step::ComputeDone { task, attempt });
                        if self.faults.chance(self.cfg.fault_rate * 0.25) {
                            let forged = task | FORGED_TASK_BIT;
                            self.inject(
                                FaultKind::CorruptCompletion,
                                now,
                                format!("compute-done t{task} forged as t{forged}"),
                            );
                            self.schedule(
                                now + Micros(250),
                                Step::Byzantine {
                                    task: forged,
                                    compute: true,
                                },
                            );
                        }
                    }
                }
                Effect::Allocate(n) => {
                    for _ in 0..n {
                        self.schedule(now + Micros::from_millis(GRAM_MS), Step::NodeUp);
                    }
                }
                Effect::Release(execs) => {
                    for e in execs {
                        self.oracle.on_release(e, now);
                        self.live.remove(&e.0);
                        self.exec_shard.remove(&e.0);
                        self.router.release_node(e);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_matches_reference_vector() {
        // Canonical splitmix64 test vector: first outputs for seed 0
        // (Vigna's reference implementation).
        let mut s = SplitMix64::new(0);
        assert_eq!(s.next(), 0xe220_a839_7b1d_cdaf);
        assert_eq!(s.next(), 0x6e78_9e6a_a1b9_65f4);
        assert_eq!(s.next(), 0x06c4_5d18_8009_454f);
    }

    #[test]
    fn same_seed_reproduces_schedule_and_tallies() {
        let cfg = ChaosConfig::quick(11);
        let a = run_chaos(&cfg);
        let b = run_chaos(&cfg);
        assert_eq!(a.plan, b.plan, "fault schedule must reproduce from the seed");
        assert_eq!(a.tally, b.tally);
        assert_eq!(a.fingerprint, b.fingerprint);
        assert_eq!((a.completed, a.failed), (b.completed, b.failed));
    }

    #[test]
    fn quick_runs_are_clean_and_always_inject() {
        for seed in 1..=4 {
            let r = run_chaos(&ChaosConfig::quick(seed));
            assert!(r.faults_injected > 0, "seed {seed} injected nothing");
            assert_eq!(
                r.oracle_violations, 0,
                "seed {seed}:\n{}",
                r.dump.as_deref().unwrap_or("")
            );
            assert!(!r.stalled, "seed {seed} stalled");
            assert_eq!(
                r.completed + r.failed,
                r.events as u64,
                "seed {seed}: every task reaches a terminal state exactly once"
            );
        }
    }

    #[test]
    fn sharded_runs_survive_partitions() {
        let mut cfg = ChaosConfig::quick(5);
        cfg.shards = 4;
        cfg.nodes = 8;
        let r = run_chaos(&cfg);
        assert!(r.clean(), "{}", r.dump.as_deref().unwrap_or("stalled"));
        assert_eq!(r.completed + r.failed, r.events as u64);
    }

    #[test]
    fn pipeline_scenario_stream_reproduces_and_gates_deps() {
        // Scenario stream with real dependency edges under faults:
        // every task still reaches exactly one terminal state (failed
        // predecessors satisfy edges), and the seed reproduces the
        // schedule bit-for-bit.
        let mut cfg = ChaosConfig::quick(21);
        cfg.scenario = Some(ScenarioSpec::preset("pipeline").unwrap());
        let a = run_chaos(&cfg);
        assert!(a.clean(), "{}", a.dump.as_deref().unwrap_or("stalled"));
        // Whole pipelines: the driver clamps events to the stream.
        assert!(a.events > 0 && a.events <= 60);
        assert_eq!(a.completed + a.failed, a.events as u64);
        let b = run_chaos(&cfg);
        assert_eq!(a.plan, b.plan);
        assert_eq!(a.fingerprint, b.fingerprint);
    }

    #[test]
    fn byzantine_reports_are_rejected_and_accounted() {
        // Forged completions must bounce off the id tables — exactly as
        // many rejections as injections, no effects, oracle clean — and
        // duplicated notifications must not double-grant work. Covers
        // both the K = 1 core path and the K > 1 router path.
        let mut forged_total = 0;
        let mut dup_total = 0;
        for (seed, shards, nodes) in [(3u64, 1usize, 6), (7, 1, 6), (9, 4, 8), (13, 4, 8)] {
            let mut cfg = ChaosConfig::quick(seed);
            cfg.shards = shards;
            cfg.nodes = nodes;
            let r = run_chaos(&cfg);
            assert!(
                r.clean(),
                "seed {seed} shards {shards}:\n{}",
                r.dump.as_deref().unwrap_or("stalled")
            );
            assert_eq!(r.completed + r.failed, r.events as u64);
            assert_eq!(
                r.stale_rejected,
                r.tally.count(FaultKind::CorruptCompletion),
                "seed {seed}: every forged report is rejected, nothing else is"
            );
            forged_total += r.tally.count(FaultKind::CorruptCompletion);
            dup_total += r.tally.count(FaultKind::DuplicateNotify);
        }
        assert!(forged_total > 0, "no seed forged a completion");
        assert!(dup_total > 0, "no seed duplicated a notification");
    }

    #[test]
    fn self_test_produces_seed_and_trace() {
        let dump = oracle_self_test();
        assert!(dump.contains("seed="));
        assert!(dump.contains("fault plan"));
        assert!(dump.contains("trailing event trace"));
        assert!(dump.contains("terminal state twice"));
    }

    #[test]
    fn tally_renders_nonzero_kinds() {
        let mut t = FaultTally::default();
        assert_eq!(t.to_string(), "none");
        t.bump(FaultKind::DelayNotify);
        t.bump(FaultKind::DelayNotify);
        t.bump(FaultKind::KillMidFetch);
        assert_eq!(t.to_string(), "delay-notify=2 kill-mid-fetch=1");
        assert_eq!(t.total(), 3);
    }
}
