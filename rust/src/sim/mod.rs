//! Discrete-event cluster simulator — the ANL/UC TeraGrid substitute.
//!
//! See DESIGN.md §3 for the substitution argument: the paper's evaluation
//! metrics are functions of bandwidth contention, cache contents, and
//! scheduler decisions, which is exactly what this substrate models:
//!
//! * [`flow`] — fluid-flow bandwidth sharing over links (GPFS, per-node
//!   disk and NIC), implementing the paper's η(ν,ω) available-bandwidth
//!   model along transfer paths;
//! * [`engine`] — the event loop driving the coordinator over simulated
//!   time, with dispatcher service-time and GRAM-latency models.
//!
//! Runs are deterministic: `run(cfg)` with the same config and seed
//! produces bit-identical metrics (asserted by the integration suite).

pub mod engine;
pub mod flow;

pub use engine::{run, run_with_shard_recorders, RunResult};
