//! Fluid-flow transfer model — the bandwidth-contention substrate.
//!
//! Every shared resource (the GPFS server, each node's local disk, each
//! node's NIC in/out direction) is a link with an ideal capacity ν.
//! A transfer occupies one or more links; its instantaneous rate is
//! `min over links (capacity / active-count)` — the paper's available-
//! bandwidth model η(ν,ω) = ν/ω (§4.1) applied along the path.
//!
//! Rates change only when a transfer starts or completes, so progress is
//! integrated lazily per transfer and completion times are kept *exact*
//! in an indexed min-heap (decrease-key, no stale entries) — the engine
//! interleaves these completions with its own event queue.
//!
//! ## §Perf — batched rerating
//!
//! Under the 128-concurrent churn regimes of Figs 11–15 every transfer
//! start/completion rerates all co-flows on the shared GPFS link, and
//! same-instant event pileups (a completion chained into the next fetch,
//! a multi-task pickup staging m files at once) repeat that O(active)
//! work per event. [`RerateMode::Batched`] (the default) coalesces:
//! membership changes and progress settling stay eager, but the rerate
//! is deferred and applied **once per touched link per timestamp** at
//! the next query ([`FlowNet::next_completion`] / [`FlowNet::pop_completion`]),
//! with a per-flush epoch so a transfer straddling several dirty links
//! is rerated once, and the completion-heap update skipped whenever the
//! recomputed key is bit-identical (rate provably unchanged ⇒ completion
//! time provably unchanged ⇒ heap untouched).
//!
//! [`RerateMode::Reference`] retains the per-event path
//! (`FlowNet::rerate_reference`) as the executable specification; the
//! `flow_parity` differential suite proves both modes produce
//! **bit-identical completion timestamps** under seeded random churn,
//! including same-instant pileups.
//!
//! To make that equivalence exact (not merely up-to-rounding), both
//! paths share one normalization: a rerate always recomputes the rate
//! *and* the completion key `now + remaining/rate` for every transfer on
//! a touched link. The previous epsilon-skip ("rate unchanged → keep the
//! old key") made the surviving key's anchor depend on *intermediate*
//! same-instant states — e.g. a pop+start pair returning a link to its
//! prior active count re-anchored keys in the per-event path but not in
//! a coalesced one, and the two anchors can differ by 1 µs of float
//! rounding. Anchoring every touched key at the current timestamp makes
//! the final state a pure function of (timestamp, final counts,
//! remaining bytes), which both modes compute identically.

//! ## Active-set and SoA layout
//!
//! Each link keeps its active transfers in a **dense `Vec<u32>`** of
//! slab indices with swap-remove, not a hash set: the settle and rerate
//! sweeps (the flush's inner loops under 128-concurrent churn) iterate
//! it cache-linearly in place, with no per-link scratch copy and no
//! hashing. Removal is a linear scan, but it happens once per transfer
//! per link at completion and is dominated by the O(active) rerate that
//! follows anyway. Iteration order is insertion order — deterministic —
//! and cannot affect results: rates depend only on active *counts*, and
//! the completion heap orders ties by transfer id (its entries are
//! `(key, id)` pairs compared lexicographically), so pop order is
//! layout-independent.
//!
//! Link and transfer state are **struct-of-arrays**: parallel `Vec`s
//! indexed by the link/transfer id instead of `Vec<Link>` /
//! `Vec<Option<Transfer>>` structs. The settle sweep reads exactly
//! three transfer columns (`remaining`, `rate`, `updated`) and the
//! rerate reads two link columns (`cap`, active length), so the inner
//! loops stride over tightly packed floats instead of pulling whole
//! mixed-field structs (tags, link paths, epochs) through the cache —
//! and slot liveness is a plain `bool` column checked in debug builds
//! rather than an `Option` discriminant branched on every access.
//! Completion no longer allocates: the fixed `[u32; 3]` link path is
//! copied out of the column instead of collected into a `Vec`.

use crate::util::time::Micros;

/// Handle to a bandwidth link.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct LinkId(pub u32);

/// Handle to an in-flight transfer.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TransferId(pub u32);

/// When rerates are applied relative to membership changes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RerateMode {
    /// Coalesce same-instant events: settle + rerate each touched link
    /// once per timestamp, at the next query (§Perf rerate batching).
    #[default]
    Batched,
    /// Rerate at every event — the retained per-event reference path;
    /// `rust/tests/flow_parity.rs` proves the modes produce bit-identical
    /// completion timestamps.
    Reference,
}

/// §Perf counters for the rerate work (`perf_hotpath` reports these;
/// the CI bench gate watches the batched-vs-reference ratios).
#[derive(Debug, Default, Clone)]
pub struct FlowStats {
    /// Start/complete events whose rerate was absorbed into a batch.
    pub batched_events: u64,
    /// Batched flushes performed (≤ one per distinct query timestamp).
    pub flushes: u64,
    /// Per-transfer progress integrations (settle steps).
    pub settles: u64,
    /// Per-transfer rate recomputations — the dominant rerate cost.
    pub transfer_rerates: u64,
    /// Completion-key heap updates actually applied (keys recomputed to
    /// a bit-identical value skip the heap entirely).
    pub heap_updates: u64,
    /// Transfers skipped by the per-flush dedup (already rerated via an
    /// earlier dirty link in the same flush).
    pub dedup_skips: u64,
}

/// Indexed min-heap over (completion time, transfer id) with in-place
/// key updates — O(log n), no lazy deletion.
#[derive(Debug, Default)]
struct IndexedHeap {
    /// (key, handle) pairs in heap order.
    heap: Vec<(Micros, u32)>,
    /// handle → position in `heap` (u32::MAX = absent).
    pos: Vec<u32>,
}

const ABSENT: u32 = u32::MAX;

impl IndexedHeap {
    fn ensure(&mut self, handle: u32) {
        if handle as usize >= self.pos.len() {
            self.pos.resize(handle as usize + 1, ABSENT);
        }
    }

    fn insert(&mut self, handle: u32, key: Micros) {
        self.ensure(handle);
        debug_assert_eq!(self.pos[handle as usize], ABSENT);
        self.heap.push((key, handle));
        let i = self.heap.len() - 1;
        self.pos[handle as usize] = i as u32;
        self.sift_up(i);
    }

    #[cfg(test)]
    fn update(&mut self, handle: u32, key: Micros) {
        let _ = self.update_if_changed(handle, key);
    }

    /// Set `handle`'s key; returns false (heap untouched) when the new
    /// key equals the stored one.
    fn update_if_changed(&mut self, handle: u32, key: Micros) -> bool {
        let i = self.pos[handle as usize] as usize;
        debug_assert_ne!(i as u32, ABSENT);
        let old = self.heap[i].0;
        if old == key {
            return false;
        }
        self.heap[i].0 = key;
        if key < old {
            self.sift_up(i);
        } else {
            self.sift_down(i);
        }
        true
    }

    fn remove(&mut self, handle: u32) {
        let i = self.pos[handle as usize] as usize;
        debug_assert_ne!(i as u32, ABSENT);
        self.pos[handle as usize] = ABSENT;
        let last = self.heap.len() - 1;
        if i != last {
            self.heap.swap(i, last);
            self.heap.pop();
            let moved = self.heap[i].1;
            self.pos[moved as usize] = i as u32;
            // Restore heap property in whichever direction is needed.
            self.sift_up(i);
            let j = self.pos[moved as usize] as usize;
            self.sift_down(j);
        } else {
            self.heap.pop();
        }
    }

    fn peek(&self) -> Option<(Micros, u32)> {
        self.heap.first().copied()
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / 2;
            if self.heap[i] < self.heap[parent] {
                self.swap(i, parent);
                i = parent;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        loop {
            let l = 2 * i + 1;
            let r = 2 * i + 2;
            let mut m = i;
            if l < self.heap.len() && self.heap[l] < self.heap[m] {
                m = l;
            }
            if r < self.heap.len() && self.heap[r] < self.heap[m] {
                m = r;
            }
            if m == i {
                break;
            }
            self.swap(i, m);
            i = m;
        }
    }

    fn swap(&mut self, a: usize, b: usize) {
        self.heap.swap(a, b);
        self.pos[self.heap[a].1 as usize] = a as u32;
        self.pos[self.heap[b].1 as usize] = b as u32;
    }

    fn len(&self) -> usize {
        self.heap.len()
    }
}

/// The flow network: links + in-flight transfers + exact completion heap.
///
/// All link and transfer state lives in parallel SoA columns indexed by
/// [`LinkId`] / [`TransferId`] (see the module docs on layout).
#[derive(Debug, Default)]
pub struct FlowNet {
    // ---- links (SoA, indexed by LinkId) ----
    /// Ideal capacity ν per link (bytes/second).
    link_cap: Vec<f64>,
    /// Transfers currently using each link — dense slab-index vecs with
    /// swap-remove (see the module docs on the active-set layout).
    active: Vec<Vec<u32>>,
    /// Pending-rerate flag per link (batched mode).
    link_dirty: Vec<bool>,
    /// Last timestamp each link's co-flows were settled at (settling is
    /// idempotent per timestamp, so repeats within one instant skip).
    settled_at: Vec<Micros>,
    // ---- transfers (SoA slab, indexed by TransferId; `free` lists
    //      dead slots for reuse) ----
    /// Bytes left to move (hot: settle + rerate).
    tr_remaining: Vec<f64>,
    /// Current fair-share rate (hot: settle).
    tr_rate: Vec<f64>,
    /// Timestamp progress was last integrated to (hot: settle).
    tr_updated: Vec<Micros>,
    /// Flush epoch last rerated in (batched dedup).
    tr_epoch: Vec<u64>,
    /// Link path, `[u32::MAX; 3]`-padded (cold: rerate + completion).
    tr_links: Vec<[u32; 3]>,
    /// Live prefix length of `tr_links`.
    tr_nlinks: Vec<u8>,
    /// Engine-side identity (task id), returned on completion.
    tr_tag: Vec<u64>,
    /// Slot liveness (debug-asserted; the free list is authoritative).
    tr_live: Vec<bool>,
    free: Vec<u32>,
    completions: IndexedHeap,
    /// Cumulative completed transfer count (stats).
    pub completed: u64,
    /// Rerate cost counters (§Perf).
    pub stats: FlowStats,
    mode: RerateMode,
    /// Links with a deferred rerate (batched mode; flag lives on the link).
    dirty: Vec<u32>,
    /// Timestamp the pending batch's membership changes happened at.
    batch_time: Micros,
    /// Per-flush dedup epoch.
    epoch: u64,
}

impl FlowNet {
    /// Empty network in the default [`RerateMode::Batched`].
    pub fn new() -> Self {
        Self::default()
    }

    /// Empty network on the per-event reference path.
    pub fn reference() -> Self {
        Self::with_mode(RerateMode::Reference)
    }

    /// Empty network with an explicit rerate mode.
    pub fn with_mode(mode: RerateMode) -> Self {
        FlowNet {
            mode,
            ..Self::default()
        }
    }

    /// The rerate mode this network runs in.
    pub fn mode(&self) -> RerateMode {
        self.mode
    }

    /// Add a link with the given capacity (bytes/second).
    pub fn add_link(&mut self, capacity_bps: f64) -> LinkId {
        assert!(capacity_bps > 0.0);
        self.link_cap.push(capacity_bps);
        self.active.push(Vec::new());
        self.link_dirty.push(false);
        self.settled_at.push(Micros::ZERO);
        LinkId(self.link_cap.len() as u32 - 1)
    }

    /// Active transfer count on a link (release-safety check). Exact at
    /// all times — membership changes are applied eagerly even in
    /// batched mode.
    pub fn link_active(&self, link: LinkId) -> usize {
        self.active[link.0 as usize].len()
    }

    /// In-flight transfer count.
    pub fn in_flight(&self) -> usize {
        self.completions.len()
    }

    /// Start a transfer of `bytes` across `links` (1–3 links) at `now`.
    /// `tag` is returned on completion. Zero-byte transfers complete at
    /// `now` (still go through the heap for deterministic ordering).
    pub fn start(&mut self, now: Micros, bytes: u64, links: &[LinkId], tag: u64) -> TransferId {
        assert!(!links.is_empty() && links.len() <= 3);
        // Dense active vecs assume each link appears once per path (a
        // duplicate would double-count the transfer in the fair share).
        debug_assert!(
            links.iter().all(|l| links.iter().filter(|&m| m == l).count() == 1),
            "transfer path must not repeat a link"
        );
        self.sync_batch(now);
        let mut arr = [u32::MAX; 3];
        for (i, l) in links.iter().enumerate() {
            arr[i] = l.0;
        }
        let id = match self.free.pop() {
            Some(i) => i,
            None => {
                let i = self.tr_remaining.len() as u32;
                self.tr_remaining.push(0.0);
                self.tr_rate.push(0.0);
                self.tr_updated.push(Micros::ZERO);
                self.tr_epoch.push(0);
                self.tr_links.push([u32::MAX; 3]);
                self.tr_nlinks.push(0);
                self.tr_tag.push(0);
                self.tr_live.push(false);
                i
            }
        };
        let i = id as usize;
        debug_assert!(!self.tr_live[i], "slab slot double-booked");
        self.tr_remaining[i] = bytes as f64;
        self.tr_rate[i] = 0.0;
        self.tr_updated[i] = now;
        self.tr_epoch[i] = 0;
        self.tr_links[i] = arr;
        self.tr_nlinks[i] = links.len() as u8;
        self.tr_tag[i] = tag;
        self.tr_live[i] = true;
        // Settle existing flows on the affected links (their shares were
        // real until `now`), add us, then re-rate — immediately on the
        // reference path, or at the next query on the batched one.
        for l in links {
            self.settle_link(*l, now);
        }
        for l in links {
            self.active[l.0 as usize].push(id);
        }
        self.completions.insert(id, Micros::MAX);
        match self.mode {
            RerateMode::Reference => {
                for l in links {
                    self.rerate_reference(*l, now);
                }
            }
            RerateMode::Batched => {
                self.stats.batched_events += 1;
                self.mark_dirty(links);
            }
        }
        TransferId(id)
    }

    /// Earliest completion, if any transfers are in flight. Flushes any
    /// pending batched rerates first, so the answer is always exact.
    pub fn next_completion(&mut self) -> Option<Micros> {
        self.flush();
        self.completions.peek().map(|(t, _)| t)
    }

    /// Pop the transfer completing at `now` (must equal
    /// [`FlowNet::next_completion`]). Returns its tag.
    pub fn pop_completion(&mut self, now: Micros) -> u64 {
        // Keys must be canonical before choosing the minimum, even when
        // the pending batch is at this same instant.
        self.flush();
        self.sync_batch(now);
        let (t, id) = self.completions.peek().expect("no completion pending");
        debug_assert!(t <= now, "popping future completion {t} at {now}");
        self.completions.remove(id);
        let i = id as usize;
        debug_assert!(self.tr_live[i], "live transfer");
        // Fixed-width path copy — no per-completion Vec.
        let path = self.tr_links[i];
        let nl = self.tr_nlinks[i] as usize;
        let tag = self.tr_tag[i];
        // Settle co-flows while this transfer is still a link member (its
        // share was real until `now`), then remove it and re-rate.
        for &l in &path[..nl] {
            self.settle_link(LinkId(l), now);
        }
        for &l in &path[..nl] {
            let active = &mut self.active[l as usize];
            let pos = active
                .iter()
                .position(|&t| t == id)
                .expect("completing transfer must be active on its links");
            active.swap_remove(pos);
        }
        self.tr_live[i] = false;
        self.free.push(id);
        self.completed += 1;
        match self.mode {
            RerateMode::Reference => {
                for &l in &path[..nl] {
                    self.rerate_reference(LinkId(l), now);
                }
            }
            RerateMode::Batched => {
                self.stats.batched_events += 1;
                for &l in &path[..nl] {
                    self.mark_dirty_one(l);
                }
            }
        }
        tag
    }

    /// Apply all deferred rerates of the pending batch (no-op when none
    /// are pending, i.e. always on the reference path).
    pub fn flush(&mut self) {
        if self.dirty.is_empty() {
            return;
        }
        self.stats.flushes += 1;
        self.epoch += 1;
        let now = self.batch_time;
        let mut dirty = std::mem::take(&mut self.dirty);
        for &l in &dirty {
            self.link_dirty[l as usize] = false;
        }
        for &l in &dirty {
            // Dense active vec: iterate in place (membership cannot
            // change during a flush; rerating touches rates and the
            // completion heap only).
            for k in 0..self.active[l as usize].len() {
                let id = self.active[l as usize][k];
                debug_assert!(self.tr_live[id as usize], "active transfer must live");
                if self.tr_epoch[id as usize] == self.epoch {
                    self.stats.dedup_skips += 1;
                    continue;
                }
                self.rerate_one(id, now);
                self.tr_epoch[id as usize] = self.epoch;
            }
        }
        dirty.clear();
        self.dirty = dirty;
    }

    /// Open (or extend) the batch at `now`, flushing a previous batch
    /// left pending at an earlier instant.
    fn sync_batch(&mut self, now: Micros) {
        debug_assert!(
            now >= self.batch_time,
            "time went backwards: {now} < {}",
            self.batch_time
        );
        if now != self.batch_time {
            self.flush();
            self.batch_time = now;
        }
    }

    fn mark_dirty_one(&mut self, l: u32) {
        if !self.link_dirty[l as usize] {
            self.link_dirty[l as usize] = true;
            self.dirty.push(l);
        }
    }

    fn mark_dirty(&mut self, links: &[LinkId]) {
        for l in links {
            self.mark_dirty_one(l.0);
        }
    }

    /// Integrate progress of all transfers on `link` up to `now`.
    /// Idempotent per timestamp: repeats within one instant return
    /// immediately ("settle each touched link once per timestamp").
    /// The inner loop reads exactly three SoA columns.
    fn settle_link(&mut self, link: LinkId, now: Micros) {
        let li = link.0 as usize;
        if self.settled_at[li] == now {
            return;
        }
        self.settled_at[li] = now;
        for k in 0..self.active[li].len() {
            let id = self.active[li][k] as usize;
            debug_assert!(self.tr_live[id], "active transfer must live");
            if self.tr_updated[id] < now {
                let dt = (now - self.tr_updated[id]).as_secs_f64();
                self.tr_remaining[id] = (self.tr_remaining[id] - self.tr_rate[id] * dt).max(0.0);
                self.tr_updated[id] = now;
                self.stats.settles += 1;
            }
        }
    }

    /// Recompute one transfer's rate and completion key anchored at
    /// `now`. The heap is only touched when the key actually changed.
    fn rerate_one(&mut self, id: u32, now: Micros) {
        let i = id as usize;
        debug_assert!(self.tr_live[i], "active transfer must live");
        let mut rate = f64::INFINITY;
        for &l in &self.tr_links[i][..self.tr_nlinks[i] as usize] {
            let li = l as usize;
            rate = rate.min(self.link_cap[li] / self.active[li].len().max(1) as f64);
        }
        debug_assert!(rate.is_finite() && rate > 0.0);
        self.stats.transfer_rerates += 1;
        let done = now
            .checked_add(Micros::from_secs_f64(self.tr_remaining[i] / rate))
            .unwrap_or(Micros::MAX);
        self.tr_rate[i] = rate;
        if self.completions.update_if_changed(id, done) {
            self.stats.heap_updates += 1;
        }
    }

    /// The retained per-event rerate: recompute rates and completion
    /// keys for all transfers on `link`, immediately. This is the
    /// executable specification the batched flush must agree with
    /// (see `rust/tests/flow_parity.rs`).
    fn rerate_reference(&mut self, link: LinkId, now: Micros) {
        for k in 0..self.active[link.0 as usize].len() {
            let id = self.active[link.0 as usize][k];
            self.rerate_one(id, now);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::gbps_to_bps;

    #[test]
    fn single_transfer_at_full_rate() {
        let mut net = FlowNet::new();
        let l = net.add_link(gbps_to_bps(8.0)); // 1 GB/s
        net.start(Micros::ZERO, 1_000_000_000, &[l], 42);
        let done = net.next_completion().unwrap();
        assert!((done.as_secs_f64() - 1.0).abs() < 1e-6, "{done}");
        assert_eq!(net.pop_completion(done), 42);
        assert_eq!(net.next_completion(), None);
        assert_eq!(net.completed, 1);
    }

    #[test]
    fn fair_share_halves_rate() {
        let mut net = FlowNet::new();
        let l = net.add_link(gbps_to_bps(8.0));
        net.start(Micros::ZERO, 1_000_000_000, &[l], 1);
        net.start(Micros::ZERO, 1_000_000_000, &[l], 2);
        // Both share: each at 0.5 GB/s → 2 s.
        let done = net.next_completion().unwrap();
        assert!((done.as_secs_f64() - 2.0).abs() < 1e-6, "{done}");
        net.pop_completion(done);
        // Survivor had 0 bytes left? No: both finish at 2 s.
        let done2 = net.next_completion().unwrap();
        assert!((done2.as_secs_f64() - 2.0).abs() < 1e-6);
    }

    #[test]
    fn late_joiner_slows_then_speeds_up() {
        let mut net = FlowNet::new();
        let l = net.add_link(1000.0); // 1000 B/s
        net.start(Micros::ZERO, 1000, &[l], 1);
        // At t=0.5, 500 bytes left; second transfer joins.
        net.start(Micros::from_secs_f64(0.5), 1000, &[l], 2);
        // First: 500 B at 500 B/s → done t=1.5.
        let d1 = net.next_completion().unwrap();
        assert!((d1.as_secs_f64() - 1.5).abs() < 1e-6, "{d1}");
        assert_eq!(net.pop_completion(d1), 1);
        // Second: at t=1.5 it has 1000-500=500 left, now alone at 1000 B/s → t=2.0.
        let d2 = net.next_completion().unwrap();
        assert!((d2.as_secs_f64() - 2.0).abs() < 1e-6, "{d2}");
        assert_eq!(net.pop_completion(d2), 2);
    }

    #[test]
    fn min_over_links_bottleneck() {
        let mut net = FlowNet::new();
        let fast = net.add_link(1000.0);
        let slow = net.add_link(100.0);
        net.start(Micros::ZERO, 100, &[fast, slow], 1);
        let done = net.next_completion().unwrap();
        assert!((done.as_secs_f64() - 1.0).abs() < 1e-6, "{done}");
    }

    #[test]
    fn shared_bottleneck_across_paths() {
        let mut net = FlowNet::new();
        let gpfs = net.add_link(1000.0);
        let nic_a = net.add_link(10_000.0);
        let nic_b = net.add_link(10_000.0);
        net.start(Micros::ZERO, 500, &[gpfs, nic_a], 1);
        net.start(Micros::ZERO, 500, &[gpfs, nic_b], 2);
        // GPFS is the shared bottleneck: each gets 500 B/s → 1 s.
        let done = net.next_completion().unwrap();
        assert!((done.as_secs_f64() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn zero_byte_transfer_completes_immediately() {
        let mut net = FlowNet::new();
        let l = net.add_link(1000.0);
        net.start(Micros::from_secs(5), 0, &[l], 9);
        assert_eq!(net.next_completion(), Some(Micros::from_secs(5)));
        assert_eq!(net.pop_completion(Micros::from_secs(5)), 9);
    }

    #[test]
    fn aggregate_link_throughput_is_capped() {
        // 10 concurrent transfers on a 1000 B/s link, 100 B each: total
        // 1000 B at 1000 B/s aggregate → all complete at t=1.
        let mut net = FlowNet::new();
        let l = net.add_link(1000.0);
        for i in 0..10 {
            net.start(Micros::ZERO, 100, &[l], i);
        }
        let mut last = Micros::ZERO;
        for _ in 0..10 {
            let t = net.next_completion().unwrap();
            net.pop_completion(t);
            last = t;
        }
        assert!((last.as_secs_f64() - 1.0).abs() < 1e-6, "{last}");
    }

    #[test]
    fn slab_reuse_and_many_transfers() {
        let mut net = FlowNet::new();
        let l = net.add_link(1e9);
        for round in 0..100u64 {
            let now = Micros::from_secs(round);
            for i in 0..5 {
                net.start(now, 1000, &[l], round * 10 + i);
            }
            for _ in 0..5 {
                let t = net.next_completion().unwrap();
                net.pop_completion(t);
            }
        }
        assert_eq!(net.completed, 500);
        assert!(net.tr_tag.len() <= 8, "slab grew: {}", net.tr_tag.len());
    }

    #[test]
    fn reference_mode_behaves_identically_on_basics() {
        for mode in [RerateMode::Batched, RerateMode::Reference] {
            let mut net = FlowNet::with_mode(mode);
            let l = net.add_link(1000.0);
            net.start(Micros::ZERO, 1000, &[l], 1);
            net.start(Micros::from_secs_f64(0.5), 1000, &[l], 2);
            let d1 = net.next_completion().unwrap();
            assert_eq!(net.pop_completion(d1), 1, "{mode:?}");
            let d2 = net.next_completion().unwrap();
            assert_eq!(net.pop_completion(d2), 2, "{mode:?}");
            assert!((d1.as_secs_f64() - 1.5).abs() < 1e-6, "{mode:?}: {d1}");
            assert!((d2.as_secs_f64() - 2.0).abs() < 1e-6, "{mode:?}: {d2}");
        }
    }

    #[test]
    fn batched_mode_rerates_less_than_reference() {
        // The perf_hotpath churn shape: a shared bottleneck link, one
        // pop + one start per instant with the query in between — the
        // batched path must coalesce each pop+start pair into one flush.
        let run = |mode: RerateMode| -> FlowStats {
            let mut net = FlowNet::with_mode(mode);
            let gpfs = net.add_link(5.5e8);
            let nics: Vec<LinkId> = (0..8).map(|_| net.add_link(1.25e8)).collect();
            let mut i = 0u64;
            for _ in 0..32 {
                net.start(Micros::ZERO, 10_000_000, &[gpfs, nics[(i % 8) as usize]], i);
                i += 1;
            }
            for _ in 0..200 {
                let t = net.next_completion().expect("in flight");
                net.pop_completion(t);
                net.start(t, 10_000_000, &[gpfs, nics[(i % 8) as usize]], i);
                i += 1;
            }
            net.stats.clone()
        };
        let batched = run(RerateMode::Batched);
        let reference = run(RerateMode::Reference);
        assert!(
            batched.transfer_rerates * 3 < reference.transfer_rerates * 2,
            "batched {} !≪ reference {}",
            batched.transfer_rerates,
            reference.transfer_rerates
        );
        assert!(batched.heap_updates <= reference.heap_updates);
        assert!(batched.flushes > 0 && batched.batched_events > 0);
        assert_eq!(reference.flushes, 0);
    }

    #[test]
    fn indexed_heap_ordering_under_updates() {
        use crate::util::proptest::{property, Gen};
        property("indexed heap", 100, |g: &mut Gen| {
            let mut h = IndexedHeap::default();
            let mut model: std::collections::HashMap<u32, Micros> = Default::default();
            for _ in 0..g.usize_in(1..100) {
                let handle = g.u64_in(0..20) as u32;
                match g.usize_in(0..3) {
                    0 if !model.contains_key(&handle) => {
                        let k = Micros(g.u64_in(0..1000));
                        h.insert(handle, k);
                        model.insert(handle, k);
                    }
                    1 if model.contains_key(&handle) => {
                        let k = Micros(g.u64_in(0..1000));
                        h.update(handle, k);
                        model.insert(handle, k);
                    }
                    2 if model.contains_key(&handle) => {
                        h.remove(handle);
                        model.remove(&handle);
                    }
                    _ => {}
                }
                match h.peek() {
                    None => {
                        if !model.is_empty() {
                            return Err("heap empty but model not".into());
                        }
                    }
                    Some((k, _)) => {
                        let min = model.values().min().copied().unwrap();
                        if k != min {
                            return Err(format!("peek {k} != model min {min}"));
                        }
                    }
                }
            }
            Ok(())
        });
    }
}
