//! The discrete-event cluster engine — the testbed substitute.
//!
//! Since the coordinator-core refactor this engine is a **thin driver**:
//! every dispatch decision — queueing, notification, pickup, access
//! resolution, cache admission, replica accounting, provisioning — lives
//! in the shared
//! [`CoordinatorCore`](crate::coordinator::core::CoordinatorCore), and
//! this module only maps the returned [`Effect`]s onto simulated time
//! and the fluid-flow contention model of [`super::flow`]:
//!
//! * [`Effect::Notify`] → a dispatch round-trip through the owning
//!   shard's dispatcher service instance with a per-decision service
//!   time, reproducing Falkon's measured dispatch throughput ceiling
//!   (§5.1);
//! * [`Effect::Fetch`] → a transfer on the flow network. **GPFS** is one
//!   shared link (≈4.4 Gb/s sustained); each node contributes a
//!   **local-disk link** and **NIC in/out links**; a local hit reads
//!   `[disk(e)]`, a peer ("global") hit reads
//!   `[disk(peer), nic_out(peer), nic_in(e)]` (GridFTP alongside each
//!   executor, §3.1.1) after a session-setup delay, and a miss reads
//!   `[gpfs, nic_in(e)]`;
//! * [`Effect::Compute`] → a `ComputeDone` event after the task's μ(κ);
//! * [`Effect::Allocate`] → `NodesUp` after the GRAM/LRM allocation
//!   latency (30–60 s, §5.2.5); [`Effect::Release`] → deregistration,
//!   deferred while the node still serves peer transfers.
//!
//! The engine is fully deterministic for a given config: integer event
//! times, seeded PRNG streams, sequence-numbered heap ties. The same
//! effects drive the live engine ([`crate::live`]) over wall clock and
//! real file copies; `rust/tests/core_parity.rs` asserts both drivers
//! replay identical decision sequences.
//!
//! Since PR 5 the engine drives a [`ShardedCoordinator`] — K coordinator
//! cores under one router (`cluster.shards`, default 1) — while keeping
//! **one flow network** for the whole cluster: cross-shard peer fetches
//! ride the same per-node disk/NIC links as in-shard ones, and GPFS
//! stays a single shared bottleneck. Each shard gets its own dispatcher
//! service instance (the paper's §5.1 throughput ceiling is per
//! dispatcher, which is exactly what sharding multiplies). At K = 1 the
//! router is a bit-identical pass-through (`rust/tests/shard_parity.rs`),
//! so single-shard results are unchanged.
//!
//! Data movement runs on the **batched** flow-net rerate path
//! ([`FlowNet::new`] defaults to [`super::flow::RerateMode::Batched`]):
//! same-instant transfer starts/completions (a completion chaining into
//! the next fetch, a multi-task pickup staging several files) settle and
//! rerate each touched link once per timestamp instead of once per
//! event. The per-event path is retained as the executable reference and
//! proven bit-identical by `rust/tests/flow_parity.rs`, so simulation
//! results do not depend on the mode.

use super::flow::{FlowNet, LinkId};
use crate::config::ExperimentConfig;
use crate::coordinator::core::{CoreConfig, Effect, FetchPlan, FileSizes};
use crate::coordinator::queue::Task;
use crate::coordinator::scheduler::SchedulerStats;
use crate::coordinator::shard::ShardedCoordinator;
use crate::coordinator::AccessKind;
use crate::ids::{ExecutorId, TaskId};
use crate::metrics::{IntervalStat, Recorder, ShardCounters, SummaryMetrics, TimeSeries};
use crate::util::prng::Pcg64;
use crate::util::time::Micros;
use crate::util::units::gbps_to_bps;
use crate::workload::{self, Workload};
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};

/// Result of one simulated experiment.
#[derive(Debug)]
pub struct RunResult {
    /// Experiment name (from the config).
    pub name: String,
    /// End-of-run summary metrics.
    pub summary: SummaryMetrics,
    /// Per-second time series (the Figs 4–10 summary views).
    pub ts: TimeSeries,
    /// Per arrival-interval slowdown stats (Fig 14).
    pub intervals: Vec<IntervalStat>,
    /// Scheduler behaviour counters.
    pub sched_stats: SchedulerStats,
    /// Tasks in dispatch order — the coordinator-core decision trace
    /// `core_parity` compares against the live driver. For sharded runs
    /// the per-shard traces are concatenated in shard order.
    pub dispatch_order: Vec<TaskId>,
    /// Raw access tallies `(hits_local, hits_global, misses)`.
    pub access_counts: (u64, u64, u64),
    /// Router-level sharding tallies (`shards == 1` for plain runs).
    pub shard: ShardCounters,
    /// Working-set size of the generated workload (bytes).
    pub working_set_bytes: u64,
    /// Bytes per file in the workload.
    pub file_size_bytes: u64,
    /// Wall-clock seconds the simulation itself took (engine §Perf).
    pub sim_wall_s: f64,
    /// Events processed (engine §Perf).
    pub events_processed: u64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Event {
    /// Task `workload index` arrives.
    Arrival(u32),
    /// Dispatch notification delivered; executor asks for work.
    Pickup(ExecutorId),
    /// Task finished computing on its executor.
    ComputeDone(u64),
    /// Delayed transfer start (peer-fetch session setup elapsed).
    StartTransfer(u64),
    /// A provisioning batch of `n` nodes finished GRAM bootstrap.
    NodesUp(u32),
    /// 1 Hz metrics sample + provisioning decision.
    Tick,
}

#[derive(Debug)]
struct HeapEntry {
    time: Micros,
    seq: u64,
    event: Event,
}

impl PartialEq for HeapEntry {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for HeapEntry {}
impl PartialOrd for HeapEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for HeapEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.time, self.seq).cmp(&(other.time, other.seq))
    }
}

/// Per-node link handles.
#[derive(Debug, Clone, Copy)]
struct NodeLinks {
    disk: LinkId,
    nic_in: LinkId,
    nic_out: LinkId,
}

/// Does any transfer — live on the flow net, or parked in the GridFTP
/// session-setup window — touch one of this node's links?
///
/// Parked transfers (`delayed`) have reserved their path but hold no
/// flow-link capacity yet, so `link_active` alone misses them; a source
/// released during the setup window would have its transfer start over
/// the links of a node that no longer exists.
fn node_serving(
    flow: &FlowNet,
    delayed: &HashMap<u64, (u64, Vec<LinkId>)>,
    links: &NodeLinks,
) -> bool {
    let lids = [links.disk, links.nic_in, links.nic_out];
    lids.iter().any(|&l| flow.link_active(l) > 0)
        || delayed
            .values()
            .any(|(_, path)| path.iter().any(|l| lids.contains(l)))
}

/// The engine. Construct via [`run`].
struct Engine {
    cfg: ExperimentConfig,
    wl: Workload,
    clock: Micros,
    heap: BinaryHeap<Reverse<HeapEntry>>,
    seq: u64,
    /// The coordinator router: all dispatch state transitions go
    /// through its event API (K cores at `cluster.shards`; a
    /// bit-identical pass-through at K = 1); this driver never touches
    /// a wait queue, scheduler or pending index directly.
    router: ShardedCoordinator,
    // Cluster substrate.
    flow: FlowNet,
    gpfs: LinkId,
    node_links: HashMap<ExecutorId, NodeLinks>,
    /// Peer fetches waiting out the GridFTP session setup:
    /// task id → (bytes, flow path).
    delayed: HashMap<u64, (u64, Vec<LinkId>)>,
    /// Dispatcher service model — one service instance *per shard*
    /// (indexed by shard id), reproducing Falkon's per-dispatcher
    /// throughput ceiling while letting shards dispatch concurrently.
    dispatcher_free_at: Vec<Micros>,
    pending_pickups: usize,
    // GRAM latency randomness.
    rng_gram: Pcg64,
    /// Dependency gating (scenario workloads with dep edges only; all
    /// three stay empty for flat workloads, so the legacy arrival path
    /// pays nothing). Indexed by workload task index (== task id).
    dep_remaining: Vec<u32>,
    dep_children: Vec<Vec<u32>>,
    held: Vec<bool>,
    // Progress.
    completed: u64,
    events: u64,
}

/// Run one experiment to completion.
pub fn run(cfg: &ExperimentConfig) -> RunResult {
    run_with_shard_recorders(cfg).0
}

/// Run one experiment, also returning the per-shard recorders the merged
/// report was built from (in shard order) — the `figures --emit-shards`
/// seam. The [`RunResult`] is identical to [`run`]'s: the merged view is
/// a fresh [`Recorder`] absorbing clones of the returned shard
/// recorders, which `Recorder::absorb`'s losslessness makes bit-equal to
/// the router's own end-of-run merge.
pub fn run_with_shard_recorders(cfg: &ExperimentConfig) -> (RunResult, Vec<Recorder>) {
    cfg.validate().expect("invalid experiment config");
    let t_wall = std::time::Instant::now();
    let wl = workload::generate(&cfg.workload, cfg.seed);
    let working_set = wl.working_set_bytes();
    // Scenario workloads can carry dependency edges, so their ideal WET
    // comes from the generated DAG; flat workloads keep the closed-form
    // path (bit-identical to the pre-scenario engine).
    let ideal_wet = if cfg.workload.scenario.is_some() {
        wl.ideal_execution_time_s()
    } else {
        workload::ideal_execution_time_s(&cfg.workload)
    };

    // Fork order matters: the coordinator's access-resolution stream is
    // fork(1), GRAM latency fork(2) — identical to the pre-core engine.
    // At K > 1 the router forks per-shard streams from the fork(1)
    // stream; at K = 1 the single core receives it verbatim.
    let mut root = Pcg64::seeded(cfg.seed);
    let rng_cache = root.fork(1);
    let rng_gram = root.fork(2);
    let shards = cfg.cluster.shards.max(1);
    let mut router = ShardedCoordinator::new(
        CoreConfig {
            scheduler: cfg.scheduler.clone(),
            provisioner: cfg.provisioner.clone(),
            cache: cfg.cache,
            max_nodes: cfg.cluster.max_nodes,
            slots_per_node: cfg.cluster.cpus_per_node as u32,
            file_sizes: FileSizes::Uniform(cfg.workload.file_size_bytes),
        },
        shards,
        rng_cache,
    );
    // Calibrate the online §3 controller (if `--allocation model`) with
    // the same cluster rates and per-task overhead the offline model
    // uses, so fig02's validation transfers to the closed loop.
    router.set_model_config(crate::coordinator::model::ModelControllerConfig {
        persistent_gbps: cfg.cluster.gpfs_gbps,
        local_disk_gbps: cfg.cluster.local_disk_gbps,
        overhead_s: cfg.cluster.dispatch_service_us / 1e6
            + 2.0 * cfg.cluster.net_latency_ms / 1e3,
        ..Default::default()
    });
    // Dependency bookkeeping only materializes when the workload
    // actually carries edges (pipeline scenarios).
    let (dep_remaining, dep_children, held) = if wl.dep_edges > 0 {
        let n = wl.tasks.len();
        let mut remaining = vec![0u32; n];
        let mut children: Vec<Vec<u32>> = vec![Vec::new(); n];
        for (i, t) in wl.tasks.iter().enumerate() {
            remaining[i] = t.deps.len() as u32;
            for d in &t.deps {
                children[d.0 as usize].push(i as u32);
            }
        }
        (remaining, children, vec![false; n])
    } else {
        (Vec::new(), Vec::new(), Vec::new())
    };
    let mut eng = Engine {
        router,
        flow: FlowNet::new(),
        gpfs: LinkId(0),
        node_links: HashMap::new(),
        delayed: HashMap::new(),
        dispatcher_free_at: vec![Micros::ZERO; shards],
        pending_pickups: 0,
        rng_gram,
        dep_remaining,
        dep_children,
        held,
        completed: 0,
        events: 0,
        clock: Micros::ZERO,
        heap: BinaryHeap::new(),
        seq: 0,
        cfg: cfg.clone(),
        wl,
    };
    eng.gpfs = eng.flow.add_link(gbps_to_bps(cfg.cluster.gpfs_gbps));

    // Initial nodes (static provisioning / warm start) register at t=0.
    for _ in 0..cfg.provisioner.initial_nodes {
        eng.register_node();
    }
    // Kick off arrivals and the 1 Hz tick.
    if !eng.wl.tasks.is_empty() {
        let t0 = eng.wl.tasks[0].arrival;
        eng.push(t0, Event::Arrival(0));
    }
    eng.push(Micros::ZERO, Event::Tick);

    eng.run_loop();

    let fs = &eng.flow.stats;
    crate::debug!(
        "`{}` flow rerate stats: {} events batched into {} flushes, \
         {} transfer rerates, {} heap updates ({} dedup skips)",
        cfg.name,
        fs.batched_events,
        fs.flushes,
        fs.transfer_rerates,
        fs.heap_updates,
        fs.dedup_skips
    );
    // Merged reporting: the per-shard recorders are taken unmerged (so
    // emit-shards can snapshot them) and absorbed into one cluster view,
    // which Recorder::absorb's losslessness makes bit-identical to the
    // router's own merge at any K. The dispatch log must be taken before
    // the counters so the per-shard dispatch tallies are filled.
    let sched_stats = eng.router.merged_sched_stats();
    let dispatch_order = eng.router.take_dispatch_log();
    let shard = eng.router.take_counters();
    let shard_recs = eng.router.take_shard_recorders();
    let mut rec = Recorder::new();
    for r in &shard_recs {
        rec.absorb(r.clone());
    }
    let summary = rec.summarize(ideal_wet);
    let result = RunResult {
        name: cfg.name.clone(),
        summary,
        access_counts: rec.access_counts(),
        ts: std::mem::take(&mut rec.ts),
        intervals: std::mem::take(&mut rec.intervals),
        sched_stats,
        dispatch_order,
        shard,
        working_set_bytes: working_set,
        file_size_bytes: cfg.workload.file_size_bytes,
        sim_wall_s: t_wall.elapsed().as_secs_f64(),
        events_processed: eng.events,
    };
    (result, shard_recs)
}

impl Engine {
    fn push(&mut self, time: Micros, event: Event) {
        debug_assert!(time >= self.clock, "event scheduled in the past");
        self.seq += 1;
        self.heap.push(Reverse(HeapEntry {
            time,
            seq: self.seq,
            event,
        }));
    }

    fn run_loop(&mut self) {
        let total = self.wl.tasks.len() as u64;
        while self.completed < total {
            // Interleave flow completions with coordinator events;
            // transfer completions win ties so data is accounted before
            // same-instant samples.
            let next_main = self.heap.peek().map(|Reverse(e)| e.time);
            let next_flow = self.flow.next_completion();
            match (next_main, next_flow) {
                (None, None) => {
                    panic!(
                        "simulation stalled at {} with {} tasks incomplete \
                         (queue={})",
                        self.clock,
                        total - self.completed,
                        self.router.queue_len()
                    );
                }
                (m, Some(f)) if m.is_none_or(|m| f <= m) => {
                    self.clock = f;
                    self.events += 1;
                    let tag = self.flow.pop_completion(f);
                    let effects = self.router.on_fetch_done(TaskId(tag), f, None);
                    self.handle(effects);
                }
                _ => {
                    let Reverse(entry) = self.heap.pop().expect("peeked");
                    self.clock = entry.time;
                    self.events += 1;
                    self.on_event(entry.event);
                }
            }
        }
    }

    fn on_event(&mut self, event: Event) {
        match event {
            Event::Arrival(i) => self.on_arrival(i),
            Event::Pickup(e) => {
                self.pending_pickups -= 1;
                let effects = self.router.on_pickup(e, self.clock);
                self.handle(effects);
            }
            Event::ComputeDone(task_id) => {
                let latency = Micros::from_secs_f64(self.cfg.cluster.net_latency_ms / 1e3);
                let effects =
                    self.router
                        .on_compute_done(TaskId(task_id), self.clock, self.clock + latency);
                self.completed += 1;
                self.handle(effects);
                // Task ids equal workload indices in every generator.
                self.on_task_done(task_id as usize);
            }
            Event::StartTransfer(task_id) => {
                let (bytes, path) = self
                    .delayed
                    .remove(&task_id)
                    .expect("delayed start for unknown task");
                debug_assert!(!path.is_empty());
                self.flow.start(self.clock, bytes, &path, task_id);
            }
            Event::NodesUp(n) => {
                for _ in 0..n {
                    let (id, effects) = self.router.on_node_registered(self.clock);
                    self.add_node_links(id);
                    self.handle(effects);
                }
            }
            Event::Tick => self.on_tick(),
        }
    }

    /// Enact a batch of coordinator effects on the simulated substrate.
    fn handle(&mut self, effects: Vec<Effect>) {
        for effect in effects {
            match effect {
                Effect::Notify(e) => self.deliver_pickup(e),
                Effect::Fetch(plan) => self.start_transfer(plan),
                Effect::Compute {
                    task_id, compute, ..
                } => {
                    self.push(self.clock + compute, Event::ComputeDone(task_id.0));
                }
                Effect::Allocate(n) => {
                    let (lo, hi) = self.cfg.cluster.gram_latency_s;
                    let latency =
                        Micros::from_secs_f64(self.rng_gram.range_f64(lo, hi.max(lo + 1e-9)));
                    self.push(self.clock + latency, Event::NodesUp(n as u32));
                }
                Effect::Release(execs) => {
                    for e in execs {
                        self.try_release(e);
                    }
                }
            }
        }
    }

    // ---- node lifecycle -------------------------------------------------

    fn add_node_links(&mut self, id: ExecutorId) {
        let disk = self.flow.add_link(gbps_to_bps(self.cfg.cluster.local_disk_gbps));
        let nic_in = self.flow.add_link(gbps_to_bps(self.cfg.cluster.nic_gbps));
        let nic_out = self.flow.add_link(gbps_to_bps(self.cfg.cluster.nic_gbps));
        self.node_links.insert(
            id,
            NodeLinks {
                disk,
                nic_in,
                nic_out,
            },
        );
    }

    fn register_node(&mut self) {
        let (id, effects) = self.router.register_node(self.clock);
        self.add_node_links(id);
        // A fresh executor immediately asks for work.
        self.handle(effects);
    }

    fn try_release(&mut self, id: ExecutorId) {
        // Peers may be mid-transfer from this node's cache; skip the
        // release this round if so (retry next tick). The coordinator
        // core already withholds serving sources via its peer-serving
        // refcounts; this driver-side check is the engine's own guard
        // for anything the core cannot see — in particular transfers
        // still parked in the GridFTP session-setup window (`delayed`),
        // which hold no flow-link capacity yet but name this node's
        // links in their path.
        if let Some(links) = self.node_links.get(&id) {
            if node_serving(&self.flow, &self.delayed, links) {
                return;
            }
        }
        self.router.release_node(id);
        self.node_links.remove(&id);
    }

    // ---- dispatch path --------------------------------------------------

    /// Route a `Notify` effect through the owning shard's dispatcher
    /// service queue: the reservation is already held by the core; this
    /// models the per-decision service time plus network latency before
    /// the executor asks for work. One service instance per shard — the
    /// §5.1 dispatch ceiling is a per-dispatcher property, so K shards
    /// dispatch concurrently (at K = 1 this is the single pre-shard
    /// dispatcher, unchanged).
    fn deliver_pickup(&mut self, exec: ExecutorId) {
        self.pending_pickups += 1;
        let shard = self.router.shard_of_exec(exec).unwrap_or(0);
        let service = Micros::from_secs_f64(self.cfg.cluster.dispatch_service_us / 1e6);
        let start = self.dispatcher_free_at[shard].max(self.clock);
        self.dispatcher_free_at[shard] = start + service;
        let latency = Micros::from_secs_f64(self.cfg.cluster.net_latency_ms / 1e3);
        self.push(self.dispatcher_free_at[shard] + latency, Event::Pickup(exec));
    }

    fn on_arrival(&mut self, i: u32) {
        // Chain the next arrival first: a dependency-gated task must
        // not stall the arrival stream behind it.
        let next = i as usize + 1;
        if next < self.wl.tasks.len() {
            let t = self.wl.tasks[next].arrival;
            self.push(t.max(self.clock), Event::Arrival(next as u32));
        }
        if !self.dep_remaining.is_empty() && self.dep_remaining[i as usize] > 0 {
            // Unmet predecessors: hold the task until the last one
            // completes (`on_task_done` submits it then).
            self.held[i as usize] = true;
            return;
        }
        self.submit(i);
    }

    /// Hand task `i` to the coordinator — at its arrival event, or (for
    /// dependency-gated tasks) when the last predecessor completes. For
    /// sorted, ungated streams `clock == spec.arrival`, so the clamp is
    /// a no-op and the legacy path is bit-identical; a released task's
    /// effective arrival is the instant it became runnable.
    fn submit(&mut self, i: u32) {
        let spec = &self.wl.tasks[i as usize];
        let task = Task {
            id: spec.id,
            files: spec.inputs.clone(),
            compute: self.wl.compute,
            arrival: spec.arrival.max(self.clock),
        };
        let interval = spec.interval;
        let rate = self
            .wl
            .stages
            .get(interval as usize)
            .map_or(0.0, |&(_, r)| r);
        let effects = self.router.on_arrival(task, interval, rate, self.clock);
        self.handle(effects);
    }

    /// Release dependency-gated children of a finished task: decrement
    /// each child's unmet-predecessor count, and submit any child whose
    /// own arrival event already passed while it was held.
    fn on_task_done(&mut self, idx: usize) {
        if self.dep_children.is_empty() {
            return;
        }
        let children = self.dep_children[idx].clone();
        for c in children {
            let c = c as usize;
            self.dep_remaining[c] -= 1;
            if self.dep_remaining[c] == 0 && self.held[c] {
                self.held[c] = false;
                self.submit(c as u32);
            }
        }
    }

    /// Map a resolved fetch onto the flow network. Peer fetches pay a
    /// GridFTP session-setup cost before bytes flow
    /// (`cluster.peer_overhead_ms`) — see Fig 10's discussion of remote
    /// cache access costs.
    fn start_transfer(&mut self, plan: FetchPlan) {
        let links = self.node_links[&plan.exec];
        let path: Vec<LinkId> = match (plan.kind, plan.peer) {
            (AccessKind::HitLocal, _) => vec![links.disk],
            (AccessKind::HitGlobal, Some(p)) => {
                let pl = self.node_links[&p];
                vec![pl.disk, pl.nic_out, links.nic_in]
            }
            (AccessKind::HitGlobal, None) => unreachable!("global hit needs a peer"),
            (AccessKind::Miss, _) => vec![self.gpfs, links.nic_in],
        };
        let overhead = self.cfg.cluster.peer_overhead_ms;
        if plan.kind == AccessKind::HitGlobal && overhead > 0.0 {
            self.delayed.insert(plan.task_id.0, (plan.bytes, path));
            self.push(
                self.clock + Micros::from_secs_f64(overhead / 1e3),
                Event::StartTransfer(plan.task_id.0),
            );
        } else {
            self.flow.start(self.clock, plan.bytes, &path, plan.task_id.0);
        }
    }

    // ---- provisioning ---------------------------------------------------

    fn on_tick(&mut self) {
        let effects = self.router.on_tick(self.clock);
        self.handle(effects);
        // Safety net: if tasks wait, executors are free, and no pickup is
        // in flight (e.g. every notification was declined), re-notify —
        // and force one pickup if the policy still declines.
        if !self.router.queue_is_empty() && self.router.free_count() > 0 && self.pending_pickups == 0 {
            let effects = self.router.kick();
            self.handle(effects);
        }
        self.push(self.clock + Micros::from_secs(1), Event::Tick);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ArrivalSpec, ExperimentConfig};
    use crate::coordinator::scheduler::DispatchPolicy;
    use crate::util::units::MB;

    /// A small workload that runs in milliseconds of wall time.
    fn small_cfg(policy: DispatchPolicy) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.name = format!("test-{policy}");
        cfg.cluster.max_nodes = 8;
        cfg.workload.num_tasks = 2_000;
        cfg.workload.num_files = 100;
        cfg.workload.file_size_bytes = 10 * MB;
        cfg.workload.arrival = ArrivalSpec::IncreasingRate {
            initial: 4.0,
            factor: 1.5,
            interval_s: 10.0,
            max_rate: 100.0,
        };
        cfg.scheduler.policy = policy;
        cfg.cache.capacity_bytes = 4_000 * MB;
        cfg
    }

    #[test]
    fn completes_all_tasks_first_available() {
        let r = run(&small_cfg(DispatchPolicy::FirstAvailable));
        assert_eq!(r.summary.tasks_completed, 2_000);
        assert_eq!(r.summary.miss_rate, 1.0, "no caching under first-available");
        assert!(r.summary.workload_execution_time_s > 0.0);
    }

    #[test]
    fn completes_all_tasks_every_policy() {
        for policy in DispatchPolicy::ALL {
            let r = run(&small_cfg(policy));
            assert_eq!(r.summary.tasks_completed, 2_000, "policy {policy}");
            let rates =
                r.summary.hit_local_rate + r.summary.hit_global_rate + r.summary.miss_rate;
            assert!((rates - 1.0).abs() < 1e-9, "policy {policy}: rates {rates}");
        }
    }

    #[test]
    fn caching_policies_get_hits() {
        // 100 files × 10 MB = 1 GB working set, 4 GB caches: after the
        // first pass everything is cached.
        let r = run(&small_cfg(DispatchPolicy::GoodCacheCompute));
        assert!(
            r.summary.hit_local_rate > 0.7,
            "hit rate {} too low",
            r.summary.hit_local_rate
        );
        assert!(r.summary.miss_rate < 0.2, "miss rate {}", r.summary.miss_rate);
    }

    #[test]
    fn deterministic_across_runs() {
        let a = run(&small_cfg(DispatchPolicy::GoodCacheCompute));
        let b = run(&small_cfg(DispatchPolicy::GoodCacheCompute));
        assert_eq!(
            a.summary.workload_execution_time_s,
            b.summary.workload_execution_time_s
        );
        assert_eq!(a.summary.hit_local_rate, b.summary.hit_local_rate);
        assert_eq!(a.events_processed, b.events_processed);
        assert_eq!(a.dispatch_order, b.dispatch_order);
    }

    #[test]
    fn dispatch_trace_covers_every_task() {
        let r = run(&small_cfg(DispatchPolicy::GoodCacheCompute));
        assert_eq!(r.dispatch_order.len(), 2_000);
        let mut ids: Vec<u64> = r.dispatch_order.iter().map(|t| t.0).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 2_000, "every task dispatched exactly once");
        let (hl, hg, m) = r.access_counts;
        assert_eq!(hl + hg + m, 2_000, "one access per single-file task");
    }

    #[test]
    fn provisioner_grows_fleet_under_load() {
        let r = run(&small_cfg(DispatchPolicy::GoodCacheCompute));
        let max_nodes = r.ts.buckets().iter().map(|b| b.nodes).max().unwrap_or(0);
        assert!(max_nodes >= 2, "fleet never grew: {max_nodes}");
    }

    #[test]
    fn static_provisioning_uses_fixed_fleet() {
        let mut cfg = small_cfg(DispatchPolicy::GoodCacheCompute);
        cfg.provisioner = crate::coordinator::provisioner::ProvisionerConfig::static_nodes(8);
        let r = run(&cfg);
        assert_eq!(r.summary.tasks_completed, 2_000);
        for b in r.ts.buckets().iter().filter(|b| b.total_slots > 0) {
            assert_eq!(b.nodes, 8);
        }
    }

    #[test]
    fn sharded_run_completes_and_conserves() {
        let mut cfg = small_cfg(DispatchPolicy::GoodCacheCompute);
        cfg.cluster.shards = 4;
        let r = run(&cfg);
        assert_eq!(r.summary.tasks_completed, 2_000);
        assert_eq!(r.shard.shards, 4);
        assert_eq!(r.shard.tasks_routed(), 2_000);
        let mut ids: Vec<u64> = r.dispatch_order.iter().map(|t| t.0).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 2_000, "every task dispatched exactly once");
        let (hl, hg, m) = r.access_counts;
        assert_eq!(hl + hg + m, 2_000, "one access per single-file task");
        assert!(r.shard.router_events > 0);
        // 100 files hash across 4 shards: every shard sees work.
        assert!(r.shard.per_shard.iter().all(|t| t.tasks_routed > 0));
        assert_eq!(
            r.shard.per_shard.iter().map(|t| t.dispatches).sum::<u64>(),
            2_000
        );
        let rates = r.summary.hit_local_rate + r.summary.hit_global_rate + r.summary.miss_rate;
        assert!((rates - 1.0).abs() < 1e-9, "rates {rates}");
    }

    #[test]
    fn sharded_run_is_deterministic() {
        let mut cfg = small_cfg(DispatchPolicy::GoodCacheCompute);
        cfg.cluster.shards = 4;
        let a = run(&cfg);
        let b = run(&cfg);
        assert_eq!(a.dispatch_order, b.dispatch_order);
        assert_eq!(a.events_processed, b.events_processed);
        assert_eq!(a.shard, b.shard);
        assert_eq!(
            a.summary.workload_execution_time_s,
            b.summary.workload_execution_time_s
        );
    }

    #[test]
    fn model_allocation_completes_and_grows_under_load() {
        let mut cfg = small_cfg(DispatchPolicy::GoodCacheCompute);
        cfg.provisioner.allocation =
            crate::coordinator::provisioner::AllocationPolicy::Model;
        // 100 ms/task at up to 100 tasks/s saturates several nodes, so
        // the solved target must climb above the single seed node.
        cfg.workload.compute_ms = 100.0;
        let r = run(&cfg);
        assert_eq!(r.summary.tasks_completed, 2_000);
        let peak = r.ts.buckets().iter().map(|b| b.nodes).max().unwrap_or(0);
        assert!(peak >= 2, "controller never grew the fleet: {peak}");
        assert!(peak as usize <= cfg.cluster.max_nodes, "cap respected");
    }

    #[test]
    fn sharded_model_allocation_run_is_deterministic() {
        let mut cfg = small_cfg(DispatchPolicy::GoodCacheCompute);
        cfg.provisioner.allocation =
            crate::coordinator::provisioner::AllocationPolicy::Model;
        cfg.cluster.shards = 4;
        let a = run(&cfg);
        let b = run(&cfg);
        assert_eq!(a.summary.tasks_completed, 2_000);
        assert_eq!(a.dispatch_order, b.dispatch_order);
        assert_eq!(a.events_processed, b.events_processed);
        assert_eq!(a.shard, b.shard);
    }

    #[test]
    fn node_serving_sees_parked_session_setup_transfers() {
        let mut flow = FlowNet::new();
        let gpfs = flow.add_link(1e9);
        let links = NodeLinks {
            disk: flow.add_link(1e9),
            nic_in: flow.add_link(1e9),
            nic_out: flow.add_link(1e9),
        };
        let mut delayed: HashMap<u64, (u64, Vec<LinkId>)> = HashMap::new();

        // Idle node, nothing parked: releasable.
        assert!(!node_serving(&flow, &delayed, &links));

        // A peer fetch parked in the GridFTP session-setup window names
        // this node's nic_out in its path but holds no flow capacity:
        // link_active alone would say "idle", node_serving must not.
        delayed.insert(7, (10, vec![links.nic_out, LinkId(99)]));
        assert_eq!(flow.link_active(links.nic_out), 0);
        assert!(node_serving(&flow, &delayed, &links));

        // A parked transfer on unrelated links doesn't pin this node.
        delayed.clear();
        delayed.insert(8, (10, vec![gpfs, LinkId(99)]));
        assert!(!node_serving(&flow, &delayed, &links));

        // A live transfer on the disk link still defers, as before.
        flow.start(Micros::ZERO, 10, &[links.disk], 1);
        assert!(node_serving(&flow, &delayed, &links));
    }

    #[test]
    fn release_under_cross_fetch_load_loses_no_transfers() {
        // Aggressive idle release + small caches (peer fetches on most
        // tasks) + a long GridFTP session-setup window: releases race
        // parked transfers constantly. Every task must still complete
        // and the run must stay deterministic.
        let mut cfg = small_cfg(DispatchPolicy::GoodCacheCompute);
        cfg.workload.num_tasks = 1_000;
        cfg.cache.capacity_bytes = 150 * MB;
        cfg.cluster.peer_overhead_ms = 60.0;
        cfg.provisioner.idle_release_s = 0.5;
        let a = run(&cfg);
        assert_eq!(a.summary.tasks_completed, 1_000);
        assert!(
            a.summary.hit_global_rate > 0.0,
            "no peer fetches — the test exercised nothing"
        );
        let b = run(&cfg);
        assert_eq!(a.dispatch_order, b.dispatch_order);
        assert_eq!(a.events_processed, b.events_processed);
    }

    #[test]
    fn pipeline_scenario_completes_with_dep_gating() {
        // The pipeline scenario carries real dependency edges: every
        // task must still complete (held tasks released on predecessor
        // completion), at K = 1 and K = 4, deterministically.
        let mut cfg = small_cfg(DispatchPolicy::GoodCacheCompute);
        cfg.name = "test-pipeline".into();
        cfg.workload.num_tasks = 700;
        cfg.workload.scenario = Some(crate::config::ScenarioSpec::preset("pipeline").unwrap());
        let wl = workload::generate(&cfg.workload, cfg.seed);
        assert!(wl.dep_edges > 0, "pipeline scenario must carry dep edges");
        let expect = wl.tasks.len() as u64;
        let a = run(&cfg);
        assert_eq!(a.summary.tasks_completed, expect);
        let b = run(&cfg);
        assert_eq!(a.dispatch_order, b.dispatch_order);
        assert_eq!(a.events_processed, b.events_processed);
        cfg.cluster.shards = 4;
        let r4 = run(&cfg);
        assert_eq!(r4.summary.tasks_completed, expect);
        assert_eq!(r4.shard.tasks_routed(), expect);
    }

    #[test]
    fn zipf_churn_scenario_runs_end_to_end() {
        // A flat (no-deps) scenario exercises the multi-input task
        // build and per-epoch stage table through the whole engine.
        let mut cfg = small_cfg(DispatchPolicy::GoodCacheCompute);
        cfg.name = "test-zipf-churn".into();
        cfg.workload.num_tasks = 1_500;
        cfg.workload.scenario = Some(crate::config::ScenarioSpec::preset("zipf-churn").unwrap());
        let r = run(&cfg);
        assert_eq!(r.summary.tasks_completed, 1_500);
        assert!(
            r.summary.hit_local_rate > 0.3,
            "heavy-tailed reuse should cache well: {}",
            r.summary.hit_local_rate
        );
    }

    #[test]
    fn gpfs_bound_throughput_under_first_available() {
        // With first-available everything reads GPFS: aggregate
        // throughput must never exceed the GPFS capacity.
        let cfg = small_cfg(DispatchPolicy::FirstAvailable);
        let r = run(&cfg);
        // Allow 15% slack for bucket-boundary attribution (bytes are
        // credited at transfer completion, so seconds can burst).
        let cap = cfg.cluster.gpfs_gbps * 1.15;
        for (sec, b) in r.ts.buckets().iter().enumerate() {
            let gbps = crate::util::units::bps_to_gbps(b.bytes_total() as f64);
            assert!(gbps <= cap, "second {sec}: {gbps} Gb/s > GPFS cap");
        }
    }
}
