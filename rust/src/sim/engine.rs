//! The discrete-event cluster engine — the testbed substitute.
//!
//! Drives the pure coordinator logic (queue / scheduler / provisioner /
//! index / caches) over simulated time, with data movement flowing
//! through the fluid-flow contention model of [`super::flow`]:
//!
//! * **GPFS** is one shared link (≈4.4 Gb/s sustained);
//! * each node contributes a **local-disk link** and **NIC in/out links**;
//! * a local cache hit reads `[disk(e)]`; a peer ("global") hit reads
//!   `[disk(peer), nic_out(peer), nic_in(e)]` (GridFTP alongside each
//!   executor, §3.1.1); a miss reads `[gpfs, nic_in(e)]`;
//! * dispatch passes through a single dispatcher service instance with a
//!   per-decision service time, reproducing Falkon's measured dispatch
//!   throughput ceiling (§5.1);
//! * GRAM/LRM allocation latency delays every provisioning batch
//!   (30–60 s, §5.2.5).
//!
//! The engine is fully deterministic for a given config: integer event
//! times, seeded PRNG streams, sequence-numbered heap ties.
//!
//! Data movement runs on the **batched** flow-net rerate path
//! ([`FlowNet::new`] defaults to [`super::flow::RerateMode::Batched`]):
//! same-instant transfer starts/completions (a completion chaining into
//! the next fetch, a multi-task pickup staging several files) settle and
//! rerate each touched link once per timestamp instead of once per
//! event. The per-event path is retained as the executable reference and
//! proven bit-identical by `rust/tests/flow_parity.rs`, so simulation
//! results do not depend on the mode.

use super::flow::{FlowNet, LinkId};
use crate::cache::ObjectCache;
use crate::config::ExperimentConfig;
use crate::coordinator::executor::ExecutorRegistry;
use crate::coordinator::pending::PendingIndex;
use crate::coordinator::provisioner::Provisioner;
use crate::coordinator::queue::{Task, WaitQueue};
use crate::coordinator::scheduler::{NotifyOutcome, Scheduler, SchedulerStats};
use crate::coordinator::{resolve_access, AccessKind};
use crate::ids::{ExecutorId, FileId, TaskId};
use crate::index::LocationIndex;
use crate::metrics::{IntervalStat, Recorder, SummaryMetrics, TimeSeries};
use crate::util::prng::Pcg64;
use crate::util::time::Micros;
use crate::util::units::gbps_to_bps;
use crate::workload::{self, Workload};
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};

/// Result of one simulated experiment.
#[derive(Debug)]
pub struct RunResult {
    /// Experiment name (from the config).
    pub name: String,
    /// End-of-run summary metrics.
    pub summary: SummaryMetrics,
    /// Per-second time series (the Figs 4–10 summary views).
    pub ts: TimeSeries,
    /// Per arrival-interval slowdown stats (Fig 14).
    pub intervals: Vec<IntervalStat>,
    /// Scheduler behaviour counters.
    pub sched_stats: SchedulerStats,
    /// Working-set size of the generated workload (bytes).
    pub working_set_bytes: u64,
    /// Bytes per file in the workload.
    pub file_size_bytes: u64,
    /// Wall-clock seconds the simulation itself took (engine §Perf).
    pub sim_wall_s: f64,
    /// Events processed (engine §Perf).
    pub events_processed: u64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Event {
    /// Task `workload index` arrives.
    Arrival(u32),
    /// Dispatch notification delivered; executor asks for work.
    Pickup(ExecutorId),
    /// Task finished computing on its executor.
    ComputeDone(u64),
    /// Delayed transfer start (peer-fetch session setup elapsed).
    StartTransfer(u64),
    /// A provisioning batch of `n` nodes finished GRAM bootstrap.
    NodesUp(u32),
    /// 1 Hz metrics sample + provisioning decision.
    Tick,
}

#[derive(Debug)]
struct HeapEntry {
    time: Micros,
    seq: u64,
    event: Event,
}

impl PartialEq for HeapEntry {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for HeapEntry {}
impl PartialOrd for HeapEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for HeapEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.time, self.seq).cmp(&(other.time, other.seq))
    }
}

/// Per-node link handles.
#[derive(Debug, Clone, Copy)]
struct NodeLinks {
    disk: LinkId,
    nic_in: LinkId,
    nic_out: LinkId,
}

/// A dispatched task moving through fetch → compute.
#[derive(Debug)]
struct InFlight {
    task: Task,
    exec: ExecutorId,
    /// Files still to fetch after the current transfer.
    remaining_files: Vec<FileId>,
    /// Kind of the access currently in flight (recorded on completion).
    current_kind: AccessKind,
    /// Path waiting on a delayed start (peer session setup).
    pending_path: Vec<LinkId>,
    interval: u32,
}

/// The engine. Construct via [`run`].
struct Engine {
    cfg: ExperimentConfig,
    wl: Workload,
    clock: Micros,
    heap: BinaryHeap<Reverse<HeapEntry>>,
    seq: u64,
    // Coordinator state (pure logic).
    sched: Scheduler,
    reg: ExecutorRegistry,
    queue: WaitQueue,
    index: LocationIndex,
    /// Inverted pending-task index (maintained for caching policies only)
    /// in its default **epoch-lazy** mode: every `LocationIndex` mutation
    /// site below reports to it (O(1)-bounded per event), and the
    /// scheduler settles the deferred candidate maintenance at each
    /// pickup — see `coordinator::pending` for the invariants.
    pending: PendingIndex,
    prov: Provisioner,
    caches: HashMap<ExecutorId, ObjectCache>,
    // Cluster substrate.
    flow: FlowNet,
    gpfs: LinkId,
    node_links: HashMap<ExecutorId, NodeLinks>,
    inflight: HashMap<u64, InFlight>,
    // Dispatcher service model.
    dispatcher_free_at: Micros,
    pending_pickups: usize,
    // Randomness streams.
    rng_cache: Pcg64,
    rng_gram: Pcg64,
    // Progress.
    completed: u64,
    rec: Recorder,
    events: u64,
}

/// Run one experiment to completion.
pub fn run(cfg: &ExperimentConfig) -> RunResult {
    cfg.validate().expect("invalid experiment config");
    let t_wall = std::time::Instant::now();
    let wl = workload::generate(&cfg.workload, cfg.seed);
    let working_set = wl.working_set_bytes();
    let ideal_wet = workload::ideal_execution_time_s(&cfg.workload);

    let mut root = Pcg64::seeded(cfg.seed);
    let mut eng = Engine {
        sched: Scheduler::new(cfg.scheduler.clone()),
        reg: ExecutorRegistry::new(),
        queue: WaitQueue::new(),
        index: LocationIndex::new(),
        pending: PendingIndex::new(),
        prov: Provisioner::new(cfg.provisioner.clone(), cfg.cluster.max_nodes),
        caches: HashMap::new(),
        flow: FlowNet::new(),
        gpfs: LinkId(0),
        node_links: HashMap::new(),
        inflight: HashMap::new(),
        dispatcher_free_at: Micros::ZERO,
        pending_pickups: 0,
        rng_cache: root.fork(1),
        rng_gram: root.fork(2),
        completed: 0,
        rec: Recorder::new(),
        events: 0,
        clock: Micros::ZERO,
        heap: BinaryHeap::new(),
        seq: 0,
        cfg: cfg.clone(),
        wl,
    };
    eng.gpfs = eng.flow.add_link(gbps_to_bps(cfg.cluster.gpfs_gbps));

    // Initial nodes (static provisioning / warm start) register at t=0.
    for _ in 0..cfg.provisioner.initial_nodes {
        eng.register_node();
    }
    // Kick off arrivals and the 1 Hz tick.
    if !eng.wl.tasks.is_empty() {
        let t0 = eng.wl.tasks[0].arrival;
        eng.push(t0, Event::Arrival(0));
    }
    eng.push(Micros::ZERO, Event::Tick);

    eng.run_loop();

    let fs = &eng.flow.stats;
    crate::debug!(
        "`{}` flow rerate stats: {} events batched into {} flushes, \
         {} transfer rerates, {} heap updates ({} dedup skips)",
        cfg.name,
        fs.batched_events,
        fs.flushes,
        fs.transfer_rerates,
        fs.heap_updates,
        fs.dedup_skips
    );
    let summary = eng.rec.summarize(ideal_wet);
    RunResult {
        name: cfg.name.clone(),
        summary,
        ts: std::mem::take(&mut eng.rec.ts),
        intervals: std::mem::take(&mut eng.rec.intervals),
        sched_stats: eng.sched.stats.clone(),
        working_set_bytes: working_set,
        file_size_bytes: cfg.workload.file_size_bytes,
        sim_wall_s: t_wall.elapsed().as_secs_f64(),
        events_processed: eng.events,
    }
}

impl Engine {
    fn push(&mut self, time: Micros, event: Event) {
        debug_assert!(time >= self.clock, "event scheduled in the past");
        self.seq += 1;
        self.heap.push(Reverse(HeapEntry {
            time,
            seq: self.seq,
            event,
        }));
    }

    fn run_loop(&mut self) {
        let total = self.wl.tasks.len() as u64;
        while self.completed < total {
            // Interleave flow completions with coordinator events;
            // transfer completions win ties so data is accounted before
            // same-instant samples.
            let next_main = self.heap.peek().map(|Reverse(e)| e.time);
            let next_flow = self.flow.next_completion();
            match (next_main, next_flow) {
                (None, None) => {
                    panic!(
                        "simulation stalled at {} with {} tasks incomplete \
                         (queue={}, inflight={})",
                        self.clock,
                        total - self.completed,
                        self.queue.len(),
                        self.inflight.len()
                    );
                }
                (m, Some(f)) if m.is_none_or(|m| f <= m) => {
                    self.clock = f;
                    self.events += 1;
                    let tag = self.flow.pop_completion(f);
                    self.on_transfer_done(tag);
                }
                _ => {
                    let Reverse(entry) = self.heap.pop().expect("peeked");
                    self.clock = entry.time;
                    self.events += 1;
                    self.on_event(entry.event);
                }
            }
        }
    }

    fn on_event(&mut self, event: Event) {
        match event {
            Event::Arrival(i) => self.on_arrival(i),
            Event::Pickup(e) => self.on_pickup(e),
            Event::ComputeDone(task_id) => self.on_compute_done(task_id),
            Event::StartTransfer(task_id) => {
                let inf = self
                    .inflight
                    .get_mut(&task_id)
                    .expect("delayed start for unknown task");
                let path = std::mem::take(&mut inf.pending_path);
                debug_assert!(!path.is_empty());
                self.flow
                    .start(self.clock, self.wl.file_size_bytes, &path, task_id);
            }
            Event::NodesUp(n) => {
                for _ in 0..n {
                    self.prov.on_node_registered();
                    self.register_node();
                }
            }
            Event::Tick => self.on_tick(),
        }
    }

    // ---- node lifecycle -------------------------------------------------

    fn register_node(&mut self) {
        let now = self.clock;
        let id = self.reg.register(self.cfg.cluster.cpus_per_node as u32, now);
        let disk = self.flow.add_link(gbps_to_bps(self.cfg.cluster.local_disk_gbps));
        let nic_in = self.flow.add_link(gbps_to_bps(self.cfg.cluster.nic_gbps));
        let nic_out = self.flow.add_link(gbps_to_bps(self.cfg.cluster.nic_gbps));
        self.node_links.insert(
            id,
            NodeLinks {
                disk,
                nic_in,
                nic_out,
            },
        );
        if self.cfg.scheduler.policy.uses_caching() {
            self.caches.insert(id, ObjectCache::new(self.cfg.cache));
            self.index.register_executor(id);
        }
        // A fresh executor immediately asks for work.
        self.schedule_pickup(id);
    }

    fn release_node(&mut self, id: ExecutorId) {
        // Peers may be mid-transfer from this node's cache; skip the
        // release this round if so (retry next tick).
        if let Some(links) = self.node_links.get(&id) {
            if self.flow.link_active(links.disk) > 0
                || self.flow.link_active(links.nic_in) > 0
                || self.flow.link_active(links.nic_out) > 0
            {
                return;
            }
        }
        if self.cfg.scheduler.policy.uses_caching() {
            self.index.deregister_executor(id);
            self.pending.on_deregister(id);
            self.caches.remove(&id);
        }
        self.node_links.remove(&id);
        self.reg.deregister(id);
    }

    // ---- dispatch path --------------------------------------------------

    /// Reserve a pending slot on `exec` and schedule its pickup through
    /// the dispatcher service queue.
    fn schedule_pickup(&mut self, exec: ExecutorId) {
        if !self.reg.is_free(exec) {
            return;
        }
        self.reg.mark_pending(exec);
        self.pending_pickups += 1;
        let service = Micros::from_secs_f64(self.cfg.cluster.dispatch_service_us / 1e6);
        let start = self.dispatcher_free_at.max(self.clock);
        self.dispatcher_free_at = start + service;
        let latency = Micros::from_secs_f64(self.cfg.cluster.net_latency_ms / 1e3);
        self.push(self.dispatcher_free_at + latency, Event::Pickup(exec));
    }

    fn on_arrival(&mut self, i: u32) {
        let spec = &self.wl.tasks[i as usize];
        let task = Task {
            id: spec.id,
            files: vec![spec.file],
            compute: self.wl.compute,
            arrival: spec.arrival,
        };
        let rate = self
            .wl
            .stages
            .get(spec.interval as usize)
            .map_or(0.0, |&(_, r)| r);
        self.rec.record_arrival(self.clock, spec.interval, rate);
        let qref = self.queue.push_back(task);
        if self.cfg.scheduler.policy.uses_caching() {
            self.pending.on_push(&self.queue, qref, &self.index);
        }

        // Phase 1: try to notify an executor for the head task.
        self.notify_for_head();

        // Chain the next arrival.
        let next = i as usize + 1;
        if next < self.wl.tasks.len() {
            let t = self.wl.tasks[next].arrival;
            self.push(t.max(self.clock), Event::Arrival(next as u32));
        }
    }

    fn notify_for_head(&mut self) {
        if self.reg.free_count() == 0 {
            return;
        }
        let Some(head) = self.queue.front() else {
            return;
        };
        let files = head.files.clone();
        // Phase 1 consults the pending index's memoized head ranking, so
        // repeated notifies for the same head (arrivals while saturated)
        // never recount holder overlap.
        match self
            .sched
            .select_notify(&files, &self.reg, &mut self.pending, &self.index)
        {
            NotifyOutcome::Preferred(e) | NotifyOutcome::Fallback(e) => {
                self.schedule_pickup(e);
            }
            NotifyOutcome::Wait | NotifyOutcome::NoneFree => {}
        }
    }

    fn on_pickup(&mut self, exec: ExecutorId) {
        self.pending_pickups -= 1;
        if !self.reg.contains(exec) {
            return; // released meanwhile (cannot happen while pending, but be safe)
        }
        // The pending reservation holds one slot; extra free slots allow a
        // larger batch.
        let free_extra = self.reg.get(exec).map_or(0, |e| e.free_slots()) as usize;
        let limit = self
            .cfg
            .scheduler
            .max_tasks_per_pickup
            .min(1 + free_extra)
            .max(1);
        let tasks = self.sched.pick_tasks(
            exec,
            limit,
            &mut self.queue,
            &mut self.pending,
            &self.reg,
            &self.index,
        );
        if tasks.is_empty() {
            self.reg.cancel_pending(exec);
            return;
        }
        for (i, task) in tasks.into_iter().enumerate() {
            if i == 0 {
                self.reg.pending_to_busy(exec, self.clock);
            } else {
                self.reg.start_task(exec, self.clock);
            }
            self.start_data_phase(task, exec);
        }
    }

    /// Begin fetching the task's first file (remaining files chain on
    /// transfer completion).
    fn start_data_phase(&mut self, task: Task, exec: ExecutorId) {
        let mut files = task.files.clone();
        files.reverse(); // pop() yields paper order
        let interval = self
            .wl
            .tasks
            .get(task.id.0 as usize)
            .map_or(0, |t| t.interval);
        let mut inf = InFlight {
            task,
            exec,
            remaining_files: files,
            current_kind: AccessKind::Miss,
            pending_path: Vec::new(),
            interval,
        };
        let first = inf.remaining_files.pop().expect("task has ≥1 file");
        self.start_fetch(&mut inf, first);
        self.inflight.insert(inf.task.id.0, inf);
    }

    /// Resolve one file access and start its transfer.
    fn start_fetch(&mut self, inf: &mut InFlight, file: FileId) {
        let exec = inf.exec;
        let size = self.wl.file_size_bytes;
        let links = self.node_links[&exec];
        let (kind, path): (AccessKind, Vec<LinkId>) =
            if self.cfg.scheduler.policy.uses_caching() {
                let cache = self
                    .caches
                    .get_mut(&exec)
                    .expect("caching policy ⇒ cache exists");
                let res = resolve_access(
                    exec,
                    file,
                    size,
                    cache,
                    &mut self.index,
                    &mut self.rng_cache,
                );
                // Keep the inverted pending index coherent with the
                // index mutations resolve_access just made.
                for &old in &res.evicted {
                    self.pending
                        .on_index_remove(old, exec, &self.queue, &self.index);
                }
                if res.inserted {
                    self.pending.on_index_add(file, exec);
                }
                let path = match (res.kind, res.peer) {
                    (AccessKind::HitLocal, _) => vec![links.disk],
                    (AccessKind::HitGlobal, Some(p)) => {
                        let pl = self.node_links[&p];
                        vec![pl.disk, pl.nic_out, links.nic_in]
                    }
                    (AccessKind::HitGlobal, None) => unreachable!("global hit needs a peer"),
                    (AccessKind::Miss, _) => vec![self.gpfs, links.nic_in],
                };
                (res.kind, path)
            } else {
                // first-available: every access goes to GPFS.
                (AccessKind::Miss, vec![self.gpfs, links.nic_in])
            };
        inf.current_kind = kind;
        // Peer fetches pay a GridFTP session-setup cost before bytes flow
        // (cluster.peer_overhead_ms) — see Fig 10's discussion of remote
        // cache access costs.
        let overhead = self.cfg.cluster.peer_overhead_ms;
        if kind == AccessKind::HitGlobal && overhead > 0.0 {
            inf.pending_path = path;
            self.push(
                self.clock + Micros::from_secs_f64(overhead / 1e3),
                Event::StartTransfer(inf.task.id.0),
            );
        } else {
            self.flow.start(self.clock, size, &path, inf.task.id.0);
        }
    }

    fn on_transfer_done(&mut self, task_id: u64) {
        let mut inf = self
            .inflight
            .remove(&task_id)
            .expect("transfer for unknown task");
        self.rec
            .record_access(self.clock, inf.current_kind, self.wl.file_size_bytes);
        if let Some(next_file) = inf.remaining_files.pop() {
            self.start_fetch(&mut inf, next_file);
            self.inflight.insert(task_id, inf);
        } else {
            // All data staged: compute.
            let done = self.clock + inf.task.compute;
            self.inflight.insert(task_id, inf);
            self.push(done, Event::ComputeDone(task_id));
        }
    }

    fn on_compute_done(&mut self, task_id: u64) {
        let inf = self
            .inflight
            .remove(&task_id)
            .expect("compute for unknown task");
        debug_assert_eq!(inf.task.id, TaskId(task_id));
        self.reg.finish_task(inf.exec, self.clock);
        // Result delivery back to the dispatcher.
        let latency = Micros::from_secs_f64(self.cfg.cluster.net_latency_ms / 1e3);
        self.rec
            .record_completion(self.clock + latency, inf.task.arrival, inf.interval);
        self.completed += 1;
        // The now-free executor asks for more work.
        if !self.queue.is_empty() {
            self.schedule_pickup(inf.exec);
        }
    }

    // ---- provisioning ---------------------------------------------------

    fn on_tick(&mut self) {
        self.rec.sample(
            self.clock,
            self.queue.len(),
            self.reg.len(),
            self.reg.busy_slots(),
            self.reg.total_slots(),
        );
        let action = self
            .prov
            .on_tick(self.clock, self.queue.len(), &self.reg);
        if action.allocate > 0 {
            let (lo, hi) = self.cfg.cluster.gram_latency_s;
            let latency = Micros::from_secs_f64(self.rng_gram.range_f64(lo, hi.max(lo + 1e-9)));
            self.push(self.clock + latency, Event::NodesUp(action.allocate as u32));
        }
        for e in action.release {
            self.release_node(e);
        }
        // Safety net: if tasks wait, executors are free, and no pickup is
        // in flight (e.g. every notification was declined), re-notify.
        if !self.queue.is_empty() && self.reg.free_count() > 0 && self.pending_pickups == 0 {
            self.notify_for_head();
            // max-cache-hit can legitimately Wait with free executors;
            // guarantee progress by forcing one pickup if still none.
            if self.pending_pickups == 0 {
                let first_free = self.reg.free_iter().next();
                if let Some(e) = first_free {
                    self.schedule_pickup(e);
                }
            }
        }
        self.push(self.clock + Micros::from_secs(1), Event::Tick);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ArrivalSpec, ExperimentConfig};
    use crate::coordinator::scheduler::DispatchPolicy;
    use crate::util::units::MB;

    /// A small workload that runs in milliseconds of wall time.
    fn small_cfg(policy: DispatchPolicy) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.name = format!("test-{policy}");
        cfg.cluster.max_nodes = 8;
        cfg.workload.num_tasks = 2_000;
        cfg.workload.num_files = 100;
        cfg.workload.file_size_bytes = 10 * MB;
        cfg.workload.arrival = ArrivalSpec::IncreasingRate {
            initial: 4.0,
            factor: 1.5,
            interval_s: 10.0,
            max_rate: 100.0,
        };
        cfg.scheduler.policy = policy;
        cfg.cache.capacity_bytes = 4_000 * MB;
        cfg
    }

    #[test]
    fn completes_all_tasks_first_available() {
        let r = run(&small_cfg(DispatchPolicy::FirstAvailable));
        assert_eq!(r.summary.tasks_completed, 2_000);
        assert_eq!(r.summary.miss_rate, 1.0, "no caching under first-available");
        assert!(r.summary.workload_execution_time_s > 0.0);
    }

    #[test]
    fn completes_all_tasks_every_policy() {
        for policy in DispatchPolicy::ALL {
            let r = run(&small_cfg(policy));
            assert_eq!(r.summary.tasks_completed, 2_000, "policy {policy}");
            let rates =
                r.summary.hit_local_rate + r.summary.hit_global_rate + r.summary.miss_rate;
            assert!((rates - 1.0).abs() < 1e-9, "policy {policy}: rates {rates}");
        }
    }

    #[test]
    fn caching_policies_get_hits() {
        // 100 files × 10 MB = 1 GB working set, 4 GB caches: after the
        // first pass everything is cached.
        let r = run(&small_cfg(DispatchPolicy::GoodCacheCompute));
        assert!(
            r.summary.hit_local_rate > 0.7,
            "hit rate {} too low",
            r.summary.hit_local_rate
        );
        assert!(r.summary.miss_rate < 0.2, "miss rate {}", r.summary.miss_rate);
    }

    #[test]
    fn deterministic_across_runs() {
        let a = run(&small_cfg(DispatchPolicy::GoodCacheCompute));
        let b = run(&small_cfg(DispatchPolicy::GoodCacheCompute));
        assert_eq!(
            a.summary.workload_execution_time_s,
            b.summary.workload_execution_time_s
        );
        assert_eq!(a.summary.hit_local_rate, b.summary.hit_local_rate);
        assert_eq!(a.events_processed, b.events_processed);
    }

    #[test]
    fn provisioner_grows_fleet_under_load() {
        let r = run(&small_cfg(DispatchPolicy::GoodCacheCompute));
        let max_nodes = r.ts.buckets().iter().map(|b| b.nodes).max().unwrap_or(0);
        assert!(max_nodes >= 2, "fleet never grew: {max_nodes}");
    }

    #[test]
    fn static_provisioning_uses_fixed_fleet() {
        let mut cfg = small_cfg(DispatchPolicy::GoodCacheCompute);
        cfg.provisioner = crate::coordinator::provisioner::ProvisionerConfig::static_nodes(8);
        let r = run(&cfg);
        assert_eq!(r.summary.tasks_completed, 2_000);
        for b in r.ts.buckets().iter().filter(|b| b.total_slots > 0) {
            assert_eq!(b.nodes, 8);
        }
    }

    #[test]
    fn gpfs_bound_throughput_under_first_available() {
        // With first-available everything reads GPFS: aggregate
        // throughput must never exceed the GPFS capacity.
        let cfg = small_cfg(DispatchPolicy::FirstAvailable);
        let r = run(&cfg);
        // Allow 15% slack for bucket-boundary attribution (bytes are
        // credited at transfer completion, so seconds can burst).
        let cap = cfg.cluster.gpfs_gbps * 1.15;
        for (sec, b) in r.ts.buckets().iter().enumerate() {
            let gbps = crate::util::units::bps_to_gbps(b.bytes_total() as f64);
            assert!(gbps <= cap, "second {sec}: {gbps} Gb/s > GPFS cap");
        }
    }
}
