//! # data-diffusion
//!
//! A production-quality reproduction of **"Data Diffusion: Dynamic Resource
//! Provisioning and Data-Aware Scheduling for Data-Intensive Applications"**
//! (Raicu, Zhao, Foster, Szalay; 2008) — the Falkon data-diffusion system.
//!
//! The crate implements the paper's full stack:
//!
//! * a **data-aware scheduler** with the paper's five dispatch policies
//!   (`first-available`, `first-cache-available`, `max-cache-hit`,
//!   `max-compute-util`, `good-cache-compute`), realized as the two-phase
//!   notify/window algorithm of §3.2 ([`coordinator::scheduler`]);
//! * **per-executor data caches** with the four eviction policies of §3.1.1
//!   (LRU / FIFO / LFU / Random) ([`cache`]);
//! * a **centralized location index** (`I_map`/`E_map`) ([`index`]);
//! * a **dynamic resource provisioner** with tunable allocation and release
//!   policies and a GRAM/LRM allocation-latency model
//!   ([`coordinator::provisioner`]);
//! * the paper's **abstract model** of data-centric task farms (§4) and its
//!   validation machinery ([`model`]);
//! * a deterministic **discrete-event cluster simulator** standing in for
//!   the ANL/UC TeraGrid testbed ([`sim`]), plus a **live execution engine**
//!   that runs real tasks on real files with worker threads ([`live`]);
//! * a **runtime bridge** for the AOT-compiled JAX/Pallas artifacts
//!   (built once by `make artifacts`; Python is never on the request
//!   path), shipped with a dependency-free pure-Rust reference backend
//!   so offline builds stay green ([`runtime`]);
//! * **workload generators**, **metrics**, **report renderers** and one
//!   [`experiments`] entry point per figure of the paper's evaluation;
//! * a seeded **chaos harness** with a shadow-state oracle that
//!   perturbs the coordinator's effect stream (dropped notifications,
//!   executor kills, stalled transfers, shard partitions) and gates the
//!   §4.2 failure/replay path in CI ([`chaos`]).
//!
//! See `DESIGN.md` for the system inventory and the per-experiment index,
//! and `EXPERIMENTS.md` for paper-vs-measured results.
//!
//! ## Quickstart
//!
//! ```no_run
//! use datadiffusion::config::ExperimentConfig;
//! use datadiffusion::experiments;
//!
//! // Run the paper's Figure 7 experiment (good-cache-compute, 2 GB caches)
//! let cfg = ExperimentConfig::paper_fig(7).expect("known figure");
//! let outcome = experiments::run_summary_experiment(&cfg);
//! println!("workload execution time: {:.0} s", outcome.summary.workload_execution_time_s);
//! ```

pub mod cache;
pub mod chaos;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod experiments;
pub mod index;
pub mod live;
pub mod metrics;
pub mod model;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod util;
pub mod workload;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Crate-wide error type.
///
/// `Display`/`Error` are implemented by hand — the build environment is
/// offline and the crate carries zero external dependencies (no
/// `thiserror`).
#[derive(Debug)]
pub enum Error {
    /// Configuration parse/validation failure — a typed
    /// [`config::ConfigError`] naming the field and offending value.
    Config(config::ConfigError),
    /// Artifact (AOT HLO) missing or failed to load/compile.
    Runtime(String),
    /// Simulation invariant violated (a bug, not a user error).
    SimInvariant(String),
    /// Live-engine I/O failure.
    Io(std::io::Error),
    /// XLA/PJRT failure (kept for API stability; the in-tree runtime
    /// backend is pure Rust and never produces it).
    Xla(String),
}

impl Error {
    /// Free-form configuration error (CLI usage messages and other
    /// callers without a structured field to point at).
    pub fn config(msg: impl Into<String>) -> Error {
        Error::Config(config::ConfigError::Message(msg.into()))
    }
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::SimInvariant(m) => write!(f, "simulation invariant violated: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Xla(m) => write!(f, "xla error: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

/// Identifier newtypes shared across layers.
pub mod ids {
    /// A logical data object (file) in the persistent store (δ ∈ Δ).
    #[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
    pub struct FileId(pub u32);

    /// A provisioned executor (transient compute+storage resource, τ ∈ T).
    #[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
    pub struct ExecutorId(pub u32);

    /// A task in the incoming stream (κ ∈ K).
    #[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
    pub struct TaskId(pub u64);

    impl std::fmt::Display for FileId {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            write!(f, "f{}", self.0)
        }
    }
    impl std::fmt::Display for ExecutorId {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            write!(f, "e{}", self.0)
        }
    }
    impl std::fmt::Display for TaskId {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            write!(f, "t{}", self.0)
        }
    }
}

/// Convenient re-exports for downstream users and the examples — the
/// driver-facing surface: configs (including the scenario library),
/// workload types, the coordinator's event→effect vocabulary, and run
/// outputs. Test/bench seams (`probe_*`, `drain_effects`, reference
/// scheduler paths) are deliberately *not* here and carry
/// `#[doc(hidden)]`.
pub mod prelude {
    pub use crate::cache::{CacheConfig, EvictionPolicy};
    pub use crate::chaos::{ChaosConfig, ChaosReport};
    pub use crate::config::{
        AccessSpec, ArrivalSpec, ClusterConfig, ConfigError, ExperimentConfig, ScenarioSpec,
        WorkloadConfig,
    };
    pub use crate::coordinator::core::{
        CoordinatorCore, CoreConfig, Effect, FetchPlan, FileSizes,
    };
    pub use crate::coordinator::provisioner::{AllocationPolicy, ProvisionerConfig};
    pub use crate::coordinator::queue::Task;
    pub use crate::coordinator::scheduler::{DispatchPolicy, SchedulerConfig};
    pub use crate::coordinator::shard::ShardedCoordinator;
    pub use crate::ids::{ExecutorId, FileId, TaskId};
    pub use crate::metrics::{SummaryMetrics, TimeSeries};
    pub use crate::sim::RunResult;
    pub use crate::util::time::Micros;
    pub use crate::workload::{TaskSpec, Workload};
    pub use crate::{Error, Result};
}
