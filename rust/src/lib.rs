//! # data-diffusion
//!
//! A production-quality reproduction of **"Data Diffusion: Dynamic Resource
//! Provisioning and Data-Aware Scheduling for Data-Intensive Applications"**
//! (Raicu, Zhao, Foster, Szalay; 2008) — the Falkon data-diffusion system.
//!
//! The crate implements the paper's full stack:
//!
//! * a **data-aware scheduler** with the paper's five dispatch policies
//!   (`first-available`, `first-cache-available`, `max-cache-hit`,
//!   `max-compute-util`, `good-cache-compute`), realized as the two-phase
//!   notify/window algorithm of §3.2 ([`coordinator::scheduler`]);
//! * **per-executor data caches** with the four eviction policies of §3.1.1
//!   (LRU / FIFO / LFU / Random) ([`cache`]);
//! * a **centralized location index** (`I_map`/`E_map`) ([`index`]);
//! * a **dynamic resource provisioner** with tunable allocation and release
//!   policies and a GRAM/LRM allocation-latency model
//!   ([`coordinator::provisioner`]);
//! * the paper's **abstract model** of data-centric task farms (§4) and its
//!   validation machinery ([`model`]);
//! * a deterministic **discrete-event cluster simulator** standing in for
//!   the ANL/UC TeraGrid testbed ([`sim`]), plus a **live execution engine**
//!   that runs real tasks on real files with worker threads ([`live`]);
//! * a **PJRT runtime bridge** that loads the AOT-compiled JAX/Pallas
//!   artifacts (built once by `make artifacts`; Python is never on the
//!   request path) ([`runtime`]);
//! * **workload generators**, **metrics**, **report renderers** and one
//!   [`experiments`] entry point per figure of the paper's evaluation.
//!
//! See `DESIGN.md` for the system inventory and the per-experiment index,
//! and `EXPERIMENTS.md` for paper-vs-measured results.
//!
//! ## Quickstart
//!
//! ```no_run
//! use datadiffusion::config::ExperimentConfig;
//! use datadiffusion::experiments;
//!
//! // Run the paper's Figure 7 experiment (good-cache-compute, 2 GB caches)
//! let cfg = ExperimentConfig::paper_fig(7).expect("known figure");
//! let outcome = experiments::run_summary_experiment(&cfg);
//! println!("workload execution time: {:.0} s", outcome.summary.workload_execution_time_s);
//! ```

pub mod cache;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod experiments;
pub mod index;
pub mod live;
pub mod metrics;
pub mod model;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod util;
pub mod workload;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Crate-wide error type.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// Configuration parse/validation failure.
    #[error("config error: {0}")]
    Config(String),
    /// Artifact (AOT HLO) missing or failed to load/compile.
    #[error("runtime error: {0}")]
    Runtime(String),
    /// Simulation invariant violated (a bug, not a user error).
    #[error("simulation invariant violated: {0}")]
    SimInvariant(String),
    /// Live-engine I/O failure.
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
    /// XLA/PJRT failure.
    #[error("xla error: {0}")]
    Xla(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Identifier newtypes shared across layers.
pub mod ids {
    /// A logical data object (file) in the persistent store (δ ∈ Δ).
    #[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
    pub struct FileId(pub u32);

    /// A provisioned executor (transient compute+storage resource, τ ∈ T).
    #[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
    pub struct ExecutorId(pub u32);

    /// A task in the incoming stream (κ ∈ K).
    #[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
    pub struct TaskId(pub u64);

    impl std::fmt::Display for FileId {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            write!(f, "f{}", self.0)
        }
    }
    impl std::fmt::Display for ExecutorId {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            write!(f, "e{}", self.0)
        }
    }
    impl std::fmt::Display for TaskId {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            write!(f, "t{}", self.0)
        }
    }
}

/// Convenient re-exports for downstream users and the examples.
pub mod prelude {
    pub use crate::cache::{CacheConfig, EvictionPolicy};
    pub use crate::config::ExperimentConfig;
    pub use crate::coordinator::provisioner::{AllocationPolicy, ProvisionerConfig};
    pub use crate::coordinator::scheduler::DispatchPolicy;
    pub use crate::ids::{ExecutorId, FileId, TaskId};
    pub use crate::metrics::{SummaryMetrics, TimeSeries};
    pub use crate::util::time::Micros;
    pub use crate::{Error, Result};
}
