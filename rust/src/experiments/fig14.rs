//! Figure 14 — slowdown as a function of arrival rate (§5.2.5).
//!
//! For every arrival-rate interval of the §5.2 workload, slowdown is the
//! makespan of that interval's tasks over the ideal. Paper shape:
//! first-available saturates at 59 tasks/s and its slowdown climbs
//! steadily; 1.5 GB caches recover from ~5× back to ~1× once the working
//! set is cached; 2–4 GB caches stay near 1× throughout (with a small
//! provisioning blip at low rates — GRAM latency).

use crate::report::{f, Table};
use crate::sim::RunResult;

/// Render the Figure 14 table: one row per arrival-rate interval, one
/// column per experiment.
pub fn table(results: &[RunResult]) -> Table {
    let mut headers: Vec<String> = vec!["arrival(tasks/s)".into()];
    headers.extend(results.iter().map(|r| r.name.clone()));
    let header_refs: Vec<&str> = headers.iter().map(String::as_str).collect();
    let mut t = Table::new("Figure 14: slowdown vs arrival rate", &header_refs);

    let max_intervals = results.iter().map(|r| r.intervals.len()).max().unwrap_or(0);
    for i in 0..max_intervals {
        let rate = results
            .iter()
            .find_map(|r| r.intervals.get(i).map(|s| s.rate))
            .unwrap_or(0.0);
        let mut row = vec![f(rate, 0)];
        for r in results {
            row.push(match r.intervals.get(i) {
                Some(s) if s.tasks > 0 => f(s.slowdown(), 2),
                _ => "-".into(),
            });
        }
        t.row(row);
    }
    t
}

/// The arrival rate at which an experiment saturates: the first interval
/// whose slowdown exceeds `threshold` and never recovers below it.
pub fn saturation_rate(r: &RunResult, threshold: f64) -> Option<f64> {
    let n = r.intervals.len();
    for i in 0..n {
        if r.intervals[i..]
            .iter()
            .all(|s| s.tasks == 0 || s.slowdown() > threshold)
            && r.intervals[i].tasks > 0
            && r.intervals[i].slowdown() > threshold
        {
            return Some(r.intervals[i].rate);
        }
    }
    None
}

/// Registry entry: renders from the shared Figure 4–10 runs.
pub fn figure() -> crate::experiments::registry::Figure {
    use crate::experiments::registry::{Figure, FigureKind, SimSet};
    fn render(results: &[RunResult]) -> Vec<Table> {
        vec![table(results)]
    }
    Figure {
        id: "fig14",
        title: "Figure 14: slowdown vs arrival rate (§5.2.5)",
        deterministic: true,
        kind: FigureKind::Sims {
            set: SimSet::Paper,
            render,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::coordinator::scheduler::DispatchPolicy;
    use crate::experiments::run_summary_experiment;
    use crate::util::units::MB;

    #[test]
    fn saturation_detected_for_overloaded_first_available() {
        let mut cfg = ExperimentConfig::default();
        cfg.name = "sat".into();
        cfg.cluster.max_nodes = 4;
        cfg.workload.num_tasks = 3_000;
        cfg.workload.num_files = 100;
        cfg.workload.file_size_bytes = 10 * MB;
        // Rates 2 → 128 tasks/s: GPFS (4.4 Gb/s ≈ 55 × 10 MB/s) saturates.
        cfg.workload.arrival = crate::config::ArrivalSpec::IncreasingRate {
            initial: 2.0,
            factor: 2.0,
            interval_s: 15.0,
            max_rate: 128.0,
        };
        cfg.scheduler.policy = DispatchPolicy::FirstAvailable;
        let r = run_summary_experiment(&cfg);
        let sat = saturation_rate(&r, 1.5);
        assert!(sat.is_some(), "no saturation found");
        assert!(sat.unwrap() <= 128.0);
        let t = table(&[r]);
        assert!(!t.rows.is_empty());
    }
}
