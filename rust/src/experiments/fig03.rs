//! Figure 3 — raw data-aware scheduler performance (§5.1).
//!
//! The paper measures the Falkon service's scheduling throughput with a
//! no-I/O workload: 250K tasks over 10K 1-byte files on 32 nodes, window
//! 3200, utilization threshold 0.8. Reported: 2981 decisions/s for
//! first-available (no I/O) down to 1322/s for max-cache-hit, with a
//! per-decision cost breakdown (communication vs scheduling).
//!
//! This driver benchmarks *our* scheduler implementation on the same
//! workload shape, driving the notify + pickup phases directly (no
//! simulated time, executors complete instantly) and attributing wall
//! time to the paper's cost categories.

use crate::cache::{CacheConfig, EvictionPolicy, ObjectCache};
use crate::coordinator::executor::ExecutorRegistry;
use crate::coordinator::pending::{self, PendingIndex};
use crate::coordinator::queue::{Task, WaitQueue};
use crate::coordinator::scheduler::{DispatchPolicy, Scheduler, SchedulerConfig};
use crate::coordinator::resolve_access;
use crate::ids::{ExecutorId, FileId, TaskId};
use crate::index::LocationIndex;
use crate::report::{f, Table};
use crate::util::prng::Pcg64;
use crate::util::time::Micros;
use std::collections::HashMap;
use std::time::Instant;

/// Result of one policy's microbenchmark.
#[derive(Debug, Clone)]
pub struct SchedulerBenchResult {
    /// Policy measured.
    pub policy: DispatchPolicy,
    /// Tasks dispatched.
    pub tasks: u64,
    /// Scheduling decisions per second (the paper's headline number).
    pub decisions_per_sec: f64,
    /// Seconds spent in phase 1 (notification scoring).
    pub notify_s: f64,
    /// Seconds spent in phase 2 (window scan + dispatch).
    pub pickup_s: f64,
    /// Seconds spent in cache/index maintenance (executor side).
    pub index_s: f64,
    /// Fraction of dispatches that were 100 % cache hits.
    pub full_hit_frac: f64,
}

/// Run the §5.1 microbenchmark for one policy.
///
/// `num_tasks` tasks over `num_files` 1-byte files, `nodes`×2 executors.
/// Executors "execute" instantly; with caching policies they also update
/// their caches + the central index, so data-aware scoring sees realistic
/// replica state (every file ends up cached after its first dispatch).
pub fn bench_policy(
    policy: DispatchPolicy,
    num_tasks: u64,
    num_files: u32,
    nodes: usize,
) -> SchedulerBenchResult {
    let mut rng = Pcg64::seeded(0x5eed);
    let mut reg = ExecutorRegistry::new();
    let mut index = LocationIndex::new();
    let mut queue = WaitQueue::new();
    let mut pend = PendingIndex::new();
    let mut caches: HashMap<ExecutorId, ObjectCache> = HashMap::new();
    let caching = policy.uses_caching();

    let execs: Vec<ExecutorId> = (0..nodes).map(|_| reg.register(2, Micros::ZERO)).collect();
    for &e in &execs {
        if caching {
            index.register_executor(e);
            caches.insert(
                e,
                ObjectCache::new(CacheConfig {
                    capacity_bytes: 1 << 30, // 1-byte files: effectively infinite
                    policy: EvictionPolicy::Lru,
                }),
            );
        }
    }

    // Pre-fill the wait queue (batch submission, as in §5.1).
    for i in 0..num_tasks {
        let qref = queue.push_back(Task {
            id: TaskId(i),
            files: vec![FileId(rng.below(num_files as u64) as u32)],
            compute: Micros::ZERO,
            arrival: Micros::ZERO,
        });
        if caching {
            pend.on_push(&queue, qref, &index);
        }
    }

    let mut sched = Scheduler::new(SchedulerConfig {
        policy,
        window_multiplier: 100, // window = 3200 at 32 nodes, as in §5.1
        cpu_util_threshold: 0.8,
        max_replication: 4,
        max_tasks_per_pickup: 1,
        ..SchedulerConfig::default()
    });

    let mut notify_s = 0.0;
    let mut pickup_s = 0.0;
    let mut index_s = 0.0;
    let mut dispatched = 0u64;
    let t0 = Instant::now();
    let mut ei = 0usize;
    // Drive the dispatch loop: notify for the head task, then serve the
    // chosen executor's pickup; executors complete instantly so the
    // registry never saturates (pure scheduler cost, like sleep-0 tasks).
    while !queue.is_empty() {
        let head_files = queue.front().expect("non-empty").files.clone();
        let tn = Instant::now();
        let outcome = sched.select_notify(&head_files, &reg, &mut pend, &index);
        notify_s += tn.elapsed().as_secs_f64();
        let exec = match outcome {
            crate::coordinator::scheduler::NotifyOutcome::Preferred(e)
            | crate::coordinator::scheduler::NotifyOutcome::Fallback(e) => e,
            _ => {
                // All executors momentarily out of the free set cannot
                // happen here (instant completion); round-robin fallback.
                ei = (ei + 1) % execs.len();
                execs[ei]
            }
        };
        let tp = Instant::now();
        let tasks = sched.pick_tasks(exec, 1, &mut queue, &mut pend, &reg, &index);
        pickup_s += tp.elapsed().as_secs_f64();
        if tasks.is_empty() {
            // max-cache-hit can decline; force progress on the head task
            // via its holder (paper: dispatch is delayed — here the
            // holder is instantly free, so serve it directly).
            let holder = head_files
                .first()
                .and_then(|&f| index.holders(f))
                .and_then(|h| h.first());
            if let Some(h) = holder {
                let tp2 = Instant::now();
                let t2 = sched.pick_tasks(h, 1, &mut queue, &mut pend, &reg, &index);
                pickup_s += tp2.elapsed().as_secs_f64();
                dispatched += execute(
                    &t2,
                    h,
                    caching,
                    &mut caches,
                    &mut index,
                    &mut pend,
                    &queue,
                    &mut rng,
                    &mut index_s,
                );
            } else {
                // Nothing anywhere (cold cache, mch): head pops via its
                // bootstrap class on the fallback executor next round —
                // guard against a livelock by popping directly (through
                // the shared removal path so the pending index stays
                // coherent).
                let qref = queue.front_ref().expect("non-empty");
                let t = pending::remove_queued(&mut queue, &mut pend, qref, &index);
                dispatched += execute(
                    &[t],
                    exec,
                    caching,
                    &mut caches,
                    &mut index,
                    &mut pend,
                    &queue,
                    &mut rng,
                    &mut index_s,
                );
            }
            continue;
        }
        dispatched += execute(
            &tasks,
            exec,
            caching,
            &mut caches,
            &mut index,
            &mut pend,
            &queue,
            &mut rng,
            &mut index_s,
        );
    }
    let elapsed = t0.elapsed().as_secs_f64();

    SchedulerBenchResult {
        policy,
        tasks: dispatched,
        decisions_per_sec: dispatched as f64 / elapsed,
        notify_s,
        pickup_s,
        index_s,
        full_hit_frac: if sched.stats.tasks_dispatched > 0 {
            sched.stats.full_hit_dispatches as f64 / sched.stats.tasks_dispatched as f64
        } else {
            0.0
        },
    }
}

/// "Execute" dispatched tasks instantly: cache+index maintenance only
/// (including the inverted pending index, mirroring the engines).
#[allow(clippy::too_many_arguments)]
fn execute(
    tasks: &[Task],
    exec: ExecutorId,
    caching: bool,
    caches: &mut HashMap<ExecutorId, ObjectCache>,
    index: &mut LocationIndex,
    pend: &mut PendingIndex,
    queue: &WaitQueue,
    rng: &mut Pcg64,
    index_s: &mut f64,
) -> u64 {
    if caching {
        let ti = Instant::now();
        for t in tasks {
            let cache = caches.get_mut(&exec).expect("cache exists");
            for &file in &t.files {
                let res = resolve_access(exec, file, 1, cache, index, rng);
                for &old in &res.evicted {
                    pend.on_index_remove(old, exec, queue, index);
                }
                if res.inserted {
                    pend.on_index_add(file, exec);
                }
            }
        }
        *index_s += ti.elapsed().as_secs_f64();
    }
    tasks.len() as u64
}

/// Run the benchmark across the paper's policy set.
pub fn run(num_tasks: u64, num_files: u32, nodes: usize) -> Vec<SchedulerBenchResult> {
    [
        DispatchPolicy::FirstAvailable,
        DispatchPolicy::FirstCacheAvailable,
        DispatchPolicy::MaxComputeUtil,
        DispatchPolicy::MaxCacheHit,
        DispatchPolicy::GoodCacheCompute,
    ]
    .into_iter()
    .map(|p| bench_policy(p, num_tasks, num_files, nodes))
    .collect()
}

/// Render the Figure 3 table.
pub fn table(results: &[SchedulerBenchResult]) -> Table {
    let mut t = Table::new(
        "Figure 3: data-aware scheduler performance (paper: 2981/s first-available → 1322/s max-cache-hit)",
        &[
            "policy",
            "tasks",
            "decisions/s",
            "notify(s)",
            "window-scan(s)",
            "cache+index(s)",
            "full-hit",
        ],
    );
    for r in results {
        t.row(vec![
            r.policy.name().into(),
            r.tasks.to_string(),
            f(r.decisions_per_sec, 0),
            f(r.notify_s, 3),
            f(r.pickup_s, 3),
            f(r.index_s, 3),
            crate::report::pct(r.full_hit_frac),
        ]);
    }
    t
}

/// Registry entry. `deterministic: false`: the table reports measured
/// decisions/s, which varies run to run — the registry runs this entry
/// alone on the caller's thread (never inside the fan-out) so the
/// numbers are not distorted by concurrent simulator runs.
pub fn figure() -> crate::experiments::registry::Figure {
    use crate::experiments::registry::{Figure, FigureKind};
    fn run_tables(scale: f64, _jobs: usize) -> Vec<Table> {
        let tasks = ((250_000.0 * scale) as u64).max(10_000);
        vec![table(&run(tasks, 10_000, 32))]
    }
    Figure {
        id: "fig03",
        title: "Figure 3: raw data-aware scheduler performance (§5.1)",
        deterministic: false,
        kind: FigureKind::Standalone(run_tables),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_policies_dispatch_all_tasks() {
        for policy in DispatchPolicy::ALL {
            let r = bench_policy(policy, 2_000, 500, 8);
            assert_eq!(r.tasks, 2_000, "policy {policy}");
            assert!(r.decisions_per_sec > 0.0);
        }
    }

    #[test]
    fn data_aware_policies_get_cache_hits() {
        // 2000 tasks over 100 files: after first pass every file is
        // cached somewhere — data-aware policies should score hits.
        let r = bench_policy(DispatchPolicy::GoodCacheCompute, 2_000, 100, 8);
        assert!(r.full_hit_frac > 0.5, "full hits {}", r.full_hit_frac);
        let r = bench_policy(DispatchPolicy::FirstAvailable, 2_000, 100, 8);
        assert_eq!(r.full_hit_frac, 0.0);
    }
}
