//! The workload scenario library's acceptance experiments.
//!
//! One registry [`Figure`] per scenario family
//! ([`ScenarioSpec::CATALOG`]): each runs its preset end-to-end through
//! the simulator at **K = 1 and K = 4 shards** and renders an acceptance
//! table — generated task/edge counts, the workload fingerprint (the
//! determinism witness `docs/WORKLOADS.md` documents), and the run's
//! WET / efficiency / hit-rate split. `datadiff scenarios` selects these
//! entries; `--check` routes them through the same
//! [`registry::check_outputs`] gate as the paper figures, so an empty
//! stream or a NaN efficiency fails CI (`scenarios-smoke`).

use crate::config::{ExperimentConfig, ScenarioSpec};
use crate::experiments::registry::{self, Figure, FigureKind};
use crate::report::{f, pct, Table};
use crate::util::units::MB;
use crate::workload;

/// Shard counts every acceptance run covers.
const SHARD_POINTS: [usize; 2] = [1, 4];

/// Baseline task count at scale 1.0 (floored so `--quick` still clears
/// every family's minimum useful stream: a few churn epochs, a few
/// diurnal slots, whole pipelines).
fn scaled_tasks(scale: f64) -> u64 {
    ((20_000f64 * scale) as u64).max(800)
}

/// The experiment config one scenario acceptance run uses.
pub fn scenario_config(spec: &ScenarioSpec, scale: f64, shards: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.name = format!("scenario-{}-k{}", spec.name(), shards);
    cfg.seed = 42;
    cfg.cluster.max_nodes = 16;
    cfg.cluster.shards = shards;
    cfg.workload.num_tasks = scaled_tasks(scale);
    cfg.workload.num_files = 400;
    cfg.workload.file_size_bytes = 10 * MB;
    cfg.workload.scenario = Some(spec.clone());
    cfg.cache.capacity_bytes = 2_000 * MB;
    cfg
}

/// Run one family's acceptance pass (K ∈ {1, 4}) and render its table.
fn acceptance_tables(name: &'static str, scale: f64, jobs: usize) -> Vec<Table> {
    let spec = ScenarioSpec::preset(name).expect("catalog name");
    let cfgs: Vec<ExperimentConfig> = SHARD_POINTS
        .iter()
        .map(|&k| scenario_config(&spec, scale, k))
        .collect();
    // The stream itself is a property of the config, not the shard
    // count: fingerprint/edge counts are computed once and asserted
    // identical to what each run consumed (same generate call).
    let wl = workload::generate(&cfgs[0].workload, cfgs[0].seed);
    let results = registry::run_configs(cfgs, jobs);
    let mut t = Table::new(
        &format!("scenario acceptance: {name} (seed 42)"),
        &[
            "shards",
            "tasks",
            "dep-edges",
            "fingerprint",
            "WET(s)",
            "efficiency",
            "hit-local",
            "hit-global",
            "miss",
        ],
    );
    for (r, &k) in results.iter().zip(SHARD_POINTS.iter()) {
        assert_eq!(
            r.summary.tasks_completed,
            wl.tasks.len() as u64,
            "scenario {name} k={k}: incomplete run"
        );
        t.row(vec![
            k.to_string(),
            wl.tasks.len().to_string(),
            wl.dep_edges.to_string(),
            format!("{:016x}", wl.fingerprint()),
            f(r.summary.workload_execution_time_s, 1),
            pct(r.summary.efficiency),
            pct(r.summary.hit_local_rate),
            pct(r.summary.hit_global_rate),
            pct(r.summary.miss_rate),
        ]);
    }
    vec![t]
}

// `FigureKind::Standalone` carries a plain fn pointer, so each family
// gets a non-capturing wrapper.
fn run_zipf_churn(scale: f64, jobs: usize) -> Vec<Table> {
    acceptance_tables("zipf-churn", scale, jobs)
}
fn run_diurnal(scale: f64, jobs: usize) -> Vec<Table> {
    acceptance_tables("diurnal", scale, jobs)
}
fn run_bulk_batch(scale: f64, jobs: usize) -> Vec<Table> {
    acceptance_tables("bulk-batch", scale, jobs)
}
fn run_pipeline(scale: f64, jobs: usize) -> Vec<Table> {
    acceptance_tables("pipeline", scale, jobs)
}

/// Registry entries for all four scenario families, catalog order.
pub fn figures() -> Vec<Figure> {
    vec![
        Figure {
            id: "scenario-zipf-churn",
            title: "Scenario: Zipf popularity with hot-set churn",
            deterministic: true,
            kind: FigureKind::Standalone(run_zipf_churn),
        },
        Figure {
            id: "scenario-diurnal",
            title: "Scenario: diurnal multi-user traffic with flash crowds",
            deterministic: true,
            kind: FigureKind::Standalone(run_diurnal),
        },
        Figure {
            id: "scenario-bulk-batch",
            title: "Scenario: DIANA-style bulk batch submission",
            deterministic: true,
            kind: FigureKind::Standalone(run_bulk_batch),
        },
        Figure {
            id: "scenario-pipeline",
            title: "Scenario: multi-stage pipelines with dependency edges",
            deterministic: true,
            kind: FigureKind::Standalone(run_pipeline),
        },
    ]
}

/// Registry id of one family's acceptance figure.
pub fn figure_id(spec: &ScenarioSpec) -> String {
    format!("scenario-{}", spec.name())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::registry::check_outputs;

    /// End-to-end: every family generates, runs at K = 1 and K = 4, and
    /// renders a table that clears the CI output gate — the ISSUE's
    /// acceptance criterion, at smoke scale.
    #[test]
    fn every_family_passes_acceptance_at_smoke_scale() {
        let ids: Vec<String> = ScenarioSpec::CATALOG
            .iter()
            .map(|n| format!("scenario-{n}"))
            .collect();
        let ids: Vec<&str> = ids.iter().map(String::as_str).collect();
        let outs = registry::run_selected(&ids, 0.02, 2);
        assert_eq!(outs.len(), 4, "all four families selected");
        for o in &outs {
            assert_eq!(o.tables.len(), 1);
            assert_eq!(o.tables[0].rows.len(), SHARD_POINTS.len());
            // Same generate call feeds both shard counts: identical
            // fingerprints across the K = 1 and K = 4 rows.
            assert_eq!(o.tables[0].rows[0][3], o.tables[0].rows[1][3]);
        }
        check_outputs(&outs).unwrap();
    }

    #[test]
    fn scenario_configs_validate_and_scale() {
        for name in ScenarioSpec::CATALOG {
            let spec = ScenarioSpec::preset(name).unwrap();
            for k in SHARD_POINTS {
                let cfg = scenario_config(&spec, 0.02, k);
                cfg.validate().unwrap();
                assert_eq!(cfg.cluster.shards, k);
                assert_eq!(cfg.workload.num_tasks, 800);
            }
        }
    }
}
