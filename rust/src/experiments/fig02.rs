//! Figure 2 — abstract-model validation (§4.4).
//!
//! The paper validates the model against 92 astronomy-application runs:
//! (left) CPUs swept 2→128 at data locality 1, 1.38 and 30; (right)
//! locality swept 1→30 at 128 CPUs. Reported model error: ≈5 % mean
//! (CPU sweep), ≈8 % mean (locality sweep), ≤29 % worst case.
//!
//! Here every "measured" value comes from the discrete-event simulator
//! (the testbed substitute) and every "predicted" value from
//! [`crate::model::predict`]; the bench prints the same two sweeps and
//! the error statistics.

use crate::config::{AccessSpec, ArrivalSpec, ExperimentConfig};
use crate::coordinator::provisioner::ProvisionerConfig;
use crate::coordinator::scheduler::DispatchPolicy;
use crate::model::{self, ModelInputs};
use crate::report::{f, pct, Table};
use crate::sim;
use crate::util::stats::Running;
use crate::util::units::{GB, MB};

/// One validation point.
#[derive(Debug, Clone)]
pub struct ValidationPoint {
    /// CPUs in the (static) fleet.
    pub cpus: usize,
    /// Data locality of the workload.
    pub locality: f64,
    /// Simulator-measured workload execution time (s).
    pub measured_s: f64,
    /// Model-predicted W (s).
    pub predicted_s: f64,
    /// Relative error.
    pub error: f64,
}

/// The astronomy-style validation workload for a given CPU count and
/// locality (static provisioning — the paper's §4.4 experiments predate
/// DRP; the model assumes fixed |T|).
pub fn validation_config(cpus: usize, locality: f64, tasks: u64) -> ExperimentConfig {
    let nodes = (cpus / 2).max(1);
    let mut cfg = ExperimentConfig::default();
    cfg.name = format!("fig02-cpus{cpus}-loc{locality}");
    cfg.cluster.max_nodes = nodes;
    cfg.cluster.cpus_per_node = if cpus == 1 { 1 } else { 2 };
    cfg.provisioner = ProvisionerConfig::static_nodes(nodes);
    cfg.workload.num_tasks = tasks;
    // Large namespace so locality fully controls the distinct-file count.
    cfg.workload.num_files = u32::MAX / 2;
    cfg.workload.file_size_bytes = 5 * MB;
    cfg.workload.compute_ms = 100.0; // astronomy stacking-like ratio
    cfg.workload.arrival = ArrivalSpec::Batch;
    cfg.workload.access = AccessSpec::Locality(locality);
    cfg.scheduler.policy = DispatchPolicy::GoodCacheCompute;
    cfg.cache.capacity_bytes = 50 * GB; // caches never bind here
    cfg
}

/// Run one validation point: simulate, predict, compare.
pub fn run_point(cpus: usize, locality: f64, tasks: u64) -> ValidationPoint {
    let cfg = validation_config(cpus, locality, tasks);
    let r = sim::run(&cfg);
    let inputs = ModelInputs::from_config(&cfg);
    let pred = model::predict(&inputs);
    let measured = r.summary.workload_execution_time_s;
    ValidationPoint {
        cpus,
        locality,
        measured_s: measured,
        predicted_s: pred.w,
        error: model::relative_error(&pred, measured),
    }
}

/// Output of the full Figure 2 reproduction.
#[derive(Debug)]
pub struct Fig02Output {
    /// CPU-sweep points (left panel).
    pub cpu_sweep: Vec<ValidationPoint>,
    /// Locality-sweep points (right panel).
    pub locality_sweep: Vec<ValidationPoint>,
}

impl Fig02Output {
    /// Error statistics over a panel.
    pub fn stats(points: &[ValidationPoint]) -> (f64, f64, f64) {
        let mut run = Running::new();
        for p in points {
            run.push(p.error);
        }
        (run.mean(), run.stddev(), run.max())
    }
}

/// Run both sweeps. `scale` shrinks task counts for quick runs
/// (1.0 ≈ paper-scale task counts; benches use 0.2). Points fan out
/// over all cores; see [`run_jobs`].
pub fn run(scale: f64) -> Fig02Output {
    run_jobs(scale, crate::util::par::default_jobs())
}

/// Run both sweeps with the validation points fanned out over `jobs`
/// workers. Every point is an independent seeded simulation and the
/// output order is fixed, so the result is identical for any job count.
pub fn run_jobs(scale: f64, jobs: usize) -> Fig02Output {
    // Paper: 111K/154K/23K tasks for locality 1/1.38/30.
    let tasks_for = |l: f64| -> u64 {
        let base = if l < 1.2 {
            111_000.0
        } else if l < 10.0 {
            154_000.0
        } else {
            23_000.0
        };
        ((base * scale) as u64).max(2_000)
    };
    let mut specs: Vec<(usize, f64)> = Vec::new();
    for &locality in &[1.0, 1.38, 30.0] {
        for &cpus in &[2usize, 4, 8, 16, 32, 64, 128] {
            specs.push((cpus, locality));
        }
    }
    let cpu_points = specs.len();
    for &locality in &[1.0, 2.0, 3.0, 4.0, 5.0, 10.0, 15.0, 20.0, 25.0, 30.0] {
        specs.push((128, locality));
    }
    let mut points = crate::util::par::map(specs, jobs, |_, (cpus, locality)| {
        run_point(cpus, locality, tasks_for(locality))
    });
    let locality_sweep = points.split_off(cpu_points);
    Fig02Output {
        cpu_sweep: points,
        locality_sweep,
    }
}

/// Registry entry: standalone driver at 0.2× the suite scale (the
/// historical `figures` scaling for this figure's sweeps).
pub fn figure() -> crate::experiments::registry::Figure {
    use crate::experiments::registry::{Figure, FigureKind};
    fn run_tables(scale: f64, jobs: usize) -> Vec<Table> {
        tables(&run_jobs(0.2 * scale, jobs))
    }
    Figure {
        id: "fig02",
        title: "Figure 2: abstract-model validation (§4.4)",
        deterministic: true,
        kind: FigureKind::Standalone(run_tables),
    }
}

/// Render both panels + the error statistics as tables.
pub fn tables(out: &Fig02Output) -> Vec<Table> {
    let mut left = Table::new(
        "Figure 2 (left): model error vs #CPUs",
        &["cpus", "locality", "measured(s)", "model(s)", "error"],
    );
    for p in &out.cpu_sweep {
        left.row(vec![
            p.cpus.to_string(),
            f(p.locality, 2),
            f(p.measured_s, 1),
            f(p.predicted_s, 1),
            pct(p.error),
        ]);
    }
    let mut right = Table::new(
        "Figure 2 (right): model error vs data locality (128 CPUs)",
        &["locality", "measured(s)", "model(s)", "error"],
    );
    for p in &out.locality_sweep {
        right.row(vec![
            f(p.locality, 2),
            f(p.measured_s, 1),
            f(p.predicted_s, 1),
            pct(p.error),
        ]);
    }
    let (m1, s1, w1) = Fig02Output::stats(&out.cpu_sweep);
    let (m2, s2, w2) = Fig02Output::stats(&out.locality_sweep);
    let mut stats = Table::new(
        "Figure 2: error statistics (paper: 5%/8% mean, 5% stddev, 29% worst)",
        &["panel", "mean", "stddev", "worst"],
    );
    stats.row(vec!["cpu sweep".into(), pct(m1), pct(s1), pct(w1)]);
    stats.row(vec!["locality sweep".into(), pct(m2), pct(s2), pct(w2)]);
    vec![left, right, stats]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_point_is_sane() {
        let p = run_point(16, 5.0, 3_000);
        assert!(p.measured_s > 0.0);
        assert!(p.predicted_s > 0.0);
        assert!(p.error.is_finite());
        // The model should be in the right ballpark (same order).
        assert!(p.error < 1.0, "error {:.1}%", p.error * 100.0);
    }

    #[test]
    fn more_cpus_run_faster() {
        let slow = run_point(4, 10.0, 3_000);
        let fast = run_point(64, 10.0, 3_000);
        assert!(
            fast.measured_s < slow.measured_s,
            "{} !< {}",
            fast.measured_s,
            slow.measured_s
        );
        // And the model agrees on the direction.
        assert!(fast.predicted_s < slow.predicted_s);
    }

    #[test]
    fn higher_locality_runs_faster() {
        let low = run_point(32, 1.0, 4_000);
        let high = run_point(32, 30.0, 4_000);
        assert!(
            high.measured_s < low.measured_s,
            "locality speedup missing: {} !< {}",
            high.measured_s,
            low.measured_s
        );
    }
}
