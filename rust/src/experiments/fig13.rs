//! Figure 13 — performance index and speedup (§5.2.4).
//!
//! `SP = WET_GPFS / WET_DD` (baseline = the first-available run);
//! `PI = SP / CPU_T`, normalized to [0, 1] across experiments.
//!
//! Paper shape: good-cache-compute 2 GB and 4 GB both reach SP = 3.5×,
//! but the 4 GB run used 17 CPU-hours vs 24 → PI 1.0 vs 0.7; a static
//! 64-node run of the same workload matches the speedup but burns 46
//! CPU-hours → PI 0.33; first-available PI is 2–34× below diffusion.

use super::run_summary_experiment;
use crate::config::ExperimentConfig;
use crate::coordinator::provisioner::ProvisionerConfig;
use crate::report::{f, Table};
use crate::sim::RunResult;

/// One Figure 13 row.
#[derive(Debug, Clone)]
pub struct PiRow {
    /// Experiment name.
    pub name: String,
    /// Speedup vs the first-available baseline.
    pub speedup: f64,
    /// CPU hours consumed.
    pub cpu_hours: f64,
    /// Normalized performance index ∈ [0, 1].
    pub pi: f64,
}

/// The extra run Figure 13 adds: the best policy (good-cache-compute,
/// 4 GB) with *static* provisioning — 64 nodes held for the whole run.
pub fn static_best_config() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::paper_fig(8).expect("preset");
    cfg.name = "fig13-gcc-4gb-static64".into();
    cfg.provisioner = ProvisionerConfig::static_nodes(cfg.cluster.max_nodes);
    cfg
}

/// Compute Figure 13 rows. `results` must start with the first-available
/// baseline (Fig 4) and may include the static run appended.
pub fn rows(results: &[RunResult]) -> Vec<PiRow> {
    let baseline = results
        .first()
        .expect("need the first-available baseline")
        .summary
        .workload_execution_time_s;
    let mut rows: Vec<PiRow> = results
        .iter()
        .map(|r| {
            let sp = r.summary.speedup_vs(baseline);
            PiRow {
                name: r.name.clone(),
                speedup: sp,
                cpu_hours: r.summary.cpu_time_hours,
                pi: r.summary.performance_index_raw(baseline),
            }
        })
        .collect();
    let max_pi = rows.iter().map(|r| r.pi).fold(0.0, f64::max);
    if max_pi > 0.0 {
        for r in &mut rows {
            r.pi /= max_pi;
        }
    }
    rows
}

/// Run the full Figure 13 set: the seven paper runs plus the static one.
pub fn run() -> Vec<RunResult> {
    let mut results = super::fig04_10::run();
    results.push(run_summary_experiment(&static_best_config()));
    results
}

/// Render the Figure 13 table.
pub fn table(results: &[RunResult]) -> Table {
    let mut t = Table::new(
        "Figure 13: performance index and speedup (baseline = first-available)",
        &["experiment", "speedup", "CPU-hrs", "PI (normalized)"],
    );
    for r in rows(results) {
        t.row(vec![
            r.name,
            f(r.speedup, 2),
            f(r.cpu_hours, 1),
            f(r.pi, 2),
        ]);
    }
    t
}

/// Registry entry: renders from the Figure 4–10 runs **plus** the
/// static-provisioning run ([`static_best_config`]) the registry
/// materializes alongside them.
pub fn figure() -> crate::experiments::registry::Figure {
    use crate::experiments::registry::{Figure, FigureKind, SimSet};
    fn render(results: &[RunResult]) -> Vec<Table> {
        vec![table(results)]
    }
    Figure {
        id: "fig13",
        title: "Figure 13: performance index and speedup (§5.2.4)",
        deterministic: true,
        kind: FigureKind::Sims {
            set: SimSet::PaperPlusStatic,
            render,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ArrivalSpec;
    use crate::coordinator::scheduler::DispatchPolicy;
    use crate::util::units::MB;

    fn mini(policy: DispatchPolicy, static_nodes: bool) -> RunResult {
        let mut cfg = ExperimentConfig::default();
        cfg.name = format!("{policy}-{static_nodes}");
        cfg.cluster.max_nodes = 4;
        cfg.workload.num_tasks = 400;
        cfg.workload.num_files = 40;
        cfg.workload.file_size_bytes = 5 * MB;
        cfg.workload.arrival = ArrivalSpec::Constant(50.0);
        cfg.scheduler.policy = policy;
        if static_nodes {
            cfg.provisioner = ProvisionerConfig::static_nodes(4);
        }
        run_summary_experiment(&cfg)
    }

    #[test]
    fn baseline_speedup_is_one_and_pi_normalized() {
        let results = vec![
            mini(DispatchPolicy::FirstAvailable, false),
            mini(DispatchPolicy::GoodCacheCompute, false),
            mini(DispatchPolicy::GoodCacheCompute, true),
        ];
        let rs = rows(&results);
        assert!((rs[0].speedup - 1.0).abs() < 1e-9);
        let max = rs.iter().map(|r| r.pi).fold(0.0, f64::max);
        assert!((max - 1.0).abs() < 1e-9);
        // Static provisioning burns at least as many CPU hours as DRP
        // for the same policy.
        assert!(rs[2].cpu_hours >= rs[1].cpu_hours * 0.9);
    }
}
