//! The figure registry — the paper reproduction as a first-class,
//! CI-runnable artifact.
//!
//! Every figure module (plus the §6 sweeps) contributes one [`Figure`]
//! entry; [`run_selected`] materializes the union of the experiment
//! configs the selected figures need, **deduplicates shared runs** (Figs
//! 11–15 reuse the Fig 4–10 set), fans all simulator runs out across
//! cores with [`crate::util::par`], and renders tables in figure order —
//! so the merged output is byte-identical for any `--jobs` value.
//!
//! Standalone figures run after the fan-out on the caller's thread:
//! Figure 2 parallelizes its validation points internally, and Figure 3
//! is a wall-clock scheduler benchmark that must not contend with other
//! work (its throughput numbers are inherently non-deterministic, which
//! its entry declares via `deterministic: false`).
//!
//! [`check_outputs`] is the CI `figures-smoke` gate: it rejects empty
//! tables and non-finite cells, so a regression that silently produces
//! NaN efficiency or an empty sweep fails the build.

use super::{fig02, fig03, fig04_10, fig11, fig12, fig13, fig14, fig15, scenarios, sweeps};
use crate::config::ExperimentConfig;
use crate::report::Table;
use crate::sim::RunResult;
use crate::util::par;

/// Which shared simulator-run set a figure renders from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimSet {
    /// The seven Figure 4–10 paper runs, in figure order.
    Paper,
    /// The paper runs plus the Figure 13 static-provisioning run.
    PaperPlusStatic,
    /// The §6 eviction-policy ablation runs.
    Eviction,
    /// The §6 dispatch-policy sweep runs.
    Dispatch,
}

/// How a figure produces its tables.
#[derive(Clone, Copy)]
pub enum FigureKind {
    /// Self-contained driver: `run(scale, jobs)`.
    Standalone(fn(f64, usize) -> Vec<Table>),
    /// Renders from a shared simulator-run set.
    Sims {
        /// Which run set the renderer consumes.
        set: SimSet,
        /// Renderer over the set's results (set order).
        render: fn(&[RunResult]) -> Vec<Table>,
    },
}

/// One registry entry.
pub struct Figure {
    /// Stable id (`fig02` … `fig15`, `sweep-eviction`, `sweep-dispatch`,
    /// `sweep-allocation`).
    pub id: &'static str,
    /// Human title for logs and reports.
    pub title: &'static str,
    /// Whether the rendered tables are byte-stable across reruns and job
    /// counts (false only for wall-clock benchmarks like Figure 3).
    pub deterministic: bool,
    /// How to produce the tables.
    pub kind: FigureKind,
}

/// Rendered output of one figure.
pub struct FigureOutput {
    /// Registry id.
    pub id: &'static str,
    /// Registry title.
    pub title: &'static str,
    /// Copied from the registry entry.
    pub deterministic: bool,
    /// The figure's tables, in render order.
    pub tables: Vec<Table>,
}

/// All registered figures, in paper order (sweeps, then the workload
/// scenario library's acceptance figures, last).
pub fn registry() -> Vec<Figure> {
    let mut v = vec![
        fig02::figure(),
        fig03::figure(),
        fig04_10::figure(),
        fig11::figure(),
        fig12::figure(),
        fig13::figure(),
        fig14::figure(),
        fig15::figure(),
        sweeps::eviction_figure(),
        sweeps::dispatch_figure(),
        sweeps::allocation_figure(),
    ];
    v.extend(scenarios::figures());
    v
}

/// Ids of every registered figure, in registry order.
pub fn all_ids() -> Vec<&'static str> {
    registry().iter().map(|f| f.id).collect()
}

/// Fan a list of experiment configs out across `jobs` workers; results
/// come back in config order (per-run seeding lives in each config, so
/// scheduling cannot perturb them).
pub fn run_configs(cfgs: Vec<ExperimentConfig>, jobs: usize) -> Vec<RunResult> {
    par::map(cfgs, jobs, |_, cfg| super::run_summary_experiment(&cfg))
}

/// Run every registered figure at `scale` with `jobs` workers.
pub fn run_all_figures(scale: f64, jobs: usize) -> Vec<FigureOutput> {
    let ids = all_ids();
    run_selected(&ids, scale, jobs)
}

/// Run the figures named in `ids` (unknown ids are ignored; use
/// [`all_ids`] to enumerate) at `scale` with `jobs` workers.
pub fn run_selected(ids: &[&str], scale: f64, jobs: usize) -> Vec<FigureOutput> {
    let figures: Vec<Figure> = registry()
        .into_iter()
        .filter(|f| ids.contains(&f.id))
        .collect();
    let needs = |set: SimSet| -> bool {
        figures
            .iter()
            .any(|f| matches!(f.kind, FigureKind::Sims { set: s, .. } if s == set))
    };
    let need_paper = needs(SimSet::Paper) || needs(SimSet::PaperPlusStatic);
    let need_static = needs(SimSet::PaperPlusStatic);
    let need_evict = needs(SimSet::Eviction);
    let need_dispatch = needs(SimSet::Dispatch);

    // One shared fan-out over the union of needed configs, deduplicated:
    // the paper set is materialized once no matter how many figures
    // render from it.
    let mut cfgs: Vec<ExperimentConfig> = Vec::new();
    if need_paper {
        cfgs.extend(fig04_10::configs(scale));
    }
    let paper_n = cfgs.len();
    if need_static {
        let mut cfg = fig13::static_best_config();
        cfg.workload.num_tasks = ((cfg.workload.num_tasks as f64 * scale) as u64).max(1_000);
        cfgs.push(cfg);
    }
    let static_n = cfgs.len() - paper_n;
    if need_evict {
        cfgs.extend(sweeps::eviction_configs(scale));
    }
    let evict_n = cfgs.len() - paper_n - static_n;
    if need_dispatch {
        cfgs.extend(sweeps::dispatch_configs(scale));
    }
    let mut results = run_configs(cfgs, jobs);

    // Split the flat result vector back into the per-set slices.
    let dispatch_results = results.split_off(paper_n + static_n + evict_n);
    let evict_results = results.split_off(paper_n + static_n);
    let mut static13 = if need_static { results.pop() } else { None };
    let mut paper = results; // the first `paper_n` entries

    let mut out = Vec::with_capacity(figures.len());
    for fig in &figures {
        let tables = match fig.kind {
            FigureKind::Standalone(run) => run(scale, jobs),
            FigureKind::Sims { set, render } => match set {
                SimSet::Paper => render(&paper),
                SimSet::PaperPlusStatic => {
                    let s = static13.take().expect("static run materialized");
                    paper.push(s);
                    let tables = render(&paper);
                    static13 = paper.pop();
                    tables
                }
                SimSet::Eviction => render(&evict_results),
                SimSet::Dispatch => render(&dispatch_results),
            },
        };
        out.push(FigureOutput {
            id: fig.id,
            title: fig.title,
            deterministic: fig.deterministic,
            tables,
        });
    }
    out
}

/// The `figures --check` / CI `figures-smoke` gate: every selected
/// figure must render at least one table, every table must have rows,
/// and no cell may hold a non-finite number.
pub fn check_outputs(outputs: &[FigureOutput]) -> Result<(), String> {
    if outputs.is_empty() {
        return Err("no figures were produced".into());
    }
    for o in outputs {
        if o.tables.is_empty() {
            return Err(format!("{}: produced no tables", o.id));
        }
        for t in &o.tables {
            if t.rows.is_empty() {
                return Err(format!("{}: table `{}` is empty", o.id, t.title));
            }
            for row in &t.rows {
                for cell in row {
                    let bad = cell.contains("NaN") || cell.contains("inf");
                    if bad {
                        return Err(format!(
                            "{}: table `{}` has non-finite cell `{cell}`",
                            o.id, t.title
                        ));
                    }
                }
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_ids_are_unique_and_complete() {
        let ids = all_ids();
        let mut dedup = ids.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), ids.len(), "duplicate figure ids");
        for id in [
            "fig02",
            "fig03",
            "fig04-10",
            "fig11",
            "fig15",
            "sweep-eviction",
            "sweep-allocation",
            "scenario-zipf-churn",
            "scenario-diurnal",
            "scenario-bulk-batch",
            "scenario-pipeline",
        ] {
            assert!(ids.contains(&id), "missing {id}");
        }
    }

    #[test]
    fn check_outputs_flags_bad_tables() {
        let mut t = Table::new("x", &["a"]);
        t.row(vec!["1.0".into()]);
        let good = FigureOutput {
            id: "fig99",
            title: "t",
            deterministic: true,
            tables: vec![t.clone()],
        };
        assert!(check_outputs(&[good]).is_ok());
        let empty = FigureOutput {
            id: "fig99",
            title: "t",
            deterministic: true,
            tables: vec![Table::new("e", &["a"])],
        };
        assert!(check_outputs(&[empty]).unwrap_err().contains("empty"));
        let mut nan = Table::new("n", &["a"]);
        nan.row(vec!["NaN".into()]);
        let bad = FigureOutput {
            id: "fig99",
            title: "t",
            deterministic: true,
            tables: vec![nan],
        };
        assert!(check_outputs(&[bad]).unwrap_err().contains("non-finite"));
        assert!(check_outputs(&[]).is_err());
    }

    #[test]
    fn sweep_selection_runs_only_the_sweeps() {
        // Tiny scale (clamped to the 1K-task floor) keeps this fast while
        // exercising the fan-out + split logic end to end.
        let outs = run_selected(&["sweep-eviction", "sweep-dispatch"], 0.004, 4);
        assert_eq!(outs.len(), 2);
        assert_eq!(outs[0].id, "sweep-eviction");
        assert_eq!(outs[0].tables[0].rows.len(), 4);
        assert_eq!(outs[1].tables[0].rows.len(), 5);
        check_outputs(&outs).unwrap();
    }
}
