//! Figure 15 — average response time per experiment (§5.2.6).
//!
//! `AR_T = WQ_T + E_T + D_T` (wait-queue + execution + delivery). Paper
//! shape: 3.1 s for the best diffusion run (good-cache-compute 4 GB) vs
//! 1569+ s for first-available on GPFS — a >500× gap, driven almost
//! entirely by wait-queue length.

use crate::report::{f, Table};
use crate::sim::RunResult;

/// Render the Figure 15 table from the Figure 4–10 runs.
pub fn table(results: &[RunResult]) -> Table {
    let mut t = Table::new(
        "Figure 15: average response time (paper: 3.1s best diffusion vs 1870s worst GPFS)",
        &["experiment", "avg-resp(s)", "max-resp(s)", "queue-max"],
    );
    for r in results {
        t.row(vec![
            r.name.clone(),
            f(r.summary.avg_response_time_s, 1),
            f(r.summary.max_response_time_s, 1),
            r.summary.queue_max_len.to_string(),
        ]);
    }
    t
}

/// The headline ratio: worst response time over best (paper: >500×).
pub fn best_worst_ratio(results: &[RunResult]) -> f64 {
    let best = results
        .iter()
        .map(|r| r.summary.avg_response_time_s)
        .fold(f64::INFINITY, f64::min);
    let worst = results
        .iter()
        .map(|r| r.summary.avg_response_time_s)
        .fold(0.0, f64::max);
    if best > 0.0 {
        worst / best
    } else {
        f64::INFINITY
    }
}

/// Registry entry: renders from the shared Figure 4–10 runs.
pub fn figure() -> crate::experiments::registry::Figure {
    use crate::experiments::registry::{Figure, FigureKind, SimSet};
    fn render(results: &[RunResult]) -> Vec<Table> {
        vec![table(results)]
    }
    Figure {
        id: "fig15",
        title: "Figure 15: average response time (§5.2.6)",
        deterministic: true,
        kind: FigureKind::Sims {
            set: SimSet::Paper,
            render,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ArrivalSpec, ExperimentConfig};
    use crate::coordinator::scheduler::DispatchPolicy;
    use crate::experiments::run_summary_experiment;
    use crate::util::units::MB;

    #[test]
    fn diffusion_beats_gpfs_on_response_time() {
        let mk = |policy| {
            let mut cfg = ExperimentConfig::default();
            cfg.name = format!("{policy}");
            cfg.cluster.max_nodes = 4;
            cfg.workload.num_tasks = 2_000;
            cfg.workload.num_files = 50;
            cfg.workload.file_size_bytes = 10 * MB;
            cfg.workload.arrival = ArrivalSpec::IncreasingRate {
                initial: 10.0,
                factor: 1.5,
                interval_s: 10.0,
                max_rate: 100.0,
            };
            cfg.scheduler.policy = policy;
            run_summary_experiment(&cfg)
        };
        let fa = mk(DispatchPolicy::FirstAvailable);
        let gcc = mk(DispatchPolicy::GoodCacheCompute);
        assert!(
            gcc.summary.avg_response_time_s < fa.summary.avg_response_time_s,
            "diffusion {} !< gpfs {}",
            gcc.summary.avg_response_time_s,
            fa.summary.avg_response_time_s
        );
        let ratio = best_worst_ratio(&[fa, gcc]);
        assert!(ratio > 1.0);
    }
}
