//! Figure 11 — cache performance across the diffusion experiments
//! (§5.2.2): local/global hit and miss percentages per experiment, plus
//! the ideal case (working set fully cached: only cold misses).

use crate::report::{pct, Table};
use crate::sim::RunResult;

/// Render the Figure 11 table from the Figure 4–10 runs.
pub fn table(results: &[RunResult]) -> Table {
    let mut t = Table::new(
        "Figure 11: cache performance (paper: 1GB misses ~70%, ≥1.5GB 4-6% misses)",
        &["experiment", "hit-local", "hit-global", "miss"],
    );
    // Ideal: every distinct file misses exactly once, everything else is
    // a local hit.
    if let Some(r) = results.first() {
        let tasks = r.summary.tasks_completed.max(1) as f64;
        let distinct = r.working_set_bytes as f64 / r.file_size_bytes.max(1) as f64;
        let cold = distinct / tasks;
        t.row(vec![
            "ideal".into(),
            pct(1.0 - cold),
            pct(0.0),
            pct(cold),
        ]);
    }
    for r in results {
        t.row(vec![
            r.name.clone(),
            pct(r.summary.hit_local_rate),
            pct(r.summary.hit_global_rate),
            pct(r.summary.miss_rate),
        ]);
    }
    t
}

/// Registry entry: renders from the shared Figure 4–10 runs.
pub fn figure() -> crate::experiments::registry::Figure {
    use crate::experiments::registry::{Figure, FigureKind, SimSet};
    fn render(results: &[RunResult]) -> Vec<Table> {
        vec![table(results)]
    }
    Figure {
        id: "fig11",
        title: "Figure 11: cache performance (§5.2.2)",
        deterministic: true,
        kind: FigureKind::Sims {
            set: SimSet::Paper,
            render,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::scheduler::DispatchPolicy;
    use crate::experiments::run_summary_experiment;

    #[test]
    fn table_includes_ideal_and_each_run() {
        let mut cfg = crate::config::ExperimentConfig::default();
        cfg.name = "t".into();
        cfg.cluster.max_nodes = 2;
        cfg.workload.num_tasks = 200;
        cfg.workload.num_files = 20;
        cfg.workload.arrival = crate::config::ArrivalSpec::Constant(100.0);
        cfg.scheduler.policy = DispatchPolicy::GoodCacheCompute;
        let r = run_summary_experiment(&cfg);
        let t = table(&[r]);
        assert_eq!(t.rows.len(), 2);
        assert_eq!(t.rows[0][0], "ideal");
    }
}
