//! Figure 12 — average and peak (99-percentile) throughput per
//! experiment, split by source (§5.2.3).
//!
//! Paper shape: first-available averages ~4 Gb/s (all GPFS, peak 6);
//! data diffusion averages 5.3–13.9 Gb/s with peaks up to 100 Gb/s and
//! GPFS load shrinking to 0.4 Gb/s once the working set is cached.

use super::throughput_split;
use crate::report::{f, Table};
use crate::sim::RunResult;

/// Render the Figure 12 table from the Figure 4–10 runs.
pub fn table(results: &[RunResult]) -> Table {
    let mut t = Table::new(
        "Figure 12: avg + peak throughput by source (Gb/s)",
        &[
            "experiment",
            "local",
            "remote",
            "gpfs",
            "avg-total",
            "peak(99%)",
        ],
    );
    for r in results {
        let sp = throughput_split(r);
        t.row(vec![
            r.name.clone(),
            f(sp.local_gbps, 2),
            f(sp.remote_gbps, 2),
            f(sp.gpfs_gbps, 2),
            f(sp.local_gbps + sp.remote_gbps + sp.gpfs_gbps, 2),
            f(sp.peak_gbps, 1),
        ]);
    }
    t
}

/// Registry entry: renders from the shared Figure 4–10 runs.
pub fn figure() -> crate::experiments::registry::Figure {
    use crate::experiments::registry::{Figure, FigureKind, SimSet};
    fn render(results: &[RunResult]) -> Vec<Table> {
        vec![table(results)]
    }
    Figure {
        id: "fig12",
        title: "Figure 12: avg/peak throughput by source (§5.2.3)",
        deterministic: true,
        kind: FigureKind::Sims {
            set: SimSet::Paper,
            render,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ArrivalSpec;
    use crate::coordinator::scheduler::DispatchPolicy;
    use crate::experiments::run_summary_experiment;
    use crate::util::units::MB;

    #[test]
    fn first_available_is_all_gpfs() {
        let mut cfg = crate::config::ExperimentConfig::default();
        cfg.name = "fa".into();
        cfg.cluster.max_nodes = 2;
        cfg.workload.num_tasks = 300;
        cfg.workload.num_files = 30;
        cfg.workload.file_size_bytes = 5 * MB;
        cfg.workload.arrival = ArrivalSpec::Constant(60.0);
        cfg.scheduler.policy = DispatchPolicy::FirstAvailable;
        let r = run_summary_experiment(&cfg);
        let sp = throughput_split(&r);
        assert_eq!(sp.local_gbps, 0.0);
        assert_eq!(sp.remote_gbps, 0.0);
        assert!(sp.gpfs_gbps > 0.0);
        let t = table(&[r]);
        assert_eq!(t.rows.len(), 1);
    }
}
