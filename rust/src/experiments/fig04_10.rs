//! Figures 4–10 — the seven summary-view experiments (§5.2.1).
//!
//! | fig | policy | cache/node | paper WET | paper eff |
//! |-----|--------|-----------:|----------:|----------:|
//! | 4 | first-available (GPFS) | — | 5011 s | 28 % |
//! | 5 | good-cache-compute | 1 GB | 3762 s | 38 % |
//! | 6 | good-cache-compute | 1.5 GB | 1596 s | 89 % |
//! | 7 | good-cache-compute | 2 GB | 1436 s | 99 % |
//! | 8 | good-cache-compute | 4 GB | 1427 s | 99 % |
//! | 9 | max-cache-hit | 4 GB | 2888 s | 49 % |
//! | 10 | max-compute-util | 4 GB | 2037 s | 69 % |

use super::{summary_table, summary_view_table};
use crate::config::ExperimentConfig;
use crate::report::Table;
use crate::sim::RunResult;

/// Paper-reported workload execution times, for shape comparison.
pub const PAPER_WET_S: [(u32, f64); 7] = [
    (4, 5011.0),
    (5, 3762.0),
    (6, 1596.0),
    (7, 1436.0),
    (8, 1427.0),
    (9, 2888.0),
    (10, 2037.0),
];

/// Run all seven experiments (figure order).
pub fn run() -> Vec<RunResult> {
    scaled_run(1.0)
}

/// The seven experiment configs with the task count scaled by `scale`
/// (1.0 = the paper's 250K tasks; benches use smaller scales for quick
/// iterations — the shape holds, absolute times shrink).
pub fn configs(scale: f64) -> Vec<ExperimentConfig> {
    (4..=10)
        .map(|fig| {
            let mut cfg = ExperimentConfig::paper_fig(fig).expect("preset");
            cfg.workload.num_tasks =
                ((cfg.workload.num_tasks as f64 * scale) as u64).max(1_000);
            cfg
        })
        .collect()
}

/// Run all seven experiments at `scale`, fanned out across all cores.
/// The runs are independent and carry their own seeds, so results are
/// identical to a sequential run and returned in figure order.
pub fn scaled_run(scale: f64) -> Vec<RunResult> {
    scaled_run_jobs(scale, crate::util::par::default_jobs())
}

/// [`scaled_run`] with an explicit worker count (`1` = inline).
pub fn scaled_run_jobs(scale: f64, jobs: usize) -> Vec<RunResult> {
    crate::experiments::registry::run_configs(configs(scale), jobs)
}

/// Registry entry: renders the summary, the paper-comparison table, and
/// the per-run summary views from the shared paper set.
pub fn figure() -> crate::experiments::registry::Figure {
    use crate::experiments::registry::{Figure, FigureKind, SimSet};
    fn render(results: &[RunResult]) -> Vec<Table> {
        tables(results, 120)
    }
    Figure {
        id: "fig04-10",
        title: "Figures 4-10: the seven summary-view experiments (§5.2.1)",
        deterministic: true,
        kind: FigureKind::Sims {
            set: SimSet::Paper,
            render,
        },
    }
}

/// Render: one summary table plus a sampled time-series view per run.
pub fn tables(results: &[RunResult], view_every_s: usize) -> Vec<Table> {
    let mut out = vec![summary_table(results)];
    let mut cmp = Table::new(
        "Figures 4-10: measured vs paper workload execution time",
        &["experiment", "measured WET(s)", "paper WET(s)", "ratio"],
    );
    for (r, &(fig, paper)) in results.iter().zip(PAPER_WET_S.iter()) {
        let _ = fig;
        cmp.row(vec![
            r.name.clone(),
            crate::report::f(r.summary.workload_execution_time_s, 0),
            crate::report::f(paper, 0),
            crate::report::f(r.summary.workload_execution_time_s / paper, 2),
        ]);
    }
    out.push(cmp);
    for r in results {
        out.push(summary_view_table(r, view_every_s));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::scheduler::DispatchPolicy;

    /// The ordering relations the paper's figures demonstrate must hold
    /// in the reproduction (shape, not absolute numbers). This is the
    /// headline qualitative check and runs at paper scale — it is the
    /// slowest test in the suite (~20 s release / may take minutes in
    /// debug), so it is ignored by default; the fig04_10 bench and the
    /// integration suite run it.
    #[test]
    #[ignore = "paper-scale; run via cargo test -- --ignored or the benches"]
    fn paper_orderings_hold() {
        let rs = run();
        let wet: Vec<f64> = rs
            .iter()
            .map(|r| r.summary.workload_execution_time_s)
            .collect();
        let (fa, gcc1, gcc15, gcc2, gcc4, mch, mcu) =
            (wet[0], wet[1], wet[2], wet[3], wet[4], wet[5], wet[6]);
        // first-available is the slowest of all.
        for (i, &w) in wet.iter().enumerate().skip(1) {
            assert!(w < fa, "experiment {i} not faster than first-available");
        }
        // Bigger caches help monotonically (1 GB ≥ 1.5 GB ≥ 2 GB ≈ 4 GB).
        assert!(gcc15 < gcc1);
        assert!(gcc2 <= gcc15);
        assert!((gcc4 - gcc2).abs() / gcc2 < 0.25, "2GB≈4GB: {gcc2} vs {gcc4}");
        // good-cache-compute beats max-cache-hit outright; vs
        // max-compute-util our simulator gives a near-tie in WET (both
        // keep up with arrivals — see EXPERIMENTS.md §Deviations), so we
        // assert the paper's *mechanism* instead: mcu moves more data
        // through remote caches than gcc does.
        assert!(gcc4 < mch);
        assert!(gcc4 <= mcu * 1.02, "gcc {gcc4} ≫ mcu {mcu}");
        assert!(
            rs[6].summary.hit_global_rate >= rs[4].summary.hit_global_rate,
            "mcu remote {} < gcc remote {}",
            rs[6].summary.hit_global_rate,
            rs[4].summary.hit_global_rate
        );
        // max-compute-util beats max-cache-hit (paper: 2037 vs 2888).
        assert!(mcu < mch, "mcu {mcu} !< mch {mch}");
        // Policy sanity on the runs.
        assert_eq!(rs[0].summary.miss_rate, 1.0);
        assert!(rs[4].summary.hit_local_rate > 0.6);
    }

    #[test]
    fn presets_match_module_doc() {
        let cfgs: Vec<ExperimentConfig> =
            (4..=10).map(|f| ExperimentConfig::paper_fig(f).unwrap()).collect();
        assert_eq!(cfgs[0].scheduler.policy, DispatchPolicy::FirstAvailable);
        assert_eq!(cfgs[5].scheduler.policy, DispatchPolicy::MaxCacheHit);
        assert_eq!(cfgs[6].scheduler.policy, DispatchPolicy::MaxComputeUtil);
    }
}
