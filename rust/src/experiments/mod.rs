//! Experiment drivers — one entry point per figure of the paper's
//! evaluation (§4.4 Figure 2, §5.1 Figure 3, §5.2 Figures 4–15).
//!
//! Each driver builds its configs, runs the simulator, and renders the
//! same rows/series the paper reports (ASCII + CSV under
//! `target/figures/`). The `rust/benches/*` binaries and the `datadiff`
//! CLI are thin wrappers over these functions, so a figure can be
//! regenerated either way.
//!
//! The [`registry`] module exposes the whole suite (figs 2–15 plus the
//! §6 sweeps) as one [`run_all_figures`] entry point: shared runs are
//! deduplicated and fanned out across cores, and the merged tables are
//! byte-identical for any `--jobs` value — the artifact the CI
//! `figures-smoke` job runs on every push.

pub mod fig02;
pub mod fig03;
pub mod fig04_10;
pub mod fig11;
pub mod fig12;
pub mod fig13;
pub mod fig14;
pub mod fig15;
pub mod registry;
pub mod scenarios;
pub mod shardio;
pub mod sweeps;

pub use registry::{run_all_figures, FigureOutput};

use crate::config::ExperimentConfig;
use crate::report::{f, pct, Table};
use crate::sim::{self, RunResult};
use crate::util::units::bps_to_gbps;

/// Run one summary-view experiment (Figs 4–10 style).
pub fn run_summary_experiment(cfg: &ExperimentConfig) -> RunResult {
    crate::info!(
        "running experiment `{}` (policy {}, cache {})",
        cfg.name,
        cfg.scheduler.policy,
        crate::util::units::fmt_bytes(cfg.cache.capacity_bytes)
    );
    let r = sim::run(cfg);
    crate::info!(
        "`{}`: WET {:.0}s, eff {:.0}%, {} events in {:.1}s wall",
        cfg.name,
        r.summary.workload_execution_time_s,
        r.summary.efficiency * 100.0,
        r.events_processed,
        r.sim_wall_s
    );
    r
}

/// The seven summary-view experiments of Figures 4–10, in figure order.
pub fn paper_experiment_set() -> Vec<ExperimentConfig> {
    (4..=10)
        .map(|fig| ExperimentConfig::paper_fig(fig).expect("known preset"))
        .collect()
}

/// Run the full Figure 4–10 set (the aggregate figures 11–15 reuse it),
/// fanned out across all cores.
pub fn run_paper_set() -> Vec<RunResult> {
    registry::run_configs(paper_experiment_set(), crate::util::par::default_jobs())
}

/// One-line-per-experiment summary table (the numbers §5.2 quotes).
pub fn summary_table(results: &[RunResult]) -> Table {
    let mut t = Table::new(
        "experiment summaries (paper §5.2)",
        &[
            "experiment",
            "WET(s)",
            "eff",
            "hit-local",
            "hit-global",
            "miss",
            "avgTP(Gb/s)",
            "peakTP(Gb/s)",
            "queue-max",
            "CPU-hrs",
            "avg-resp(s)",
        ],
    );
    for r in results {
        let s = &r.summary;
        t.row(vec![
            r.name.clone(),
            f(s.workload_execution_time_s, 0),
            pct(s.efficiency),
            pct(s.hit_local_rate),
            pct(s.hit_global_rate),
            pct(s.miss_rate),
            f(s.avg_throughput_gbps, 1),
            f(s.peak_throughput_gbps, 1),
            s.queue_max_len.to_string(),
            f(s.cpu_time_hours, 1),
            f(s.avg_response_time_s, 1),
        ]);
    }
    t
}

/// Render one run's per-second time series (the Figs 4–10 summary view),
/// sampled every `every_s` seconds.
pub fn summary_view_table(r: &RunResult, every_s: usize) -> Table {
    let mut t = Table::new(
        &format!("summary view: {}", r.name),
        &[
            "t(s)",
            "ideal(Gb/s)",
            "tp(Gb/s)",
            "local(Gb/s)",
            "remote(Gb/s)",
            "gpfs(Gb/s)",
            "nodes",
            "busy-cpus",
            "queue",
        ],
    );
    // The ideal throughput is the arrival rate times the file size — we
    // reconstruct it from arrivals (A·β per second).
    for (sec, b) in r.ts.buckets().iter().enumerate().step_by(every_s.max(1)) {
        let ideal = bps_to_gbps(b.arrivals as f64 * bytes_per_task(r));
        t.row(vec![
            sec.to_string(),
            f(ideal, 2),
            f(bps_to_gbps(b.bytes_total() as f64), 2),
            f(bps_to_gbps(b.bytes_local as f64), 2),
            f(bps_to_gbps(b.bytes_remote as f64), 2),
            f(bps_to_gbps(b.bytes_gpfs as f64), 2),
            b.nodes.to_string(),
            b.busy_slots.to_string(),
            b.queue_len.to_string(),
        ]);
    }
    t
}

fn bytes_per_task(r: &RunResult) -> f64 {
    let total: u64 = r
        .ts
        .buckets()
        .iter()
        .map(|b| b.bytes_local + b.bytes_remote + b.bytes_gpfs)
        .sum();
    if r.summary.tasks_completed > 0 {
        total as f64 / r.summary.tasks_completed as f64
    } else {
        0.0
    }
}

/// Per-source average/peak throughput decomposition used by Figure 12.
#[derive(Debug, Clone, Copy)]
pub struct ThroughputSplit {
    /// Mean Gb/s from local caches over active seconds.
    pub local_gbps: f64,
    /// Mean Gb/s from peer caches.
    pub remote_gbps: f64,
    /// Mean Gb/s from GPFS.
    pub gpfs_gbps: f64,
    /// 99th-percentile total Gb/s (the paper's "peak").
    pub peak_gbps: f64,
}

/// Compute the Figure 12 decomposition for one run.
pub fn throughput_split(r: &RunResult) -> ThroughputSplit {
    let active: Vec<&crate::metrics::Bucket> = r
        .ts
        .buckets()
        .iter()
        .filter(|b| b.bytes_total() > 0)
        .collect();
    let n = active.len().max(1) as f64;
    let mean_of = |sel: fn(&crate::metrics::Bucket) -> u64| -> f64 {
        bps_to_gbps(active.iter().map(|b| sel(b) as f64).sum::<f64>() / n)
    };
    let totals: Vec<f64> = r
        .ts
        .buckets()
        .iter()
        .map(|b| bps_to_gbps(b.bytes_total() as f64))
        .collect();
    ThroughputSplit {
        local_gbps: mean_of(|b| b.bytes_local),
        remote_gbps: mean_of(|b| b.bytes_remote),
        gpfs_gbps: mean_of(|b| b.bytes_gpfs),
        peak_gbps: crate::util::stats::percentile(&totals, 0.99),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ArrivalSpec;
    use crate::coordinator::scheduler::DispatchPolicy;
    use crate::util::units::MB;

    pub(crate) fn tiny_cfg(name: &str, policy: DispatchPolicy) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.name = name.into();
        cfg.cluster.max_nodes = 4;
        cfg.workload.num_tasks = 500;
        cfg.workload.num_files = 50;
        cfg.workload.file_size_bytes = 5 * MB;
        cfg.workload.arrival = ArrivalSpec::Constant(50.0);
        cfg.scheduler.policy = policy;
        cfg.cache.capacity_bytes = 1000 * MB;
        cfg
    }

    #[test]
    fn summary_table_has_row_per_result() {
        let r1 = run_summary_experiment(&tiny_cfg("a", DispatchPolicy::GoodCacheCompute));
        let r2 = run_summary_experiment(&tiny_cfg("b", DispatchPolicy::FirstAvailable));
        let t = summary_table(&[r1, r2]);
        assert_eq!(t.rows.len(), 2);
        assert_eq!(t.rows[0][0], "a");
    }

    #[test]
    fn summary_view_sampling() {
        let r = run_summary_experiment(&tiny_cfg("v", DispatchPolicy::GoodCacheCompute));
        let t = summary_view_table(&r, 5);
        assert!(!t.rows.is_empty());
        assert!(t.rows.len() <= r.ts.len() / 5 + 1);
    }

    #[test]
    fn throughput_split_sums_to_total() {
        let r = run_summary_experiment(&tiny_cfg("s", DispatchPolicy::GoodCacheCompute));
        let sp = throughput_split(&r);
        let total = sp.local_gbps + sp.remote_gbps + sp.gpfs_gbps;
        assert!(total > 0.0);
        assert!(sp.peak_gbps >= 0.0);
        // Average of the split equals the average computed over the same
        // active-second definition.
        let avg = r.summary.avg_throughput_gbps;
        assert!((total - avg).abs() / avg < 0.05, "split {total} vs avg {avg}");
    }
}
