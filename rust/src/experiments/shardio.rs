//! File-based shard fan-out/merge (ROADMAP item 1, step 1).
//!
//! A sharded run's per-shard [`Recorder`]s can leave the process as
//! JSON-lines snapshot envelopes ([`crate::metrics::snapshot`]) and be
//! recombined later — the transport seam a multi-process coordinator
//! deployment needs. [`emit_shards`] runs experiment configs through
//! the simulator and writes one `NAME.shard-I.jsonl` file per
//! coordinator shard; [`merge_dir`] reads a directory of envelopes back
//! and recombines each run via the lossless [`Recorder::absorb`], so a
//! merged run's summary is **bit-identical** to the same run merged
//! in-process (asserted by `rust/tests/integration.rs`).

use crate::config::{ConfigError, ExperimentConfig};
use crate::metrics::snapshot::{self, SnapshotMeta};
use crate::metrics::Recorder;
use crate::sim;
use crate::workload;
use crate::Result;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One run recombined from its per-shard snapshot envelopes.
#[derive(Debug)]
pub struct MergedRun {
    /// Experiment name (shared by all shard files of the run).
    pub name: String,
    /// Coordinator shard count the run was recorded with.
    pub shards: usize,
    /// Ideal workload execution time carried through the envelopes, so
    /// the merge side can summarize without re-deriving the workload.
    pub ideal_wet_s: f64,
    /// The losslessly recombined recorder.
    pub recorder: Recorder,
}

/// The engine's ideal-WET derivation (see `sim::engine::run`): scenario
/// workloads read it off the generated DAG, flat workloads keep the
/// closed form.
fn ideal_wet_s(cfg: &ExperimentConfig) -> f64 {
    if cfg.workload.scenario.is_some() {
        workload::generate(&cfg.workload, cfg.seed).ideal_execution_time_s()
    } else {
        workload::ideal_execution_time_s(&cfg.workload)
    }
}

/// Run each config and write one snapshot envelope per coordinator
/// shard into `dir` (created if missing) as `NAME.shard-I.jsonl`.
/// Returns the written paths in run order, shard-major.
pub fn emit_shards(cfgs: &[ExperimentConfig], dir: &Path) -> Result<Vec<PathBuf>> {
    std::fs::create_dir_all(dir)?;
    let mut written = Vec::new();
    for cfg in cfgs {
        let ideal = ideal_wet_s(cfg);
        let (result, shard_recs) = sim::run_with_shard_recorders(cfg);
        let k = shard_recs.len();
        crate::info!("`{}`: emitting {k} shard snapshot(s)", result.name);
        for (i, rec) in shard_recs.iter().enumerate() {
            let meta = SnapshotMeta {
                run: result.name.clone(),
                shard: i,
                shards: k,
                ideal_wet_s: ideal,
            };
            let path = dir.join(format!("{}.shard-{i}.jsonl", result.name));
            std::fs::write(&path, snapshot::to_jsonl(&meta, rec))?;
            written.push(path);
        }
    }
    Ok(written)
}

/// Read every `*.jsonl` envelope under `dir`, group by run name, and
/// recombine each run's shards with [`Recorder::absorb`]. Returns runs
/// in name order. Incomplete shard sets, duplicate shards, and
/// disagreeing metadata are typed [`ConfigError`]s, never panics.
pub fn merge_dir(dir: &Path) -> Result<Vec<MergedRun>> {
    let mut paths: Vec<PathBuf> = std::fs::read_dir(dir)?
        .collect::<std::io::Result<Vec<_>>>()?
        .into_iter()
        .map(|e| e.path())
        .filter(|p| p.extension().and_then(|e| e.to_str()) == Some("jsonl"))
        .collect();
    paths.sort();
    if paths.is_empty() {
        return Err(ConfigError::MissingKey {
            key: "*.jsonl".into(),
            context: format!("no shard snapshots in {}", dir.display()),
        }
        .into());
    }
    let mut runs: BTreeMap<String, Vec<(SnapshotMeta, Recorder)>> = BTreeMap::new();
    for path in &paths {
        let text = std::fs::read_to_string(path)?;
        let (meta, rec) = snapshot::from_jsonl(&text)?;
        runs.entry(meta.run.clone()).or_default().push((meta, rec));
    }
    let mut out = Vec::new();
    for (name, mut parts) in runs {
        parts.sort_by_key(|(m, _)| m.shard);
        let shards = parts[0].0.shards;
        let ideal_bits = parts[0].0.ideal_wet_s.to_bits();
        let ok = parts.len() == shards
            && parts.iter().enumerate().all(|(i, (m, _))| {
                m.shard == i && m.shards == shards && m.ideal_wet_s.to_bits() == ideal_bits
            });
        if !ok {
            let found: Vec<usize> = parts.iter().map(|(m, _)| m.shard).collect();
            return Err(ConfigError::Invariant {
                field: "snapshot set".into(),
                message: format!(
                    "run `{name}` promises {shards} shard(s) but the directory \
                     holds shards {found:?} (missing, duplicate, or mixed-run files)"
                ),
            }
            .into());
        }
        let mut recorder = Recorder::new();
        for (_, r) in parts {
            recorder.absorb(r);
        }
        out.push(MergedRun {
            name,
            shards,
            ideal_wet_s: f64::from_bits(ideal_bits),
            recorder,
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::scheduler::DispatchPolicy;
    use crate::experiments::tests::tiny_cfg;

    fn tmp(name: &str) -> PathBuf {
        let p = std::env::temp_dir().join(format!("dd-shardio-{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&p);
        p
    }

    #[test]
    fn emit_then_merge_matches_in_process_run() {
        let mut cfg = tiny_cfg("shardio-rt", DispatchPolicy::GoodCacheCompute);
        cfg.cluster.shards = 2;
        let dir = tmp("rt");
        let paths = emit_shards(std::slice::from_ref(&cfg), &dir).expect("emit");
        assert_eq!(paths.len(), 2, "one envelope per shard");
        let merged = merge_dir(&dir).expect("merge");
        assert_eq!(merged.len(), 1);
        let m = &merged[0];
        assert_eq!(m.name, "shardio-rt");
        assert_eq!(m.shards, 2);

        let reference = sim::run(&cfg);
        assert_eq!(m.recorder.access_counts(), reference.access_counts);
        let s = m.recorder.summarize(m.ideal_wet_s);
        assert_eq!(
            format!("{s:?}"),
            format!("{:?}", reference.summary),
            "file-merged summary must be bit-identical to the in-process one"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_shard_is_a_typed_error() {
        let mut cfg = tiny_cfg("shardio-miss", DispatchPolicy::FirstAvailable);
        cfg.cluster.shards = 2;
        let dir = tmp("miss");
        let paths = emit_shards(std::slice::from_ref(&cfg), &dir).expect("emit");
        std::fs::remove_file(&paths[1]).unwrap();
        let err = merge_dir(&dir).expect_err("incomplete set must fail");
        assert!(
            matches!(
                err,
                crate::Error::Config(ConfigError::Invariant { ref field, .. })
                    if field == "snapshot set"
            ),
            "unexpected error: {err:?}"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn empty_dir_is_a_typed_error() {
        let dir = tmp("empty");
        std::fs::create_dir_all(&dir).unwrap();
        let err = merge_dir(&dir).expect_err("empty dir must fail");
        assert!(matches!(
            err,
            crate::Error::Config(ConfigError::MissingKey { .. })
        ));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
