//! The §6 sweeps — the ablations the paper defers to future work.
//!
//! * **Eviction sweep**: LRU / LFU / FIFO / Random on the Figure 5
//!   configuration (1 GB caches — the thrashing regime, where eviction
//!   choice matters most).
//! * **Dispatch sweep**: all five dispatch policies at 4 GB caches
//!   (the Figure 8 configuration).
//!
//! Both are plain config lists + table renderers so the figure registry
//! fans the runs out with the rest of the suite and
//! `examples/policy_sweep.rs` stays a thin wrapper.

use crate::cache::EvictionPolicy;
use crate::config::ExperimentConfig;
use crate::coordinator::scheduler::DispatchPolicy;
use crate::report::{f, pct, Table};
use crate::sim::RunResult;

/// The four eviction policies, in sweep order.
pub const EVICTION_POLICIES: [EvictionPolicy; 4] = [
    EvictionPolicy::Lru,
    EvictionPolicy::Lfu,
    EvictionPolicy::Fifo,
    EvictionPolicy::Random,
];

fn scale_tasks(cfg: &mut ExperimentConfig, scale: f64) {
    cfg.workload.num_tasks = ((cfg.workload.num_tasks as f64 * scale) as u64).max(1_000);
}

/// Configs for the eviction-policy ablation at `scale`.
pub fn eviction_configs(scale: f64) -> Vec<ExperimentConfig> {
    EVICTION_POLICIES
        .iter()
        .map(|&policy| {
            let mut cfg = ExperimentConfig::paper_fig(5).expect("preset");
            cfg.name = format!("evict-{}", policy.name());
            cfg.cache.policy = policy;
            scale_tasks(&mut cfg, scale);
            cfg
        })
        .collect()
}

/// Render the eviction-ablation table from its runs (same order as
/// [`eviction_configs`]).
pub fn eviction_table(results: &[RunResult]) -> Table {
    let mut t = Table::new(
        "eviction-policy ablation (good-cache-compute, 1GB caches — paper future work §6)",
        &["eviction", "WET(s)", "efficiency", "hit-local", "miss"],
    );
    for (r, policy) in results.iter().zip(EVICTION_POLICIES.iter()) {
        t.row(vec![
            policy.name().into(),
            f(r.summary.workload_execution_time_s, 0),
            pct(r.summary.efficiency),
            pct(r.summary.hit_local_rate),
            pct(r.summary.miss_rate),
        ]);
    }
    t
}

/// Configs for the dispatch-policy sweep at `scale`.
pub fn dispatch_configs(scale: f64) -> Vec<ExperimentConfig> {
    DispatchPolicy::ALL
        .into_iter()
        .map(|policy| {
            let mut cfg = ExperimentConfig::paper_fig(8).expect("preset");
            cfg.name = format!("dispatch-{policy}");
            cfg.scheduler.policy = policy;
            scale_tasks(&mut cfg, scale);
            cfg
        })
        .collect()
}

/// Render the dispatch-sweep table from its runs (same order as
/// [`dispatch_configs`]).
pub fn dispatch_table(results: &[RunResult]) -> Table {
    let mut t = Table::new(
        "dispatch-policy sweep (4GB caches)",
        &[
            "policy",
            "WET(s)",
            "efficiency",
            "hit-local",
            "hit-global",
            "miss",
            "cpu-util",
        ],
    );
    for (r, policy) in results.iter().zip(DispatchPolicy::ALL.into_iter()) {
        t.row(vec![
            policy.name().into(),
            f(r.summary.workload_execution_time_s, 0),
            pct(r.summary.efficiency),
            pct(r.summary.hit_local_rate),
            pct(r.summary.hit_global_rate),
            pct(r.summary.miss_rate),
            pct(r.summary.avg_cpu_utilization),
        ]);
    }
    t
}

/// Registry entry for the eviction-policy ablation.
pub fn eviction_figure() -> crate::experiments::registry::Figure {
    use crate::experiments::registry::{Figure, FigureKind, SimSet};
    fn render(results: &[RunResult]) -> Vec<Table> {
        vec![eviction_table(results)]
    }
    Figure {
        id: "sweep-eviction",
        title: "Eviction sweep: LRU/LFU/FIFO/Random on 1GB caches (§6)",
        deterministic: true,
        kind: FigureKind::Sims {
            set: SimSet::Eviction,
            render,
        },
    }
}

/// Registry entry for the dispatch-policy sweep.
pub fn dispatch_figure() -> crate::experiments::registry::Figure {
    use crate::experiments::registry::{Figure, FigureKind, SimSet};
    fn render(results: &[RunResult]) -> Vec<Table> {
        vec![dispatch_table(results)]
    }
    Figure {
        id: "sweep-dispatch",
        title: "Dispatch sweep: all five policies at 4GB caches (§6)",
        deterministic: true,
        kind: FigureKind::Sims {
            set: SimSet::Dispatch,
            render,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::run_summary_experiment;

    #[test]
    fn configs_are_named_and_scaled() {
        let ev = eviction_configs(0.004); // clamps at the 1K-task floor
        assert_eq!(ev.len(), 4);
        assert_eq!(ev[0].name, "evict-lru");
        assert!(ev.iter().all(|c| c.workload.num_tasks == 1_000));
        let dp = dispatch_configs(0.004);
        assert_eq!(dp.len(), 5);
        assert!(dp[0].name.starts_with("dispatch-"));
    }

    #[test]
    fn tables_render_one_row_per_config() {
        let ev: Vec<RunResult> = eviction_configs(0.004)
            .iter()
            .map(run_summary_experiment)
            .collect();
        let t = eviction_table(&ev);
        assert_eq!(t.rows.len(), 4);
        assert_eq!(t.rows[0][0], "lru");
    }
}
