//! The §6 sweeps — the ablations the paper defers to future work.
//!
//! * **Eviction sweep**: LRU / LFU / FIFO / Random on the Figure 5
//!   configuration (1 GB caches — the thrashing regime, where eviction
//!   choice matters most).
//! * **Dispatch sweep**: all five dispatch policies at 4 GB caches
//!   (the Figure 8 configuration).
//! * **Allocation sweep**: all five provisioner allocation policies
//!   (one / add:8 / mult:2 / all / model) × the four scenario families —
//!   the divergence table ROADMAP item 2 asks for, and the benchmark
//!   that shows the closed-loop `model` controller matching the best
//!   static policy's performance index at a fraction of `all`'s
//!   node-seconds (docs/PROVISIONING.md).
//!
//! All are plain config lists + table renderers so the figure registry
//! fans the runs out with the rest of the suite and
//! `examples/policy_sweep.rs` stays a thin wrapper.

use crate::cache::EvictionPolicy;
use crate::config::{ExperimentConfig, ScenarioSpec};
use crate::coordinator::provisioner::AllocationPolicy;
use crate::coordinator::scheduler::DispatchPolicy;
use crate::report::{f, pct, Table};
use crate::sim::RunResult;

/// The four eviction policies, in sweep order.
pub const EVICTION_POLICIES: [EvictionPolicy; 4] = [
    EvictionPolicy::Lru,
    EvictionPolicy::Lfu,
    EvictionPolicy::Fifo,
    EvictionPolicy::Random,
];

fn scale_tasks(cfg: &mut ExperimentConfig, scale: f64) {
    cfg.workload.num_tasks = ((cfg.workload.num_tasks as f64 * scale) as u64).max(1_000);
}

/// Configs for the eviction-policy ablation at `scale`.
pub fn eviction_configs(scale: f64) -> Vec<ExperimentConfig> {
    EVICTION_POLICIES
        .iter()
        .map(|&policy| {
            let mut cfg = ExperimentConfig::paper_fig(5).expect("preset");
            cfg.name = format!("evict-{}", policy.name());
            cfg.cache.policy = policy;
            scale_tasks(&mut cfg, scale);
            cfg
        })
        .collect()
}

/// Render the eviction-ablation table from its runs (same order as
/// [`eviction_configs`]).
pub fn eviction_table(results: &[RunResult]) -> Table {
    let mut t = Table::new(
        "eviction-policy ablation (good-cache-compute, 1GB caches — paper future work §6)",
        &["eviction", "WET(s)", "efficiency", "hit-local", "miss"],
    );
    for (r, policy) in results.iter().zip(EVICTION_POLICIES.iter()) {
        t.row(vec![
            policy.name().into(),
            f(r.summary.workload_execution_time_s, 0),
            pct(r.summary.efficiency),
            pct(r.summary.hit_local_rate),
            pct(r.summary.miss_rate),
        ]);
    }
    t
}

/// Configs for the dispatch-policy sweep at `scale`.
pub fn dispatch_configs(scale: f64) -> Vec<ExperimentConfig> {
    DispatchPolicy::ALL
        .into_iter()
        .map(|policy| {
            let mut cfg = ExperimentConfig::paper_fig(8).expect("preset");
            cfg.name = format!("dispatch-{policy}");
            cfg.scheduler.policy = policy;
            scale_tasks(&mut cfg, scale);
            cfg
        })
        .collect()
}

/// Render the dispatch-sweep table from its runs (same order as
/// [`dispatch_configs`]).
pub fn dispatch_table(results: &[RunResult]) -> Table {
    let mut t = Table::new(
        "dispatch-policy sweep (4GB caches)",
        &[
            "policy",
            "WET(s)",
            "efficiency",
            "hit-local",
            "hit-global",
            "miss",
            "cpu-util",
        ],
    );
    for (r, policy) in results.iter().zip(DispatchPolicy::ALL.into_iter()) {
        t.row(vec![
            policy.name().into(),
            f(r.summary.workload_execution_time_s, 0),
            pct(r.summary.efficiency),
            pct(r.summary.hit_local_rate),
            pct(r.summary.hit_global_rate),
            pct(r.summary.miss_rate),
            pct(r.summary.avg_cpu_utilization),
        ]);
    }
    t
}

/// The five allocation policies, in sweep order. `one` comes first so
/// each scenario family's first run doubles as the speedup/PI baseline.
pub const ALLOCATION_POLICIES: [(&str, AllocationPolicy); 5] = [
    ("one", AllocationPolicy::OneAtATime),
    ("add:8", AllocationPolicy::Additive(8)),
    ("mult:2", AllocationPolicy::Multiplicative(2.0)),
    ("all", AllocationPolicy::AllAtOnce),
    ("model", AllocationPolicy::Model),
];

/// Node-seconds a run held registered capacity for: the per-second
/// fleet-size series integrated at 1 Hz — the provisioning *cost* axis
/// of the divergence table (CPU-hours scales it by `cpus_per_node`).
pub fn node_seconds(r: &RunResult) -> u64 {
    r.ts.buckets().iter().map(|b| u64::from(b.nodes)).sum()
}

/// Configs for the allocation divergence sweep at `scale`:
/// family-major over [`ScenarioSpec::CATALOG`], then
/// [`ALLOCATION_POLICIES`] within each family (20 runs).
pub fn allocation_configs(scale: f64) -> Vec<ExperimentConfig> {
    let mut out = Vec::new();
    for name in ScenarioSpec::CATALOG {
        let spec = ScenarioSpec::preset(name).expect("catalog name");
        for (label, policy) in ALLOCATION_POLICIES {
            let mut cfg = crate::experiments::scenarios::scenario_config(&spec, scale, 1);
            cfg.name = format!("alloc-{name}-{label}");
            cfg.provisioner.allocation = policy;
            out.push(cfg);
        }
    }
    out
}

/// Render the allocation divergence table from its runs (same order as
/// [`allocation_configs`]). Speedup and PI are measured against each
/// family's own `one` run, so the columns compare provisioning policies
/// on identical workloads, not workloads against each other.
pub fn allocation_table(results: &[RunResult]) -> Table {
    let mut t = Table::new(
        "allocation divergence: 5 provisioning policies x 4 scenario families (seed 42)",
        &[
            "family",
            "allocation",
            "WET(s)",
            "node-sec",
            "cpu-h",
            "speedup",
            "PI",
            "efficiency",
        ],
    );
    for (fam_i, name) in ScenarioSpec::CATALOG.iter().enumerate() {
        let base = fam_i * ALLOCATION_POLICIES.len();
        let baseline_wet = results[base].summary.workload_execution_time_s;
        for (j, (label, _)) in ALLOCATION_POLICIES.iter().enumerate() {
            let r = &results[base + j];
            t.row(vec![
                (*name).into(),
                (*label).into(),
                f(r.summary.workload_execution_time_s, 1),
                node_seconds(r).to_string(),
                f(r.summary.cpu_time_hours, 3),
                f(r.summary.speedup_vs(baseline_wet), 2),
                f(r.summary.performance_index_raw(baseline_wet), 2),
                pct(r.summary.efficiency),
            ]);
        }
    }
    t
}

// `FigureKind::Standalone` carries a non-capturing fn pointer.
fn run_allocation(scale: f64, jobs: usize) -> Vec<Table> {
    let results = crate::experiments::registry::run_configs(allocation_configs(scale), jobs);
    vec![allocation_table(&results)]
}

/// Registry entry for the allocation divergence sweep.
pub fn allocation_figure() -> crate::experiments::registry::Figure {
    use crate::experiments::registry::{Figure, FigureKind};
    Figure {
        id: "sweep-allocation",
        title: "Allocation sweep: one/add/mult/all/model x 4 scenario families",
        deterministic: true,
        kind: FigureKind::Standalone(run_allocation),
    }
}

/// Registry entry for the eviction-policy ablation.
pub fn eviction_figure() -> crate::experiments::registry::Figure {
    use crate::experiments::registry::{Figure, FigureKind, SimSet};
    fn render(results: &[RunResult]) -> Vec<Table> {
        vec![eviction_table(results)]
    }
    Figure {
        id: "sweep-eviction",
        title: "Eviction sweep: LRU/LFU/FIFO/Random on 1GB caches (§6)",
        deterministic: true,
        kind: FigureKind::Sims {
            set: SimSet::Eviction,
            render,
        },
    }
}

/// Registry entry for the dispatch-policy sweep.
pub fn dispatch_figure() -> crate::experiments::registry::Figure {
    use crate::experiments::registry::{Figure, FigureKind, SimSet};
    fn render(results: &[RunResult]) -> Vec<Table> {
        vec![dispatch_table(results)]
    }
    Figure {
        id: "sweep-dispatch",
        title: "Dispatch sweep: all five policies at 4GB caches (§6)",
        deterministic: true,
        kind: FigureKind::Sims {
            set: SimSet::Dispatch,
            render,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::run_summary_experiment;

    #[test]
    fn configs_are_named_and_scaled() {
        let ev = eviction_configs(0.004); // clamps at the 1K-task floor
        assert_eq!(ev.len(), 4);
        assert_eq!(ev[0].name, "evict-lru");
        assert!(ev.iter().all(|c| c.workload.num_tasks == 1_000));
        let dp = dispatch_configs(0.004);
        assert_eq!(dp.len(), 5);
        assert!(dp[0].name.starts_with("dispatch-"));
        let al = allocation_configs(0.004);
        assert_eq!(al.len(), 20, "4 families x 5 allocation policies");
        assert_eq!(al[0].name, "alloc-zipf-churn-one");
        assert_eq!(
            al[4].provisioner.allocation,
            AllocationPolicy::Model,
            "model closes each family's block"
        );
        for c in &al {
            c.validate().unwrap();
        }
    }

    #[test]
    fn tables_render_one_row_per_config() {
        let ev: Vec<RunResult> = eviction_configs(0.004)
            .iter()
            .map(run_summary_experiment)
            .collect();
        let t = eviction_table(&ev);
        assert_eq!(t.rows.len(), 4);
        assert_eq!(t.rows[0][0], "lru");
    }
}
