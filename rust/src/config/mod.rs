//! Typed experiment configuration.
//!
//! Every run of the system — CLI, examples, tests, figure benches — is
//! described by an [`ExperimentConfig`], loadable from a TOML-subset file
//! ([`toml_lite`]) or constructed from the paper presets
//! ([`ExperimentConfig::paper_fig`]). Defaults are the calibration
//! constants from DESIGN.md §6 (all taken from the paper's text).

pub mod toml_lite;

use crate::cache::{CacheConfig, EvictionPolicy};
use crate::coordinator::provisioner::{AllocationPolicy, ProvisionerConfig};
use crate::coordinator::scheduler::{DispatchPolicy, SchedulerConfig};
use crate::util::units::{GB, MB};
use crate::{Error, Result};
use std::fmt;
use toml_lite::Document;

/// A typed configuration error: which field, which value, what was
/// expected. [`Error::Config`] wraps this, so every config failure —
/// TOML loading, validation, CLI flag parsing — renders uniformly.
#[derive(Debug, Clone, PartialEq)]
pub enum ConfigError {
    /// A key outside the schema (typos fail loudly).
    UnknownKey {
        /// The offending key.
        key: String,
    },
    /// A key whose value failed to parse or is outside its domain.
    InvalidValue {
        /// Dotted key or flag name.
        key: String,
        /// The offending value, verbatim.
        value: String,
        /// What would have been accepted.
        expected: String,
    },
    /// A key another setting requires is absent.
    MissingKey {
        /// The absent key.
        key: String,
        /// Which setting needs it.
        context: String,
    },
    /// A cross-field invariant violation from [`ExperimentConfig::validate`].
    Invariant {
        /// Field (dotted path) the invariant is anchored to.
        field: String,
        /// Human-readable violation.
        message: String,
    },
    /// TOML-subset syntax error from [`toml_lite`].
    Toml(String),
    /// Free-form configuration error (CLI usage and similar callers).
    Message(String),
}

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ConfigError::UnknownKey { key } => write!(f, "unknown config key `{key}`"),
            ConfigError::InvalidValue {
                key,
                value,
                expected,
            } => write!(f, "invalid value `{value}` for `{key}`: expected {expected}"),
            ConfigError::MissingKey { key, context } => {
                write!(f, "missing key `{key}`: required by {context}")
            }
            ConfigError::Invariant { field, message } => write!(f, "{field}: {message}"),
            ConfigError::Toml(m) => write!(f, "TOML: {m}"),
            ConfigError::Message(m) => f.write_str(m),
        }
    }
}

impl From<ConfigError> for Error {
    fn from(e: ConfigError) -> Error {
        Error::Config(e)
    }
}

/// Physical testbed parameters (the simulated ANL/UC TeraGrid site).
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Maximum provisionable nodes (paper: 64).
    pub max_nodes: usize,
    /// CPUs (task slots) per node (paper: 2 — "2 per node, 1 per CPU").
    pub cpus_per_node: usize,
    /// GPFS aggregate sustained read bandwidth, Gb/s (paper: ≈4).
    pub gpfs_gbps: f64,
    /// Per-node local-disk read bandwidth, Gb/s (sized so 64 nodes peak
    /// near the paper's 100 Gb/s aggregate).
    pub local_disk_gbps: f64,
    /// Per-node NIC bandwidth for peer cache transfers, Gb/s.
    pub nic_gbps: f64,
    /// Dispatcher↔executor network latency, milliseconds (paper: 2 ms).
    pub net_latency_ms: f64,
    /// GRAM/LRM resource-allocation latency bounds, seconds (paper: 30–60).
    pub gram_latency_s: (f64, f64),
    /// Dispatcher service time per scheduling decision, microseconds —
    /// caps dispatch throughput like Falkon's single service instance
    /// (paper §5.1: 1322–2981 decisions/s → 335–760 µs each).
    pub dispatch_service_us: f64,
    /// Per-transfer session setup cost for *peer* cache fetches,
    /// milliseconds — each remote read opens a GridFTP session to the
    /// holder's server (§3.1.1); this is why max-compute-util's heavy
    /// remote traffic loses to good-cache-compute despite 100% CPU
    /// utilization (§5.2.1, Fig 10 discussion).
    pub peer_overhead_ms: f64,
    /// Coordinator shards K: the dispatch state machine is replicated
    /// K ways behind a router
    /// ([`crate::coordinator::shard::ShardedCoordinator`]), with the
    /// task stream partitioned by dominant-file hash and one dispatcher
    /// service instance per shard. 1 (the default) is the paper's
    /// single-coordinator deployment and is bit-identical to a bare
    /// core; see `docs/SHARDING.md`.
    pub shards: usize,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            max_nodes: 64,
            cpus_per_node: 2,
            gpfs_gbps: 4.4,
            local_disk_gbps: 1.6,
            nic_gbps: 1.0,
            net_latency_ms: 2.0,
            gram_latency_s: (30.0, 60.0),
            dispatch_service_us: 600.0,
            peer_overhead_ms: 60.0,
            shards: 1,
        }
    }
}

/// How task arrival times are generated.
#[derive(Debug, Clone, PartialEq)]
pub enum ArrivalSpec {
    /// The paper's §5.2 schedule: `A_i = min(ceil(A_{i-1}·factor), max)`,
    /// one interval per `interval_s` seconds, until `num_tasks` tasks.
    IncreasingRate {
        /// Initial arrival rate, tasks/sec (paper: 1).
        initial: f64,
        /// Multiplicative increase per interval (paper: 1.3).
        factor: f64,
        /// Seconds between increases (paper: 60).
        interval_s: f64,
        /// Arrival-rate ceiling, tasks/sec (paper: 1000).
        max_rate: f64,
    },
    /// Constant arrival rate, tasks/sec.
    Constant(f64),
    /// All tasks arrive at t = 0 (batch submission; scheduler microbench).
    Batch,
}

/// How tasks pick the file(s) they read.
#[derive(Debug, Clone, PartialEq)]
pub enum AccessSpec {
    /// Uniformly random file per task (the paper's §5 workloads).
    Uniform,
    /// Zipf-distributed popularity with exponent `s`.
    Zipf(f64),
    /// Astronomy-style locality: each file is accessed `locality` times;
    /// accesses are shuffled within a bounded reordering window, matching
    /// the paper's "locality of 1 … 30" workload definition (Fig 2).
    Locality(f64),
}

/// A workload scenario from the scenario library
/// (`rust/src/workload/scenarios/`; catalog in `docs/WORKLOADS.md`).
///
/// When [`WorkloadConfig::scenario`] is set, the scenario's own arrival
/// and access model replaces [`ArrivalSpec`]/[`AccessSpec`]; task count,
/// catalog size, file size, and compute time still come from the
/// surrounding [`WorkloadConfig`]. Each variant has a named preset
/// ([`ScenarioSpec::preset`]) whose parameters TOML `scenario.*` keys
/// override.
#[derive(Debug, Clone, PartialEq)]
pub enum ScenarioSpec {
    /// Zipf popularity by rank with the rank→file map rewired every
    /// churn interval.
    ZipfChurn {
        /// Zipf exponent over ranks.
        s: f64,
        /// Seconds between hot-set rewires.
        churn_interval_s: f64,
        /// Fraction of the catalog rewired per churn (the hot head).
        churn_fraction: f64,
        /// Constant arrival rate, tasks/s.
        rate: f64,
    },
    /// Diurnal multi-user traffic with seeded flash crowds.
    Diurnal {
        /// Simulated user population size.
        users: u32,
        /// Day/night cycle length, seconds.
        period_s: f64,
        /// Rate at the cycle peak, tasks/s.
        peak_rate: f64,
        /// Rate at the cycle trough, tasks/s.
        trough_rate: f64,
        /// Number of flash-crowd windows.
        flash_crowds: u32,
        /// Rate multiplier inside a flash window.
        flash_factor: f64,
        /// Flash window length, seconds.
        flash_duration_s: f64,
    },
    /// DIANA-style at-once batch submission over per-batch datasets.
    BulkBatch {
        /// Number of batches.
        batches: u32,
        /// Seconds between batch submissions.
        batch_gap_s: f64,
    },
    /// Pilot-Data-style fan-in pipelines (outputs feed downstream
    /// inputs; dependency edges gate submission).
    Pipeline {
        /// Stages per pipeline.
        stages: u32,
        /// Stage-0 width; later stages halve it.
        fanin: u32,
        /// Seconds between pipeline submissions.
        submit_gap_s: f64,
    },
}

impl ScenarioSpec {
    /// Every scenario family's preset name, in catalog order.
    pub const CATALOG: [&'static str; 4] =
        ["zipf-churn", "diurnal", "bulk-batch", "pipeline"];

    /// The family's preset name.
    pub fn name(&self) -> &'static str {
        match self {
            ScenarioSpec::ZipfChurn { .. } => "zipf-churn",
            ScenarioSpec::Diurnal { .. } => "diurnal",
            ScenarioSpec::BulkBatch { .. } => "bulk-batch",
            ScenarioSpec::Pipeline { .. } => "pipeline",
        }
    }

    /// Default parameters for a named family (hyphens and underscores
    /// both accepted). `None` for names outside [`Self::CATALOG`].
    pub fn preset(name: &str) -> Option<ScenarioSpec> {
        match name.replace('_', "-").as_str() {
            "zipf-churn" => Some(ScenarioSpec::ZipfChurn {
                s: 1.1,
                churn_interval_s: 4.0,
                churn_fraction: 0.1,
                rate: 250.0,
            }),
            "diurnal" => Some(ScenarioSpec::Diurnal {
                users: 64,
                period_s: 60.0,
                peak_rate: 50.0,
                trough_rate: 5.0,
                flash_crowds: 2,
                flash_factor: 4.0,
                flash_duration_s: 10.0,
            }),
            "bulk-batch" => Some(ScenarioSpec::BulkBatch {
                batches: 8,
                batch_gap_s: 30.0,
            }),
            "pipeline" => Some(ScenarioSpec::Pipeline {
                stages: 3,
                fanin: 4,
                submit_gap_s: 0.05,
            }),
            _ => None,
        }
    }

    /// Apply `scenario.*` overrides from a parsed document.
    fn apply_overrides(&mut self, doc: &Document) {
        match self {
            ScenarioSpec::ZipfChurn {
                s,
                churn_interval_s,
                churn_fraction,
                rate,
            } => {
                if let Some(v) = doc.get_float("scenario.zipf_s") {
                    *s = v;
                }
                if let Some(v) = doc.get_float("scenario.churn_interval_s") {
                    *churn_interval_s = v;
                }
                if let Some(v) = doc.get_float("scenario.churn_fraction") {
                    *churn_fraction = v;
                }
                if let Some(v) = doc.get_float("scenario.rate") {
                    *rate = v;
                }
            }
            ScenarioSpec::Diurnal {
                users,
                period_s,
                peak_rate,
                trough_rate,
                flash_crowds,
                flash_factor,
                flash_duration_s,
            } => {
                if let Some(v) = doc.get_int("scenario.users") {
                    *users = v as u32;
                }
                if let Some(v) = doc.get_float("scenario.period_s") {
                    *period_s = v;
                }
                if let Some(v) = doc.get_float("scenario.peak_rate") {
                    *peak_rate = v;
                }
                if let Some(v) = doc.get_float("scenario.trough_rate") {
                    *trough_rate = v;
                }
                if let Some(v) = doc.get_int("scenario.flash_crowds") {
                    *flash_crowds = v as u32;
                }
                if let Some(v) = doc.get_float("scenario.flash_factor") {
                    *flash_factor = v;
                }
                if let Some(v) = doc.get_float("scenario.flash_duration_s") {
                    *flash_duration_s = v;
                }
            }
            ScenarioSpec::BulkBatch {
                batches,
                batch_gap_s,
            } => {
                if let Some(v) = doc.get_int("scenario.batches") {
                    *batches = v as u32;
                }
                if let Some(v) = doc.get_float("scenario.batch_gap_s") {
                    *batch_gap_s = v;
                }
            }
            ScenarioSpec::Pipeline {
                stages,
                fanin,
                submit_gap_s,
            } => {
                if let Some(v) = doc.get_int("scenario.stages") {
                    *stages = v as u32;
                }
                if let Some(v) = doc.get_int("scenario.fanin") {
                    *fanin = v as u32;
                }
                if let Some(v) = doc.get_float("scenario.submit_gap_s") {
                    *submit_gap_s = v;
                }
            }
        }
    }
}

impl fmt::Display for ScenarioSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Workload description (task count, dataset, arrival, access pattern).
#[derive(Debug, Clone)]
pub struct WorkloadConfig {
    /// Total tasks |K| (paper: 250 000).
    pub num_tasks: u64,
    /// Dataset size in files (paper: 10 000).
    pub num_files: u32,
    /// Bytes per file (paper: 10 MB; scheduler microbench: 1 B).
    pub file_size_bytes: u64,
    /// Per-task compute time μ(κ), milliseconds (paper: 10 ms).
    pub compute_ms: f64,
    /// Arrival process (ignored when a scenario is configured).
    pub arrival: ArrivalSpec,
    /// File access pattern (ignored when a scenario is configured).
    pub access: AccessSpec,
    /// Scenario-library workload; `None` is the paper's generator,
    /// bit-identical to its pre-scenario form.
    pub scenario: Option<ScenarioSpec>,
}

impl Default for WorkloadConfig {
    /// The §5.2 provisioning workload, verbatim.
    fn default() -> Self {
        WorkloadConfig {
            num_tasks: 250_000,
            num_files: 10_000,
            file_size_bytes: 10 * MB,
            compute_ms: 10.0,
            arrival: ArrivalSpec::IncreasingRate {
                initial: 1.0,
                factor: 1.3,
                interval_s: 60.0,
                max_rate: 1000.0,
            },
            access: AccessSpec::Uniform,
            scenario: None,
        }
    }
}

/// Full experiment description.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// Human-readable experiment name (report headers, CSV filenames).
    pub name: String,
    /// PRNG seed; every run with the same config+seed is bit-identical.
    pub seed: u64,
    /// Testbed parameters.
    pub cluster: ClusterConfig,
    /// Workload parameters.
    pub workload: WorkloadConfig,
    /// Scheduler policy and tuning.
    pub scheduler: SchedulerConfig,
    /// Dynamic-resource-provisioner policy and tuning.
    pub provisioner: ProvisionerConfig,
    /// Per-executor cache sizing and eviction.
    pub cache: CacheConfig,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            name: "default".into(),
            seed: 42,
            cluster: ClusterConfig::default(),
            workload: WorkloadConfig::default(),
            scheduler: SchedulerConfig::default(),
            provisioner: ProvisionerConfig::default(),
            cache: CacheConfig::lru(4 * GB),
        }
    }
}

impl ExperimentConfig {
    /// Preset for a paper figure's experiment (4–10 are the summary-view
    /// experiments; the aggregate figures 11–15 reuse those runs).
    ///
    /// | fig | policy | cache/node |
    /// |-----|--------|------------|
    /// | 4 | first-available (GPFS only) | — |
    /// | 5 | good-cache-compute | 1 GB |
    /// | 6 | good-cache-compute | 1.5 GB |
    /// | 7 | good-cache-compute | 2 GB |
    /// | 8 | good-cache-compute | 4 GB |
    /// | 9 | max-cache-hit | 4 GB |
    /// | 10 | max-compute-util | 4 GB |
    pub fn paper_fig(fig: u32) -> Option<ExperimentConfig> {
        let (name, policy, cache_bytes) = match fig {
            4 => ("fig04-first-available-gpfs", DispatchPolicy::FirstAvailable, 0),
            5 => ("fig05-gcc-1gb", DispatchPolicy::GoodCacheCompute, GB),
            6 => ("fig06-gcc-1.5gb", DispatchPolicy::GoodCacheCompute, 3 * GB / 2),
            7 => ("fig07-gcc-2gb", DispatchPolicy::GoodCacheCompute, 2 * GB),
            8 => ("fig08-gcc-4gb", DispatchPolicy::GoodCacheCompute, 4 * GB),
            9 => ("fig09-mch-4gb", DispatchPolicy::MaxCacheHit, 4 * GB),
            10 => ("fig10-mcu-4gb", DispatchPolicy::MaxComputeUtil, 4 * GB),
            _ => return None,
        };
        let mut cfg = ExperimentConfig {
            name: name.into(),
            ..ExperimentConfig::default()
        };
        cfg.scheduler.policy = policy;
        cfg.cache = CacheConfig::lru(cache_bytes.max(1)); // first-available never caches
        Some(cfg)
    }

    /// The paper's ideal workload execution time for this workload
    /// (infinite resources, zero-cost communication) — §5.2.5's 1415 s.
    pub fn ideal_wet_s(&self) -> f64 {
        crate::workload::ideal_execution_time_s(&self.workload)
    }

    /// Parse from TOML-subset text. Unknown keys are rejected so typos in
    /// experiment files fail loudly.
    pub fn from_toml(text: &str) -> Result<ExperimentConfig> {
        let doc = Document::parse(text).map_err(|e| Error::Config(ConfigError::Toml(e)))?;
        let mut cfg = ExperimentConfig::default();

        const KNOWN: &[&str] = &[
            "name",
            "seed",
            "cluster.max_nodes",
            "cluster.cpus_per_node",
            "cluster.gpfs_gbps",
            "cluster.local_disk_gbps",
            "cluster.nic_gbps",
            "cluster.net_latency_ms",
            "cluster.gram_latency_min_s",
            "cluster.gram_latency_max_s",
            "cluster.dispatch_service_us",
            "cluster.peer_overhead_ms",
            "cluster.shards",
            "workload.num_tasks",
            "workload.num_files",
            "workload.file_size_mb",
            "workload.compute_ms",
            "workload.arrival",
            "workload.arrival_initial",
            "workload.arrival_factor",
            "workload.arrival_interval_s",
            "workload.arrival_max_rate",
            "workload.arrival_rate",
            "workload.access",
            "workload.zipf_s",
            "workload.locality",
            "workload.scenario",
            "scenario.zipf_s",
            "scenario.churn_interval_s",
            "scenario.churn_fraction",
            "scenario.rate",
            "scenario.users",
            "scenario.period_s",
            "scenario.peak_rate",
            "scenario.trough_rate",
            "scenario.flash_crowds",
            "scenario.flash_factor",
            "scenario.flash_duration_s",
            "scenario.batches",
            "scenario.batch_gap_s",
            "scenario.stages",
            "scenario.fanin",
            "scenario.submit_gap_s",
            "scheduler.policy",
            "scheduler.window_multiplier",
            "scheduler.cpu_util_threshold",
            "scheduler.max_replication",
            "scheduler.max_tasks_per_pickup",
            "provisioner.allocation",
            "provisioner.allocation_increment",
            "provisioner.allocation_factor",
            "provisioner.idle_release_s",
            "provisioner.static",
            "provisioner.initial_nodes",
            "cache.capacity_gb",
            "cache.policy",
        ];
        for key in doc.keys() {
            if !KNOWN.contains(&key) {
                return Err(ConfigError::UnknownKey { key: key.into() }.into());
            }
        }

        if let Some(name) = doc.get_str("name") {
            cfg.name = name.to_string();
        }
        if let Some(seed) = doc.get_int("seed") {
            cfg.seed = seed as u64;
        }

        // [cluster]
        let c = &mut cfg.cluster;
        if let Some(v) = doc.get_int("cluster.max_nodes") {
            c.max_nodes = v as usize;
        }
        if let Some(v) = doc.get_int("cluster.cpus_per_node") {
            c.cpus_per_node = v as usize;
        }
        if let Some(v) = doc.get_float("cluster.gpfs_gbps") {
            c.gpfs_gbps = v;
        }
        if let Some(v) = doc.get_float("cluster.local_disk_gbps") {
            c.local_disk_gbps = v;
        }
        if let Some(v) = doc.get_float("cluster.nic_gbps") {
            c.nic_gbps = v;
        }
        if let Some(v) = doc.get_float("cluster.net_latency_ms") {
            c.net_latency_ms = v;
        }
        if let Some(v) = doc.get_float("cluster.gram_latency_min_s") {
            c.gram_latency_s.0 = v;
        }
        if let Some(v) = doc.get_float("cluster.gram_latency_max_s") {
            c.gram_latency_s.1 = v;
        }
        if let Some(v) = doc.get_float("cluster.dispatch_service_us") {
            c.dispatch_service_us = v;
        }
        if let Some(v) = doc.get_float("cluster.peer_overhead_ms") {
            c.peer_overhead_ms = v;
        }
        if let Some(v) = doc.get_int("cluster.shards") {
            c.shards = v as usize;
        }

        // [workload]
        let w = &mut cfg.workload;
        if let Some(v) = doc.get_int("workload.num_tasks") {
            w.num_tasks = v as u64;
        }
        if let Some(v) = doc.get_int("workload.num_files") {
            w.num_files = v as u32;
        }
        if let Some(v) = doc.get_float("workload.file_size_mb") {
            w.file_size_bytes = (v * MB as f64) as u64;
        }
        if let Some(v) = doc.get_float("workload.compute_ms") {
            w.compute_ms = v;
        }
        match doc.get_str("workload.arrival") {
            None | Some("increasing") => {
                if let ArrivalSpec::IncreasingRate {
                    initial,
                    factor,
                    interval_s,
                    max_rate,
                } = &mut w.arrival
                {
                    if let Some(v) = doc.get_float("workload.arrival_initial") {
                        *initial = v;
                    }
                    if let Some(v) = doc.get_float("workload.arrival_factor") {
                        *factor = v;
                    }
                    if let Some(v) = doc.get_float("workload.arrival_interval_s") {
                        *interval_s = v;
                    }
                    if let Some(v) = doc.get_float("workload.arrival_max_rate") {
                        *max_rate = v;
                    }
                }
            }
            Some("constant") => {
                let rate = doc.get_float("workload.arrival_rate").ok_or_else(|| {
                    ConfigError::MissingKey {
                        key: "workload.arrival_rate".into(),
                        context: "workload.arrival = \"constant\"".into(),
                    }
                })?;
                w.arrival = ArrivalSpec::Constant(rate);
            }
            Some("batch") => w.arrival = ArrivalSpec::Batch,
            Some(other) => {
                return Err(ConfigError::InvalidValue {
                    key: "workload.arrival".into(),
                    value: other.into(),
                    expected: "increasing, constant, or batch".into(),
                }
                .into());
            }
        }
        match doc.get_str("workload.access") {
            None | Some("uniform") => w.access = AccessSpec::Uniform,
            Some("zipf") => {
                let s = doc.get_float("workload.zipf_s").unwrap_or(1.0);
                w.access = AccessSpec::Zipf(s);
            }
            Some("locality") => {
                let l = doc.get_float("workload.locality").ok_or_else(|| {
                    ConfigError::MissingKey {
                        key: "workload.locality".into(),
                        context: "workload.access = \"locality\"".into(),
                    }
                })?;
                w.access = AccessSpec::Locality(l);
            }
            Some(other) => {
                return Err(ConfigError::InvalidValue {
                    key: "workload.access".into(),
                    value: other.into(),
                    expected: "uniform, zipf, or locality".into(),
                }
                .into());
            }
        }
        if let Some(name) = doc.get_str("workload.scenario") {
            let mut spec = ScenarioSpec::preset(name).ok_or_else(|| ConfigError::InvalidValue {
                key: "workload.scenario".into(),
                value: name.into(),
                expected: format!("one of {}", ScenarioSpec::CATALOG.join(", ")),
            })?;
            spec.apply_overrides(&doc);
            w.scenario = Some(spec);
        }

        // [scheduler]
        let s = &mut cfg.scheduler;
        if let Some(p) = doc.get_str("scheduler.policy") {
            s.policy = DispatchPolicy::parse(p).ok_or_else(|| ConfigError::InvalidValue {
                key: "scheduler.policy".into(),
                value: p.into(),
                expected: "a dispatch policy name (see docs)".into(),
            })?;
        }
        if let Some(v) = doc.get_int("scheduler.window_multiplier") {
            s.window_multiplier = v as usize;
        }
        if let Some(v) = doc.get_float("scheduler.cpu_util_threshold") {
            s.cpu_util_threshold = v;
        }
        if let Some(v) = doc.get_int("scheduler.max_replication") {
            s.max_replication = v as usize;
        }
        if let Some(v) = doc.get_int("scheduler.max_tasks_per_pickup") {
            s.max_tasks_per_pickup = v as usize;
        }

        // [provisioner]
        let p = &mut cfg.provisioner;
        match doc.get_str("provisioner.allocation") {
            None => {}
            Some("one") => p.allocation = AllocationPolicy::OneAtATime,
            Some("additive") => {
                let inc = doc.get_int("provisioner.allocation_increment").unwrap_or(8) as usize;
                p.allocation = AllocationPolicy::Additive(inc);
            }
            Some("multiplicative") => {
                let f = doc.get_float("provisioner.allocation_factor").unwrap_or(2.0);
                p.allocation = AllocationPolicy::Multiplicative(f);
            }
            Some("all") => p.allocation = AllocationPolicy::AllAtOnce,
            Some("model") => p.allocation = AllocationPolicy::Model,
            Some(other) => {
                return Err(ConfigError::InvalidValue {
                    key: "provisioner.allocation".into(),
                    value: other.into(),
                    expected: "one, additive, multiplicative, all, or model".into(),
                }
                .into());
            }
        }
        if let Some(v) = doc.get_float("provisioner.idle_release_s") {
            p.idle_release_s = v;
        }
        if let Some(v) = doc.get_bool("provisioner.static") {
            p.static_provisioning = v;
        }
        if let Some(v) = doc.get_int("provisioner.initial_nodes") {
            p.initial_nodes = v as usize;
        }

        // [cache]
        if let Some(v) = doc.get_float("cache.capacity_gb") {
            cfg.cache.capacity_bytes = (v * GB as f64) as u64;
        }
        if let Some(v) = doc.get_str("cache.policy") {
            cfg.cache.policy = EvictionPolicy::parse(v).ok_or_else(|| ConfigError::InvalidValue {
                key: "cache.policy".into(),
                value: v.into(),
                expected: "random, fifo, lru, or lfu".into(),
            })?;
        }

        cfg.validate()?;
        Ok(cfg)
    }

    /// Load from a file path.
    pub fn from_file(path: &std::path::Path) -> Result<ExperimentConfig> {
        let text = std::fs::read_to_string(path)?;
        Self::from_toml(&text)
    }

    /// Sanity-check invariants; returns a typed
    /// [`ConfigError::Invariant`] (field + violation) on the first one
    /// broken.
    pub fn validate(&self) -> Result<()> {
        let fail = |field: &str, message: String| {
            Err(Error::Config(ConfigError::Invariant {
                field: field.into(),
                message,
            }))
        };
        if self.cluster.max_nodes == 0 {
            return fail("cluster.max_nodes", "must be ≥ 1".into());
        }
        if self.cluster.cpus_per_node == 0 {
            return fail("cluster.cpus_per_node", "must be ≥ 1".into());
        }
        for (name, v) in [
            ("cluster.gpfs_gbps", self.cluster.gpfs_gbps),
            ("cluster.local_disk_gbps", self.cluster.local_disk_gbps),
            ("cluster.nic_gbps", self.cluster.nic_gbps),
        ] {
            if v <= 0.0 {
                return fail(name, format!("must be > 0, got {v}"));
            }
        }
        if self.cluster.gram_latency_s.0 > self.cluster.gram_latency_s.1 {
            return fail("cluster.gram_latency_s", "min > max".into());
        }
        if self.workload.num_tasks == 0 || self.workload.num_files == 0 {
            return fail("workload", "must have tasks and files".into());
        }
        if self.workload.compute_ms < 0.0 {
            return fail(
                "workload.compute_ms",
                format!("must be ≥ 0, got {}", self.workload.compute_ms),
            );
        }
        match self.workload.arrival {
            ArrivalSpec::IncreasingRate {
                initial,
                factor,
                interval_s,
                max_rate,
            } => {
                if initial <= 0.0 || factor <= 1.0 || interval_s <= 0.0 || max_rate < initial {
                    return fail(
                        "workload.arrival",
                        "invalid increasing-rate parameters".into(),
                    );
                }
            }
            ArrivalSpec::Constant(rate) => {
                if rate <= 0.0 {
                    return fail("workload.arrival_rate", format!("must be > 0, got {rate}"));
                }
            }
            ArrivalSpec::Batch => {}
        }
        if let AccessSpec::Locality(l) = self.workload.access {
            if l < 1.0 {
                return fail("workload.locality", format!("must be ≥ 1, got {l}"));
            }
        }
        self.validate_scenario()?;
        if !(0.0..=1.0).contains(&self.scheduler.cpu_util_threshold) {
            return fail(
                "scheduler.cpu_util_threshold",
                format!(
                    "must be in [0,1], got {}",
                    self.scheduler.cpu_util_threshold
                ),
            );
        }
        if self.scheduler.max_tasks_per_pickup == 0 {
            return fail("scheduler.max_tasks_per_pickup", "must be ≥ 1".into());
        }
        if self.scheduler.policy != DispatchPolicy::FirstAvailable
            && self.cache.capacity_bytes < self.workload.file_size_bytes
        {
            return fail(
                "cache.capacity_gb",
                format!(
                    "cache capacity {} cannot hold even one file of {}",
                    self.cache.capacity_bytes, self.workload.file_size_bytes
                ),
            );
        }
        if self.provisioner.initial_nodes > self.cluster.max_nodes {
            return fail(
                "provisioner.initial_nodes",
                format!(
                    "{} > cluster.max_nodes ({})",
                    self.provisioner.initial_nodes, self.cluster.max_nodes
                ),
            );
        }
        if self.cluster.shards == 0 {
            return fail("cluster.shards", "must be ≥ 1".into());
        }
        if self.cluster.shards > self.cluster.max_nodes {
            return fail(
                "cluster.shards",
                format!(
                    "({}) > cluster.max_nodes ({}): a shard with a zero node \
                     quota could never run its tasks",
                    self.cluster.shards, self.cluster.max_nodes
                ),
            );
        }
        if self.cluster.shards > 1
            && self.provisioner.static_provisioning
            && self.provisioner.initial_nodes < self.cluster.shards
        {
            return fail(
                "provisioner.initial_nodes",
                format!(
                    "static provisioning with {} initial nodes across {} shards \
                     leaves node-less shards that can never grow",
                    self.provisioner.initial_nodes, self.cluster.shards
                ),
            );
        }
        Ok(())
    }

    /// Scenario-parameter invariants (a no-op for legacy workloads).
    fn validate_scenario(&self) -> Result<()> {
        let fail = |field: &str, message: String| {
            Err(Error::Config(ConfigError::Invariant {
                field: field.into(),
                message,
            }))
        };
        match &self.workload.scenario {
            None => Ok(()),
            Some(ScenarioSpec::ZipfChurn {
                s,
                churn_interval_s,
                churn_fraction,
                rate,
            }) => {
                if *s < 0.0 {
                    return fail("scenario.zipf_s", format!("must be ≥ 0, got {s}"));
                }
                if *churn_interval_s <= 0.0 {
                    return fail(
                        "scenario.churn_interval_s",
                        format!("must be > 0, got {churn_interval_s}"),
                    );
                }
                if !(0.0..=1.0).contains(churn_fraction) {
                    return fail(
                        "scenario.churn_fraction",
                        format!("must be in [0,1], got {churn_fraction}"),
                    );
                }
                if *rate <= 0.0 {
                    return fail("scenario.rate", format!("must be > 0, got {rate}"));
                }
                Ok(())
            }
            Some(ScenarioSpec::Diurnal {
                users,
                period_s,
                peak_rate,
                trough_rate,
                flash_factor,
                flash_duration_s,
                ..
            }) => {
                if *users == 0 {
                    return fail("scenario.users", "must be ≥ 1".into());
                }
                if *period_s <= 0.0 {
                    return fail("scenario.period_s", format!("must be > 0, got {period_s}"));
                }
                if *trough_rate <= 0.0 || peak_rate < trough_rate {
                    return fail(
                        "scenario.peak_rate",
                        format!("need 0 < trough ({trough_rate}) ≤ peak ({peak_rate})"),
                    );
                }
                if *flash_factor < 1.0 {
                    return fail(
                        "scenario.flash_factor",
                        format!("must be ≥ 1, got {flash_factor}"),
                    );
                }
                if *flash_duration_s < 0.0 {
                    return fail(
                        "scenario.flash_duration_s",
                        format!("must be ≥ 0, got {flash_duration_s}"),
                    );
                }
                Ok(())
            }
            Some(ScenarioSpec::BulkBatch {
                batches,
                batch_gap_s,
            }) => {
                if *batches == 0 {
                    return fail("scenario.batches", "must be ≥ 1".into());
                }
                if *batch_gap_s < 0.0 {
                    return fail(
                        "scenario.batch_gap_s",
                        format!("must be ≥ 0, got {batch_gap_s}"),
                    );
                }
                Ok(())
            }
            Some(ScenarioSpec::Pipeline {
                stages,
                fanin,
                submit_gap_s,
            }) => {
                if *stages == 0 {
                    return fail("scenario.stages", "must be ≥ 1".into());
                }
                if *fanin == 0 {
                    return fail("scenario.fanin", "must be ≥ 1".into());
                }
                if *submit_gap_s <= 0.0 {
                    return fail(
                        "scenario.submit_gap_s",
                        format!("must be > 0, got {submit_gap_s}"),
                    );
                }
                Ok(())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid_and_matches_paper() {
        let cfg = ExperimentConfig::default();
        cfg.validate().unwrap();
        assert_eq!(cfg.cluster.max_nodes, 64);
        assert_eq!(cfg.workload.num_tasks, 250_000);
        assert_eq!(cfg.workload.file_size_bytes, 10 * MB);
        // Ideal WET from the arrival function ≈ 1415 s (§5.2).
        let wet = cfg.ideal_wet_s();
        assert!((wet - 1415.0).abs() < 30.0, "ideal WET = {wet}");
    }

    #[test]
    fn shard_count_is_validated() {
        let mut cfg = ExperimentConfig::default();
        cfg.cluster.shards = 4;
        cfg.validate().unwrap();
        cfg.cluster.shards = 0;
        assert!(cfg.validate().is_err(), "zero shards");
        cfg.cluster.shards = cfg.cluster.max_nodes + 1;
        assert!(cfg.validate().is_err(), "more shards than nodes");
        cfg.cluster.shards = 4;
        cfg.provisioner = ProvisionerConfig::static_nodes(2);
        assert!(cfg.validate().is_err(), "static fleet smaller than K");
        cfg.provisioner = ProvisionerConfig::static_nodes(4);
        cfg.validate().unwrap();
    }

    #[test]
    fn shards_parse_from_toml() {
        let cfg = ExperimentConfig::from_toml("[cluster]\nshards = 4\n").unwrap();
        assert_eq!(cfg.cluster.shards, 4);
        assert!(ExperimentConfig::from_toml("[cluster]\nshards = 0\n").is_err());
    }

    #[test]
    fn paper_fig_presets() {
        for fig in 4..=10 {
            let cfg = ExperimentConfig::paper_fig(fig).unwrap();
            cfg.validate().unwrap();
        }
        assert!(ExperimentConfig::paper_fig(3).is_none());
        let f7 = ExperimentConfig::paper_fig(7).unwrap();
        assert_eq!(f7.cache.capacity_bytes, 2 * GB);
        assert_eq!(f7.scheduler.policy, DispatchPolicy::GoodCacheCompute);
    }

    #[test]
    fn toml_round_trip() {
        let cfg = ExperimentConfig::from_toml(
            r#"
            name = "custom"
            seed = 7
            [cluster]
            max_nodes = 32
            gpfs_gbps = 8.0
            [workload]
            num_tasks = 1000
            file_size_mb = 1.0
            access = "zipf"
            zipf_s = 1.1
            [scheduler]
            policy = "max-cache-hit"
            [cache]
            capacity_gb = 0.5
            policy = "lfu"
            "#,
        )
        .unwrap();
        assert_eq!(cfg.name, "custom");
        assert_eq!(cfg.cluster.max_nodes, 32);
        assert_eq!(cfg.workload.num_tasks, 1000);
        assert_eq!(cfg.workload.access, AccessSpec::Zipf(1.1));
        assert_eq!(cfg.scheduler.policy, DispatchPolicy::MaxCacheHit);
        assert_eq!(cfg.cache.policy, EvictionPolicy::Lfu);
    }

    #[test]
    fn model_allocation_parses_from_toml() {
        let cfg =
            ExperimentConfig::from_toml("[provisioner]\nallocation = \"model\"\n").unwrap();
        assert_eq!(cfg.provisioner.allocation, AllocationPolicy::Model);
        let err = ExperimentConfig::from_toml("[provisioner]\nallocation = \"bogus\"\n")
            .unwrap_err();
        assert!(
            err.to_string().contains("model"),
            "rejection lists the model policy: {err}"
        );
    }

    #[test]
    fn unknown_key_rejected() {
        let err = ExperimentConfig::from_toml("typo_key = 1").unwrap_err();
        assert!(err.to_string().contains("unknown config key"));
    }

    #[test]
    fn invalid_values_rejected() {
        assert!(ExperimentConfig::from_toml("[cluster]\ngpfs_gbps = -1.0").is_err());
        assert!(ExperimentConfig::from_toml("[scheduler]\npolicy = \"bogus\"").is_err());
        assert!(ExperimentConfig::from_toml("[workload]\narrival = \"constant\"").is_err());
    }

    #[test]
    fn scenario_parses_from_toml_with_overrides() {
        let cfg = ExperimentConfig::from_toml(
            "[workload]\nscenario = \"zipf-churn\"\n[scenario]\nzipf_s = 0.9\nrate = 100.0\n",
        )
        .unwrap();
        match cfg.workload.scenario {
            Some(ScenarioSpec::ZipfChurn { s, rate, .. }) => {
                assert_eq!(s, 0.9);
                assert_eq!(rate, 100.0);
            }
            other => panic!("wrong scenario: {other:?}"),
        }
        // Underscores are accepted in family names; unknown names fail.
        assert!(
            ExperimentConfig::from_toml("[workload]\nscenario = \"bulk_batch\"\n").is_ok()
        );
        let err =
            ExperimentConfig::from_toml("[workload]\nscenario = \"nope\"\n").unwrap_err();
        match err {
            Error::Config(ConfigError::InvalidValue { key, value, .. }) => {
                assert_eq!(key, "workload.scenario");
                assert_eq!(value, "nope");
            }
            other => panic!("untyped error: {other:?}"),
        }
    }

    #[test]
    fn config_errors_are_typed() {
        match ExperimentConfig::from_toml("typo_key = 1").unwrap_err() {
            Error::Config(ConfigError::UnknownKey { key }) => assert_eq!(key, "typo_key"),
            other => panic!("untyped error: {other:?}"),
        }
        match ExperimentConfig::from_toml("[cluster]\ngpfs_gbps = -1.0").unwrap_err() {
            Error::Config(ConfigError::Invariant { field, message }) => {
                assert_eq!(field, "cluster.gpfs_gbps");
                assert!(message.contains("-1"), "offending value in message: {message}");
            }
            other => panic!("untyped error: {other:?}"),
        }
        match ExperimentConfig::from_toml("[scheduler]\npolicy = \"bogus\"").unwrap_err() {
            Error::Config(ConfigError::InvalidValue { key, value, .. }) => {
                assert_eq!(key, "scheduler.policy");
                assert_eq!(value, "bogus");
            }
            other => panic!("untyped error: {other:?}"),
        }
    }

    #[test]
    fn scenario_params_validated() {
        let mut cfg = ExperimentConfig::default();
        cfg.workload.scenario = Some(ScenarioSpec::ZipfChurn {
            s: 1.0,
            churn_interval_s: 0.0,
            churn_fraction: 0.1,
            rate: 10.0,
        });
        assert!(cfg.validate().is_err(), "zero churn interval");
        cfg.workload.scenario = Some(ScenarioSpec::Pipeline {
            stages: 0,
            fanin: 4,
            submit_gap_s: 1.0,
        });
        assert!(cfg.validate().is_err(), "zero stages");
        for name in ScenarioSpec::CATALOG {
            cfg.workload.scenario = ScenarioSpec::preset(name);
            cfg.validate().unwrap();
        }
    }
}
