//! Typed experiment configuration.
//!
//! Every run of the system — CLI, examples, tests, figure benches — is
//! described by an [`ExperimentConfig`], loadable from a TOML-subset file
//! ([`toml_lite`]) or constructed from the paper presets
//! ([`ExperimentConfig::paper_fig`]). Defaults are the calibration
//! constants from DESIGN.md §6 (all taken from the paper's text).

pub mod toml_lite;

use crate::cache::{CacheConfig, EvictionPolicy};
use crate::coordinator::provisioner::{AllocationPolicy, ProvisionerConfig};
use crate::coordinator::scheduler::{DispatchPolicy, SchedulerConfig};
use crate::util::units::{GB, MB};
use crate::{Error, Result};
use toml_lite::Document;

/// Physical testbed parameters (the simulated ANL/UC TeraGrid site).
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Maximum provisionable nodes (paper: 64).
    pub max_nodes: usize,
    /// CPUs (task slots) per node (paper: 2 — "2 per node, 1 per CPU").
    pub cpus_per_node: usize,
    /// GPFS aggregate sustained read bandwidth, Gb/s (paper: ≈4).
    pub gpfs_gbps: f64,
    /// Per-node local-disk read bandwidth, Gb/s (sized so 64 nodes peak
    /// near the paper's 100 Gb/s aggregate).
    pub local_disk_gbps: f64,
    /// Per-node NIC bandwidth for peer cache transfers, Gb/s.
    pub nic_gbps: f64,
    /// Dispatcher↔executor network latency, milliseconds (paper: 2 ms).
    pub net_latency_ms: f64,
    /// GRAM/LRM resource-allocation latency bounds, seconds (paper: 30–60).
    pub gram_latency_s: (f64, f64),
    /// Dispatcher service time per scheduling decision, microseconds —
    /// caps dispatch throughput like Falkon's single service instance
    /// (paper §5.1: 1322–2981 decisions/s → 335–760 µs each).
    pub dispatch_service_us: f64,
    /// Per-transfer session setup cost for *peer* cache fetches,
    /// milliseconds — each remote read opens a GridFTP session to the
    /// holder's server (§3.1.1); this is why max-compute-util's heavy
    /// remote traffic loses to good-cache-compute despite 100% CPU
    /// utilization (§5.2.1, Fig 10 discussion).
    pub peer_overhead_ms: f64,
    /// Coordinator shards K: the dispatch state machine is replicated
    /// K ways behind a router
    /// ([`crate::coordinator::shard::ShardedCoordinator`]), with the
    /// task stream partitioned by dominant-file hash and one dispatcher
    /// service instance per shard. 1 (the default) is the paper's
    /// single-coordinator deployment and is bit-identical to a bare
    /// core; see `docs/SHARDING.md`.
    pub shards: usize,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            max_nodes: 64,
            cpus_per_node: 2,
            gpfs_gbps: 4.4,
            local_disk_gbps: 1.6,
            nic_gbps: 1.0,
            net_latency_ms: 2.0,
            gram_latency_s: (30.0, 60.0),
            dispatch_service_us: 600.0,
            peer_overhead_ms: 60.0,
            shards: 1,
        }
    }
}

/// How task arrival times are generated.
#[derive(Debug, Clone, PartialEq)]
pub enum ArrivalSpec {
    /// The paper's §5.2 schedule: `A_i = min(ceil(A_{i-1}·factor), max)`,
    /// one interval per `interval_s` seconds, until `num_tasks` tasks.
    IncreasingRate {
        /// Initial arrival rate, tasks/sec (paper: 1).
        initial: f64,
        /// Multiplicative increase per interval (paper: 1.3).
        factor: f64,
        /// Seconds between increases (paper: 60).
        interval_s: f64,
        /// Arrival-rate ceiling, tasks/sec (paper: 1000).
        max_rate: f64,
    },
    /// Constant arrival rate, tasks/sec.
    Constant(f64),
    /// All tasks arrive at t = 0 (batch submission; scheduler microbench).
    Batch,
}

/// How tasks pick the file(s) they read.
#[derive(Debug, Clone, PartialEq)]
pub enum AccessSpec {
    /// Uniformly random file per task (the paper's §5 workloads).
    Uniform,
    /// Zipf-distributed popularity with exponent `s`.
    Zipf(f64),
    /// Astronomy-style locality: each file is accessed `locality` times;
    /// accesses are shuffled within a bounded reordering window, matching
    /// the paper's "locality of 1 … 30" workload definition (Fig 2).
    Locality(f64),
}

/// Workload description (task count, dataset, arrival, access pattern).
#[derive(Debug, Clone)]
pub struct WorkloadConfig {
    /// Total tasks |K| (paper: 250 000).
    pub num_tasks: u64,
    /// Dataset size in files (paper: 10 000).
    pub num_files: u32,
    /// Bytes per file (paper: 10 MB; scheduler microbench: 1 B).
    pub file_size_bytes: u64,
    /// Per-task compute time μ(κ), milliseconds (paper: 10 ms).
    pub compute_ms: f64,
    /// Arrival process.
    pub arrival: ArrivalSpec,
    /// File access pattern.
    pub access: AccessSpec,
}

impl Default for WorkloadConfig {
    /// The §5.2 provisioning workload, verbatim.
    fn default() -> Self {
        WorkloadConfig {
            num_tasks: 250_000,
            num_files: 10_000,
            file_size_bytes: 10 * MB,
            compute_ms: 10.0,
            arrival: ArrivalSpec::IncreasingRate {
                initial: 1.0,
                factor: 1.3,
                interval_s: 60.0,
                max_rate: 1000.0,
            },
            access: AccessSpec::Uniform,
        }
    }
}

/// Full experiment description.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// Human-readable experiment name (report headers, CSV filenames).
    pub name: String,
    /// PRNG seed; every run with the same config+seed is bit-identical.
    pub seed: u64,
    /// Testbed parameters.
    pub cluster: ClusterConfig,
    /// Workload parameters.
    pub workload: WorkloadConfig,
    /// Scheduler policy and tuning.
    pub scheduler: SchedulerConfig,
    /// Dynamic-resource-provisioner policy and tuning.
    pub provisioner: ProvisionerConfig,
    /// Per-executor cache sizing and eviction.
    pub cache: CacheConfig,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            name: "default".into(),
            seed: 42,
            cluster: ClusterConfig::default(),
            workload: WorkloadConfig::default(),
            scheduler: SchedulerConfig::default(),
            provisioner: ProvisionerConfig::default(),
            cache: CacheConfig::lru(4 * GB),
        }
    }
}

impl ExperimentConfig {
    /// Preset for a paper figure's experiment (4–10 are the summary-view
    /// experiments; the aggregate figures 11–15 reuse those runs).
    ///
    /// | fig | policy | cache/node |
    /// |-----|--------|------------|
    /// | 4 | first-available (GPFS only) | — |
    /// | 5 | good-cache-compute | 1 GB |
    /// | 6 | good-cache-compute | 1.5 GB |
    /// | 7 | good-cache-compute | 2 GB |
    /// | 8 | good-cache-compute | 4 GB |
    /// | 9 | max-cache-hit | 4 GB |
    /// | 10 | max-compute-util | 4 GB |
    pub fn paper_fig(fig: u32) -> Option<ExperimentConfig> {
        let (name, policy, cache_bytes) = match fig {
            4 => ("fig04-first-available-gpfs", DispatchPolicy::FirstAvailable, 0),
            5 => ("fig05-gcc-1gb", DispatchPolicy::GoodCacheCompute, GB),
            6 => ("fig06-gcc-1.5gb", DispatchPolicy::GoodCacheCompute, 3 * GB / 2),
            7 => ("fig07-gcc-2gb", DispatchPolicy::GoodCacheCompute, 2 * GB),
            8 => ("fig08-gcc-4gb", DispatchPolicy::GoodCacheCompute, 4 * GB),
            9 => ("fig09-mch-4gb", DispatchPolicy::MaxCacheHit, 4 * GB),
            10 => ("fig10-mcu-4gb", DispatchPolicy::MaxComputeUtil, 4 * GB),
            _ => return None,
        };
        let mut cfg = ExperimentConfig {
            name: name.into(),
            ..ExperimentConfig::default()
        };
        cfg.scheduler.policy = policy;
        cfg.cache = CacheConfig::lru(cache_bytes.max(1)); // first-available never caches
        Some(cfg)
    }

    /// The paper's ideal workload execution time for this workload
    /// (infinite resources, zero-cost communication) — §5.2.5's 1415 s.
    pub fn ideal_wet_s(&self) -> f64 {
        crate::workload::ideal_execution_time_s(&self.workload)
    }

    /// Parse from TOML-subset text. Unknown keys are rejected so typos in
    /// experiment files fail loudly.
    pub fn from_toml(text: &str) -> Result<ExperimentConfig> {
        let doc = Document::parse(text).map_err(Error::Config)?;
        let mut cfg = ExperimentConfig::default();

        const KNOWN: &[&str] = &[
            "name",
            "seed",
            "cluster.max_nodes",
            "cluster.cpus_per_node",
            "cluster.gpfs_gbps",
            "cluster.local_disk_gbps",
            "cluster.nic_gbps",
            "cluster.net_latency_ms",
            "cluster.gram_latency_min_s",
            "cluster.gram_latency_max_s",
            "cluster.dispatch_service_us",
            "cluster.peer_overhead_ms",
            "cluster.shards",
            "workload.num_tasks",
            "workload.num_files",
            "workload.file_size_mb",
            "workload.compute_ms",
            "workload.arrival",
            "workload.arrival_initial",
            "workload.arrival_factor",
            "workload.arrival_interval_s",
            "workload.arrival_max_rate",
            "workload.arrival_rate",
            "workload.access",
            "workload.zipf_s",
            "workload.locality",
            "scheduler.policy",
            "scheduler.window_multiplier",
            "scheduler.cpu_util_threshold",
            "scheduler.max_replication",
            "scheduler.max_tasks_per_pickup",
            "provisioner.allocation",
            "provisioner.allocation_increment",
            "provisioner.allocation_factor",
            "provisioner.idle_release_s",
            "provisioner.static",
            "provisioner.initial_nodes",
            "cache.capacity_gb",
            "cache.policy",
        ];
        for key in doc.keys() {
            if !KNOWN.contains(&key) {
                return Err(Error::Config(format!("unknown config key `{key}`")));
            }
        }

        if let Some(name) = doc.get_str("name") {
            cfg.name = name.to_string();
        }
        if let Some(seed) = doc.get_int("seed") {
            cfg.seed = seed as u64;
        }

        // [cluster]
        let c = &mut cfg.cluster;
        if let Some(v) = doc.get_int("cluster.max_nodes") {
            c.max_nodes = v as usize;
        }
        if let Some(v) = doc.get_int("cluster.cpus_per_node") {
            c.cpus_per_node = v as usize;
        }
        if let Some(v) = doc.get_float("cluster.gpfs_gbps") {
            c.gpfs_gbps = v;
        }
        if let Some(v) = doc.get_float("cluster.local_disk_gbps") {
            c.local_disk_gbps = v;
        }
        if let Some(v) = doc.get_float("cluster.nic_gbps") {
            c.nic_gbps = v;
        }
        if let Some(v) = doc.get_float("cluster.net_latency_ms") {
            c.net_latency_ms = v;
        }
        if let Some(v) = doc.get_float("cluster.gram_latency_min_s") {
            c.gram_latency_s.0 = v;
        }
        if let Some(v) = doc.get_float("cluster.gram_latency_max_s") {
            c.gram_latency_s.1 = v;
        }
        if let Some(v) = doc.get_float("cluster.dispatch_service_us") {
            c.dispatch_service_us = v;
        }
        if let Some(v) = doc.get_float("cluster.peer_overhead_ms") {
            c.peer_overhead_ms = v;
        }
        if let Some(v) = doc.get_int("cluster.shards") {
            c.shards = v as usize;
        }

        // [workload]
        let w = &mut cfg.workload;
        if let Some(v) = doc.get_int("workload.num_tasks") {
            w.num_tasks = v as u64;
        }
        if let Some(v) = doc.get_int("workload.num_files") {
            w.num_files = v as u32;
        }
        if let Some(v) = doc.get_float("workload.file_size_mb") {
            w.file_size_bytes = (v * MB as f64) as u64;
        }
        if let Some(v) = doc.get_float("workload.compute_ms") {
            w.compute_ms = v;
        }
        match doc.get_str("workload.arrival") {
            None | Some("increasing") => {
                if let ArrivalSpec::IncreasingRate {
                    initial,
                    factor,
                    interval_s,
                    max_rate,
                } = &mut w.arrival
                {
                    if let Some(v) = doc.get_float("workload.arrival_initial") {
                        *initial = v;
                    }
                    if let Some(v) = doc.get_float("workload.arrival_factor") {
                        *factor = v;
                    }
                    if let Some(v) = doc.get_float("workload.arrival_interval_s") {
                        *interval_s = v;
                    }
                    if let Some(v) = doc.get_float("workload.arrival_max_rate") {
                        *max_rate = v;
                    }
                }
            }
            Some("constant") => {
                let rate = doc
                    .get_float("workload.arrival_rate")
                    .ok_or_else(|| Error::Config("constant arrival needs workload.arrival_rate".into()))?;
                w.arrival = ArrivalSpec::Constant(rate);
            }
            Some("batch") => w.arrival = ArrivalSpec::Batch,
            Some(other) => {
                return Err(Error::Config(format!("unknown arrival spec `{other}`")));
            }
        }
        match doc.get_str("workload.access") {
            None | Some("uniform") => w.access = AccessSpec::Uniform,
            Some("zipf") => {
                let s = doc.get_float("workload.zipf_s").unwrap_or(1.0);
                w.access = AccessSpec::Zipf(s);
            }
            Some("locality") => {
                let l = doc
                    .get_float("workload.locality")
                    .ok_or_else(|| Error::Config("locality access needs workload.locality".into()))?;
                w.access = AccessSpec::Locality(l);
            }
            Some(other) => {
                return Err(Error::Config(format!("unknown access spec `{other}`")));
            }
        }

        // [scheduler]
        let s = &mut cfg.scheduler;
        if let Some(p) = doc.get_str("scheduler.policy") {
            s.policy = DispatchPolicy::parse(p)
                .ok_or_else(|| Error::Config(format!("unknown dispatch policy `{p}`")))?;
        }
        if let Some(v) = doc.get_int("scheduler.window_multiplier") {
            s.window_multiplier = v as usize;
        }
        if let Some(v) = doc.get_float("scheduler.cpu_util_threshold") {
            s.cpu_util_threshold = v;
        }
        if let Some(v) = doc.get_int("scheduler.max_replication") {
            s.max_replication = v as usize;
        }
        if let Some(v) = doc.get_int("scheduler.max_tasks_per_pickup") {
            s.max_tasks_per_pickup = v as usize;
        }

        // [provisioner]
        let p = &mut cfg.provisioner;
        match doc.get_str("provisioner.allocation") {
            None => {}
            Some("one") => p.allocation = AllocationPolicy::OneAtATime,
            Some("additive") => {
                let inc = doc.get_int("provisioner.allocation_increment").unwrap_or(8) as usize;
                p.allocation = AllocationPolicy::Additive(inc);
            }
            Some("multiplicative") => {
                let f = doc.get_float("provisioner.allocation_factor").unwrap_or(2.0);
                p.allocation = AllocationPolicy::Multiplicative(f);
            }
            Some("all") => p.allocation = AllocationPolicy::AllAtOnce,
            Some(other) => {
                return Err(Error::Config(format!("unknown allocation policy `{other}`")));
            }
        }
        if let Some(v) = doc.get_float("provisioner.idle_release_s") {
            p.idle_release_s = v;
        }
        if let Some(v) = doc.get_bool("provisioner.static") {
            p.static_provisioning = v;
        }
        if let Some(v) = doc.get_int("provisioner.initial_nodes") {
            p.initial_nodes = v as usize;
        }

        // [cache]
        if let Some(v) = doc.get_float("cache.capacity_gb") {
            cfg.cache.capacity_bytes = (v * GB as f64) as u64;
        }
        if let Some(v) = doc.get_str("cache.policy") {
            cfg.cache.policy = EvictionPolicy::parse(v)
                .ok_or_else(|| Error::Config(format!("unknown eviction policy `{v}`")))?;
        }

        cfg.validate()?;
        Ok(cfg)
    }

    /// Load from a file path.
    pub fn from_file(path: &std::path::Path) -> Result<ExperimentConfig> {
        let text = std::fs::read_to_string(path)?;
        Self::from_toml(&text)
    }

    /// Sanity-check invariants; returns a config error on violation.
    pub fn validate(&self) -> Result<()> {
        let fail = |msg: String| Err(Error::Config(msg));
        if self.cluster.max_nodes == 0 {
            return fail("cluster.max_nodes must be ≥ 1".into());
        }
        if self.cluster.cpus_per_node == 0 {
            return fail("cluster.cpus_per_node must be ≥ 1".into());
        }
        for (name, v) in [
            ("gpfs_gbps", self.cluster.gpfs_gbps),
            ("local_disk_gbps", self.cluster.local_disk_gbps),
            ("nic_gbps", self.cluster.nic_gbps),
        ] {
            if v <= 0.0 {
                return fail(format!("cluster.{name} must be > 0"));
            }
        }
        if self.cluster.gram_latency_s.0 > self.cluster.gram_latency_s.1 {
            return fail("gram latency min > max".into());
        }
        if self.workload.num_tasks == 0 || self.workload.num_files == 0 {
            return fail("workload must have tasks and files".into());
        }
        if self.workload.compute_ms < 0.0 {
            return fail("workload.compute_ms must be ≥ 0".into());
        }
        match self.workload.arrival {
            ArrivalSpec::IncreasingRate {
                initial,
                factor,
                interval_s,
                max_rate,
            } => {
                if initial <= 0.0 || factor <= 1.0 || interval_s <= 0.0 || max_rate < initial {
                    return fail("invalid increasing-rate arrival parameters".into());
                }
            }
            ArrivalSpec::Constant(rate) => {
                if rate <= 0.0 {
                    return fail("constant arrival rate must be > 0".into());
                }
            }
            ArrivalSpec::Batch => {}
        }
        if let AccessSpec::Locality(l) = self.workload.access {
            if l < 1.0 {
                return fail("locality must be ≥ 1".into());
            }
        }
        if !(0.0..=1.0).contains(&self.scheduler.cpu_util_threshold) {
            return fail("cpu_util_threshold must be in [0,1]".into());
        }
        if self.scheduler.max_tasks_per_pickup == 0 {
            return fail("max_tasks_per_pickup must be ≥ 1".into());
        }
        if self.scheduler.policy != DispatchPolicy::FirstAvailable
            && self.cache.capacity_bytes < self.workload.file_size_bytes
        {
            return fail(format!(
                "cache capacity {} cannot hold even one file of {}",
                self.cache.capacity_bytes, self.workload.file_size_bytes
            ));
        }
        if self.provisioner.initial_nodes > self.cluster.max_nodes {
            return fail("provisioner.initial_nodes > cluster.max_nodes".into());
        }
        if self.cluster.shards == 0 {
            return fail("cluster.shards must be ≥ 1".into());
        }
        if self.cluster.shards > self.cluster.max_nodes {
            return fail(format!(
                "cluster.shards ({}) > cluster.max_nodes ({}): a shard with a \
                 zero node quota could never run its tasks",
                self.cluster.shards, self.cluster.max_nodes
            ));
        }
        if self.cluster.shards > 1
            && self.provisioner.static_provisioning
            && self.provisioner.initial_nodes < self.cluster.shards
        {
            return fail(format!(
                "static provisioning with {} initial nodes across {} shards \
                 leaves node-less shards that can never grow",
                self.provisioner.initial_nodes, self.cluster.shards
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid_and_matches_paper() {
        let cfg = ExperimentConfig::default();
        cfg.validate().unwrap();
        assert_eq!(cfg.cluster.max_nodes, 64);
        assert_eq!(cfg.workload.num_tasks, 250_000);
        assert_eq!(cfg.workload.file_size_bytes, 10 * MB);
        // Ideal WET from the arrival function ≈ 1415 s (§5.2).
        let wet = cfg.ideal_wet_s();
        assert!((wet - 1415.0).abs() < 30.0, "ideal WET = {wet}");
    }

    #[test]
    fn shard_count_is_validated() {
        let mut cfg = ExperimentConfig::default();
        cfg.cluster.shards = 4;
        cfg.validate().unwrap();
        cfg.cluster.shards = 0;
        assert!(cfg.validate().is_err(), "zero shards");
        cfg.cluster.shards = cfg.cluster.max_nodes + 1;
        assert!(cfg.validate().is_err(), "more shards than nodes");
        cfg.cluster.shards = 4;
        cfg.provisioner = ProvisionerConfig::static_nodes(2);
        assert!(cfg.validate().is_err(), "static fleet smaller than K");
        cfg.provisioner = ProvisionerConfig::static_nodes(4);
        cfg.validate().unwrap();
    }

    #[test]
    fn shards_parse_from_toml() {
        let cfg = ExperimentConfig::from_toml("[cluster]\nshards = 4\n").unwrap();
        assert_eq!(cfg.cluster.shards, 4);
        assert!(ExperimentConfig::from_toml("[cluster]\nshards = 0\n").is_err());
    }

    #[test]
    fn paper_fig_presets() {
        for fig in 4..=10 {
            let cfg = ExperimentConfig::paper_fig(fig).unwrap();
            cfg.validate().unwrap();
        }
        assert!(ExperimentConfig::paper_fig(3).is_none());
        let f7 = ExperimentConfig::paper_fig(7).unwrap();
        assert_eq!(f7.cache.capacity_bytes, 2 * GB);
        assert_eq!(f7.scheduler.policy, DispatchPolicy::GoodCacheCompute);
    }

    #[test]
    fn toml_round_trip() {
        let cfg = ExperimentConfig::from_toml(
            r#"
            name = "custom"
            seed = 7
            [cluster]
            max_nodes = 32
            gpfs_gbps = 8.0
            [workload]
            num_tasks = 1000
            file_size_mb = 1.0
            access = "zipf"
            zipf_s = 1.1
            [scheduler]
            policy = "max-cache-hit"
            [cache]
            capacity_gb = 0.5
            policy = "lfu"
            "#,
        )
        .unwrap();
        assert_eq!(cfg.name, "custom");
        assert_eq!(cfg.cluster.max_nodes, 32);
        assert_eq!(cfg.workload.num_tasks, 1000);
        assert_eq!(cfg.workload.access, AccessSpec::Zipf(1.1));
        assert_eq!(cfg.scheduler.policy, DispatchPolicy::MaxCacheHit);
        assert_eq!(cfg.cache.policy, EvictionPolicy::Lfu);
    }

    #[test]
    fn unknown_key_rejected() {
        let err = ExperimentConfig::from_toml("typo_key = 1").unwrap_err();
        assert!(err.to_string().contains("unknown config key"));
    }

    #[test]
    fn invalid_values_rejected() {
        assert!(ExperimentConfig::from_toml("[cluster]\ngpfs_gbps = -1.0").is_err());
        assert!(ExperimentConfig::from_toml("[scheduler]\npolicy = \"bogus\"").is_err());
        assert!(ExperimentConfig::from_toml("[workload]\narrival = \"constant\"").is_err());
    }
}
