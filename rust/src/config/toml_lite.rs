//! A small TOML-subset parser (offline replacement for the `toml` crate).
//!
//! Supported syntax — enough for experiment configuration files:
//!
//! * `[section]` and `[dotted.section]` headers;
//! * `key = value` with string (`"…"`), integer, float, boolean values;
//! * `#` comments and blank lines;
//! * bare keys before the first header live in the root table.
//!
//! Values are stored flattened under dotted paths (`section.key`), which is
//! what the typed config layer consumes. Arrays/inline tables/multi-line
//! strings are intentionally out of scope.

use std::collections::BTreeMap;

/// A parsed scalar value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// Quoted string.
    Str(String),
    /// 64-bit signed integer.
    Int(i64),
    /// 64-bit float.
    Float(f64),
    /// `true` / `false`.
    Bool(bool),
}

impl Value {
    /// As string (exact type required).
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As integer (exact type required).
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    /// As float; integers coerce losslessly.
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    /// As boolean (exact type required).
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// A flat `dotted.path → Value` document.
#[derive(Debug, Default, Clone)]
pub struct Document {
    entries: BTreeMap<String, Value>,
}

impl Document {
    /// Parse a document; errors carry 1-based line numbers.
    pub fn parse(text: &str) -> Result<Document, String> {
        let mut doc = Document::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| format!("line {}: unterminated section header", lineno + 1))?
                    .trim();
                if name.is_empty() {
                    return Err(format!("line {}: empty section name", lineno + 1));
                }
                section = name.to_string();
                continue;
            }
            let eq = line
                .find('=')
                .ok_or_else(|| format!("line {}: expected `key = value`", lineno + 1))?;
            let key = line[..eq].trim();
            let val_text = line[eq + 1..].trim();
            if key.is_empty() {
                return Err(format!("line {}: empty key", lineno + 1));
            }
            let value = parse_value(val_text)
                .map_err(|e| format!("line {}: {e}", lineno + 1))?;
            let path = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            if doc.entries.insert(path.clone(), value).is_some() {
                return Err(format!("line {}: duplicate key `{path}`", lineno + 1));
            }
        }
        Ok(doc)
    }

    /// Look up a dotted path.
    pub fn get(&self, path: &str) -> Option<&Value> {
        self.entries.get(path)
    }

    /// String at path.
    pub fn get_str(&self, path: &str) -> Option<&str> {
        self.get(path).and_then(Value::as_str)
    }

    /// Integer at path.
    pub fn get_int(&self, path: &str) -> Option<i64> {
        self.get(path).and_then(Value::as_int)
    }

    /// Float at path (integers coerce).
    pub fn get_float(&self, path: &str) -> Option<f64> {
        self.get(path).and_then(Value::as_float)
    }

    /// Boolean at path.
    pub fn get_bool(&self, path: &str) -> Option<bool> {
        self.get(path).and_then(Value::as_bool)
    }

    /// All keys under a dotted prefix (for unknown-key validation).
    pub fn keys_under<'a>(&'a self, prefix: &'a str) -> impl Iterator<Item = &'a str> + 'a {
        self.entries
            .keys()
            .filter(move |k| k.starts_with(prefix))
            .map(String::as_str)
    }

    /// All keys in the document.
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(String::as_str)
    }
}

fn strip_comment(line: &str) -> &str {
    // A `#` inside a quoted string must not start a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(text: &str) -> Result<Value, String> {
    if text.is_empty() {
        return Err("missing value".into());
    }
    if let Some(rest) = text.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or("unterminated string literal")?;
        if inner.contains('"') {
            return Err("embedded quote in string (escapes unsupported)".into());
        }
        return Ok(Value::Str(inner.to_string()));
    }
    match text {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    // Underscore separators allowed in numbers, as in TOML.
    let num = text.replace('_', "");
    if num.contains('.') || num.contains('e') || num.contains('E') {
        num.parse::<f64>()
            .map(Value::Float)
            .map_err(|_| format!("invalid float `{text}`"))
    } else {
        num.parse::<i64>()
            .map(Value::Int)
            .map_err(|_| format!("invalid value `{text}` (not a string/int/float/bool)"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_scalars() {
        let doc = Document::parse(
            r#"
            # experiment
            name = "fig7"
            seed = 42

            [cache]
            capacity_gb = 2.0
            policy = "lru"

            [scheduler]
            window_multiplier = 100
            data_aware = true
            "#,
        )
        .unwrap();
        assert_eq!(doc.get_str("name"), Some("fig7"));
        assert_eq!(doc.get_int("seed"), Some(42));
        assert_eq!(doc.get_float("cache.capacity_gb"), Some(2.0));
        assert_eq!(doc.get_str("cache.policy"), Some("lru"));
        assert_eq!(doc.get_bool("scheduler.data_aware"), Some(true));
        assert_eq!(doc.get_float("scheduler.window_multiplier"), Some(100.0));
    }

    #[test]
    fn underscores_and_comments() {
        let doc = Document::parse("n = 250_000 # tasks\nbw = 4.0# gbps\ns = \"a # b\"").unwrap();
        assert_eq!(doc.get_int("n"), Some(250_000));
        assert_eq!(doc.get_float("bw"), Some(4.0));
        assert_eq!(doc.get_str("s"), Some("a # b"));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = Document::parse("ok = 1\nbroken").unwrap_err();
        assert!(err.contains("line 2"), "{err}");
        let err = Document::parse("[unterminated").unwrap_err();
        assert!(err.contains("line 1"), "{err}");
        let err = Document::parse("x = \"open").unwrap_err();
        assert!(err.contains("line 1"), "{err}");
        let err = Document::parse("a = 1\na = 2").unwrap_err();
        assert!(err.contains("duplicate"), "{err}");
    }

    #[test]
    fn type_mismatches_are_none() {
        let doc = Document::parse("x = 5").unwrap();
        assert_eq!(doc.get_str("x"), None);
        assert_eq!(doc.get_bool("x"), None);
        assert_eq!(doc.get_float("x"), Some(5.0)); // int coerces to float
        assert_eq!(doc.get("missing"), None);
    }

    #[test]
    fn keys_under_prefix() {
        let doc = Document::parse("[a]\nx = 1\ny = 2\n[b]\nz = 3").unwrap();
        let keys: Vec<_> = doc.keys_under("a.").collect();
        assert_eq!(keys, vec!["a.x", "a.y"]);
    }
}
