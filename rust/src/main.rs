//! `datadiff` — the data-diffusion framework launcher.
//!
//! See `datadiff help` (or [`datadiffusion::cli::USAGE`]) for commands.

fn main() {
    datadiffusion::util::logger::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match datadiffusion::cli::parse(&args).and_then(datadiffusion::cli::execute) {
        Ok(code) => code,
        Err(e) => {
            eprintln!("error: {e}");
            eprintln!("run `datadiff help` for usage");
            2
        }
    };
    std::process::exit(code);
}
