//! The transport-agnostic coordinator core — **one** Falkon coordinator
//! shared by the discrete-event simulator and the live engine.
//!
//! The paper's claim (§3.1, §5.2) is that a single coordinator — wait
//! queue, data-aware scheduler, location index, per-executor caches,
//! dynamic resource provisioner — serves both modeled and deployed
//! workloads. Before this module the repo asserted that only by
//! convention: [`crate::sim::engine`] and [`crate::live`] each hand-wired
//! their own copy of the arrival → select → notify/pickup →
//! access-resolve → fetch → compute → complete loop. [`CoordinatorCore`]
//! owns that loop outright; the engines shrink to *drivers* that own
//! nothing but time and data movement:
//!
//! * the **sim driver** maps effects onto the fluid-flow network and the
//!   event heap (virtual clock, dispatcher service model, GRAM latency);
//! * the **live driver** maps the *same* effects onto worker threads and
//!   real file copies (wall clock).
//!
//! ## The event → effect contract
//!
//! Every entry point is a coordinator *event*; the return value is a list
//! of [`Effect`]s the driver must enact. The core never performs I/O,
//! reads a clock, or spawns a thread — `now` is always supplied by the
//! driver, and all randomness flows through the injected PRNG:
//!
//! | event                        | effects it can emit                  |
//! |------------------------------|--------------------------------------|
//! | [`CoordinatorCore::on_arrival`]      | `Notify`                     |
//! | [`CoordinatorCore::on_pickup`]       | `Fetch` (one per dispatched task) |
//! | [`CoordinatorCore::on_fetch_done`]   | `Fetch` (next file) or `Compute` |
//! | [`CoordinatorCore::on_compute_done`] | `Notify`                     |
//! | [`CoordinatorCore::on_tick`]         | `Allocate`, `Release`        |
//! | [`CoordinatorCore::kick`]            | `Notify` (the progress safety net) |
//! | [`CoordinatorCore::register_node`]   | `Notify` (fresh executor asks for work) |
//!
//! A `Notify(e)` carries an implicit contract: the core has already
//! reserved a pending slot on `e` (§3.2's *pending* state), and the
//! driver **must** eventually deliver the round-trip by calling
//! [`CoordinatorCore::on_pickup`] for `e` — the pickup either converts
//! the reservation into a running task or cancels it.
//!
//! ## Single mutation sites
//!
//! `resolve_access` (cache admission + location-index update + pending
//! maintenance), replica accounting, and provisioner enactment each live
//! in exactly one place — here. The engines contain **no** direct
//! `WaitQueue`/`Scheduler`/`PendingIndex` mutation; `rust/tests/
//! core_parity.rs` drives the same deterministic workload through both
//! drivers and asserts identical dispatch order and access tallies, and
//! `sched_parity`/`flow_parity` keep pinning the scheduler and flow-net
//! halves independently.
//!
//! Metrics are part of the shared state: the core owns the
//! [`Recorder`], so hit/miss tallies, arrival/completion accounting and
//! the 1 Hz samples are produced identically by both engines (the live
//! engine's old ad-hoc counters are gone — its report reads
//! [`Recorder::access_counts`]).
//!
//! ## Scaling out: the sharding seam
//!
//! Because every entry point is an event and every output is an effect,
//! replicating the coordinator is a routing problem, not a refactor:
//! [`crate::coordinator::shard::ShardedCoordinator`] runs K cores side by
//! side, partitions the task stream by dominant-file hash, and fans the
//! driver's events in through this same API (see `docs/SHARDING.md`).
//! The only addition the core makes for it is deliberately *read-only*:
//! [`CoordinatorCore::probe_holder`] answers "does any executor here
//! cache this file?" without touching caches, index, or PRNG, so the
//! router can rewrite a GPFS miss into a cross-shard peer fetch while
//! each core's single-mutation-site invariants stay intact.

use crate::cache::{CacheConfig, ObjectCache};
use crate::coordinator::executor::ExecutorRegistry;
use crate::coordinator::model::{ModelController, ModelControllerConfig, ModelStats};
use crate::coordinator::pending::PendingIndex;
use crate::coordinator::provisioner::{AllocationPolicy, Provisioner, ProvisionerConfig};
use crate::coordinator::queue::{Task, WaitQueue};
use crate::coordinator::scheduler::{NotifyOutcome, Scheduler, SchedulerConfig, SchedulerStats};
use crate::coordinator::{resolve_access, AccessKind};
use crate::ids::{ExecutorId, FileId, TaskId};
use crate::index::LocationIndex;
use crate::metrics::Recorder;
use crate::util::prng::Pcg64;
use crate::util::time::Micros;
use std::collections::HashMap;

/// Where the core looks up data-object sizes (cache-admission input).
#[derive(Debug, Clone)]
pub enum FileSizes {
    /// Every object has the same size (the simulator's workloads).
    Uniform(u64),
    /// Per-object sizes in a dense table indexed by `FileId.0` (the live
    /// engine reads them off the store). File ids are arena indices, so
    /// the lookup is one bounds-checked load instead of a hash probe on
    /// the per-access hot path; `0` marks an unknown id.
    PerFile(Vec<u64>),
}

impl FileSizes {
    /// Build a per-file table from `(file, bytes)` pairs. Ids absent from
    /// the input read back as 0 (unknown).
    pub fn per_file(pairs: impl IntoIterator<Item = (FileId, u64)>) -> Self {
        let mut table = Vec::new();
        for (file, bytes) in pairs {
            let i = file.0 as usize;
            if table.len() <= i {
                table.resize(i + 1, 0);
            }
            table[i] = bytes;
        }
        FileSizes::PerFile(table)
    }

    /// Size of `file` in bytes. Unknown per-file entries resolve to 0
    /// (a zero-byte object always fits; the driver will surface the
    /// missing file as an I/O error long before cache accounting cares).
    pub fn size_of(&self, file: FileId) -> u64 {
        match self {
            FileSizes::Uniform(n) => *n,
            FileSizes::PerFile(t) => t.get(file.0 as usize).copied().unwrap_or(0),
        }
    }

    /// Mean object size (the model controller's per-task transfer
    /// estimate), over known (non-zero) entries. Zero for an empty table.
    pub fn mean_bytes(&self) -> f64 {
        match self {
            FileSizes::Uniform(n) => *n as f64,
            FileSizes::PerFile(t) => {
                let known = t.iter().filter(|&&b| b != 0).count();
                if known == 0 {
                    0.0
                } else {
                    t.iter().map(|&b| b as f64).sum::<f64>() / known as f64
                }
            }
        }
    }
}

/// Everything the core needs to know about the deployment, shared
/// verbatim by both drivers.
#[derive(Debug, Clone)]
pub struct CoreConfig {
    /// Scheduler tuning (policy, window, pickup batch size).
    pub scheduler: SchedulerConfig,
    /// Provisioner tuning (allocation/release policies).
    pub provisioner: ProvisionerConfig,
    /// Per-executor cache configuration.
    pub cache: CacheConfig,
    /// Hard cap on provisioned nodes.
    pub max_nodes: usize,
    /// Task slots (CPUs) per registered node.
    pub slots_per_node: u32,
    /// Data-object sizes for cache admission.
    pub file_sizes: FileSizes,
}

/// One resolved file access the driver must enact as a data transfer.
///
/// The access has already been *resolved* (§5.2.1 three-way split) and
/// the coordinator's cache model + location index updated; the plan tells
/// the driver where the bytes come from.
#[derive(Debug, Clone)]
pub struct FetchPlan {
    /// Task this fetch belongs to.
    pub task_id: TaskId,
    /// Executor the data moves to.
    pub exec: ExecutorId,
    /// Object being fetched.
    pub file: FileId,
    /// Object size in bytes (cache-accounting size; the live driver may
    /// observe a different on-disk byte count and report it back).
    pub bytes: u64,
    /// Local hit / peer (global) hit / persistent-store miss.
    pub kind: AccessKind,
    /// For global hits, the peer executor chosen as the source.
    pub peer: Option<ExecutorId>,
    /// Objects the coordinator's cache model evicted to admit this one
    /// (the live driver deletes them from the worker's cache directory).
    pub evicted: Vec<FileId>,
}

/// What a driver must do after a coordinator event. See the module docs
/// for the per-event emission table.
#[derive(Debug, Clone)]
pub enum Effect {
    /// Deliver a dispatch notification to this executor: a pending slot
    /// is reserved; the driver must route the round-trip back into
    /// [`CoordinatorCore::on_pickup`].
    Notify(ExecutorId),
    /// Start moving one file per the resolved plan.
    Fetch(FetchPlan),
    /// All input staged: run the task's compute on the executor.
    Compute {
        /// Task to run.
        task_id: TaskId,
        /// Executor it was dispatched to.
        exec: ExecutorId,
        /// Modeled compute duration μ(κ) (the live driver runs real
        /// compute instead and ignores this).
        compute: Micros,
    },
    /// Request this many nodes from the resource manager (they register
    /// via [`CoordinatorCore::on_node_registered`] after the driver's
    /// allocation latency).
    Allocate(usize),
    /// Release these idle executors. The core itself withholds any
    /// executor still serving peer transfers (its peer-serving refcount
    /// is non-zero) and retries next tick, so the list only ever names
    /// safe-to-release nodes; `CoordinatorCore::release_deferrals`
    /// counts the withheld decisions.
    Release(Vec<ExecutorId>),
}

/// A dispatched task moving through its fetch → compute pipeline.
#[derive(Debug)]
struct InFlight {
    task: Task,
    exec: ExecutorId,
    /// Files still to fetch after the current one (reverse order; `pop`
    /// yields paper order).
    remaining: Vec<FileId>,
    /// File currently being transferred.
    current_file: FileId,
    /// Resolution of the access currently in flight (recorded when the
    /// driver reports the transfer done).
    current_kind: AccessKind,
    /// Peer executor sourcing the current transfer (global hits only);
    /// holds one peer-serving reference until the fetch drains.
    current_peer: Option<ExecutorId>,
    /// Arrival-rate interval (slowdown accounting, Fig 14).
    interval: u32,
}

/// Reusable scratch buffers for the event path. Every coordinator event
/// used to allocate its effect `Vec` (and every dispatch its
/// remaining-files `Vec`) fresh; the pools recycle those buffers so a
/// steady-state run allocates near zero per event. `alloc_events` counts
/// the pool misses — it is deterministic (a pure function of the event
/// stream and the drivers' recycling discipline), and feeds the
/// `scale/allocs_per_event` bench counter.
///
/// Excluded from `Debug` on purpose: pooled *capacity* depends on how
/// diligently a driver recycles, and state comparisons (the shard
/// pass-through parity test formats whole cores) must not see it.
#[derive(Default)]
struct Scratch {
    effects: Vec<Vec<Effect>>,
    files: Vec<Vec<FileId>>,
    alloc_events: u64,
    events: u64,
}

impl std::fmt::Debug for Scratch {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("Scratch { .. }")
    }
}

/// Cap on pooled buffers of each kind — a burst (mass executor failure)
/// must not pin its high-water allocation forever.
const SCRATCH_POOL_CAP: usize = 64;

/// The shared coordinator: the full dispatch state machine of §3, pure
/// decision logic over explicit state. Construct with
/// [`CoordinatorCore::new`]; drive with the `on_*` event methods; enact
/// the returned [`Effect`]s.
#[derive(Debug)]
pub struct CoordinatorCore {
    /// Deployment configuration (read-only after construction).
    pub config: CoreConfig,
    /// Shared metrics recorder — both engines' summary/report numbers
    /// come out of this one instance.
    pub rec: Recorder,
    sched: Scheduler,
    reg: ExecutorRegistry,
    queue: WaitQueue,
    index: LocationIndex,
    pending: PendingIndex,
    prov: Provisioner,
    /// The §3 model run online (only under `AllocationPolicy::Model`):
    /// installs the provisioner's fleet target each tick.
    model: Option<ModelController>,
    caches: HashMap<ExecutorId, ObjectCache>,
    /// Peer selection + eviction randomness (single injected stream so
    /// a driver's seeding fully determines coordinator behaviour).
    rng: Pcg64,
    inflight: HashMap<u64, InFlight>,
    /// Active peer transfers per source executor (keyed by raw id).
    /// While an executor's refcount is non-zero it must not be released
    /// — the §3.1 GridFTP source is mid-session.
    peer_serving: HashMap<u32, u32>,
    /// Release decisions withheld because the executor was serving.
    release_deferrals: u64,
    /// Fetch/compute/failure reports for tasks not in flight — rejected
    /// byzantine duplicates and corrupted completions (see
    /// `docs/CHAOS.md`). A healthy driver keeps this at zero.
    stale_events: u64,
    /// Arrival-interval of queued tasks (only non-zero intervals are
    /// stored; consumed at dispatch).
    interval_of: HashMap<u64, u32>,
    /// Tasks in dispatch order — the decision trace `core_parity`
    /// compares across drivers.
    dispatch_log: Vec<TaskId>,
    /// Recycled effect/file buffers + the allocation counter.
    scratch: Scratch,
}

impl CoordinatorCore {
    /// New coordinator. `rng` drives peer selection and cache-eviction
    /// randomness (the sim passes its forked `rng_cache` stream so
    /// results stay bit-identical to the pre-core engine).
    pub fn new(config: CoreConfig, rng: Pcg64) -> Self {
        let model = (config.provisioner.allocation == AllocationPolicy::Model).then(|| {
            ModelController::new(
                ModelControllerConfig::default(),
                config.slots_per_node,
                config.file_sizes.mean_bytes(),
            )
        });
        CoordinatorCore {
            sched: Scheduler::new(config.scheduler.clone()),
            reg: ExecutorRegistry::new(),
            queue: WaitQueue::new(),
            index: LocationIndex::new(),
            pending: PendingIndex::new(),
            prov: Provisioner::new(config.provisioner.clone(), config.max_nodes),
            model,
            caches: HashMap::new(),
            rng,
            rec: Recorder::new(),
            inflight: HashMap::new(),
            peer_serving: HashMap::new(),
            release_deferrals: 0,
            stale_events: 0,
            interval_of: HashMap::new(),
            dispatch_log: Vec::new(),
            scratch: Scratch::default(),
            config,
        }
    }

    // ---- scratch reuse --------------------------------------------------

    /// An effect buffer for the current event: pooled when a driver has
    /// recycled one, freshly allocated (and counted) otherwise.
    fn take_effects(&mut self) -> Vec<Effect> {
        self.scratch.events += 1;
        match self.scratch.effects.pop() {
            Some(v) => v,
            None => {
                self.scratch.alloc_events += 1;
                Vec::new()
            }
        }
    }

    /// Return an enacted effect buffer to the pool. Drivers call this
    /// after draining the effects of an event; skipping it is always
    /// correct, just slower (the next event allocates fresh).
    pub fn recycle_effects(&mut self, mut effects: Vec<Effect>) {
        if self.scratch.effects.len() < SCRATCH_POOL_CAP {
            effects.clear();
            self.scratch.effects.push(effects);
        }
    }

    fn take_files(&mut self) -> Vec<FileId> {
        match self.scratch.files.pop() {
            Some(v) => v,
            None => {
                self.scratch.alloc_events += 1;
                Vec::new()
            }
        }
    }

    fn recycle_files(&mut self, mut files: Vec<FileId>) {
        if self.scratch.files.len() < SCRATCH_POOL_CAP {
            files.clear();
            self.scratch.files.push(files);
        }
    }

    /// Fresh scratch-buffer allocations so far (pool misses on the event
    /// path). Deterministic for a given event stream + recycling
    /// discipline; the `scale/allocs_per_event` numerator.
    pub fn alloc_events(&self) -> u64 {
        self.scratch.alloc_events
    }

    /// Events that took an effect buffer so far — the
    /// `scale/allocs_per_event` denominator.
    pub fn effect_events(&self) -> u64 {
        self.scratch.events
    }

    /// Bytes behind the coordinator's dense dispatch tables (location
    /// index, pending index, per-executor cache slabs) — capacity-based,
    /// so it tracks the high-water footprint `scale/peak_table_bytes`
    /// reports.
    pub fn table_bytes(&self) -> u64 {
        let caches: u64 = self.caches.values().map(ObjectCache::table_bytes).sum();
        self.index.table_bytes() + self.pending.table_bytes() + caches
    }

    fn caching(&self) -> bool {
        self.config.scheduler.policy.uses_caching()
    }

    /// Reserve a pending slot on `exec` for an in-flight notification.
    /// Returns false when the executor has no free slot.
    fn reserve(&mut self, exec: ExecutorId) -> bool {
        if !self.reg.is_free(exec) {
            return false;
        }
        self.reg.mark_pending(exec);
        true
    }

    /// Phase-1 notification for the queue head; reserves the chosen
    /// executor. Mirrors the paper's notify step: holders preferred,
    /// policy decides the fallback.
    fn notify_head(&mut self) -> Option<ExecutorId> {
        if self.reg.free_count() == 0 || self.queue.is_empty() {
            return None;
        }
        // Scratch-copy the head's file list so the selector can mutate
        // the pending index while reading it (no per-call allocation).
        let mut files = self.take_files();
        if let Some(t) = self.queue.front() {
            files.extend_from_slice(&t.files);
        }
        let outcome = self
            .sched
            .select_notify(&files, &self.reg, &mut self.pending, &self.index);
        self.recycle_files(files);
        match outcome {
            NotifyOutcome::Preferred(e) | NotifyOutcome::Fallback(e) => {
                let reserved = self.reserve(e);
                debug_assert!(reserved, "select_notify returned a busy executor");
                Some(e)
            }
            NotifyOutcome::Wait | NotifyOutcome::NoneFree => None,
        }
    }

    // ---- node lifecycle -------------------------------------------------

    /// Register a freshly provisioned node (initial fleet or a driver
    /// enacting [`Effect::Allocate`] without LRM bookkeeping). The new
    /// executor immediately asks for work, so the effects usually carry
    /// its `Notify`.
    pub fn register_node(&mut self, now: Micros) -> (ExecutorId, Vec<Effect>) {
        let id = self.reg.register(self.config.slots_per_node, now);
        if self.caching() {
            self.caches.insert(id, ObjectCache::new(self.config.cache));
            self.index.register_executor(id);
        }
        let mut effects = self.take_effects();
        if self.reserve(id) {
            effects.push(Effect::Notify(id));
        }
        (id, effects)
    }

    /// A node requested through [`Effect::Allocate`] finished its LRM
    /// bootstrap: drains the provisioner's pending count, then registers.
    pub fn on_node_registered(&mut self, now: Micros) -> (ExecutorId, Vec<Effect>) {
        self.prov.on_node_registered();
        self.register_node(now)
    }

    /// Release an idle executor: scrubs its cache, index entries and
    /// pending candidates, then deregisters it. The driver must only
    /// call this for executors named in [`Effect::Release`] — the core
    /// has already withheld any executor still serving peer transfers.
    pub fn release_node(&mut self, id: ExecutorId) {
        if self.caching() {
            self.index.deregister_executor(id);
            self.pending.on_deregister(id);
            self.caches.remove(&id);
        }
        self.peer_serving.remove(&id.0);
        self.reg.deregister(id);
    }

    /// Drop one peer-serving reference on `peer`. Tolerates a missing
    /// entry: a failed source's refcounts are dropped wholesale by
    /// [`CoordinatorCore::on_executor_failed`] while its destinations'
    /// fetches are still draining.
    fn peer_release(&mut self, peer: ExecutorId) {
        if let Some(n) = self.peer_serving.get_mut(&peer.0) {
            *n -= 1;
            if *n == 0 {
                self.peer_serving.remove(&peer.0);
            }
        }
    }

    // ---- dispatch events ------------------------------------------------

    /// A task arrived (or re-arrived — the live replay policy resubmits
    /// failed tasks). Queues it, maintains the pending index, and runs
    /// the phase-1 notification for the queue head. `interval`/`rate`
    /// feed slowdown accounting; drivers without arrival staging pass
    /// `0`/`0.0`.
    pub fn on_arrival(
        &mut self,
        task: Task,
        interval: u32,
        rate: f64,
        now: Micros,
    ) -> Vec<Effect> {
        self.rec.record_arrival(now, interval, rate);
        if let Some(ctl) = self.model.as_mut() {
            // Declared compute feeds the controller's μ estimate ahead
            // of the first completion.
            ctl.observe_compute(task.compute.as_secs_f64());
        }
        if interval != 0 {
            self.interval_of.insert(task.id.0, interval);
        }
        let qref = self.queue.push_back(task);
        if self.caching() {
            self.pending.on_push(&self.queue, qref, &self.index);
        }
        let mut effects = self.take_effects();
        if let Some(e) = self.notify_head() {
            effects.push(Effect::Notify(e));
        }
        effects
    }

    /// An executor asks for work (a delivered notification round-trip, or
    /// a live worker polling). Runs the phase-2 pickup: selects up to
    /// `max_tasks_per_pickup` (capped by free slots) window tasks,
    /// converts or cancels the pending reservation, and resolves each
    /// dispatched task's first file access into a [`Effect::Fetch`].
    pub fn on_pickup(&mut self, exec: ExecutorId, now: Micros) -> Vec<Effect> {
        if !self.reg.contains(exec) {
            return Vec::new(); // released meanwhile
        }
        let entry = self.reg.get(exec).expect("contains() checked");
        let reserved = entry.pending_slots > 0;
        let free_extra = entry.free_slots() as usize;
        // The reservation holds one slot; extra free slots allow a larger
        // batch. Without a reservation (live polling) only free slots count.
        let cap = if reserved { 1 + free_extra } else { free_extra };
        if cap == 0 {
            return Vec::new();
        }
        let limit = self.config.scheduler.max_tasks_per_pickup.min(cap).max(1);
        let tasks = self.sched.pick_tasks(
            exec,
            limit,
            &mut self.queue,
            &mut self.pending,
            &self.reg,
            &self.index,
        );
        if tasks.is_empty() {
            if reserved {
                self.reg.cancel_pending(exec);
            }
            return Vec::new();
        }
        let mut effects = self.take_effects();
        for (i, task) in tasks.into_iter().enumerate() {
            if i == 0 && reserved {
                self.reg.pending_to_busy(exec, now);
            } else {
                self.reg.start_task(exec, now);
            }
            self.dispatch_log.push(task.id);
            effects.push(self.begin_task(task, exec));
        }
        effects
    }

    /// Start a dispatched task's data phase: resolve its first file.
    fn begin_task(&mut self, task: Task, exec: ExecutorId) -> Effect {
        let interval = self.interval_of.remove(&task.id.0).unwrap_or(0);
        let mut remaining = self.take_files();
        remaining.extend_from_slice(&task.files);
        remaining.reverse(); // pop() yields paper order
        let first = remaining.pop().expect("task has ≥1 file");
        let mut inf = InFlight {
            task,
            exec,
            remaining,
            current_file: first,
            current_kind: AccessKind::Miss,
            current_peer: None,
            interval,
        };
        let plan = self.resolve(&mut inf, first);
        self.inflight.insert(inf.task.id.0, inf);
        Effect::Fetch(plan)
    }

    /// Resolve one file access: cache admission, location-index update,
    /// pending-index maintenance — the single mutation site on the task
    /// data path for *both* engines.
    fn resolve(&mut self, inf: &mut InFlight, file: FileId) -> FetchPlan {
        let exec = inf.exec;
        let size = self.config.file_sizes.size_of(file);
        let (kind, peer, evicted) = if self.caching() {
            let cache = self
                .caches
                .get_mut(&exec)
                .expect("caching policy ⇒ cache exists");
            let res = resolve_access(exec, file, size, cache, &mut self.index, &mut self.rng);
            // Keep the inverted pending index coherent with the index
            // mutations resolve_access just made.
            for &old in &res.evicted {
                self.pending
                    .on_index_remove(old, exec, &self.queue, &self.index);
            }
            if res.inserted {
                self.pending.on_index_add(file, exec);
            }
            (res.kind, res.peer, res.evicted)
        } else {
            // first-available: every access goes to persistent storage.
            (AccessKind::Miss, None, Vec::new())
        };
        inf.current_file = file;
        inf.current_kind = kind;
        // A chosen peer is mid-serve until the driver reports the fetch
        // done; the refcount blocks its release for that window.
        if let Some(prev) = inf.current_peer.take() {
            self.peer_release(prev);
        }
        if let Some(p) = peer {
            *self.peer_serving.entry(p.0).or_insert(0) += 1;
        }
        inf.current_peer = peer;
        FetchPlan {
            task_id: inf.task.id,
            exec,
            file,
            bytes: size,
            kind,
            peer,
            evicted,
        }
    }

    /// The driver finished one file transfer. Records the access in the
    /// shared recorder and either chains the next fetch or declares the
    /// data phase complete. `observed` lets the live driver report what
    /// the worker actually experienced — kind (a peer copy can race the
    /// peer's eviction and fall back to persistent storage, §3.1) and
    /// real byte count; the sim passes `None` to record the resolution.
    pub fn on_fetch_done(
        &mut self,
        task_id: TaskId,
        now: Micros,
        observed: Option<(AccessKind, u64)>,
    ) -> Vec<Effect> {
        let Some(mut inf) = self.inflight.remove(&task_id.0) else {
            // Not in flight: a duplicated or corrupted report (byzantine
            // driver/worker). Rejecting it here keeps the slot ledger and
            // replica accounting exact — see `stale_events`.
            self.stale_events += 1;
            return Vec::new();
        };
        if let Some(peer) = inf.current_peer.take() {
            self.peer_release(peer);
        }
        let (kind, bytes) = match observed {
            Some(kb) => kb,
            None => (
                inf.current_kind,
                self.config.file_sizes.size_of(inf.current_file),
            ),
        };
        self.rec.record_access(now, kind, bytes);
        let effect = if let Some(next) = inf.remaining.pop() {
            Effect::Fetch(self.resolve(&mut inf, next))
        } else {
            Effect::Compute {
                task_id,
                exec: inf.exec,
                compute: inf.task.compute,
            }
        };
        self.inflight.insert(task_id.0, inf);
        let mut effects = self.take_effects();
        effects.push(effect);
        effects
    }

    /// The task's compute finished. Frees the slot, records the
    /// completion (at `completed_at`, which the sim offsets by the result
    /// delivery latency), and — if work is still queued — notifies the
    /// now-free executor.
    pub fn on_compute_done(
        &mut self,
        task_id: TaskId,
        now: Micros,
        completed_at: Micros,
    ) -> Vec<Effect> {
        let Some(mut inf) = self.inflight.remove(&task_id.0) else {
            self.stale_events += 1;
            return Vec::new();
        };
        debug_assert_eq!(inf.task.id, task_id);
        self.recycle_files(std::mem::take(&mut inf.remaining));
        self.reg.finish_task(inf.exec, now);
        self.rec
            .record_completion(completed_at, inf.task.arrival, inf.interval);
        let mut effects = self.take_effects();
        if !self.queue.is_empty() && self.reserve(inf.exec) {
            effects.push(Effect::Notify(inf.exec));
        }
        effects
    }

    /// A dispatched task failed on its executor (live-engine worker
    /// error). Frees the slot without recording an access or completion;
    /// the driver decides whether to resubmit (the §4.2 replay policy)
    /// via [`CoordinatorCore::on_arrival`]. Like a successful
    /// completion, the freed executor is re-notified when work is still
    /// queued — otherwise a permanently-failed task would idle its
    /// executor until the backlog drained.
    pub fn on_task_failed(&mut self, task_id: TaskId, now: Micros) -> Vec<Effect> {
        let Some(mut inf) = self.inflight.remove(&task_id.0) else {
            self.stale_events += 1;
            return Vec::new();
        };
        if let Some(peer) = inf.current_peer.take() {
            self.peer_release(peer);
        }
        self.recycle_files(std::mem::take(&mut inf.remaining));
        self.reg.finish_task(inf.exec, now);
        let mut effects = self.take_effects();
        if !self.queue.is_empty() && self.reserve(inf.exec) {
            effects.push(Effect::Notify(inf.exec));
        }
        effects
    }

    /// An executor crashed (chaos fault or live worker death), possibly
    /// with tasks mid-fetch or mid-compute. Unlike
    /// [`CoordinatorCore::release_node`] — which refuses busy executors
    /// — this scrubs the dead node outright: its cache model, location-
    /// index replicas and pending candidates are dropped (replica
    /// accounting stays exact), and every task in flight on it is
    /// re-queued per the §4.2 replay policy so its data re-diffuses from
    /// surviving replicas. Returns the re-queued task ids (the shard
    /// router scrubs cross-shard bookkeeping with them) plus `Notify`
    /// effects for the re-queued backlog. A no-op for executors already
    /// released or failed.
    pub fn on_executor_failed(
        &mut self,
        exec: ExecutorId,
        now: Micros,
    ) -> (Vec<TaskId>, Vec<Effect>) {
        if !self.reg.contains(exec) {
            return (Vec::new(), Vec::new());
        }
        // Victims: every task in flight on the dead executor, in task-id
        // order (HashMap iteration is nondeterministic; the replay order
        // must be seed-reproducible).
        let mut victims: Vec<u64> = self
            .inflight
            .iter()
            .filter(|(_, inf)| inf.exec == exec)
            .map(|(&id, _)| id)
            .collect();
        victims.sort_unstable();
        let mut tasks = Vec::with_capacity(victims.len());
        for id in &victims {
            let mut inf = self.inflight.remove(id).expect("collected above");
            if let Some(peer) = inf.current_peer.take() {
                self.peer_release(peer);
            }
            self.recycle_files(std::mem::take(&mut inf.remaining));
            tasks.push((inf.task, inf.interval));
        }
        // Transfers *sourced from* the dead executor can no longer be
        // served by it; the drivers fall back to persistent storage
        // (§3.1 peer-copy race) and report the observed kind, so the
        // serving references die with the source.
        for inf in self.inflight.values_mut() {
            if inf.current_peer == Some(exec) {
                inf.current_peer = None;
            }
        }
        self.peer_serving.remove(&exec.0);
        // Scrub replicas, pending candidates and the cache model before
        // re-queuing, so the replayed tasks' candidate sets never name
        // the dead node.
        if self.caching() {
            self.index.deregister_executor(exec);
            self.pending.on_deregister(exec);
            self.caches.remove(&exec);
        }
        self.reg.fail(exec);
        crate::debug!(
            "executor {exec} failed at {now:?}: requeueing {} task(s)",
            tasks.len()
        );
        let mut requeued = Vec::with_capacity(tasks.len());
        for (task, interval) in tasks {
            requeued.push(task.id);
            if interval != 0 {
                self.interval_of.insert(task.id.0, interval);
            }
            let qref = self.queue.push_back(task);
            if self.caching() {
                self.pending.on_push(&self.queue, qref, &self.index);
            }
        }
        // One notification per re-queued task, mirroring on_arrival.
        let mut effects = self.take_effects();
        for _ in 0..requeued.len() {
            match self.notify_head() {
                Some(e) => effects.push(Effect::Notify(e)),
                None => break,
            }
        }
        (requeued, effects)
    }

    /// Periodic (1 Hz in the sim, per-completion in the live engine)
    /// sample + provisioning decision. Emits `Allocate`/`Release`
    /// effects; the driver adds allocation latency and may defer releases
    /// of executors still serving transfers.
    pub fn on_tick(&mut self, now: Micros) -> Vec<Effect> {
        self.rec.sample(
            now,
            self.queue.len(),
            self.reg.len(),
            self.reg.busy_slots(),
            self.reg.total_slots(),
        );
        // Model-predictive step: the controller reads the sample that
        // was just recorded, solves for the PI-maximizing fleet, and
        // installs the target the provisioner tracks below.
        if let Some(ctl) = self.model.as_mut() {
            let target = ctl.decide(&self.rec, self.queue.len(), self.prov.max_nodes());
            self.prov.set_model_target(target);
        }
        let action = self.prov.on_tick(now, self.queue.len(), &self.reg);
        let mut effects = self.take_effects();
        if action.allocate > 0 {
            effects.push(Effect::Allocate(action.allocate));
        }
        if !action.release.is_empty() {
            // Enforce the Release contract: an executor still serving
            // peer transfers is withheld this tick. Its idle timestamp
            // is untouched, so the provisioner re-lists it once the
            // transfers drain.
            let (release, deferred): (Vec<_>, Vec<_>) = action
                .release
                .into_iter()
                .partition(|e| !self.peer_serving.contains_key(&e.0));
            self.release_deferrals += deferred.len() as u64;
            if !release.is_empty() {
                effects.push(Effect::Release(release));
            }
        }
        effects
    }

    /// Progress safety net: if tasks wait and executors are free, notify
    /// for the head; when the policy declines (max-cache-hit can
    /// legitimately `Wait` with free executors), force one pickup on the
    /// first free executor. Drivers call this when no pickup is already
    /// in flight.
    pub fn kick(&mut self) -> Vec<Effect> {
        if self.queue.is_empty() || self.reg.free_count() == 0 {
            return Vec::new();
        }
        let mut effects = self.take_effects();
        if let Some(e) = self.notify_head() {
            effects.push(Effect::Notify(e));
            return effects;
        }
        let first_free = self.reg.free_iter().next();
        if let Some(e) = first_free {
            if self.reserve(e) {
                effects.push(Effect::Notify(e));
            }
        }
        effects
    }

    // ---- read-only state queries ---------------------------------------

    /// Read-only holder probe: the first executor (ascending id order)
    /// whose cache holds `file`, per this coordinator's location index.
    /// O(1) hash probe + one bit scan; mutates nothing and draws no
    /// randomness. This is the seam the shard router's cross-shard
    /// fetch rewrite reads — see
    /// [`crate::coordinator::shard::ShardedCoordinator`] — and it is
    /// deliberately weaker than [`resolve_access`]: the probe names a
    /// *source candidate* on a foreign coordinator without perturbing
    /// either side's cache or index state.
    ///
    /// [`resolve_access`]: crate::coordinator::resolve_access
    pub fn probe_holder(&self, file: FileId) -> Option<ExecutorId> {
        self.index.holders(file).and_then(|h| h.iter().next())
    }

    /// Holder count for `file` (read-only, O(1) cached popcount). With
    /// [`CoordinatorCore::probe_holder_nth`] this lets the shard router
    /// rotate cross-shard source selection over *all* of a file's
    /// foreign holders instead of always drafting the first.
    #[doc(hidden)]
    pub fn probe_holder_count(&self, file: FileId) -> usize {
        self.index.holders(file).map_or(0, |h| h.len())
    }

    /// The `n`-th executor (ascending id order) caching `file`, if any.
    /// Read-only like [`CoordinatorCore::probe_holder`].
    #[doc(hidden)]
    pub fn probe_holder_nth(&self, file: FileId, n: usize) -> Option<ExecutorId> {
        self.index.holders(file).and_then(|h| h.iter().nth(n))
    }

    /// Release decisions withheld because the named executor was still
    /// serving peer transfers.
    pub fn release_deferrals(&self) -> u64 {
        self.release_deferrals
    }

    /// Reports rejected because they named a task not in flight
    /// (byzantine duplicates / corrupted completions).
    pub fn stale_events(&self) -> u64 {
        self.stale_events
    }

    /// Active peer transfers currently sourced from `exec` — the
    /// Release-deferral input, exposed for drivers, tests and the chaos
    /// oracle.
    pub fn peer_serving_on(&self, exec: ExecutorId) -> u32 {
        self.peer_serving.get(&exec.0).copied().unwrap_or(0)
    }

    /// Cross-check coordinator state against itself — the chaos
    /// oracle's replica-accounting invariant. Verifies the registry's
    /// slot sums, both location-index maps, cache contents against the
    /// index, in-flight tasks against registered executors, and the
    /// peer-serving refcounts against the in-flight plans. Read-only;
    /// `Err` describes the first violation found.
    #[doc(hidden)]
    pub fn check_integrity(&self) -> Result<(), String> {
        self.reg.check_consistent()?;
        self.index.check_consistent()?;
        if self.caching() {
            if self.index.executors() != self.caches.len() {
                return Err(format!(
                    "index tracks {} executor(s), {} cache(s) exist",
                    self.index.executors(),
                    self.caches.len()
                ));
            }
            for (&e, cache) in &self.caches {
                let indexed = self.index.cached_at(e);
                let indexed_len = indexed.map_or(0, |s| s.len());
                if cache.len() != indexed_len {
                    return Err(format!(
                        "{e}: cache holds {} object(s), index says {indexed_len}",
                        cache.len()
                    ));
                }
                for f in cache.files() {
                    if !indexed.is_some_and(|s| s.contains(&f)) {
                        return Err(format!("{e} caches {f} but the index disagrees"));
                    }
                }
            }
        }
        let mut serving: HashMap<u32, u32> = HashMap::new();
        for inf in self.inflight.values() {
            if !self.reg.contains(inf.exec) {
                return Err(format!(
                    "task {} in flight on unregistered executor {}",
                    inf.task.id, inf.exec
                ));
            }
            if let Some(p) = inf.current_peer {
                *serving.entry(p.0).or_insert(0) += 1;
            }
        }
        if serving != self.peer_serving {
            return Err(format!(
                "peer-serving refcounts {:?} disagree with in-flight plans {:?}",
                self.peer_serving, serving
            ));
        }
        Ok(())
    }

    /// Nodes requested via [`Effect::Allocate`] that have not yet come
    /// back through [`CoordinatorCore::on_node_registered`]. The shard
    /// router uses this to route a finished node bootstrap to the shard
    /// whose provisioner asked for it.
    pub fn pending_allocations(&self) -> usize {
        self.prov.pending()
    }

    /// Override the model controller's tuning (the sim engine wires the
    /// experiment's actual cluster rates in; defaults otherwise). No-op
    /// unless the core runs under `AllocationPolicy::Model`.
    pub fn set_model_config(&mut self, cfg: ModelControllerConfig) {
        if let Some(ctl) = self.model.as_mut() {
            ctl.config = cfg;
        }
    }

    /// The model controller's decision counters, when one is running.
    pub fn model_stats(&self) -> Option<&ModelStats> {
        self.model.as_ref().map(|c| &c.stats)
    }

    /// The model controller's standing fleet target, when one is
    /// running and has solved at least once.
    pub fn model_target(&self) -> Option<usize> {
        self.model.as_ref().and_then(|c| c.target())
    }

    /// This core's node quota (its provisioner cap; `config.max_nodes`
    /// at construction, possibly rebalanced since by the shard router).
    pub fn node_quota(&self) -> usize {
        self.prov.max_nodes()
    }

    /// Rebalance this core's node quota (the sharded router's model-
    /// driven apportionment — docs/PROVISIONING.md). Never drops below
    /// what is already registered-or-pending of its own accord; the
    /// provisioner simply stops allocating and releases idles toward
    /// the new cap.
    pub fn set_node_quota(&mut self, quota: usize) {
        self.config.max_nodes = quota;
        self.prov.set_max_nodes(quota);
    }

    /// Does the configured policy maintain caches and the location
    /// index? (False only for first-available, which always reads GPFS.)
    pub fn caching_enabled(&self) -> bool {
        self.caching()
    }

    /// Queued (not yet dispatched) task count.
    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// True when no tasks are waiting.
    pub fn queue_is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Executors with at least one free slot.
    pub fn free_count(&self) -> usize {
        self.reg.free_count()
    }

    /// Registered executor count.
    pub fn node_count(&self) -> usize {
        self.reg.len()
    }

    /// The executor registry (read-only; state transitions go through
    /// the event methods).
    pub fn executors(&self) -> &ExecutorRegistry {
        &self.reg
    }

    /// Scheduler behaviour counters.
    pub fn sched_stats(&self) -> &SchedulerStats {
        &self.sched.stats
    }

    /// Pending-index work counters (maintenance ops, dead-hint purges).
    pub fn pending_stats(&self) -> &crate::coordinator::pending::PendingStats {
        &self.pending.stats
    }

    /// Tasks in dispatch order so far — the cross-driver decision trace.
    pub fn dispatch_order(&self) -> &[TaskId] {
        &self.dispatch_log
    }

    /// Take ownership of the dispatch trace (end-of-run reporting).
    pub fn take_dispatch_log(&mut self) -> Vec<TaskId> {
        std::mem::take(&mut self.dispatch_log)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::EvictionPolicy;
    use crate::coordinator::scheduler::DispatchPolicy;

    fn config(policy: DispatchPolicy) -> CoreConfig {
        CoreConfig {
            scheduler: SchedulerConfig {
                policy,
                ..SchedulerConfig::default()
            },
            provisioner: ProvisionerConfig::default(),
            cache: CacheConfig {
                capacity_bytes: 100,
                policy: EvictionPolicy::Lru,
            },
            max_nodes: 4,
            slots_per_node: 1,
            file_sizes: FileSizes::Uniform(10),
        }
    }

    fn core(policy: DispatchPolicy) -> CoordinatorCore {
        CoordinatorCore::new(config(policy), Pcg64::seeded(1))
    }

    fn task(i: u64, file: u32) -> Task {
        Task {
            id: TaskId(i),
            files: vec![FileId(file)],
            compute: Micros::from_millis(5),
            arrival: Micros::ZERO,
        }
    }

    /// Walk one task through the full event loop, checking each effect.
    #[test]
    fn arrival_to_completion_round_trip() {
        let mut c = core(DispatchPolicy::GoodCacheCompute);
        let (e0, effs) = c.register_node(Micros::ZERO);
        // A fresh executor asks for work (reservation made).
        assert!(matches!(effs.as_slice(), [Effect::Notify(e)] if *e == e0));
        // Nothing queued: the pickup cancels the reservation.
        assert!(c.on_pickup(e0, Micros::ZERO).is_empty());
        assert_eq!(c.free_count(), 1);

        // Arrival notifies the free executor again.
        let effs = c.on_arrival(task(0, 7), 0, 0.0, Micros::ZERO);
        assert!(matches!(effs.as_slice(), [Effect::Notify(e)] if *e == e0));
        assert_eq!(c.queue_len(), 1);

        // Pickup dispatches it and resolves the first access (cold miss).
        let effs = c.on_pickup(e0, Micros::from_millis(1));
        let plan = match effs.as_slice() {
            [Effect::Fetch(p)] => p.clone(),
            other => panic!("expected one fetch, got {other:?}"),
        };
        assert_eq!(plan.task_id, TaskId(0));
        assert_eq!(plan.kind, AccessKind::Miss);
        assert_eq!(plan.bytes, 10);
        assert_eq!(c.queue_len(), 0);
        assert_eq!(c.dispatch_order(), &[TaskId(0)]);

        // Transfer done → compute; compute done → completion recorded.
        let effs = c.on_fetch_done(TaskId(0), Micros::from_millis(2), None);
        assert!(matches!(
            effs.as_slice(),
            [Effect::Compute { task_id, .. }] if *task_id == TaskId(0)
        ));
        let effs = c.on_compute_done(TaskId(0), Micros::from_millis(7), Micros::from_millis(7));
        assert!(effs.is_empty(), "queue empty: no re-notify");
        assert_eq!(c.rec.tasks_done(), 1);
        assert_eq!(c.rec.access_counts(), (0, 0, 1));
        assert_eq!(c.free_count(), 1);
    }

    #[test]
    fn second_access_is_a_local_hit_and_renotifies() {
        let mut c = core(DispatchPolicy::GoodCacheCompute);
        let (e0, _) = c.register_node(Micros::ZERO);
        for i in 0..2 {
            let _ = c.on_arrival(task(i, 7), 0, 0.0, Micros::ZERO);
        }
        let _ = c.on_pickup(e0, Micros::ZERO);
        let _ = c.on_fetch_done(TaskId(0), Micros::from_millis(1), None);
        // Completion with work still queued re-notifies the executor.
        let effs = c.on_compute_done(TaskId(0), Micros::from_millis(6), Micros::from_millis(6));
        assert!(matches!(effs.as_slice(), [Effect::Notify(e)] if *e == e0));
        let effs = c.on_pickup(e0, Micros::from_millis(6));
        match effs.as_slice() {
            [Effect::Fetch(p)] => assert_eq!(p.kind, AccessKind::HitLocal),
            other => panic!("expected fetch, got {other:?}"),
        }
    }

    #[test]
    fn observed_access_overrides_resolution() {
        let mut c = core(DispatchPolicy::GoodCacheCompute);
        let (e0, _) = c.register_node(Micros::ZERO);
        let _ = c.on_arrival(task(0, 7), 0, 0.0, Micros::ZERO);
        let _ = c.on_pickup(e0, Micros::ZERO);
        // The live driver reports what the worker actually saw.
        let _ = c.on_fetch_done(TaskId(0), Micros::ZERO, Some((AccessKind::Miss, 4096)));
        assert_eq!(c.rec.access_counts(), (0, 0, 1));
    }

    #[test]
    fn failed_task_frees_slot_without_recording() {
        let mut c = core(DispatchPolicy::GoodCacheCompute);
        let (e0, _) = c.register_node(Micros::ZERO);
        let _ = c.on_arrival(task(0, 7), 0, 0.0, Micros::ZERO);
        let _ = c.on_pickup(e0, Micros::ZERO);
        let effs = c.on_task_failed(TaskId(0), Micros::from_millis(1));
        assert!(effs.is_empty(), "empty queue: nothing to notify for");
        assert_eq!(c.free_count(), 1);
        assert_eq!(c.rec.tasks_done(), 0);
        // The replay resubmission goes back through on_arrival.
        let effs = c.on_arrival(task(0, 7), 0, 0.0, Micros::from_millis(1));
        assert!(matches!(effs.as_slice(), [Effect::Notify(e)] if *e == e0));
    }

    #[test]
    fn failure_with_backlog_renotifies_the_freed_executor() {
        // A permanently-failed task must not idle its executor while
        // work is still queued (the driver may choose not to resubmit).
        let mut c = core(DispatchPolicy::GoodCacheCompute);
        let (e0, _) = c.register_node(Micros::ZERO);
        for i in 0..2 {
            let _ = c.on_arrival(task(i, 7), 0, 0.0, Micros::ZERO);
        }
        let _ = c.on_pickup(e0, Micros::ZERO); // dispatches task 0
        let effs = c.on_task_failed(TaskId(0), Micros::from_millis(1));
        assert!(matches!(effs.as_slice(), [Effect::Notify(e)] if *e == e0));
        let effs = c.on_pickup(e0, Micros::from_millis(1));
        assert!(
            matches!(effs.as_slice(), [Effect::Fetch(p)] if p.task_id == TaskId(1)),
            "freed executor must pick up the backlog"
        );
    }

    #[test]
    fn tick_allocates_under_queue_pressure() {
        let mut c = core(DispatchPolicy::GoodCacheCompute);
        for i in 0..100 {
            let _ = c.on_arrival(task(i, i as u32), 0, 0.0, Micros::ZERO);
        }
        let effs = c.on_tick(Micros::from_secs(1));
        let n = match effs.as_slice() {
            [Effect::Allocate(n)] => *n,
            other => panic!("expected allocate, got {other:?}"),
        };
        assert!(n >= 1);
        let (e, effs) = c.on_node_registered(Micros::from_secs(2));
        assert!(matches!(effs.as_slice(), [Effect::Notify(x)] if *x == e));
    }

    #[test]
    fn model_allocation_closes_the_loop() {
        let mut cfg = config(DispatchPolicy::GoodCacheCompute);
        cfg.provisioner.allocation = AllocationPolicy::Model;
        let mut c = CoordinatorCore::new(cfg, Pcg64::seeded(1));
        assert_eq!(c.model_target(), None, "no solve before the first tick");
        for i in 0..100 {
            let _ = c.on_arrival(task(i, i as u32), 0, 0.0, Micros::ZERO);
        }
        let effs = c.on_tick(Micros::from_secs(1));
        let n = match effs.as_slice() {
            [Effect::Allocate(n)] => *n,
            other => panic!("expected allocate, got {other:?}"),
        };
        let target = c.model_target().expect("tick ran a solve");
        assert!((1..=4).contains(&target), "target within quota: {target}");
        assert_eq!(n, target, "empty fleet allocates straight to target");
        assert_eq!(c.model_stats().unwrap().solves, 1);

        // A killed executor re-enters the solved target: register one,
        // fail it, and the next tick re-requests toward the target.
        let (e, _) = c.on_node_registered(Micros::from_secs(2));
        let _ = c.on_executor_failed(e, Micros::from_secs(3));
        let effs = c.on_tick(Micros::from_secs(4));
        assert!(
            effs.iter()
                .any(|eff| matches!(eff, Effect::Allocate(k) if *k >= 1)),
            "lost capacity must be re-requested: {effs:?}"
        );
        c.check_integrity().unwrap();
    }

    #[test]
    fn model_release_defers_while_serving_peer_transfer() {
        // Same serving-source setup as the static-policy deferral test,
        // but with the controller driving releases toward its target:
        // the mid-serve source must still be withheld.
        let mut cfg = config(DispatchPolicy::MaxComputeUtil);
        cfg.provisioner.allocation = AllocationPolicy::Model;
        cfg.provisioner.idle_release_s = 1.0;
        let mut c = CoordinatorCore::new(cfg, Pcg64::seeded(1));
        let (e0, _) = c.register_node(Micros::ZERO);
        let (e1, _) = c.register_node(Micros::ZERO);
        let _ = c.on_pickup(e0, Micros::ZERO);
        let _ = c.on_pickup(e1, Micros::ZERO);
        let _ = c.on_arrival(task(0, 7), 0, 0.0, Micros::ZERO);
        let _ = c.on_pickup(e0, Micros::ZERO);
        let _ = c.on_fetch_done(TaskId(0), Micros::ZERO, None);
        let _ = c.on_arrival(task(1, 7), 0, 0.0, Micros::ZERO);
        let effs = c.on_pickup(e1, Micros::ZERO);
        assert!(
            matches!(effs.as_slice(), [Effect::Fetch(p)] if p.peer == Some(e0)),
            "second reader fetches peer-to-peer: {effs:?}"
        );
        let _ = c.on_compute_done(TaskId(0), Micros::from_millis(5), Micros::from_millis(5));
        // Idle stream → the target collapses below the fleet, but the
        // serving source is withheld.
        let effs = c.on_tick(Micros::from_secs(10));
        assert!(
            !effs
                .iter()
                .any(|e| matches!(e, Effect::Release(v) if v.contains(&e0))),
            "serving peer must not be released: {effs:?}"
        );
        assert!(c.release_deferrals() >= 1);
        c.check_integrity().unwrap();
        // Transfer drains → the source becomes releasable.
        let _ = c.on_fetch_done(TaskId(1), Micros::from_secs(10), None);
        let effs = c.on_tick(Micros::from_secs(20));
        assert!(
            effs.iter()
                .any(|e| matches!(e, Effect::Release(v) if v.contains(&e0))),
            "drained source must be released toward target: {effs:?}"
        );
    }

    #[test]
    fn kick_forces_progress_when_notify_declines() {
        // max-cache-hit with the only holder busy: notify says Wait, the
        // safety net must still force a pickup on a free executor.
        let mut c = core(DispatchPolicy::MaxCacheHit);
        let (e0, _) = c.register_node(Micros::ZERO);
        let (e1, _) = c.register_node(Micros::ZERO);
        // Cancel the fresh-node reservations so both start free.
        let _ = c.on_pickup(e0, Micros::ZERO);
        let _ = c.on_pickup(e1, Micros::ZERO);
        // e0 caches file 7 and becomes busy with an unrelated task.
        let _ = c.on_arrival(task(0, 7), 0, 0.0, Micros::ZERO);
        let _ = c.on_pickup(e0, Micros::ZERO);
        let _ = c.on_fetch_done(TaskId(0), Micros::ZERO, None);
        // A second reader of file 7 arrives; holder e0 is busy → Wait.
        let effs = c.on_arrival(task(1, 7), 0, 0.0, Micros::ZERO);
        assert!(effs.is_empty(), "mch waits for the busy holder");
        // The safety net forces a pickup on the free executor.
        let effs = c.kick();
        assert!(matches!(effs.as_slice(), [Effect::Notify(e)] if *e == e1));
        // …but mch still declines foreign work at pickup time.
        assert!(c.on_pickup(e1, Micros::ZERO).is_empty());
    }

    #[test]
    fn release_scrubs_executor_state() {
        let mut c = core(DispatchPolicy::GoodCacheCompute);
        let (e0, _) = c.register_node(Micros::ZERO);
        let _ = c.on_pickup(e0, Micros::ZERO); // cancel reservation
        let _ = c.on_arrival(task(0, 7), 0, 0.0, Micros::ZERO);
        let _ = c.on_pickup(e0, Micros::ZERO);
        let _ = c.on_fetch_done(TaskId(0), Micros::ZERO, None);
        let _ = c.on_compute_done(TaskId(0), Micros::from_millis(5), Micros::from_millis(5));
        c.release_node(e0);
        assert_eq!(c.node_count(), 0);
        assert_eq!(c.free_count(), 0);
    }

    #[test]
    fn multi_file_tasks_chain_fetches() {
        let mut c = core(DispatchPolicy::GoodCacheCompute);
        let (e0, _) = c.register_node(Micros::ZERO);
        let t = Task {
            id: TaskId(0),
            files: vec![FileId(1), FileId(2)],
            compute: Micros::from_millis(1),
            arrival: Micros::ZERO,
        };
        let _ = c.on_arrival(t, 0, 0.0, Micros::ZERO);
        let effs = c.on_pickup(e0, Micros::ZERO);
        match effs.as_slice() {
            [Effect::Fetch(p)] => assert_eq!(p.file, FileId(1), "paper order"),
            other => panic!("{other:?}"),
        }
        let effs = c.on_fetch_done(TaskId(0), Micros::ZERO, None);
        match effs.as_slice() {
            [Effect::Fetch(p)] => assert_eq!(p.file, FileId(2)),
            other => panic!("{other:?}"),
        }
        let effs = c.on_fetch_done(TaskId(0), Micros::ZERO, None);
        assert!(matches!(effs.as_slice(), [Effect::Compute { .. }]));
        assert_eq!(c.rec.access_counts(), (0, 0, 2));
    }

    #[test]
    fn probe_holder_reads_without_perturbing() {
        let mut c = core(DispatchPolicy::GoodCacheCompute);
        let (e0, _) = c.register_node(Micros::ZERO);
        assert_eq!(c.probe_holder(FileId(7)), None);
        let _ = c.on_arrival(task(0, 7), 0, 0.0, Micros::ZERO);
        let _ = c.on_pickup(e0, Micros::ZERO);
        let _ = c.on_fetch_done(TaskId(0), Micros::ZERO, None);
        assert_eq!(c.probe_holder(FileId(7)), Some(e0));
        // Repeated probes never count as accesses or touch the caches.
        for _ in 0..10 {
            let _ = c.probe_holder(FileId(7));
        }
        assert_eq!(c.rec.access_counts(), (0, 0, 1));
        assert!(c.caching_enabled());
        assert_eq!(c.pending_allocations(), 0);
    }

    #[test]
    fn first_available_never_caches() {
        let mut c = core(DispatchPolicy::FirstAvailable);
        let (e0, _) = c.register_node(Micros::ZERO);
        let _ = c.on_pickup(e0, Micros::ZERO);
        for i in 0..2 {
            let _ = c.on_arrival(task(i, 7), 0, 0.0, Micros::ZERO);
        }
        for i in 0..2u64 {
            let effs = c.on_pickup(e0, Micros::ZERO);
            match effs.as_slice() {
                [Effect::Fetch(p)] => assert_eq!(p.kind, AccessKind::Miss),
                other => panic!("{other:?}"),
            }
            let _ = c.on_fetch_done(TaskId(i), Micros::ZERO, None);
            let _ = c.on_compute_done(TaskId(i), Micros::ZERO, Micros::ZERO);
        }
        assert_eq!(c.rec.access_counts(), (0, 0, 2));
    }

    #[test]
    fn release_defers_while_serving_peer_transfer() {
        // e0 caches file 7 and goes idle; a task on e1 fetches the file
        // peer-to-peer. While that transfer is in flight the
        // provisioner's release of the idle source must be withheld.
        let mut cfg = config(DispatchPolicy::MaxComputeUtil);
        cfg.provisioner.idle_release_s = 1.0;
        let mut c = CoordinatorCore::new(cfg, Pcg64::seeded(1));
        let (e0, _) = c.register_node(Micros::ZERO);
        let (e1, _) = c.register_node(Micros::ZERO);
        let _ = c.on_pickup(e0, Micros::ZERO);
        let _ = c.on_pickup(e1, Micros::ZERO);
        // Seed file 7 into e0's cache; keep e0 busy so the second
        // reader lands on e1.
        let _ = c.on_arrival(task(0, 7), 0, 0.0, Micros::ZERO);
        let _ = c.on_pickup(e0, Micros::ZERO);
        let _ = c.on_fetch_done(TaskId(0), Micros::ZERO, None);
        let _ = c.on_arrival(task(1, 7), 0, 0.0, Micros::ZERO);
        let effs = c.on_pickup(e1, Micros::ZERO);
        match effs.as_slice() {
            [Effect::Fetch(p)] => {
                assert_eq!(p.kind, AccessKind::HitGlobal);
                assert_eq!(p.peer, Some(e0));
            }
            other => panic!("expected a peer fetch, got {other:?}"),
        }
        assert_eq!(c.peer_serving_on(e0), 1);
        // e0 finishes its own task and goes idle well past the cutoff…
        let _ = c.on_compute_done(TaskId(0), Micros::from_millis(5), Micros::from_millis(5));
        // …but the tick must withhold its release: e1's fetch is still
        // sourced from it.
        let effs = c.on_tick(Micros::from_secs(10));
        assert!(
            !effs
                .iter()
                .any(|e| matches!(e, Effect::Release(v) if v.contains(&e0))),
            "serving peer must not be released: {effs:?}"
        );
        assert_eq!(c.release_deferrals(), 1);
        c.check_integrity().unwrap();
        // Transfer drains → the next tick releases the idle source.
        let _ = c.on_fetch_done(TaskId(1), Micros::from_secs(10), None);
        assert_eq!(c.peer_serving_on(e0), 0);
        let effs = c.on_tick(Micros::from_secs(20));
        assert!(
            effs.iter()
                .any(|e| matches!(e, Effect::Release(v) if v.contains(&e0))),
            "drained source must be released: {effs:?}"
        );
    }

    #[test]
    fn executor_failure_requeues_and_scrubs() {
        let mut c = core(DispatchPolicy::FirstCacheAvailable);
        let (e0, _) = c.register_node(Micros::ZERO);
        let (e1, _) = c.register_node(Micros::ZERO);
        let _ = c.on_pickup(e0, Micros::ZERO);
        let _ = c.on_pickup(e1, Micros::ZERO);
        // Warm e0's cache with file 7, then kill it mid-fetch of task 1
        // (a second reader of file 7: notify prefers the holder, so the
        // dispatch deterministically lands on e0).
        let _ = c.on_arrival(task(0, 7), 0, 0.0, Micros::ZERO);
        let _ = c.on_pickup(e0, Micros::ZERO);
        let _ = c.on_fetch_done(TaskId(0), Micros::ZERO, None);
        let _ = c.on_compute_done(TaskId(0), Micros::ZERO, Micros::ZERO);
        let effs = c.on_arrival(task(1, 7), 0, 0.0, Micros::ZERO);
        assert!(matches!(effs.as_slice(), [Effect::Notify(e)] if *e == e0));
        let _ = c.on_pickup(e0, Micros::ZERO);
        assert_eq!(c.dispatch_order(), &[TaskId(0), TaskId(1)]);

        let (requeued, effs) = c.on_executor_failed(e0, Micros::from_millis(1));
        assert_eq!(requeued, vec![TaskId(1)]);
        assert_eq!(c.node_count(), 1);
        // Replica accounting: e0's cached copy of file 7 is gone.
        assert_eq!(c.probe_holder(FileId(7)), None);
        // The re-queued task notifies the surviving executor.
        assert!(matches!(effs.as_slice(), [Effect::Notify(e)] if *e == e1));
        c.check_integrity().unwrap();

        // Replay: e1 picks the task up and runs it to completion.
        let effs = c.on_pickup(e1, Micros::from_millis(1));
        match effs.as_slice() {
            [Effect::Fetch(p)] => {
                assert_eq!(p.task_id, TaskId(1));
                assert_eq!(p.kind, AccessKind::Miss, "no surviving replica");
            }
            other => panic!("expected a re-dispatch fetch, got {other:?}"),
        }
        let _ = c.on_fetch_done(TaskId(1), Micros::from_millis(2), None);
        let _ = c.on_compute_done(TaskId(1), Micros::from_millis(7), Micros::from_millis(7));
        assert_eq!(c.rec.tasks_done(), 2);
        c.check_integrity().unwrap();

        // Events aimed at the dead executor are no-ops.
        assert!(c.on_pickup(e0, Micros::from_millis(8)).is_empty());
        let (r, e) = c.on_executor_failed(e0, Micros::from_millis(8));
        assert!(r.is_empty() && e.is_empty());
    }
}
