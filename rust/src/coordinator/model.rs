//! Model-predictive provisioning — the §3 model as a closed-loop
//! controller (`--allocation model`, docs/PROVISIONING.md).
//!
//! The offline model ([`crate::model::predict`]) maps a workload
//! description (arrival rate, per-task compute, object size, hit-rate
//! split) and a fleet size to a predicted makespan `W`. Fig 2 validates
//! that mapping against the simulator; this module *acts* on it: each
//! provisioner tick, [`ModelController::decide`] estimates the workload
//! signals from the [`Recorder`](crate::metrics::Recorder)'s per-second
//! time series, calls the pure solver [`solve`] for the node count that
//! maximizes the performance index, and installs the result as the
//! [`Provisioner`](crate::coordinator::provisioner::Provisioner)'s fleet
//! target. Allocate/Release still flow through the existing effect API —
//! the controller only moves the target.
//!
//! ## The objective
//!
//! The summary's performance index is `PI = speedup / cpu_hours` where
//! `speedup = W_base / W` for a workload-fixed baseline and `cpu_hours`
//! integrates *registered* slot capacity over the run — so for a fleet
//! of `n` nodes held for the makespan, `cpu_hours ∝ n·W`. Hence
//! `PI ∝ 1 / (n · W²)` with the baseline cancelling in the argmax: the
//! solver scans `n ∈ [min_nodes, max_nodes]`, predicts `W(n)` through
//! the §3 fixed point (store contention included), and picks the
//! smallest `n` maximizing `1/(n·W²)`. Below the arrival-saturation
//! knee `W` shrinks like `1/n` so the score grows; above it `W` is
//! pinned by the arrival rate and the score decays like `1/n` — the
//! optimum sits exactly at the knee, which moves up with arrival
//! pressure (the monotonicity property pinned in the unit suite).
//!
//! ## Stability
//!
//! A feedback controller that re-solves every second will oscillate if
//! the adopted target chases every ±1 wobble of the estimate. Two
//! mechanisms damp it: signals are averaged over a sliding window
//! (`window_s`), and a new solve only displaces the standing target
//! when it moves by more than the deadband (`deadband` fraction of the
//! current target, at least 1 node). On a steady-state workload the
//! solve is a pure function of converged inputs, so the target is a
//! fixed point — asserted bit-for-bit by the property tests below.

use crate::metrics::Recorder;
use crate::model::{predict, ModelInputs};
use crate::util::units::gbps_to_bps;

/// Per-task compute assumed before the first completion feeds the EWMA
/// (the fig02 workload's 100 ms).
const DEFAULT_MU_S: f64 = 0.1;

/// EWMA smoothing for observed per-task compute times.
const MU_ALPHA: f64 = 0.2;

/// Controller tuning. Defaults mirror
/// [`ClusterConfig`](crate::config::ClusterConfig) (ANL/UC TeraGrid
/// rates); the sim engine overwrites them from the experiment's actual
/// cluster description.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ModelControllerConfig {
    /// Persistent-store (GPFS) aggregate bandwidth, Gb/s.
    pub persistent_gbps: f64,
    /// Local-disk read bandwidth, Gb/s.
    pub local_disk_gbps: f64,
    /// Per-task dispatch + network overhead, seconds.
    pub overhead_s: f64,
    /// Never target fewer nodes than this (the coordinator itself needs
    /// a fleet to measure).
    pub min_nodes: usize,
    /// Sliding signal-estimation window, seconds (recorder buckets).
    pub window_s: usize,
    /// Deadband as a fraction of the standing target: a new solve is
    /// adopted only when it moves by more than `max(1, ceil(cur·band))`
    /// nodes.
    pub deadband: f64,
}

impl Default for ModelControllerConfig {
    fn default() -> Self {
        ModelControllerConfig {
            persistent_gbps: 4.4,
            local_disk_gbps: 1.6,
            // 600 µs dispatch + one 2 ms network round trip each way.
            overhead_s: 600.0 / 1e6 + 2.0 * 2.0 / 1e3,
            min_nodes: 1,
            window_s: 30,
            deadband: 0.15,
        }
    }
}

/// Everything the pure solver looks at. Constructed by
/// [`ModelController::decide`]; exposed so tests (and the fig02
/// consistency suite) can drive the solver directly.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SolveInputs {
    /// Current wait-queue length (the model's outstanding `|K|`).
    pub queue_len: usize,
    /// Estimated task arrival rate, tasks/s (`f64::INFINITY` = batch:
    /// everything already queued).
    pub arrival_rate: f64,
    /// Mean per-task compute, seconds.
    pub mu_s: f64,
    /// Per-task dispatch + network overhead, seconds.
    pub overhead_s: f64,
    /// Mean object size, bytes.
    pub object_bytes: f64,
    /// Fraction of accessed bytes missing to persistent storage.
    pub p_miss: f64,
    /// Fraction of accessed bytes served from the local cache.
    pub p_local: f64,
    /// Persistent-store bandwidth, bits/s.
    pub persistent_bps: f64,
    /// Local-disk bandwidth, bits/s.
    pub transient_bps: f64,
    /// CPU slots per node.
    pub cpus_per_node: u32,
    /// Smallest admissible fleet.
    pub min_nodes: usize,
    /// Largest admissible fleet (the cluster/shard quota).
    pub max_nodes: usize,
}

/// The solver's answer for one set of inputs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SolveDecision {
    /// Fleet size maximizing the performance-index score.
    pub nodes: usize,
    /// The winning score, `1 / (n · W²)` (0.0 on the idle fast path).
    pub score: f64,
    /// Predicted makespan at `nodes`, seconds.
    pub w: f64,
    /// Predicted efficiency at `nodes`.
    pub efficiency: f64,
}

/// Solve the §3 model for the performance-index-maximizing fleet size.
///
/// Pure and deterministic: bit-equal outputs for bit-equal inputs (no
/// ambient state, no randomness — the property suite asserts this). An
/// idle stream (`queue_len == 0` and no measurable arrivals) short-
/// circuits to `min_nodes`. Ties break to the smallest fleet.
pub fn solve(inp: &SolveInputs) -> SolveDecision {
    let lo = inp.min_nodes.max(1).min(inp.max_nodes.max(1));
    let hi = inp.max_nodes.max(lo);
    if inp.queue_len == 0 && !(inp.arrival_rate > 0.0) {
        return SolveDecision {
            nodes: lo,
            score: 0.0,
            w: 0.0,
            efficiency: 0.0,
        };
    }
    // A vanished arrival estimate with work still queued is a drained
    // burst: batch semantics (everything outstanding, nothing more
    // coming) keep the store-saturation knee meaningful.
    let arrival_rate = if inp.arrival_rate > 0.0 {
        inp.arrival_rate
    } else {
        f64::INFINITY
    };
    let mut best: Option<SolveDecision> = None;
    for n in lo..=hi {
        let m = ModelInputs {
            num_tasks: inp.queue_len.max(1) as f64,
            cpus: (n as f64 * inp.cpus_per_node.max(1) as f64).max(1.0),
            mu_s: inp.mu_s,
            overhead_s: inp.overhead_s,
            object_bytes: inp.object_bytes,
            arrival_rate,
            persistent_bps: inp.persistent_bps,
            transient_bps: inp.transient_bps,
            p_miss: inp.p_miss,
            p_local: inp.p_local,
        };
        let p = predict(&m);
        let w = p.w.max(1e-12);
        let score = 1.0 / (n as f64 * w * w);
        // Strict > keeps the smallest node count on score plateaus.
        if best.is_none_or(|b| score > b.score) {
            best = Some(SolveDecision {
                nodes: n,
                score,
                w: p.w,
                efficiency: p.efficiency,
            });
        }
    }
    best.expect("solve scans at least one candidate")
}

/// Largest-remainder apportionment of `total` nodes across shards by
/// non-negative weight, each shard floored at `floor` (reduced if
/// `total` cannot cover it). The result always sums to exactly `total`;
/// zero total weight degrades to an even split. Ties in the remainder
/// go to the lowest shard index, so the split is deterministic.
pub fn apportion(total: usize, weights: &[f64], floor: usize) -> Vec<usize> {
    let k = weights.len();
    if k == 0 {
        return Vec::new();
    }
    let floor = floor.min(total / k);
    let pool = total - floor * k;
    let mut out = vec![floor; k];
    if pool == 0 {
        return out;
    }
    let wsum: f64 = weights.iter().map(|w| w.max(0.0)).sum();
    let shares: Vec<f64> = if wsum > 0.0 {
        weights
            .iter()
            .map(|w| w.max(0.0) / wsum * pool as f64)
            .collect()
    } else {
        vec![pool as f64 / k as f64; k]
    };
    let mut assigned = 0usize;
    let mut rem: Vec<(usize, f64)> = Vec::with_capacity(k);
    for (i, s) in shares.iter().enumerate() {
        let fl = s.floor() as usize;
        out[i] += fl;
        assigned += fl;
        rem.push((i, s - fl));
    }
    rem.sort_by(|a, b| {
        b.1.partial_cmp(&a.1)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.0.cmp(&b.0))
    });
    for &(i, _) in rem.iter().take(pool - assigned) {
        out[i] += 1;
    }
    out
}

/// Per-decision counters, surfaced as `model/*` bench counters and the
/// run summary's controller line.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct ModelStats {
    /// Solver invocations (one per provisioner tick once signals exist).
    pub solves: u64,
    /// Adopted-target movements (the churn the deadband is damping).
    pub target_changes: u64,
    /// Solves whose answer was inside the deadband and ignored.
    pub deadband_holds: u64,
}

/// The online controller: signal estimation + solver + deadband, one
/// instance per [`CoordinatorCore`](crate::coordinator::core) running
/// under [`AllocationPolicy::Model`](super::provisioner::AllocationPolicy).
#[derive(Debug, Clone)]
pub struct ModelController {
    /// Tuning (rates, window, deadband).
    pub config: ModelControllerConfig,
    cpus_per_node: u32,
    object_bytes: f64,
    mu_ewma: Option<f64>,
    target: Option<usize>,
    /// Decision counters.
    pub stats: ModelStats,
}

impl ModelController {
    /// New controller for nodes exposing `cpus_per_node` slots over
    /// objects of `object_bytes` mean size.
    pub fn new(config: ModelControllerConfig, cpus_per_node: u32, object_bytes: f64) -> Self {
        ModelController {
            config,
            cpus_per_node,
            object_bytes,
            mu_ewma: None,
            target: None,
            stats: ModelStats::default(),
        }
    }

    /// Feed one observed per-task compute time (seconds) into the μ
    /// estimate. The core calls this on every arrival with the task's
    /// declared compute, so the estimate leads the completions.
    pub fn observe_compute(&mut self, compute_s: f64) {
        if !(compute_s > 0.0) {
            return;
        }
        self.mu_ewma = Some(match self.mu_ewma {
            None => compute_s,
            Some(prev) => MU_ALPHA * compute_s + (1.0 - MU_ALPHA) * prev,
        });
    }

    /// The standing adopted target, if any solve has happened.
    pub fn target(&self) -> Option<usize> {
        self.target
    }

    /// Estimate workload signals from the recorder's trailing window.
    /// Exposed for the fig02 consistency test.
    pub fn estimate(&self, rec: &Recorder, queue_len: usize, max_nodes: usize) -> SolveInputs {
        let buckets = rec.ts.buckets();
        let start = buckets.len().saturating_sub(self.config.window_s.max(1));
        let win = &buckets[start..];
        let secs = win.len().max(1) as f64;
        let arrivals: u64 = win.iter().map(|b| b.arrivals as u64).sum();
        let (mut local, mut remote, mut gpfs) = (0u64, 0u64, 0u64);
        for b in win {
            local += b.bytes_local;
            remote += b.bytes_remote;
            gpfs += b.bytes_gpfs;
        }
        let total = local + remote + gpfs;
        // Before any byte moves, assume everything misses — the model
        // then provisions for cold caches, the conservative direction.
        let (p_local, p_miss) = if total == 0 {
            (0.0, 1.0)
        } else {
            (local as f64 / total as f64, gpfs as f64 / total as f64)
        };
        SolveInputs {
            queue_len,
            arrival_rate: arrivals as f64 / secs,
            mu_s: self.mu_ewma.unwrap_or(DEFAULT_MU_S),
            overhead_s: self.config.overhead_s,
            object_bytes: self.object_bytes,
            p_miss,
            p_local,
            persistent_bps: gbps_to_bps(self.config.persistent_gbps),
            transient_bps: gbps_to_bps(self.config.local_disk_gbps),
            cpus_per_node: self.cpus_per_node,
            min_nodes: self.config.min_nodes,
            max_nodes,
        }
    }

    /// One control step: estimate → solve → deadband → adopted target.
    /// `max_nodes` is the caller's current quota (the sharded router
    /// rebalances it between ticks).
    pub fn decide(&mut self, rec: &Recorder, queue_len: usize, max_nodes: usize) -> usize {
        let inputs = self.estimate(rec, queue_len, max_nodes);
        let solved = solve(&inputs).nodes;
        self.stats.solves += 1;
        let adopted = match self.target {
            None => solved,
            Some(cur) => {
                let band = ((cur as f64 * self.config.deadband).ceil() as usize).max(1);
                if solved.abs_diff(cur) > band {
                    solved
                } else {
                    cur
                }
            }
        };
        // The quota may have shrunk under a standing target.
        let adopted = adopted.min(max_nodes).max(inputs.min_nodes.min(max_nodes));
        if self.target != Some(adopted) {
            if self.target.is_some() {
                self.stats.target_changes += 1;
            }
            self.target = Some(adopted);
        } else if adopted != solved {
            self.stats.deadband_holds += 1;
        }
        adopted
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base_inputs() -> SolveInputs {
        SolveInputs {
            queue_len: 500,
            arrival_rate: 50.0,
            mu_s: 0.1,
            overhead_s: 0.0046,
            object_bytes: 1e7,
            p_miss: 0.3,
            p_local: 0.6,
            persistent_bps: gbps_to_bps(4.4),
            transient_bps: gbps_to_bps(1.6),
            cpus_per_node: 2,
            min_nodes: 1,
            max_nodes: 64,
        }
    }

    /// Satellite: more arrival pressure never lowers the solved fleet.
    #[test]
    fn solved_nodes_are_monotone_in_arrival_rate() {
        let mut prev = 0usize;
        for rate in [1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0, 200.0, 500.0] {
            let d = solve(&SolveInputs {
                arrival_rate: rate,
                ..base_inputs()
            });
            assert!(
                d.nodes >= prev,
                "rate {rate}: solved {} < previous {prev}",
                d.nodes
            );
            prev = d.nodes;
        }
        // And the pressure actually moves the knee somewhere in range.
        assert!(prev > 1, "high arrival pressure should grow the fleet");
    }

    /// Satellite: min/max clamping.
    #[test]
    fn solve_clamps_to_the_admissible_range() {
        // Batch pressure wants everything; the cap binds.
        let d = solve(&SolveInputs {
            arrival_rate: f64::INFINITY,
            max_nodes: 8,
            ..base_inputs()
        });
        assert!(d.nodes <= 8);
        // An idle stream collapses to min_nodes.
        let d = solve(&SolveInputs {
            queue_len: 0,
            arrival_rate: 0.0,
            min_nodes: 3,
            ..base_inputs()
        });
        assert_eq!(d.nodes, 3);
        // min_nodes floors even under mild load.
        let d = solve(&SolveInputs {
            arrival_rate: 0.001,
            queue_len: 1,
            min_nodes: 5,
            ..base_inputs()
        });
        assert!(d.nodes >= 5);
        // Degenerate range: min > max resolves to max.
        let d = solve(&SolveInputs {
            min_nodes: 100,
            max_nodes: 8,
            ..base_inputs()
        });
        assert_eq!(d.nodes, 8);
    }

    /// Satellite: the solver is a pure function — bit-equal outputs
    /// across repeated calls on the same inputs.
    #[test]
    fn solve_is_bit_equal_across_repeated_calls() {
        let inp = base_inputs();
        let first = solve(&inp);
        for _ in 0..100 {
            let again = solve(&inp);
            assert_eq!(again.nodes, first.nodes);
            assert_eq!(again.score.to_bits(), first.score.to_bits());
            assert_eq!(again.w.to_bits(), first.w.to_bits());
            assert_eq!(again.efficiency.to_bits(), first.efficiency.to_bits());
        }
    }

    /// Satellite: fixed-point stability — on a steady-state workload the
    /// adopted target settles and never oscillates.
    #[test]
    fn steady_state_target_does_not_oscillate() {
        let mut rec = Recorder::default();
        let mut ctl = ModelController::new(ModelControllerConfig::default(), 2, 1e7);
        // A steady 40 tasks/s stream with a stable byte mix.
        for s in 0..120u64 {
            let now = crate::util::time::Micros::from_secs(s);
            let b = rec.ts.bucket_mut(s);
            b.arrivals += 40;
            b.bytes_local += 6_000;
            b.bytes_gpfs += 1_000;
            rec.sample(now, 100, 8, 10, 16);
        }
        let first = ctl.decide(&rec, 100, 64);
        for _ in 0..200 {
            let again = ctl.decide(&rec, 100, 64);
            assert_eq!(again, first, "steady inputs must hold the target");
        }
        assert_eq!(ctl.stats.target_changes, 0, "no churn after adoption");
        assert_eq!(ctl.target(), Some(first));
    }

    /// The deadband swallows ±1 estimate wobble but passes real shifts.
    #[test]
    fn deadband_damps_small_wobble_and_admits_regime_changes() {
        let mut ctl = ModelController::new(
            ModelControllerConfig {
                window_s: 1,
                ..ModelControllerConfig::default()
            },
            2,
            1e7,
        );
        let mut rec = Recorder::default();
        let mk = |rec: &mut Recorder, sec: u64, rate: u32| {
            let now = crate::util::time::Micros::from_secs(sec);
            rec.ts.bucket_mut(sec).arrivals += rate;
            rec.sample(now, 50, 4, 4, 8);
        };
        mk(&mut rec, 0, 40);
        let t1 = ctl.decide(&rec, 50, 64);
        // 10x the arrival pressure: the target must move despite the
        // deadband.
        mk(&mut rec, 1, 400);
        let t2 = ctl.decide(&rec, 50, 64);
        assert!(t2 > t1, "regime change must punch through ({t1} → {t2})");
        assert!(ctl.stats.target_changes >= 1);
    }

    #[test]
    fn compute_ewma_tracks_observations() {
        let mut ctl = ModelController::new(ModelControllerConfig::default(), 2, 1e7);
        let rec = Recorder::default();
        // Default μ before any observation.
        let inp = ctl.estimate(&rec, 10, 64);
        assert_eq!(inp.mu_s, DEFAULT_MU_S);
        ctl.observe_compute(2.0);
        assert_eq!(ctl.estimate(&rec, 10, 64).mu_s, 2.0);
        ctl.observe_compute(1.0);
        let mu = ctl.estimate(&rec, 10, 64).mu_s;
        assert!(mu < 2.0 && mu > 1.0, "EWMA blends: {mu}");
        // Garbage observations are ignored.
        ctl.observe_compute(0.0);
        ctl.observe_compute(-5.0);
        ctl.observe_compute(f64::NAN);
        assert_eq!(ctl.estimate(&rec, 10, 64).mu_s, mu);
    }

    #[test]
    fn cold_start_assumes_all_misses() {
        let ctl = ModelController::new(ModelControllerConfig::default(), 2, 1e7);
        let rec = Recorder::default();
        let inp = ctl.estimate(&rec, 10, 64);
        assert_eq!(inp.p_miss, 1.0);
        assert_eq!(inp.p_local, 0.0);
    }

    #[test]
    fn apportion_conserves_total_and_respects_floor() {
        let q = apportion(8, &[3.0, 1.0, 0.0, 0.0], 1);
        assert_eq!(q.iter().sum::<usize>(), 8);
        assert!(q.iter().all(|&n| n >= 1), "floor of one per shard: {q:?}");
        assert!(q[0] > q[1], "weight orders the split: {q:?}");
        // Zero weights degrade to an even split.
        let q = apportion(8, &[0.0; 4], 1);
        assert_eq!(q, vec![2, 2, 2, 2]);
        // Floor infeasible for the total: reduced, never panics.
        let q = apportion(2, &[1.0; 4], 1);
        assert_eq!(q.iter().sum::<usize>(), 2);
        // Deterministic across calls.
        assert_eq!(
            apportion(13, &[0.2, 0.2, 0.3], 1),
            apportion(13, &[0.2, 0.2, 0.3], 1)
        );
        assert!(apportion(4, &[], 1).is_empty());
    }
}
