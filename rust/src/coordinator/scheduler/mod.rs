//! The data-aware scheduler (§3.2) — the heart of data diffusion.
//!
//! The scheduler is split in two parts, exactly as in the paper:
//!
//! 1. **Notification** ([`Scheduler::select_notify`]): given the task at
//!    the head of the wait queue (T₀), score candidate executors by how
//!    many of the task's files they cache (via the I_map), and pick the
//!    best *free* candidate to notify that work is available. Policy
//!    decides the fallback when no preferred executor is free.
//! 2. **Pickup** ([`Scheduler::pick_tasks`]): when an executor asks for
//!    work, consider a *scheduling window* of up to W tasks from the
//!    queue head, score each by its local cache-hit fraction
//!    (|fileSet ∩ E_map(executor)| / |fileSet|), dispatch any 100 %-hit
//!    task immediately, and otherwise dispatch the m best-scoring
//!    eligible tasks. Policy decides eligibility of 0-hit tasks.
//!
//! ## §Perf iteration 3 — sub-linear pickup
//!
//! Iterations 1–2 (scratch-buffer reuse, hoisted E_map lookups, the
//! cold-start early exit) still paid the O(min(|Q|, W)) scan per pickup
//! — 3200–6400 probed window entries at 32–64 nodes, the throughput
//! ceiling the paper's §5.1 microbench measures. Iteration 3 removes the
//! scan from the common path entirely:
//!
//! * the [`PendingIndex`](crate::coordinator::pending::PendingIndex)
//!   materializes, per executor, the queued tasks with ≥ 1 cached file
//!   (the intersection of E_map(executor) with the pending set), ordered
//!   by queue sequence number;
//! * [`WaitQueue::window_boundary_seq`] makes "inside the window?" an
//!   O(1) integer comparison (amortized-O(1) boundary cursor);
//! * pickup enumerates the candidate set in queue order, stopping at the
//!   first 100 %-hit task — cost proportional to the executor's **actual
//!   cache overlap with the window**, not the window size;
//! * only when the candidates cannot fill the batch does a **bounded
//!   head scan** classify zero-hit tasks (classes 2/3/4), and since every
//!   window task with a local hit is in the candidate set, that scan
//!   needs no cache probes and exits at the first class-2 single-file
//!   task in the m = 1 case.
//!
//! Per-decision complexity is O(|θ(κ)| + replication + overlap) on the
//! hit path — strictly below the paper's claimed
//! O(|θ(κ)| + replication + min(|Q|, W)) bound, which remains the
//! worst case (cold caches, max-cache-hit with every holder busy).
//! `cargo bench --bench perf_hotpath` tracks both the per-pickup cost
//! and the `tasks_inspected`-per-pickup ratio.
//!
//! Decisions are **bit-identical** to the plain window scan: same tasks,
//! same order, same deterministic tie-break (class asc, misses asc,
//! queue order). [`Scheduler::pick_refs_reference`] retains the O(W)
//! scan as the executable specification, and the `sched_parity`
//! differential property test asserts equality across all five policies.
//!
//! ## §Perf iteration 4 — epoch-lazy candidates + notify-side reuse
//!
//! Iteration 3 made the pickup sub-linear but left two per-event costs
//! (ROADMAP items, both closed here):
//!
//! * **Candidate maintenance**: a cache insert/evict of a popular file
//!   walked every pending reader. The pickup now consults the pending
//!   index through [`PendingIndex::refresh`](crate::coordinator::pending::PendingIndex::refresh)
//!   — cache events are O(1)-bounded bookkeeping, settled lazily at the
//!   consult (see [`crate::coordinator::pending`] for the epoch
//!   invariants). Lazily maintained entries are *hints*: phase A
//!   validates each against the queue
//!   ([`WaitQueue::live_seq`](crate::coordinator::queue::WaitQueue::live_seq),
//!   O(1)) and purges dead ones on encounter, so dispatch decisions stay
//!   bit-identical to the eager reference.
//! * **Notify scoring**: [`Scheduler::select_notify`] used to rebuild a
//!   per-executor overlap count from the holder sets on every call. The
//!   single-file fast path (the paper's workload shape) never counted;
//!   the multi-file path now consults
//!   [`PendingIndex::head_ranked`](crate::coordinator::pending::PendingIndex::head_ranked)
//!   — a ranking memoized per (head file set, index epoch) — and only
//!   probes free-ness per call. [`SchedulerStats::holder_recounts`] is a
//!   tripwire for the retired per-call recount: it stays 0 on the
//!   indexed path, `perf_hotpath` snapshots it, and the CI bench gate
//!   fails if it ever moves.

pub mod policy;

pub use policy::DispatchPolicy;

use crate::coordinator::executor::ExecutorRegistry;
use crate::coordinator::pending::{remove_queued, PendingIndex};
use crate::coordinator::queue::{QueueRef, Task, WaitQueue};
use crate::ids::{ExecutorId, FileId};
use crate::index::LocationIndex;

/// Scheduler tuning knobs (§3.2, §5.1).
#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// Dispatch policy (paper policies 1–5).
    pub policy: DispatchPolicy,
    /// Scheduling window W = `window_multiplier` × registered executors
    /// (paper: 100× → 3200 at 32 nodes).
    pub window_multiplier: usize,
    /// good-cache-compute heuristic 1: CPU-utilization threshold that
    /// switches between max-cache-hit behaviour (util ≥ threshold) and
    /// max-compute-util behaviour (util < threshold). Paper: 0.8 in the
    /// empirical section.
    pub cpu_util_threshold: f64,
    /// good-cache-compute heuristic 2: maximum replicas of a data object
    /// before the scheduler stops diffusing additional copies.
    pub max_replication: usize,
    /// Maximum tasks handed to an executor per pickup (m in §3.2).
    pub max_tasks_per_pickup: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            policy: DispatchPolicy::GoodCacheCompute,
            window_multiplier: 100,
            cpu_util_threshold: 0.8,
            max_replication: 2,
            max_tasks_per_pickup: 1,
        }
    }
}

/// Why phase 1 chose (or declined to choose) an executor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NotifyOutcome {
    /// Notify this executor; it caches ≥1 of the task's files.
    Preferred(ExecutorId),
    /// No free preferred executor; fall back to the next free executor.
    Fallback(ExecutorId),
    /// Policy says wait (max-cache-hit semantics: a preferred executor
    /// exists but is busy; dispatch is delayed until it frees).
    Wait,
    /// Nothing is free at all.
    NoneFree,
}

/// Counters the Figure 3 microbench reports (per-decision cost breakdown).
#[derive(Debug, Default, Clone)]
pub struct SchedulerStats {
    /// Phase-1 decisions taken.
    pub notify_decisions: u64,
    /// Phase-2 pickups served.
    pub pickups: u64,
    /// Tasks dispatched.
    pub tasks_dispatched: u64,
    /// Tasks examined across all pickups: indexed candidates plus
    /// zero-hit fallback-scan entries. Under the plain window scan this
    /// was ~window-size per pickup; the indexed pickup drops it to
    /// ~cache-overlap-size (the perf_hotpath bench reports the ratio).
    pub tasks_inspected: u64,
    /// Tasks dispatched with a 100 % local-hit score.
    pub full_hit_dispatches: u64,
    /// Per-call holder-overlap recounts in `select_notify` — the cost the
    /// memoized ranking retired. Nothing on the indexed path increments
    /// this; it exists as a tripwire (snapshotted by `perf_hotpath`,
    /// asserted == 0 by `tools/bench_gate.py`) so a future change that
    /// reintroduces per-call recounting fails CI instead of silently
    /// regressing the Fig 3 notify column.
    pub holder_recounts: u64,
}

/// The data-aware scheduler. Pure logic: no clocks, no I/O — both the
/// discrete-event engine and the live engine drive it.
#[derive(Debug)]
pub struct Scheduler {
    /// Tuning knobs.
    pub config: SchedulerConfig,
    /// Rotating hint so first-available round-robins over free executors.
    next_free_hint: u32,
    /// Cost/behaviour counters.
    pub stats: SchedulerStats,
    /// Scratch buffer for partial candidates — (class, misses, seq, ref)
    /// (perf: §Perf iteration 1 — reuse instead of re-allocating).
    partial_scratch: Vec<(u8, usize, u64, QueueRef)>,
    /// Scratch for dead candidate hints found during phase A (lazily
    /// maintained entries whose task already left the queue; purged from
    /// the pending index after the selection — §Perf iteration 4).
    dead_scratch: Vec<u64>,
}

impl Scheduler {
    /// New scheduler with the given configuration.
    pub fn new(config: SchedulerConfig) -> Self {
        Scheduler {
            config,
            next_free_hint: 0,
            stats: SchedulerStats::default(),
            partial_scratch: Vec::new(),
            dead_scratch: Vec::new(),
        }
    }

    /// Effective scheduling window for the current cluster size.
    pub fn window_size(&self, registry: &ExecutorRegistry) -> usize {
        (self.config.window_multiplier * registry.len()).max(1)
    }

    /// Current rotating free-executor hint (exposed for the differential
    /// parity tests, which replay the rotation logic).
    #[doc(hidden)]
    pub fn free_hint(&self) -> ExecutorId {
        ExecutorId(self.next_free_hint)
    }

    /// **Phase 1 — notification.** Choose an executor to notify for the
    /// task with files `files` at the head of the wait queue.
    ///
    /// The decision reuses the pending machinery instead of recounting
    /// holder overlap per call (§Perf iteration 4): single-file heads
    /// take the bitset fast path (every holder scores 1 — no counting to
    /// do), multi-file heads consult the ranking
    /// [`PendingIndex::head_ranked`] memoizes per (file set, index
    /// epoch), so repeated notifies for one head — the saturated-cluster
    /// pattern — only probe free-ness. `pending` is untouched for
    /// first-available (which never uses it).
    pub fn select_notify(
        &mut self,
        files: &[FileId],
        registry: &ExecutorRegistry,
        pending: &mut PendingIndex,
        index: &LocationIndex,
    ) -> NotifyOutcome {
        self.stats.notify_decisions += 1;
        if registry.free_count() == 0 {
            return NotifyOutcome::NoneFree;
        }
        let policy = self.config.policy;
        if policy == DispatchPolicy::FirstAvailable {
            return match self.rotate_free(registry) {
                Some(e) => NotifyOutcome::Fallback(e),
                None => NotifyOutcome::NoneFree,
            };
        }

        let mut any_holder = false;
        let mut best: Option<ExecutorId> = None;
        if let [f] = files {
            // Single-file fast path (the paper's workload shape): every
            // holder scores 1, so the best free candidate is the first
            // free holder in ascending-id bitset order — same tie-break
            // as the ranked path, no ranking needed.
            if let Some(holders) = index.holders(*f) {
                for e in holders {
                    any_holder = true;
                    if registry.is_free(e) {
                        best = Some(e);
                        break;
                    }
                }
            }
        } else {
            // Multi-file: the memoized (overlap desc, id asc) ranking.
            // The first free entry is exactly the reference tie-break's
            // winner; overlap is never recounted here.
            let ranked = pending.head_ranked(files, index);
            any_holder = !ranked.is_empty();
            for &(e, _overlap) in ranked {
                if registry.is_free(e) {
                    best = Some(e);
                    break;
                }
            }
        }
        if let Some(e) = best {
            return NotifyOutcome::Preferred(e);
        }

        if policy == DispatchPolicy::FirstCacheAvailable {
            // No free executor holds the data: fall back immediately.
            return match self.rotate_free(registry) {
                Some(e) => NotifyOutcome::Fallback(e),
                None => NotifyOutcome::NoneFree,
            };
        }

        let wait_for_holder = match policy {
            DispatchPolicy::MaxCacheHit => true,
            DispatchPolicy::MaxComputeUtil => false,
            DispatchPolicy::GoodCacheCompute => {
                registry.cpu_utilization() >= self.config.cpu_util_threshold
            }
            DispatchPolicy::FirstAvailable | DispatchPolicy::FirstCacheAvailable => {
                unreachable!("handled above")
            }
        };
        if any_holder && wait_for_holder {
            // Data is cached somewhere but every holder is busy: delay
            // dispatch until the holder frees (max-cache-hit semantics).
            NotifyOutcome::Wait
        } else {
            // Data cached nowhere (bootstrap miss) or policy prefers
            // utilization: send to the next free executor.
            match self.rotate_free(registry) {
                Some(e) => NotifyOutcome::Fallback(e),
                None => NotifyOutcome::NoneFree,
            }
        }
    }

    /// **Phase 2 — pickup.** The executor `exec` is asking for work:
    /// select and remove up to `limit` window tasks for it (the engine
    /// passes `min(max_tasks_per_pickup, free slots)`). Returns the
    /// dispatched tasks (possibly empty — the paper's "no tasks
    /// returned" outcome sends the executor back to the free pool).
    ///
    /// Decisions are bit-identical to [`Scheduler::pick_refs_reference`]
    /// (the plain O(W) scan); the cost is sub-linear in W via the
    /// inverted pending index — see the module docs.
    pub fn pick_tasks(
        &mut self,
        exec: ExecutorId,
        limit: usize,
        queue: &mut WaitQueue,
        pending: &mut PendingIndex,
        registry: &ExecutorRegistry,
        index: &LocationIndex,
    ) -> Vec<Task> {
        self.stats.pickups += 1;
        let m = limit.max(1);
        if queue.is_empty() {
            return Vec::new();
        }

        // first-available ignores data location entirely: O(1) head pops.
        // (The pending index is not maintained for it; removal through
        // `remove_queued` is a safe no-op on the empty index.)
        if self.config.policy == DispatchPolicy::FirstAvailable {
            let mut out = Vec::with_capacity(m);
            while out.len() < m {
                let Some(qref) = queue.front_ref() else { break };
                out.push(remove_queued(queue, pending, qref, index));
            }
            self.stats.tasks_dispatched += out.len() as u64;
            return out;
        }

        let refs = self.select_refs(exec, m, queue, pending, registry, index);
        let tasks: Vec<Task> = refs
            .into_iter()
            .map(|r| remove_queued(queue, pending, r, index))
            .collect();
        self.stats.tasks_dispatched += tasks.len() as u64;
        tasks
    }

    /// The indexed selection (data-aware policies). Chooses up to `m`
    /// window tasks without removing them; see the module docs for the
    /// phase structure and the parity argument.
    fn select_refs(
        &mut self,
        exec: ExecutorId,
        m: usize,
        queue: &mut WaitQueue,
        pending: &mut PendingIndex,
        registry: &ExecutorRegistry,
        index: &LocationIndex,
    ) -> Vec<QueueRef> {
        let window = self.window_size(registry);
        // Amortized O(1): "in the window" becomes `seq < boundary`.
        let boundary = queue.window_boundary_seq(window);
        let mcu_mode = self.mcu_mode(registry);
        let mut inspected = 0u64;

        // Settle the epoch-lazy maintenance debt for this executor before
        // consulting its candidate set (O(1) when nothing changed since
        // the last consult — see coordinator::pending).
        pending.refresh(exec, queue, index);

        // Phase A — enumerate indexed candidates (tasks with ≥1 file
        // cached at `exec`) in queue order; cost ∝ cache overlap.
        let mut fulls: Vec<QueueRef> = Vec::new();
        let mut partial = std::mem::take(&mut self.partial_scratch);
        partial.clear();
        let mut dead = std::mem::take(&mut self.dead_scratch);
        dead.clear();
        if let Some(cands) = pending.candidates(exec) {
            for (seq, qref) in cands.iter() {
                if boundary.is_some_and(|b| seq >= b) {
                    break; // past the window boundary; so is everything later
                }
                // Refreshed entries are exact for live tasks, but a dead
                // hint can linger (pending.rs invariant 2): validate in
                // O(1) and purge on encounter.
                if queue.live_seq(qref) != Some(seq) {
                    dead.push(seq);
                    continue;
                }
                inspected += 1;
                let task = queue.get(qref);
                let nfiles = task.files.len().max(1);
                let hits = task
                    .files
                    .iter()
                    .filter(|&&f| index.holds(f, exec))
                    .count();
                debug_assert!(hits > 0, "candidate set contains a zero-hit task");
                if hits == nfiles {
                    // 100 % local hit: dispatched in queue order, exactly
                    // like the reference scan's first-m full hits.
                    fulls.push(qref);
                    if fulls.len() == m {
                        break;
                    }
                } else {
                    partial.push((1, nfiles - hits, seq, qref));
                }
            }
        }
        self.stats.full_hit_dispatches += fulls.len() as u64;

        if fulls.len() + partial.len() < m {
            // Phase B — bounded head-scan fallback for the zero-hit
            // classes. A window task has ≥1 local hit iff its seq is in
            // the candidate set (Phase A handled those), so skipping is
            // one candidate-map probe and the scan needs no cache
            // probes or scratch allocation; with m == 1 it stops at the
            // first class-2 single-file task (nothing later can beat
            // (2, 1, earlier-seq) under the tie-break).
            let cands = pending.candidates(exec);
            for (qref, task) in queue.window(window) {
                let seq = queue.seq_of(qref);
                if cands.is_some_and(|c| c.contains(seq)) {
                    continue;
                }
                inspected += 1;
                let class = self.zero_hit_class(task, index, mcu_mode);
                if class == u8::MAX {
                    continue;
                }
                let nfiles = task.files.len().max(1);
                partial.push((class, nfiles, seq, qref));
                if m == 1 && class == 2 && nfiles == 1 {
                    break;
                }
            }
        }
        self.stats.tasks_inspected += inspected;

        // Drop the dead hints phase A encountered so they are never
        // revisited (the set may keep others past the early-stop point;
        // they die at their own encounter or at an overflow rebuild).
        if !dead.is_empty() {
            pending.purge_dead(exec, &dead);
        }
        self.dead_scratch = dead;

        let mut refs = fulls;
        if refs.len() < m && !partial.is_empty() {
            // Order: class asc (local-partial, uncached, replica-ok,
            // replica-capped), then misses asc (higher hit fraction
            // first), then queue order (seq asc). Deterministic, and
            // identical to the reference scan's tie-break.
            partial.sort_unstable_by_key(|&(class, miss, seq, _)| (class, miss, seq));
            for &(_, _, _, qref) in partial.iter().take(m - refs.len()) {
                refs.push(qref);
            }
        }
        self.partial_scratch = partial;
        refs
    }

    /// Reference implementation of the §3.2 pickup: the plain
    /// O(min(|Q|, W)) window scan, retained as the executable
    /// specification of the dispatch decision. Pure — mutates neither
    /// queue nor stats; returns the selected refs in dispatch order.
    ///
    /// [`Scheduler::pick_tasks`] must agree with this function on every
    /// state (same tasks, same order); the `sched_parity` differential
    /// property test drives both across all five policies.
    #[doc(hidden)]
    pub fn pick_refs_reference(
        &self,
        exec: ExecutorId,
        limit: usize,
        queue: &WaitQueue,
        registry: &ExecutorRegistry,
        index: &LocationIndex,
    ) -> Vec<QueueRef> {
        let m = limit.max(1);
        if self.config.policy == DispatchPolicy::FirstAvailable {
            return queue.window(m).map(|(r, _)| r).collect();
        }
        let window = self.window_size(registry);
        let mcu_mode = self.mcu_mode(registry);
        let mut fulls: Vec<QueueRef> = Vec::new();
        let mut partial: Vec<(u8, usize, usize, QueueRef)> = Vec::new();
        for (pos, (qref, task)) in queue.window(window).enumerate() {
            let nfiles = task.files.len().max(1);
            let hits = index.hit_count(exec, &task.files);
            if hits == nfiles {
                fulls.push(qref);
                if fulls.len() == m {
                    break;
                }
                continue;
            }
            let class = if hits > 0 {
                1 // partial local hit
            } else {
                self.zero_hit_class(task, index, mcu_mode)
            };
            if class < u8::MAX {
                partial.push((class, nfiles - hits, pos, qref));
            }
        }
        if fulls.len() < m {
            partial.sort_by_key(|&(class, miss, pos, _)| (class, miss, pos));
            for &(_, _, _, qref) in partial.iter().take(m - fulls.len()) {
                fulls.push(qref);
            }
        }
        fulls
    }

    /// Eligibility class for a task with zero local hits at the asking
    /// executor. `u8::MAX` means "leave it in the queue".
    ///
    /// * class 2 — files cached **nowhere**: someone must fetch from
    ///   persistent storage; dispatching here bootstraps diffusion.
    /// * class 3 — files cached only at busy executors, replication below
    ///   the cap: dispatching here creates a useful extra replica
    ///   (max-compute-util behaviour).
    /// * class 4 — as above but replication already at the cap (only
    ///   taken when CPUs are starving).
    fn zero_hit_class(&self, task: &Task, index: &LocationIndex, mcu_mode: bool) -> u8 {
        // §Perf: replication() is a cached popcount — one hash probe per
        // file answers both cached-anywhere and the replication cap.
        let max_repl = task
            .files
            .iter()
            .map(|&f| index.replication(f))
            .max()
            .unwrap_or(0);
        if max_repl == 0 {
            return 2;
        }
        match self.config.policy {
            // max-cache-hit never dispatches a task away from its data:
            // wait for the holder (paper: "no tasks are returned").
            DispatchPolicy::MaxCacheHit => u8::MAX,
            DispatchPolicy::GoodCacheCompute if !mcu_mode => u8::MAX,
            _ => {
                if max_repl >= self.config.max_replication {
                    4
                } else {
                    3
                }
            }
        }
    }

    /// Is good-cache-compute currently in max-compute-util mode?
    fn mcu_mode(&self, registry: &ExecutorRegistry) -> bool {
        match self.config.policy {
            DispatchPolicy::MaxComputeUtil
            | DispatchPolicy::FirstAvailable
            | DispatchPolicy::FirstCacheAvailable => true,
            DispatchPolicy::MaxCacheHit => false,
            DispatchPolicy::GoodCacheCompute => {
                registry.cpu_utilization() < self.config.cpu_util_threshold
            }
        }
    }

    fn rotate_free(&mut self, registry: &ExecutorRegistry) -> Option<ExecutorId> {
        let from = ExecutorId(self.next_free_hint);
        let found = registry.next_free(from)?;
        self.next_free_hint = found.0.wrapping_add(1);
        Some(found)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ids::TaskId;
    use crate::util::time::Micros;

    fn task(i: u64, files: &[u32]) -> Task {
        Task {
            id: TaskId(i),
            files: files.iter().map(|&f| FileId(f)).collect(),
            compute: Micros::from_millis(10),
            arrival: Micros::ZERO,
        }
    }

    fn setup(n_exec: usize) -> (ExecutorRegistry, LocationIndex, WaitQueue, PendingIndex) {
        let mut reg = ExecutorRegistry::new();
        for _ in 0..n_exec {
            reg.register(2, Micros::ZERO);
        }
        (reg, LocationIndex::new(), WaitQueue::new(), PendingIndex::new())
    }

    /// Push + maintain the pending index (what the engines do).
    fn push(q: &mut WaitQueue, p: &mut PendingIndex, ix: &LocationIndex, t: Task) {
        let r = q.push_back(t);
        p.on_push(q, r, ix);
    }

    fn sched(policy: DispatchPolicy) -> Scheduler {
        Scheduler::new(SchedulerConfig {
            policy,
            ..SchedulerConfig::default()
        })
    }

    #[test]
    fn first_available_round_robins() {
        let (reg, index, _, mut p) = setup(3);
        let mut s = sched(DispatchPolicy::FirstAvailable);
        let mut picks = Vec::new();
        for _ in 0..3 {
            match s.select_notify(&[FileId(0)], &reg, &mut p, &index) {
                NotifyOutcome::Fallback(e) => picks.push(e.0),
                other => panic!("unexpected {other:?}"),
            }
        }
        picks.sort_unstable();
        assert_eq!(picks, vec![0, 1, 2]);
    }

    #[test]
    fn notify_prefers_holder() {
        let (reg, mut index, _, mut p) = setup(3);
        index.add(FileId(7), ExecutorId(2));
        let mut s = sched(DispatchPolicy::MaxComputeUtil);
        assert_eq!(
            s.select_notify(&[FileId(7)], &reg, &mut p, &index),
            NotifyOutcome::Preferred(ExecutorId(2))
        );
    }

    #[test]
    fn notify_multi_file_prefers_highest_score() {
        let (reg, mut index, _, mut p) = setup(3);
        index.add(FileId(1), ExecutorId(0));
        index.add(FileId(1), ExecutorId(2));
        index.add(FileId(2), ExecutorId(2));
        let mut s = sched(DispatchPolicy::MaxComputeUtil);
        // Executor 2 holds both files; executor 0 only one.
        assert_eq!(
            s.select_notify(&[FileId(1), FileId(2)], &reg, &mut p, &index),
            NotifyOutcome::Preferred(ExecutorId(2))
        );
    }

    #[test]
    fn notify_memoizes_multifile_ranking_without_recounts() {
        let (mut reg, mut index, _, mut p) = setup(3);
        index.add(FileId(1), ExecutorId(0));
        index.add(FileId(2), ExecutorId(0));
        index.add(FileId(1), ExecutorId(1));
        let files = [FileId(1), FileId(2)];
        let mut s = sched(DispatchPolicy::MaxComputeUtil);
        assert_eq!(
            s.select_notify(&files, &reg, &mut p, &index),
            NotifyOutcome::Preferred(ExecutorId(0))
        );
        // Same head, busier cluster: the ranking is reused, only
        // free-ness is re-probed.
        reg.start_task(ExecutorId(0), Micros::ZERO);
        reg.start_task(ExecutorId(0), Micros::ZERO);
        assert_eq!(
            s.select_notify(&files, &reg, &mut p, &index),
            NotifyOutcome::Preferred(ExecutorId(1))
        );
        assert_eq!(p.stats.notify_memo_builds, 1);
        assert_eq!(p.stats.notify_memo_hits, 1);
        assert_eq!(s.stats.holder_recounts, 0);
        // An index change invalidates the memo.
        index.add(FileId(2), ExecutorId(2));
        p.on_index_add(FileId(2), ExecutorId(2));
        let _ = s.select_notify(&files, &reg, &mut p, &index);
        assert_eq!(p.stats.notify_memo_builds, 2);
    }

    #[test]
    fn mch_waits_for_busy_holder() {
        let (mut reg, mut index, _, mut p) = setup(2);
        index.add(FileId(7), ExecutorId(0));
        // Make executor 0 fully busy.
        reg.start_task(ExecutorId(0), Micros::ZERO);
        reg.start_task(ExecutorId(0), Micros::ZERO);
        let mut s = sched(DispatchPolicy::MaxCacheHit);
        assert_eq!(
            s.select_notify(&[FileId(7)], &reg, &mut p, &index),
            NotifyOutcome::Wait
        );
        // But a file cached nowhere bootstraps to a free executor.
        assert_eq!(
            s.select_notify(&[FileId(8)], &reg, &mut p, &index),
            NotifyOutcome::Fallback(ExecutorId(1))
        );
    }

    #[test]
    fn mcu_falls_back_to_free_executor() {
        let (mut reg, mut index, _, mut p) = setup(2);
        index.add(FileId(7), ExecutorId(0));
        reg.start_task(ExecutorId(0), Micros::ZERO);
        reg.start_task(ExecutorId(0), Micros::ZERO);
        let mut s = sched(DispatchPolicy::MaxComputeUtil);
        assert!(matches!(
            s.select_notify(&[FileId(7)], &reg, &mut p, &index),
            NotifyOutcome::Fallback(ExecutorId(1))
        ));
    }

    #[test]
    fn gcc_switches_on_utilization() {
        let (mut reg, mut index, _, mut p) = setup(2);
        index.add(FileId(7), ExecutorId(0));
        reg.start_task(ExecutorId(0), Micros::ZERO);
        reg.start_task(ExecutorId(0), Micros::ZERO);
        let mut s = sched(DispatchPolicy::GoodCacheCompute);
        // util = 2/4 = 0.5 < 0.8 → mcu mode → fallback.
        assert!(matches!(
            s.select_notify(&[FileId(7)], &reg, &mut p, &index),
            NotifyOutcome::Fallback(_)
        ));
        // Push util to 0.75… still below. One more task → 3/4 < 0.8; fill all → 1.0.
        reg.start_task(ExecutorId(1), Micros::ZERO);
        reg.start_task(ExecutorId(1), Micros::ZERO);
        assert_eq!(
            s.select_notify(&[FileId(7)], &reg, &mut p, &index),
            NotifyOutcome::NoneFree
        );
    }

    #[test]
    fn pickup_prefers_full_hits() {
        let (reg, mut index, mut q, mut p) = setup(2);
        index.add(FileId(1), ExecutorId(0));
        index.add(FileId(2), ExecutorId(1));
        push(&mut q, &mut p, &index, task(0, &[2])); // hit at exec 1, not exec 0
        push(&mut q, &mut p, &index, task(1, &[1])); // hit at exec 0
        let mut s = sched(DispatchPolicy::GoodCacheCompute);
        let picked = s.pick_tasks(ExecutorId(0), 1, &mut q, &mut p, &reg, &index);
        assert_eq!(picked.len(), 1);
        assert_eq!(picked[0].id, TaskId(1));
        assert_eq!(q.len(), 1);
        assert_eq!(s.stats.full_hit_dispatches, 1);
        p.check_consistent(&q, &index).unwrap();
    }

    #[test]
    fn mch_pickup_leaves_foreign_tasks() {
        let (mut reg, mut index, mut q, mut p) = setup(2);
        index.add(FileId(1), ExecutorId(1));
        // Executor 1 is busy; its task sits in the queue.
        reg.start_task(ExecutorId(1), Micros::ZERO);
        reg.start_task(ExecutorId(1), Micros::ZERO);
        push(&mut q, &mut p, &index, task(0, &[1]));
        let mut s = sched(DispatchPolicy::MaxCacheHit);
        let picked = s.pick_tasks(ExecutorId(0), 1, &mut q, &mut p, &reg, &index);
        assert!(picked.is_empty(), "mch must wait for the holder");
        assert_eq!(q.len(), 1);
        // An uncached task bootstraps.
        push(&mut q, &mut p, &index, task(1, &[9]));
        let picked = s.pick_tasks(ExecutorId(0), 1, &mut q, &mut p, &reg, &index);
        assert_eq!(picked.len(), 1);
        assert_eq!(picked[0].id, TaskId(1));
    }

    #[test]
    fn mcu_pickup_takes_foreign_tasks() {
        let (mut reg, mut index, mut q, mut p) = setup(2);
        index.add(FileId(1), ExecutorId(1));
        reg.start_task(ExecutorId(1), Micros::ZERO);
        reg.start_task(ExecutorId(1), Micros::ZERO);
        push(&mut q, &mut p, &index, task(0, &[1]));
        let mut s = sched(DispatchPolicy::MaxComputeUtil);
        let picked = s.pick_tasks(ExecutorId(0), 1, &mut q, &mut p, &reg, &index);
        assert_eq!(picked.len(), 1, "mcu must keep the CPU busy");
    }

    #[test]
    fn replication_cap_orders_candidates() {
        let (reg, mut index, mut q, mut p) = setup(8);
        // file 1 already at 4 replicas (the default cap); file 2 at 1.
        for e in 0..4 {
            index.add(FileId(1), ExecutorId(e));
        }
        index.add(FileId(2), ExecutorId(0));
        push(&mut q, &mut p, &index, task(0, &[1])); // over cap → class 4
        push(&mut q, &mut p, &index, task(1, &[2])); // under cap → class 3
        let mut s = sched(DispatchPolicy::MaxComputeUtil);
        let picked = s.pick_tasks(ExecutorId(7), 1, &mut q, &mut p, &reg, &index);
        assert_eq!(picked[0].id, TaskId(1), "under-cap replica preferred");
    }

    #[test]
    fn first_available_pickup_is_fifo() {
        let (reg, index, mut q, mut p) = setup(1);
        for i in 0..5 {
            // first-available maintains no pending index (uses_caching()
            // is false), mirroring the engines.
            q.push_back(task(i, &[i as u32]));
        }
        let mut s = Scheduler::new(SchedulerConfig {
            policy: DispatchPolicy::FirstAvailable,
            max_tasks_per_pickup: 3,
            ..SchedulerConfig::default()
        });
        let picked = s.pick_tasks(ExecutorId(0), 3, &mut q, &mut p, &reg, &index);
        let ids: Vec<u64> = picked.iter().map(|t| t.id.0).collect();
        assert_eq!(ids, vec![0, 1, 2]);
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn window_bounds_inspection() {
        let (reg, index, mut q, mut p) = setup(1); // window = 100 × 1
        for i in 0..500 {
            push(&mut q, &mut p, &index, task(i, &[i as u32]));
        }
        let mut s = sched(DispatchPolicy::GoodCacheCompute);
        let _ = s.pick_tasks(ExecutorId(0), 1, &mut q, &mut p, &reg, &index);
        assert!(s.stats.tasks_inspected <= 100, "{}", s.stats.tasks_inspected);
    }

    #[test]
    fn indexed_pickup_inspects_overlap_not_window() {
        // 200 queued tasks, only 3 reference files cached at the asking
        // executor: the pickup must examine ~overlap, not ~window.
        let (reg, mut index, mut q, mut p) = setup(2); // window = 200
        index.add(FileId(0), ExecutorId(0));
        for i in 0..200u64 {
            push(&mut q, &mut p, &index, task(i, &[(i % 67) as u32]));
        }
        let mut s = sched(DispatchPolicy::MaxComputeUtil);
        let picked = s.pick_tasks(ExecutorId(0), 1, &mut q, &mut p, &reg, &index);
        assert_eq!(picked[0].id, TaskId(0), "earliest full hit wins");
        assert!(
            s.stats.tasks_inspected <= 4,
            "inspected {} — expected ~overlap",
            s.stats.tasks_inspected
        );
    }

    #[test]
    fn pickup_skips_and_purges_dead_hints() {
        use crate::coordinator::pending::FANOUT_CAP;
        // A hot file (fan-out above the cap, so its eviction defers),
        // whose first reader is dispatched before any consult: the
        // pickup must skip the resulting dead hint, purge it, and still
        // agree with the reference scan.
        let (reg, mut index, mut q, mut p) = setup(2);
        index.add(FileId(1), ExecutorId(0));
        let readers = (FANOUT_CAP + 4) as u64;
        for i in 0..readers {
            push(&mut q, &mut p, &index, task(i, &[1]));
        }
        index.remove(FileId(1), ExecutorId(0));
        p.on_index_remove(FileId(1), ExecutorId(0), &q, &index);
        // Head leaves the queue while the eviction is still deferred.
        let head = q.front_ref().unwrap();
        crate::coordinator::pending::remove_queued(&mut q, &mut p, head, &index);
        // A dispatchable task for the asking executor.
        index.add(FileId(9), ExecutorId(0));
        p.on_index_add(FileId(9), ExecutorId(0));
        push(&mut q, &mut p, &index, task(readers, &[9]));

        let mut s = sched(DispatchPolicy::MaxComputeUtil);
        let expected: Vec<u64> = s
            .pick_refs_reference(ExecutorId(0), 1, &q, &reg, &index)
            .iter()
            .map(|&r| q.get(r).id.0)
            .collect();
        let picked = s.pick_tasks(ExecutorId(0), 1, &mut q, &mut p, &reg, &index);
        let ids: Vec<u64> = picked.iter().map(|t| t.id.0).collect();
        assert_eq!(ids, expected, "dead hints must not perturb dispatch");
        assert_eq!(ids, vec![readers], "full hit on file 9 wins");
        p.check_consistent(&q, &index).unwrap();
    }

    #[test]
    fn multi_file_tasks_score_fractionally() {
        let (reg, mut index, mut q, mut p) = setup(2);
        index.add(FileId(1), ExecutorId(0));
        index.add(FileId(2), ExecutorId(0));
        index.add(FileId(3), ExecutorId(1));
        push(&mut q, &mut p, &index, task(0, &[1, 3])); // 1/2 hit at exec 0
        push(&mut q, &mut p, &index, task(1, &[1, 2])); // 2/2 hit at exec 0
        let mut s = sched(DispatchPolicy::GoodCacheCompute);
        let picked = s.pick_tasks(ExecutorId(0), 1, &mut q, &mut p, &reg, &index);
        assert_eq!(picked[0].id, TaskId(1));
    }

    #[test]
    fn batched_pickup_mixes_classes_in_spec_order() {
        let (reg, mut index, mut q, mut p) = setup(4); // window = 400
        index.add(FileId(1), ExecutorId(0));
        index.add(FileId(2), ExecutorId(0));
        index.add(FileId(9), ExecutorId(3)); // cached elsewhere only
        push(&mut q, &mut p, &index, task(0, &[9])); // zero-hit, class 3
        push(&mut q, &mut p, &index, task(1, &[1, 7])); // partial (1/2)
        push(&mut q, &mut p, &index, task(2, &[2])); // full hit
        push(&mut q, &mut p, &index, task(3, &[42])); // uncached, class 2
        let mut s = Scheduler::new(SchedulerConfig {
            policy: DispatchPolicy::MaxComputeUtil,
            max_tasks_per_pickup: 3,
            ..SchedulerConfig::default()
        });
        let expected: Vec<u64> = s
            .pick_refs_reference(ExecutorId(0), 3, &q, &reg, &index)
            .iter()
            .map(|&r| q.get(r).id.0)
            .collect();
        let picked = s.pick_tasks(ExecutorId(0), 3, &mut q, &mut p, &reg, &index);
        let ids: Vec<u64> = picked.iter().map(|t| t.id.0).collect();
        // Full hit first, then partial (class 1), then uncached (class 2).
        assert_eq!(ids, vec![2, 1, 3]);
        assert_eq!(ids, expected, "indexed and reference scans must agree");
        p.check_consistent(&q, &index).unwrap();
    }
}
