//! The data-aware scheduler (§3.2) — the heart of data diffusion.
//!
//! The scheduler is split in two parts, exactly as in the paper:
//!
//! 1. **Notification** ([`Scheduler::select_notify`]): given the task at
//!    the head of the wait queue (T₀), score candidate executors by how
//!    many of the task's files they cache (via the I_map), and pick the
//!    best *free* candidate to notify that work is available. Policy
//!    decides the fallback when no preferred executor is free.
//! 2. **Pickup** ([`Scheduler::pick_tasks`]): when an executor asks for
//!    work, scan a *scheduling window* of up to W tasks from the queue
//!    head, score each by its local cache-hit fraction
//!    (|fileSet ∩ E_map(executor)| / |fileSet|), dispatch any 100 %-hit
//!    task immediately, and otherwise dispatch the m best-scoring
//!    eligible tasks. Policy decides eligibility of 0-hit tasks.
//!
//! Complexity is O(|θ(κ)| + replication + min(|Q|, W)) per decision, as
//! claimed in the paper — guaranteed by the hash-map/sorted-set shapes of
//! [`LocationIndex`](crate::index::LocationIndex) and
//! [`WaitQueue`](crate::coordinator::queue::WaitQueue), and measured by
//! the Figure 3 bench (`cargo bench --bench fig03_scheduler`).

pub mod policy;

pub use policy::DispatchPolicy;

use crate::coordinator::executor::ExecutorRegistry;
use crate::coordinator::queue::{QueueRef, Task, WaitQueue};
use crate::ids::{ExecutorId, FileId};
use crate::index::LocationIndex;
use std::collections::HashMap;

/// Scheduler tuning knobs (§3.2, §5.1).
#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// Dispatch policy (paper policies 1–5).
    pub policy: DispatchPolicy,
    /// Scheduling window W = `window_multiplier` × registered executors
    /// (paper: 100× → 3200 at 32 nodes).
    pub window_multiplier: usize,
    /// good-cache-compute heuristic 1: CPU-utilization threshold that
    /// switches between max-cache-hit behaviour (util ≥ threshold) and
    /// max-compute-util behaviour (util < threshold). Paper: 0.8 in the
    /// empirical section.
    pub cpu_util_threshold: f64,
    /// good-cache-compute heuristic 2: maximum replicas of a data object
    /// before the scheduler stops diffusing additional copies.
    pub max_replication: usize,
    /// Maximum tasks handed to an executor per pickup (m in §3.2).
    pub max_tasks_per_pickup: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            policy: DispatchPolicy::GoodCacheCompute,
            window_multiplier: 100,
            cpu_util_threshold: 0.8,
            max_replication: 2,
            max_tasks_per_pickup: 1,
        }
    }
}

/// Why phase 1 chose (or declined to choose) an executor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NotifyOutcome {
    /// Notify this executor; it caches ≥1 of the task's files.
    Preferred(ExecutorId),
    /// No free preferred executor; fall back to the next free executor.
    Fallback(ExecutorId),
    /// Policy says wait (max-cache-hit semantics: a preferred executor
    /// exists but is busy; dispatch is delayed until it frees).
    Wait,
    /// Nothing is free at all.
    NoneFree,
}

/// Counters the Figure 3 microbench reports (per-decision cost breakdown).
#[derive(Debug, Default, Clone)]
pub struct SchedulerStats {
    /// Phase-1 decisions taken.
    pub notify_decisions: u64,
    /// Phase-2 pickups served.
    pub pickups: u64,
    /// Tasks dispatched.
    pub tasks_dispatched: u64,
    /// Window entries inspected across all pickups.
    pub tasks_inspected: u64,
    /// Tasks dispatched with a 100 % local-hit score.
    pub full_hit_dispatches: u64,
}

/// The data-aware scheduler. Pure logic: no clocks, no I/O — both the
/// discrete-event engine and the live engine drive it.
#[derive(Debug)]
pub struct Scheduler {
    /// Tuning knobs.
    pub config: SchedulerConfig,
    /// Rotating hint so first-available round-robins over free executors.
    next_free_hint: u32,
    /// Cost/behaviour counters.
    pub stats: SchedulerStats,
    /// Scratch buffer reused across notify decisions (perf: avoids an
    /// allocation per decision on the hot path).
    candidates: HashMap<ExecutorId, usize>,
    /// Scratch buffer for the window scan's partial candidates (perf:
    /// §Perf iteration 1 — reuse instead of re-allocating per pickup).
    partial_scratch: Vec<(u8, usize, usize, QueueRef)>,
}

impl Scheduler {
    /// New scheduler with the given configuration.
    pub fn new(config: SchedulerConfig) -> Self {
        Scheduler {
            config,
            next_free_hint: 0,
            stats: SchedulerStats::default(),
            candidates: HashMap::new(),
            partial_scratch: Vec::new(),
        }
    }

    /// Effective scheduling window for the current cluster size.
    pub fn window_size(&self, registry: &ExecutorRegistry) -> usize {
        (self.config.window_multiplier * registry.len()).max(1)
    }

    /// **Phase 1 — notification.** Choose an executor to notify for the
    /// task with files `files` at the head of the wait queue.
    pub fn select_notify(
        &mut self,
        files: &[FileId],
        registry: &ExecutorRegistry,
        index: &LocationIndex,
    ) -> NotifyOutcome {
        self.stats.notify_decisions += 1;
        if registry.free_count() == 0 {
            return NotifyOutcome::NoneFree;
        }
        let policy = self.config.policy;
        if policy == DispatchPolicy::FirstAvailable {
            return match self.rotate_free(registry) {
                Some(e) => NotifyOutcome::Fallback(e),
                None => NotifyOutcome::NoneFree,
            };
        }

        // Score candidates: executors holding any of the task's files,
        // weighted by how many they hold (the paper's candidate counting).
        self.candidates.clear();
        let mut any_holder = false;
        for &f in files {
            if let Some(holders) = index.holders(f) {
                for &e in holders {
                    any_holder = true;
                    *self.candidates.entry(e).or_insert(0) += 1;
                }
            }
        }
        // Best free candidate, ties broken by id for determinism.
        let mut best: Option<(usize, ExecutorId)> = None;
        for (&e, &score) in self.candidates.iter() {
            if registry.is_free(e) {
                let better = match best {
                    None => true,
                    Some((bs, be)) => score > bs || (score == bs && e < be),
                };
                if better {
                    best = Some((score, e));
                }
            }
        }
        if let Some((_, e)) = best {
            return NotifyOutcome::Preferred(e);
        }

        if policy == DispatchPolicy::FirstCacheAvailable {
            // No free executor holds the data: fall back immediately.
            return match self.rotate_free(registry) {
                Some(e) => NotifyOutcome::Fallback(e),
                None => NotifyOutcome::NoneFree,
            };
        }

        let wait_for_holder = match policy {
            DispatchPolicy::MaxCacheHit => true,
            DispatchPolicy::MaxComputeUtil => false,
            DispatchPolicy::GoodCacheCompute => {
                registry.cpu_utilization() >= self.config.cpu_util_threshold
            }
            DispatchPolicy::FirstAvailable | DispatchPolicy::FirstCacheAvailable => {
                unreachable!("handled above")
            }
        };
        if any_holder && wait_for_holder {
            // Data is cached somewhere but every holder is busy: delay
            // dispatch until the holder frees (max-cache-hit semantics).
            NotifyOutcome::Wait
        } else {
            // Data cached nowhere (bootstrap miss) or policy prefers
            // utilization: send to the next free executor.
            match self.rotate_free(registry) {
                Some(e) => NotifyOutcome::Fallback(e),
                None => NotifyOutcome::NoneFree,
            }
        }
    }

    /// **Phase 2 — pickup.** The executor `exec` is asking for work: scan
    /// the scheduling window and remove up to `limit` tasks for it (the
    /// engine passes `min(max_tasks_per_pickup, free slots)`). Returns
    /// the dispatched tasks (possibly empty — the paper's "no tasks
    /// returned" outcome sends the executor back to the free pool).
    pub fn pick_tasks(
        &mut self,
        exec: ExecutorId,
        limit: usize,
        queue: &mut WaitQueue,
        registry: &ExecutorRegistry,
        index: &LocationIndex,
    ) -> Vec<Task> {
        self.stats.pickups += 1;
        let m = limit.max(1);
        if queue.is_empty() {
            return Vec::new();
        }

        // first-available ignores data location entirely: O(1) head pop.
        if self.config.policy == DispatchPolicy::FirstAvailable {
            let mut out = Vec::with_capacity(m);
            for _ in 0..m {
                match queue.pop_front() {
                    Some(t) => out.push(t),
                    None => break,
                }
            }
            self.stats.tasks_dispatched += out.len() as u64;
            return out;
        }

        let window = self.window_size(registry);
        let mcu_mode = self.mcu_mode(registry);
        // §Perf: hoist the E_map(exec) lookup out of the scan — one hash
        // probe per pickup instead of one per window entry.
        let exec_set = index.cached_at(exec);

        // Single pass over the window: take 100 %-hit tasks immediately,
        // remember the best partial candidates otherwise.
        let mut full_hits: Vec<QueueRef> = Vec::new();
        // (class, score_num, queue_position) — lower tuple is better.
        let mut partial = std::mem::take(&mut self.partial_scratch);
        partial.clear();
        // §Perf: with m == 1 (the common case) track the single best
        // partial candidate inline instead of collecting + sorting.
        let mut best_one: Option<(u8, usize, usize, QueueRef)> = None;
        // §Perf iteration 2: when the executor caches nothing, no task
        // can score hits, so the first class-2 candidate (files cached
        // nowhere — the best zero-hit class) is provably optimal and the
        // scan can stop there. This collapses the cold-start phase from
        // full-window scans to O(1) without changing any decision.
        let no_hits_possible = exec_set.is_none_or(|s| s.is_empty());
        let mut inspected = 0u64;
        for (pos, (qref, task)) in queue.window(window).enumerate() {
            inspected += 1;
            let nfiles = task.files.len().max(1);
            let hits = match exec_set {
                Some(set) => task.files.iter().filter(|f| set.contains(f)).count(),
                None => 0,
            };
            if hits == nfiles {
                full_hits.push(qref);
                if full_hits.len() == m {
                    break;
                }
                continue;
            }
            let class = if hits > 0 {
                1 // partial local hit
            } else {
                self.zero_hit_class(task, index, mcu_mode)
            };
            if class < u8::MAX {
                let cand = (class, nfiles - hits, pos, qref);
                if m == 1 {
                    let key = (cand.0, cand.1, cand.2);
                    if best_one.is_none_or(|b| key < (b.0, b.1, b.2)) {
                        best_one = Some(cand);
                    }
                    if no_hits_possible && class == 2 {
                        break; // nothing later can beat (2, ·, earlier pos)
                    }
                } else if full_hits.len() + partial.len() < window {
                    partial.push(cand);
                }
            }
        }
        self.stats.tasks_inspected += inspected;

        let mut refs = full_hits;
        self.stats.full_hit_dispatches += refs.len() as u64;
        if refs.len() < m {
            if m == 1 {
                if let Some((_, _, _, qref)) = best_one {
                    refs.push(qref);
                }
            } else if !partial.is_empty() {
                // Order: class asc (local-partial, uncached, replica-ok,
                // replica-capped), then misses asc (higher hit fraction
                // first), then queue order. Deterministic.
                partial.sort_unstable_by_key(|&(class, miss, pos, _)| (class, miss, pos));
                for &(_, _, _, qref) in partial.iter().take(m - refs.len()) {
                    refs.push(qref);
                }
            }
        }
        self.partial_scratch = partial;

        let tasks: Vec<Task> = refs.into_iter().map(|r| queue.remove(r)).collect();
        self.stats.tasks_dispatched += tasks.len() as u64;
        tasks
    }

    /// Eligibility class for a task with zero local hits at the asking
    /// executor. `u8::MAX` means "leave it in the queue".
    ///
    /// * class 2 — files cached **nowhere**: someone must fetch from
    ///   persistent storage; dispatching here bootstraps diffusion.
    /// * class 3 — files cached only at busy executors, replication below
    ///   the cap: dispatching here creates a useful extra replica
    ///   (max-compute-util behaviour).
    /// * class 4 — as above but replication already at the cap (only
    ///   taken when CPUs are starving).
    fn zero_hit_class(&self, task: &Task, index: &LocationIndex, mcu_mode: bool) -> u8 {
        // §Perf: one index probe per file gives both the cached-anywhere
        // and the replication-cap answers.
        let max_repl = task
            .files
            .iter()
            .map(|&f| index.replication(f))
            .max()
            .unwrap_or(0);
        if max_repl == 0 {
            return 2;
        }
        match self.config.policy {
            // max-cache-hit never dispatches a task away from its data:
            // wait for the holder (paper: "no tasks are returned").
            DispatchPolicy::MaxCacheHit => u8::MAX,
            DispatchPolicy::GoodCacheCompute if !mcu_mode => u8::MAX,
            _ => {
                if max_repl >= self.config.max_replication {
                    4
                } else {
                    3
                }
            }
        }
    }

    /// Is good-cache-compute currently in max-compute-util mode?
    fn mcu_mode(&self, registry: &ExecutorRegistry) -> bool {
        match self.config.policy {
            DispatchPolicy::MaxComputeUtil
            | DispatchPolicy::FirstAvailable
            | DispatchPolicy::FirstCacheAvailable => true,
            DispatchPolicy::MaxCacheHit => false,
            DispatchPolicy::GoodCacheCompute => {
                registry.cpu_utilization() < self.config.cpu_util_threshold
            }
        }
    }

    fn rotate_free(&mut self, registry: &ExecutorRegistry) -> Option<ExecutorId> {
        let from = ExecutorId(self.next_free_hint);
        let found = registry.next_free(from)?;
        self.next_free_hint = found.0.wrapping_add(1);
        Some(found)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ids::TaskId;
    use crate::util::time::Micros;

    fn task(i: u64, files: &[u32]) -> Task {
        Task {
            id: TaskId(i),
            files: files.iter().map(|&f| FileId(f)).collect(),
            compute: Micros::from_millis(10),
            arrival: Micros::ZERO,
        }
    }

    fn setup(n_exec: usize) -> (ExecutorRegistry, LocationIndex, WaitQueue) {
        let mut reg = ExecutorRegistry::new();
        for _ in 0..n_exec {
            reg.register(2, Micros::ZERO);
        }
        (reg, LocationIndex::new(), WaitQueue::new())
    }

    fn sched(policy: DispatchPolicy) -> Scheduler {
        Scheduler::new(SchedulerConfig {
            policy,
            ..SchedulerConfig::default()
        })
    }

    #[test]
    fn first_available_round_robins() {
        let (reg, index, _) = setup(3);
        let mut s = sched(DispatchPolicy::FirstAvailable);
        let mut picks = Vec::new();
        for _ in 0..3 {
            match s.select_notify(&[FileId(0)], &reg, &index) {
                NotifyOutcome::Fallback(e) => picks.push(e.0),
                other => panic!("unexpected {other:?}"),
            }
        }
        picks.sort_unstable();
        assert_eq!(picks, vec![0, 1, 2]);
    }

    #[test]
    fn notify_prefers_holder() {
        let (reg, mut index, _) = setup(3);
        index.add(FileId(7), ExecutorId(2));
        let mut s = sched(DispatchPolicy::MaxComputeUtil);
        assert_eq!(
            s.select_notify(&[FileId(7)], &reg, &index),
            NotifyOutcome::Preferred(ExecutorId(2))
        );
    }

    #[test]
    fn mch_waits_for_busy_holder() {
        let (mut reg, mut index, _) = setup(2);
        index.add(FileId(7), ExecutorId(0));
        // Make executor 0 fully busy.
        reg.start_task(ExecutorId(0), Micros::ZERO);
        reg.start_task(ExecutorId(0), Micros::ZERO);
        let mut s = sched(DispatchPolicy::MaxCacheHit);
        assert_eq!(
            s.select_notify(&[FileId(7)], &reg, &index),
            NotifyOutcome::Wait
        );
        // But a file cached nowhere bootstraps to a free executor.
        assert_eq!(
            s.select_notify(&[FileId(8)], &reg, &index),
            NotifyOutcome::Fallback(ExecutorId(1))
        );
    }

    #[test]
    fn mcu_falls_back_to_free_executor() {
        let (mut reg, mut index, _) = setup(2);
        index.add(FileId(7), ExecutorId(0));
        reg.start_task(ExecutorId(0), Micros::ZERO);
        reg.start_task(ExecutorId(0), Micros::ZERO);
        let mut s = sched(DispatchPolicy::MaxComputeUtil);
        assert!(matches!(
            s.select_notify(&[FileId(7)], &reg, &index),
            NotifyOutcome::Fallback(ExecutorId(1))
        ));
    }

    #[test]
    fn gcc_switches_on_utilization() {
        let (mut reg, mut index, _) = setup(2);
        index.add(FileId(7), ExecutorId(0));
        reg.start_task(ExecutorId(0), Micros::ZERO);
        reg.start_task(ExecutorId(0), Micros::ZERO);
        let mut s = sched(DispatchPolicy::GoodCacheCompute);
        // util = 2/4 = 0.5 < 0.8 → mcu mode → fallback.
        assert!(matches!(
            s.select_notify(&[FileId(7)], &reg, &index),
            NotifyOutcome::Fallback(_)
        ));
        // Push util to 0.75… still below. One more task → 3/4 < 0.8; fill all → 1.0.
        reg.start_task(ExecutorId(1), Micros::ZERO);
        reg.start_task(ExecutorId(1), Micros::ZERO);
        assert_eq!(
            s.select_notify(&[FileId(7)], &reg, &index),
            NotifyOutcome::NoneFree
        );
    }

    #[test]
    fn pickup_prefers_full_hits() {
        let (reg, mut index, mut q) = setup(2);
        index.add(FileId(1), ExecutorId(0));
        index.add(FileId(2), ExecutorId(1));
        q.push_back(task(0, &[2])); // hit at exec 1, not exec 0
        q.push_back(task(1, &[1])); // hit at exec 0
        let mut s = sched(DispatchPolicy::GoodCacheCompute);
        let picked = s.pick_tasks(ExecutorId(0), 1, &mut q, &reg, &index);
        assert_eq!(picked.len(), 1);
        assert_eq!(picked[0].id, TaskId(1));
        assert_eq!(q.len(), 1);
        assert_eq!(s.stats.full_hit_dispatches, 1);
    }

    #[test]
    fn mch_pickup_leaves_foreign_tasks() {
        let (mut reg, mut index, mut q) = setup(2);
        index.add(FileId(1), ExecutorId(1));
        // Executor 1 is busy; its task sits in the queue.
        reg.start_task(ExecutorId(1), Micros::ZERO);
        reg.start_task(ExecutorId(1), Micros::ZERO);
        q.push_back(task(0, &[1]));
        let mut s = sched(DispatchPolicy::MaxCacheHit);
        let picked = s.pick_tasks(ExecutorId(0), 1, &mut q, &reg, &index);
        assert!(picked.is_empty(), "mch must wait for the holder");
        assert_eq!(q.len(), 1);
        // An uncached task bootstraps.
        q.push_back(task(1, &[9]));
        let picked = s.pick_tasks(ExecutorId(0), 1, &mut q, &reg, &index);
        assert_eq!(picked.len(), 1);
        assert_eq!(picked[0].id, TaskId(1));
    }

    #[test]
    fn mcu_pickup_takes_foreign_tasks() {
        let (mut reg, mut index, mut q) = setup(2);
        index.add(FileId(1), ExecutorId(1));
        reg.start_task(ExecutorId(1), Micros::ZERO);
        reg.start_task(ExecutorId(1), Micros::ZERO);
        q.push_back(task(0, &[1]));
        let mut s = sched(DispatchPolicy::MaxComputeUtil);
        let picked = s.pick_tasks(ExecutorId(0), 1, &mut q, &reg, &index);
        assert_eq!(picked.len(), 1, "mcu must keep the CPU busy");
    }

    #[test]
    fn replication_cap_orders_candidates() {
        let (reg, mut index, mut q) = setup(8);
        // file 1 already at 4 replicas (the default cap); file 2 at 1.
        for e in 0..4 {
            index.add(FileId(1), ExecutorId(e));
        }
        index.add(FileId(2), ExecutorId(0));
        q.push_back(task(0, &[1])); // over cap → class 4
        q.push_back(task(1, &[2])); // under cap → class 3
        let mut s = sched(DispatchPolicy::MaxComputeUtil);
        let picked = s.pick_tasks(ExecutorId(7), 1, &mut q, &reg, &index);
        assert_eq!(picked[0].id, TaskId(1), "under-cap replica preferred");
    }

    #[test]
    fn first_available_pickup_is_fifo() {
        let (reg, index, mut q) = setup(1);
        for i in 0..5 {
            q.push_back(task(i, &[i as u32]));
        }
        let mut s = Scheduler::new(SchedulerConfig {
            policy: DispatchPolicy::FirstAvailable,
            max_tasks_per_pickup: 3,
            ..SchedulerConfig::default()
        });
        let picked = s.pick_tasks(ExecutorId(0), 3, &mut q, &reg, &index);
        let ids: Vec<u64> = picked.iter().map(|t| t.id.0).collect();
        assert_eq!(ids, vec![0, 1, 2]);
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn window_bounds_inspection() {
        let (reg, index, mut q) = setup(1); // window = 100 × 1
        for i in 0..500 {
            q.push_back(task(i, &[i as u32]));
        }
        let mut s = sched(DispatchPolicy::GoodCacheCompute);
        let _ = s.pick_tasks(ExecutorId(0), 1, &mut q, &reg, &index);
        assert!(s.stats.tasks_inspected <= 100, "{}", s.stats.tasks_inspected);
    }

    #[test]
    fn multi_file_tasks_score_fractionally() {
        let (reg, mut index, mut q) = setup(2);
        index.add(FileId(1), ExecutorId(0));
        index.add(FileId(2), ExecutorId(0));
        index.add(FileId(3), ExecutorId(1));
        q.push_back(task(0, &[1, 3])); // 1/2 hit at exec 0
        q.push_back(task(1, &[1, 2])); // 2/2 hit at exec 0
        let mut s = sched(DispatchPolicy::GoodCacheCompute);
        let picked = s.pick_tasks(ExecutorId(0), 1, &mut q, &reg, &index);
        assert_eq!(picked[0].id, TaskId(1));
    }
}
