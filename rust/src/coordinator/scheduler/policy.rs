//! The five dispatch policies of §3.2 / §4.2.

/// Task dispatch policy (paper numbering in comments).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DispatchPolicy {
    /// (1) Ignore data location; first free executor; executors always
    /// read from persistent storage (the no-data-diffusion baseline).
    FirstAvailable,
    /// (2) Prefer a free executor holding any of the task's data, else
    /// the first free executor. The paper notes it has no practical
    /// advantage; included for completeness and the Fig 3 bench.
    FirstCacheAvailable,
    /// (3) Dispatch to the executor with the most of the task's data,
    /// waiting for it if busy. Maximizes cache-hit ratio at the cost of
    /// CPU utilization (best for data-intensive workloads).
    MaxCacheHit,
    /// (4) Always dispatch to an available executor, preferring the one
    /// with the most of the task's data. Maximizes CPU utilization at
    /// the cost of extra data movement (best for compute-intensive
    /// workloads).
    MaxComputeUtil,
    /// (5) Combination of (3) and (4): behave like max-cache-hit while
    /// CPU utilization is above a threshold, like max-compute-util
    /// below it; bounded by a maximum replication factor.
    GoodCacheCompute,
}

impl DispatchPolicy {
    /// All policies, in paper order.
    pub const ALL: [DispatchPolicy; 5] = [
        DispatchPolicy::FirstAvailable,
        DispatchPolicy::FirstCacheAvailable,
        DispatchPolicy::MaxCacheHit,
        DispatchPolicy::MaxComputeUtil,
        DispatchPolicy::GoodCacheCompute,
    ];

    /// Canonical hyphenated name (as in the paper).
    pub fn name(&self) -> &'static str {
        match self {
            DispatchPolicy::FirstAvailable => "first-available",
            DispatchPolicy::FirstCacheAvailable => "first-cache-available",
            DispatchPolicy::MaxCacheHit => "max-cache-hit",
            DispatchPolicy::MaxComputeUtil => "max-compute-util",
            DispatchPolicy::GoodCacheCompute => "good-cache-compute",
        }
    }

    /// Parse a policy name (hyphens or underscores).
    pub fn parse(s: &str) -> Option<DispatchPolicy> {
        match s.to_ascii_lowercase().replace('_', "-").as_str() {
            "first-available" | "fa" => Some(DispatchPolicy::FirstAvailable),
            "first-cache-available" | "fca" => Some(DispatchPolicy::FirstCacheAvailable),
            "max-cache-hit" | "mch" => Some(DispatchPolicy::MaxCacheHit),
            "max-compute-util" | "mcu" => Some(DispatchPolicy::MaxComputeUtil),
            "good-cache-compute" | "gcc" => Some(DispatchPolicy::GoodCacheCompute),
            _ => None,
        }
    }

    /// Does this policy use data diffusion (per-executor caching)?
    /// first-available works directly against persistent storage.
    ///
    /// This flag also gates all pending-index upkeep: the engines only
    /// maintain [`crate::coordinator::pending::PendingIndex`] (pushes,
    /// cache-event bookkeeping, epoch bumps) when it returns true —
    /// first-available pops the queue head and never consults candidate
    /// sets, so paying maintenance for it would be pure overhead. See
    /// `docs/ARCHITECTURE.md` for the layer map.
    pub fn uses_caching(&self) -> bool {
        !matches!(self, DispatchPolicy::FirstAvailable)
    }
}

impl std::fmt::Display for DispatchPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for DispatchPolicy {
    type Err = String;

    /// The `FromStr` face of [`DispatchPolicy::parse`]. Round-trips
    /// with `Display`.
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        DispatchPolicy::parse(s)
            .ok_or_else(|| format!("unknown dispatch policy `{s}` (fa|fca|mch|mcu|gcc)"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_round_trip() {
        for p in DispatchPolicy::ALL {
            assert_eq!(DispatchPolicy::parse(p.name()), Some(p));
        }
        assert_eq!(DispatchPolicy::parse("GCC"), Some(DispatchPolicy::GoodCacheCompute));
        assert_eq!(DispatchPolicy::parse("max_cache_hit"), Some(DispatchPolicy::MaxCacheHit));
        assert_eq!(DispatchPolicy::parse("nope"), None);
    }

    #[test]
    fn from_str_round_trips_with_display() {
        for p in DispatchPolicy::ALL {
            assert_eq!(p.to_string().parse::<DispatchPolicy>(), Ok(p));
        }
        assert!("nope".parse::<DispatchPolicy>().is_err());
    }

    #[test]
    fn caching_flag() {
        assert!(!DispatchPolicy::FirstAvailable.uses_caching());
        for p in &DispatchPolicy::ALL[1..] {
            assert!(p.uses_caching());
        }
    }
}
