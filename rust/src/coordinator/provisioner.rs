//! Dynamic Resource Provisioning (DRP) — §3.1, §5.2.
//!
//! Falkon's provisioner watches the wait-queue length (the paper's load
//! metric) and acquires executors through GRAM4/the LRM, which imposes a
//! 30–60 s allocation latency; idle executors are released so the
//! resources can serve other users (the performance-index win of Fig 13).
//!
//! The provisioner here is pure decision logic: the engine calls
//! [`Provisioner::on_tick`] periodically (1 Hz in the simulator, matching
//! the paper's provisioning granularity) and enacts the returned
//! [`ProvisionAction`] — scheduling `allocate` node registrations after
//! the GRAM latency, and deregistering the `release` list.

use crate::coordinator::executor::ExecutorRegistry;
use crate::ids::ExecutorId;
use crate::util::time::Micros;

/// How aggressively new nodes are requested (the paper's tunable
/// allocation policies; `one`/`additive`/`multiplicative`/`all`, plus
/// the closed-loop `model` controller of docs/PROVISIONING.md).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AllocationPolicy {
    /// Request one node per decision.
    OneAtATime,
    /// Request a fixed batch per decision.
    Additive(usize),
    /// Grow the fleet by a factor per decision (≥1 node).
    Multiplicative(f64),
    /// Request everything still needed at once.
    AllAtOnce,
    /// Model-predictive: track the node target solved from the §3
    /// performance model each tick
    /// ([`ModelController`](crate::coordinator::model::ModelController)).
    Model,
}

impl AllocationPolicy {
    /// Parse the CLI flag form shared by `datadiff run --allocation` and
    /// the live-engine drivers: `one`, `add:N`, `mult:F`, `all`, or
    /// `model`.
    pub fn parse_flag(s: &str) -> Result<AllocationPolicy, String> {
        match s {
            "one" => Ok(AllocationPolicy::OneAtATime),
            "all" => Ok(AllocationPolicy::AllAtOnce),
            "model" => Ok(AllocationPolicy::Model),
            _ => {
                if let Some(n) = s.strip_prefix("add:") {
                    let n: usize = n
                        .parse()
                        .map_err(|_| format!("bad additive step in `{s}`"))?;
                    if n == 0 {
                        return Err(format!("additive step must be ≥ 1 in `{s}`"));
                    }
                    Ok(AllocationPolicy::Additive(n))
                } else if let Some(f) = s.strip_prefix("mult:") {
                    let f: f64 = f
                        .parse()
                        .map_err(|_| format!("bad multiplicative factor in `{s}`"))?;
                    if f.is_nan() || f <= 1.0 {
                        return Err(format!("multiplicative factor must be > 1 in `{s}`"));
                    }
                    Ok(AllocationPolicy::Multiplicative(f))
                } else {
                    Err(format!(
                        "unknown allocation policy `{s}` (expected one|add:N|mult:F|all|model)"
                    ))
                }
            }
        }
    }
}

impl std::fmt::Display for AllocationPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AllocationPolicy::OneAtATime => write!(f, "one"),
            AllocationPolicy::Additive(n) => write!(f, "add:{n}"),
            AllocationPolicy::Multiplicative(x) => write!(f, "mult:{x}"),
            AllocationPolicy::AllAtOnce => write!(f, "all"),
            AllocationPolicy::Model => write!(f, "model"),
        }
    }
}

impl std::str::FromStr for AllocationPolicy {
    type Err = String;

    /// The `FromStr` face of [`AllocationPolicy::parse_flag`] — one
    /// parser shared by every CLI subcommand and example. Round-trips
    /// with `Display`.
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        AllocationPolicy::parse_flag(s)
    }
}

/// Provisioner tuning.
#[derive(Debug, Clone)]
pub struct ProvisionerConfig {
    /// Allocation aggressiveness.
    pub allocation: AllocationPolicy,
    /// Release executors idle for this many seconds (the paper's
    /// de-allocation policy; releases drop cached data).
    pub idle_release_s: f64,
    /// Static provisioning: allocate `initial_nodes` before t=0 and never
    /// change (the Fig 13 comparison run uses 64 static nodes).
    pub static_provisioning: bool,
    /// Nodes registered at experiment start (before any GRAM latency).
    pub initial_nodes: usize,
    /// Queue pressure that justifies one node: desired fleet =
    /// ceil(queue_len / queue_tasks_per_node), clamped to max_nodes.
    pub queue_tasks_per_node: u64,
}

impl Default for ProvisionerConfig {
    fn default() -> Self {
        ProvisionerConfig {
            allocation: AllocationPolicy::Multiplicative(2.0),
            idle_release_s: 60.0,
            static_provisioning: false,
            initial_nodes: 0,
            queue_tasks_per_node: 10,
        }
    }
}

impl ProvisionerConfig {
    /// Static fleet of `n` nodes (the paper's non-DRP baseline).
    pub fn static_nodes(n: usize) -> Self {
        ProvisionerConfig {
            static_provisioning: true,
            initial_nodes: n,
            ..ProvisionerConfig::default()
        }
    }
}

/// What the engine should enact after a provisioning tick.
#[derive(Debug, Default, Clone, PartialEq)]
pub struct ProvisionAction {
    /// Nodes to request from the LRM now (arrive after GRAM latency).
    pub allocate: usize,
    /// Idle executors to release now.
    pub release: Vec<ExecutorId>,
}

/// Cumulative provisioner statistics (Fig 13's CPU-time accounting uses
/// the registration intervals tracked by the metrics layer; these
/// counters cover decisions).
#[derive(Debug, Default, Clone)]
pub struct ProvisionerStats {
    /// Total nodes requested.
    pub nodes_requested: u64,
    /// Total nodes released.
    pub nodes_released: u64,
    /// Ticks that requested at least one node.
    pub allocation_decisions: u64,
}

/// The DRP decision engine.
#[derive(Debug)]
pub struct Provisioner {
    /// Tuning.
    pub config: ProvisionerConfig,
    max_nodes: usize,
    /// Nodes requested but not yet registered (in GRAM limbo).
    pending: usize,
    /// Fleet target for [`AllocationPolicy::Model`], set by the model
    /// controller just before each tick; `None` until the first solve.
    model_target: Option<usize>,
    /// Counters.
    pub stats: ProvisionerStats,
}

impl Provisioner {
    /// New provisioner for a cluster capped at `max_nodes`.
    pub fn new(config: ProvisionerConfig, max_nodes: usize) -> Self {
        Provisioner {
            config,
            max_nodes,
            pending: 0,
            model_target: None,
            stats: ProvisionerStats::default(),
        }
    }

    /// Nodes requested but not yet registered.
    pub fn pending(&self) -> usize {
        self.pending
    }

    /// Cluster node cap.
    pub fn max_nodes(&self) -> usize {
        self.max_nodes
    }

    /// Resize the node cap (the sharded router's model-driven quota
    /// rebalancing — docs/PROVISIONING.md). A standing model target is
    /// re-clamped to the new cap.
    pub fn set_max_nodes(&mut self, max_nodes: usize) {
        self.max_nodes = max_nodes;
        if let Some(t) = self.model_target {
            self.model_target = Some(t.min(max_nodes));
        }
    }

    /// Install the model controller's solved fleet target (clamped to
    /// `max_nodes`). Only consulted under [`AllocationPolicy::Model`].
    pub fn set_model_target(&mut self, target: usize) {
        self.model_target = Some(target.min(self.max_nodes));
    }

    /// The current model target, if a solve has happened.
    pub fn model_target(&self) -> Option<usize> {
        self.model_target
    }

    /// The engine must call this when a requested node finishes GRAM
    /// bootstrap and registers.
    pub fn on_node_registered(&mut self) {
        debug_assert!(self.pending > 0, "registration without a request");
        self.pending = self.pending.saturating_sub(1);
    }

    /// Periodic provisioning decision.
    ///
    /// `queue_len` is the current wait-queue length (the paper's load
    /// metric). Returns how many nodes to request and which to release.
    pub fn on_tick(
        &mut self,
        now: Micros,
        queue_len: usize,
        registry: &ExecutorRegistry,
    ) -> ProvisionAction {
        if self.config.static_provisioning {
            return ProvisionAction::default();
        }
        let mut action = ProvisionAction::default();
        let registered = registry.len();
        let capacity = registered + self.pending;

        // --- Model-predictive: track the solved target directly. The
        // controller already folded arrival pressure into the target, so
        // allocation happens even on a momentarily empty queue; release
        // stays idle-based and backlog-suppressed so the mid-serve and
        // about-to-work invariants of the static policies carry over.
        if self.config.allocation == AllocationPolicy::Model {
            if let Some(target) = self.model_target {
                if capacity < target {
                    action.allocate = (target - capacity).min(self.max_nodes - capacity);
                    if action.allocate > 0 {
                        self.pending += action.allocate;
                        self.stats.nodes_requested += action.allocate as u64;
                        self.stats.allocation_decisions += 1;
                    }
                }
                if queue_len == 0 && capacity > target && self.config.idle_release_s > 0.0 {
                    let cutoff =
                        now.saturating_sub(Micros::from_secs_f64(self.config.idle_release_s));
                    let mut idle = registry.idle_since(cutoff);
                    idle.truncate(capacity - target);
                    self.stats.nodes_released += idle.len() as u64;
                    action.release = idle;
                }
                return action;
            }
            // No solve yet (first tick): fall through to the
            // queue-pressure heuristic below.
        }

        // --- Allocation: queue pressure → desired fleet size.
        if queue_len > 0 && capacity < self.max_nodes {
            let desired = (queue_len as u64)
                .div_ceil(self.config.queue_tasks_per_node)
                .min(self.max_nodes as u64) as usize;
            let deficit = desired.saturating_sub(capacity);
            if deficit > 0 {
                let step = match self.config.allocation {
                    AllocationPolicy::OneAtATime => 1,
                    AllocationPolicy::Additive(k) => k.max(1),
                    AllocationPolicy::Multiplicative(f) => {
                        let grown = ((capacity.max(1)) as f64 * (f - 1.0)).ceil() as usize;
                        grown.max(1)
                    }
                    // Pre-solve fallback only (a standing target returns
                    // above): cover the visible deficit.
                    AllocationPolicy::AllAtOnce | AllocationPolicy::Model => deficit,
                };
                action.allocate = step.min(deficit).min(self.max_nodes - capacity);
                if action.allocate > 0 {
                    self.pending += action.allocate;
                    self.stats.nodes_requested += action.allocate as u64;
                    self.stats.allocation_decisions += 1;
                }
            }
        }

        // --- Release: executors idle longer than the threshold. Never
        // release while the queue is non-empty (they are about to get
        // work) — mirrors Falkon's demand-driven contraction.
        if queue_len == 0 && self.config.idle_release_s > 0.0 {
            let cutoff = now.saturating_sub(Micros::from_secs_f64(self.config.idle_release_s));
            action.release = registry.idle_since(cutoff);
            self.stats.nodes_released += action.release.len() as u64;
        }

        action
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn registry(n: usize) -> ExecutorRegistry {
        let mut reg = ExecutorRegistry::new();
        for _ in 0..n {
            reg.register(2, Micros::ZERO);
        }
        reg
    }

    #[test]
    fn allocates_under_queue_pressure() {
        let mut p = Provisioner::new(ProvisionerConfig::default(), 64);
        let reg = registry(0);
        let a = p.on_tick(Micros::from_secs(1), 100, &reg);
        assert!(a.allocate >= 1);
        assert_eq!(p.pending(), a.allocate);
    }

    #[test]
    fn respects_max_nodes() {
        let mut p = Provisioner::new(
            ProvisionerConfig {
                allocation: AllocationPolicy::AllAtOnce,
                ..ProvisionerConfig::default()
            },
            8,
        );
        let reg = registry(5);
        let a = p.on_tick(Micros::from_secs(1), 1_000_000, &reg);
        assert_eq!(a.allocate, 3);
        // All pending: no more allocations.
        let a2 = p.on_tick(Micros::from_secs(2), 1_000_000, &reg);
        assert_eq!(a2.allocate, 0);
    }

    #[test]
    fn multiplicative_growth_doubles() {
        let mut p = Provisioner::new(
            ProvisionerConfig {
                allocation: AllocationPolicy::Multiplicative(2.0),
                queue_tasks_per_node: 1,
                ..ProvisionerConfig::default()
            },
            64,
        );
        let reg = registry(4);
        let a = p.on_tick(Micros::from_secs(1), 1_000, &reg);
        assert_eq!(a.allocate, 4, "capacity 4 doubles to 8");
    }

    #[test]
    fn one_at_a_time_is_gentle() {
        let mut p = Provisioner::new(
            ProvisionerConfig {
                allocation: AllocationPolicy::OneAtATime,
                ..ProvisionerConfig::default()
            },
            64,
        );
        let reg = registry(0);
        assert_eq!(p.on_tick(Micros::from_secs(1), 10_000, &reg).allocate, 1);
    }

    #[test]
    fn no_allocation_when_queue_within_capacity() {
        let mut p = Provisioner::new(ProvisionerConfig::default(), 64);
        let reg = registry(10);
        // 10 nodes × 4 tasks/node threshold covers a queue of 40.
        let a = p.on_tick(Micros::from_secs(1), 40, &reg);
        assert_eq!(a.allocate, 0);
    }

    #[test]
    fn releases_idle_nodes_when_queue_empty() {
        let mut p = Provisioner::new(ProvisionerConfig::default(), 64);
        let mut reg = registry(2);
        // Node 1 worked recently; node 0 idle since t=0.
        reg.start_task(ExecutorId(1), Micros::from_secs(100));
        reg.finish_task(ExecutorId(1), Micros::from_secs(100));
        let a = p.on_tick(Micros::from_secs(90), 0, &reg);
        assert_eq!(a.release, vec![ExecutorId(0)]);
        // Queue pressure suppresses release.
        let a = p.on_tick(Micros::from_secs(90), 5, &reg);
        assert!(a.release.is_empty());
    }

    #[test]
    fn static_provisioning_never_changes() {
        let mut p = Provisioner::new(ProvisionerConfig::static_nodes(64), 64);
        let reg = registry(64);
        let a = p.on_tick(Micros::from_secs(1000), 1_000_000, &reg);
        assert_eq!(a, ProvisionAction::default());
    }

    #[test]
    fn model_policy_tracks_the_installed_target() {
        let mut p = Provisioner::new(
            ProvisionerConfig {
                allocation: AllocationPolicy::Model,
                idle_release_s: 10.0,
                ..ProvisionerConfig::default()
            },
            64,
        );
        let reg = registry(2);
        // Below target: allocate the difference, even with an empty queue.
        p.set_model_target(6);
        let a = p.on_tick(Micros::from_secs(1), 0, &reg);
        assert_eq!(a.allocate, 4);
        assert_eq!(p.pending(), 4);
        // At target (counting pending): no churn either way.
        let a = p.on_tick(Micros::from_secs(2), 50, &reg);
        assert_eq!(a, ProvisionAction::default());
        // Above target with an empty queue: release idles down to target,
        // not all of them.
        for _ in 0..4 {
            p.on_node_registered();
        }
        let reg6 = registry(6);
        p.set_model_target(4);
        let a = p.on_tick(Micros::from_secs(100), 0, &reg6);
        assert_eq!(a.allocate, 0);
        assert_eq!(a.release.len(), 2, "releases only the excess over target");
        // Backlog suppresses release entirely.
        let a = p.on_tick(Micros::from_secs(100), 3, &reg6);
        assert!(a.release.is_empty());
    }

    #[test]
    fn model_target_clamps_to_max_nodes() {
        let mut p = Provisioner::new(
            ProvisionerConfig {
                allocation: AllocationPolicy::Model,
                ..ProvisionerConfig::default()
            },
            8,
        );
        p.set_model_target(1_000);
        assert_eq!(p.model_target(), Some(8));
        let reg = registry(0);
        assert_eq!(p.on_tick(Micros::from_secs(1), 0, &reg).allocate, 8);
        // Shrinking the cap re-clamps a standing target.
        p.set_max_nodes(4);
        assert_eq!(p.model_target(), Some(4));
        assert_eq!(p.max_nodes(), 4);
    }

    #[test]
    fn allocation_flag_round_trips() {
        for s in ["one", "add:8", "mult:2", "all", "model"] {
            let p = AllocationPolicy::parse_flag(s).unwrap();
            assert_eq!(p.to_string(), s, "display must round-trip `{s}`");
            // FromStr is the same parser.
            assert_eq!(s.parse::<AllocationPolicy>(), Ok(p));
        }
        assert_eq!(
            AllocationPolicy::parse_flag("mult:1.5").unwrap(),
            AllocationPolicy::Multiplicative(1.5)
        );
        for bad in ["", "two", "add:0", "add:x", "mult:1", "mult:nan", "mult:"] {
            assert!(AllocationPolicy::parse_flag(bad).is_err(), "`{bad}`");
        }
    }

    #[test]
    fn registration_drains_pending() {
        let mut p = Provisioner::new(ProvisionerConfig::default(), 64);
        let reg = registry(0);
        let a = p.on_tick(Micros::from_secs(1), 100, &reg);
        for _ in 0..a.allocate {
            p.on_node_registered();
        }
        assert_eq!(p.pending(), 0);
    }
}
