//! Inverted pending-task index with **epoch-lazy candidate maintenance**
//! (§Perf iterations 3–4).
//!
//! The O(min(|Q|, W)) window scan of §3.2 is the paper's *upper bound*
//! per scheduling decision, and at W = 100×nodes (3200–6400 entries) it
//! is exactly the hot path DIANA-style bulk schedulers identify as the
//! throughput ceiling. This module replaces the scan with two inverted
//! maps:
//!
//! * **by_file** — `FileId → {seq → QueueRef}`: every queued task,
//!   keyed by each file it reads. This is the paper's wait queue viewed
//!   through θ(κ) instead of arrival order. It is maintained **eagerly**
//!   and is always exact: a task enters on push and leaves on dispatch,
//!   both O(|θ(κ)|).
//! * **per-executor candidate sets** — `ExecutorId → {seq → QueueRef}`:
//!   the materialized intersection of `E_map(executor)` with the pending
//!   set — the queued tasks with ≥ 1 cached file at that executor, in
//!   queue order. A pickup enumerates this set and stops at the first
//!   100 %-hit task, so its cost tracks the executor's **actual cache
//!   overlap with the window**, not the window size.
//!
//! ## Epoch-lazy maintenance (§Perf iteration 4)
//!
//! Keeping the candidate sets exact at every cache event is where the
//! original design could lose its win: a cache insert or evict of file
//! `f` at executor `e` touches every pending reader of `f`, and a single
//! popular file with thousands of queued readers under eviction churn
//! (the Fig 11 regime) pays O(pending readers) **per event** — per-event
//! scheduler overhead is exactly what bounds achievable throughput in
//! bulk schedulers (DIANA; the data-diffusion follow-up, arXiv:0808.3546).
//! The candidate sets are therefore maintained *lazily*:
//!
//! * The index keeps a global **epoch** — a counter bumped by every
//!   location-index mutation ([`PendingIndex::on_index_add`] /
//!   [`PendingIndex::on_index_remove`] / [`PendingIndex::on_deregister`]).
//!   Each executor's candidate set records the epoch it was last
//!   reconciled at ([`PendingIndex::epoch_of`]); a set whose epoch lags
//!   the global epoch **may be stale** and must not be consulted without
//!   a [`PendingIndex::refresh`].
//! * A cache event touching a file with at most [`FANOUT_CAP`] pending
//!   readers is applied immediately (bounded work — the *capped per-file
//!   fan-out*). A hotter file is recorded as an O(1) **dirty record** on
//!   the executor instead; at most [`DIRTY_CAP`] distinct dirty files are
//!   kept, beyond which the patch log is abandoned and the set marked for
//!   a full **overflow rebuild**.
//! * [`PendingIndex::refresh`] — called once per consult (the scheduler's
//!   pickup, [`crate::coordinator::scheduler::Scheduler::pick_tasks`]) —
//!   settles the debt: dirty files are patched against the *current*
//!   location index (so an evict+re-add cycle between consults coalesces
//!   to a no-op membership check), and an overflowed set is rebuilt from
//!   `E_map(executor) × by_file` — the *lazy overflow scan*, proportional
//!   to the executor's overlap, not the queue.
//!
//! ### Invariants (what the parity suite pins down)
//!
//! 1. After `refresh(e)`, the **live** entries of `e`'s candidate set are
//!    exactly the eager set: `{(seq, qref) : ∃ f ∈ θ(task), holds(f, e)}`
//!    over queued tasks.
//! 2. A refreshed set may additionally contain **dead hints**: a task
//!    whose every `e`-cached file was evicted *while its fan-out was
//!    deferred*, and which then left the queue, cannot be found by any
//!    later patch (it is gone from `by_file`). Dead hints are harmless:
//!    consumers validate each entry in O(1) via
//!    [`crate::coordinator::queue::WaitQueue::live_seq`] (sequence
//!    numbers are never reused) and purge them on encounter
//!    ([`PendingIndex::purge_dead`]); an overflow rebuild discards them
//!    wholesale.
//! 3. `by_file` is always exact; only candidate sets are lazy.
//!
//! This is why eviction is O(1) on the hot path: the event does a length
//! probe, bumps the epoch, and either applies a ≤ [`FANOUT_CAP`] fan-out
//! or pushes one dirty record. The deferred work is paid once per
//! consult, after coalescing — [`PendingStats`] counts it so the
//! `perf_hotpath` bench and the CI gate can assert lazy ≤ eager.
//!
//! ## Notify-side reuse
//!
//! Phase 1 of the scheduler ([`crate::coordinator::scheduler::Scheduler::select_notify`])
//! repeatedly asks "which executors hold any of the head task's files,
//! and which free one overlaps most?" — historically recounted from the
//! holder sets on every call. [`PendingIndex::head_ranked`] memoizes the
//! answer: the candidate executors are the word-wise **union** of the
//! files' holder bitsets ([`crate::index::ExecSet::union_with`]), ranked
//! once by overlap (descending, ids ascending), and the memo is valid
//! until the epoch moves or the head's file set changes. Repeat notifies
//! for the same head — the common pattern while the cluster is saturated
//! — reuse the ranking and only probe free-ness.
//!
//! ## Modes
//!
//! [`PendingIndex::new`] is **lazy** (the engine default);
//! [`PendingIndex::eager`] retains the always-exact maintenance as the
//! executable reference. `rust/tests/sched_parity.rs` drives both (all
//! five policies, eviction churn over a popular file with thousands of
//! queued readers) and asserts identical dispatch plus lazy maintenance
//! strictly below eager. The index is **only maintained for data-aware
//! policies** (`uses_caching()`); first-available pops the queue head
//! and never consults it. All removal paths are safe no-ops on an
//! unmaintained (empty) index.

use crate::coordinator::queue::{QueueRef, WaitQueue};
use crate::ids::{ExecutorId, FileId};
use crate::index::{ExecSet, LocationIndex};
use std::collections::{BTreeMap, HashMap};

/// Per-key pending sets, ordered by queue sequence number so iteration
/// yields tasks in queue order (seq order == queue order).
pub type SeqSet = BTreeMap<u64, QueueRef>;

/// Cache events touching a file with at most this many pending readers
/// are applied to the executor's candidate set immediately (the capped
/// per-file fan-out); hotter files defer to a dirty record instead.
pub const FANOUT_CAP: usize = 16;

/// Distinct deferred files per executor before the incremental patch log
/// is abandoned for a full overflow rebuild at the next consult.
pub const DIRTY_CAP: usize = 32;

/// How the per-executor candidate sets are maintained.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Maintenance {
    /// Epoch-lazy (engine default): O(1)-bounded work per cache event,
    /// debt settled at consult. See the module docs.
    Lazy,
    /// Always-exact maintenance — the executable reference the parity
    /// suite compares against (the pre-iteration-4 behavior).
    Eager,
}

/// Deterministic work counters for the maintenance machinery. These are
/// machine-independent, so `perf_hotpath` snapshots them and
/// `tools/bench_gate.py` gates lazy ≤ eager on the hot-file workload.
#[derive(Debug, Default, Clone)]
pub struct PendingStats {
    /// `on_index_add`/`on_index_remove` calls (cache events seen).
    pub index_events: u64,
    /// Per-entry candidate-set mutations/examinations — the cost being
    /// bounded. Eager mode pays these at event time; lazy mode at
    /// consult time, after coalescing.
    pub maintenance_ops: u64,
    /// O(1) deferrals recorded instead of an immediate fan-out.
    pub dirty_records: u64,
    /// Full per-executor rebuilds (overflowed patch logs).
    pub epoch_rebuilds: u64,
    /// Distinct dirty files patched incrementally at refresh.
    pub patched_files: u64,
    /// Notify rankings rebuilt ([`PendingIndex::head_ranked`] misses).
    pub notify_memo_builds: u64,
    /// Notify decisions answered from the memoized ranking.
    pub notify_memo_hits: u64,
    /// Dead hints dropped by [`PendingIndex::purge_dead`] — lazily
    /// maintained candidate entries whose task left the queue while its
    /// eviction was deferred (module-docs invariant 2), purged on
    /// encounter by the scheduler's phase-A walk. This makes the memory
    /// argument explicit: dead hints never accumulate past their first
    /// encounter, and the `sched_parity` leave-queue-churn regression
    /// bounds the count.
    pub dead_hints_purged: u64,
}

/// One executor's lazily maintained candidate set.
#[derive(Debug, Default)]
struct ExecState {
    /// Materialized candidates (live entries exact after a refresh; may
    /// carry dead hints — see the module docs).
    set: SeqSet,
    /// Global epoch this set was last reconciled at (diagnostic: a set
    /// is *possibly stale* while this lags [`PendingIndex::epoch`]).
    epoch: u64,
    /// Distinct files with a deferred membership change (≤ [`DIRTY_CAP`]).
    dirty: Vec<FileId>,
    /// Patch log abandoned; rebuild from scratch at the next refresh.
    overflow: bool,
}

/// Memoized phase-1 ranking for the current head task (see module docs).
#[derive(Debug, Default)]
struct NotifyMemo {
    valid: bool,
    epoch: u64,
    files: Vec<FileId>,
    /// Scratch union of the files' holder bitsets.
    union: ExecSet,
    /// Candidates ranked by (overlap desc, id asc) — the reference
    /// notify tie-break, precomputed.
    ranked: Vec<(ExecutorId, u32)>,
}

/// The inverted pending index. See the module docs for the invariants.
#[derive(Debug)]
pub struct PendingIndex {
    /// Pending tasks by file read (always exact).
    by_file: HashMap<FileId, SeqSet>,
    /// Per-executor candidate state (lazy or eager per `mode`).
    execs: HashMap<ExecutorId, ExecState>,
    /// Maintenance mode (lazy = engine default).
    mode: Maintenance,
    /// Global location-index mutation counter — the validity epoch for
    /// candidate sets and the notify memo.
    epoch: u64,
    memo: NotifyMemo,
    /// Deterministic work counters (see [`PendingStats`]).
    pub stats: PendingStats,
}

impl Default for PendingIndex {
    fn default() -> Self {
        PendingIndex {
            by_file: HashMap::new(),
            execs: HashMap::new(),
            mode: Maintenance::Lazy,
            epoch: 0,
            memo: NotifyMemo::default(),
            stats: PendingStats::default(),
        }
    }
}

impl PendingIndex {
    /// Empty index in [`Maintenance::Lazy`] mode (the engine default).
    pub fn new() -> Self {
        Self::default()
    }

    /// Empty index in [`Maintenance::Eager`] mode — the always-exact
    /// reference the parity suite compares against.
    pub fn eager() -> Self {
        PendingIndex {
            mode: Maintenance::Eager,
            ..Self::default()
        }
    }

    /// The maintenance mode this index runs in.
    pub fn mode(&self) -> Maintenance {
        self.mode
    }

    /// Current global epoch (bumped by every location-index mutation).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Epoch `executor`'s candidate set was last reconciled at, if it has
    /// one. Lagging [`PendingIndex::epoch`] means *possibly stale*.
    pub fn epoch_of(&self, executor: ExecutorId) -> Option<u64> {
        self.execs.get(&executor).map(|st| st.epoch)
    }

    /// Record a task just pushed onto the wait queue. Must be called
    /// after `queue.push_back` (it reads the task back through `qref`),
    /// and only for caching policies. O(|θ(κ)| × replication): pushes are
    /// applied eagerly in both modes — the fan-out is bounded by the
    /// replication cap, not by queue depth, so there is nothing to defer.
    pub fn on_push(&mut self, queue: &WaitQueue, qref: QueueRef, index: &LocationIndex) {
        let seq = queue.seq_of(qref);
        let task = queue.get(qref);
        for &f in &task.files {
            self.by_file.entry(f).or_default().insert(seq, qref);
            if let Some(holders) = index.holders(f) {
                for e in holders {
                    self.execs.entry(e).or_default().set.insert(seq, qref);
                }
            }
        }
    }

    /// Record a task leaving the wait queue. `files`/`seq` are the
    /// removed task's (capture `seq` via [`WaitQueue::seq_of`] *before*
    /// the `queue.remove`). Safe no-op when the index is unmaintained.
    ///
    /// Sweeping the *current* holders of every file covers all candidate
    /// entries the eager semantics would hold; an entry kept alive only
    /// by a deferred (not-yet-patched) eviction becomes a dead hint and
    /// is caught by read-time validation (module docs, invariant 2).
    pub fn on_remove(&mut self, files: &[FileId], seq: u64, index: &LocationIndex) {
        for &f in files {
            if let Some(set) = self.by_file.get_mut(&f) {
                set.remove(&seq);
                if set.is_empty() {
                    self.by_file.remove(&f);
                }
            }
            if let Some(holders) = index.holders(f) {
                for e in holders {
                    if let Some(st) = self.execs.get_mut(&e) {
                        st.set.remove(&seq);
                    }
                }
            }
        }
    }

    /// Record that the location index just **added** (file, executor) —
    /// a cache insert. Call after [`LocationIndex::add`].
    ///
    /// Lazy mode: O([`FANOUT_CAP`]) worst case — a small fan-out applies
    /// immediately, a hot file becomes one dirty record.
    pub fn on_index_add(&mut self, file: FileId, executor: ExecutorId) {
        self.epoch += 1;
        self.stats.index_events += 1;
        let Some(pending) = self.by_file.get(&file) else {
            return; // no pending readers: nothing can change
        };
        match self.mode {
            Maintenance::Eager => {
                let st = self.execs.entry(executor).or_default();
                for (&seq, &qref) in pending {
                    st.set.insert(seq, qref);
                    self.stats.maintenance_ops += 1;
                }
            }
            Maintenance::Lazy => {
                let st = self.execs.entry(executor).or_default();
                if st.overflow {
                    return; // rebuild at next consult covers this event
                }
                if pending.len() <= FANOUT_CAP {
                    for (&seq, &qref) in pending {
                        st.set.insert(seq, qref);
                        self.stats.maintenance_ops += 1;
                    }
                } else {
                    self.stats.dirty_records += 1;
                    Self::defer(st, file);
                }
            }
        }
    }

    /// Record that the location index just **removed** (file, executor)
    /// — an eviction. Call after [`LocationIndex::remove`]. A pending
    /// task reading `file` stays a candidate only if another of its
    /// files is still cached there.
    ///
    /// Lazy mode: O([`FANOUT_CAP`]) worst case, like
    /// [`PendingIndex::on_index_add`] — this is the call that used to pay
    /// O(pending readers) per eviction of a popular file.
    pub fn on_index_remove(
        &mut self,
        file: FileId,
        executor: ExecutorId,
        queue: &WaitQueue,
        index: &LocationIndex,
    ) {
        self.epoch += 1;
        self.stats.index_events += 1;
        let Some(pending) = self.by_file.get(&file) else {
            return;
        };
        let Some(st) = self.execs.get_mut(&executor) else {
            return; // never had candidates: nothing to retract
        };
        match self.mode {
            Maintenance::Eager => {
                for (&seq, &qref) in pending {
                    self.stats.maintenance_ops += 1;
                    let task = queue.get(qref);
                    if !task.files.iter().any(|&f2| index.holds(f2, executor)) {
                        st.set.remove(&seq);
                    }
                }
            }
            Maintenance::Lazy => {
                if st.overflow {
                    return;
                }
                if pending.len() <= FANOUT_CAP {
                    for (&seq, &qref) in pending {
                        self.stats.maintenance_ops += 1;
                        let task = queue.get(qref);
                        if !task.files.iter().any(|&f2| index.holds(f2, executor)) {
                            st.set.remove(&seq);
                        }
                    }
                } else {
                    self.stats.dirty_records += 1;
                    Self::defer(st, file);
                }
            }
        }
    }

    /// Enqueue a dirty record, overflowing into a rebuild when the patch
    /// log is full. The `contains` probe is O([`DIRTY_CAP`]) — repeated
    /// churn on the same hot file coalesces into one record.
    fn defer(st: &mut ExecState, file: FileId) {
        if st.dirty.contains(&file) {
            return;
        }
        if st.dirty.len() >= DIRTY_CAP {
            st.overflow = true;
            st.dirty.clear();
        } else {
            st.dirty.push(file);
        }
    }

    /// Settle an executor's deferred maintenance so its candidate set is
    /// consultable (module-docs invariant 1). Called once per pickup by
    /// the scheduler; O(1) when nothing changed since the last consult.
    ///
    /// Dirty files are patched against the **current** index state, so
    /// any number of add/evict cycles on one file between consults costs
    /// one walk of its pending readers. An overflowed log rebuilds the
    /// set from `E_map(executor) × by_file` instead — proportional to the
    /// executor's overlap with the pending set, never to |Q|.
    pub fn refresh(&mut self, executor: ExecutorId, queue: &WaitQueue, index: &LocationIndex) {
        let Some(st) = self.execs.get_mut(&executor) else {
            return;
        };
        if st.overflow {
            self.stats.epoch_rebuilds += 1;
            st.overflow = false;
            st.dirty.clear();
            st.set.clear();
            if let Some(cached) = index.cached_at(executor) {
                for &f in cached {
                    if let Some(pending) = self.by_file.get(&f) {
                        for (&seq, &qref) in pending {
                            st.set.insert(seq, qref);
                            self.stats.maintenance_ops += 1;
                        }
                    }
                }
            }
        } else if !st.dirty.is_empty() {
            let mut dirty = std::mem::take(&mut st.dirty);
            for &f in &dirty {
                self.stats.patched_files += 1;
                let Some(pending) = self.by_file.get(&f) else {
                    continue; // last reader dispatched meanwhile
                };
                if index.holds(f, executor) {
                    for (&seq, &qref) in pending {
                        st.set.insert(seq, qref);
                        self.stats.maintenance_ops += 1;
                    }
                } else {
                    for (&seq, &qref) in pending {
                        self.stats.maintenance_ops += 1;
                        let task = queue.get(qref);
                        if !task.files.iter().any(|&f2| index.holds(f2, executor)) {
                            st.set.remove(&seq);
                        }
                    }
                }
            }
            dirty.clear();
            st.dirty = dirty; // hand the allocation back
        }
        st.epoch = self.epoch;
    }

    /// Drop dead hints the consumer found while iterating `executor`'s
    /// candidate set (entries failing the
    /// [`WaitQueue::live_seq`] validation — module-docs invariant 2).
    pub fn purge_dead(&mut self, executor: ExecutorId, seqs: &[u64]) {
        if let Some(st) = self.execs.get_mut(&executor) {
            for seq in seqs {
                if st.set.remove(seq).is_some() {
                    self.stats.dead_hints_purged += 1;
                }
            }
        }
    }

    /// The executor's materialized candidate set (≥1 cached file), in
    /// queue order. **Raw view**: in lazy mode, call
    /// [`PendingIndex::refresh`] first and validate entries with
    /// [`WaitQueue::live_seq`] while iterating — see the module docs.
    pub fn candidates(&self, executor: ExecutorId) -> Option<&SeqSet> {
        self.execs.get(&executor).map(|st| &st.set)
    }

    /// Memoized phase-1 ranking for a head task reading `files`: every
    /// executor holding ≥1 of the files, ordered by (overlap desc, id
    /// asc) — the reference notify tie-break. Built from a word-wise
    /// union of the holder bitsets, at most once per (file set, epoch);
    /// repeat notifies for the same head reuse it, so `select_notify`
    /// never recounts holder overlap per call.
    pub fn head_ranked(
        &mut self,
        files: &[FileId],
        index: &LocationIndex,
    ) -> &[(ExecutorId, u32)] {
        let memo = &mut self.memo;
        if memo.valid && memo.epoch == self.epoch && memo.files.as_slice() == files {
            self.stats.notify_memo_hits += 1;
            return &memo.ranked;
        }
        self.stats.notify_memo_builds += 1;
        memo.valid = true;
        memo.epoch = self.epoch;
        memo.files.clear();
        memo.files.extend_from_slice(files);
        memo.union.clear();
        for &f in files {
            if let Some(holders) = index.holders(f) {
                memo.union.union_with(holders);
            }
        }
        memo.ranked.clear();
        for e in &memo.union {
            let overlap = index.hit_count(e, files) as u32;
            memo.ranked.push((e, overlap));
        }
        memo.ranked
            .sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        &memo.ranked
    }

    /// Drop an executor's candidate state (provisioner release).
    pub fn on_deregister(&mut self, executor: ExecutorId) {
        self.epoch += 1; // holder sets changed: invalidate the memo
        self.execs.remove(&executor);
    }

    /// Pending tasks referencing `file`, in queue order.
    pub fn pending_for_file(&self, file: FileId) -> Option<&SeqSet> {
        self.by_file.get(&file)
    }

    /// Distinct files with ≥1 pending reader.
    pub fn distinct_pending_files(&self) -> usize {
        self.by_file.len()
    }

    /// Rebuild from scratch — the executable spec of the incremental
    /// maintenance, used by the consistency check and tests. Built with
    /// pushes only, so the result is exact in either mode.
    #[doc(hidden)]
    pub fn rebuild(queue: &WaitQueue, index: &LocationIndex) -> PendingIndex {
        let mut fresh = PendingIndex::new();
        let refs: Vec<QueueRef> = queue.window(usize::MAX).map(|(r, _)| r).collect();
        for r in refs {
            fresh.on_push(queue, r, index);
        }
        fresh
    }

    /// Check the incremental state equals a from-scratch rebuild: after a
    /// refresh, each executor's **live** candidate entries must match the
    /// rebuild exactly (dead hints are excluded — module-docs invariant
    /// 2; in eager mode there are none, so this is full equality).
    #[doc(hidden)]
    pub fn check_consistent(
        &mut self,
        queue: &WaitQueue,
        index: &LocationIndex,
    ) -> Result<(), String> {
        let fresh = PendingIndex::rebuild(queue, index);
        if self.by_file != fresh.by_file {
            return Err("by_file drifted from rebuild".into());
        }
        let mut keys: Vec<ExecutorId> = self.execs.keys().copied().collect();
        keys.extend(fresh.execs.keys().copied());
        keys.sort_unstable();
        keys.dedup();
        for e in keys {
            self.refresh(e, queue, index);
            let live: SeqSet = self
                .execs
                .get(&e)
                .map(|st| {
                    st.set
                        .iter()
                        .filter(|&(&s, &q)| queue.live_seq(q) == Some(s))
                        .map(|(&s, &q)| (s, q))
                        .collect()
                })
                .unwrap_or_default();
            let expect = fresh
                .execs
                .get(&e)
                .map(|st| st.set.clone())
                .unwrap_or_default();
            if live != expect {
                return Err(format!(
                    "candidates for {e} drifted from rebuild: {} live vs {} expected",
                    live.len(),
                    expect.len()
                ));
            }
        }
        Ok(())
    }
}

/// Remove a queued task and keep the pending index coherent — the single
/// removal path shared by the scheduler and the experiment drivers.
pub fn remove_queued(
    queue: &mut WaitQueue,
    pending: &mut PendingIndex,
    qref: QueueRef,
    index: &LocationIndex,
) -> crate::coordinator::queue::Task {
    let seq = queue.seq_of(qref);
    let task = queue.remove(qref);
    pending.on_remove(&task.files, seq, index);
    task
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::queue::Task;
    use crate::ids::TaskId;
    use crate::util::time::Micros;

    fn task(i: u64, files: &[u32]) -> Task {
        Task {
            id: TaskId(i),
            files: files.iter().map(|&f| FileId(f)).collect(),
            compute: Micros::ZERO,
            arrival: Micros::ZERO,
        }
    }

    fn push(
        q: &mut WaitQueue,
        p: &mut PendingIndex,
        ix: &LocationIndex,
        t: Task,
    ) -> QueueRef {
        let r = q.push_back(t);
        p.on_push(q, r, ix);
        r
    }

    #[test]
    fn candidates_follow_index_adds_and_evictions() {
        // Fan-outs below FANOUT_CAP apply immediately even in lazy mode,
        // so small scenarios behave exactly like the eager reference.
        let mut q = WaitQueue::new();
        let mut p = PendingIndex::new();
        let mut ix = LocationIndex::new();
        let e = ExecutorId(3);

        let r = push(&mut q, &mut p, &ix, task(0, &[7]));
        assert!(p.candidates(e).is_none_or(|s| s.is_empty()));

        ix.add(FileId(7), e);
        p.on_index_add(FileId(7), e);
        assert_eq!(p.candidates(e).unwrap().len(), 1);

        ix.remove(FileId(7), e);
        p.on_index_remove(FileId(7), e, &q, &ix);
        assert!(p.candidates(e).unwrap().is_empty());
        p.check_consistent(&q, &ix).unwrap();

        // Removal cleans by_file.
        let seq = q.seq_of(r);
        let t = q.remove(r);
        p.on_remove(&t.files, seq, &ix);
        assert_eq!(p.distinct_pending_files(), 0);
    }

    #[test]
    fn multi_file_task_stays_candidate_after_partial_eviction() {
        let mut q = WaitQueue::new();
        let mut p = PendingIndex::new();
        let mut ix = LocationIndex::new();
        let e = ExecutorId(0);
        ix.add(FileId(1), e);
        ix.add(FileId(2), e);
        push(&mut q, &mut p, &ix, task(0, &[1, 2]));
        assert_eq!(p.candidates(e).unwrap().len(), 1);

        // Evict file 1: the task still reads file 2, cached at e.
        ix.remove(FileId(1), e);
        p.on_index_remove(FileId(1), e, &q, &ix);
        assert_eq!(p.candidates(e).unwrap().len(), 1);

        // Evict file 2 too: no longer a candidate.
        ix.remove(FileId(2), e);
        p.on_index_remove(FileId(2), e, &q, &ix);
        assert!(p.candidates(e).unwrap().is_empty());
        p.check_consistent(&q, &ix).unwrap();
    }

    #[test]
    fn remove_queued_keeps_everything_coherent() {
        let mut q = WaitQueue::new();
        let mut p = PendingIndex::new();
        let mut ix = LocationIndex::new();
        ix.add(FileId(5), ExecutorId(1));
        let a = push(&mut q, &mut p, &ix, task(0, &[5]));
        let _b = push(&mut q, &mut p, &ix, task(1, &[5]));
        let t = remove_queued(&mut q, &mut p, a, &ix);
        assert_eq!(t.id, TaskId(0));
        assert_eq!(p.candidates(ExecutorId(1)).unwrap().len(), 1);
        p.check_consistent(&q, &ix).unwrap();
    }

    /// Hot-file events (readers > FANOUT_CAP) must become O(1) dirty
    /// records, with add/evict cycles coalescing at the refresh.
    #[test]
    fn hot_file_defers_and_coalesces() {
        let mut q = WaitQueue::new();
        let mut p = PendingIndex::new();
        let mut ix = LocationIndex::new();
        let e = ExecutorId(0);
        let hot = FileId(9);
        let readers = (FANOUT_CAP + 4) as u64;
        for i in 0..readers {
            push(&mut q, &mut p, &ix, task(i, &[9]));
        }
        let epoch0 = p.epoch();

        // Churn the hot file several times between consults: every event
        // is a deferral, not a fan-out.
        for _ in 0..5 {
            ix.add(hot, e);
            p.on_index_add(hot, e);
            ix.remove(hot, e);
            p.on_index_remove(hot, e, &q, &ix);
        }
        ix.add(hot, e);
        p.on_index_add(hot, e);
        assert_eq!(p.stats.maintenance_ops, 0, "hot events must not fan out");
        assert_eq!(p.stats.dirty_records, 11);
        assert!(p.epoch() > epoch0);
        assert!(p.epoch_of(e).unwrap_or(0) < p.epoch(), "set is stale");

        // One refresh settles the whole cycle with one coalesced walk.
        p.refresh(e, &q, &ix);
        assert_eq!(p.candidates(e).unwrap().len(), readers as usize);
        assert_eq!(p.stats.maintenance_ops, readers);
        assert_eq!(p.stats.patched_files, 1);
        assert_eq!(p.epoch_of(e), Some(p.epoch()));
        p.check_consistent(&q, &ix).unwrap();
    }

    /// More than DIRTY_CAP distinct hot files abandon the patch log and
    /// rebuild the set from the executor's cache contents.
    #[test]
    fn overflow_triggers_rebuild() {
        let mut q = WaitQueue::new();
        let mut p = PendingIndex::new();
        let mut ix = LocationIndex::new();
        let e = ExecutorId(2);
        let nfiles = (DIRTY_CAP + 1) as u32;
        let readers_per_file = (FANOUT_CAP + 1) as u64;
        let mut id = 0u64;
        for f in 0..nfiles {
            for _ in 0..readers_per_file {
                push(&mut q, &mut p, &ix, task(id, &[f]));
                id += 1;
            }
        }
        for f in 0..nfiles {
            ix.add(FileId(f), e);
            p.on_index_add(FileId(f), e);
        }
        p.refresh(e, &q, &ix);
        assert_eq!(p.stats.epoch_rebuilds, 1);
        assert_eq!(
            p.candidates(e).unwrap().len(),
            (nfiles as u64 * readers_per_file) as usize
        );
        p.check_consistent(&q, &ix).unwrap();
    }

    /// Invariant 2: a task whose deferred eviction was never patched and
    /// which then left the queue lingers as a dead hint — skipped by
    /// read-time validation and removable via purge_dead.
    #[test]
    fn dead_hints_validate_and_purge() {
        let mut q = WaitQueue::new();
        let mut p = PendingIndex::new();
        let mut ix = LocationIndex::new();
        let e = ExecutorId(1);
        let hot = FileId(3);
        ix.add(hot, e);
        let readers = (FANOUT_CAP + 4) as u64;
        let refs: Vec<QueueRef> = (0..readers)
            .map(|i| push(&mut q, &mut p, &ix, task(i, &[3])))
            .collect();
        assert_eq!(p.candidates(e).unwrap().len(), readers as usize);

        // Evict the hot file (deferred), then dispatch one reader before
        // any refresh: its candidate entry cannot be found by the patch.
        ix.remove(hot, e);
        p.on_index_remove(hot, e, &q, &ix);
        let victim = refs[0];
        let seq = q.seq_of(victim);
        let t = remove_queued(&mut q, &mut p, victim, &ix);
        assert_eq!(t.id, TaskId(0));

        p.refresh(e, &q, &ix);
        let set = p.candidates(e).unwrap();
        assert_eq!(set.len(), 1, "only the dead hint survives the patch");
        let (&dead_seq, &dead_ref) = set.iter().next().unwrap();
        assert_eq!(dead_seq, seq);
        assert_ne!(q.live_seq(dead_ref), Some(dead_seq), "hint must be dead");
        // The consistency check ignores dead hints…
        p.check_consistent(&q, &ix).unwrap();
        // …and purge removes them for good, counting each drop once
        // (repeat purges of the same seq are not double-counted).
        p.purge_dead(e, &[dead_seq]);
        assert!(p.candidates(e).unwrap().is_empty());
        assert_eq!(p.stats.dead_hints_purged, 1);
        p.purge_dead(e, &[dead_seq]);
        assert_eq!(p.stats.dead_hints_purged, 1);
    }

    #[test]
    fn notify_memo_reuses_until_epoch_moves() {
        let mut p = PendingIndex::new();
        let mut ix = LocationIndex::new();
        ix.add(FileId(1), ExecutorId(0));
        ix.add(FileId(1), ExecutorId(2));
        ix.add(FileId(2), ExecutorId(2));
        let files = [FileId(1), FileId(2)];
        let ranked: Vec<(ExecutorId, u32)> = p.head_ranked(&files, &ix).to_vec();
        // Executor 2 holds both files, executor 0 one; ids break ties.
        assert_eq!(ranked, vec![(ExecutorId(2), 2), (ExecutorId(0), 1)]);
        let _ = p.head_ranked(&files, &ix);
        assert_eq!(p.stats.notify_memo_builds, 1);
        assert_eq!(p.stats.notify_memo_hits, 1);

        // A different head misses; the epoch moving misses again.
        let _ = p.head_ranked(&[FileId(2)], &ix);
        assert_eq!(p.stats.notify_memo_builds, 2);
        ix.add(FileId(2), ExecutorId(1));
        p.on_index_add(FileId(2), ExecutorId(1));
        let ranked: Vec<(ExecutorId, u32)> = p.head_ranked(&[FileId(2)], &ix).to_vec();
        assert_eq!(p.stats.notify_memo_builds, 3);
        assert_eq!(ranked, vec![(ExecutorId(1), 1), (ExecutorId(2), 1)]);
    }

    #[test]
    fn eager_mode_matches_old_behavior_and_counts_ops() {
        let mut q = WaitQueue::new();
        let mut p = PendingIndex::eager();
        let mut ix = LocationIndex::new();
        assert_eq!(p.mode(), Maintenance::Eager);
        let e = ExecutorId(0);
        let readers = (FANOUT_CAP + 10) as u64;
        for i in 0..readers {
            push(&mut q, &mut p, &ix, task(i, &[1]));
        }
        ix.add(FileId(1), e);
        p.on_index_add(FileId(1), e);
        // Eager: the fan-out happens at event time, however hot the file.
        assert_eq!(p.candidates(e).unwrap().len(), readers as usize);
        assert_eq!(p.stats.maintenance_ops, readers);
        assert_eq!(p.stats.dirty_records, 0);
        ix.remove(FileId(1), e);
        p.on_index_remove(FileId(1), e, &q, &ix);
        assert!(p.candidates(e).unwrap().is_empty());
        assert_eq!(p.stats.maintenance_ops, 2 * readers);
        p.check_consistent(&q, &ix).unwrap();
    }

    #[test]
    fn incremental_matches_rebuild_under_random_ops() {
        use crate::util::proptest::{property, Gen};
        for eager in [false, true] {
            property("pending index vs rebuild", 60, |g: &mut Gen| {
                let mut q = WaitQueue::new();
                let mut p = if eager {
                    PendingIndex::eager()
                } else {
                    PendingIndex::new()
                };
                let mut ix = LocationIndex::new();
                let mut live: Vec<QueueRef> = Vec::new();
                let mut next_id = 0u64;
                for _ in 0..g.usize_in(1..120) {
                    match g.usize_in(0..7) {
                        0 | 1 => {
                            let nfiles = g.usize_in(1..4);
                            let files: Vec<u32> =
                                (0..nfiles).map(|_| g.u64_in(0..12) as u32).collect();
                            let r = push(&mut q, &mut p, &ix, task(next_id, &files));
                            live.push(r);
                            next_id += 1;
                        }
                        2 => {
                            let f = FileId(g.u64_in(0..12) as u32);
                            let e = ExecutorId(g.u64_in(0..6) as u32);
                            ix.add(f, e);
                            p.on_index_add(f, e);
                        }
                        3 => {
                            let f = FileId(g.u64_in(0..12) as u32);
                            let e = ExecutorId(g.u64_in(0..6) as u32);
                            ix.remove(f, e);
                            p.on_index_remove(f, e, &q, &ix);
                        }
                        4 if !live.is_empty() => {
                            let i = g.usize_in(0..live.len());
                            let r = live.swap_remove(i);
                            remove_queued(&mut q, &mut p, r, &ix);
                        }
                        5 => {
                            // Deregistration drops every (f, e) pair at once;
                            // by_file is untouched by design.
                            let e = ExecutorId(g.u64_in(0..6) as u32);
                            ix.deregister_executor(e);
                            p.on_deregister(e);
                        }
                        6 => {
                            // Mid-stream consult: settle one executor's debt.
                            let e = ExecutorId(g.u64_in(0..6) as u32);
                            p.refresh(e, &q, &ix);
                        }
                        _ => {}
                    }
                    p.check_consistent(&q, &ix)?;
                }
                Ok(())
            });
        }
    }
}
