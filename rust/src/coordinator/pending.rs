//! Inverted pending-task index — the sub-linear pickup structure
//! (§Perf iteration 3).
//!
//! The O(min(|Q|, W)) window scan of §3.2 is the paper's *upper bound*
//! per scheduling decision, and at W = 100×nodes (3200–6400 entries) it
//! is exactly the hot path DIANA-style bulk schedulers identify as the
//! throughput ceiling. This module replaces the scan with two inverted
//! maps, maintained incrementally as the queue and the location index
//! change:
//!
//! * **by_file** — `FileId → {seq → QueueRef}`: every queued task,
//!   keyed by each file it reads. This is the paper's wait queue viewed
//!   through θ(κ) instead of arrival order.
//! * **by_exec** — `ExecutorId → {seq → QueueRef}`: the *materialized
//!   intersection* of `E_map(executor)` with the pending set — exactly
//!   the tasks with ≥ 1 cached file at that executor, ordered by queue
//!   sequence number. A pickup enumerates this set in queue order and
//!   stops at the first 100 %-hit task, so its cost is proportional to
//!   the executor's **actual cache overlap with the window**, not the
//!   window size. Zero-hit eligibility classes (2/3/4 in
//!   `zero_hit_class`) are, by construction, precisely the queued tasks
//!   *absent* from `by_exec[executor]`, so the scheduler's bounded
//!   head-scan fallback never needs a cache probe.
//!
//! Maintenance costs, all amortized over the structures the coordinator
//! already touches:
//!
//! * task queued — O(|θ(κ)| × replication) bitset-iterated inserts;
//! * task dispatched — the mirror removals;
//! * index add/remove (a cache insert or eviction at executor `e`) —
//!   O(pending tasks referencing that file) set updates;
//! * executor deregistered — one map removal.
//!
//! The index is **only maintained for data-aware policies**
//! (`uses_caching()`); first-available pops the queue head and never
//! consults it. All removal paths are safe no-ops on an unmaintained
//! (empty) index, so the scheduler can call them unconditionally.

use crate::coordinator::queue::{QueueRef, WaitQueue};
use crate::ids::{ExecutorId, FileId};
use crate::index::LocationIndex;
use std::collections::{BTreeMap, HashMap};

/// Per-key pending sets, ordered by queue sequence number so iteration
/// yields tasks in queue order (seq order == queue order).
pub type SeqSet = BTreeMap<u64, QueueRef>;

/// The inverted pending index. See the module docs for the invariants.
#[derive(Debug, Default)]
pub struct PendingIndex {
    /// Pending tasks by file read.
    by_file: HashMap<FileId, SeqSet>,
    /// Pending tasks by executor caching ≥1 of their files (candidates).
    by_exec: HashMap<ExecutorId, SeqSet>,
}

impl PendingIndex {
    /// Empty index.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record a task just pushed onto the wait queue. Must be called
    /// after `queue.push_back` (it reads the task back through `qref`),
    /// and only for caching policies.
    pub fn on_push(&mut self, queue: &WaitQueue, qref: QueueRef, index: &LocationIndex) {
        let seq = queue.seq_of(qref);
        let task = queue.get(qref);
        for &f in &task.files {
            self.by_file.entry(f).or_default().insert(seq, qref);
            if let Some(holders) = index.holders(f) {
                for e in holders {
                    self.by_exec.entry(e).or_default().insert(seq, qref);
                }
            }
        }
    }

    /// Record a task leaving the wait queue. `files`/`seq` are the
    /// removed task's (capture `seq` via [`WaitQueue::seq_of`] *before*
    /// the `queue.remove`). Safe no-op when the index is unmaintained.
    pub fn on_remove(&mut self, files: &[FileId], seq: u64, index: &LocationIndex) {
        for &f in files {
            if let Some(set) = self.by_file.get_mut(&f) {
                set.remove(&seq);
                if set.is_empty() {
                    self.by_file.remove(&f);
                }
            }
            // Invariant: by_exec[e] ∋ seq ⟹ e holds ≥1 of the task's
            // files, so sweeping the holders of every file covers all
            // candidate entries (double-removals are no-ops).
            if let Some(holders) = index.holders(f) {
                for e in holders {
                    if let Some(set) = self.by_exec.get_mut(&e) {
                        set.remove(&seq);
                    }
                }
            }
        }
    }

    /// Record that the location index just **added** (file, executor):
    /// every pending task reading `file` becomes a candidate at
    /// `executor`. Call after `LocationIndex::add`.
    ///
    /// Cost is O(pending readers of `file`) — fine for the paper's
    /// workloads (reads spread over 10K+ files), but a single ultra-hot
    /// file with thousands of queued readers under eviction churn makes
    /// this the dominant term; see ROADMAP "Bound hot-file pending
    /// maintenance" before pointing such a workload at this index.
    pub fn on_index_add(&mut self, file: FileId, executor: ExecutorId) {
        if let Some(pending) = self.by_file.get(&file) {
            if !pending.is_empty() {
                let set = self.by_exec.entry(executor).or_default();
                for (&seq, &qref) in pending {
                    set.insert(seq, qref);
                }
            }
        }
    }

    /// Record that the location index just **removed** (file, executor)
    /// — an eviction. A pending task reading `file` stays a candidate
    /// only if another of its files is still cached there. Call after
    /// `LocationIndex::remove`.
    pub fn on_index_remove(
        &mut self,
        file: FileId,
        executor: ExecutorId,
        queue: &WaitQueue,
        index: &LocationIndex,
    ) {
        let Some(pending) = self.by_file.get(&file) else {
            return;
        };
        let Some(set) = self.by_exec.get_mut(&executor) else {
            return;
        };
        for (&seq, &qref) in pending {
            let task = queue.get(qref);
            if !task.files.iter().any(|&f2| index.holds(f2, executor)) {
                set.remove(&seq);
            }
        }
    }

    /// Drop an executor's candidate set (provisioner release).
    pub fn on_deregister(&mut self, executor: ExecutorId) {
        self.by_exec.remove(&executor);
    }

    /// The executor's candidate tasks (≥1 cached file), in queue order.
    pub fn candidates(&self, executor: ExecutorId) -> Option<&SeqSet> {
        self.by_exec.get(&executor)
    }

    /// Pending tasks referencing `file`, in queue order.
    pub fn pending_for_file(&self, file: FileId) -> Option<&SeqSet> {
        self.by_file.get(&file)
    }

    /// Distinct files with ≥1 pending reader.
    pub fn distinct_pending_files(&self) -> usize {
        self.by_file.len()
    }

    /// Rebuild from scratch — the executable spec of the incremental
    /// maintenance, used by the consistency check and tests.
    #[doc(hidden)]
    pub fn rebuild(queue: &WaitQueue, index: &LocationIndex) -> PendingIndex {
        let mut fresh = PendingIndex::new();
        let refs: Vec<QueueRef> = queue.window(usize::MAX).map(|(r, _)| r).collect();
        for r in refs {
            fresh.on_push(queue, r, index);
        }
        fresh
    }

    /// Check the incremental state equals a from-scratch rebuild.
    #[doc(hidden)]
    pub fn check_consistent(
        &self,
        queue: &WaitQueue,
        index: &LocationIndex,
    ) -> Result<(), String> {
        let fresh = PendingIndex::rebuild(queue, index);
        if self.by_file != fresh.by_file {
            return Err("by_file drifted from rebuild".into());
        }
        // Empty candidate sets may linger (executors whose last candidate
        // left); compare only non-empty sets.
        let non_empty =
            |m: &HashMap<ExecutorId, SeqSet>| -> HashMap<ExecutorId, SeqSet> {
                m.iter()
                    .filter(|(_, s)| !s.is_empty())
                    .map(|(&e, s)| (e, s.clone()))
                    .collect()
            };
        if non_empty(&self.by_exec) != non_empty(&fresh.by_exec) {
            return Err("by_exec drifted from rebuild".into());
        }
        Ok(())
    }
}

/// Remove a queued task and keep the pending index coherent — the single
/// removal path shared by the scheduler and the experiment drivers.
pub fn remove_queued(
    queue: &mut WaitQueue,
    pending: &mut PendingIndex,
    qref: QueueRef,
    index: &LocationIndex,
) -> crate::coordinator::queue::Task {
    let seq = queue.seq_of(qref);
    let task = queue.remove(qref);
    pending.on_remove(&task.files, seq, index);
    task
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::queue::Task;
    use crate::ids::TaskId;
    use crate::util::time::Micros;

    fn task(i: u64, files: &[u32]) -> Task {
        Task {
            id: TaskId(i),
            files: files.iter().map(|&f| FileId(f)).collect(),
            compute: Micros::ZERO,
            arrival: Micros::ZERO,
        }
    }

    fn push(
        q: &mut WaitQueue,
        p: &mut PendingIndex,
        ix: &LocationIndex,
        t: Task,
    ) -> QueueRef {
        let r = q.push_back(t);
        p.on_push(q, r, ix);
        r
    }

    #[test]
    fn candidates_follow_index_adds_and_evictions() {
        let mut q = WaitQueue::new();
        let mut p = PendingIndex::new();
        let mut ix = LocationIndex::new();
        let e = ExecutorId(3);

        let r = push(&mut q, &mut p, &ix, task(0, &[7]));
        assert!(p.candidates(e).is_none_or(|s| s.is_empty()));

        ix.add(FileId(7), e);
        p.on_index_add(FileId(7), e);
        assert_eq!(p.candidates(e).unwrap().len(), 1);

        ix.remove(FileId(7), e);
        p.on_index_remove(FileId(7), e, &q, &ix);
        assert!(p.candidates(e).unwrap().is_empty());
        p.check_consistent(&q, &ix).unwrap();

        // Removal cleans by_file.
        let seq = q.seq_of(r);
        let t = q.remove(r);
        p.on_remove(&t.files, seq, &ix);
        assert_eq!(p.distinct_pending_files(), 0);
    }

    #[test]
    fn multi_file_task_stays_candidate_after_partial_eviction() {
        let mut q = WaitQueue::new();
        let mut p = PendingIndex::new();
        let mut ix = LocationIndex::new();
        let e = ExecutorId(0);
        ix.add(FileId(1), e);
        ix.add(FileId(2), e);
        push(&mut q, &mut p, &ix, task(0, &[1, 2]));
        assert_eq!(p.candidates(e).unwrap().len(), 1);

        // Evict file 1: the task still reads file 2, cached at e.
        ix.remove(FileId(1), e);
        p.on_index_remove(FileId(1), e, &q, &ix);
        assert_eq!(p.candidates(e).unwrap().len(), 1);

        // Evict file 2 too: no longer a candidate.
        ix.remove(FileId(2), e);
        p.on_index_remove(FileId(2), e, &q, &ix);
        assert!(p.candidates(e).unwrap().is_empty());
        p.check_consistent(&q, &ix).unwrap();
    }

    #[test]
    fn remove_queued_keeps_everything_coherent() {
        let mut q = WaitQueue::new();
        let mut p = PendingIndex::new();
        let mut ix = LocationIndex::new();
        ix.add(FileId(5), ExecutorId(1));
        let a = push(&mut q, &mut p, &ix, task(0, &[5]));
        let _b = push(&mut q, &mut p, &ix, task(1, &[5]));
        let t = remove_queued(&mut q, &mut p, a, &ix);
        assert_eq!(t.id, TaskId(0));
        assert_eq!(p.candidates(ExecutorId(1)).unwrap().len(), 1);
        p.check_consistent(&q, &ix).unwrap();
    }

    #[test]
    fn incremental_matches_rebuild_under_random_ops() {
        use crate::util::proptest::{property, Gen};
        property("pending index vs rebuild", 60, |g: &mut Gen| {
            let mut q = WaitQueue::new();
            let mut p = PendingIndex::new();
            let mut ix = LocationIndex::new();
            let mut live: Vec<QueueRef> = Vec::new();
            let mut next_id = 0u64;
            for _ in 0..g.usize_in(1..120) {
                match g.usize_in(0..6) {
                    0 | 1 => {
                        let nfiles = g.usize_in(1..4);
                        let files: Vec<u32> =
                            (0..nfiles).map(|_| g.u64_in(0..12) as u32).collect();
                        let r = push(&mut q, &mut p, &ix, task(next_id, &files));
                        live.push(r);
                        next_id += 1;
                    }
                    2 => {
                        let f = FileId(g.u64_in(0..12) as u32);
                        let e = ExecutorId(g.u64_in(0..6) as u32);
                        ix.add(f, e);
                        p.on_index_add(f, e);
                    }
                    3 => {
                        let f = FileId(g.u64_in(0..12) as u32);
                        let e = ExecutorId(g.u64_in(0..6) as u32);
                        ix.remove(f, e);
                        p.on_index_remove(f, e, &q, &ix);
                    }
                    4 if !live.is_empty() => {
                        let i = g.usize_in(0..live.len());
                        let r = live.swap_remove(i);
                        remove_queued(&mut q, &mut p, r, &ix);
                    }
                    5 => {
                        // Deregistration drops every (f, e) pair at once;
                        // by_file is untouched by design.
                        let e = ExecutorId(g.u64_in(0..6) as u32);
                        ix.deregister_executor(e);
                        p.on_deregister(e);
                    }
                    _ => {}
                }
                p.check_consistent(&q, &ix)?;
            }
            Ok(())
        });
    }
}
