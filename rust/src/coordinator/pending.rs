//! Inverted pending-task index with **epoch-lazy candidate maintenance**
//! (§Perf iterations 3–4) over **arena-indexed, struct-of-arrays storage**
//! (§Perf iteration 5).
//!
//! The O(min(|Q|, W)) window scan of §3.2 is the paper's *upper bound*
//! per scheduling decision, and at W = 100×nodes (3200–6400 entries) it
//! is exactly the hot path DIANA-style bulk schedulers identify as the
//! throughput ceiling. This module replaces the scan with two inverted
//! maps:
//!
//! * **by_file** — `FileId → {seq → QueueRef}`: every queued task,
//!   keyed by each file it reads. This is the paper's wait queue viewed
//!   through θ(κ) instead of arrival order. It is maintained **eagerly**
//!   and is always exact: a task enters on push and leaves on dispatch,
//!   both O(|θ(κ)|).
//! * **per-executor candidate sets** — `ExecutorId → {seq → QueueRef}`:
//!   the materialized intersection of `E_map(executor)` with the pending
//!   set — the queued tasks with ≥ 1 cached file at that executor, in
//!   queue order. A pickup enumerates this set and stops at the first
//!   100 %-hit task, so its cost tracks the executor's **actual cache
//!   overlap with the window**, not the window size.
//!
//! ## Arena + SoA layout (§Perf iteration 5)
//!
//! Both sides of the index are dense arenas, not hash maps:
//!
//! * `by_file` is a `Vec<SeqSet>` indexed by `FileId.0` — file ids are
//!   handed out densely by the workloads, so the slot for a file is a
//!   direct offset, no hashing on the push/remove path.
//! * `execs` is a `Vec<Option<ExecState>>` indexed by `ExecutorId.0`.
//! * [`SeqSet`] itself is struct-of-arrays: parallel sorted `Vec<u64>` /
//!   `Vec<QueueRef>` columns. Candidate iteration — the hottest loop in
//!   dispatch — is a linear scan over a dense `u64` column instead of a
//!   B-tree walk, and the dominant insert (queue seqs are monotone) is
//!   an append. Iteration order (ascending seq) is identical to the
//!   `BTreeMap` it replaced, so dispatch is bit-for-bit unchanged.
//!
//! Candidate sets freed by executor deregistration park in a small pool
//! and are handed back — cleared, capacity intact — to the next
//! executor that registers; `PendingStats::slab_reuse` counts the
//! recycles so churn tests can assert the arena does not grow without
//! bound ([`PendingIndex::table_bytes`] is the capacity-based footprint
//! the `perf_hotpath` scale group snapshots).
//!
//! ## Epoch-lazy maintenance (§Perf iteration 4)
//!
//! Keeping the candidate sets exact at every cache event is where the
//! original design could lose its win: a cache insert or evict of file
//! `f` at executor `e` touches every pending reader of `f`, and a single
//! popular file with thousands of queued readers under eviction churn
//! (the Fig 11 regime) pays O(pending readers) **per event** — per-event
//! scheduler overhead is exactly what bounds achievable throughput in
//! bulk schedulers (DIANA; the data-diffusion follow-up, arXiv:0808.3546).
//! The candidate sets are therefore maintained *lazily*:
//!
//! * The index keeps a global **epoch** — a counter bumped by every
//!   location-index mutation ([`PendingIndex::on_index_add`] /
//!   [`PendingIndex::on_index_remove`] / [`PendingIndex::on_deregister`]).
//!   Each executor's candidate set records the epoch it was last
//!   reconciled at ([`PendingIndex::epoch_of`]); a set whose epoch lags
//!   the global epoch **may be stale** and must not be consulted without
//!   a [`PendingIndex::refresh`].
//! * A cache event touching a file with at most the **fan-out cap**
//!   pending readers is applied immediately (bounded work — the *capped
//!   per-file fan-out*). A hotter file is recorded as an O(1) **dirty
//!   record** on the executor instead; at most the **dirty budget** of
//!   distinct dirty files are kept, beyond which the patch log is
//!   abandoned and the set marked for a full **overflow rebuild**.
//! * [`PendingIndex::refresh`] — called once per consult (the scheduler's
//!   pickup, [`crate::coordinator::scheduler::Scheduler::pick_tasks`]) —
//!   settles the debt: dirty files are patched against the *current*
//!   location index (so an evict+re-add cycle between consults coalesces
//!   to a no-op membership check), and an overflowed set is rebuilt from
//!   `E_map(executor) × by_file` — the *lazy overflow scan*, proportional
//!   to the executor's overlap, not the queue.
//!
//! ## Adaptive caps (§Perf iteration 5)
//!
//! The fan-out cap and dirty budget start at [`FANOUT_CAP`] /
//! [`DIRTY_CAP`] but adapt to the observed **consult rate**: every
//! adaptation window of consults, the index-event count over the same
//! span is compared against it. Event-heavy regimes (caches churning far
//! faster than the scheduler consults — the Fig 11 shape) shift toward
//! deferral: the fan-out cap halves, the dirty budget doubles, so more
//! work coalesces before a consult pays it. Consult-heavy regimes shift
//! the other way. Caps move by powers of two inside
//! [`FANOUT_CAP_MIN`]..=[`FANOUT_CAP_MAX`] and
//! [`DIRTY_CAP_MIN`]..=[`DIRTY_CAP_MAX`]. Because `refresh()` always
//! reconciles to the exact live set before a consult, cap choice affects
//! *when* maintenance happens, never *what* the candidate set contains —
//! dispatch stays bit-identical under any cap schedule (pinned by the
//! `adapted_caps_keep_dispatch_bit_identical` property below).
//!
//! ### Invariants (what the parity suite pins down)
//!
//! 1. After `refresh(e)`, the **live** entries of `e`'s candidate set are
//!    exactly the eager set: `{(seq, qref) : ∃ f ∈ θ(task), holds(f, e)}`
//!    over queued tasks.
//! 2. A refreshed set may additionally contain **dead hints**: a task
//!    whose every `e`-cached file was evicted *while its fan-out was
//!    deferred*, and which then left the queue, cannot be found by any
//!    later patch (it is gone from `by_file`). Dead hints are harmless:
//!    consumers validate each entry in O(1) via
//!    [`crate::coordinator::queue::WaitQueue::live_seq`] (sequence
//!    numbers are never reused) and purge them on encounter
//!    ([`PendingIndex::purge_dead`]); an overflow rebuild discards them
//!    wholesale.
//! 3. `by_file` is always exact; only candidate sets are lazy.
//!
//! This is why eviction is O(1) on the hot path: the event does a length
//! probe, bumps the epoch, and either applies a ≤ fan-out-cap fan-out
//! or pushes one dirty record. The deferred work is paid once per
//! consult, after coalescing — [`PendingStats`] counts it so the
//! `perf_hotpath` bench and the CI gate can assert lazy ≤ eager.
//!
//! ## Notify-side reuse
//!
//! Phase 1 of the scheduler ([`crate::coordinator::scheduler::Scheduler::select_notify`])
//! repeatedly asks "which executors hold any of the head task's files,
//! and which free one overlaps most?" — historically recounted from the
//! holder sets on every call. [`PendingIndex::head_ranked`] memoizes the
//! answer: the candidate executors are the word-wise **union** of the
//! files' holder bitsets ([`crate::index::ExecSet::union_with`]), ranked
//! once by overlap (descending, ids ascending), and the memo is valid
//! until the epoch moves or the head's file set changes. Repeat notifies
//! for the same head — the common pattern while the cluster is saturated
//! — reuse the ranking and only probe free-ness.
//!
//! ## Modes
//!
//! [`PendingIndex::new`] is **lazy** (the engine default);
//! [`PendingIndex::eager`] retains the always-exact maintenance as the
//! executable reference. `rust/tests/sched_parity.rs` drives both (all
//! five policies, eviction churn over a popular file with thousands of
//! queued readers) and asserts identical dispatch plus lazy maintenance
//! strictly below eager. The index is **only maintained for data-aware
//! policies** (`uses_caching()`); first-available pops the queue head
//! and never consults it. All removal paths are safe no-ops on an
//! unmaintained (empty) index.

use crate::coordinator::queue::{QueueRef, WaitQueue};
use crate::ids::{ExecutorId, FileId};
use crate::index::{ExecSet, LocationIndex};

/// Cache events touching a file with at most this many pending readers
/// are applied to the executor's candidate set immediately (the capped
/// per-file fan-out); hotter files defer to a dirty record instead.
/// This is the *initial* value — the cap adapts within
/// [`FANOUT_CAP_MIN`]..=[`FANOUT_CAP_MAX`] (see the module docs).
pub const FANOUT_CAP: usize = 16;

/// Distinct deferred files per executor before the incremental patch log
/// is abandoned for a full overflow rebuild at the next consult. Initial
/// value; adapts within [`DIRTY_CAP_MIN`]..=[`DIRTY_CAP_MAX`].
pub const DIRTY_CAP: usize = 32;

/// Adaptive floor for the fan-out cap.
pub const FANOUT_CAP_MIN: usize = 8;
/// Adaptive ceiling for the fan-out cap.
pub const FANOUT_CAP_MAX: usize = 64;
/// Adaptive floor for the dirty budget.
pub const DIRTY_CAP_MIN: usize = 16;
/// Adaptive ceiling for the dirty budget.
pub const DIRTY_CAP_MAX: usize = 128;

/// Consults per adaptation decision. Long enough that unit tests pinning
/// exact maintenance counters never see an adaptation; tests exercising
/// the adaptive path shrink it via [`PendingIndex::set_adapt_window`].
const ADAPT_WINDOW: u64 = 1024;

/// Candidate sets parked by deregistration, kept for reuse.
const SET_POOL_CAP: usize = 64;

/// Sorted struct-of-arrays set of `(seq, QueueRef)` pairs — the storage
/// behind both `by_file` slots and per-executor candidate sets.
///
/// Two parallel columns sorted by seq. The dominant insert (queue seqs
/// are handed out monotonically) is an O(1) append; out-of-order inserts
/// are an O(n) memmove, removals a binary search plus memmove. Iteration
/// is a pair of linear column scans in ascending-seq order — identical
/// to the `BTreeMap<u64, QueueRef>` this replaced, so every downstream
/// tie-break is unchanged.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct SeqSet {
    seqs: Vec<u64>,
    refs: Vec<QueueRef>,
}

impl SeqSet {
    /// Number of entries.
    #[inline]
    pub fn len(&self) -> usize {
        self.seqs.len()
    }

    /// True when there are no entries.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.seqs.is_empty()
    }

    /// Insert (or overwrite) an entry; returns true if `seq` was new.
    pub fn insert(&mut self, seq: u64, qref: QueueRef) -> bool {
        match self.seqs.last() {
            Some(&last) if last < seq => {
                self.seqs.push(seq);
                self.refs.push(qref);
                true
            }
            Some(&last) if last == seq => {
                *self.refs.last_mut().expect("columns in sync") = qref;
                false
            }
            _ => match self.seqs.binary_search(&seq) {
                Ok(i) => {
                    self.refs[i] = qref;
                    false
                }
                Err(i) => {
                    self.seqs.insert(i, seq);
                    self.refs.insert(i, qref);
                    true
                }
            },
        }
    }

    /// Remove an entry; returns true if it was present.
    pub fn remove(&mut self, seq: u64) -> bool {
        match self.seqs.binary_search(&seq) {
            Ok(i) => {
                self.seqs.remove(i);
                self.refs.remove(i);
                true
            }
            Err(_) => false,
        }
    }

    /// Membership test — O(log n).
    #[inline]
    pub fn contains(&self, seq: u64) -> bool {
        self.seqs.binary_search(&seq).is_ok()
    }

    /// Entries in ascending seq (= queue) order.
    #[inline]
    pub fn iter(&self) -> impl Iterator<Item = (u64, QueueRef)> + '_ {
        self.seqs.iter().copied().zip(self.refs.iter().copied())
    }

    /// Smallest entry, if any.
    pub fn first(&self) -> Option<(u64, QueueRef)> {
        Some((*self.seqs.first()?, *self.refs.first()?))
    }

    /// Drop every entry, keeping both columns' capacity (slab reuse).
    pub fn clear(&mut self) {
        self.seqs.clear();
        self.refs.clear();
    }

    /// Heap bytes behind both columns (capacity-based; feeds
    /// `scale/peak_table_bytes`).
    pub fn heap_bytes(&self) -> usize {
        self.seqs.capacity() * std::mem::size_of::<u64>()
            + self.refs.capacity() * std::mem::size_of::<QueueRef>()
    }
}

impl FromIterator<(u64, QueueRef)> for SeqSet {
    fn from_iter<T: IntoIterator<Item = (u64, QueueRef)>>(iter: T) -> Self {
        let mut s = SeqSet::default();
        for (seq, qref) in iter {
            s.insert(seq, qref);
        }
        s
    }
}

/// How the per-executor candidate sets are maintained.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Maintenance {
    /// Epoch-lazy (engine default): O(1)-bounded work per cache event,
    /// debt settled at consult. See the module docs.
    Lazy,
    /// Always-exact maintenance — the executable reference the parity
    /// suite compares against (the pre-iteration-4 behavior).
    Eager,
}

/// Deterministic work counters for the maintenance machinery. These are
/// machine-independent, so `perf_hotpath` snapshots them and
/// `tools/bench_gate.py` gates lazy ≤ eager on the hot-file workload.
#[derive(Debug, Default, Clone)]
pub struct PendingStats {
    /// `on_index_add`/`on_index_remove` calls (cache events seen).
    pub index_events: u64,
    /// Per-entry candidate-set mutations/examinations — the cost being
    /// bounded. Eager mode pays these at event time; lazy mode at
    /// consult time, after coalescing.
    pub maintenance_ops: u64,
    /// O(1) deferrals recorded instead of an immediate fan-out.
    pub dirty_records: u64,
    /// Full per-executor rebuilds (overflowed patch logs).
    pub epoch_rebuilds: u64,
    /// Distinct dirty files patched incrementally at refresh.
    pub patched_files: u64,
    /// Notify rankings rebuilt ([`PendingIndex::head_ranked`] misses).
    pub notify_memo_builds: u64,
    /// Notify decisions answered from the memoized ranking.
    pub notify_memo_hits: u64,
    /// Dead hints dropped by [`PendingIndex::purge_dead`] — lazily
    /// maintained candidate entries whose task left the queue while its
    /// eviction was deferred (module-docs invariant 2), purged on
    /// encounter by the scheduler's phase-A walk. This makes the memory
    /// argument explicit: dead hints never accumulate past their first
    /// encounter, and the `sched_parity` leave-queue-churn regression
    /// bounds the count.
    pub dead_hints_purged: u64,
    /// Candidate sets recycled from the deregistration pool instead of
    /// freshly allocated (the `pending/slab_reuse` gate counter).
    pub slab_reuse: u64,
    /// Times the adaptive caps actually changed value.
    pub cap_adaptations: u64,
    /// Candidate-set consults ([`PendingIndex::refresh`] calls) — the
    /// denominator of the adaptation ratio.
    pub consults: u64,
}

/// One executor's lazily maintained candidate set.
#[derive(Debug, Default)]
struct ExecState {
    /// Materialized candidates (live entries exact after a refresh; may
    /// carry dead hints — see the module docs).
    set: SeqSet,
    /// Global epoch this set was last reconciled at (diagnostic: a set
    /// is *possibly stale* while this lags [`PendingIndex::epoch`]).
    epoch: u64,
    /// Distinct files with a deferred membership change (≤ dirty budget).
    dirty: Vec<FileId>,
    /// Patch log abandoned; rebuild from scratch at the next refresh.
    overflow: bool,
}

/// Memoized phase-1 ranking for the current head task (see module docs).
#[derive(Debug, Default)]
struct NotifyMemo {
    valid: bool,
    epoch: u64,
    files: Vec<FileId>,
    /// Scratch union of the files' holder bitsets.
    union: ExecSet,
    /// Candidates ranked by (overlap desc, id asc) — the reference
    /// notify tie-break, precomputed.
    ranked: Vec<(ExecutorId, u32)>,
}

/// The inverted pending index. See the module docs for the invariants.
#[derive(Debug)]
pub struct PendingIndex {
    /// Pending tasks by file read, indexed by `FileId.0` (always exact).
    by_file: Vec<SeqSet>,
    /// Files with a non-empty `by_file` slot (O(1) distinct-count).
    nonempty_by_file: usize,
    /// Per-executor candidate state, indexed by `ExecutorId.0`.
    execs: Vec<Option<ExecState>>,
    /// Cleared candidate sets parked by deregistration, ready for reuse.
    set_pool: Vec<SeqSet>,
    /// Maintenance mode (lazy = engine default).
    mode: Maintenance,
    /// Global location-index mutation counter — the validity epoch for
    /// candidate sets and the notify memo.
    epoch: u64,
    memo: NotifyMemo,
    /// Current adaptive fan-out cap (starts at [`FANOUT_CAP`]).
    fanout_cap: usize,
    /// Current adaptive dirty budget (starts at [`DIRTY_CAP`]).
    dirty_cap: usize,
    /// Consults per adaptation decision.
    adapt_window: u64,
    /// Consults accumulated in the current window.
    window_consults: u64,
    /// `stats.index_events` at the start of the current window.
    window_events_mark: u64,
    /// Deterministic work counters (see [`PendingStats`]).
    pub stats: PendingStats,
}

impl Default for PendingIndex {
    fn default() -> Self {
        PendingIndex {
            by_file: Vec::new(),
            nonempty_by_file: 0,
            execs: Vec::new(),
            set_pool: Vec::new(),
            mode: Maintenance::Lazy,
            epoch: 0,
            memo: NotifyMemo::default(),
            fanout_cap: FANOUT_CAP,
            dirty_cap: DIRTY_CAP,
            adapt_window: ADAPT_WINDOW,
            window_consults: 0,
            window_events_mark: 0,
            stats: PendingStats::default(),
        }
    }
}

impl PendingIndex {
    /// Empty index in [`Maintenance::Lazy`] mode (the engine default).
    pub fn new() -> Self {
        Self::default()
    }

    /// Empty index in [`Maintenance::Eager`] mode — the always-exact
    /// reference the parity suite compares against.
    pub fn eager() -> Self {
        PendingIndex {
            mode: Maintenance::Eager,
            ..Self::default()
        }
    }

    /// The maintenance mode this index runs in.
    pub fn mode(&self) -> Maintenance {
        self.mode
    }

    /// Current global epoch (bumped by every location-index mutation).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Epoch `executor`'s candidate set was last reconciled at, if it has
    /// one. Lagging [`PendingIndex::epoch`] means *possibly stale*.
    pub fn epoch_of(&self, executor: ExecutorId) -> Option<u64> {
        self.execs
            .get(executor.0 as usize)?
            .as_ref()
            .map(|st| st.epoch)
    }

    /// Current fan-out cap (adaptive; see the module docs).
    pub fn fanout_cap(&self) -> usize {
        self.fanout_cap
    }

    /// Current dirty budget (adaptive; see the module docs).
    pub fn dirty_cap(&self) -> usize {
        self.dirty_cap
    }

    /// Shrink the adaptation window so tests can drive the adaptive path
    /// without thousands of consults.
    #[doc(hidden)]
    pub fn set_adapt_window(&mut self, consults: u64) {
        self.adapt_window = consults.max(1);
    }

    /// Heap bytes behind the index's tables — arena capacity, per-set
    /// columns, dirty logs, and the parked pool (capacity-based
    /// estimate; feeds `scale/peak_table_bytes`).
    pub fn table_bytes(&self) -> u64 {
        let mut total = self.by_file.capacity() * std::mem::size_of::<SeqSet>()
            + self.execs.capacity() * std::mem::size_of::<Option<ExecState>>()
            + self.set_pool.capacity() * std::mem::size_of::<SeqSet>();
        for set in &self.by_file {
            total += set.heap_bytes();
        }
        for st in self.execs.iter().flatten() {
            total += st.set.heap_bytes() + st.dirty.capacity() * std::mem::size_of::<FileId>();
        }
        for set in &self.set_pool {
            total += set.heap_bytes();
        }
        total as u64
    }

    /// Grow-on-demand slot accessor for `by_file`.
    fn by_file_slot(&mut self, file: FileId) -> &mut SeqSet {
        let i = file.0 as usize;
        if self.by_file.len() <= i {
            self.by_file.resize_with(i + 1, SeqSet::default);
        }
        &mut self.by_file[i]
    }

    /// Dense-slot accessor for an executor's candidate state,
    /// registering it (with a pooled or fresh set) on first touch.
    ///
    /// Associated fn — not `&mut self` — so callers can hold a disjoint
    /// borrow of `by_file` alongside the returned state.
    fn exec_slot<'a>(
        execs: &'a mut Vec<Option<ExecState>>,
        pool: &mut Vec<SeqSet>,
        stats: &mut PendingStats,
        executor: ExecutorId,
    ) -> &'a mut ExecState {
        let i = executor.0 as usize;
        if execs.len() <= i {
            execs.resize_with(i + 1, || None);
        }
        execs[i].get_or_insert_with(|| {
            let set = match pool.pop() {
                Some(s) => {
                    stats.slab_reuse += 1;
                    s
                }
                None => SeqSet::default(),
            };
            ExecState {
                set,
                ..ExecState::default()
            }
        })
    }

    /// Record a task just pushed onto the wait queue. Must be called
    /// after `queue.push_back` (it reads the task back through `qref`),
    /// and only for caching policies. O(|θ(κ)| × replication): pushes are
    /// applied eagerly in both modes — the fan-out is bounded by the
    /// replication cap, not by queue depth, so there is nothing to defer.
    pub fn on_push(&mut self, queue: &WaitQueue, qref: QueueRef, index: &LocationIndex) {
        let seq = queue.seq_of(qref);
        let task = queue.get(qref);
        for &f in &task.files {
            let slot = self.by_file_slot(f);
            let was_empty = slot.is_empty();
            if slot.insert(seq, qref) && was_empty {
                self.nonempty_by_file += 1;
            }
            if let Some(holders) = index.holders(f) {
                for e in holders {
                    let st = Self::exec_slot(
                        &mut self.execs,
                        &mut self.set_pool,
                        &mut self.stats,
                        e,
                    );
                    st.set.insert(seq, qref);
                }
            }
        }
    }

    /// Record a task leaving the wait queue. `files`/`seq` are the
    /// removed task's (capture `seq` via [`WaitQueue::seq_of`] *before*
    /// the `queue.remove`). Safe no-op when the index is unmaintained.
    ///
    /// Sweeping the *current* holders of every file covers all candidate
    /// entries the eager semantics would hold; an entry kept alive only
    /// by a deferred (not-yet-patched) eviction becomes a dead hint and
    /// is caught by read-time validation (module docs, invariant 2).
    pub fn on_remove(&mut self, files: &[FileId], seq: u64, index: &LocationIndex) {
        for &f in files {
            if let Some(set) = self.by_file.get_mut(f.0 as usize) {
                if set.remove(seq) && set.is_empty() {
                    self.nonempty_by_file -= 1;
                }
            }
            if let Some(holders) = index.holders(f) {
                for e in holders {
                    if let Some(st) = self.execs.get_mut(e.0 as usize).and_then(Option::as_mut) {
                        st.set.remove(seq);
                    }
                }
            }
        }
    }

    /// Record that the location index just **added** (file, executor) —
    /// a cache insert. Call after [`LocationIndex::add`].
    ///
    /// Lazy mode: O(fan-out cap) worst case — a small fan-out applies
    /// immediately, a hot file becomes one dirty record.
    pub fn on_index_add(&mut self, file: FileId, executor: ExecutorId) {
        self.epoch += 1;
        self.stats.index_events += 1;
        let fanout_cap = self.fanout_cap;
        let dirty_cap = self.dirty_cap;
        let pending = match self.by_file.get(file.0 as usize) {
            Some(s) if !s.is_empty() => s,
            _ => return, // no pending readers: nothing can change
        };
        match self.mode {
            Maintenance::Eager => {
                let st =
                    Self::exec_slot(&mut self.execs, &mut self.set_pool, &mut self.stats, executor);
                for (seq, qref) in pending.iter() {
                    st.set.insert(seq, qref);
                    self.stats.maintenance_ops += 1;
                }
            }
            Maintenance::Lazy => {
                let st =
                    Self::exec_slot(&mut self.execs, &mut self.set_pool, &mut self.stats, executor);
                if st.overflow {
                    return; // rebuild at next consult covers this event
                }
                if pending.len() <= fanout_cap {
                    for (seq, qref) in pending.iter() {
                        st.set.insert(seq, qref);
                        self.stats.maintenance_ops += 1;
                    }
                } else {
                    self.stats.dirty_records += 1;
                    Self::defer(st, file, dirty_cap);
                }
            }
        }
    }

    /// Record that the location index just **removed** (file, executor)
    /// — an eviction. Call after [`LocationIndex::remove`]. A pending
    /// task reading `file` stays a candidate only if another of its
    /// files is still cached there.
    ///
    /// Lazy mode: O(fan-out cap) worst case, like
    /// [`PendingIndex::on_index_add`] — this is the call that used to pay
    /// O(pending readers) per eviction of a popular file.
    pub fn on_index_remove(
        &mut self,
        file: FileId,
        executor: ExecutorId,
        queue: &WaitQueue,
        index: &LocationIndex,
    ) {
        self.epoch += 1;
        self.stats.index_events += 1;
        let fanout_cap = self.fanout_cap;
        let dirty_cap = self.dirty_cap;
        let pending = match self.by_file.get(file.0 as usize) {
            Some(s) if !s.is_empty() => s,
            _ => return,
        };
        let Some(st) = self.execs.get_mut(executor.0 as usize).and_then(Option::as_mut) else {
            return; // never had candidates: nothing to retract
        };
        match self.mode {
            Maintenance::Eager => {
                for (seq, qref) in pending.iter() {
                    self.stats.maintenance_ops += 1;
                    let task = queue.get(qref);
                    if !task.files.iter().any(|&f2| index.holds(f2, executor)) {
                        st.set.remove(seq);
                    }
                }
            }
            Maintenance::Lazy => {
                if st.overflow {
                    return;
                }
                if pending.len() <= fanout_cap {
                    for (seq, qref) in pending.iter() {
                        self.stats.maintenance_ops += 1;
                        let task = queue.get(qref);
                        if !task.files.iter().any(|&f2| index.holds(f2, executor)) {
                            st.set.remove(seq);
                        }
                    }
                } else {
                    self.stats.dirty_records += 1;
                    Self::defer(st, file, dirty_cap);
                }
            }
        }
    }

    /// Enqueue a dirty record, overflowing into a rebuild when the patch
    /// log is full. The `contains` probe is O(dirty budget) — repeated
    /// churn on the same hot file coalesces into one record.
    fn defer(st: &mut ExecState, file: FileId, dirty_cap: usize) {
        if st.dirty.contains(&file) {
            return;
        }
        if st.dirty.len() >= dirty_cap {
            st.overflow = true;
            st.dirty.clear();
        } else {
            st.dirty.push(file);
        }
    }

    /// Settle an executor's deferred maintenance so its candidate set is
    /// consultable (module-docs invariant 1). Called once per pickup by
    /// the scheduler; O(1) when nothing changed since the last consult.
    ///
    /// Dirty files are patched against the **current** index state, so
    /// any number of add/evict cycles on one file between consults costs
    /// one walk of its pending readers. An overflowed log rebuilds the
    /// set from `E_map(executor) × by_file` instead — proportional to the
    /// executor's overlap with the pending set, never to |Q|.
    pub fn refresh(&mut self, executor: ExecutorId, queue: &WaitQueue, index: &LocationIndex) {
        self.note_consult();
        let Some(st) = self.execs.get_mut(executor.0 as usize).and_then(Option::as_mut) else {
            return;
        };
        if st.overflow {
            self.stats.epoch_rebuilds += 1;
            st.overflow = false;
            st.dirty.clear();
            st.set.clear();
            if let Some(cached) = index.cached_at(executor) {
                for &f in cached {
                    if let Some(pending) = self.by_file.get(f.0 as usize) {
                        for (seq, qref) in pending.iter() {
                            st.set.insert(seq, qref);
                            self.stats.maintenance_ops += 1;
                        }
                    }
                }
            }
        } else if !st.dirty.is_empty() {
            let mut dirty = std::mem::take(&mut st.dirty);
            for &f in &dirty {
                self.stats.patched_files += 1;
                let Some(pending) = self.by_file.get(f.0 as usize).filter(|s| !s.is_empty())
                else {
                    continue; // last reader dispatched meanwhile
                };
                if index.holds(f, executor) {
                    for (seq, qref) in pending.iter() {
                        st.set.insert(seq, qref);
                        self.stats.maintenance_ops += 1;
                    }
                } else {
                    for (seq, qref) in pending.iter() {
                        self.stats.maintenance_ops += 1;
                        let task = queue.get(qref);
                        if !task.files.iter().any(|&f2| index.holds(f2, executor)) {
                            st.set.remove(seq);
                        }
                    }
                }
            }
            dirty.clear();
            st.dirty = dirty; // hand the allocation back
        }
        st.epoch = self.epoch;
    }

    /// Count a consult and, once per adaptation window, retune the caps
    /// against the observed event/consult ratio (see the module docs).
    fn note_consult(&mut self) {
        self.stats.consults += 1;
        self.window_consults += 1;
        if self.window_consults < self.adapt_window {
            return;
        }
        let consults = self.window_consults;
        let events = self.stats.index_events - self.window_events_mark;
        let old = (self.fanout_cap, self.dirty_cap);
        if events >= consults.saturating_mul(4) {
            // Event-heavy: defer harder so refreshes coalesce more churn.
            self.fanout_cap = (self.fanout_cap / 2).max(FANOUT_CAP_MIN);
            self.dirty_cap = (self.dirty_cap * 2).min(DIRTY_CAP_MAX);
        } else if events * 2 <= consults {
            // Consult-heavy: apply eagerly, keep the patch log short.
            self.fanout_cap = (self.fanout_cap * 2).min(FANOUT_CAP_MAX);
            self.dirty_cap = (self.dirty_cap / 2).max(DIRTY_CAP_MIN);
        }
        if (self.fanout_cap, self.dirty_cap) != old {
            self.stats.cap_adaptations += 1;
        }
        self.window_consults = 0;
        self.window_events_mark = self.stats.index_events;
    }

    /// Drop dead hints the consumer found while iterating `executor`'s
    /// candidate set (entries failing the
    /// [`WaitQueue::live_seq`] validation — module-docs invariant 2).
    pub fn purge_dead(&mut self, executor: ExecutorId, seqs: &[u64]) {
        if let Some(st) = self.execs.get_mut(executor.0 as usize).and_then(Option::as_mut) {
            for &seq in seqs {
                if st.set.remove(seq) {
                    self.stats.dead_hints_purged += 1;
                }
            }
        }
    }

    /// The executor's materialized candidate set (≥1 cached file), in
    /// queue order. **Raw view**: in lazy mode, call
    /// [`PendingIndex::refresh`] first and validate entries with
    /// [`WaitQueue::live_seq`] while iterating — see the module docs.
    pub fn candidates(&self, executor: ExecutorId) -> Option<&SeqSet> {
        self.execs
            .get(executor.0 as usize)?
            .as_ref()
            .map(|st| &st.set)
    }

    /// Memoized phase-1 ranking for a head task reading `files`: every
    /// executor holding ≥1 of the files, ordered by (overlap desc, id
    /// asc) — the reference notify tie-break. Built from a word-wise
    /// union of the holder bitsets, at most once per (file set, epoch);
    /// repeat notifies for the same head reuse it, so `select_notify`
    /// never recounts holder overlap per call.
    pub fn head_ranked(
        &mut self,
        files: &[FileId],
        index: &LocationIndex,
    ) -> &[(ExecutorId, u32)] {
        let memo = &mut self.memo;
        if memo.valid && memo.epoch == self.epoch && memo.files.as_slice() == files {
            self.stats.notify_memo_hits += 1;
            return &memo.ranked;
        }
        self.stats.notify_memo_builds += 1;
        memo.valid = true;
        memo.epoch = self.epoch;
        memo.files.clear();
        memo.files.extend_from_slice(files);
        memo.union.clear();
        for &f in files {
            if let Some(holders) = index.holders(f) {
                memo.union.union_with(holders);
            }
        }
        memo.ranked.clear();
        for e in &memo.union {
            let overlap = index.hit_count(e, files) as u32;
            memo.ranked.push((e, overlap));
        }
        memo.ranked
            .sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        &memo.ranked
    }

    /// Drop an executor's candidate state (provisioner release), parking
    /// its set — cleared, capacity intact — for the next registration.
    pub fn on_deregister(&mut self, executor: ExecutorId) {
        self.epoch += 1; // holder sets changed: invalidate the memo
        if let Some(st) = self.execs.get_mut(executor.0 as usize).and_then(Option::take) {
            if self.set_pool.len() < SET_POOL_CAP {
                let mut set = st.set;
                set.clear();
                self.set_pool.push(set);
            }
        }
    }

    /// Pending tasks referencing `file`, in queue order.
    pub fn pending_for_file(&self, file: FileId) -> Option<&SeqSet> {
        self.by_file.get(file.0 as usize).filter(|s| !s.is_empty())
    }

    /// Distinct files with ≥1 pending reader — O(1) (maintained count).
    pub fn distinct_pending_files(&self) -> usize {
        self.nonempty_by_file
    }

    /// Rebuild from scratch — the executable spec of the incremental
    /// maintenance, used by the consistency check and tests. Built with
    /// pushes only, so the result is exact in either mode.
    #[doc(hidden)]
    pub fn rebuild(queue: &WaitQueue, index: &LocationIndex) -> PendingIndex {
        let mut fresh = PendingIndex::new();
        let refs: Vec<QueueRef> = queue.window(usize::MAX).map(|(r, _)| r).collect();
        for r in refs {
            fresh.on_push(queue, r, index);
        }
        fresh
    }

    /// Check the incremental state equals a from-scratch rebuild: after a
    /// refresh, each executor's **live** candidate entries must match the
    /// rebuild exactly (dead hints are excluded — module-docs invariant
    /// 2; in eager mode there are none, so this is full equality).
    #[doc(hidden)]
    pub fn check_consistent(
        &mut self,
        queue: &WaitQueue,
        index: &LocationIndex,
    ) -> Result<(), String> {
        let fresh = PendingIndex::rebuild(queue, index);
        let empty = SeqSet::default();
        let width = self.by_file.len().max(fresh.by_file.len());
        let mut nonempty = 0usize;
        for i in 0..width {
            let got = self.by_file.get(i).unwrap_or(&empty);
            let want = fresh.by_file.get(i).unwrap_or(&empty);
            if got != want {
                return Err(format!("by_file[{i}] drifted from rebuild"));
            }
            if !got.is_empty() {
                nonempty += 1;
            }
        }
        if nonempty != self.nonempty_by_file {
            return Err(format!(
                "nonempty_by_file {} != recount {nonempty}",
                self.nonempty_by_file
            ));
        }
        let mut keys: Vec<ExecutorId> = self
            .execs
            .iter()
            .enumerate()
            .filter(|(_, s)| s.is_some())
            .map(|(i, _)| ExecutorId(i as u32))
            .collect();
        keys.extend(
            fresh
                .execs
                .iter()
                .enumerate()
                .filter(|(_, s)| s.is_some())
                .map(|(i, _)| ExecutorId(i as u32)),
        );
        keys.sort_unstable();
        keys.dedup();
        for e in keys {
            self.refresh(e, queue, index);
            let live: SeqSet = self
                .candidates(e)
                .map(|set| {
                    set.iter()
                        .filter(|&(s, q)| queue.live_seq(q) == Some(s))
                        .collect()
                })
                .unwrap_or_default();
            let expect = fresh
                .candidates(e)
                .cloned()
                .unwrap_or_default();
            if live != expect {
                return Err(format!(
                    "candidates for {e} drifted from rebuild: {} live vs {} expected",
                    live.len(),
                    expect.len()
                ));
            }
        }
        Ok(())
    }
}

/// Remove a queued task and keep the pending index coherent — the single
/// removal path shared by the scheduler and the experiment drivers.
pub fn remove_queued(
    queue: &mut WaitQueue,
    pending: &mut PendingIndex,
    qref: QueueRef,
    index: &LocationIndex,
) -> crate::coordinator::queue::Task {
    let seq = queue.seq_of(qref);
    let task = queue.remove(qref);
    pending.on_remove(&task.files, seq, index);
    task
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::queue::Task;
    use crate::ids::TaskId;
    use crate::util::time::Micros;

    fn task(i: u64, files: &[u32]) -> Task {
        Task {
            id: TaskId(i),
            files: files.iter().map(|&f| FileId(f)).collect(),
            compute: Micros::ZERO,
            arrival: Micros::ZERO,
        }
    }

    fn push(
        q: &mut WaitQueue,
        p: &mut PendingIndex,
        ix: &LocationIndex,
        t: Task,
    ) -> QueueRef {
        let r = q.push_back(t);
        p.on_push(q, r, ix);
        r
    }

    #[test]
    fn seqset_matches_btreemap_semantics() {
        use crate::util::proptest::{property, Gen};
        use std::collections::BTreeMap;
        property("seqset vs btreemap", 100, |g: &mut Gen| {
            let mut q = WaitQueue::new();
            let refs: Vec<QueueRef> = (0..8)
                .map(|i| q.push_back(task(i, &[0])))
                .collect();
            let mut fast = SeqSet::default();
            let mut slow: BTreeMap<u64, QueueRef> = BTreeMap::new();
            for _ in 0..g.usize_in(1..300) {
                let seq = g.u64_in(0..32);
                let r = refs[g.usize_in(0..refs.len())];
                if g.bool(0.6) {
                    if fast.insert(seq, r) != slow.insert(seq, r).is_none() {
                        return Err(format!("insert({seq}) disagreed"));
                    }
                } else if fast.remove(seq) != slow.remove(&seq).is_some() {
                    return Err(format!("remove({seq}) disagreed"));
                }
                if fast.len() != slow.len() {
                    return Err(format!("len {} != {}", fast.len(), slow.len()));
                }
                let a: Vec<(u64, QueueRef)> = fast.iter().collect();
                let b: Vec<(u64, QueueRef)> = slow.iter().map(|(&s, &r)| (s, r)).collect();
                if a != b {
                    return Err(format!("order {a:?} != {b:?}"));
                }
                if fast.first() != b.first().copied() {
                    return Err("first() disagreed".into());
                }
                let probe = g.u64_in(0..32);
                if fast.contains(probe) != slow.contains_key(&probe) {
                    return Err(format!("contains({probe}) disagreed"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn candidates_follow_index_adds_and_evictions() {
        // Fan-outs below FANOUT_CAP apply immediately even in lazy mode,
        // so small scenarios behave exactly like the eager reference.
        let mut q = WaitQueue::new();
        let mut p = PendingIndex::new();
        let mut ix = LocationIndex::new();
        let e = ExecutorId(3);

        let r = push(&mut q, &mut p, &ix, task(0, &[7]));
        assert!(p.candidates(e).is_none_or(|s| s.is_empty()));

        ix.add(FileId(7), e);
        p.on_index_add(FileId(7), e);
        assert_eq!(p.candidates(e).unwrap().len(), 1);

        ix.remove(FileId(7), e);
        p.on_index_remove(FileId(7), e, &q, &ix);
        assert!(p.candidates(e).unwrap().is_empty());
        p.check_consistent(&q, &ix).unwrap();

        // Removal cleans by_file.
        let seq = q.seq_of(r);
        let t = q.remove(r);
        p.on_remove(&t.files, seq, &ix);
        assert_eq!(p.distinct_pending_files(), 0);
    }

    #[test]
    fn multi_file_task_stays_candidate_after_partial_eviction() {
        let mut q = WaitQueue::new();
        let mut p = PendingIndex::new();
        let mut ix = LocationIndex::new();
        let e = ExecutorId(0);
        ix.add(FileId(1), e);
        ix.add(FileId(2), e);
        push(&mut q, &mut p, &ix, task(0, &[1, 2]));
        assert_eq!(p.candidates(e).unwrap().len(), 1);

        // Evict file 1: the task still reads file 2, cached at e.
        ix.remove(FileId(1), e);
        p.on_index_remove(FileId(1), e, &q, &ix);
        assert_eq!(p.candidates(e).unwrap().len(), 1);

        // Evict file 2 too: no longer a candidate.
        ix.remove(FileId(2), e);
        p.on_index_remove(FileId(2), e, &q, &ix);
        assert!(p.candidates(e).unwrap().is_empty());
        p.check_consistent(&q, &ix).unwrap();
    }

    #[test]
    fn remove_queued_keeps_everything_coherent() {
        let mut q = WaitQueue::new();
        let mut p = PendingIndex::new();
        let mut ix = LocationIndex::new();
        ix.add(FileId(5), ExecutorId(1));
        let a = push(&mut q, &mut p, &ix, task(0, &[5]));
        let _b = push(&mut q, &mut p, &ix, task(1, &[5]));
        let t = remove_queued(&mut q, &mut p, a, &ix);
        assert_eq!(t.id, TaskId(0));
        assert_eq!(p.candidates(ExecutorId(1)).unwrap().len(), 1);
        p.check_consistent(&q, &ix).unwrap();
    }

    /// Hot-file events (readers > FANOUT_CAP) must become O(1) dirty
    /// records, with add/evict cycles coalescing at the refresh.
    #[test]
    fn hot_file_defers_and_coalesces() {
        let mut q = WaitQueue::new();
        let mut p = PendingIndex::new();
        let mut ix = LocationIndex::new();
        let e = ExecutorId(0);
        let hot = FileId(9);
        let readers = (FANOUT_CAP + 4) as u64;
        for i in 0..readers {
            push(&mut q, &mut p, &ix, task(i, &[9]));
        }
        let epoch0 = p.epoch();

        // Churn the hot file several times between consults: every event
        // is a deferral, not a fan-out.
        for _ in 0..5 {
            ix.add(hot, e);
            p.on_index_add(hot, e);
            ix.remove(hot, e);
            p.on_index_remove(hot, e, &q, &ix);
        }
        ix.add(hot, e);
        p.on_index_add(hot, e);
        assert_eq!(p.stats.maintenance_ops, 0, "hot events must not fan out");
        assert_eq!(p.stats.dirty_records, 11);
        assert!(p.epoch() > epoch0);
        assert!(p.epoch_of(e).unwrap_or(0) < p.epoch(), "set is stale");

        // One refresh settles the whole cycle with one coalesced walk.
        p.refresh(e, &q, &ix);
        assert_eq!(p.candidates(e).unwrap().len(), readers as usize);
        assert_eq!(p.stats.maintenance_ops, readers);
        assert_eq!(p.stats.patched_files, 1);
        assert_eq!(p.epoch_of(e), Some(p.epoch()));
        p.check_consistent(&q, &ix).unwrap();
    }

    /// More than DIRTY_CAP distinct hot files abandon the patch log and
    /// rebuild the set from the executor's cache contents.
    #[test]
    fn overflow_triggers_rebuild() {
        let mut q = WaitQueue::new();
        let mut p = PendingIndex::new();
        let mut ix = LocationIndex::new();
        let e = ExecutorId(2);
        let nfiles = (DIRTY_CAP + 1) as u32;
        let readers_per_file = (FANOUT_CAP + 1) as u64;
        let mut id = 0u64;
        for f in 0..nfiles {
            for _ in 0..readers_per_file {
                push(&mut q, &mut p, &ix, task(id, &[f]));
                id += 1;
            }
        }
        for f in 0..nfiles {
            ix.add(FileId(f), e);
            p.on_index_add(FileId(f), e);
        }
        p.refresh(e, &q, &ix);
        assert_eq!(p.stats.epoch_rebuilds, 1);
        assert_eq!(
            p.candidates(e).unwrap().len(),
            (nfiles as u64 * readers_per_file) as usize
        );
        p.check_consistent(&q, &ix).unwrap();
    }

    /// Invariant 2: a task whose deferred eviction was never patched and
    /// which then left the queue lingers as a dead hint — skipped by
    /// read-time validation and removable via purge_dead.
    #[test]
    fn dead_hints_validate_and_purge() {
        let mut q = WaitQueue::new();
        let mut p = PendingIndex::new();
        let mut ix = LocationIndex::new();
        let e = ExecutorId(1);
        let hot = FileId(3);
        ix.add(hot, e);
        let readers = (FANOUT_CAP + 4) as u64;
        let refs: Vec<QueueRef> = (0..readers)
            .map(|i| push(&mut q, &mut p, &ix, task(i, &[3])))
            .collect();
        assert_eq!(p.candidates(e).unwrap().len(), readers as usize);

        // Evict the hot file (deferred), then dispatch one reader before
        // any refresh: its candidate entry cannot be found by the patch.
        ix.remove(hot, e);
        p.on_index_remove(hot, e, &q, &ix);
        let victim = refs[0];
        let seq = q.seq_of(victim);
        let t = remove_queued(&mut q, &mut p, victim, &ix);
        assert_eq!(t.id, TaskId(0));

        p.refresh(e, &q, &ix);
        let set = p.candidates(e).unwrap();
        assert_eq!(set.len(), 1, "only the dead hint survives the patch");
        let (dead_seq, dead_ref) = set.iter().next().unwrap();
        assert_eq!(dead_seq, seq);
        assert_ne!(q.live_seq(dead_ref), Some(dead_seq), "hint must be dead");
        // The consistency check ignores dead hints…
        p.check_consistent(&q, &ix).unwrap();
        // …and purge removes them for good, counting each drop once
        // (repeat purges of the same seq are not double-counted).
        p.purge_dead(e, &[dead_seq]);
        assert!(p.candidates(e).unwrap().is_empty());
        assert_eq!(p.stats.dead_hints_purged, 1);
        p.purge_dead(e, &[dead_seq]);
        assert_eq!(p.stats.dead_hints_purged, 1);
    }

    /// Satellite: deregistration parks the candidate set (capacity and
    /// all) and the next registration recycles it instead of allocating.
    #[test]
    fn deregister_parks_set_for_reuse() {
        let mut q = WaitQueue::new();
        let mut p = PendingIndex::new();
        let mut ix = LocationIndex::new();
        let e0 = ExecutorId(0);
        ix.add(FileId(3), e0);
        push(&mut q, &mut p, &ix, task(0, &[3]));
        assert_eq!(p.candidates(e0).unwrap().len(), 1);
        assert_eq!(p.stats.slab_reuse, 0);

        ix.deregister_executor(e0);
        p.on_deregister(e0);
        assert!(p.candidates(e0).is_none(), "state dropped");

        // A different executor registering pops the pooled set.
        let e1 = ExecutorId(1);
        ix.add(FileId(3), e1);
        p.on_index_add(FileId(3), e1);
        assert_eq!(p.stats.slab_reuse, 1, "pooled set recycled");
        assert_eq!(p.candidates(e1).unwrap().len(), 1);
        p.check_consistent(&q, &ix).unwrap();
    }

    /// Satellite: leave-queue churn must not grow the tables — removed
    /// entries hand their slots back in place, so the capacity-based
    /// footprint plateaus at the first round's high-water mark.
    #[test]
    fn churn_does_not_grow_tables() {
        let mut q = WaitQueue::new();
        let mut p = PendingIndex::new();
        let mut ix = LocationIndex::new();
        let e = ExecutorId(0);
        ix.add(FileId(1), e);
        let mut id = 0u64;
        let mut high_water = 0u64;
        for round in 0..50 {
            let refs: Vec<QueueRef> = (0..12)
                .map(|_| {
                    id += 1;
                    push(&mut q, &mut p, &ix, task(id, &[1]))
                })
                .collect();
            for r in refs {
                remove_queued(&mut q, &mut p, r, &ix);
            }
            let bytes = p.table_bytes();
            if round < 2 {
                high_water = high_water.max(bytes);
            } else {
                assert!(
                    bytes <= high_water,
                    "round {round}: tables grew {bytes} > {high_water}"
                );
            }
        }
        assert!(p.candidates(e).unwrap().is_empty());
        p.check_consistent(&q, &ix).unwrap();
    }

    #[test]
    fn notify_memo_reuses_until_epoch_moves() {
        let mut p = PendingIndex::new();
        let mut ix = LocationIndex::new();
        ix.add(FileId(1), ExecutorId(0));
        ix.add(FileId(1), ExecutorId(2));
        ix.add(FileId(2), ExecutorId(2));
        let files = [FileId(1), FileId(2)];
        let ranked: Vec<(ExecutorId, u32)> = p.head_ranked(&files, &ix).to_vec();
        // Executor 2 holds both files, executor 0 one; ids break ties.
        assert_eq!(ranked, vec![(ExecutorId(2), 2), (ExecutorId(0), 1)]);
        let _ = p.head_ranked(&files, &ix);
        assert_eq!(p.stats.notify_memo_builds, 1);
        assert_eq!(p.stats.notify_memo_hits, 1);

        // A different head misses; the epoch moving misses again.
        let _ = p.head_ranked(&[FileId(2)], &ix);
        assert_eq!(p.stats.notify_memo_builds, 2);
        ix.add(FileId(2), ExecutorId(1));
        p.on_index_add(FileId(2), ExecutorId(1));
        let ranked: Vec<(ExecutorId, u32)> = p.head_ranked(&[FileId(2)], &ix).to_vec();
        assert_eq!(p.stats.notify_memo_builds, 3);
        assert_eq!(ranked, vec![(ExecutorId(1), 1), (ExecutorId(2), 1)]);
    }

    #[test]
    fn eager_mode_matches_old_behavior_and_counts_ops() {
        let mut q = WaitQueue::new();
        let mut p = PendingIndex::eager();
        let mut ix = LocationIndex::new();
        assert_eq!(p.mode(), Maintenance::Eager);
        let e = ExecutorId(0);
        let readers = (FANOUT_CAP + 10) as u64;
        for i in 0..readers {
            push(&mut q, &mut p, &ix, task(i, &[1]));
        }
        ix.add(FileId(1), e);
        p.on_index_add(FileId(1), e);
        // Eager: the fan-out happens at event time, however hot the file.
        assert_eq!(p.candidates(e).unwrap().len(), readers as usize);
        assert_eq!(p.stats.maintenance_ops, readers);
        assert_eq!(p.stats.dirty_records, 0);
        ix.remove(FileId(1), e);
        p.on_index_remove(FileId(1), e, &q, &ix);
        assert!(p.candidates(e).unwrap().is_empty());
        assert_eq!(p.stats.maintenance_ops, 2 * readers);
        p.check_consistent(&q, &ix).unwrap();
    }

    #[test]
    fn incremental_matches_rebuild_under_random_ops() {
        use crate::util::proptest::{property, Gen};
        for eager in [false, true] {
            property("pending index vs rebuild", 60, |g: &mut Gen| {
                let mut q = WaitQueue::new();
                let mut p = if eager {
                    PendingIndex::eager()
                } else {
                    PendingIndex::new()
                };
                let mut ix = LocationIndex::new();
                let mut live: Vec<QueueRef> = Vec::new();
                let mut next_id = 0u64;
                for _ in 0..g.usize_in(1..120) {
                    match g.usize_in(0..7) {
                        0 | 1 => {
                            let nfiles = g.usize_in(1..4);
                            let files: Vec<u32> =
                                (0..nfiles).map(|_| g.u64_in(0..12) as u32).collect();
                            let r = push(&mut q, &mut p, &ix, task(next_id, &files));
                            live.push(r);
                            next_id += 1;
                        }
                        2 => {
                            let f = FileId(g.u64_in(0..12) as u32);
                            let e = ExecutorId(g.u64_in(0..6) as u32);
                            ix.add(f, e);
                            p.on_index_add(f, e);
                        }
                        3 => {
                            let f = FileId(g.u64_in(0..12) as u32);
                            let e = ExecutorId(g.u64_in(0..6) as u32);
                            ix.remove(f, e);
                            p.on_index_remove(f, e, &q, &ix);
                        }
                        4 if !live.is_empty() => {
                            let i = g.usize_in(0..live.len());
                            let r = live.swap_remove(i);
                            remove_queued(&mut q, &mut p, r, &ix);
                        }
                        5 => {
                            // Deregistration drops every (f, e) pair at once;
                            // by_file is untouched by design.
                            let e = ExecutorId(g.u64_in(0..6) as u32);
                            ix.deregister_executor(e);
                            p.on_deregister(e);
                        }
                        6 => {
                            // Mid-stream consult: settle one executor's debt.
                            let e = ExecutorId(g.u64_in(0..6) as u32);
                            p.refresh(e, &q, &ix);
                        }
                        _ => {}
                    }
                    p.check_consistent(&q, &ix)?;
                }
                Ok(())
            });
        }
    }

    // ---- adaptive-caps suite ----

    /// Drive many cache events per consult: caps must walk monotonically
    /// to (FANOUT_CAP_MIN, DIRTY_CAP_MAX) and stop at the bounds.
    #[test]
    fn event_heavy_regime_defers_harder() {
        let q = WaitQueue::new();
        let mut p = PendingIndex::new();
        let mut ix = LocationIndex::new();
        let e = ExecutorId(0);
        p.set_adapt_window(4);
        assert_eq!(p.fanout_cap(), FANOUT_CAP);
        assert_eq!(p.dirty_cap(), DIRTY_CAP);
        let mut last = (p.fanout_cap(), p.dirty_cap());
        for round in 0..8 {
            // 40 events per 4 consults: ratio 10 ≥ 4 → defer harder.
            for i in 0..20u32 {
                let f = FileId(i % 6);
                ix.add(f, e);
                p.on_index_add(f, e);
                ix.remove(f, e);
                p.on_index_remove(f, e, &q, &ix);
            }
            for _ in 0..4 {
                p.refresh(e, &q, &ix);
            }
            assert!(p.fanout_cap() <= last.0, "round {round}: fan-out cap rose");
            assert!(p.dirty_cap() >= last.1, "round {round}: dirty budget fell");
            assert!(p.fanout_cap() >= FANOUT_CAP_MIN, "below floor");
            assert!(p.dirty_cap() <= DIRTY_CAP_MAX, "above ceiling");
            last = (p.fanout_cap(), p.dirty_cap());
        }
        assert_eq!(p.fanout_cap(), FANOUT_CAP_MIN, "converged to floor");
        assert_eq!(p.dirty_cap(), DIRTY_CAP_MAX, "converged to ceiling");
        // fanout 16→8 in one step; dirty 32→64→128 in two; at the bounds
        // further windows change nothing (and are not counted).
        assert_eq!(p.stats.cap_adaptations, 2);
    }

    /// Consults with no events: caps must walk the other way, to
    /// (FANOUT_CAP_MAX, DIRTY_CAP_MIN), and stay bounded.
    #[test]
    fn consult_heavy_regime_applies_eagerly() {
        let q = WaitQueue::new();
        let mut p = PendingIndex::new();
        let ix = LocationIndex::new();
        let e = ExecutorId(0);
        p.set_adapt_window(4);
        for _ in 0..6 {
            for _ in 0..4 {
                p.refresh(e, &q, &ix);
            }
            assert!(p.fanout_cap() <= FANOUT_CAP_MAX);
            assert!(p.dirty_cap() >= DIRTY_CAP_MIN);
        }
        assert_eq!(p.fanout_cap(), FANOUT_CAP_MAX, "converged to ceiling");
        assert_eq!(p.dirty_cap(), DIRTY_CAP_MIN, "converged to floor");
        assert_eq!(p.stats.cap_adaptations, 2);
    }

    /// Between the thresholds (½ < events/consults < 4) nothing adapts.
    #[test]
    fn balanced_regime_leaves_caps_alone() {
        let q = WaitQueue::new();
        let mut p = PendingIndex::new();
        let mut ix = LocationIndex::new();
        let e = ExecutorId(0);
        p.set_adapt_window(4);
        for _ in 0..6 {
            // 8 events per 4 consults: ratio 2 — inside the dead band.
            for i in 0..4u32 {
                let f = FileId(i);
                ix.add(f, e);
                p.on_index_add(f, e);
                ix.remove(f, e);
                p.on_index_remove(f, e, &q, &ix);
            }
            for _ in 0..4 {
                p.refresh(e, &q, &ix);
            }
        }
        assert_eq!(p.fanout_cap(), FANOUT_CAP);
        assert_eq!(p.dirty_cap(), DIRTY_CAP);
        assert_eq!(p.stats.cap_adaptations, 0);
    }

    /// An adapting index and a fixed-cap index driven by the same op
    /// stream must expose identical live candidate sets at every consult
    /// — caps reschedule maintenance, they never change results.
    #[test]
    fn adapted_caps_keep_dispatch_bit_identical() {
        use crate::util::proptest::{property, Gen};

        fn live(p: &PendingIndex, e: ExecutorId, q: &WaitQueue) -> Vec<u64> {
            p.candidates(e)
                .map(|set| {
                    set.iter()
                        .filter(|&(s, r)| q.live_seq(r) == Some(s))
                        .map(|(s, _)| s)
                        .collect()
                })
                .unwrap_or_default()
        }

        property("adaptive caps parity", 40, |g: &mut Gen| {
            let mut q = WaitQueue::new();
            let mut adapting = PendingIndex::new();
            adapting.set_adapt_window(3);
            let mut fixed = PendingIndex::new();
            let mut ix = LocationIndex::new();
            let mut live_refs: Vec<QueueRef> = Vec::new();
            let mut next_id = 0u64;
            for _ in 0..g.usize_in(20..150) {
                match g.usize_in(0..6) {
                    0 | 1 => {
                        let f = g.u64_in(0..8) as u32;
                        let r = q.push_back(task(next_id, &[f]));
                        next_id += 1;
                        adapting.on_push(&q, r, &ix);
                        fixed.on_push(&q, r, &ix);
                        live_refs.push(r);
                    }
                    2 if !live_refs.is_empty() => {
                        let i = g.usize_in(0..live_refs.len());
                        let r = live_refs.swap_remove(i);
                        let seq = q.seq_of(r);
                        let t = q.remove(r);
                        adapting.on_remove(&t.files, seq, &ix);
                        fixed.on_remove(&t.files, seq, &ix);
                    }
                    3 => {
                        let f = FileId(g.u64_in(0..8) as u32);
                        let e = ExecutorId(g.u64_in(0..3) as u32);
                        ix.add(f, e);
                        adapting.on_index_add(f, e);
                        fixed.on_index_add(f, e);
                    }
                    4 => {
                        let f = FileId(g.u64_in(0..8) as u32);
                        let e = ExecutorId(g.u64_in(0..3) as u32);
                        ix.remove(f, e);
                        adapting.on_index_remove(f, e, &q, &ix);
                        fixed.on_index_remove(f, e, &q, &ix);
                    }
                    _ => {
                        let e = ExecutorId(g.u64_in(0..3) as u32);
                        adapting.refresh(e, &q, &ix);
                        fixed.refresh(e, &q, &ix);
                        let a = live(&adapting, e, &q);
                        let b = live(&fixed, e, &q);
                        if a != b {
                            return Err(format!(
                                "consult diverged for {e}: adaptive {a:?} != fixed {b:?} \
                                 (caps {}/{})",
                                adapting.fanout_cap(),
                                adapting.dirty_cap()
                            ));
                        }
                    }
                }
                let fc = adapting.fanout_cap();
                let dc = adapting.dirty_cap();
                if !(FANOUT_CAP_MIN..=FANOUT_CAP_MAX).contains(&fc) {
                    return Err(format!("fan-out cap {fc} out of bounds"));
                }
                if !(DIRTY_CAP_MIN..=DIRTY_CAP_MAX).contains(&dc) {
                    return Err(format!("dirty budget {dc} out of bounds"));
                }
            }
            for i in 0..3 {
                let e = ExecutorId(i);
                adapting.refresh(e, &q, &ix);
                fixed.refresh(e, &q, &ix);
                let a = live(&adapting, e, &q);
                let b = live(&fixed, e, &q);
                if a != b {
                    return Err(format!("final diverged for {e}: {a:?} != {b:?}"));
                }
            }
            adapting
                .check_consistent(&q, &ix)
                .map_err(|err| format!("adaptive inconsistent: {err}"))?;
            Ok(())
        });
    }
}
